//! Memory survey: the Fig-1 / Fig-3 / Table-8 view of the design space.
//!
//!   cargo run --release --example memory_survey
//!
//! Prints the Appendix-F estimate for every paper-scale preset × method,
//! plus the 8-bit and per-layer variants the paper combines for its
//! headline "73% memory reduction on LLaMA 7B" claim — all from the rust
//! estimator (no artifacts needed, covers sizes we cannot train here).

use sltrain::bench::{fmt, Table};
use sltrain::config::{preset, METHODS};
use sltrain::mem::{estimate, MemEstimate, MemOptions};

fn main() -> anyhow::Result<()> {
    let sizes = ["paper60m", "paper130m", "paper350m", "paper1b", "spec7b"];

    let mut t = Table::new(
        "Estimated memory (param + optimizer, bf16) — paper Table 2 'Mem' column",
        &["size", "full", "lowrank", "relora", "galore", "sltrain"],
    );
    for s in sizes {
        let p = preset(s).unwrap();
        let mut row = vec![s.to_string()];
        for m in ["full", "lowrank", "relora", "galore", "sltrain"] {
            let e = estimate(&p, m, MemOptions::default());
            row.push(fmt(MemEstimate::gb(e.table2_bytes()), 2));
        }
        t.row(row);
    }
    t.print();

    let mut t2 = Table::new(
        "Training footprint w/ grads, 8-bit Adam + per-layer updates (Fig 3 model)",
        &["size", "full+Adam", "full+8bit", "galore+8bit+pl", "sltrain+8bit+pl", "sltrain cut vs full"],
    );
    for s in sizes {
        let p = preset(s).unwrap();
        let base = estimate(&p, "full", MemOptions::default()).train_bytes();
        let f8 = estimate(&p, "full", MemOptions { eight_bit: true, per_layer: false })
            .train_bytes();
        let g8 = estimate(&p, "galore", MemOptions { eight_bit: true, per_layer: true })
            .train_bytes();
        let s8 = estimate(&p, "sltrain", MemOptions { eight_bit: true, per_layer: true })
            .train_bytes();
        t2.row(vec![
            s.to_string(),
            fmt(MemEstimate::gb(base), 2),
            fmt(MemEstimate::gb(f8), 2),
            fmt(MemEstimate::gb(g8), 2),
            fmt(MemEstimate::gb(s8), 2),
            format!("{:.0}%", 100.0 * (1.0 - s8 / base)),
        ]);
    }
    t2.print();

    // the paper's headline: 7B with quantization + per-layer updates
    let p7 = preset("spec7b").unwrap();
    let full = estimate(&p7, "full", MemOptions::default()).train_bytes();
    let slt = estimate(&p7, "sltrain", MemOptions { eight_bit: true, per_layer: true })
        .train_bytes();
    println!(
        "\nLLaMA 7B headline: SLTrain(8-bit, per-layer) {:.1}G vs full-rank Adam {:.1}G -> {:.0}% reduction (paper reports up to 73%)",
        MemEstimate::gb(slt),
        MemEstimate::gb(full),
        100.0 * (1.0 - slt / full)
    );

    // parameter-count view (Fig 1 x-axis)
    let mut t3 = Table::new(
        "Trainable parameters (M) — Fig-1 circle sizes",
        &["size", "full", "lowrank", "relora", "galore", "sltrain"],
    );
    for s in sizes {
        let p = preset(s).unwrap();
        let mut row = vec![s.to_string()];
        for m in METHODS {
            row.push(fmt(p.param_count(m) as f64 / 1e6, 1));
        }
        t3.row(row);
    }
    t3.print();
    Ok(())
}

//! Quickstart: pretrain a tiny LLaMA with SLTrain in under a minute.
//!
//!   make artifacts && cargo build --release
//!   cargo run --release --example quickstart
//!
//! Loads the `tiny_sltrain` artifact (W = BA ⊕_I V on every linear),
//! streams the synthetic corpus through the rust data pipeline, runs the
//! AOT train-step, and prints the loss curve — no Python anywhere.

use anyhow::Result;
use sltrain::coordinator::{train, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::runtime::{Artifact, Runtime};

fn main() -> Result<()> {
    let rt = Runtime::cpu()?;
    let dir = std::path::Path::new("artifacts/tiny_sltrain");
    let mut art = Artifact::load(dir)?;
    println!(
        "model: {} ({} params: {:.2}M), method: {}, optimizer: {}",
        art.manifest.preset.name,
        art.manifest.params.len(),
        art.manifest.n_params as f64 / 1e6,
        art.manifest.method,
        art.manifest.optimizer,
    );

    let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);
    let cfg = TrainConfig {
        steps: 100,
        eval_every: 25,
        eval_batches: 4,
        log_every: 10,
        ..Default::default()
    };
    let r = train(&rt, &mut art, &mut pipe, &cfg)?;

    println!("\nloss curve (every 10 steps):");
    for (step, loss) in r.train_curve.points.iter().step_by(10) {
        let bar = "#".repeat((loss * 8.0) as usize);
        println!("  {step:>4} {loss:>7.4} {bar}");
    }
    println!(
        "\nfinal eval ppl {:.2} | {:.0} tok/s | sltrain params {:.2}M vs full-rank {:.2}M",
        r.final_ppl,
        r.tokens_per_sec,
        art.manifest.n_params as f64 / 1e6,
        art.manifest.preset.param_count("full") as f64 / 1e6,
    );
    Ok(())
}

//! Quickstart: pretrain a tiny LLaMA with SLTrain in under a minute —
//! no artifacts, no XLA, no Python.
//!
//!   cargo run --release --example quickstart
//!
//! Builds the pure-rust native backend (W = scale·BA ⊕_I V on every
//! linear, Adam over {B, A, V}), streams the synthetic corpus through
//! the rust data pipeline, and prints the loss curve. Pass
//! `--backend xla --artifact artifacts/tiny_sltrain` (with the `xla`
//! cargo feature) to run the same loop on an AOT artifact bundle.

use anyhow::Result;
use sltrain::backend::{self, BackendSpec};
use sltrain::coordinator::{train, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::util::cli::Cli;

fn main() -> Result<()> {
    let a = Cli::new("quickstart", "tiny SLTrain pretraining, artifact-free")
        .opt("backend", "native", "engine: native | xla")
        .opt("artifact", "", "artifact dir (xla backend)")
        .opt("config", "tiny", "model preset (native backend)")
        .opt("method", "sltrain", "weight parameterization (native backend)")
        .opt("steps", "100", "optimizer steps")
        .opt("threads", "0", "step-loop worker threads (native backend, 0 = auto)")
        .opt("optim-bits", "0", "Adam moment precision: 32 | 8 (native backend, 0 = auto)")
        .opt("galore-every", "0", "GaLore projector refresh period (0 = default 200)")
        .opt("support", "random", "sltrain support pattern: random | n:m, e.g. 2:4 (native backend)")
        .parse_env();
    let steps = a.usize("steps");
    let spec = BackendSpec::from_flags(
        &a.str("backend"),
        &a.str("artifact"),
        &a.str("config"),
        &a.str("method"),
        8,
        3e-3,
        steps.max(1),
        a.usize("threads"),
        a.usize("optim-bits"),
        a.usize("galore-every"),
        &a.str("support"),
        0, // workers: single-engine (see `train --workers`)
    )?;
    let mut be = backend::open(spec)?;
    println!(
        "model: {} ({:.2}M params), method: {}, backend: {}, optimizer: {}",
        be.preset().name,
        be.n_params() as f64 / 1e6,
        be.method(),
        be.kind(),
        be.optimizer(),
    );

    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    let cfg = TrainConfig {
        steps,
        eval_every: 25,
        eval_batches: 4,
        log_every: 10,
        ..Default::default()
    };
    let r = train(be.as_mut(), &mut pipe, &cfg)?;

    println!("\nloss curve (every 10 steps):");
    for (step, loss) in r.train_curve.points.iter().step_by(10) {
        let bar = "#".repeat((loss * 8.0) as usize);
        println!("  {step:>4} {loss:>7.4} {bar}");
    }
    println!(
        "\nfinal eval ppl {:.2} | {:.0} tok/s | {} params {:.2}M vs full-rank {:.2}M",
        r.final_ppl,
        r.tokens_per_sec,
        be.method(),
        r.n_params as f64 / 1e6,
        be.preset().param_count("full") as f64 / 1e6,
    );
    Ok(())
}

//! Support ablation (paper Fig 4): pretrain SLTrain with five different
//! random sparse supports and show the convergence curves coincide —
//! the evidence that a *random fixed* support is enough (no pruning, no
//! support learning).
//!
//!   make artifacts  (plus the _supN variants, see Makefile bench target)
//!   cargo run --release --example support_ablation -- --steps 150

use anyhow::Result;
use sltrain::backend::xla_backend::XlaBackend;
use sltrain::backend::Backend;
use sltrain::bench::{fmt, Table};
use sltrain::coordinator::metrics::stats;
use sltrain::coordinator::{train, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::util::cli::Cli;

fn main() -> Result<()> {
    let a = Cli::new("support_ablation", "Fig-4 random-support robustness")
        .opt("steps", "150", "steps per run")
        .opt("root", "artifacts", "artifacts root")
        .parse_env();
    let steps = a.usize("steps");

    let mut finals = vec![];
    let mut curves = vec![];
    for seed in 1..=5 {
        let dir = format!("{}/tiny_sltrain_sup{seed}", a.str("root"));
        let path = std::path::Path::new(&dir);
        if !path.exists() {
            println!("[skip] {dir} not emitted — run `make bench-artifacts` first");
            continue;
        }
        let mut be = XlaBackend::open(path)?;
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        let cfg = TrainConfig {
            steps,
            eval_every: steps / 3,
            eval_batches: 4,
            log_every: 0,
            ..Default::default()
        };
        let r = train(&mut be, &mut pipe, &cfg)?;
        println!("support seed {seed}: final eval ppl {:.2}", r.final_ppl);
        finals.push(r.final_ppl);
        curves.push((seed, r.eval_curve));
    }
    if finals.is_empty() {
        anyhow::bail!("no tiny_sltrain_sup* artifacts found");
    }

    let mut t = Table::new(
        "Fig 4 — eval ppl across random supports (same data, same init seed)",
        &["step", "sup1", "sup2", "sup3", "sup4", "sup5"],
    );
    let n_points = curves[0].1.points.len();
    for i in 0..n_points {
        let step = curves[0].1.points[i].0;
        let mut row = vec![step.to_string()];
        for (_, c) in &curves {
            row.push(fmt(c.points.get(i).map(|&(_, l)| l.exp()).unwrap_or(f64::NAN), 2));
        }
        t.row(row);
    }
    t.print();

    let s = stats(&finals);
    println!(
        "\nfinal ppl across supports: mean {:.2} ± {:.2} (spread {:.1}% — the paper's claim: support choice does not materially matter)",
        s.mean,
        s.std,
        100.0 * s.std / s.mean
    );
    Ok(())
}

//! End-to-end driver: pretrain a LLaMA with SLTrain for a few hundred
//! steps on the synthetic corpus, logging the loss curve, checkpointing,
//! and reporting throughput + memory. This is the deliverable-(e2e) run
//! recorded in EXPERIMENTS.md.
//!
//!   cargo run --release --example pretrain_e2e -- --steps 300
//!   # xla build: make artifacts-extended, then
//!   cargo run --release --features xla --example pretrain_e2e -- \
//!       --backend xla --artifact artifacts/e2e100m_sltrain
//!
//! Defaults to the pure-rust native backend on the `tiny2` preset (no
//! artifacts needed); the xla backend runs the JAX-lowered ~100M-param
//! artifact with the Pallas-verified SLTrain linear math inside.

use anyhow::Result;
use sltrain::backend::{self, BackendSpec};
use sltrain::coordinator::{train, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::mem::{estimate, MemEstimate, MemOptions};
use sltrain::util::cli::Cli;

fn main() -> Result<()> {
    let a = Cli::new("pretrain_e2e", "end-to-end SLTrain pretraining run")
        .opt("backend", "native", "engine: native | xla")
        .opt("artifact", "", "artifact dir (xla backend)")
        .opt("config", "tiny2", "model preset (native backend)")
        .opt("steps", "300", "optimizer steps")
        .opt("eval-every", "50", "eval period")
        .opt("out", "runs/pretrain_e2e", "output dir (metrics + checkpoint)")
        .opt("threads", "0", "step-loop worker threads (native backend, 0 = auto)")
        .opt("optim-bits", "0", "Adam moment precision: 32 | 8 (native backend, 0 = auto)")
        .parse_env();

    let steps = a.usize("steps");
    let spec = BackendSpec::from_flags(
        &a.str("backend"),
        &a.str("artifact"),
        &a.str("config"),
        "sltrain",
        8,
        3e-3,
        steps.max(1),
        a.usize("threads"),
        a.usize("optim-bits"),
        0, // galore refresh: unused (this example trains sltrain)
        "random",
        0, // workers: single-engine (see `train --workers`)
    )?;
    let mut be = backend::open(spec)?;
    let p = be.preset().clone();
    println!(
        "=== e2e pretraining: {} [{}] | {:.1}M params (full-rank equivalent {:.1}M) ===",
        p.name,
        be.kind(),
        be.n_params() as f64 / 1e6,
        p.param_count("full") as f64 / 1e6
    );
    let est = estimate(&p, "sltrain", MemOptions::default());
    let est_full = estimate(&p, "full", MemOptions::default());
    println!(
        "estimated train memory (bf16 model): sltrain {:.3}G vs full-rank {:.3}G ({:.0}% cut)",
        MemEstimate::gb(est.table2_bytes()),
        MemEstimate::gb(est_full.table2_bytes()),
        100.0 * (1.0 - est.table2_bytes() / est_full.table2_bytes())
    );

    let out = std::path::PathBuf::from(a.str("out"));
    std::fs::create_dir_all(&out)?;
    let mut pipe = Pipeline::build(p.vocab, 7);
    let cfg = TrainConfig {
        steps,
        eval_every: a.usize("eval-every"),
        eval_batches: 2,
        log_every: 5,
        metrics_path: Some(out.join("metrics.jsonl")),
        checkpoint_path: Some(out.join("final.ckpt")),
        ..Default::default()
    };
    let r = train(be.as_mut(), &mut pipe, &cfg)?;

    println!("\n=== loss curve ===");
    for (step, loss) in r.train_curve.points.iter().step_by(10) {
        println!("  step {step:>5}: {loss:.4}");
    }
    println!("\n=== eval curve ===");
    for (step, loss) in &r.eval_curve.points {
        println!("  step {step:>5}: loss {loss:.4} ppl {:.2}", loss.exp());
    }
    println!(
        "\nsummary: final ppl {:.2} | {:.0} tok/s | {:.0}s wall | peak rss {:.0} MB",
        r.final_ppl,
        r.tokens_per_sec,
        r.wall_secs,
        r.peak_rss_bytes as f64 / 1e6
    );
    if let Some(m) = be.mem_report() {
        println!(
            "measured state: params {:.1} MB | optim {:.1} MB ({}-bit moments) | grad peak {:.1} MB",
            m.param_bytes as f64 / 1e6,
            m.optim_bytes as f64 / 1e6,
            m.optim_bits,
            m.grad_peak_bytes as f64 / 1e6
        );
    }
    std::fs::write(
        out.join("summary.json"),
        sltrain::coordinator::trainer::summary_json(
            &format!("{}_sltrain_{}", p.name, be.kind()),
            &r,
        )
        .to_string(),
    )?;
    println!("metrics: {:?}", out.join("metrics.jsonl"));
    Ok(())
}

//! Black-box tests of the serving daemon: the real `sltrain serve`
//! binary, spawned per test, spoken to over its Unix socket through
//! `support::harness`. Everything asynchronous is awaited by
//! deadline-poll (see `support/mod.rs`) — no fixed sleeps.

mod support;

use std::process::Command;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use support::harness::{deadline_poll, signal_pid, Client, Daemon, DEADLINE};

/// Full lifecycle: start → ping/info → prefill+decode (generate) →
/// evict (second generate reuses the slot) → clean shutdown, exit 0,
/// socket unlinked.
#[test]
fn daemon_lifecycle_start_generate_shutdown() {
    let mut daemon = Daemon::spawn(&[]);
    let mut c = daemon.connect();

    let pong = c.request(r#"{"op":"ping"}"#);
    assert_eq!(pong.get("ok").and_then(|o| o.as_bool()), Some(true));
    assert_eq!(pong.get("op").and_then(|o| o.as_str()), Some("pong"));

    let info = c.request(r#"{"op":"info"}"#);
    assert_eq!(info.get("ok").and_then(|o| o.as_bool()), Some(true));
    assert_eq!(info.get("preset").and_then(|o| o.as_str()), Some("tiny"));
    assert_eq!(info.get("method").and_then(|o| o.as_str()), Some("sltrain"));
    // the daemon serves the Table-5 folded weights by default
    assert_eq!(info.get("folded").and_then(|o| o.as_bool()), Some(true));
    let vocab = info.get("vocab").and_then(|o| o.as_i64()).unwrap();
    assert!(vocab > 0);

    // prefill + incremental decode
    let r1 = c.generate(&[1, 2, 3], 5);
    let toks1 = Client::tokens_of(&r1);
    assert_eq!(toks1.len(), 5);
    assert_eq!(r1.get("prompt_len").and_then(|o| o.as_i64()), Some(3));
    assert!(toks1.iter().all(|&t| t >= 0 && t < vocab), "tokens out of vocab: {toks1:?}");

    // the finished sequence was evicted; its slot serves the next one
    let r2 = c.generate(&[4, 5], 3);
    assert_eq!(Client::tokens_of(&r2).len(), 3);

    // greedy decoding is deterministic: same prompt, same continuation
    let r3 = c.generate(&[1, 2, 3], 5);
    assert_eq!(Client::tokens_of(&r3), toks1, "same prompt must reproduce the continuation");

    let bye = c.request(r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").and_then(|o| o.as_bool()), Some(true));
    let status = daemon.wait_exit();
    assert!(status.success(), "daemon did not exit cleanly: {status}");
    assert!(!daemon.socket.exists(), "socket file not unlinked on shutdown");
}

/// Hostile input: malformed lines and invalid generates are answered
/// with `{"ok":false,...}` on the same connection — the daemon and the
/// connection both survive, and a valid request still works afterwards.
#[test]
fn malformed_requests_get_error_responses_not_a_dead_daemon() {
    let mut daemon = Daemon::spawn(&[]);
    let mut c = daemon.connect();

    for bad in [
        "this is not json",
        r#"{"op":"warp_core_breach"}"#,
        r#"{"op":"generate"}"#,
        r#"{"op":"generate","prompt":"abc"}"#,
        r#"{"op":"generate","prompt":[],"max_tokens":4}"#,
        r#"{"op":"generate","prompt":[999999],"max_tokens":4}"#,
        r#"{"op":"generate","prompt":[1],"max_tokens":0}"#,
    ] {
        let resp = c.request(bad);
        assert_eq!(
            resp.get("ok").and_then(|o| o.as_bool()),
            Some(false),
            "{bad:?} should have produced an error response, got {resp:?}"
        );
        assert!(resp.get("error").is_some(), "no error message for {bad:?}");
    }

    // the connection still serves valid traffic after every error
    let ok = c.generate(&[1, 2], 2);
    assert_eq!(Client::tokens_of(&ok).len(), 2);

    c.request(r#"{"op":"shutdown"}"#);
    assert!(daemon.wait_exit().success());
}

/// Continuous batching across connections: several clients in flight at
/// once, each getting the same continuation it would get alone (each
/// sequence has its own KV cache; batching cannot change outputs).
#[test]
fn concurrent_clients_share_the_decode_batch() {
    let mut daemon = Daemon::spawn(&["--max-batch", "2"]);

    // reference continuations, served solo
    let mut c0 = daemon.connect();
    let solo_a = Client::tokens_of(&c0.generate(&[1, 2, 3], 6));
    let solo_b = Client::tokens_of(&c0.generate(&[7, 8], 6));

    // now both at once from separate connections (2 slots: both admit)
    let mut ca = daemon.connect();
    let mut cb = daemon.connect();
    ca.send_raw(r#"{"op":"generate","prompt":[1,2,3],"max_tokens":6,"id":1}"#);
    cb.send_raw(r#"{"op":"generate","prompt":[7,8],"max_tokens":6,"id":2}"#);
    let ra = ca.recv();
    let rb = cb.recv();
    assert_eq!(Client::tokens_of(&ra), solo_a, "batched run changed client A's tokens");
    assert_eq!(Client::tokens_of(&rb), solo_b, "batched run changed client B's tokens");
    assert_eq!(ra.get("id").and_then(|o| o.as_i64()), Some(1));
    assert_eq!(rb.get("id").and_then(|o| o.as_i64()), Some(2));

    c0.request(r#"{"op":"shutdown"}"#);
    assert!(daemon.wait_exit().success());
}

/// The CI smoke (wired as a dedicated tier-1 step): train a short run
/// to a real SLTCKPT1 checkpoint through the CLI, serve it, answer 3
/// generate requests through the harness, shut down cleanly.
#[test]
fn serve_smoke_checkpoint_three_generates_clean_exit() {
    let dir = std::env::temp_dir().join(format!("sltrain-servesmoke-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("smoke.ckpt");
    let out = Command::new(env!("CARGO_BIN_EXE_sltrain"))
        .args([
            "train", "--backend", "native", "--config", "tiny", "--method", "sltrain",
            "--batch", "2", "--steps", "2", "--eval-every", "0", "--log-every", "0",
        ])
        .arg("--checkpoint")
        .arg(&ckpt)
        .output()
        .unwrap();
    assert!(out.status.success(), "train failed:\n{}", String::from_utf8_lossy(&out.stderr));

    let mut daemon = Daemon::spawn(&["--checkpoint", ckpt.to_str().unwrap()]);
    let mut c = daemon.connect();
    for prompt in [vec![1, 2, 3], vec![9], vec![4, 5, 6, 7]] {
        let resp = c.generate(&prompt, 4);
        let toks = Client::tokens_of(&resp);
        assert_eq!(toks.len(), 4, "prompt {prompt:?}");
    }
    c.request(r#"{"op":"shutdown"}"#);
    assert!(daemon.wait_exit().success(), "daemon did not exit cleanly after smoke");
    std::fs::remove_dir_all(dir).ok();
}

/// Admission control: with `--max-queue 1`, a generate arriving while
/// another occupies the slot is shed with the typed overloaded
/// response — immediately, not after an unbounded queue wait.
#[test]
fn overloaded_daemon_sheds_with_typed_response() {
    let mut daemon = Daemon::spawn(&["--max-queue", "1"]);

    // background client keeps long generates in flight
    let stop = Arc::new(AtomicBool::new(false));
    let bg_stop = stop.clone();
    let mut bg = daemon.connect();
    let bg_handle = std::thread::spawn(move || {
        while !bg_stop.load(Ordering::SeqCst) {
            // ok or shed, doesn't matter — keep the slot hot
            let _ = bg.generate(&[1, 2, 3], 32);
        }
    });

    // probe until we collide with an in-flight background generate
    let mut c = daemon.connect();
    let shed = deadline_poll("an overloaded shed response", DEADLINE, || {
        let resp = c.generate(&[4], 1);
        (resp.get("overloaded").and_then(|o| o.as_bool()) == Some(true)).then_some(resp)
    });
    assert_eq!(shed.get("ok").and_then(|o| o.as_bool()), Some(false));
    let msg = shed.get("error").and_then(|o| o.as_str()).unwrap_or_default();
    assert!(msg.contains("overloaded"), "shed response should say so: {shed:?}");

    stop.store(true, Ordering::SeqCst);
    bg_handle.join().unwrap();

    // shedding is per-request: the daemon still serves normally
    let ok = c.generate(&[5, 6], 2);
    assert_eq!(Client::tokens_of(&ok).len(), 2);
    c.request(r#"{"op":"shutdown"}"#);
    assert!(daemon.wait_exit().success());
}

/// SIGTERM honors the drain contract: an admitted in-flight generate
/// still gets its full response, then the daemon exits 0 and unlinks
/// the socket — exactly like a protocol `shutdown`. The `stats` op
/// proves the request is in flight before the signal goes out; if the
/// tiny model outruns the poll and finishes first, the test still
/// asserts the same response/exit contract rather than flaking.
#[test]
fn sigterm_drains_inflight_generate_and_exits_zero() {
    let mut daemon = Daemon::spawn(&[]);

    let mut gen_conn = daemon.connect();
    gen_conn.send_raw(r#"{"op":"generate","prompt":[1,2,3],"max_tokens":48,"id":7}"#);

    // wait until the generate is provably in flight — or already done
    let mut early: Option<sltrain::Json> = None;
    let mut stats_conn = daemon.connect();
    deadline_poll("the generate to be in flight (or finished)", DEADLINE, || {
        let st = stats_conn.request(r#"{"op":"stats"}"#);
        assert_eq!(st.get("ok").and_then(|o| o.as_bool()), Some(true));
        if st.get("inflight").and_then(|o| o.as_i64()).unwrap_or(0) >= 1 {
            return Some(());
        }
        early = gen_conn.try_recv_within(std::time::Duration::from_millis(20));
        early.as_ref().map(|_| ())
    });
    signal_pid(daemon.pid(), "TERM");

    let resp = early.unwrap_or_else(|| gen_conn.recv());
    let toks = Client::tokens_of(&resp);
    assert_eq!(toks.len(), 48, "drained generate must complete in full");
    assert_eq!(resp.get("id").and_then(|o| o.as_i64()), Some(7));

    let status = daemon.wait_exit();
    assert!(status.success(), "SIGTERM must exit 0, got {status}");
    assert!(!daemon.socket.exists(), "socket file not unlinked after SIGTERM drain");
}

/// Read-timeout semantics: a connection stalled mid-request-line is
/// dropped once the timeout fires, while an idle connection (no bytes
/// at all) survives arbitrarily long and still serves requests.
#[test]
fn read_timeout_drops_stalled_but_not_idle_connections() {
    let mut daemon = Daemon::spawn(&["--read-timeout", "1"]);

    let mut idle = daemon.connect();

    let mut stalled = daemon.connect();
    stalled.send_partial(r#"{"op":"pi"#); // no newline: a wedged peer
    // blocks until the daemon's ~1s timeout tick closes the connection
    assert!(stalled.wait_closed(), "stalled connection was not dropped");

    // the idle connection sat silent for longer than the timeout and
    // must still be alive
    let pong = idle.request(r#"{"op":"ping"}"#);
    assert_eq!(pong.get("op").and_then(|o| o.as_str()), Some("pong"));

    idle.request(r#"{"op":"shutdown"}"#);
    assert!(daemon.wait_exit().success());
}

//! Black-box tests of the serving daemon: the real `sltrain serve`
//! binary, spawned per test, spoken to over its Unix socket through
//! `support::harness`. Everything asynchronous is awaited by
//! deadline-poll (see `support/mod.rs`) — no fixed sleeps.

mod support;

use std::process::Command;

use support::harness::{Client, Daemon};

/// Full lifecycle: start → ping/info → prefill+decode (generate) →
/// evict (second generate reuses the slot) → clean shutdown, exit 0,
/// socket unlinked.
#[test]
fn daemon_lifecycle_start_generate_shutdown() {
    let mut daemon = Daemon::spawn(&[]);
    let mut c = daemon.connect();

    let pong = c.request(r#"{"op":"ping"}"#);
    assert_eq!(pong.get("ok").and_then(|o| o.as_bool()), Some(true));
    assert_eq!(pong.get("op").and_then(|o| o.as_str()), Some("pong"));

    let info = c.request(r#"{"op":"info"}"#);
    assert_eq!(info.get("ok").and_then(|o| o.as_bool()), Some(true));
    assert_eq!(info.get("preset").and_then(|o| o.as_str()), Some("tiny"));
    assert_eq!(info.get("method").and_then(|o| o.as_str()), Some("sltrain"));
    // the daemon serves the Table-5 folded weights by default
    assert_eq!(info.get("folded").and_then(|o| o.as_bool()), Some(true));
    let vocab = info.get("vocab").and_then(|o| o.as_i64()).unwrap();
    assert!(vocab > 0);

    // prefill + incremental decode
    let r1 = c.generate(&[1, 2, 3], 5);
    let toks1 = Client::tokens_of(&r1);
    assert_eq!(toks1.len(), 5);
    assert_eq!(r1.get("prompt_len").and_then(|o| o.as_i64()), Some(3));
    assert!(toks1.iter().all(|&t| t >= 0 && t < vocab), "tokens out of vocab: {toks1:?}");

    // the finished sequence was evicted; its slot serves the next one
    let r2 = c.generate(&[4, 5], 3);
    assert_eq!(Client::tokens_of(&r2).len(), 3);

    // greedy decoding is deterministic: same prompt, same continuation
    let r3 = c.generate(&[1, 2, 3], 5);
    assert_eq!(Client::tokens_of(&r3), toks1, "same prompt must reproduce the continuation");

    let bye = c.request(r#"{"op":"shutdown"}"#);
    assert_eq!(bye.get("ok").and_then(|o| o.as_bool()), Some(true));
    let status = daemon.wait_exit();
    assert!(status.success(), "daemon did not exit cleanly: {status}");
    assert!(!daemon.socket.exists(), "socket file not unlinked on shutdown");
}

/// Hostile input: malformed lines and invalid generates are answered
/// with `{"ok":false,...}` on the same connection — the daemon and the
/// connection both survive, and a valid request still works afterwards.
#[test]
fn malformed_requests_get_error_responses_not_a_dead_daemon() {
    let mut daemon = Daemon::spawn(&[]);
    let mut c = daemon.connect();

    for bad in [
        "this is not json",
        r#"{"op":"warp_core_breach"}"#,
        r#"{"op":"generate"}"#,
        r#"{"op":"generate","prompt":"abc"}"#,
        r#"{"op":"generate","prompt":[],"max_tokens":4}"#,
        r#"{"op":"generate","prompt":[999999],"max_tokens":4}"#,
        r#"{"op":"generate","prompt":[1],"max_tokens":0}"#,
    ] {
        let resp = c.request(bad);
        assert_eq!(
            resp.get("ok").and_then(|o| o.as_bool()),
            Some(false),
            "{bad:?} should have produced an error response, got {resp:?}"
        );
        assert!(resp.get("error").is_some(), "no error message for {bad:?}");
    }

    // the connection still serves valid traffic after every error
    let ok = c.generate(&[1, 2], 2);
    assert_eq!(Client::tokens_of(&ok).len(), 2);

    c.request(r#"{"op":"shutdown"}"#);
    assert!(daemon.wait_exit().success());
}

/// Continuous batching across connections: several clients in flight at
/// once, each getting the same continuation it would get alone (each
/// sequence has its own KV cache; batching cannot change outputs).
#[test]
fn concurrent_clients_share_the_decode_batch() {
    let mut daemon = Daemon::spawn(&["--max-batch", "2"]);

    // reference continuations, served solo
    let mut c0 = daemon.connect();
    let solo_a = Client::tokens_of(&c0.generate(&[1, 2, 3], 6));
    let solo_b = Client::tokens_of(&c0.generate(&[7, 8], 6));

    // now both at once from separate connections (2 slots: both admit)
    let mut ca = daemon.connect();
    let mut cb = daemon.connect();
    ca.send_raw(r#"{"op":"generate","prompt":[1,2,3],"max_tokens":6,"id":1}"#);
    cb.send_raw(r#"{"op":"generate","prompt":[7,8],"max_tokens":6,"id":2}"#);
    let ra = ca.recv();
    let rb = cb.recv();
    assert_eq!(Client::tokens_of(&ra), solo_a, "batched run changed client A's tokens");
    assert_eq!(Client::tokens_of(&rb), solo_b, "batched run changed client B's tokens");
    assert_eq!(ra.get("id").and_then(|o| o.as_i64()), Some(1));
    assert_eq!(rb.get("id").and_then(|o| o.as_i64()), Some(2));

    c0.request(r#"{"op":"shutdown"}"#);
    assert!(daemon.wait_exit().success());
}

/// The CI smoke (wired as a dedicated tier-1 step): train a short run
/// to a real SLTCKPT1 checkpoint through the CLI, serve it, answer 3
/// generate requests through the harness, shut down cleanly.
#[test]
fn serve_smoke_checkpoint_three_generates_clean_exit() {
    let dir = std::env::temp_dir().join(format!("sltrain-servesmoke-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt = dir.join("smoke.ckpt");
    let out = Command::new(env!("CARGO_BIN_EXE_sltrain"))
        .args([
            "train", "--backend", "native", "--config", "tiny", "--method", "sltrain",
            "--batch", "2", "--steps", "2", "--eval-every", "0", "--log-every", "0",
        ])
        .arg("--checkpoint")
        .arg(&ckpt)
        .output()
        .unwrap();
    assert!(out.status.success(), "train failed:\n{}", String::from_utf8_lossy(&out.stderr));

    let mut daemon = Daemon::spawn(&["--checkpoint", ckpt.to_str().unwrap()]);
    let mut c = daemon.connect();
    for prompt in [vec![1, 2, 3], vec![9], vec![4, 5, 6, 7]] {
        let resp = c.generate(&prompt, 4);
        let toks = Client::tokens_of(&resp);
        assert_eq!(toks.len(), 4, "prompt {prompt:?}");
    }
    c.request(r#"{"op":"shutdown"}"#);
    assert!(daemon.wait_exit().success(), "daemon did not exit cleanly after smoke");
    std::fs::remove_dir_all(dir).ok();
}

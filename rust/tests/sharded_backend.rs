//! End-to-end tests of the data-parallel `ShardedBackend`: the fourth
//! determinism axis (worker count), owner-sharded optimizer state,
//! cross-worker-count checkpoint portability, and the process transport
//! through the real CLI binary.
//!
//! The bitwise reference for this axis is the 1-worker sharded engine:
//! `--workers 1..N` are bit-identical to each other at every thread
//! count (the fixed-block tree reduction depends only on the batch).
//! The plain `--workers 0` engine computes the same math with a
//! different f32 re-association and is deliberately *not* compared here.

use std::path::PathBuf;
use std::process::Command;

use sltrain::backend::{self, Backend, BackendSpec};
use sltrain::config::preset;
use sltrain::coordinator::{train, Checkpoint, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::linalg::SupportPattern;

fn spec(method: &str, batch: usize, threads: usize, workers: usize) -> BackendSpec {
    BackendSpec::Native {
        preset: preset("tiny").unwrap(),
        method: method.to_string(),
        batch,
        lr: 3e-3,
        total_steps: 50,
        threads,
        optim_bits: 0,
        galore_every: 3, // refresh inside short runs
        support: SupportPattern::UniformRandom,
        workers,
    }
}

fn open(method: &str, batch: usize, threads: usize, workers: usize) -> Box<dyn Backend> {
    backend::open(spec(method, batch, threads, workers)).unwrap()
}

/// Full state snapshot in comparable form (name, shape, dtype, bytes).
fn snapshot(be: &mut dyn Backend) -> Vec<(String, Vec<usize>, String, Vec<u8>)> {
    be.state_tensors()
        .unwrap()
        .into_iter()
        .map(|t| (t.name, t.shape, format!("{:?}", t.dtype), t.bytes))
        .collect()
}

/// Train `steps` fresh steps and return (loss bit patterns, final state).
fn run(
    method: &str,
    batch: usize,
    threads: usize,
    workers: usize,
    steps: usize,
) -> (Vec<u64>, Vec<(String, Vec<usize>, String, Vec<u8>)>) {
    let mut be = open(method, batch, threads, workers);
    be.init_state(42).unwrap();
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    let mut losses = Vec::new();
    for step in 0..steps {
        let toks = pipe.train.next_batch(be.batch_size(), be.seq_len());
        losses.push(be.train_step(step as i32, &toks).unwrap().to_bits());
    }
    (losses, snapshot(be.as_mut()))
}

/// The tentpole contract: 1, 2 and 4 workers produce bit-identical
/// losses AND bit-identical full state snapshots (weights + owner-merged
/// optimizer moments), at 1 and 2 pool threads each.
#[test]
fn worker_count_never_changes_a_bit_sltrain() {
    let (ref_losses, ref_state) = run("sltrain", 8, 1, 1, 5);
    for threads in [1usize, 2] {
        for workers in [1usize, 2, 4] {
            let (losses, state) = run("sltrain", 8, threads, workers, 5);
            assert_eq!(losses, ref_losses, "losses @ {workers}w {threads}t");
            assert_eq!(state, ref_state, "state @ {workers}w {threads}t");
        }
    }
}

/// Same contract for the full-rank and galore methods — galore
/// exercises owner-local projector refresh (`optim.proj.*` merges from
/// the owner replica).
#[test]
fn worker_count_never_changes_a_bit_full_and_galore() {
    for method in ["full", "galore"] {
        let (ref_losses, ref_state) = run(method, 8, 1, 1, 5);
        for workers in [2usize, 4] {
            let (losses, state) = run(method, 8, 1, workers, 5);
            assert_eq!(losses, ref_losses, "{method} losses @ {workers}w");
            assert_eq!(state, ref_state, "{method} state @ {workers}w");
        }
    }
}

/// The coordinator path: a relora run (restart merges broadcast to all
/// replicas) and its eval losses match bitwise at 1 vs 2 workers.
#[test]
fn trainer_relora_run_is_worker_count_invariant() {
    let mut curves = Vec::new();
    for workers in [1usize, 2] {
        let mut be = open("relora", 8, 1, workers);
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        let cfg = TrainConfig {
            steps: 8,
            eval_every: 0,
            eval_batches: 2,
            log_every: 0,
            relora_every: 4,
            ..Default::default()
        };
        let r = train(be.as_mut(), &mut pipe, &cfg).unwrap();
        assert_eq!(r.relora_merges, 2, "@{workers}w");
        let bits: Vec<(usize, u64)> =
            r.train_curve.points.iter().map(|&(s, l)| (s, l.to_bits())).collect();
        curves.push((bits, r.final_eval_loss.to_bits()));
    }
    assert_eq!(curves[0], curves[1], "1 vs 2 workers through the trainer");
}

/// Satellite: a checkpoint written by an N-worker run resumes bit-
/// identically on an M-worker engine (owner-sharded moments serialize
/// into the flat `optim.*` namespace, so the snapshot is worker-count
/// agnostic). Covers 4 -> 1 and 1 -> 4.
#[test]
fn sharded_checkpoint_resumes_bitwise_on_a_different_worker_count() {
    for (w_save, w_resume) in [(4usize, 1usize), (1, 4)] {
        // run A: 3 steps, snapshot, then 3 more steps uninterrupted
        let mut a = open("sltrain", 8, 1, w_save);
        a.init_state(42).unwrap();
        let mut pipe_a = Pipeline::build(a.preset().vocab, 7);
        for step in 0..3 {
            let toks = pipe_a.train.next_batch(a.batch_size(), a.seq_len());
            a.train_step(step, &toks).unwrap();
        }
        let saved = a.state_tensors().unwrap();
        let mut tail_a = Vec::new();
        for step in 3..6 {
            let toks = pipe_a.train.next_batch(a.batch_size(), a.seq_len());
            tail_a.push(a.train_step(step, &toks).unwrap().to_bits());
        }
        let state_a = snapshot(a.as_mut());

        // run B: different worker count, restore the snapshot, fast-
        // forward the stream, replay the tail
        let mut b = open("sltrain", 8, 1, w_resume);
        b.init_state(42).unwrap();
        b.load_state_tensors(&saved).unwrap();
        let mut pipe_b = Pipeline::build(b.preset().vocab, 7);
        for _ in 0..3 {
            pipe_b.train.next_batch(b.batch_size(), b.seq_len());
        }
        let mut tail_b = Vec::new();
        for step in 3..6 {
            let toks = pipe_b.train.next_batch(b.batch_size(), b.seq_len());
            tail_b.push(b.train_step(step, &toks).unwrap().to_bits());
        }
        assert_eq!(tail_b, tail_a, "resumed losses, {w_save}w -> {w_resume}w");
        assert_eq!(snapshot(b.as_mut()), state_a, "final state, {w_save}w -> {w_resume}w");
    }
}

fn tmp_dir(tag: &str) -> PathBuf {
    let d = std::env::temp_dir().join(format!("sltrain-sharded-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&d).unwrap();
    d
}

fn cli_train(ckpt: &PathBuf, transport: &str) {
    let out = Command::new(env!("CARGO_BIN_EXE_sltrain"))
        .args([
            "train",
            "--backend",
            "native",
            "--config",
            "tiny",
            "--method",
            "sltrain",
            "--batch",
            "8",
            "--workers",
            "2",
            "--steps",
            "5",
            "--eval-every",
            "0",
            "--eval-batches",
            "1",
            "--log-every",
            "0",
            "--checkpoint",
            ckpt.to_str().unwrap(),
        ])
        .env("SLTRAIN_WORKER_TRANSPORT", transport)
        .output()
        .unwrap();
    assert!(
        out.status.success(),
        "train --workers 2 ({transport}) failed:\n{}{}",
        String::from_utf8_lossy(&out.stdout),
        String::from_utf8_lossy(&out.stderr)
    );
}

/// Satellite: the process transport (replicas as `shard-worker` child
/// processes over a unix socket) is a drop-in for the thread transport —
/// the 5-step CLI checkpoints match tensor for tensor, bit for bit.
#[test]
fn process_transport_matches_thread_transport_through_the_cli() {
    let dir = tmp_dir("transport");
    let ck_thread = dir.join("thread.ckpt");
    let ck_process = dir.join("process.ckpt");
    cli_train(&ck_thread, "thread");
    cli_train(&ck_process, "process");
    let a = Checkpoint::load(&ck_thread).unwrap();
    let b = Checkpoint::load(&ck_process).unwrap();
    assert_eq!(a.step, b.step);
    assert_eq!(a.tensors, b.tensors, "thread vs process transport state");
    let _ = std::fs::remove_dir_all(&dir);
}

/// Acceptance smoke (ignored by default — needs a quiet >= 4-core box):
/// with the same total thread budget, 4 data-parallel workers finish
/// more full-rank steps than 1 worker inside a fixed deadline.
#[test]
#[ignore = "perf smoke: run on a quiet >= 4-core machine"]
fn four_workers_beat_one_worker_on_full_rank() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores < 4 {
        eprintln!("[skip] only {cores} cores");
        return;
    }
    let deadline = std::time::Duration::from_secs(3);
    let mut done = Vec::new();
    for workers in [1usize, 4] {
        let mut be = open("full", 8, 4, workers);
        be.init_state(42).unwrap();
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        // warmup
        let toks = pipe.train.next_batch(be.batch_size(), be.seq_len());
        be.train_step(0, &toks).unwrap();
        let t0 = std::time::Instant::now();
        let mut steps = 0usize;
        while t0.elapsed() < deadline {
            let toks = pipe.train.next_batch(be.batch_size(), be.seq_len());
            be.train_step(1 + steps as i32, &toks).unwrap();
            steps += 1;
        }
        println!("  {workers} worker(s): {steps} steps in {:?}", t0.elapsed());
        done.push(steps);
    }
    assert!(
        done[1] > done[0],
        "4 workers ({} steps) should beat 1 worker ({} steps)",
        done[1],
        done[0]
    );
}

//! End-to-end tests of the pure-rust native backend: the artifact-free
//! path through the full stack — coordinator, data pipeline, backend,
//! checkpointing. No XLA, no Python, no `make artifacts`: this is the
//! coverage the AOT path can only get on machines with the toolchain.

use sltrain::backend::{self, Backend, BackendSpec};
use sltrain::config::preset;
use sltrain::coordinator::trainer::{quick_train, save_checkpoint};
use sltrain::coordinator::{train, Checkpoint, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::linalg::SupportPattern;

fn native_spec(method: &str, batch: usize, steps: usize) -> BackendSpec {
    BackendSpec::Native {
        preset: preset("tiny").unwrap(),
        method: method.to_string(),
        batch,
        lr: 3e-3,
        total_steps: steps.max(1),
        threads: 0,     // auto (results are thread-count independent)
        optim_bits: 0,  // auto (SLTRAIN_OPTIM_BITS env matrix flows through)
        galore_every: 5, // short refresh so small runs cross boundaries
        support: SupportPattern::UniformRandom,
        workers: 0,
    }
}

fn open(method: &str, batch: usize, steps: usize) -> Box<dyn Backend> {
    backend::open(native_spec(method, batch, steps)).unwrap()
}

/// The headline acceptance run: `sltrain train --backend native` trains
/// end-to-end with no artifact dir, and the loss decreases over 200
/// steps on the synthetic pipeline.
#[test]
fn native_sltrain_200_steps_loss_decreases() {
    let mut be = open("sltrain", 4, 200);
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    let cfg = TrainConfig {
        steps: 200,
        eval_every: 100,
        eval_batches: 2,
        log_every: 0,
        ..Default::default()
    };
    let r = train(be.as_mut(), &mut pipe, &cfg).unwrap();
    let first = r.train_curve.points[0].1;
    let last = r.train_curve.points.last().unwrap().1;
    // init loss ≈ ln(vocab) = 5.55; must have improved decisively
    assert!(last < first - 0.5, "train loss {first} -> {last}");
    assert!(
        r.final_eval_loss < first - 0.3,
        "eval loss {} vs init {first}",
        r.final_eval_loss
    );
    assert_eq!(r.n_params, preset("tiny").unwrap().param_count("sltrain"));
}

#[test]
fn native_full_and_lowrank_train() {
    for method in ["full", "lowrank"] {
        let mut be = open(method, 4, 60);
        let r = quick_train(be.as_mut(), 60, 7).unwrap();
        let first = r.train_curve.points[0].1;
        let last = r.train_curve.points.last().unwrap().1;
        assert!(last < first, "{method}: {first} -> {last}");
    }
}

/// The baseline rows of Tables 2/3 run natively end-to-end: the
/// coordinator drives relora restarts through `Backend::merge` (the
/// `relora_every` schedule) and galore's projected optimizer, and both
/// improve over their initial loss.
#[test]
fn native_relora_and_galore_train_through_coordinator() {
    for method in ["relora", "galore"] {
        let mut be = open(method, 4, 60);
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        let cfg = TrainConfig {
            steps: 60,
            eval_every: 0,
            eval_batches: 2,
            log_every: 0,
            relora_every: 20,
            ..Default::default()
        };
        let r = train(be.as_mut(), &mut pipe, &cfg).unwrap();
        let first = r.train_curve.points[0].1;
        let last = r.train_curve.points.last().unwrap().1;
        assert!(last < first, "{method}: {first} -> {last}");
        let expect_merges = if method == "relora" { 2 } else { 0 };
        assert_eq!(r.relora_merges, expect_merges, "{method} merges");
        assert_eq!(r.n_params, preset("tiny").unwrap().param_count(method), "{method}");
    }
}

#[test]
fn native_training_is_deterministic_given_seeds() {
    let mut losses = vec![];
    for _ in 0..2 {
        let mut be = open("sltrain", 4, 50);
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        be.init_state(42).unwrap();
        let mut run = vec![];
        for step in 0..5 {
            let toks = pipe.train.next_batch(be.batch_size(), be.seq_len());
            run.push(be.train_step(step, &toks).unwrap());
        }
        losses.push(run);
    }
    assert_eq!(losses[0], losses[1], "same seeds must reproduce bit-identical losses");
}

#[test]
fn native_checkpoint_roundtrip_preserves_eval() {
    let mut be = open("sltrain", 4, 50);
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    be.init_state(42).unwrap();
    for step in 0..5 {
        let toks = pipe.train.next_batch(be.batch_size(), be.seq_len());
        be.train_step(step, &toks).unwrap();
    }
    let probe = pipe.valid.next_batch(be.batch_size(), be.seq_len());
    let before = be.eval_loss(&probe).unwrap();

    let dir = std::env::temp_dir().join(format!("sltrain-native-{}", std::process::id()));
    let path = dir.join("mid.ckpt");
    save_checkpoint(be.as_ref(), 5, &path).unwrap();

    // restore into a FRESH backend with a different init seed
    let mut be2 = open("sltrain", 4, 50);
    be2.init_state(99).unwrap();
    let ck = Checkpoint::load(&path).unwrap();
    be2.load_state_tensors(&ck.to_state_tensors()).unwrap();
    let after = be2.eval_loss(&probe).unwrap();
    assert!((before - after).abs() < 1e-6, "{before} vs {after}");
    std::fs::remove_dir_all(dir).ok();
}

/// A stub backend that counts state snapshots, to observe exactly how
/// many times the coordinator writes checkpoints.
struct CountingBackend {
    preset: sltrain::config::ModelPreset,
    snapshots: std::sync::Arc<std::sync::atomic::AtomicUsize>,
}

impl Backend for CountingBackend {
    fn kind(&self) -> &'static str {
        "counting-stub"
    }
    fn method(&self) -> &str {
        "full"
    }
    fn preset(&self) -> &sltrain::config::ModelPreset {
        &self.preset
    }
    fn batch_size(&self) -> usize {
        1
    }
    fn n_params(&self) -> usize {
        0
    }
    fn init_state(&mut self, _seed: u32) -> anyhow::Result<()> {
        Ok(())
    }
    fn train_step(&mut self, _step: i32, _tokens: &[i32]) -> anyhow::Result<f32> {
        Ok(1.0)
    }
    fn eval_loss(&mut self, _tokens: &[i32]) -> anyhow::Result<f32> {
        Ok(1.0)
    }
    fn forward(&mut self, _tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        Ok(vec![])
    }
    fn state_tensors(&self) -> anyhow::Result<Vec<sltrain::backend::StateTensor>> {
        self.snapshots.fetch_add(1, std::sync::atomic::Ordering::SeqCst);
        Ok(vec![])
    }
    fn load_state_tensors(
        &mut self,
        _tensors: &[sltrain::backend::StateTensor],
    ) -> anyhow::Result<()> {
        Ok(())
    }
}

/// The duplicate-final-checkpoint regression: when checkpoint_every
/// divides steps, the final step must be snapshotted exactly once.
#[test]
fn no_duplicate_final_checkpoint_write() {
    let dir = std::env::temp_dir().join(format!("sltrain-ckptdup-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let count = |steps: usize, every: usize, tag: &str| {
        let snapshots = std::sync::Arc::new(std::sync::atomic::AtomicUsize::new(0));
        let mut be = CountingBackend {
            preset: preset("tiny").unwrap(),
            snapshots: snapshots.clone(),
        };
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        let cfg = TrainConfig {
            steps,
            eval_every: 0,
            eval_batches: 1,
            log_every: 0,
            checkpoint_path: Some(dir.join(format!("{tag}.ckpt"))),
            checkpoint_every: every,
            ..Default::default()
        };
        train(&mut be, &mut pipe, &cfg).unwrap();
        snapshots.load(std::sync::atomic::Ordering::SeqCst)
    };
    // 10 % 5 == 0: saves at steps 5 and 10 only — the post-loop save
    // must not re-write step 10
    assert_eq!(count(10, 5, "divides"), 2);
    // 10 % 4 != 0: saves at 4, 8, then the post-loop final at 10
    assert_eq!(count(10, 4, "ragged"), 3);
    // no periodic saves: just the final one
    assert_eq!(count(10, 0, "endonly"), 1);
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn native_checkpoint_is_analyzable() {
    // the analyze subcommand's contract: sltrain checkpoints expose
    // .B/.A/.vals/.idx per adapted linear
    let mut be = open("sltrain", 2, 10);
    be.init_state(1).unwrap();
    let tensors = be.state_tensors().unwrap();
    let names: std::collections::BTreeSet<&str> =
        tensors.iter().map(|t| t.name.as_str()).collect();
    for suffix in ["B", "A", "vals", "idx"] {
        assert!(
            names.contains(format!("layers.0.attn.q.{suffix}").as_str()),
            "missing layers.0.attn.q.{suffix}"
        );
    }
    assert!(names.contains("embed.w"));
    assert!(names.contains("head.w"));
    assert!(names.contains("lnf.g"));
}

#[test]
fn backend_spec_validation() {
    // unknown engine and missing artifact are caught early
    assert!(BackendSpec::from_flags("tpu", "", "tiny", "sltrain", 8, 3e-3, 100, 0, 0, 0, "random", 0).is_err());
    assert!(BackendSpec::from_flags("xla", "", "tiny", "sltrain", 8, 3e-3, 100, 0, 0, 0, "random", 0).is_err());
    assert!(
        BackendSpec::from_flags("native", "", "nope", "sltrain", 8, 3e-3, 100, 0, 0, 0, "random", 0).is_err()
    );
    // --artifact with the native engine is a misdirected run, not a no-op
    let misdirected =
        BackendSpec::from_flags("native", "a/dir", "tiny", "sltrain", 8, 3e-3, 100, 0, 0, 0, "random", 0);
    assert!(misdirected.is_err());
    // every method of the paper's comparison set opens natively
    for method in ["full", "lowrank", "sltrain", "relora", "galore"] {
        assert!(backend::open(native_spec(method, 2, 10)).is_ok(), "{method}");
    }
    // unknown methods are rejected at open()
    assert!(backend::open(native_spec("lora", 2, 10)).is_err());
    // only 32 and 8 are valid Adam moment precisions
    let bad_bits = BackendSpec::Native {
        preset: preset("tiny").unwrap(),
        method: "sltrain".into(),
        batch: 2,
        lr: 3e-3,
        total_steps: 10,
        threads: 1,
        optim_bits: 16,
        galore_every: 0,
        support: SupportPattern::UniformRandom,
        workers: 0,
    };
    assert!(backend::open(bad_bits).is_err());
    // support-pattern strings are validated in from_flags
    assert!(BackendSpec::from_flags(
        "native", "", "tiny", "sltrain", 8, 3e-3, 100, 0, 0, 0, "3:2", 0
    )
    .is_err());
    assert!(BackendSpec::from_flags(
        "native", "", "tiny", "sltrain", 8, 3e-3, 100, 0, 0, 0, "2:4", 0
    )
    .is_ok());
}

/// The parallelism payoff: on machines with >= 4 cores, the threaded
/// step loop at 4 threads must beat 1 thread wall-clock on the tiny
/// preset. Skipped on smaller runners where the comparison is
/// meaningless.
///
/// `#[ignore]`d in the default suite: libtest runs sibling tests (incl.
/// 200-step e2e training) concurrently in this binary, which poisons
/// wall-clock ratios. CI runs it in a dedicated serial step:
///   cargo test -q --test native_backend -- --ignored --test-threads=1
///
/// Flake-proofing (the deadline-poll pattern, `tests/support/mod.rs`):
/// a single timing sample is at the mercy of whatever the runner is
/// doing that instant, so instead of asserting on one measurement the
/// test re-measures until the expected relation holds, and only fails
/// if a generous deadline expires without it *ever* holding — i.e.
/// the speedup is genuinely absent, not merely masked by noise.
#[test]
#[ignore = "timing-sensitive: run serially (see doc comment)"]
fn threaded_step_loop_beats_single_thread() {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores < 4 {
        eprintln!("[skip] threaded_step_loop_beats_single_thread: only {cores} cores");
        return;
    }
    let time_threads = |threads: usize| {
        let mut be = backend::open(BackendSpec::Native {
            preset: preset("tiny").unwrap(),
            method: "sltrain".to_string(),
            batch: 8,
            lr: 3e-3,
            total_steps: 100,
            threads,
            optim_bits: 0,
            galore_every: 0,
            support: SupportPattern::UniformRandom,
            workers: 0,
        })
        .unwrap();
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        be.init_state(42).unwrap();
        let (batch, seq) = (be.batch_size(), be.seq_len());
        // warmup (pool spin-up, page faults)
        for w in 0..2 {
            let toks = pipe.train.next_batch(batch, seq);
            be.train_step(w, &toks).unwrap();
        }
        let t0 = std::time::Instant::now();
        for step in 0..8 {
            let toks = pipe.train.next_batch(batch, seq);
            be.train_step(2 + step, &toks).unwrap();
        }
        t0.elapsed().as_secs_f64()
    };
    // the issue's contract is simply "4 threads beats 1 thread"; the
    // 0.95 factor leaves headroom so a near-tie doesn't count as a win
    let deadline = std::time::Duration::from_secs(120);
    let t0 = std::time::Instant::now();
    let (mut best_t1, mut best_t4) = (f64::INFINITY, f64::INFINITY);
    let mut rounds = 0;
    loop {
        best_t1 = best_t1.min(time_threads(1));
        best_t4 = best_t4.min(time_threads(4));
        rounds += 1;
        if best_t4 < best_t1 * 0.95 {
            eprintln!(
                "4 threads beat 1 thread after {rounds} round(s): \
                 {best_t4:.3}s vs {best_t1:.3}s"
            );
            return;
        }
        // keep re-measuring (best-of-N shrugs off transient runner
        // contention) until the relation holds or the deadline says
        // the speedup is genuinely absent
        assert!(
            t0.elapsed() <= deadline,
            "4 threads ({best_t4:.3}s) never beat 1 thread ({best_t1:.3}s) \
             over 8 steps in {rounds} rounds within {deadline:?}"
        );
    }
}

/// The per-layer fused refactor's acceptance contract: at
/// `--optim-bits 32`, the streaming fused `train_step` produces losses
/// bit-identical to the pre-refactor two-phase loop (kept as
/// `train_step_two_phase`) at every thread count.
#[test]
fn per_layer_fused_updates_match_two_phase_loop() {
    use sltrain::backend::native::NativeBackend;
    let p = preset("tiny").unwrap();
    let mut pipe = Pipeline::build(p.vocab, 7);
    let batches: Vec<Vec<i32>> = (0..5).map(|_| pipe.train.next_batch(4, p.seq_len)).collect();
    let mk = |threads: usize| {
        let mut be =
            NativeBackend::build(p.clone(), "sltrain", 4, 3e-3, 100, threads, 32, 0, SupportPattern::UniformRandom)
                .unwrap();
        be.init_state(42).unwrap();
        be
    };
    let mut reference = mk(1);
    let ref_losses: Vec<f32> = batches
        .iter()
        .enumerate()
        .map(|(s, b)| reference.train_step_two_phase(s as i32, b).unwrap())
        .collect();
    for threads in [1usize, 2, 4] {
        let mut be = mk(threads);
        let losses: Vec<f32> = batches
            .iter()
            .enumerate()
            .map(|(s, b)| be.train_step(s as i32, b).unwrap())
            .collect();
        assert_eq!(losses, ref_losses, "fused x{threads} vs serial two-phase loop");
    }
}

/// Quantized optimizer state survives the full checkpoint file format:
/// 8-bit moment codes (I8) + per-block scales (f32) round-trip
/// bit-identically through save/load, and the restored backend resumes
/// the exact training trajectory.
#[test]
fn q8_optimizer_state_roundtrips_through_checkpoint_file() {
    use sltrain::backend::native::NativeBackend;
    let p = preset("tiny").unwrap();
    let mut be = NativeBackend::build(
        p.clone(),
        "sltrain",
        4,
        3e-3,
        100,
        0,
        8,
        0,
        SupportPattern::UniformRandom,
    )
    .unwrap();
    be.init_state(42).unwrap();
    let mut pipe = Pipeline::build(p.vocab, 7);
    let batch: Vec<i32> = pipe.train.next_batch(4, p.seq_len);
    for step in 0..3 {
        be.train_step(step, &batch).unwrap();
    }

    let dir = std::env::temp_dir().join(format!("sltrain-q8ckpt-{}", std::process::id()));
    let path = dir.join("q8.ckpt");
    save_checkpoint(&be, 3, &path).unwrap();

    let ck = Checkpoint::load(&path).unwrap();
    let restored = ck.to_state_tensors();
    // the checkpoint must carry the quantized moments explicitly
    assert!(restored.iter().any(|t| t.name.starts_with("optim.m.q8.")), "missing I8 codes");
    assert!(restored.iter().any(|t| t.name.starts_with("optim.m.scale.")), "missing scales");
    // byte-level roundtrip against the source snapshot
    let src = be.state_tensors().unwrap();
    for st in &src {
        let back = restored.iter().find(|t| t.name == st.name).unwrap_or_else(|| {
            panic!("{} lost in checkpoint roundtrip", st.name)
        });
        assert_eq!(back.bytes, st.bytes, "{} bytes drifted", st.name);
    }

    let mut be2 = NativeBackend::build(
        p.clone(),
        "sltrain",
        4,
        3e-3,
        100,
        0,
        8,
        0,
        SupportPattern::UniformRandom,
    )
    .unwrap();
    be2.init_state(99).unwrap(); // different init, fully overwritten by load
    be2.load_state_tensors(&restored).unwrap();
    for step in 3..6 {
        let l1 = be.train_step(step, &batch).unwrap();
        let l2 = be2.train_step(step, &batch).unwrap();
        assert_eq!(l1, l2, "resumed q8 trajectory diverged at step {step}");
    }
    std::fs::remove_dir_all(dir).ok();
}

/// The streaming fused backward's gradient high-water must sit well
/// under the two-phase footprint (the memory claim of this refactor),
/// visible through the engine-agnostic `Backend::mem_report`.
#[test]
fn mem_report_shows_streaming_grad_peak_through_trait() {
    let mut be = open("sltrain", 4, 20);
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    be.init_state(42).unwrap();
    let toks = pipe.train.next_batch(be.batch_size(), be.seq_len());
    be.train_step(0, &toks).unwrap();
    let r = be.mem_report().expect("native backend must report memory");
    assert!(r.param_bytes > 0 && r.optim_bytes > 0);
    assert!(r.grad_peak_bytes > 0, "peak tracker must observe the backward walk");
    assert!(
        r.grad_peak_bytes < r.grad_all_bytes / 2,
        "streaming peak {} not lean vs two-phase {}",
        r.grad_peak_bytes,
        r.grad_all_bytes
    );
}

/// `train --resume` through the real CLI binary: interrupt a run at
/// step 3, resume to step 6, and the final checkpoint must be
/// byte-identical to an uninterrupted 6-step run — weights, quantized
/// optimizer moments, supports, step counter, everything.
#[test]
fn cli_resume_matches_uninterrupted_run_bit_for_bit() {
    let dir = std::env::temp_dir().join(format!("sltrain-resume-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let run = |steps: usize, ckpt: &std::path::Path, resume: bool| {
        let mut cmd = std::process::Command::new(env!("CARGO_BIN_EXE_sltrain"));
        cmd.args([
            "train",
            "--backend",
            "native",
            "--config",
            "tiny",
            "--method",
            "sltrain",
            "--batch",
            "2",
            "--threads",
            "2",
            "--eval-every",
            "0",
            "--log-every",
            "0",
        ]);
        cmd.arg("--steps").arg(steps.to_string());
        cmd.arg("--checkpoint").arg(ckpt);
        if resume {
            cmd.arg("--resume");
        }
        let out = cmd.output().unwrap();
        assert!(
            out.status.success(),
            "train --steps {steps} resume={resume} failed:\n{}",
            String::from_utf8_lossy(&out.stderr)
        );
    };
    let full = dir.join("full.ckpt");
    let part = dir.join("part.ckpt");
    run(6, &full, false); // uninterrupted reference
    run(3, &part, false); // "interrupted" prefix
    run(6, &part, true); // resume the prefix to the same horizon
    let a = std::fs::read(&full).unwrap();
    let b = std::fs::read(&part).unwrap();
    assert_eq!(a, b, "resumed checkpoint diverged from the uninterrupted run");
    // --resume without --checkpoint is a usage error, not a silent fresh run
    let out = std::process::Command::new(env!("CARGO_BIN_EXE_sltrain"))
        .args(["train", "--backend", "native", "--config", "tiny", "--steps", "1", "--resume"])
        .output()
        .unwrap();
    assert!(!out.status.success(), "--resume without --checkpoint must fail");
    std::fs::remove_dir_all(dir).ok();
}

#[cfg(not(feature = "xla"))]
#[test]
fn xla_spec_fails_cleanly_without_feature() {
    let spec = BackendSpec::Xla { artifact_dir: "artifacts/tiny_sltrain".into() };
    let err = backend::open(spec).err().expect("must fail without xla feature");
    assert!(format!("{err}").contains("xla"), "unhelpful error: {err}");
}

//! Token-shard format tests: byte-exact write/read roundtrip, one typed
//! [`ShardError`] per corruption class (mirroring the SLTCKPT1
//! checkpoint corruption suite), purity of the epoch shuffle, stream
//! determinism across runs / worker counts / the mmap-vs-heap backing,
//! and the `Pipeline::from_shard_dir` train/valid split.

use std::path::{Path, PathBuf};

use sltrain::data::shard::{build_shards, epoch_order, shard_name, write_shard};
use sltrain::data::{Pipeline, ShardError, ShardReader, ShardSet, ShardStream};

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sltrain-shard-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn write_read_roundtrip_is_byte_exact() {
    let dir = tmp_dir("roundtrip");
    let path = dir.join(shard_name(3));
    let tokens: Vec<u32> = (0..5000u32).map(|i| i.wrapping_mul(2654435761) % 911).collect();
    write_shard(&path, &tokens, 3, 42, 911).unwrap();
    let r = ShardReader::open(&path).unwrap();
    assert_eq!(r.meta.shard, 3);
    assert_eq!(r.meta.seed, 42);
    assert_eq!(r.meta.vocab, 911);
    assert_eq!(r.len(), tokens.len());
    let got: Vec<u32> = (0..r.len()).map(|i| r.token(i)).collect();
    assert_eq!(got, tokens, "tokens did not roundtrip byte-exactly");
    std::fs::remove_dir_all(dir).ok();
}

/// Every malformed-shard class yields the right typed [`ShardError`]
/// variant — never a panic — and the error chain names the failing
/// shard file.
#[test]
fn malformed_shards_yield_typed_errors_naming_the_file() {
    let dir = tmp_dir("typed-errors");
    let good_path = dir.join(shard_name(0));
    let tokens: Vec<u32> = (0..256u32).collect();
    write_shard(&good_path, &tokens, 0, 7, 256).unwrap();
    let good = std::fs::read(&good_path).unwrap();

    let truncated_header = good[..20].to_vec(); // mid-JSON-header
    let truncated_tokens = good[..good.len() - 12].to_vec();
    let flipped_payload = {
        let mut v = good.clone();
        let n = v.len();
        v[n - 3] ^= 0x01;
        v
    };
    let cases: Vec<(&str, Vec<u8>, fn(&ShardError) -> bool)> = vec![
        ("zero-byte", vec![], |e| matches!(e, ShardError::Empty)),
        ("foreign", b"PNG\x89this is not a shard".to_vec(), |e| {
            matches!(e, ShardError::NotAShard)
        }),
        ("truncated-header", truncated_header, |e| {
            matches!(e, ShardError::TruncatedHeader { .. })
        }),
        ("truncated-tokens", truncated_tokens, |e| {
            matches!(e, ShardError::TruncatedTokens { .. })
        }),
        ("flipped-payload-byte", flipped_payload, |e| {
            matches!(e, ShardError::CrcMismatch { .. })
        }),
    ];
    for (tag, bytes, is_right_class) in cases {
        let p = dir.join(format!("{tag}.slt"));
        std::fs::write(&p, &bytes).unwrap();
        let err = ShardReader::open(&p)
            .err()
            .unwrap_or_else(|| panic!("{tag}: malformed shard loaded successfully"));
        let typed = err
            .downcast_ref::<ShardError>()
            .unwrap_or_else(|| panic!("{tag}: error is not a typed ShardError: {err:#}"));
        assert!(is_right_class(typed), "{tag}: wrong error class: {typed:?}");
        let chain = format!("{err:#}");
        assert!(chain.contains(&format!("{tag}.slt")), "{tag}: failing file not named: {chain}");
    }
    std::fs::remove_dir_all(dir).ok();
}

/// The epoch shuffle is a pure function of `(seed, epoch)`: identical
/// on recomputation, a permutation, seed-sensitive, and epoch-varying.
#[test]
fn epoch_order_is_a_pure_seeded_permutation() {
    let n = 16;
    for epoch in 0..4u64 {
        let a = epoch_order(7, epoch, n);
        let b = epoch_order(7, epoch, n);
        assert_eq!(a, b, "epoch {epoch} order not pure");
        let mut sorted = a.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..n).collect::<Vec<_>>(), "epoch {epoch} not a permutation");
    }
    let orders: Vec<Vec<usize>> = (0..4).map(|e| epoch_order(7, e, n)).collect();
    assert!(
        orders.windows(2).any(|w| w[0] != w[1]),
        "four consecutive epochs produced the identical order"
    );
    assert_ne!(epoch_order(7, 0, n), epoch_order(8, 0, n), "seed does not change the order");
}

fn drain(dir: &Path, seed: u64, n: usize) -> Vec<i32> {
    let set = ShardSet::open(dir).unwrap();
    let mut stream = ShardStream::new(set.readers, seed, 4096).unwrap();
    (0..n).map(|_| stream.next_token()).collect()
}

/// One `build_shards` corpus, read many ways: repeated opens, a
/// different builder worker count, and the heap (non-mmap) backing all
/// produce the identical token stream.
#[test]
fn stream_is_deterministic_across_runs_workers_and_backings() {
    let dir1 = tmp_dir("stream-det-1");
    let dir4 = tmp_dir("stream-det-4");
    let r1 = build_shards(&dir1, 3, 4000, 512, 42, 1).unwrap();
    let r4 = build_shards(&dir4, 3, 4000, 512, 42, 4).unwrap();
    assert_eq!(r1.tokens, r4.tokens);
    for i in 0..3 {
        assert_eq!(
            std::fs::read(dir1.join(shard_name(i))).unwrap(),
            std::fs::read(dir4.join(shard_name(i))).unwrap(),
            "shard {i} differs between 1-thread and 4-thread builds"
        );
    }

    // enough to cross shard AND epoch boundaries (3 x 4000 tokens)
    let n = 3 * 4000 + 500;
    let a = drain(&dir1, 7, n);
    let b = drain(&dir1, 7, n);
    assert_eq!(a, b, "same-seed streams differ across opens");
    // pick a seed whose epoch-0 permutation provably differs (with only
    // 3 shards two seeds can coincide by chance)
    let seed2 = (8u64..).find(|&s| epoch_order(s, 0, 3) != epoch_order(7, 0, 3)).unwrap();
    let c = drain(&dir1, seed2, n);
    assert_ne!(a, c, "shuffle seed does not affect the stream");

    // heap backing must be bit-identical to the mmap backing
    std::env::set_var("SLTRAIN_MMAP", "off");
    let heap = drain(&dir1, 7, n);
    std::env::remove_var("SLTRAIN_MMAP");
    assert_eq!(a, heap, "heap backing diverges from mmap backing");

    std::fs::remove_dir_all(dir1).ok();
    std::fs::remove_dir_all(dir4).ok();
}

#[test]
fn from_shard_dir_splits_train_valid_and_is_deterministic() {
    let dir = tmp_dir("pipeline");
    build_shards(&dir, 3, 3000, 512, 42, 1).unwrap();
    let mut p1 = Pipeline::from_shard_dir(&dir, 512, 7).unwrap();
    let mut p2 = Pipeline::from_shard_dir(&dir, 512, 7).unwrap();
    let a1 = p1.train.next_batch(2, 64);
    assert_eq!(a1.len(), 2 * 64);
    assert_eq!(a1, p2.train.next_batch(2, 64), "same-seed shard pipelines differ");
    assert!(a1.iter().all(|&t| (0..512).contains(&t)), "token id out of vocab range");
    let v = p1.valid.next_batch(2, 64);
    assert_ne!(v, a1, "train/valid splits overlap");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn single_shard_dir_is_rejected_needing_a_valid_split() {
    let dir = tmp_dir("one-shard");
    build_shards(&dir, 1, 1000, 512, 42, 1).unwrap();
    let err = Pipeline::from_shard_dir(&dir, 512, 7)
        .err()
        .expect("a 1-shard dir cannot provide a held-out split");
    assert!(format!("{err:#}").contains("valid"), "unhelpful error: {err:#}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn empty_dir_error_mentions_make_shards() {
    let dir = tmp_dir("empty");
    let err = ShardSet::open(&dir).err().expect("empty dir must not open");
    assert!(
        format!("{err:#}").contains("--make-shards"),
        "error should point at the builder command: {err:#}"
    );
    std::fs::remove_dir_all(dir).ok();
}

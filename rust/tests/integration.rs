//! Integration tests over REAL artifacts: the python-AOT → rust-PJRT
//! contract, end to end. Requires the `xla` cargo feature and
//! `make artifacts` (the tiny set).
//!
//! These are the tests that would catch a broken interchange format, a
//! manifest/HLO mismatch, a training-dynamics regression — and, via the
//! parity smoke test, an AOT path that drifts from the pure-rust native
//! reference.
#![cfg(feature = "xla")]

use std::path::Path;
use std::sync::Mutex;

use sltrain::backend::xla_backend::XlaBackend;
use sltrain::backend::{self, Backend, BackendSpec};
use sltrain::coordinator::{train, Checkpoint, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::runtime::{Artifact, Dtype};

// PJRT CPU client: one per process is plenty; serialize tests around it.
static RT: Mutex<()> = Mutex::new(());

fn has_artifacts() -> bool {
    Path::new("artifacts/tiny_sltrain/manifest.json").exists()
}

fn open_xla(dir: &str) -> Box<dyn Backend> {
    backend::open(BackendSpec::Xla { artifact_dir: dir.into() }).unwrap()
}

#[test]
fn manifest_matches_config_presets() {
    if !has_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    for method in ["full", "lowrank", "sltrain", "relora", "galore"] {
        let art = Artifact::load(Path::new(&format!("artifacts/tiny_{method}"))).unwrap();
        let man = &art.manifest;
        assert_eq!(man.method, method);
        // parameter count in manifest equals the sum of tensor sizes
        assert_eq!(man.n_params, man.count_params(), "{method}");
        // and equals the rust-side preset model (shared formula)
        let preset = sltrain::config::preset("tiny").unwrap();
        assert_eq!(man.n_params, preset.param_count(method), "{method}");
        // every entrypoint input is either __special, a param, a const or opt
        let known: std::collections::HashSet<&str> = man
            .params
            .iter()
            .chain(&man.consts)
            .chain(&man.opt_state)
            .map(|t| t.name.as_str())
            .collect();
        for (ename, e) in &man.entrypoints {
            for i in &e.inputs {
                assert!(
                    i.starts_with("__") || known.contains(i.as_str()),
                    "{method}/{ename}: unknown input {i}"
                );
            }
        }
    }
}

#[test]
fn sltrain_trains_and_beats_init() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let mut be = open_xla("artifacts/tiny_sltrain");
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    let cfg = TrainConfig {
        steps: 40,
        eval_every: 20,
        eval_batches: 3,
        log_every: 0,
        ..Default::default()
    };
    let r = train(be.as_mut(), &mut pipe, &cfg).unwrap();
    // init loss ≈ ln(vocab) = 5.55; must have improved decisively
    assert!(r.final_eval_loss < 4.5, "loss {}", r.final_eval_loss);
    // loss curve is decreasing overall
    let first = r.train_curve.points[0].1;
    let last = r.train_curve.points.last().unwrap().1;
    assert!(last < first - 0.5, "{first} -> {last}");
}

#[test]
fn training_is_deterministic_given_seeds() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let mut losses = vec![];
    for _ in 0..2 {
        let mut be = open_xla("artifacts/tiny_sltrain");
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        be.init_state(42).unwrap();
        let mut run = vec![];
        for step in 0..5 {
            let toks = pipe.train.next_batch(be.batch_size(), be.seq_len());
            run.push(be.train_step(step, &toks).unwrap());
        }
        losses.push(run);
    }
    assert_eq!(losses[0], losses[1], "same seeds must reproduce bit-identical losses");
}

#[test]
fn relora_merge_preserves_eval_loss() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let mut be = open_xla("artifacts/tiny_relora");
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    be.init_state(42).unwrap();
    let (batch, seq) = (be.batch_size(), be.seq_len());
    for step in 0..10 {
        let toks = pipe.train.next_batch(batch, seq);
        be.train_step(step, &toks).unwrap();
    }
    let probe = pipe.valid.next_batch(batch, seq);
    let before = be.eval_loss(&probe).unwrap();
    be.merge(1).unwrap();
    let after = be.eval_loss(&probe).unwrap();
    // W0 + BA is absorbed: function unchanged (up to float noise)
    assert!((before - after).abs() < 1e-3, "{before} vs {after}");
}

#[test]
fn eight_bit_state_dtypes_are_int8() {
    if !has_artifacts() {
        return;
    }
    let art = Artifact::load(Path::new("artifacts/tiny_sltrain_8bit")).unwrap();
    let mq: Vec<_> = art
        .manifest
        .opt_state
        .iter()
        .filter(|t| t.name.ends_with(".mq"))
        .collect();
    assert!(!mq.is_empty());
    assert!(mq.iter().all(|t| t.dtype == Dtype::I8));
    // quantized moments must be ~half the optimizer footprint of f32 Adam
    let art_f32 = Artifact::load(Path::new("artifacts/tiny_sltrain")).unwrap();
    let bytes8: usize =
        art.manifest.opt_state.iter().map(|t| t.numel() * t.dtype.size_bytes()).sum();
    let bytes32: usize =
        art_f32.manifest.opt_state.iter().map(|t| t.numel() * t.dtype.size_bytes()).sum();
    assert!(
        (bytes8 as f64) < 0.5 * bytes32 as f64,
        "8bit {bytes8} vs f32 {bytes32}"
    );
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let mut be = open_xla("artifacts/tiny_sltrain");
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    be.init_state(42).unwrap();
    let (batch, seq) = (be.batch_size(), be.seq_len());
    for step in 0..8 {
        let toks = pipe.train.next_batch(batch, seq);
        be.train_step(step, &toks).unwrap();
    }
    let probe = pipe.valid.next_batch(batch, seq);
    let before = be.eval_loss(&probe).unwrap();

    let dir = std::env::temp_dir().join(format!("sltrain-int-{}", std::process::id()));
    let path = dir.join("mid.ckpt");
    sltrain::coordinator::trainer::save_checkpoint(be.as_ref(), 8, &path).unwrap();

    // restore into a FRESH backend state initialized from a different seed
    let mut be2 = open_xla("artifacts/tiny_sltrain");
    be2.init_state(99).unwrap();
    let ck = Checkpoint::load(&path).unwrap();
    be2.load_state_tensors(&ck.to_state_tensors()).unwrap();
    let after = be2.eval_loss(&probe).unwrap();
    assert!((before - after).abs() < 1e-5, "{before} vs {after}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn support_sidecars_match_manifest_and_are_valid() {
    if !has_artifacts() {
        return;
    }
    let art = Artifact::load(Path::new("artifacts/tiny_sltrain")).unwrap();
    let p = &art.manifest.preset;
    for (name, sup) in &art.manifest.supports {
        let raw = std::fs::read(art.dir.join(&sup.file)).unwrap();
        assert_eq!(raw.len(), sup.nnz * 4, "{name}");
        let idx: Vec<u32> = raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        // sorted, distinct, in range
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "{name} not sorted-unique");
        // bound: the largest linear is d_ff x d_model
        let bound = (p.d_ff.max(p.d_model) * p.d_ff.max(p.d_model)) as u32;
        assert!(idx.iter().all(|&i| i < bound), "{name} out of range");
        let dims: Vec<usize> = art
            .manifest
            .consts
            .iter()
            .filter(|t| t.name == *name)
            .flat_map(|t| t.shape.clone())
            .collect();
        assert_eq!(dims[0], sup.nnz, "{name}");
    }
}

#[test]
fn galore_artifact_trains() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let mut be = open_xla("artifacts/tiny_galore");
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    be.init_state(42).unwrap();
    let (batch, seq) = (be.batch_size(), be.seq_len());
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..25 {
        let toks = pipe.train.next_batch(batch, seq);
        let l = be.train_step(step, &toks).unwrap();
        if step == 0 {
            first = l;
        }
        last = l;
    }
    assert!(last < first, "galore did not reduce loss: {first} -> {last}");
    assert_eq!(be.optimizer(), "galore");
}

/// Parity smoke: the native pure-rust backend and the AOT/PJRT backend
/// implement the same method and must show the same training dynamics —
/// both start near ln|V| and land in the same loss band after the same
/// number of steps on the same data stream.
#[test]
fn native_and_xla_loss_parity_smoke() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let run = |mut be: Box<dyn Backend>| -> (f64, f64) {
        let mut pipe = Pipeline::build(be.preset().vocab, 7);
        be.init_state(42).unwrap();
        let (batch, seq) = (be.batch_size(), be.seq_len());
        let mut first = 0.0f64;
        let mut last = 0.0f64;
        for step in 0..30 {
            let toks = pipe.train.next_batch(batch, seq);
            let l = be.train_step(step, &toks).unwrap() as f64;
            if step == 0 {
                first = l;
            }
            last = l;
        }
        (first, last)
    };
    let xla_be = open_xla("artifacts/tiny_sltrain");
    let batch = xla_be.batch_size();
    let (xf, xl) = run(xla_be);
    let native = backend::open(BackendSpec::Native {
        preset: sltrain::config::preset("tiny").unwrap(),
        method: "sltrain".into(),
        batch,
        lr: 3e-3,
        total_steps: 2000,
        threads: 0,
        optim_bits: 0,
        galore_every: 0,
        support: sltrain::linalg::SupportPattern::UniformRandom,
        workers: 0,
    })
    .unwrap();
    let (nf, nl) = run(native);
    // same init distributions: initial losses agree to within float-
    // and-RNG noise around ln(256) = 5.545
    assert!((xf - nf).abs() < 0.5, "init loss drift: xla {xf} vs native {nf}");
    // both must improve, and land in the same band
    assert!(xl < xf && nl < nf, "xla {xf}->{xl}, native {nf}->{nl}");
    assert!((xl - nl).abs() < 1.0, "final loss drift: xla {xl} vs native {nl}");
}

/// XlaBackend must be reachable directly too (bench binaries).
#[test]
fn xla_backend_direct_open() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let be = XlaBackend::open(Path::new("artifacts/tiny_sltrain")).unwrap();
    assert_eq!(be.kind(), "xla");
    assert_eq!(be.method(), "sltrain");
    assert!(be.n_params() > 0);
}

//! Integration tests over REAL artifacts: the python-AOT → rust-PJRT
//! contract, end to end. Requires `make artifacts` (the tiny set).
//!
//! These are the tests that would catch a broken interchange format, a
//! manifest/HLO mismatch, or a training-dynamics regression.

use std::path::Path;
use std::sync::Mutex;

use sltrain::coordinator::{train, Checkpoint, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::runtime::{Artifact, Dtype, Runtime};

// PJRT CPU client: one per process is plenty; serialize tests around it.
static RT: Mutex<()> = Mutex::new(());

fn rt() -> Runtime {
    Runtime::cpu().expect("pjrt cpu client")
}

fn has_artifacts() -> bool {
    Path::new("artifacts/tiny_sltrain/manifest.json").exists()
}

#[test]
fn manifest_matches_config_presets() {
    if !has_artifacts() {
        eprintln!("skipping: run `make artifacts` first");
        return;
    }
    for method in ["full", "lowrank", "sltrain", "relora", "galore"] {
        let art = Artifact::load(Path::new(&format!("artifacts/tiny_{method}"))).unwrap();
        let man = &art.manifest;
        assert_eq!(man.method, method);
        // parameter count in manifest equals the sum of tensor sizes
        assert_eq!(man.n_params, man.count_params(), "{method}");
        // and equals the rust-side preset model (shared formula)
        let preset = sltrain::config::preset("tiny").unwrap();
        assert_eq!(man.n_params, preset.param_count(method), "{method}");
        // every entrypoint input is either __special, a param, a const or opt
        let known: std::collections::HashSet<&str> = man
            .params
            .iter()
            .chain(&man.consts)
            .chain(&man.opt_state)
            .map(|t| t.name.as_str())
            .collect();
        for (ename, e) in &man.entrypoints {
            for i in &e.inputs {
                assert!(
                    i.starts_with("__") || known.contains(i.as_str()),
                    "{method}/{ename}: unknown input {i}"
                );
            }
        }
    }
}

#[test]
fn sltrain_trains_and_beats_init() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let rt = rt();
    let mut art = Artifact::load(Path::new("artifacts/tiny_sltrain")).unwrap();
    let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);
    let cfg = TrainConfig { steps: 40, eval_every: 20, eval_batches: 3, log_every: 0, ..Default::default() };
    let r = train(&rt, &mut art, &mut pipe, &cfg).unwrap();
    // init loss ≈ ln(vocab) = 5.55; must have improved decisively
    assert!(r.final_eval_loss < 4.5, "loss {}", r.final_eval_loss);
    // loss curve is decreasing overall
    let first = r.train_curve.points[0].1;
    let last = r.train_curve.points.last().unwrap().1;
    assert!(last < first - 0.5, "{first} -> {last}");
}

#[test]
fn training_is_deterministic_given_seeds() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let rt = rt();
    let mut losses = vec![];
    for _ in 0..2 {
        let mut art = Artifact::load(Path::new("artifacts/tiny_sltrain")).unwrap();
        let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);
        let mut state = art.init_state(&rt, 42).unwrap();
        let mut run = vec![];
        for step in 0..5 {
            let toks = pipe
                .train
                .next_batch(art.entry("train_step").unwrap().batch, art.manifest.seq_len());
            run.push(art.train_step(&rt, &mut state, step, &toks).unwrap());
        }
        losses.push(run);
    }
    assert_eq!(losses[0], losses[1], "same seeds must reproduce bit-identical losses");
}

#[test]
fn relora_merge_preserves_eval_loss() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let rt = rt();
    let mut art = Artifact::load(Path::new("artifacts/tiny_relora")).unwrap();
    let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);
    let mut state = art.init_state(&rt, 42).unwrap();
    let batch = art.entry("train_step").unwrap().batch;
    let seq = art.manifest.seq_len();
    for step in 0..10 {
        let toks = pipe.train.next_batch(batch, seq);
        art.train_step(&rt, &mut state, step, &toks).unwrap();
    }
    let probe = pipe.valid.next_batch(batch, seq);
    let before = art.eval_loss(&rt, &mut state, &probe).unwrap();
    art.relora_merge(&rt, &mut state, 1).unwrap();
    let after = art.eval_loss(&rt, &mut state, &probe).unwrap();
    // W0 + BA is absorbed: function unchanged (up to float noise)
    assert!((before - after).abs() < 1e-3, "{before} vs {after}");
}

#[test]
fn eight_bit_state_dtypes_are_int8() {
    if !has_artifacts() {
        return;
    }
    let art = Artifact::load(Path::new("artifacts/tiny_sltrain_8bit")).unwrap();
    let mq: Vec<_> = art
        .manifest
        .opt_state
        .iter()
        .filter(|t| t.name.ends_with(".mq"))
        .collect();
    assert!(!mq.is_empty());
    assert!(mq.iter().all(|t| t.dtype == Dtype::I8));
    // quantized moments must be ~half the optimizer footprint of f32 Adam
    let art_f32 = Artifact::load(Path::new("artifacts/tiny_sltrain")).unwrap();
    let bytes8: usize = art.manifest.opt_state.iter().map(|t| t.numel() * t.dtype.size_bytes()).sum();
    let bytes32: usize =
        art_f32.manifest.opt_state.iter().map(|t| t.numel() * t.dtype.size_bytes()).sum();
    assert!(
        (bytes8 as f64) < 0.5 * bytes32 as f64,
        "8bit {bytes8} vs f32 {bytes32}"
    );
}

#[test]
fn checkpoint_roundtrip_preserves_eval() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let rt = rt();
    let mut art = Artifact::load(Path::new("artifacts/tiny_sltrain")).unwrap();
    let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);
    let mut state = art.init_state(&rt, 42).unwrap();
    let batch = art.entry("train_step").unwrap().batch;
    let seq = art.manifest.seq_len();
    for step in 0..8 {
        let toks = pipe.train.next_batch(batch, seq);
        art.train_step(&rt, &mut state, step, &toks).unwrap();
    }
    let probe = pipe.valid.next_batch(batch, seq);
    let before = art.eval_loss(&rt, &mut state, &probe).unwrap();

    let dir = std::env::temp_dir().join(format!("sltrain-int-{}", std::process::id()));
    let path = dir.join("mid.ckpt");
    sltrain::coordinator::trainer::save_checkpoint(&art, &state, 8, &path).unwrap();

    // restore into a FRESH state and re-evaluate
    let mut state2 = art.init_state(&rt, 99).unwrap(); // different seed
    Checkpoint::load(&path).unwrap().restore_into(&mut state2).unwrap();
    let after = art.eval_loss(&rt, &mut state2, &probe).unwrap();
    assert!((before - after).abs() < 1e-5, "{before} vs {after}");
    std::fs::remove_dir_all(dir).ok();
}

#[test]
fn support_sidecars_match_manifest_and_are_valid() {
    if !has_artifacts() {
        return;
    }
    let art = Artifact::load(Path::new("artifacts/tiny_sltrain")).unwrap();
    let p = &art.manifest.preset;
    for (name, sup) in &art.manifest.supports {
        let raw = std::fs::read(art.dir.join(&sup.file)).unwrap();
        assert_eq!(raw.len(), sup.nnz * 4, "{name}");
        let idx: Vec<u32> = raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        // sorted, distinct, in range
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "{name} not sorted-unique");
        // bound: the largest linear is d_ff x d_model
        let bound = (p.d_ff.max(p.d_model) * p.d_ff.max(p.d_model)) as u32;
        assert!(idx.iter().all(|&i| i < bound), "{name} out of range");
        // delta: nnz should be ~3% of the corresponding matrix
        let base = name.trim_end_matches(".idx");
        let dims: Vec<usize> = art
            .manifest
            .consts
            .iter()
            .filter(|t| t.name == *name)
            .flat_map(|t| t.shape.clone())
            .collect();
        assert_eq!(dims[0], sup.nnz, "{base}");
    }
}

#[test]
fn galore_artifact_trains() {
    if !has_artifacts() {
        return;
    }
    let _g = RT.lock().unwrap();
    let rt = rt();
    let mut art = Artifact::load(Path::new("artifacts/tiny_galore")).unwrap();
    let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);
    let mut state = art.init_state(&rt, 42).unwrap();
    let batch = art.entry("train_step").unwrap().batch;
    let seq = art.manifest.seq_len();
    let mut first = 0.0;
    let mut last = 0.0;
    for step in 0..25 {
        let toks = pipe.train.next_batch(batch, seq);
        let l = art.train_step(&rt, &mut state, step, &toks).unwrap();
        if step == 0 {
            first = l;
        }
        last = l;
    }
    assert!(last < first, "galore did not reduce loss: {first} -> {last}");
    assert_eq!(art.manifest.optimizer, "galore");
}

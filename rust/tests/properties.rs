//! Property-based tests (in-repo mini-proptest: seeded random cases with
//! shrink-free failure reporting — the vendor set has no proptest crate).
//!
//! Invariants covered: JSON parse∘print = id, BPE encode/decode
//! faithfulness on random corpora, loader shard disjointness, checkpoint
//! byte-exact roundtrip on random tensors, SVD reconstruction on random
//! matrices, memory-estimator monotonicity in (r, δ), scatter-add
//! linearity — the coordinator-level invariants the paper's system relies
//! on.

use sltrain::config::preset;
use sltrain::data::{Bpe, CorpusConfig, Pipeline, SynthCorpus};
use sltrain::linalg::{svd, Matrix, ThreadPool};
use sltrain::mem::{estimate, MemOptions};
use sltrain::util::json::Json;
use sltrain::util::rng::Rng;

/// Run `f` over `n` seeded cases; report the failing seed.
fn forall(n: u64, f: impl Fn(&mut Rng) -> Result<(), String>) {
    for seed in 0..n {
        let mut rng = Rng::new(seed * 7919 + 13);
        if let Err(msg) = f(&mut rng) {
            panic!("property failed at seed {seed}: {msg}");
        }
    }
}

fn random_json(rng: &mut Rng, depth: usize) -> Json {
    match if depth == 0 { rng.below(4) } else { rng.below(6) } {
        0 => Json::Null,
        1 => Json::Bool(rng.f64() < 0.5),
        2 => Json::Num((rng.gaussian() * 100.0 * 8.0).round() / 8.0),
        3 => {
            let len = rng.below(12) as usize;
            let s: String = (0..len)
                .map(|_| {
                    let c = rng.below(96) as u8 + 32;
                    c as char
                })
                .collect();
            Json::Str(s)
        }
        4 => {
            let len = rng.below(5) as usize;
            Json::Arr((0..len).map(|_| random_json(rng, depth - 1)).collect())
        }
        _ => {
            let len = rng.below(5) as usize;
            Json::Obj(
                (0..len)
                    .map(|i| (format!("k{i}"), random_json(rng, depth - 1)))
                    .collect(),
            )
        }
    }
}

#[test]
fn prop_json_roundtrip() {
    forall(200, |rng| {
        let v = random_json(rng, 3);
        let text = v.to_string();
        let v2 = Json::parse(&text).map_err(|e| format!("{e} in {text}"))?;
        if v != v2 {
            return Err(format!("{v:?} != {v2:?}"));
        }
        Ok(())
    });
}

#[test]
fn prop_bpe_roundtrip_random_corpora() {
    forall(10, |rng| {
        let corpus = SynthCorpus::new(CorpusConfig {
            n_words: 80 + rng.below(200) as usize,
            seed: rng.next_u64(),
            ..Default::default()
        });
        let text = corpus.generate_text(800, 0);
        let bpe = Bpe::train(&text, 256 + rng.below(200) as usize);
        let other = corpus.generate_text(200, 1);
        let norm = |s: &str| {
            s.split('\n')
                .map(|l| l.split_whitespace().collect::<Vec<_>>().join(" "))
                .collect::<Vec<_>>()
                .join("\n")
        };
        let decoded = bpe.decode(&bpe.encode(&other));
        if norm(&decoded) != norm(&other) {
            return Err("bpe roundtrip mismatch".into());
        }
        Ok(())
    });
}

#[test]
fn prop_bpe_byte_roundtrip_arbitrary_byte_strings() {
    // the byte-exact path (unlike `encode`, which normalizes
    // whitespace) must invert on ARBITRARY bytes: invalid UTF-8,
    // control characters, whitespace runs, NULs — everything
    forall(20, |rng| {
        let corpus = SynthCorpus::new(CorpusConfig {
            seed: rng.next_u64(),
            ..Default::default()
        });
        let bpe = Bpe::train(&corpus.generate_text(800, 0), 256 + rng.below(300) as usize);
        let len = rng.below(2000) as usize;
        let data: Vec<u8> = (0..len)
            .map(|_| {
                if rng.f64() < 0.3 {
                    // bias toward whitespace + ASCII to stress the
                    // word-segmentation boundaries
                    *[b' ', b'\n', b'\t', b'\r', b'a', b'e'][rng.below(6) as usize]
                } else {
                    rng.below(256) as u8
                }
            })
            .collect();
        let ids = bpe.encode_bytes(&data);
        let back = bpe.decode_bytes(&ids);
        if back != data {
            return Err(format!(
                "byte roundtrip mismatch at len {len}: {} bytes back",
                back.len()
            ));
        }
        Ok(())
    });
}

#[test]
fn prop_bpe_train_deterministic_across_runs() {
    // two trainings on the same text must pick the identical merge
    // sequence: same vocab table, same encodings of unseen text
    forall(5, |rng| {
        let corpus = SynthCorpus::new(CorpusConfig {
            seed: rng.next_u64(),
            ..Default::default()
        });
        let text = corpus.generate_text(600, 0);
        let vocab = 256 + rng.below(300) as usize;
        let b1 = Bpe::train(&text, vocab);
        let b2 = Bpe::train(&text, vocab);
        if b1.vocab != b2.vocab {
            return Err("vocab tables differ between identical trainings".into());
        }
        let other = corpus.generate_text(300, 1);
        if b1.encode_bytes(other.as_bytes()) != b2.encode_bytes(other.as_bytes()) {
            return Err("encodings differ between identical trainings".into());
        }
        Ok(())
    });
}

#[test]
fn prop_bpe_parallel_tokenize_bit_identical_across_thread_counts() {
    // encode_bytes_par chunks at 16 KiB (split only after '\n'), so use
    // a corpus big enough for several chunks; the pool output must be
    // bit-identical to serial at every thread count
    forall(4, |rng| {
        let corpus = SynthCorpus::new(CorpusConfig {
            seed: rng.next_u64(),
            ..Default::default()
        });
        let bpe = Bpe::train(&corpus.generate_text(800, 0), 512);
        let text = corpus.generate_text(9000, 1); // ~50 KiB, several chunks
        let data = text.as_bytes();
        assert!(data.len() > 32 * 1024, "sample too small to exercise chunking");
        let serial = bpe.encode_bytes(data);
        for threads in [1usize, 2, 4] {
            let pool = ThreadPool::new(threads);
            let par = bpe.encode_bytes_par(data, &pool);
            if par != serial {
                return Err(format!("pool({threads}) output diverges from serial"));
            }
        }
        Ok(())
    });
}

#[test]
fn prop_loader_shards_disjoint_and_deterministic() {
    forall(6, |rng| {
        let seed = rng.next_u64() % 1000;
        let mut p1 = Pipeline::build(256, seed);
        let mut p2 = Pipeline::build(256, seed);
        let a1 = p1.train.next_batch(2, 64);
        let a2 = p2.train.next_batch(2, 64);
        if a1 != a2 {
            return Err("same-seed streams differ".into());
        }
        let v = p1.valid.next_batch(2, 64);
        if v == a1 {
            return Err("train/valid shards overlap".into());
        }
        Ok(())
    });
}

/// The pre-blocking kernel: a naive triple loop with the plain
/// `l = 0..k` accumulation order per output element.
fn matmul_naive_transb(a: &Matrix, bt: &Matrix) -> Matrix {
    assert_eq!(a.cols, bt.cols);
    let (m, k, n) = (a.rows, a.cols, bt.rows);
    let mut out = Matrix::zeros(m, n);
    for i in 0..m {
        for j in 0..n {
            let mut acc = 0.0f32;
            for l in 0..k {
                acc += a.data[i * k + l] * bt.data[j * k + l];
            }
            out.data[i * n + j] = acc;
        }
    }
    out
}

#[test]
fn prop_matmul_transb_bitwise_matches_naive_reference() {
    // random rectangular shapes, deliberately not multiples of the
    // MR=8 / NR=8 microkernel tile (including k not divisible by the
    // block size): the blocked kernel (SIMD or scalar — whichever path
    // is active) must agree bit for bit with the naive triple loop
    forall(25, |rng| {
        let m = 1 + rng.below(33) as usize;
        let k = 1 + rng.below(37) as usize;
        let n = 1 + rng.below(29) as usize;
        let a = Matrix::random(m, k, rng);
        let bt = Matrix::random(n, k, rng);
        let want = matmul_naive_transb(&a, &bt);
        let got = a.matmul_transb(&bt);
        if want.data != got.data {
            return Err(format!("blocked kernel diverges at {m}x{k}x{n}"));
        }
        let got2 = a.matmul(&bt.transpose());
        if want.data != got2.data {
            return Err(format!("matmul diverges at {m}x{k}x{n}"));
        }
        Ok(())
    });
}

#[test]
fn prop_parallel_matmul_deterministic_across_runs_and_threads() {
    // repeated parallel runs must be bit-identical (fixed reduction
    // order), and so must different thread counts
    forall(10, |rng| {
        let m = 1 + rng.below(40) as usize;
        let k = 1 + rng.below(24) as usize;
        let n = 1 + rng.below(24) as usize;
        let a = Matrix::random(m, k, rng);
        let bt = Matrix::random(n, k, rng);
        let serial = a.matmul_transb(&bt);
        for threads in [2usize, 4] {
            let pool = ThreadPool::new(threads);
            for rep in 0..3 {
                let got = a.matmul_transb_par(&bt, &pool);
                if got.data != serial.data {
                    return Err(format!(
                        "parallel run {rep} at {threads} threads diverges ({m}x{k}x{n})"
                    ));
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_svd_reconstructs_random_matrices() {
    forall(15, |rng| {
        let m = 3 + rng.below(14) as usize;
        let n = 3 + rng.below(14) as usize;
        let a = Matrix::random(m, n, rng);
        let f = svd(&a);
        // rebuild
        let k = f.s.len();
        let mut us = Matrix::zeros(m, k);
        for i in 0..m {
            for j in 0..k {
                us[(i, j)] = f.u[(i, j)] * f.s[j];
            }
        }
        let err = a.sub(&us.matmul(&f.vt)).max_abs();
        if err > 1e-3 {
            return Err(format!("svd err {err} at {m}x{n}"));
        }
        // descending singular values
        if !f.s.windows(2).all(|w| w[0] >= w[1] - 1e-5) {
            return Err("sigma not descending".into());
        }
        Ok(())
    });
}

#[test]
fn prop_scatter_add_is_linear() {
    forall(20, |rng| {
        let d = 4 + rng.below(12) as usize;
        let p = 4 + rng.below(12) as usize;
        let nnz = 1 + rng.below((d * p) as u64 / 2) as usize;
        let idx: Vec<u32> = rng
            .sample_without_replacement((d * p) as u64, nnz)
            .into_iter()
            .map(|x| x as u32)
            .collect();
        let v1: Vec<f32> = (0..nnz).map(|_| rng.gaussian() as f32).collect();
        let v2: Vec<f32> = (0..nnz).map(|_| rng.gaussian() as f32).collect();
        // scatter(v1) + scatter(v2) == scatter(v1 + v2)
        let mut a = Matrix::zeros(d, p);
        a.scatter_add(&idx, &v1);
        a.scatter_add(&idx, &v2);
        let mut b = Matrix::zeros(d, p);
        let sum: Vec<f32> = v1.iter().zip(&v2).map(|(x, y)| x + y).collect();
        b.scatter_add(&idx, &sum);
        if a.sub(&b).max_abs() > 1e-6 {
            return Err("scatter-add not linear".into());
        }
        Ok(())
    });
}

#[test]
fn prop_mem_estimator_monotone_in_r_and_delta() {
    forall(10, |rng| {
        let mut p = preset("paper60m").unwrap();
        let r1 = 16 + rng.below(100) as usize;
        let r2 = r1 + 1 + rng.below(100) as usize;
        let d1 = 0.005 + rng.f64() * 0.05;
        let d2 = d1 + 0.001 + rng.f64() * 0.05;
        let opts = MemOptions::default();
        p.rank = r1;
        p.delta = d1;
        let base = estimate(&p, "sltrain", opts).table2_bytes();
        p.rank = r2;
        let more_rank = estimate(&p, "sltrain", opts).table2_bytes();
        p.rank = r1;
        p.delta = d2;
        let more_delta = estimate(&p, "sltrain", opts).table2_bytes();
        if more_rank <= base {
            return Err(format!("mem not monotone in r: {base} vs {more_rank}"));
        }
        if more_delta <= base {
            return Err(format!("mem not monotone in delta: {base} vs {more_delta}"));
        }
        // sltrain always cheaper than full at paper-scale deltas
        p.delta = d1;
        let full = estimate(&p, "full", opts).table2_bytes();
        let slt = estimate(&p, "sltrain", opts).table2_bytes();
        if slt >= full {
            return Err("sltrain >= full memory".into());
        }
        Ok(())
    });
}

#[test]
fn prop_rng_sample_without_replacement_exact() {
    forall(30, |rng| {
        let n = 1 + rng.below(500);
        let k = rng.below(n + 1) as usize;
        let v = rng.sample_without_replacement(n, k);
        if v.len() != k {
            return Err("wrong count".into());
        }
        if !v.windows(2).all(|w| w[0] < w[1]) {
            return Err("not sorted-distinct".into());
        }
        if v.iter().any(|&x| x >= n) {
            return Err("out of range".into());
        }
        Ok(())
    });
}

#[test]
fn prop_truncate_rank_error_decreases_with_r() {
    forall(8, |rng| {
        let a = Matrix::random(16, 12, rng);
        let mut last = f32::INFINITY;
        for r in [1usize, 3, 6, 12] {
            let err = a.sub(&a.truncate_rank(r)).frob_norm();
            if err > last + 1e-4 {
                return Err(format!("rank-{r} err {err} > previous {last}"));
            }
            last = err;
        }
        if last > 1e-3 {
            return Err(format!("full-rank truncation err {last}"));
        }
        Ok(())
    });
}

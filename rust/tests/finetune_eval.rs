//! Fine-tune + eval harness tests through the REAL binary: a pinned-seed
//! golden trajectory (pretrain → finetune → eval) that must be bitwise
//! identical at 1/2/4 threads and match an in-process library replay;
//! every method fine-tuning both live and post-fold with the downstream
//! loss decreasing; and the shard-backed data path end to end.

mod support;

use std::path::{Path, PathBuf};

use support::harness::run_sltrain;

use sltrain::backend::{self, BackendSpec};
use sltrain::config::{preset, METHODS};
use sltrain::coordinator::{train, Checkpoint, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::linalg::SupportPattern;
use sltrain::util::json::Json;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sltrain-ft-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

fn run_ok(args: &[&str]) -> String {
    let (st, out, err) = run_sltrain(args, &[]);
    assert!(st.success(), "`sltrain {}` failed:\n{out}\n{err}", args.join(" "));
    out
}

fn pretrain(ckpt: &Path, method: &str, steps: usize) {
    run_ok(&[
        "train", "--backend", "native", "--config", "tiny", "--method", method,
        "--batch", "2", "--eval-every", "0", "--log-every", "0",
        "--steps", &steps.to_string(),
        "--checkpoint", ckpt.to_str().unwrap(),
    ]);
}

/// Parse a `finetune --json` / `eval --json` report from disk.
fn load_json(path: &Path) -> Json {
    let text = std::fs::read_to_string(path)
        .unwrap_or_else(|e| panic!("reading {}: {e}", path.display()));
    Json::parse(&text).unwrap_or_else(|e| panic!("bad json in {}: {e}", path.display()))
}

fn f64_of(j: &Json, key: &str) -> f64 {
    j.get(key)
        .and_then(|v| v.as_f64())
        .unwrap_or_else(|| panic!("no numeric `{key}` in {j:?}"))
}

/// Golden trajectory: pinned-seed 50-step pretrain → 20-step finetune →
/// eval, through the real binary. The full-precision final loss must be
/// BIT-identical at 1/2/4 threads, bit-identical to an in-process
/// library replay of the same run, below the zero-shot baseline, and
/// inside a sane absolute band.
#[test]
fn golden_trajectory_is_bitwise_across_threads_and_matches_library() {
    let dir = tmp_dir("golden");
    let pre = dir.join("pre.ckpt");
    pretrain(&pre, "sltrain", 50);

    let ft_ckpt = dir.join("ft.ckpt");
    let mut finals: Vec<(f64, f64)> = Vec::new();
    for threads in [1usize, 2, 4] {
        let json = dir.join(format!("ft{threads}.json"));
        run_ok(&[
            "finetune", "--backend", "native", "--config", "tiny", "--method", "sltrain",
            "--batch", "2", "--eval-every", "0", "--log-every", "0",
            "--checkpoint", pre.to_str().unwrap(),
            "--steps", "20",
            "--threads", &threads.to_string(),
            "--out-checkpoint", ft_ckpt.to_str().unwrap(),
            "--json", json.to_str().unwrap(),
        ]);
        let r = load_json(&json);
        let final_loss = f64_of(&r, "final_eval_loss");
        let zero_loss = f64_of(&r, "zero_shot_loss");
        let final_ppl = f64_of(&r, "final_ppl");
        let zero_ppl = f64_of(&r, "zero_shot_ppl");
        assert!(
            final_ppl < zero_ppl,
            "{threads}t: finetune did not beat zero-shot ({final_ppl} vs {zero_ppl})"
        );
        // absolute band: a 70-step tiny model sits far below the
        // untrained ~vocab(256) ppl but can't reach ~1
        assert!(
            final_ppl.is_finite() && final_ppl > 1.5 && final_ppl < 200.0,
            "{threads}t: final ppl {final_ppl} outside the golden band (1.5, 200)"
        );
        finals.push((final_loss, zero_loss));
    }
    for (i, threads) in [2usize, 4].iter().enumerate() {
        assert_eq!(
            finals[0],
            finals[i + 1],
            "losses at {threads} threads differ bitwise from 1 thread"
        );
    }

    // in-process library replay of the same fine-tune (same ops, same
    // seeds) — the CLI value must be the library value, bit for bit
    let ck = Checkpoint::load(&pre).unwrap();
    let base: Vec<_> = ck
        .to_state_tensors()
        .into_iter()
        .filter(|t| !t.name.starts_with("optim."))
        .collect();
    let spec = BackendSpec::Native {
        preset: preset("tiny").unwrap(),
        method: "sltrain".into(),
        batch: 2,
        lr: 3e-3,
        total_steps: 2000,
        threads: 1,
        optim_bits: 0,
        galore_every: 0,
        support: SupportPattern::UniformRandom,
        workers: 0,
    };
    let mut be = backend::open(spec).unwrap();
    let mut pipe = Pipeline::build(be.preset().vocab, 1234);
    let cfg = TrainConfig {
        steps: 20,
        eval_every: 0,
        eval_batches: 4,
        log_every: 0,
        seed: 42,
        init_tensors: Some(base),
        ..Default::default()
    };
    let r = train(be.as_mut(), &mut pipe, &cfg).unwrap();
    assert_eq!(
        r.final_eval_loss, finals[0].0,
        "CLI finetune loss differs from the in-process library replay"
    );

    // eval the fine-tuned checkpoint on the same downstream corpus: the
    // held-out loss must reproduce the trainer's final number
    let eval_json = dir.join("eval.json");
    run_ok(&[
        "eval", "--backend", "native", "--config", "tiny", "--method", "sltrain",
        "--batch", "2", "--data-seed", "1234",
        "--checkpoint", ft_ckpt.to_str().unwrap(),
        "--json", eval_json.to_str().unwrap(),
    ]);
    let rep = load_json(&eval_json);
    let rows = rep.get("results").and_then(|r| r.as_arr()).expect("results array");
    assert_eq!(rows.len(), 1);
    let eval_loss = f64_of(&rows[0], "eval_loss");
    assert!(
        (eval_loss - finals[0].0).abs() < 1e-9,
        "eval harness loss {eval_loss} != trainer final loss {}",
        finals[0].0
    );
    assert!(f64_of(&rows[0], "next_token_acc") > 0.0, "dead next-token accuracy");
    std::fs::remove_dir_all(dir).ok();
}

/// Every method resumes from its pretrain checkpoint and fine-tunes on
/// the downstream corpus both LIVE (same parameterization) and FOLDED
/// (dense after `fold_weights`), with the held-out loss ending below the
/// zero-shot baseline in both modes.
#[test]
fn all_methods_finetune_live_and_folded_decrease_downstream_loss() {
    let dir = tmp_dir("methods");
    for method in METHODS {
        let pre = dir.join(format!("pre-{method}.ckpt"));
        pretrain(&pre, method, 10);
        for fold in [false, true] {
            let tag = if fold { "fold" } else { "live" };
            let json = dir.join(format!("ft-{method}-{tag}.json"));
            let mut args = vec![
                "finetune", "--backend", "native", "--config", "tiny", "--method", method,
                "--batch", "2", "--eval-every", "0", "--log-every", "0",
                "--checkpoint", pre.to_str().unwrap(),
                "--steps", "10",
                "--json", json.to_str().unwrap(),
            ];
            if fold {
                args.push("--fold");
            }
            run_ok(&args);
            let r = load_json(&json);
            assert_eq!(r.get("fold").and_then(|f| f.as_bool()), Some(fold));
            let final_loss = f64_of(&r, "final_eval_loss");
            let zero_loss = f64_of(&r, "zero_shot_loss");
            assert!(
                final_loss < zero_loss,
                "{method}/{tag}: downstream loss did not decrease \
                 ({final_loss} vs zero-shot {zero_loss})"
            );
        }
    }
    std::fs::remove_dir_all(dir).ok();
}

/// The shard-backed data path end to end: build shards via the CLI,
/// fine-tune on them, and beat the zero-shot baseline on the shard
/// corpus' held-out split.
#[test]
fn finetune_on_shard_corpus_decreases_loss() {
    let dir = tmp_dir("shards");
    let shards = dir.join("corpus");
    run_ok(&[
        "data",
        "--make-shards", shards.to_str().unwrap(),
        "--shards", "3",
        "--shard-tokens", "3000",
        "--vocab", "256",
        "--seed", "11",
    ]);
    let pre = dir.join("pre.ckpt");
    pretrain(&pre, "sltrain", 10);
    let json = dir.join("ft.json");
    run_ok(&[
        "finetune", "--backend", "native", "--config", "tiny", "--method", "sltrain",
        "--batch", "2", "--eval-every", "0", "--log-every", "0",
        "--checkpoint", pre.to_str().unwrap(),
        "--steps", "10",
        "--data", shards.to_str().unwrap(),
        "--json", json.to_str().unwrap(),
    ]);
    let r = load_json(&json);
    assert!(
        f64_of(&r, "final_eval_loss") < f64_of(&r, "zero_shot_loss"),
        "shard-corpus finetune did not beat zero-shot: {r:?}"
    );
    std::fs::remove_dir_all(dir).ok();
}

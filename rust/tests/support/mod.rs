//! Shared test-support layer for process-level (black-box) tests.
//!
//! `harness` spawns the real `sltrain` binary (via `CARGO_BIN_EXE`),
//! talks to it over its Unix-socket protocol, and guarantees the child
//! is killed when the test ends — pass or fail.
//!
//! ## The deadline-poll pattern (no fixed sleeps)
//!
//! Anything asynchronous in these tests — a daemon binding its socket,
//! a child process exiting, a timing ratio stabilizing — is awaited
//! with [`harness::deadline_poll`]: retry a cheap check every few
//! milliseconds until it succeeds or a generous deadline expires.
//! Never `sleep(500ms)` and hope:
//!
//! * a fixed sleep long enough for the slowest CI runner wastes that
//!   time on every fast run, and is *still* a flake on an outlier;
//! * a deadline-poll costs microseconds on a fast machine and only
//!   ever fails when the awaited condition is genuinely broken —
//!   and then it fails loudly, naming what it was waiting for.
//!
//! The same idea applies to timing assertions: measure repeatedly
//! until the expected relation holds (or the deadline says it never
//! will), instead of asserting on a single noisy sample — see
//! `threaded_step_loop_beats_single_thread` in `native_backend.rs`.

// each test binary compiles its own copy of this module and uses a
// subset of it; unused helpers in one binary are not dead code
#![allow(dead_code)]

pub mod harness;

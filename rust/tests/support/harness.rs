//! Black-box daemon harness: spawn the real binary, speak the
//! newline-delimited JSON protocol, kill the child on drop.

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use sltrain::Json;

/// Generous ceiling for anything a healthy daemon does in milliseconds.
/// A deadline this loose never slows a passing test (polls return as
/// soon as the condition holds); it only bounds how long a broken one
/// can hang.
pub const DEADLINE: Duration = Duration::from_secs(60);

/// Poll `check` every 10 ms until it returns `Some`, or panic after
/// `deadline` naming `what` — the repo's flake-proof replacement for
/// fixed sleeps (see the module docs in `support/mod.rs`).
pub fn deadline_poll<T>(
    what: &str,
    deadline: Duration,
    mut check: impl FnMut() -> Option<T>,
) -> T {
    let t0 = Instant::now();
    loop {
        if let Some(v) = check() {
            return v;
        }
        assert!(
            t0.elapsed() <= deadline,
            "deadline ({deadline:?}) expired waiting for: {what}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

static NEXT_DAEMON: AtomicU64 = AtomicU64::new(0);

/// A running `sltrain serve` child process bound to a temp socket.
/// Killed (and its temp dir removed) on drop, so a failing test never
/// leaks a daemon.
pub struct Daemon {
    child: Child,
    /// The socket the daemon is serving on.
    pub socket: PathBuf,
    dir: PathBuf,
}

impl Daemon {
    /// Spawn `sltrain serve --socket <tmp> <extra args>` and wait (by
    /// deadline-poll, not sleep) until the socket accepts connections.
    pub fn spawn(extra_args: &[&str]) -> Daemon {
        let dir = std::env::temp_dir().join(format!(
            "sltrain-serve-{}-{}",
            std::process::id(),
            NEXT_DAEMON.fetch_add(1, Ordering::SeqCst)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let socket = dir.join("serve.sock");
        let child = Command::new(env!("CARGO_BIN_EXE_sltrain"))
            .arg("serve")
            .arg("--socket")
            .arg(&socket)
            .args(extra_args)
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawning sltrain serve");
        let mut daemon = Daemon { child, socket, dir };
        // connect-retry with deadline: model init can take a moment,
        // and the socket file appears slightly before bind completes
        deadline_poll("daemon socket to accept connections", DEADLINE, || {
            if let Some(status) = daemon.child.try_wait().unwrap() {
                panic!("daemon exited during startup: {status}");
            }
            UnixStream::connect(&daemon.socket).ok().map(drop)
        });
        daemon
    }

    /// Open a protocol connection to the daemon.
    pub fn connect(&self) -> Client {
        let stream = deadline_poll("connecting to the daemon socket", DEADLINE, || {
            UnixStream::connect(&self.socket).ok()
        });
        Client::new(stream)
    }

    /// Deadline-poll until the child exits; returns its status.
    pub fn wait_exit(&mut self) -> std::process::ExitStatus {
        deadline_poll("daemon process exit", DEADLINE, || {
            self.child.try_wait().expect("waiting on daemon child")
        })
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        // kill is a no-op if the child already exited cleanly
        let _ = self.child.kill();
        let _ = self.child.wait();
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// One protocol connection: typed line-oriented send/recv with read
/// timeouts, so a silent daemon fails the test instead of hanging it.
pub struct Client {
    reader: BufReader<UnixStream>,
    writer: UnixStream,
}

impl Client {
    fn new(stream: UnixStream) -> Client {
        stream.set_read_timeout(Some(DEADLINE)).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { reader, writer: stream }
    }

    /// Send one raw request line (no trailing newline needed).
    pub fn send_raw(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
    }

    /// Read one response line and parse it as JSON.
    pub fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("reading daemon response");
        assert!(n > 0, "daemon closed the connection mid-exchange");
        Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
    }

    /// Send a raw line and read the one response it produces.
    pub fn request(&mut self, line: &str) -> Json {
        self.send_raw(line);
        self.recv()
    }

    /// Typed `generate`: returns the response object (assert on
    /// `ok` / `tokens` at the call site).
    pub fn generate(&mut self, prompt: &[i32], max_tokens: usize) -> Json {
        let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
        self.request(&format!(
            r#"{{"op":"generate","prompt":[{}],"max_tokens":{max_tokens}}}"#,
            toks.join(",")
        ))
    }

    /// Extract the generated token ids from a `generate` response.
    pub fn tokens_of(resp: &Json) -> Vec<i64> {
        assert_eq!(resp.get("ok").and_then(|o| o.as_bool()), Some(true), "error: {resp:?}");
        resp.get("tokens")
            .and_then(|t| t.as_arr())
            .unwrap_or_else(|| panic!("no tokens in {resp:?}"))
            .iter()
            .map(|t| t.as_i64().unwrap())
            .collect()
    }
}

//! Black-box daemon harness: spawn the real binary, speak the
//! newline-delimited JSON protocol, kill the child on drop.

use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::UnixStream;
use std::path::PathBuf;
use std::process::{Child, Command, Stdio};
use std::sync::atomic::{AtomicU64, Ordering};
use std::time::{Duration, Instant};

use sltrain::Json;

/// Generous ceiling for anything a healthy daemon does in milliseconds.
/// A deadline this loose never slows a passing test (polls return as
/// soon as the condition holds); it only bounds how long a broken one
/// can hang.
pub const DEADLINE: Duration = Duration::from_secs(60);

/// Poll `check` every 10 ms until it returns `Some`, or panic after
/// `deadline` naming `what` — the repo's flake-proof replacement for
/// fixed sleeps (see the module docs in `support/mod.rs`).
pub fn deadline_poll<T>(
    what: &str,
    deadline: Duration,
    mut check: impl FnMut() -> Option<T>,
) -> T {
    let t0 = Instant::now();
    loop {
        if let Some(v) = check() {
            return v;
        }
        assert!(
            t0.elapsed() <= deadline,
            "deadline ({deadline:?}) expired waiting for: {what}"
        );
        std::thread::sleep(Duration::from_millis(10));
    }
}

static NEXT_DAEMON: AtomicU64 = AtomicU64::new(0);

/// A running `sltrain serve` child process bound to a temp socket.
/// Killed (and its temp dir removed) on drop, so a failing test never
/// leaks a daemon.
pub struct Daemon {
    child: Child,
    /// The socket the daemon is serving on.
    pub socket: PathBuf,
    dir: PathBuf,
}

impl Daemon {
    /// Spawn `sltrain serve --socket <tmp> <extra args>` and wait (by
    /// deadline-poll, not sleep) until the socket accepts connections.
    pub fn spawn(extra_args: &[&str]) -> Daemon {
        let dir = std::env::temp_dir().join(format!(
            "sltrain-serve-{}-{}",
            std::process::id(),
            NEXT_DAEMON.fetch_add(1, Ordering::SeqCst)
        ));
        std::fs::create_dir_all(&dir).unwrap();
        let socket = dir.join("serve.sock");
        let child = Command::new(env!("CARGO_BIN_EXE_sltrain"))
            .arg("serve")
            .arg("--socket")
            .arg(&socket)
            .args(extra_args)
            .stdout(Stdio::null())
            .stderr(Stdio::inherit())
            .spawn()
            .expect("spawning sltrain serve");
        let mut daemon = Daemon { child, socket, dir };
        // connect-retry with deadline: model init can take a moment,
        // and the socket file appears slightly before bind completes
        deadline_poll("daemon socket to accept connections", DEADLINE, || {
            if let Some(status) = daemon.child.try_wait().unwrap() {
                panic!("daemon exited during startup: {status}");
            }
            UnixStream::connect(&daemon.socket).ok().map(drop)
        });
        daemon
    }

    /// Open a protocol connection to the daemon.
    pub fn connect(&self) -> Client {
        let stream = deadline_poll("connecting to the daemon socket", DEADLINE, || {
            UnixStream::connect(&self.socket).ok()
        });
        Client::new(stream)
    }

    /// Deadline-poll until the child exits; returns its status.
    pub fn wait_exit(&mut self) -> std::process::ExitStatus {
        deadline_poll("daemon process exit", DEADLINE, || {
            self.child.try_wait().expect("waiting on daemon child")
        })
    }

    /// OS pid of the daemon child (for sending it real signals).
    pub fn pid(&self) -> u32 {
        self.child.id()
    }
}

impl Drop for Daemon {
    fn drop(&mut self) {
        // kill is a no-op if the child already exited cleanly
        let _ = self.child.kill();
        let _ = self.child.wait();
        let _ = std::fs::remove_dir_all(&self.dir);
    }
}

/// One protocol connection: typed line-oriented send/recv with read
/// timeouts, so a silent daemon fails the test instead of hanging it.
pub struct Client {
    reader: BufReader<UnixStream>,
    writer: UnixStream,
}

impl Client {
    fn new(stream: UnixStream) -> Client {
        stream.set_read_timeout(Some(DEADLINE)).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        Client { reader, writer: stream }
    }

    /// Send one raw request line (no trailing newline needed).
    pub fn send_raw(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).unwrap();
        self.writer.write_all(b"\n").unwrap();
        self.writer.flush().unwrap();
    }

    /// Write raw bytes with NO trailing newline — a deliberately
    /// half-sent request, for read-timeout/stall tests.
    pub fn send_partial(&mut self, bytes: &str) {
        self.writer.write_all(bytes.as_bytes()).unwrap();
        self.writer.flush().unwrap();
    }

    /// Block until the daemon closes this connection (clean EOF or a
    /// reset). Returns true when it did; panics only on a read timeout.
    pub fn wait_closed(&mut self) -> bool {
        let mut line = String::new();
        match self.reader.read_line(&mut line) {
            Ok(0) => true,
            Ok(_) => false,
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                panic!("daemon neither answered nor closed the connection")
            }
            Err(_) => true,
        }
    }

    /// Try to read one response line within `dur`; `None` on timeout.
    /// Restores the default (deadline-length) read timeout either way.
    /// Responses are single short lines written in one syscall, so a
    /// timeout never lands mid-line — asserted, not assumed.
    pub fn try_recv_within(&mut self, dur: Duration) -> Option<Json> {
        self.reader.get_ref().set_read_timeout(Some(dur)).unwrap();
        let mut line = String::new();
        let got = match self.reader.read_line(&mut line) {
            Ok(0) => panic!("daemon closed the connection mid-exchange"),
            Ok(_) => {
                Some(Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad {line:?}: {e}")))
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                assert!(line.is_empty(), "read timed out mid-line: {line:?}");
                None
            }
            Err(e) => panic!("reading daemon response: {e}"),
        };
        self.reader.get_ref().set_read_timeout(Some(DEADLINE)).unwrap();
        got
    }

    /// Read one response line and parse it as JSON.
    pub fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("reading daemon response");
        assert!(n > 0, "daemon closed the connection mid-exchange");
        Json::parse(line.trim()).unwrap_or_else(|e| panic!("bad response {line:?}: {e}"))
    }

    /// Send a raw line and read the one response it produces.
    pub fn request(&mut self, line: &str) -> Json {
        self.send_raw(line);
        self.recv()
    }

    /// Typed `generate`: returns the response object (assert on
    /// `ok` / `tokens` at the call site).
    pub fn generate(&mut self, prompt: &[i32], max_tokens: usize) -> Json {
        let toks: Vec<String> = prompt.iter().map(|t| t.to_string()).collect();
        self.request(&format!(
            r#"{{"op":"generate","prompt":[{}],"max_tokens":{max_tokens}}}"#,
            toks.join(",")
        ))
    }

    /// Extract the generated token ids from a `generate` response.
    pub fn tokens_of(resp: &Json) -> Vec<i64> {
        assert_eq!(resp.get("ok").and_then(|o| o.as_bool()), Some(true), "error: {resp:?}");
        resp.get("tokens")
            .and_then(|t| t.as_arr())
            .unwrap_or_else(|| panic!("no tokens in {resp:?}"))
            .iter()
            .map(|t| t.as_i64().unwrap())
            .collect()
    }
}

/// Spawn `sltrain <args>` with extra environment variables, stdout and
/// stderr piped. Wrap the child in [`ChildGuard`] (or wait on it) so a
/// failing test cannot leak the process.
pub fn spawn_sltrain(args: &[&str], envs: &[(&str, &str)]) -> Child {
    let mut cmd = Command::new(env!("CARGO_BIN_EXE_sltrain"));
    cmd.args(args).stdout(Stdio::piped()).stderr(Stdio::piped());
    for (k, v) in envs {
        cmd.env(k, v);
    }
    cmd.spawn().expect("spawning sltrain")
}

/// Run `sltrain <args>` to completion; (status, stdout, stderr).
pub fn run_sltrain(
    args: &[&str],
    envs: &[(&str, &str)],
) -> (std::process::ExitStatus, String, String) {
    let out = spawn_sltrain(args, envs).wait_with_output().expect("waiting for sltrain");
    (
        out.status,
        String::from_utf8_lossy(&out.stdout).into_owned(),
        String::from_utf8_lossy(&out.stderr).into_owned(),
    )
}

/// Send a POSIX signal ("TERM", "KILL", "INT", ...) to `pid` via the
/// `kill` shell utility — std has no direct kill(2) binding.
pub fn signal_pid(pid: u32, sig: &str) {
    let status = Command::new("kill")
        .arg(format!("-{sig}"))
        .arg(pid.to_string())
        .status()
        .expect("running kill(1)");
    assert!(status.success(), "kill -{sig} {pid} failed");
}

/// Kill-on-drop wrapper for ad-hoc child processes (train runs under
/// crash tests): a panicking test never leaks a training process.
pub struct ChildGuard(pub Child);

impl ChildGuard {
    /// Deadline-poll until the child exits; returns its status.
    pub fn wait_exit(&mut self) -> std::process::ExitStatus {
        deadline_poll("child process exit", DEADLINE, || {
            self.0.try_wait().expect("waiting on child")
        })
    }

    /// Take the real child out (e.g. for `wait_with_output`, which
    /// consumes it), leaving a trivial finished process in the guard.
    pub fn take(&mut self) -> Child {
        let placeholder = Command::new("true").spawn().expect("spawning true");
        std::mem::replace(&mut self.0, placeholder)
    }
}

impl Drop for ChildGuard {
    fn drop(&mut self) {
        let _ = self.0.kill();
        let _ = self.0.wait();
    }
}

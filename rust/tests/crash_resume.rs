//! Crash-safety tests: kill the real `sltrain train` binary inside
//! every checkpoint durability window (deterministically, via
//! `SLTRAIN_FAILPOINT`, and stochastically, via SIGKILL), then prove
//! `--resume` always finds a validating checkpoint and finishes with a
//! final checkpoint bit-identical to an uninterrupted run — the PR 6
//! determinism contract, under crash fire.
//!
//! Also covers the divergence guard (in-process, with a NaN-injecting
//! backend wrapper), graceful SIGTERM shutdown, and the typed errors
//! every class of malformed checkpoint must produce.

mod support;

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use support::harness::{
    deadline_poll, run_sltrain, signal_pid, spawn_sltrain, ChildGuard, DEADLINE,
};

use sltrain::backend::native::NativeBackend;
use sltrain::backend::{Backend, StateTensor};
use sltrain::config::{preset, ModelPreset};
use sltrain::coordinator::trainer::train;
use sltrain::coordinator::{Checkpoint, CheckpointError, TrainConfig};
use sltrain::data::Pipeline;
use sltrain::linalg::SupportPattern;

fn tmp_dir(tag: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("sltrain-crash-{tag}-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// The common fast CLI train invocation (tiny model, no eval/log noise).
fn train_args(steps: usize, ckpt: &Path, every: usize, resume: bool) -> Vec<String> {
    let mut v: Vec<String> = [
        "train", "--backend", "native", "--config", "tiny", "--method", "sltrain",
        "--batch", "2", "--eval-every", "0", "--log-every", "0",
    ]
    .iter()
    .map(|s| s.to_string())
    .collect();
    v.push("--steps".into());
    v.push(steps.to_string());
    v.push("--checkpoint".into());
    v.push(ckpt.to_string_lossy().into_owned());
    v.push("--checkpoint-every".into());
    v.push(every.to_string());
    if resume {
        v.push("--resume".into());
    }
    v
}

fn run_train(
    steps: usize,
    ckpt: &Path,
    every: usize,
    resume: bool,
    envs: &[(&str, &str)],
) -> (std::process::ExitStatus, String, String) {
    let args = train_args(steps, ckpt, every, resume);
    let refs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    run_sltrain(&refs, envs)
}

/// Step recorded in the primary checkpoint, if it currently validates.
fn ckpt_step(path: &Path) -> Option<usize> {
    Checkpoint::load(path).ok().map(|c| c.step)
}

/// Deterministic crashes: abort the process inside EVERY failpoint
/// window of the checkpoint save protocol (second save = mid-run), then
/// resume. Each window must leave a recoverable chain, and the resumed
/// final checkpoint must be byte-identical to the uninterrupted one.
#[test]
fn failpoint_abort_in_each_save_window_is_recoverable() {
    let dir = tmp_dir("failpoints");
    let ref_ckpt = dir.join("ref.ckpt");
    let (st, _, err) = run_train(6, &ref_ckpt, 2, false, &[]);
    assert!(st.success(), "reference run failed:\n{err}");
    let want = std::fs::read(&ref_ckpt).unwrap();

    for window in [
        "checkpoint.save.before_write",
        "checkpoint.save.after_header",
        "checkpoint.save.before_rotate",
        "checkpoint.save.before_rename",
        "checkpoint.save.after_rename",
    ] {
        let ckpt = dir.join(format!("{}.ckpt", window.replace('.', "_")));
        // crash on the SECOND save (step 4 of 6): history exists, the
        // rotation machinery is fully engaged
        let spec = format!("{window}=abort@2");
        let (st, _, _) = run_train(6, &ckpt, 2, false, &[("SLTRAIN_FAILPOINT", &spec)]);
        assert!(!st.success(), "{window}: armed abort did not kill the run");

        let (st, _, err) = run_train(6, &ckpt, 2, true, &[]);
        assert!(st.success(), "{window}: resume failed:\n{err}");
        let got = std::fs::read(&ckpt).unwrap();
        assert_eq!(
            got, want,
            "{window}: resumed final checkpoint is not bit-identical to the reference"
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

/// Stochastic crashes: SIGKILL the training process twice at arbitrary
/// points mid-run (timed off checkpoint progress, not sleeps), resume
/// each time, and compare the final checkpoint byte-for-byte against an
/// uninterrupted reference.
#[test]
fn sigkill_twice_then_resume_is_bit_identical() {
    let dir = tmp_dir("sigkill");
    let steps = 12usize;

    let ref_ckpt = dir.join("ref.ckpt");
    let (st, _, err) = run_train(steps, &ref_ckpt, 0, false, &[]);
    assert!(st.success(), "reference run failed:\n{err}");
    let want = std::fs::read(&ref_ckpt).unwrap();

    let ckpt = dir.join("crash.ckpt");
    for min_step in [3usize, 6] {
        let args = train_args(steps, &ckpt, 1, true);
        let refs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
        let mut child = ChildGuard(spawn_sltrain(&refs, &[]));
        // wait until the run demonstrably passed `min_step`, then KILL —
        // the signal can land mid-save, mid-rotation, anywhere. On a
        // machine fast enough to finish first, skip the kill (the final
        // bit-identity assertion below still holds).
        let reached = deadline_poll(&format!("checkpoint to reach step {min_step}"), DEADLINE, || {
            if let Some(st) = child.0.try_wait().unwrap() {
                assert!(st.success(), "train exited early and unsuccessfully: {st}");
                return Some(false);
            }
            Checkpoint::load_newest_valid(&ckpt)
                .ok()
                .flatten()
                .filter(|(ck, _)| ck.step >= min_step)
                .map(|_| true)
        });
        if !reached {
            break;
        }
        signal_pid(child.0.id(), "KILL");
        let st = child.wait_exit();
        assert!(!st.success(), "SIGKILL'd process reported success");
    }

    // final resume runs to completion
    let (st, _, err) = run_train(steps, &ckpt, 1, true, &[]);
    assert!(st.success(), "final resume failed:\n{err}");
    let got = std::fs::read(&ckpt).unwrap();
    assert_eq!(got, want, "crash-resumed final checkpoint differs from uninterrupted run");
    std::fs::remove_dir_all(dir).ok();
}

/// Graceful SIGTERM: the run saves a resumable checkpoint, announces
/// the resume step, and exits 0 — then actually resumes to the same
/// final bytes as an uninterrupted run.
#[test]
fn sigterm_saves_resumable_checkpoint_and_exits_zero() {
    let dir = tmp_dir("sigterm");
    let steps = 5000usize; // far more than will run; SIGTERM ends it

    let ckpt = dir.join("graceful.ckpt");
    let args = train_args(steps, &ckpt, 2, false);
    let refs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    let mut child = ChildGuard(spawn_sltrain(&refs, &[]));
    deadline_poll("first checkpoint to appear", DEADLINE, || {
        if let Some(st) = child.0.try_wait().unwrap() {
            panic!("train exited early: {st}");
        }
        ckpt_step(&ckpt)
    });
    signal_pid(child.0.id(), "TERM");
    let out = child.take().wait_with_output().expect("waiting for SIGTERM'd train");
    assert!(out.status.success(), "SIGTERM must exit 0, got {}", out.status);
    let stdout = String::from_utf8_lossy(&out.stdout);
    assert!(
        stdout.contains("resumable at step"),
        "no resumable-at-step notice in stdout:\n{stdout}"
    );
    let resumed_from = ckpt_step(&ckpt).expect("no valid checkpoint after SIGTERM");
    assert!(resumed_from >= 2 && resumed_from < steps, "odd resume step {resumed_from}");
    std::fs::remove_dir_all(dir).ok();
}

/// Every malformed-checkpoint class yields a typed `CheckpointError`
/// through the library API — never a panic, never a silent load.
#[test]
fn malformed_checkpoints_yield_typed_errors() {
    let dir = tmp_dir("typed-errors");
    let good_path = dir.join("good.ckpt");
    let mut tensors = BTreeMap::new();
    tensors.insert(
        "w".to_string(),
        (vec![4usize], sltrain::runtime::Dtype::F32, vec![0u8; 16]),
    );
    Checkpoint { step: 2, tensors }.save(&good_path).unwrap();
    let good = std::fs::read(&good_path).unwrap();

    let cases: Vec<(&str, Vec<u8>)> = vec![
        ("zero-byte", vec![]),
        ("foreign", b"PNG\x89not a checkpoint at all".to_vec()),
        ("truncated-header", good[..20].to_vec()),
        ("truncated-payload", good[..good.len() - 20].to_vec()),
        ("flipped-payload-byte", {
            let mut v = good.clone();
            let n = v.len();
            v[n - 14] ^= 0x01;
            v
        }),
    ];
    for (tag, bytes) in cases {
        let p = dir.join(format!("{tag}.ckpt"));
        std::fs::write(&p, &bytes).unwrap();
        let err = Checkpoint::load(&p)
            .err()
            .unwrap_or_else(|| panic!("{tag}: malformed checkpoint loaded successfully"));
        assert!(
            err.downcast_ref::<CheckpointError>().is_some(),
            "{tag}: error is not a typed CheckpointError: {err:#}"
        );
    }
    std::fs::remove_dir_all(dir).ok();
}

/// CLI surface of the same property: `--resume` against a corrupt
/// checkpoint with no history fails nonzero and names the file and the
/// reason; with a valid history sibling it falls back and succeeds.
#[test]
fn cli_resume_reports_corruption_and_uses_history_fallback() {
    let dir = tmp_dir("cli-corrupt");

    // corrupt primary, no history -> hard failure naming file + cause
    let lone = dir.join("lone.ckpt");
    std::fs::write(&lone, b"definitely not a checkpoint").unwrap();
    let (st, _, err) = run_train(2, &lone, 0, true, &[]);
    assert!(!st.success(), "resume from corrupt-with-no-history must fail");
    assert!(err.contains("lone.ckpt"), "diagnostic must name the file:\n{err}");
    assert!(
        err.contains("not a SLTCKPT1 checkpoint"),
        "diagnostic must say why it failed:\n{err}"
    );

    // corrupt primary + valid .1 -> warn, fall back, succeed
    let chain = dir.join("chain.ckpt");
    let (st, _, err) = run_train(4, &chain, 2, false, &[]);
    assert!(st.success(), "setup run failed:\n{err}");
    assert!(chain.exists() && dir.join("chain.ckpt.1").exists(), "no rotation history");
    let full = std::fs::read(&chain).unwrap();
    std::fs::write(&chain, &full[..40]).unwrap(); // torn primary
    let (st, _, err) = run_train(6, &chain, 0, true, &[]);
    assert!(st.success(), "resume with valid history must succeed:\n{err}");
    assert!(
        err.contains("failed validation") && err.contains("falling back"),
        "resume must warn about the skipped candidate:\n{err}"
    );
    assert_eq!(ckpt_step(&chain), Some(6), "resumed run did not reach the final step");
    std::fs::remove_dir_all(dir).ok();
}

/// A `Backend` wrapper that delegates everything to `NativeBackend` but
/// can replace the reported train-step loss with NaN — the in-process
/// stand-in for a numerically diverging run.
struct NanInjector {
    inner: NativeBackend,
    /// Return NaN on these 1-based train_step calls...
    from_call: u64,
    /// ...for this many calls (u64::MAX = forever).
    count: u64,
    calls: u64,
}

impl NanInjector {
    fn new(from_call: u64, count: u64) -> NanInjector {
        let p: ModelPreset = preset("tiny").unwrap();
        let inner = NativeBackend::build(
            p, "sltrain", 2, 3e-3, 100, 1, 32, 0, SupportPattern::UniformRandom,
        )
        .unwrap();
        NanInjector { inner, from_call, count, calls: 0 }
    }
}

impl Backend for NanInjector {
    fn kind(&self) -> &'static str {
        "native"
    }
    fn method(&self) -> &str {
        self.inner.method()
    }
    fn preset(&self) -> &ModelPreset {
        self.inner.preset()
    }
    fn batch_size(&self) -> usize {
        self.inner.batch_size()
    }
    fn n_params(&self) -> usize {
        self.inner.n_params()
    }
    fn init_state(&mut self, seed: u32) -> anyhow::Result<()> {
        self.inner.init_state(seed)
    }
    fn train_step(&mut self, step: i32, tokens: &[i32]) -> anyhow::Result<f32> {
        self.calls += 1;
        let until = self.from_call.saturating_add(self.count);
        if self.calls >= self.from_call && self.calls < until {
            return Ok(f32::NAN);
        }
        self.inner.train_step(step, tokens)
    }
    fn eval_loss(&mut self, tokens: &[i32]) -> anyhow::Result<f32> {
        self.inner.eval_loss(tokens)
    }
    fn forward(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
        self.inner.forward(tokens)
    }
    fn state_tensors(&self) -> anyhow::Result<Vec<StateTensor>> {
        self.inner.state_tensors()
    }
    fn load_state_tensors(&mut self, tensors: &[StateTensor]) -> anyhow::Result<()> {
        self.inner.load_state_tensors(tensors)
    }
}

fn guard_cfg(dir: &Path, steps: usize) -> TrainConfig {
    TrainConfig {
        steps,
        eval_every: 0,
        eval_batches: 2,
        log_every: 0,
        checkpoint_path: Some(dir.join("guard.ckpt")),
        checkpoint_every: 2,
        ..Default::default()
    }
}

/// One NaN step: the guard trips once, rolls back to the last
/// checkpoint, and the run still completes successfully.
#[test]
fn guard_single_nan_recovers_via_rollback() {
    let dir = tmp_dir("guard-recover");
    let mut be = NanInjector::new(5, 1);
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    let cfg = guard_cfg(&dir, 8);
    let r = train(&mut be, &mut pipe, &cfg).expect("guarded run should recover");
    assert_eq!(r.guard_trips, 1, "expected exactly one guard trip");
    assert!(r.interrupted_at.is_none());
    assert!(r.final_eval_loss.is_finite());
    std::fs::remove_dir_all(dir).ok();
}

/// Persistent NaN: consecutive trips exhaust `max_guard_trips` and the
/// run fails with a diagnostic instead of looping forever.
#[test]
fn guard_persistent_nan_exhausts_trips_and_errors() {
    let dir = tmp_dir("guard-exhaust");
    let mut be = NanInjector::new(5, u64::MAX);
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    let cfg = guard_cfg(&dir, 8);
    let err = train(&mut be, &mut pipe, &cfg).expect_err("persistent NaN must abort");
    let msg = format!("{err:#}");
    assert!(msg.contains("consecutive"), "diagnostic should mention consecutive trips: {msg}");
    std::fs::remove_dir_all(dir).ok();
}

/// Divergence before any checkpoint exists (or with no checkpoint path
/// at all) cannot roll back — it must fail with a clear error.
#[test]
fn guard_without_checkpoint_to_roll_back_to_errors() {
    // no checkpoint path configured
    let mut be = NanInjector::new(1, 1);
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    let cfg = TrainConfig { steps: 4, eval_every: 0, log_every: 0, ..Default::default() };
    let err = train(&mut be, &mut pipe, &cfg).expect_err("no rollback target must error");
    assert!(format!("{err:#}").contains("no checkpoint"), "got: {err:#}");

    // checkpoint path configured but nothing saved yet (trip at call 1,
    // first save would be after step 1)
    let dir = tmp_dir("guard-nothing-saved");
    let mut be = NanInjector::new(1, 1);
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    let cfg = guard_cfg(&dir, 4);
    let err = train(&mut be, &mut pipe, &cfg).expect_err("nothing saved yet must error");
    assert!(format!("{err:#}").contains("nothing to roll back"), "got: {err:#}");
    std::fs::remove_dir_all(dir).ok();
}

/// The loss-spike guard (finite losses): a spike above `ema × factor`
/// trips the guard even though the loss is a normal number.
#[test]
fn guard_finite_spike_trips_with_loss_guard_factor() {
    struct SpikeOnce {
        inner: NanInjector,
    }
    impl Backend for SpikeOnce {
        fn kind(&self) -> &'static str {
            "native"
        }
        fn method(&self) -> &str {
            self.inner.method()
        }
        fn preset(&self) -> &ModelPreset {
            self.inner.preset()
        }
        fn batch_size(&self) -> usize {
            self.inner.batch_size()
        }
        fn n_params(&self) -> usize {
            self.inner.n_params()
        }
        fn init_state(&mut self, seed: u32) -> anyhow::Result<()> {
            self.inner.init_state(seed)
        }
        fn train_step(&mut self, step: i32, tokens: &[i32]) -> anyhow::Result<f32> {
            self.inner.calls += 1;
            if self.inner.calls == 5 {
                return Ok(1.0e6); // huge but finite
            }
            self.inner.inner.train_step(step, tokens)
        }
        fn eval_loss(&mut self, tokens: &[i32]) -> anyhow::Result<f32> {
            self.inner.eval_loss(tokens)
        }
        fn forward(&mut self, tokens: &[i32]) -> anyhow::Result<Vec<f32>> {
            self.inner.forward(tokens)
        }
        fn state_tensors(&self) -> anyhow::Result<Vec<StateTensor>> {
            self.inner.state_tensors()
        }
        fn load_state_tensors(&mut self, tensors: &[StateTensor]) -> anyhow::Result<()> {
            self.inner.load_state_tensors(tensors)
        }
    }

    let dir = tmp_dir("guard-spike");
    let mut be = SpikeOnce { inner: NanInjector::new(u64::MAX, 0) };
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    let mut cfg = guard_cfg(&dir, 8);
    cfg.loss_guard = 10.0;
    let r = train(&mut be, &mut pipe, &cfg).expect("spike-guarded run should recover");
    assert_eq!(r.guard_trips, 1, "the finite spike should trip the guard exactly once");

    // without the factor armed, the same spike sails through
    let mut be = SpikeOnce { inner: NanInjector::new(u64::MAX, 0) };
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    let cfg2 = guard_cfg(&dir, 8);
    let r = train(&mut be, &mut pipe, &cfg2).expect("unguarded spike is not an error");
    assert_eq!(r.guard_trips, 0);
    std::fs::remove_dir_all(dir).ok();
}

/// Checkpoint saves stay atomic under concurrent readers: a loader
/// polling the primary mid-training only ever sees valid checkpoints
/// (rename-swapped), never a torn half-write.
#[test]
fn concurrent_reader_never_sees_a_torn_checkpoint() {
    let dir = tmp_dir("atomic-reader");
    let ckpt = dir.join("hot.ckpt");
    let args = train_args(10, &ckpt, 1, false);
    let refs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
    let mut child = ChildGuard(spawn_sltrain(&refs, &[]));
    let mut seen = 0usize;
    deadline_poll("train to finish while we hammer-read the checkpoint", DEADLINE, || {
        if ckpt.exists() {
            // any readable primary must validate (CRCs and all); a torn
            // file here means the save path is not atomic
            match Checkpoint::load(&ckpt) {
                Ok(_) => seen += 1,
                Err(e) => {
                    let transient = e
                        .downcast_ref::<std::io::Error>()
                        .map(|io| io.kind() == std::io::ErrorKind::NotFound)
                        .unwrap_or(false);
                    assert!(transient, "torn checkpoint observed mid-save: {e:#}");
                }
            }
        }
        child.0.try_wait().unwrap()
    });
    assert!(seen > 0, "never managed to read the checkpoint during the run");
    assert!(child.wait_exit().success());
    std::fs::remove_dir_all(dir).ok();
}

/// Data-stream resume on the mmap-shard path: kill a shard-backed
/// `finetune` mid-epoch via the `train.after_step` failpoint, resume,
/// and require the final fine-tune checkpoint byte-identical to an
/// uninterrupted run. Any drift in the replayed token-stream position
/// (shard order, epoch counter, intra-shard offset) would change every
/// subsequent weight, so bit-identity here proves the stream replays
/// to the exact token.
#[test]
fn finetune_shard_stream_failpoint_resume_is_bit_identical() {
    let dir = tmp_dir("ft-shards");
    let shards = dir.join("corpus");
    // 3 shards x 600 tokens => 1200-token train split per epoch, so 10
    // steps x 2 rows x 64 seq cross shard AND epoch boundaries — the
    // post-crash replay must fast-forward through both exactly
    let (st, _, err) = run_sltrain(
        &[
            "data", "--make-shards", shards.to_str().unwrap(),
            "--shards", "3", "--shard-tokens", "600", "--vocab", "256", "--seed", "11",
        ],
        &[],
    );
    assert!(st.success(), "make-shards failed:\n{err}");
    let pre = dir.join("pre.ckpt");
    let (st, _, err) = run_train(4, &pre, 0, false, &[]);
    assert!(st.success(), "pretrain failed:\n{err}");

    let ft_args = |out: &Path, resume: bool| -> Vec<String> {
        let mut v: Vec<String> = [
            "finetune", "--backend", "native", "--config", "tiny", "--method", "sltrain",
            "--batch", "2", "--eval-every", "0", "--log-every", "0", "--steps", "10",
            "--checkpoint-every", "2",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        for (flag, val) in [
            ("--checkpoint", pre.to_str().unwrap()),
            ("--data", shards.to_str().unwrap()),
            ("--out-checkpoint", out.to_str().unwrap()),
        ] {
            v.push(flag.into());
            v.push(val.into());
        }
        if resume {
            v.push("--resume".into());
        }
        v
    };
    let run_ft = |out: &Path, resume: bool, envs: &[(&str, &str)]| {
        let args = ft_args(out, resume);
        let refs: Vec<&str> = args.iter().map(|s| s.as_str()).collect();
        run_sltrain(&refs, envs)
    };

    // uninterrupted reference
    let ref_ckpt = dir.join("ref.ckpt");
    let (st, _, err) = run_ft(&ref_ckpt, false, &[]);
    assert!(st.success(), "reference finetune failed:\n{err}");
    let want = std::fs::read(&ref_ckpt).unwrap();

    // crash after the 6th train step (past the 1200-token epoch edge),
    // then resume to completion
    let crash = dir.join("crash.ckpt");
    let (st, _, _) =
        run_ft(&crash, false, &[("SLTRAIN_FAILPOINT", "train.after_step=abort@6")]);
    assert!(!st.success(), "armed abort did not kill the finetune");
    let (st, _, err) = run_ft(&crash, true, &[]);
    assert!(st.success(), "finetune resume failed:\n{err}");
    assert_eq!(
        std::fs::read(&crash).unwrap(),
        want,
        "resumed shard-stream finetune is not bit-identical to the uninterrupted run"
    );
    std::fs::remove_dir_all(dir).ok();
}

/// Guard against harness rot: spawning with an armed-but-never-firing
/// failpoint must not perturb a run (this is the mode CI uses for its
/// armed full-suite pass).
#[test]
fn armed_but_dormant_failpoint_changes_nothing() {
    let dir = tmp_dir("dormant");
    let a = dir.join("plain.ckpt");
    let b = dir.join("armed.ckpt");
    let (st, _, err) = run_train(4, &a, 0, false, &[]);
    assert!(st.success(), "{err}");
    let (st, _, err) = run_train(
        4,
        &b,
        0,
        false,
        &[("SLTRAIN_FAILPOINT", "train.after_step=error@1000000000")],
    );
    assert!(st.success(), "{err}");
    assert_eq!(
        std::fs::read(&a).unwrap(),
        std::fs::read(&b).unwrap(),
        "a dormant failpoint altered the trajectory"
    );
    std::fs::remove_dir_all(dir).ok();
}

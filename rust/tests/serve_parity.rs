//! Parity contracts behind the serving path:
//!
//! * `fold_weights` materializes exactly the Table-5 dense weights —
//!   bitwise-equal to the reference formulas computed from the pre-fold
//!   state tensors, for every method × support pattern, and
//!   bit-identical at every thread count;
//! * the folded forward matches the live factored forward up to f32
//!   re-association (tolerance), and is itself bitwise-deterministic;
//! * KV-cache incremental decode (`forward_incremental`) produces
//!   logits bitwise-equal to a full-sequence recompute, at 1/2/4
//!   threads, pre- and post-fold;
//! * restoring a checkpoint after `drop_optimizer_state` yields the
//!   exact same forward/eval as restoring it into a fresh backend
//!   (regression: the dropped path used to refuse full checkpoints).

use std::collections::BTreeMap;

use sltrain::backend::native::NativeBackend;
use sltrain::backend::{Backend, StateTensor};
use sltrain::config::preset;
use sltrain::linalg::{Matrix, SparseSupport, SupportPattern};

const SEED: u32 = 11;

fn build(method: &str, threads: usize, support: SupportPattern) -> NativeBackend {
    let p = preset("tiny").expect("tiny preset");
    let mut be = NativeBackend::build(p, method, 2, 3e-3, 100, threads, 32, 0, support)
        .expect("build native backend");
    be.init_state(SEED).expect("init");
    be
}

/// Deterministic token stream covering the vocab (no RNG: the exact
/// values are irrelevant, only that every run sees the same ones).
fn tokens(n: usize, vocab: usize) -> Vec<i32> {
    (0..n).map(|i| ((i * 37 + 11) % vocab) as i32).collect()
}

/// Two optimizer steps so B (zero-init for sltrain/relora) and the
/// sparse values are all non-trivial before folding.
fn warm_up(be: &mut NativeBackend) {
    let p = be.preset().clone();
    let toks = tokens(be.batch_size() * p.seq_len, p.vocab);
    be.train_step(0, &toks).expect("train step 0");
    be.train_step(1, &toks).expect("train step 1");
}

fn f32_map(ts: &[StateTensor]) -> BTreeMap<String, (Vec<usize>, Vec<f32>)> {
    ts.iter()
        .filter(|t| t.to_f32().is_ok())
        .map(|t| (t.name.clone(), (t.shape.clone(), t.to_f32().unwrap())))
        .collect()
}

fn assert_bits_eq(a: &[f32], b: &[f32], what: &str) {
    assert_eq!(a.len(), b.len(), "{what}: length mismatch");
    for (i, (x, y)) in a.iter().zip(b).enumerate() {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: bit mismatch at {i}: {x} vs {y}");
    }
}

/// The effective dense weight a method's factors encode, computed from
/// the interchange tensors with the public serial kernels (`matmul`,
/// `fused_effective`) — the fold (which runs on the pool) must agree
/// bit-for-bit, per the engine's thread-count determinism contract.
fn reference_fold(
    method: &str,
    pre: &BTreeMap<String, (Vec<usize>, Vec<f32>)>,
    idx: &BTreeMap<String, Vec<u32>>,
    path: &str,
    d_in: usize,
    d_out: usize,
    scale: f32,
) -> Vec<f32> {
    let mat = |name: String| {
        let (shape, data) = pre.get(&name).unwrap_or_else(|| panic!("missing {name}"));
        Matrix::from_vec(shape[0], shape[1], data.clone())
    };
    match method {
        "full" | "galore" => pre[&format!("{path}.w")].1.clone(),
        "lowrank" => {
            let mut w = mat(format!("{path}.B")).matmul(&mat(format!("{path}.A")));
            w.scale_mut(scale);
            w.data
        }
        "sltrain" => {
            let sup = SparseSupport::new(d_in, d_out, idx[&format!("{path}.idx")].clone());
            let vals = &pre[&format!("{path}.vals")].1;
            sup.fused_effective(&mat(format!("{path}.B")), &mat(format!("{path}.A")), vals, scale)
                .data
        }
        "relora" => {
            let ba = mat(format!("{path}.B")).matmul(&mat(format!("{path}.A")));
            let mut w = pre[&format!("{path}.w0")].1.clone();
            for (wi, x) in w.iter_mut().zip(&ba.data) {
                *wi += scale * x;
            }
            w
        }
        _ => unreachable!(),
    }
}

#[test]
fn folded_weights_match_reference_formulas_bitwise() {
    let cases = [
        ("full", SupportPattern::UniformRandom),
        ("galore", SupportPattern::UniformRandom),
        ("lowrank", SupportPattern::UniformRandom),
        ("relora", SupportPattern::UniformRandom),
        ("sltrain", SupportPattern::UniformRandom),
        ("sltrain", SupportPattern::StructuredNM { n: 2, m: 4 }),
    ];
    for (method, support) in cases {
        let tag = format!("{method}/{}", support.label());
        let mut be = build(method, 2, support);
        warm_up(&mut be);
        let p = be.preset().clone();
        let scale = (p.alpha / p.rank as f64) as f32;

        let pre_ts = be.state_tensors().unwrap();
        let pre = f32_map(&pre_ts);
        let idx: BTreeMap<String, Vec<u32>> = pre_ts
            .iter()
            .filter(|t| t.name.ends_with(".idx"))
            .map(|t| {
                let ids = t.to_i32().unwrap().iter().map(|&i| i as u32).collect();
                (t.name.clone(), ids)
            })
            .collect();

        be.fold_weights().unwrap();
        assert!(be.is_folded(), "{tag}: not marked folded");
        let post = f32_map(&be.state_tensors().unwrap());

        for (path, d_in, d_out) in p.linear_paths() {
            let want = reference_fold(method, &pre, &idx, &path, d_in, d_out, scale);
            let (shape, got) =
                post.get(&format!("{path}.w")).unwrap_or_else(|| panic!("{tag}: no {path}.w"));
            assert_eq!(shape, &vec![d_in, d_out], "{tag}: {path}.w shape");
            assert_bits_eq(got, &want, &format!("{tag}: {path}.w"));
            for gone in [".B", ".A", ".vals", ".w0"] {
                assert!(
                    !post.contains_key(&format!("{path}{gone}")),
                    "{tag}: {path}{gone} survived the fold"
                );
            }
        }
        // folded state carries no supports and no optimizer moments
        assert!(post.keys().all(|k| !k.starts_with("optim.")), "{tag}: moments survived");
        assert!(
            be.state_tensors().unwrap().iter().all(|t| !t.name.ends_with(".idx")),
            "{tag}: support indices survived"
        );
        // and the engine refuses to train from here on
        let toks = tokens(be.batch_size() * p.seq_len, p.vocab);
        let err = be.train_step(2, &toks).unwrap_err().to_string();
        assert!(err.contains("fold"), "{tag}: wrong refusal: {err}");
    }
}

#[test]
fn fold_and_folded_forward_are_bitwise_identical_across_thread_counts() {
    let p = preset("tiny").unwrap();
    let toks = tokens(p.seq_len, p.vocab);
    let mut reference: Option<(BTreeMap<String, (Vec<usize>, Vec<f32>)>, Vec<f32>)> = None;
    for threads in [1usize, 2, 4] {
        let mut be = build("sltrain", threads, SupportPattern::UniformRandom);
        warm_up(&mut be);
        be.fold_weights().unwrap();
        let state = f32_map(&be.state_tensors().unwrap());
        let logits = be.forward(&toks).unwrap();
        match &reference {
            None => reference = Some((state, logits)),
            Some((s1, l1)) => {
                assert_eq!(s1.len(), state.len(), "{threads} threads: tensor count");
                for (name, (_, data)) in &state {
                    assert_bits_eq(data, &s1[name].1, &format!("{threads} threads: {name}"));
                }
                assert_bits_eq(&logits, l1, &format!("{threads} threads: folded logits"));
            }
        }
    }
}

#[test]
fn folded_forward_matches_live_forward_within_tolerance() {
    let cases = [
        ("sltrain", SupportPattern::UniformRandom),
        ("sltrain", SupportPattern::StructuredNM { n: 2, m: 4 }),
        ("lowrank", SupportPattern::UniformRandom),
        ("relora", SupportPattern::UniformRandom),
    ];
    for (method, support) in cases {
        let tag = format!("{method}/{}", support.label());
        let mut live = build(method, 2, support);
        warm_up(&mut live);
        let mut folded = build(method, 2, support);
        warm_up(&mut folded);
        folded.fold_weights().unwrap();

        let p = live.preset().clone();
        let toks = tokens(p.seq_len, p.vocab);
        let a = live.forward(&toks).unwrap();
        let b = folded.forward(&toks).unwrap();
        assert_eq!(a.len(), b.len());
        // the fold only re-associates f32 sums (x·(BA) vs (x·B)·A);
        // logits agree to well under any decode-relevant margin
        for (i, (x, y)) in a.iter().zip(&b).enumerate() {
            assert!(
                (x - y).abs() <= 1e-3 + 1e-3 * y.abs(),
                "{tag}: logit {i} diverged: live {x} vs folded {y}"
            );
        }
    }
}

/// Row i of the incremental stream must be byte-identical to row i of
/// one full-sequence forward — prefill of P tokens, then strictly
/// one-token decode steps — at every thread count, before and after
/// the fold. This is the contract that makes KV-cache serving safe to
/// substitute for recompute.
#[test]
fn kv_cache_decode_is_bitwise_equal_to_full_recompute() {
    for fold in [false, true] {
        let mut per_thread: Option<Vec<f32>> = None;
        for threads in [1usize, 2, 4] {
            let mut be = build("sltrain", threads, SupportPattern::UniformRandom);
            warm_up(&mut be);
            if fold {
                be.fold_weights().unwrap();
            }
            let p = be.preset().clone();
            let toks = tokens(p.seq_len, p.vocab);
            let tag = format!("fold={fold} threads={threads}");

            let full = be.forward(&toks).unwrap();
            assert_eq!(full.len(), p.seq_len * p.vocab);

            let mut cache = be.new_kv_cache();
            let prefill_len = p.seq_len / 3;
            let mut inc = Vec::with_capacity(full.len());
            let m = be.forward_incremental(&toks[..prefill_len], &mut cache).unwrap();
            assert_eq!((m.rows, m.cols), (prefill_len, p.vocab), "{tag}: prefill shape");
            inc.extend_from_slice(&m.data);
            for i in prefill_len..p.seq_len {
                let m = be.forward_incremental(&toks[i..i + 1], &mut cache).unwrap();
                assert_eq!((m.rows, m.cols), (1, p.vocab), "{tag}: decode shape");
                inc.extend_from_slice(&m.data);
            }
            assert_eq!(cache.len(), p.seq_len, "{tag}: cache length");
            assert!(cache.bytes() > 0, "{tag}: cache claims zero bytes");

            assert_bits_eq(&inc, &full, &format!("{tag}: incremental vs full logits"));
            match &per_thread {
                None => per_thread = Some(inc),
                Some(l1) => assert_bits_eq(&inc, l1, &format!("{tag}: vs 1 thread")),
            }
        }
    }
}

/// Regression (the dropped-state restore bug): a checkpoint written
/// with full optimizer state must restore onto a backend whose state
/// was dropped — weights/supports only — and the restored model must
/// forward/eval bit-identically to the same checkpoint restored onto a
/// fresh backend. Covers relora (frozen W0, no W0 moments), sltrain on
/// structured 2:4 supports, and galore (projector tensors).
#[test]
fn restore_after_drop_matches_fresh_restore_bitwise() {
    let cases = [
        ("relora", SupportPattern::UniformRandom),
        ("sltrain", SupportPattern::StructuredNM { n: 2, m: 4 }),
        ("galore", SupportPattern::UniformRandom),
    ];
    for (method, support) in cases {
        let tag = format!("{method}/{}", support.label());
        let mut trained = build(method, 2, support);
        warm_up(&mut trained);
        let snap = trained.state_tensors().unwrap();
        assert!(
            snap.iter().any(|t| t.name.starts_with("optim.")),
            "{tag}: snapshot carries no moments — the regression needs a full checkpoint"
        );

        let p = trained.preset().clone();
        let toks = tokens(p.seq_len, p.vocab);

        // fresh backend, full restore: the reference
        let mut fresh = build(method, 2, support);
        fresh.load_state_tensors(&snap).unwrap();
        let want_logits = fresh.forward(&toks).unwrap();
        let want_loss = fresh.eval_loss(&toks).unwrap();

        // dropped backend, same checkpoint: weights-only restore must
        // succeed (it used to bail) and match the reference exactly
        let mut dropped = build(method, 2, support);
        dropped.drop_optimizer_state().unwrap();
        dropped.load_state_tensors(&snap).unwrap();
        let got_logits = dropped.forward(&toks).unwrap();
        let got_loss = dropped.eval_loss(&toks).unwrap();

        assert_bits_eq(&got_logits, &want_logits, &format!("{tag}: restored logits"));
        assert_eq!(got_loss.to_bits(), want_loss.to_bits(), "{tag}: restored eval loss");
    }
}

//! Deterministic PRNG stack (no `rand` in the vendor set).
//!
//! SplitMix64 for seeding / fast streams, plus gaussian (Box–Muller),
//! uniform-without-replacement sampling (for sparse supports), Zipf and
//! categorical sampling (for the synthetic corpus). Every consumer in the
//! repo derives an independent stream via `Rng::fork(tag)` so experiment
//! seeds are reproducible regardless of call order.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // avoid the all-zeros fixed point and decorrelate tiny seeds
        Rng { state: seed.wrapping_add(0x9e3779b97f4a7c15) }
    }

    /// Derive an independent stream: hash (state-origin, tag).
    pub fn fork(&self, tag: u64) -> Rng {
        let mut r = Rng::new(self.state ^ tag.wrapping_mul(0xbf58476d1ce4e5b9));
        r.next_u64();
        r
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        // SplitMix64 (Steele et al.)
        self.state = self.state.wrapping_add(0x9e3779b97f4a7c15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xbf58476d1ce4e5b9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94d049bb133111eb);
        z ^ (z >> 31)
    }

    #[inline]
    pub fn next_u32(&mut self) -> u32 {
        (self.next_u64() >> 32) as u32
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire).
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform in [lo, hi).
    pub fn range_f32(&mut self, lo: f32, hi: f32) -> f32 {
        lo + self.f32() * (hi - lo)
    }

    /// Standard normal via Box–Muller.
    pub fn gaussian(&mut self) -> f64 {
        let u1 = self.f64().max(1e-300);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// k distinct values from [0, n), sorted (Floyd's algorithm + sort).
    pub fn sample_without_replacement(&mut self, n: u64, k: usize) -> Vec<u64> {
        assert!((k as u64) <= n, "sample {k} from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k as u64)..n {
            let t = self.below(j + 1);
            let v = if chosen.contains(&t) { j } else { t };
            chosen.insert(v);
            out.push(v);
        }
        out.sort_unstable();
        out
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample an index from unnormalized weights (linear scan).
    pub fn categorical(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut t = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            t -= w;
            if t <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

/// Precomputed Zipf(s) sampler over [0, n) — the unigram backbone of the
/// synthetic corpus (natural-language token frequencies are Zipfian).
pub struct Zipf {
    cdf: Vec<f64>,
}

impl Zipf {
    pub fn new(n: usize, s: f64) -> Self {
        let mut cdf = Vec::with_capacity(n);
        let mut acc = 0.0;
        for k in 1..=n {
            acc += 1.0 / (k as f64).powf(s);
            cdf.push(acc);
        }
        let total = acc;
        for v in &mut cdf {
            *v /= total;
        }
        Zipf { cdf }
    }

    pub fn sample(&self, rng: &mut Rng) -> usize {
        let u = rng.f64();
        match self.cdf.binary_search_by(|p| p.partial_cmp(&u).unwrap()) {
            Ok(i) => i,
            Err(i) => i.min(self.cdf.len() - 1),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_by_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let va: Vec<u64> = (0..10).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..10).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..10).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn fork_streams_are_independent() {
        let root = Rng::new(1);
        let mut f1 = root.fork(1);
        let mut f2 = root.fork(2);
        assert_ne!(f1.next_u64(), f2.next_u64());
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut rng = Rng::new(3);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = rng.below(7) as usize;
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = Rng::new(4);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.08, "var {var}");
    }

    #[test]
    fn sample_without_replacement_distinct_sorted() {
        let mut rng = Rng::new(5);
        let v = rng.sample_without_replacement(1000, 200);
        assert_eq!(v.len(), 200);
        assert!(v.windows(2).all(|w| w[0] < w[1]));
        assert!(v.iter().all(|&x| x < 1000));
    }

    #[test]
    fn sample_full_range() {
        let mut rng = Rng::new(6);
        let v = rng.sample_without_replacement(16, 16);
        assert_eq!(v, (0..16).collect::<Vec<u64>>());
    }

    #[test]
    fn zipf_is_skewed_and_valid() {
        let mut rng = Rng::new(7);
        let z = Zipf::new(100, 1.1);
        let mut counts = [0usize; 100];
        for _ in 0..20_000 {
            counts[z.sample(&mut rng)] += 1;
        }
        assert!(counts[0] > counts[10]);
        assert!(counts[0] > counts[50]);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut rng = Rng::new(8);
        let mut v: Vec<u32> = (0..50).collect();
        rng.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<u32>>());
    }
}

//! Leveled stderr logger + JSONL metric emitter.
//!
//! The trainer writes one JSON object per step/eval event to a metrics
//! file; benches and EXPERIMENTS.md are generated from those streams.

use std::fs::File;
use std::io::{BufWriter, Write};
use std::path::Path;
use std::sync::atomic::{AtomicU8, Ordering};
use std::time::{SystemTime, UNIX_EPOCH};

use super::json::Json;

pub const ERROR: u8 = 0;
pub const WARN: u8 = 1;
pub const INFO: u8 = 2;
pub const DEBUG: u8 = 3;

static LEVEL: AtomicU8 = AtomicU8::new(INFO);

pub fn set_level(level: u8) {
    LEVEL.store(level, Ordering::Relaxed);
}

pub fn enabled(level: u8) -> bool {
    level <= LEVEL.load(Ordering::Relaxed)
}

pub fn log(level: u8, msg: &str) {
    if enabled(level) {
        let tag = match level {
            ERROR => "ERROR",
            WARN => "WARN ",
            INFO => "INFO ",
            _ => "DEBUG",
        };
        eprintln!("[{tag}] {msg}");
    }
}

#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::INFO, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! warn_ {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::WARN, &format!($($arg)*)) };
}

#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::logging::log($crate::util::logging::DEBUG, &format!($($arg)*)) };
}

/// Append-only JSONL sink for structured metrics.
///
/// Crash semantics: every `emit` flushes through to the OS, and `Drop`
/// flushes again, so a dying process loses at most the line it was
/// mid-writing — the tail of the metrics stream is exactly what a
/// post-mortem needs, and it is the part plain buffering would drop.
pub struct MetricsWriter {
    out: BufWriter<File>,
}

impl MetricsWriter {
    pub fn create<P: AsRef<Path>>(path: P) -> anyhow::Result<Self> {
        if let Some(dir) = path.as_ref().parent() {
            std::fs::create_dir_all(dir)?;
        }
        Ok(MetricsWriter { out: BufWriter::new(File::create(path)?) })
    }

    pub fn emit(&mut self, mut record: Json) -> anyhow::Result<()> {
        if let Json::Obj(m) = &mut record {
            let ts = SystemTime::now().duration_since(UNIX_EPOCH)?.as_secs_f64();
            m.insert("ts".into(), Json::Num(ts));
        }
        writeln!(self.out, "{}", record.to_string())?;
        // per-record flush: a crashed run's metrics file ends at the
        // last completed event, not wherever the 8 KiB buffer stood
        self.out.flush()?;
        Ok(())
    }

    /// Flush any buffered bytes to the OS (also runs on `Drop`; emit
    /// already flushes per record — this exists for explicit callers).
    pub fn flush(&mut self) -> anyhow::Result<()> {
        self.out.flush()?;
        Ok(())
    }
}

impl Drop for MetricsWriter {
    fn drop(&mut self) {
        // BufWriter's own drop also flushes, but swallows errors
        // invisibly; doing it here first keeps the contract explicit
        // (errors at drop time still have nowhere to go, but the
        // buffer is empty on every non-crash path because emit flushes)
        let _ = self.out.flush();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::json::{num, obj};

    #[test]
    fn metrics_writer_emits_parseable_lines() {
        let dir = std::env::temp_dir().join(format!("sltrain-log-{}", std::process::id()));
        let path = dir.join("m.jsonl");
        let mut w = MetricsWriter::create(&path).unwrap();
        w.emit(obj(vec![("step", num(1.0)), ("loss", num(3.5))])).unwrap();
        w.emit(obj(vec![("step", num(2.0)), ("loss", num(3.1))])).unwrap();
        drop(w);
        let text = std::fs::read_to_string(&path).unwrap();
        let lines: Vec<_> = text.lines().collect();
        assert_eq!(lines.len(), 2);
        for line in lines {
            let v = Json::parse(line).unwrap();
            assert!(v.get("ts").is_some());
            assert!(v.get("loss").is_some());
        }
        std::fs::remove_dir_all(dir).ok();
    }
}

//! Shared substrates: JSON, RNG, CLI parsing, logging/metrics,
//! checksums, fault injection, and the shutdown-signal flag.

pub mod cli;
pub mod crc;
pub mod failpoint;
pub mod json;
pub mod logging;
pub mod rng;
pub mod signal;

//! Shared substrates: JSON, RNG, CLI parsing, logging/metrics.

pub mod cli;
pub mod json;
pub mod logging;
pub mod rng;

//! Minimal JSON parser/writer (the vendored crate set has no serde).
//!
//! Covers everything manifest.json and the config files need: objects,
//! arrays, strings with escapes, numbers, bools, null. Errors carry byte
//! offsets for debuggability.

use std::collections::BTreeMap;
use std::fmt;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors -------------------------------------------------
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn req(&self, key: &str) -> anyhow::Result<&Json> {
        self.get(key)
            .ok_or_else(|| anyhow::anyhow!("missing json key {key:?}"))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Serialize compactly (stable key order — Obj is a BTreeMap).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 9e15 {
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\r' => out.push_str("\\r"),
                        '\t' => out.push_str("\\t"),
                        c if (c as u32) < 0x20 => {
                            out.push_str(&format!("\\u{:04x}", c as u32))
                        }
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write(out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for building metric/manifest objects.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(n: f64) -> Json {
    Json::Num(n)
}

pub fn s(v: &str) -> Json {
    Json::Str(v.to_string())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("unexpected character")),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| self.err("bad number"))
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'n') => out.push('\n'),
                        Some(b't') => out.push('\t'),
                        Some(b'r') => out.push('\r'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err(self.err("bad \\u escape"));
                            }
                            let hex =
                                std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                    .map_err(|_| self.err("bad \\u escape"))?;
                            let cp = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // copy a full utf-8 scalar
                    let s = &self.b[self.i..];
                    let ch_len = utf8_len(s[0]);
                    let chunk = std::str::from_utf8(&s[..ch_len.min(s.len())])
                        .map_err(|_| self.err("bad utf-8"))?;
                    out.push_str(chunk);
                    self.i += ch_len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = vec![];
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected , or ]")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected , or }")),
            }
        }
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_object() {
        let src = r#"{"a": [1, 2.5, -3], "b": {"c": "x\ny", "d": true}, "e": null}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("b").unwrap().get("c").unwrap().as_str(), Some("x\ny"));
    }

    #[test]
    fn numbers() {
        let v = Json::parse("[0, -1, 3.25, 1e3, 2.5e-2]").unwrap();
        let a = v.as_arr().unwrap();
        assert_eq!(a[3].as_f64(), Some(1000.0));
        assert_eq!(a[4].as_f64(), Some(0.025));
    }

    #[test]
    fn unicode_escape() {
        let v = Json::parse(r#""éA""#).unwrap();
        assert_eq!(v.as_str(), Some("éA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("hello").is_err());
        assert!(Json::parse("{}extra").is_err());
    }

    #[test]
    fn writer_escapes() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }
}

//! CRC-32 (IEEE 802.3, poly 0xEDB88320) — the checkpoint integrity
//! checksum. Table-driven, computed at compile time, no dependencies.
//!
//! This is the same CRC every zip/gzip/png implementation uses, so
//! checkpoint footers can be cross-checked with external tools
//! (`python3 -c 'import zlib; ...'`).

const fn make_table() -> [u32; 256] {
    let mut table = [0u32; 256];
    let mut i = 0;
    while i < 256 {
        let mut c = i as u32;
        let mut k = 0;
        while k < 8 {
            c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            k += 1;
        }
        table[i] = c;
        i += 1;
    }
    table
}

static TABLE: [u32; 256] = make_table();

/// Incremental CRC-32 state: `update` over any number of chunks, then
/// `finalize`. Chunking does not change the result.
#[derive(Debug, Clone)]
pub struct Crc32 {
    state: u32,
}

impl Crc32 {
    pub fn new() -> Crc32 {
        Crc32 { state: 0xFFFF_FFFF }
    }

    pub fn update(&mut self, data: &[u8]) {
        let mut s = self.state;
        for &b in data {
            s = TABLE[((s ^ b as u32) & 0xFF) as usize] ^ (s >> 8);
        }
        self.state = s;
    }

    pub fn finalize(&self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

impl Default for Crc32 {
    fn default() -> Self {
        Crc32::new()
    }
}

/// One-shot CRC-32 of a byte slice.
pub fn crc32(data: &[u8]) -> u32 {
    let mut c = Crc32::new();
    c.update(data);
    c.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // the canonical check value of CRC-32/ISO-HDLC
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
        assert_eq!(crc32(b"The quick brown fox jumps over the lazy dog"), 0x414F_A339);
    }

    #[test]
    fn chunking_is_equivalent() {
        let data: Vec<u8> = (0..=255u8).cycle().take(4096).collect();
        let whole = crc32(&data);
        let mut c = Crc32::new();
        for chunk in data.chunks(7) {
            c.update(chunk);
        }
        assert_eq!(c.finalize(), whole);
    }

    #[test]
    fn detects_single_bit_flip() {
        let mut data = vec![0u8; 1024];
        data[500] = 0x55;
        let good = crc32(&data);
        data[500] ^= 0x01;
        assert_ne!(crc32(&data), good);
    }
}

//! Env-armed fault-injection points (the crash-test harness hooks).
//!
//! A fail point is a named site in a durability-critical code path
//! (checkpoint save/load windows, the train-step loop). Unarmed — the
//! normal case — a site costs one relaxed atomic load. Armed via the
//! `SLTRAIN_FAILPOINT` environment variable, a site can inject a panic,
//! a hard process abort (the in-process stand-in for SIGKILL), a clean
//! exit, or an error return, optionally only on its Nth hit:
//!
//! ```text
//! SLTRAIN_FAILPOINT=checkpoint.save.before_rename=abort
//! SLTRAIN_FAILPOINT=checkpoint.save.after_header=abort@2   # 2nd hit only
//! SLTRAIN_FAILPOINT=train.after_step=error@5,checkpoint.save.before_write=panic
//! ```
//!
//! Actions: `panic` | `abort` | `exit:<code>` | `error` | `off`.
//! A malformed spec panics at first use — a typo'd fault injection that
//! silently never fires would make a crash test vacuously green (the
//! same loud-typo policy as `SLTRAIN_SIMD`).
//!
//! The black-box crash tests (`tests/crash_resume.rs`) arm these in
//! child processes to die deterministically inside each checkpoint
//! durability window; CI additionally runs the whole suite with a
//! never-firing point armed so the registry wiring itself stays live.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::OnceLock;

use anyhow::{anyhow, Result};

/// What an armed fail point does when it fires.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Action {
    /// `panic!` at the site (unwinds; caught by test harnesses).
    Panic,
    /// `std::process::abort()` — no destructors, no flushes: the
    /// closest in-process approximation of SIGKILL.
    Abort,
    /// `std::process::exit(code)` — skips destructors but flushes
    /// nothing beyond what already reached the OS.
    Exit(i32),
    /// Return an `anyhow` error from the site (exercises error paths).
    Error,
    /// Registered but inert (arm the registry without firing anything).
    Off,
}

struct Point {
    action: Action,
    /// Fire only on this 1-based hit number (None = every hit).
    at: Option<u64>,
    hits: AtomicU64,
}

fn registry() -> &'static HashMap<String, Point> {
    static REG: OnceLock<HashMap<String, Point>> = OnceLock::new();
    REG.get_or_init(|| parse_spec(&std::env::var("SLTRAIN_FAILPOINT").unwrap_or_default()))
}

/// True when `SLTRAIN_FAILPOINT` registered at least one point. The
/// unarmed fast path of [`hit`] reduces to this one cached load.
pub fn armed() -> bool {
    static ANY: OnceLock<bool> = OnceLock::new();
    *ANY.get_or_init(|| !registry().is_empty())
}

/// Execute the fail point `name`. No-op (and near zero cost) unless the
/// process was started with a matching `SLTRAIN_FAILPOINT` entry.
#[inline]
pub fn hit(name: &str) -> Result<()> {
    if !armed() {
        return Ok(());
    }
    fire(name)
}

#[cold]
fn fire(name: &str) -> Result<()> {
    let Some(p) = registry().get(name) else {
        return Ok(());
    };
    let n = p.hits.fetch_add(1, Ordering::SeqCst) + 1;
    if let Some(at) = p.at {
        if n != at {
            return Ok(());
        }
    }
    match p.action {
        Action::Off => Ok(()),
        Action::Panic => panic!("failpoint {name} tripped (hit {n})"),
        Action::Abort => {
            eprintln!("[FAILPOINT] {name}: abort (hit {n})");
            std::process::abort();
        }
        Action::Exit(code) => {
            eprintln!("[FAILPOINT] {name}: exit {code} (hit {n})");
            std::process::exit(code);
        }
        Action::Error => Err(anyhow!("failpoint {name} injected error (hit {n})")),
    }
}

fn parse_spec(spec: &str) -> HashMap<String, Point> {
    let mut map = HashMap::new();
    for entry in spec.split(',').map(str::trim).filter(|e| !e.is_empty()) {
        let Some((name, rhs)) = entry.split_once('=') else {
            panic!("SLTRAIN_FAILPOINT entry {entry:?}: expected <name>=<action>[@N]");
        };
        let (action_str, at) = match rhs.split_once('@') {
            Some((a, n)) => {
                let n: u64 = n.parse().unwrap_or_else(|_| {
                    panic!("SLTRAIN_FAILPOINT {entry:?}: @N must be a positive integer")
                });
                assert!(n >= 1, "SLTRAIN_FAILPOINT {entry:?}: hit numbers are 1-based");
                (a, Some(n))
            }
            None => (rhs, None),
        };
        let action = match action_str {
            "panic" => Action::Panic,
            "abort" => Action::Abort,
            "error" => Action::Error,
            "off" => Action::Off,
            other => match other.strip_prefix("exit:") {
                Some(code) => Action::Exit(code.parse().unwrap_or_else(|_| {
                    panic!("SLTRAIN_FAILPOINT {entry:?}: exit code must be an integer")
                })),
                None => panic!(
                    "SLTRAIN_FAILPOINT {entry:?}: unknown action {action_str:?} \
                     (panic | abort | exit:<code> | error | off)"
                ),
            },
        };
        map.insert(name.trim().to_string(), Point { action, at, hits: AtomicU64::new(0) });
    }
    map
}

#[cfg(test)]
mod tests {
    use super::*;

    // registry() reads the env once per process, so these tests work on
    // parse_spec directly; end-to-end arming is covered black-box in
    // tests/crash_resume.rs through child-process environments.

    #[test]
    fn parses_actions_and_hit_counts() {
        let m = parse_spec("a=panic,b=abort@3, c=exit:7 ,d=error,e=off");
        assert_eq!(m.len(), 5);
        assert_eq!(m["a"].action, Action::Panic);
        assert_eq!(m["a"].at, None);
        assert_eq!(m["b"].action, Action::Abort);
        assert_eq!(m["b"].at, Some(3));
        assert_eq!(m["c"].action, Action::Exit(7));
        assert_eq!(m["d"].action, Action::Error);
        assert_eq!(m["e"].action, Action::Off);
    }

    #[test]
    fn empty_spec_is_empty() {
        assert!(parse_spec("").is_empty());
        assert!(parse_spec("  ").is_empty());
    }

    #[test]
    #[should_panic(expected = "unknown action")]
    fn typo_panics_loudly() {
        parse_spec("a=pnaic");
    }

    #[test]
    #[should_panic(expected = "expected <name>=<action>")]
    fn missing_action_panics() {
        parse_spec("just_a_name");
    }

    #[test]
    fn unarmed_hit_is_ok() {
        // the suite normally runs without SLTRAIN_FAILPOINT (or with a
        // never-firing point in the CI armed pass): hit() must be Ok
        assert!(hit("no.such.point").is_ok());
    }
}

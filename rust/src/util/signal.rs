//! Graceful-shutdown signal flag: SIGINT/SIGTERM → one atomic bool.
//!
//! Std-only (no `libc` crate): the handler registration goes through a
//! hand-declared FFI binding to `signal(2)`, which links against the
//! libc the binary already carries. The handler body is a single atomic
//! store — the only thing that is async-signal-safe — and the long-lived
//! loops (the trainer's step loop, the serve daemon's scheduler loop)
//! poll [`requested`] at their natural step boundaries:
//!
//! * `sltrain train` finishes the current optimizer step, saves a final
//!   checkpoint, logs "resumable at step N", and exits 0;
//! * `sltrain serve` stops admitting, drains every in-flight sequence
//!   (exactly like a `shutdown` request), unlinks the socket, exits 0.
//!
//! A second SIGINT/SIGTERM while the first is being honored is absorbed
//! by the same flag; SIGKILL remains the untrappable hard stop the
//! crash-safe checkpoint layer (`coordinator::checkpoint`) exists for.

use std::sync::atomic::{AtomicBool, Ordering};

static REQUESTED: AtomicBool = AtomicBool::new(false);

#[cfg(unix)]
mod imp {
    use super::REQUESTED;
    use std::sync::atomic::Ordering;

    const SIGINT: i32 = 2;
    const SIGTERM: i32 = 15;

    extern "C" fn on_signal(_sig: i32) {
        // atomic store is async-signal-safe; everything else waits for
        // the main loop to notice the flag
        REQUESTED.store(true, Ordering::SeqCst);
    }

    extern "C" {
        // signal(2): BSD semantics under glibc/musl — the handler stays
        // installed and interrupted syscalls restart, which is exactly
        // what the poll-the-flag design wants.
        fn signal(signum: i32, handler: extern "C" fn(i32)) -> usize;
    }

    pub fn install() {
        unsafe {
            signal(SIGINT, on_signal);
            signal(SIGTERM, on_signal);
        }
    }
}

#[cfg(not(unix))]
mod imp {
    pub fn install() {}
}

/// Install the SIGINT/SIGTERM handlers (idempotent; no-op off unix).
/// Call once near process start, before the long-running loop.
pub fn install() {
    imp::install();
}

/// True once a shutdown signal arrived (or [`trigger`] was called).
pub fn requested() -> bool {
    REQUESTED.load(Ordering::SeqCst)
}

/// Raise the flag in-process — what the signal handler does, callable
/// from tests and from non-signal shutdown paths.
pub fn trigger() {
    REQUESTED.store(true, Ordering::SeqCst);
}

/// Clear the flag (test isolation; production code never un-requests).
pub fn reset() {
    REQUESTED.store(false, Ordering::SeqCst);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn trigger_and_reset_drive_the_flag() {
        // note: other tests in this binary must not depend on the flag
        // staying low concurrently — only this module touches it in-process
        reset();
        assert!(!requested());
        trigger();
        assert!(requested());
        reset();
        assert!(!requested());
    }

    #[test]
    fn install_is_idempotent() {
        // double registration must not crash or alter the flag's meaning
        install();
        install();
    }
}

//! Declarative CLI flag parsing (no `clap` in the vendor set).
//!
//! Supports `--flag value`, `--flag=value`, boolean `--flag`, positional
//! args, defaults, and an auto-generated `--help`. Used by `main.rs`,
//! every example, and every bench binary.

use std::collections::BTreeMap;

#[derive(Debug, Clone)]
struct Spec {
    name: String,
    help: String,
    default: Option<String>,
    is_bool: bool,
    required: bool,
}

#[derive(Debug, Default)]
pub struct Args {
    values: BTreeMap<String, String>,
    bools: BTreeMap<String, bool>,
    positional: Vec<String>,
}

impl Args {
    pub fn get(&self, name: &str) -> Option<&str> {
        self.values.get(name).map(|s| s.as_str())
    }

    pub fn str(&self, name: &str) -> String {
        self.values
            .get(name)
            .unwrap_or_else(|| panic!("flag --{name} missing (declare a default?)"))
            .clone()
    }

    pub fn usize(&self, name: &str) -> usize {
        self.parse_num(name)
    }

    pub fn u64(&self, name: &str) -> u64 {
        self.parse_num(name)
    }

    pub fn i64(&self, name: &str) -> i64 {
        self.parse_num(name)
    }

    pub fn f64(&self, name: &str) -> f64 {
        self.parse_num(name)
    }

    fn parse_num<T: std::str::FromStr>(&self, name: &str) -> T
    where
        T::Err: std::fmt::Display,
    {
        let raw = self.str(name);
        raw.parse().unwrap_or_else(|e| {
            eprintln!("bad value for --{name}: {raw:?} ({e})");
            std::process::exit(2);
        })
    }

    pub fn flag(&self, name: &str) -> bool {
        self.bools.get(name).copied().unwrap_or(false)
    }

    pub fn positional(&self) -> &[String] {
        &self.positional
    }
}

pub struct Cli {
    program: String,
    about: String,
    specs: Vec<Spec>,
}

impl Cli {
    pub fn new(program: &str, about: &str) -> Self {
        Cli { program: program.into(), about: about.into(), specs: vec![] }
    }

    /// Flag taking a value, with default.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: Some(default.into()),
            is_bool: false,
            required: false,
        });
        self
    }

    /// Flag taking a value, required.
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_bool: false,
            required: true,
        });
        self
    }

    /// Boolean switch.
    pub fn switch(mut self, name: &str, help: &str) -> Self {
        self.specs.push(Spec {
            name: name.into(),
            help: help.into(),
            default: None,
            is_bool: true,
            required: false,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nflags:\n", self.program, self.about);
        for s in &self.specs {
            let kind = if s.is_bool {
                String::new()
            } else if let Some(d) = &s.default {
                format!(" <value> (default: {d})")
            } else {
                " <value> (required)".to_string()
            };
            out.push_str(&format!("  --{}{}\n      {}\n", s.name, kind, s.help));
        }
        out
    }

    pub fn parse(self, argv: &[String]) -> Args {
        match self.try_parse(argv) {
            Ok(a) => a,
            Err(msg) => {
                eprintln!("{msg}");
                std::process::exit(2);
            }
        }
    }

    pub fn parse_env(self) -> Args {
        let argv: Vec<String> = std::env::args().skip(1).collect();
        self.parse(&argv)
    }

    pub fn try_parse(self, argv: &[String]) -> Result<Args, String> {
        let mut args = Args::default();
        for s in &self.specs {
            if let Some(d) = &s.default {
                args.values.insert(s.name.clone(), d.clone());
            }
        }
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            // cargo-bench harness flags: accept and ignore
            if a == "--bench" || a == "--test" {
                i += 1;
                continue;
            }
            if let Some(stripped) = a.strip_prefix("--") {
                let (name, inline) = match stripped.split_once('=') {
                    Some((n, v)) => (n.to_string(), Some(v.to_string())),
                    None => (stripped.to_string(), None),
                };
                let spec = self
                    .specs
                    .iter()
                    .find(|s| s.name == name)
                    .ok_or_else(|| format!("unknown flag --{name}\n\n{}", self.usage()))?;
                if spec.is_bool {
                    args.bools.insert(name, true);
                } else {
                    let v = match inline {
                        Some(v) => v,
                        None => {
                            i += 1;
                            argv.get(i)
                                .ok_or_else(|| format!("--{name} needs a value"))?
                                .clone()
                        }
                    };
                    args.values.insert(name, v);
                }
            } else {
                args.positional.push(a.clone());
            }
            i += 1;
        }
        for s in &self.specs {
            if s.required && !args.values.contains_key(&s.name) {
                return Err(format!("missing required --{}\n\n{}", s.name, self.usage()));
            }
        }
        Ok(args)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &str) -> Vec<String> {
        s.split_whitespace().map(|x| x.to_string()).collect()
    }

    #[test]
    fn defaults_and_overrides() {
        let a = Cli::new("t", "")
            .opt("steps", "100", "")
            .opt("config", "tiny", "")
            .switch("verbose", "")
            .try_parse(&argv("--steps 250 --verbose"))
            .unwrap();
        assert_eq!(a.usize("steps"), 250);
        assert_eq!(a.str("config"), "tiny");
        assert!(a.flag("verbose"));
    }

    #[test]
    fn equals_syntax_and_positional() {
        let a = Cli::new("t", "")
            .opt("lr", "0.1", "")
            .try_parse(&argv("--lr=0.003 ckpt.bin"))
            .unwrap();
        assert!((a.f64("lr") - 0.003).abs() < 1e-12);
        assert_eq!(a.positional(), &["ckpt.bin".to_string()]);
    }

    #[test]
    fn required_missing_errors() {
        let r = Cli::new("t", "").req("out", "").try_parse(&argv(""));
        assert!(r.is_err());
    }

    #[test]
    fn unknown_flag_errors() {
        let r = Cli::new("t", "").try_parse(&argv("--nope 1"));
        assert!(r.is_err());
    }
}

//! `sltrain` — the L3 launcher.
//!
//! Subcommands:
//!   train         pretrain (native pure-rust engine, or an AOT artifact)
//!   finetune      continue training from a pretrain checkpoint (live
//!                 parameterization or folded dense), fresh optimizer
//!   eval          quality suite: held-out perplexity + synthetic tasks,
//!                 per method or per checkpoint (BENCH_quality.json)
//!   estimate-mem  Appendix-F memory tables for any preset × method
//!   analyze       Fig-2/10/11 spectrum + residual analysis of a checkpoint
//!   data          inspect / dump the synthetic corpus + tokenizer, or
//!                 build mmap token shards (--make-shards)
//!   throughput    Table-3 style tokens/sec measurement
//!   inference     Table-5 style forward-only memory + throughput
//!   serve         fold-for-inference daemon (KV cache, continuous batching)
//!   prop1         Monte-Carlo check of Proposition 1
//!
//! The compute-bearing subcommands take `--backend {native,xla}`.
//! `native` (the default) needs no artifacts and no XLA: all five
//! methods (full/lowrank/sltrain/relora/galore) run on the in-crate
//! linalg kernels. `xla` executes an AOT artifact bundle through PJRT
//! and requires both `--artifact` and a build with the `xla` cargo
//! feature.
//!
//! Examples:
//!   sltrain train --backend native --config tiny --steps 200
//!   sltrain train --backend xla --artifact artifacts/tiny_sltrain
//!   sltrain estimate-mem --config paper60m
//!   sltrain analyze --checkpoint runs/tiny/ckpt.bin --layer layers.0.attn.o

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Result};

use sltrain::analysis::{full_rank_probability, ResidualReport, SpectrumDecomp};
use sltrain::backend::native::NativeBackend;
use sltrain::backend::{self, Backend, BackendSpec};
use sltrain::bench::{fmt, Table};
use sltrain::config::{preset, METHODS};
use sltrain::coordinator::{train, trainer, Checkpoint, TrainConfig};
use sltrain::data::{build_shards, CorpusConfig, Pipeline, SynthCorpus};
use sltrain::eval::evaluate;
use sltrain::linalg::Matrix;
use sltrain::mem::{estimate, MemEstimate, MemOptions};
use sltrain::serve::ServeConfig;
use sltrain::util::cli::{Args, Cli};
use sltrain::util::json::{num, obj, s, Json};
use sltrain::util::signal;

fn main() {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    let cmd = argv.first().map(|s| s.as_str()).unwrap_or("help");
    let rest = if argv.is_empty() { vec![] } else { argv[1..].to_vec() };
    let result = match cmd {
        "train" => cmd_train(&rest),
        "finetune" => cmd_finetune(&rest),
        "eval" => cmd_eval(&rest),
        "estimate-mem" => cmd_estimate_mem(&rest),
        "analyze" => cmd_analyze(&rest),
        "data" => cmd_data(&rest),
        "throughput" => cmd_throughput(&rest),
        "inference" => cmd_inference(&rest),
        "serve" => cmd_serve(&rest),
        "prop1" => cmd_prop1(&rest),
        // hidden: data-parallel replica child, spawned by ShardedBackend
        // under SLTRAIN_WORKER_TRANSPORT=process — not a user command
        "shard-worker" => cmd_shard_worker(&rest),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => {
            eprintln!("unknown subcommand {other:?}\n\n{HELP}");
            std::process::exit(2);
        }
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

const HELP: &str = "\
sltrain — sparse plus low-rank pretraining (NeurIPS 2024), reproduced

subcommands:
  train         pretrain (--backend native needs no artifacts)
  finetune      continue from a pretrain checkpoint on a downstream
                corpus (optionally folded dense first), fresh optimizer
  eval          quality suite: held-out ppl + synthetic tasks per
                method/checkpoint, emits BENCH_quality.json
  estimate-mem  Appendix-F memory tables (any preset x method)
  analyze       spectrum/residual analysis of a checkpoint
  data          synthetic corpus + tokenizer inspection; --make-shards
                builds checksummed mmap token shards
  throughput    training tokens/sec (Table 3)
  inference     forward-only memory + tokens/sec (Table 5)
  serve         persistent inference daemon on a unix socket (fold +
                KV-cache decoding + continuous batching)
  prop1         Monte-Carlo verification of Proposition 1
  help          this message

run `sltrain <subcommand> --help` for flags
";

/// The shared `--backend` flag set of the compute-bearing subcommands.
fn backend_flags(c: Cli) -> Cli {
    c.opt("backend", "auto", "engine: native | xla | auto (xla iff --artifact given)")
        .opt("artifact", "", "artifact directory (xla backend)")
        .opt("config", "tiny", "model preset (native backend)")
        .opt("method", "sltrain", "weight parameterization (native backend)")
        .opt("batch", "8", "train batch rows (native backend)")
        .opt("lr", "0.003", "base learning rate (native backend)")
        .opt("total-steps", "2000", "lr-schedule horizon (native backend)")
        .opt(
            "threads",
            "0",
            "step-loop worker threads, native backend (0 = auto; losses are \
             bit-identical at every thread count)",
        )
        .opt(
            "optim-bits",
            "0",
            "Adam moment precision, native backend: 32 | 8 (block-wise \
             quantized); 0 = auto (SLTRAIN_OPTIM_BITS env, else 32)",
        )
        .opt(
            "galore-every",
            "0",
            "GaLore projector refresh period in steps, native backend \
             (0 = default 200; only --method galore uses it)",
        )
        .opt(
            "support",
            "random",
            "sltrain sparse-support pattern, native backend: random \
             (paper, density = preset delta) | n:m (SLoPe-style \
             structured, e.g. 2:4, density n/m)",
        )
        .opt(
            "workers",
            "0",
            "data-parallel worker replicas, native backend (0 = single \
             engine; losses are bit-identical at every worker count; \
             SLTRAIN_WORKERS env when 0)",
        )
}

fn backend_spec(a: &Args) -> Result<BackendSpec> {
    let artifact = a.str("artifact");
    let chosen = match a.str("backend").as_str() {
        "auto" => {
            if artifact.is_empty() {
                "native".to_string()
            } else {
                "xla".to_string()
            }
        }
        other => other.to_string(),
    };
    BackendSpec::from_flags(
        &chosen,
        &artifact,
        &a.str("config"),
        &a.str("method"),
        a.usize("batch"),
        a.f64("lr"),
        a.usize("total-steps"),
        a.usize("threads"),
        a.usize("optim-bits"),
        a.usize("galore-every"),
        &a.str("support"),
        a.usize("workers"),
    )
}

fn cmd_train(argv: &[String]) -> Result<()> {
    let a = backend_flags(Cli::new(
        "sltrain train",
        "pretrain with the native engine or an AOT artifact bundle",
    ))
    .opt("steps", "200", "optimizer steps")
    .opt("eval-every", "50", "evaluation period (0 = only final)")
    .opt("eval-batches", "4", "validation batches per evaluation")
    .opt("log-every", "10", "train-loss log period")
    .opt("relora-every", "100", "ReLoRA restart period (--method relora, either backend)")
    .opt("seed", "42", "init + data seed")
    .opt("data-seed", "7", "synthetic corpus seed")
    .opt(
        "data",
        "",
        "token-shard directory from `sltrain data --make-shards` (empty = \
         on-the-fly synthetic stream); --data-seed seeds the shard shuffle",
    )
    .opt("metrics", "", "JSONL metrics output path")
    .opt("checkpoint", "", "checkpoint output path")
    .opt("checkpoint-every", "0", "checkpoint period (0 = end only)")
    .opt(
        "keep-checkpoints",
        "2",
        "checkpoints kept on disk: newest at --checkpoint, older as .1, .2, ...",
    )
    .opt(
        "loss-guard",
        "0",
        "divergence guard factor: roll back to the last checkpoint when loss \
         exceeds ema x this (0 = spike check off; NaN/Inf always guarded)",
    )
    .opt(
        "max-guard-trips",
        "3",
        "abort (nonzero exit) after this many consecutive guard trips",
    )
    .switch(
        "resume",
        "resume from --checkpoint if it exists: restore weights, optimizer \
         moments, the step counter and the lr schedule, and fast-forward \
         the data stream (the resumed trajectory matches an uninterrupted \
         run bit for bit)",
    )
    .parse(argv);

    // SIGINT/SIGTERM: finish the current step, save a resumable
    // checkpoint, exit 0 (the loop polls the flag at step boundaries)
    signal::install();
    let mut be = backend::open(backend_spec(&a)?)?;
    sltrain::info!(
        "backend {} | {} / {} ({:.2}M params, optimizer {})",
        be.kind(),
        be.preset().name,
        be.method(),
        be.n_params() as f64 / 1e6,
        be.optimizer()
    );
    let mut pipe = build_pipeline(&a.str("data"), be.preset().vocab, a.u64("data-seed"))?;
    let cfg = TrainConfig {
        steps: a.usize("steps"),
        eval_every: a.usize("eval-every"),
        eval_batches: a.usize("eval-batches"),
        log_every: a.usize("log-every"),
        relora_every: a.usize("relora-every"),
        seed: a.u64("seed") as u32,
        metrics_path: non_empty(a.str("metrics")).map(PathBuf::from),
        checkpoint_path: non_empty(a.str("checkpoint")).map(PathBuf::from),
        checkpoint_every: a.usize("checkpoint-every"),
        keep_checkpoints: a.usize("keep-checkpoints"),
        loss_guard: a.f64("loss-guard"),
        max_guard_trips: a.usize("max-guard-trips"),
        resume: a.flag("resume"),
        init_tensors: None,
    };
    let r = train(be.as_mut(), &mut pipe, &cfg)?;
    println!(
        "final: eval loss {:.4} ppl {:.2} | {:.0} tok/s | {:.1}s | peak rss {:.0} MB",
        r.final_eval_loss,
        r.final_ppl,
        r.tokens_per_sec,
        r.wall_secs,
        r.peak_rss_bytes as f64 / 1e6
    );
    if r.guard_trips > 0 {
        println!("divergence guard: {} trip(s), run recovered via rollback", r.guard_trips);
    }
    if let Some(step) = r.interrupted_at {
        println!("interrupted by signal — resumable at step {step} (rerun with --resume)");
    }
    if let Some(m) = be.mem_report() {
        let sharded = if m.workers > 1 {
            format!(
                " | optimizer sharded over {} workers (~1/{} moments each)",
                m.workers, m.workers
            )
        } else {
            String::new()
        };
        println!(
            "mem: params {:.1} MB | optim {:.1} MB ({}-bit moments) | grad peak {:.1} MB \
             (two-phase loop would hold {:.1} MB){sharded}",
            m.param_bytes as f64 / 1e6,
            m.optim_bytes as f64 / 1e6,
            m.optim_bits,
            m.grad_peak_bytes as f64 / 1e6,
            m.grad_all_bytes as f64 / 1e6
        );
    }
    Ok(())
}

/// Data source shared by train/finetune/eval: a shard directory when
/// `--data` is set, else the on-the-fly synthetic stream.
fn build_pipeline(data: &str, vocab_cap: usize, data_seed: u64) -> Result<Pipeline> {
    match non_empty(data.to_string()) {
        Some(dir) => Pipeline::from_shard_dir(Path::new(&dir), vocab_cap, data_seed),
        None => Ok(Pipeline::build(vocab_cap, data_seed)),
    }
}

fn cmd_finetune(argv: &[String]) -> Result<()> {
    let a = backend_flags(Cli::new(
        "sltrain finetune",
        "continue training from a pretrain SLTCKPT1 checkpoint on a downstream \
         corpus: fresh optimizer + lr schedule, optionally folding the sparse + \
         low-rank parameterization dense first (SLoPe-style fine-tuning)",
    ))
    .req("checkpoint", "pretrain SLTCKPT1 checkpoint to start from")
    .opt("steps", "100", "fine-tune optimizer steps")
    .opt("eval-every", "50", "evaluation period (0 = only final)")
    .opt("eval-batches", "4", "validation batches per evaluation")
    .opt("log-every", "10", "train-loss log period")
    .opt("relora-every", "100", "ReLoRA restart period (--method relora, live only)")
    .opt("seed", "42", "init seed for non-checkpoint tensors (e.g. a reset head)")
    .opt("ft-data-seed", "1234", "downstream corpus seed (disjoint from pretrain's)")
    .opt(
        "data",
        "",
        "token-shard directory from `sltrain data --make-shards` (empty = \
         synthetic downstream corpus from --ft-data-seed)",
    )
    .opt("metrics", "", "JSONL metrics output path")
    .opt("out-checkpoint", "", "fine-tune checkpoint output path")
    .opt("checkpoint-every", "0", "fine-tune checkpoint period (0 = end only)")
    .opt("keep-checkpoints", "2", "fine-tune checkpoints kept on disk")
    .opt("json", "", "write a machine-readable summary (full-precision losses) here")
    .switch(
        "fold",
        "fold the pretrained parameterization dense first (Table 5's scale.B.A \
         (+S / +W0) fold), then fine-tune the dense model (--method full applies \
         downstream)",
    )
    .switch(
        "reset-head",
        "drop the pretrained lm head and re-init it from --seed (the fresh-\
         objective variant)",
    )
    .switch("resume", "resume an interrupted fine-tune from --out-checkpoint")
    .parse(argv);

    signal::install();
    let ck_path = a.str("checkpoint");
    let ck = Checkpoint::load(Path::new(&ck_path))?;
    let reset_head = a.flag("reset-head");
    // fresh optimizer on the downstream objective: drop the pretrain
    // moments + galore projectors; optionally drop the head for re-init
    let base: Vec<_> = ck
        .to_state_tensors()
        .into_iter()
        .filter(|t| !t.name.starts_with("optim."))
        .filter(|t| !(reset_head && t.name == "head.w"))
        .collect();
    let seed = a.u64("seed") as u32;
    let fold = a.flag("fold");
    let (mut be, init_tensors) = if fold {
        let BackendSpec::Native {
            preset,
            method,
            batch,
            lr,
            total_steps,
            threads,
            optim_bits,
            galore_every,
            support,
            workers,
        } = backend_spec(&a)?
        else {
            bail!("finetune runs on the native engine only (drop --backend xla / --artifact)");
        };
        // converter engine: restore the pretrain parameterization, fold
        // it dense in place, snapshot the dense `.w` tensors, then
        // fine-tune them as a plain full-method model
        let mut conv = NativeBackend::build(
            preset.clone(),
            &method,
            batch,
            lr,
            total_steps,
            threads,
            optim_bits,
            galore_every,
            support,
        )?;
        conv.init_state(seed)?;
        conv.load_state_tensors(&base)?;
        conv.fold_weights()?;
        let folded = conv.state_tensors()?;
        drop(conv);
        let spec = BackendSpec::Native {
            preset,
            method: "full".into(),
            batch,
            lr,
            total_steps,
            threads,
            optim_bits,
            galore_every,
            support,
            workers,
        };
        (backend::open(spec)?, folded)
    } else {
        (backend::open(backend_spec(&a)?)?, base)
    };
    sltrain::info!(
        "finetune: {ck_path} (pretrain step {}) -> {} / {}{}",
        ck.step,
        be.preset().name,
        be.method(),
        if fold { " (folded dense)" } else { "" }
    );

    let batch = be.batch_size();
    let seq = be.seq_len();
    let eval_batches = a.usize("eval-batches");
    // zero-shot baseline on the downstream corpus, from a SEPARATE
    // pipeline so the training pipeline's valid stream is untouched
    // (same seed => the trainer sees the identical valid set)
    let zero_shot = {
        let mut zpipe =
            build_pipeline(&a.str("data"), be.preset().vocab, a.u64("ft-data-seed"))?;
        let vs = zpipe.valid_set(eval_batches, batch, seq);
        be.init_state(seed)?;
        be.load_state_tensors(&init_tensors)?;
        trainer::eval(be.as_mut(), &vs)?
    };
    println!(
        "zero-shot on downstream corpus: eval loss {:.4} ppl {:.2}",
        zero_shot,
        zero_shot.exp()
    );

    let mut pipe = build_pipeline(&a.str("data"), be.preset().vocab, a.u64("ft-data-seed"))?;
    let cfg = TrainConfig {
        steps: a.usize("steps"),
        eval_every: a.usize("eval-every"),
        eval_batches,
        log_every: a.usize("log-every"),
        relora_every: a.usize("relora-every"),
        seed,
        metrics_path: non_empty(a.str("metrics")).map(PathBuf::from),
        checkpoint_path: non_empty(a.str("out-checkpoint")).map(PathBuf::from),
        checkpoint_every: a.usize("checkpoint-every"),
        keep_checkpoints: a.usize("keep-checkpoints"),
        loss_guard: 0.0,
        max_guard_trips: 3,
        resume: a.flag("resume"),
        init_tensors: Some(init_tensors),
    };
    let r = train(be.as_mut(), &mut pipe, &cfg)?;
    println!(
        "finetune final: eval loss {:.4} ppl {:.2} (zero-shot ppl {:.2}) | {:.0} tok/s",
        r.final_eval_loss,
        r.final_ppl,
        zero_shot.exp(),
        r.tokens_per_sec
    );
    if let Some(step) = r.interrupted_at {
        println!("interrupted by signal — resumable at step {step} (rerun with --resume)");
    }
    if let Some(path) = non_empty(a.str("json")) {
        // full-precision f64 repr (Json::Num round-trips shortest form)
        let report = obj(vec![
            ("bench", s("finetune")),
            ("config", s(&be.preset().name)),
            ("method", s(be.method())),
            ("fold", Json::Bool(fold)),
            ("pretrain_step", num(ck.step as f64)),
            ("steps", num(a.usize("steps") as f64)),
            ("zero_shot_loss", num(zero_shot)),
            ("zero_shot_ppl", num(zero_shot.exp())),
            ("final_eval_loss", num(r.final_eval_loss)),
            ("final_ppl", num(r.final_ppl)),
        ]);
        std::fs::write(&path, report.to_string())?;
        println!("[json saved to {path}]");
    }
    Ok(())
}

fn cmd_eval(argv: &[String]) -> Result<()> {
    let a = backend_flags(Cli::new(
        "sltrain eval",
        "quality suite: held-out perplexity + deterministic synthetic tasks \
         (top-1 next-token accuracy, induction-copy CE gap). Grid mode \
         pretrains each --methods entry for --steps and evaluates it; \
         --checkpoint evaluates one saved run instead",
    ))
    .opt("checkpoint", "", "evaluate this SLTCKPT1 (empty = grid mode over --methods)")
    .opt("methods", "", "comma list for grid mode (default: all five)")
    .opt("steps", "50", "pretrain steps per method in grid mode")
    .opt("seed", "42", "init seed")
    .opt("data-seed", "7", "corpus seed")
    .opt(
        "data",
        "",
        "token-shard directory for the held-out eval stream (empty = synthetic)",
    )
    .opt("eval-batches", "4", "held-out batches for loss/accuracy")
    .opt("induction-batches", "2", "forward batches of the induction-copy probe")
    .opt("json", "", "write BENCH_quality.json-style report here")
    .opt("csv", "", "write the table as CSV here")
    .parse(argv);

    let seed = a.u64("seed") as u32;
    let eval_batches = a.usize("eval-batches");
    let induction = a.usize("induction-batches");
    let mut t = Table::new(
        "Quality eval — held-out ppl + synthetic task suite",
        &["method", "eval loss", "ppl", "next-tok acc", "induction gap"],
    );
    let mut rows: Vec<Json> = Vec::new();

    let mut run_one = |be: &mut dyn Backend, method: &str| -> Result<()> {
        let mut epipe =
            build_pipeline(&a.str("data"), be.preset().vocab, a.u64("data-seed"))?;
        let vs = epipe.valid_set(eval_batches, be.batch_size(), be.seq_len());
        let q = evaluate(be, &vs, induction)?;
        t.row(vec![
            method.to_string(),
            fmt(q.eval_loss, 4),
            fmt(q.ppl, 2),
            fmt(q.next_token_acc, 4),
            fmt(q.induction_gap, 4),
        ]);
        rows.push(obj(vec![
            ("config", s(&be.preset().name)),
            ("method", s(method)),
            ("eval_loss", num(q.eval_loss)),
            ("ppl", num(q.ppl)),
            ("next_token_acc", num(q.next_token_acc)),
            ("induction_gap", num(q.induction_gap)),
        ]));
        Ok(())
    };

    if let Some(ck_path) = non_empty(a.str("checkpoint")) {
        let ck = Checkpoint::load(Path::new(&ck_path))?;
        let mut be = backend::open(backend_spec(&a)?)?;
        be.init_state(seed)?;
        be.load_state_tensors(&ck.to_state_tensors())?;
        sltrain::info!("eval: checkpoint {ck_path} (step {})", ck.step);
        let method = be.method().to_string();
        run_one(be.as_mut(), &method)?;
    } else {
        let methods: Vec<String> = match non_empty(a.str("methods")) {
            Some(m) => m.split(',').map(|x| x.trim().to_string()).collect(),
            None => METHODS.iter().map(|m| m.to_string()).collect(),
        };
        let BackendSpec::Native {
            preset,
            batch,
            lr,
            total_steps,
            threads,
            optim_bits,
            galore_every,
            support,
            workers,
            ..
        } = backend_spec(&a)?
        else {
            bail!("eval grid mode runs on the native engine only");
        };
        for m in &methods {
            let spec = BackendSpec::Native {
                preset: preset.clone(),
                method: m.clone(),
                batch,
                lr,
                total_steps,
                threads,
                optim_bits,
                galore_every,
                support,
                workers,
            };
            let mut be = backend::open(spec)?;
            let mut pipe =
                build_pipeline(&a.str("data"), be.preset().vocab, a.u64("data-seed"))?;
            let cfg = TrainConfig {
                steps: a.usize("steps"),
                eval_every: 0,
                eval_batches,
                log_every: 0,
                seed,
                ..Default::default()
            };
            train(be.as_mut(), &mut pipe, &cfg)?;
            run_one(be.as_mut(), m)?;
        }
    }
    t.print();
    if let Some(path) = non_empty(a.str("csv")) {
        t.save_csv(&path)?;
        println!("[csv saved to {path}]");
    }
    if let Some(path) = non_empty(a.str("json")) {
        let report = obj(vec![
            ("bench", s("quality_eval")),
            ("steps", num(a.usize("steps") as f64)),
            ("eval_batches", num(eval_batches as f64)),
            (
                "data",
                s(&non_empty(a.str("data")).unwrap_or_else(|| "synthetic".into())),
            ),
            ("results", Json::Arr(rows)),
        ]);
        std::fs::write(&path, report.to_string())?;
        println!("[json saved to {path}]");
    }
    Ok(())
}

fn cmd_estimate_mem(argv: &[String]) -> Result<()> {
    let a = Cli::new("sltrain estimate-mem", "Appendix-F memory estimator")
        .opt("config", "paper60m", "preset (paper60m/paper130m/paper350m/paper1b/spec7b/...)")
        .opt("method", "", "single method (default: all)")
        .switch("eight-bit", "int8 optimizer moments")
        .switch("per-layer", "per-layer weight updates")
        .parse(argv);
    let p = preset(&a.str("config"))
        .ok_or_else(|| anyhow!("unknown preset {:?}", a.str("config")))?;
    let opts = MemOptions { eight_bit: a.flag("eight-bit"), per_layer: a.flag("per-layer") };
    let methods: Vec<&str> = match a.get("method") {
        Some(m) if !m.is_empty() => vec![Box::leak(m.to_string().into_boxed_str())],
        _ => METHODS.to_vec(),
    };
    let mut t = Table::new(
        &format!("Memory estimate — {} (Appendix F model)", p.name),
        &["method", "params(M)", "param mem(G)", "optim mem(G)", "total(G)", "train w/ grads(G)"],
    );
    for m in methods {
        let e = estimate(&p, m, opts);
        t.row(vec![
            m.to_string(),
            fmt(e.total_params() / 1e6, 2),
            fmt(MemEstimate::gb(e.param_bytes), 3),
            fmt(MemEstimate::gb(e.optim_bytes), 3),
            fmt(MemEstimate::gb(e.table2_bytes()), 3),
            fmt(MemEstimate::gb(e.train_bytes()), 3),
        ]);
    }
    t.print();
    Ok(())
}

fn cmd_analyze(argv: &[String]) -> Result<()> {
    let a = Cli::new("sltrain analyze", "spectrum/residual analysis of a checkpoint")
        .req("checkpoint", "checkpoint path (from train --checkpoint)")
        .opt("layer", "", "weight name prefix (default: all adapted linears)")
        .opt("rank-cut", "0", "rank for the residual split (0 = preset rank)")
        .opt("csv", "", "write singular values CSV here")
        .parse(argv);
    let ck = Checkpoint::load(Path::new(&a.str("checkpoint")))?;
    let filter = a.str("layer");
    let mut any = false;
    let mut csv = String::from("tensor,index,sigma,lowrank,sparse\n");
    // group tensors by linear path
    let mut paths: BTreeMap<String, ()> = BTreeMap::new();
    for n in ck.names() {
        if n.starts_with("optim.") {
            // optimizer moments (resume payload), not analyzable weights
            continue;
        }
        if let Some(base) = n.strip_suffix(".B") {
            paths.insert(base.to_string(), ());
        }
        if let Some(base) = n.strip_suffix(".w") {
            if base.starts_with("layers.") {
                paths.insert(base.to_string(), ());
            }
        }
    }
    for (base, _) in paths {
        if !filter.is_empty() && !base.starts_with(&filter) {
            continue;
        }
        any = true;
        if ck.tensors.contains_key(&format!("{base}.w")) {
            // full-rank weight: Fig-2 residual analysis
            let (shape, w) = ck.tensor_f32(&format!("{base}.w"))?;
            let m = Matrix::from_vec(shape[0], shape[1], w);
            let cut = if a.usize("rank-cut") > 0 { a.usize("rank-cut") } else { shape[1] / 4 };
            let rep = ResidualReport::compute(&m, cut);
            rep.print(&base);
            for (i, s) in rep.singular_values.iter().enumerate() {
                csv.push_str(&format!("{base},{i},{s},,\n"));
            }
        } else {
            // SLTrain weight: Fig-10/11 decomposition
            let (bs, b) = ck.tensor_f32(&format!("{base}.B"))?;
            let (as_, av) = ck.tensor_f32(&format!("{base}.A"))?;
            let bm = Matrix::from_vec(bs[0], bs[1], b);
            let am = Matrix::from_vec(as_[0], as_[1], av);
            if let Ok((_, vals)) = ck.tensor_f32(&format!("{base}.vals")) {
                let (_, idx_f) = ck
                    .tensors
                    .get(&format!("{base}.idx"))
                    .map(|(s, _, bytes)| {
                        let v: Vec<u32> = bytes
                            .chunks_exact(4)
                            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
                            .collect();
                        (s.clone(), v)
                    })
                    .ok_or_else(|| anyhow!("{base}: missing idx in checkpoint"))?;
                let dec = SpectrumDecomp::compute(&bm, &am, &idx_f, &vals, 1.0);
                dec.print(&base);
                for i in 0..dec.sigma.len() {
                    csv.push_str(&format!(
                        "{base},{i},{},{},{}\n",
                        dec.sigma[i], dec.lowrank_contrib[i], dec.sparse_contrib[i]
                    ));
                }
            } else {
                let w = bm.matmul(&am);
                let rep = ResidualReport::compute(&w, bs[1]);
                rep.print(&base);
            }
        }
    }
    if !any {
        bail!("no matching weights in checkpoint (filter {filter:?})");
    }
    if let Some(path) = non_empty(a.str("csv")) {
        std::fs::write(&path, csv)?;
        println!("[csv saved to {path}]");
    }
    Ok(())
}

fn cmd_data(argv: &[String]) -> Result<()> {
    let a = Cli::new("sltrain data", "synthetic corpus / tokenizer inspection + shard building")
        .opt("seed", "7", "corpus seed")
        .opt("words", "200", "words of sample text to show")
        .opt("vocab", "256", "tokenizer vocab size")
        .opt("dump", "", "write N tokens to this file as i32-LE")
        .opt("dump-tokens", "100000", "token count for --dump")
        .opt(
            "make-shards",
            "",
            "build checksummed mmap token shards + tokenizer.bin in this \
             directory (parallel BPE on the worker pool), then exit",
        )
        .opt("shards", "4", "shard files to build (last one is the held-out valid split)")
        .opt("shard-tokens", "100000", "tokens per shard file")
        .opt("threads", "0", "tokenizer worker threads (0 = auto; output is identical)")
        .parse(argv);
    if let Some(dir) = non_empty(a.str("make-shards")) {
        let rep = build_shards(
            Path::new(&dir),
            a.usize("shards"),
            a.usize("shard-tokens"),
            a.usize("vocab"),
            a.u64("seed"),
            a.usize("threads"),
        )?;
        println!(
            "built {} shards x {} tokens (bpe vocab {}) in {:.2}s — {:.0} tokens/sec -> {dir}",
            rep.shards,
            rep.tokens / rep.shards.max(1),
            rep.bpe_vocab,
            rep.wall_secs,
            rep.tokens_per_sec
        );
        return Ok(());
    }
    let corpus = SynthCorpus::new(CorpusConfig { seed: a.u64("seed"), ..Default::default() });
    let sample = corpus.generate_text(a.usize("words"), 0);
    println!("--- corpus sample (seed {}) ---\n{}\n", a.u64("seed"), &sample);
    let mut pipe = Pipeline::build(a.usize("vocab"), a.u64("seed"));
    println!("tokenizer vocab: {}", pipe.bpe_vocab);
    let batch = pipe.train.next_batch(1, 32);
    println!("first 32 train tokens: {batch:?}");
    if let Some(path) = non_empty(a.str("dump")) {
        let n = a.usize("dump-tokens");
        let toks = pipe.train.next_batch(1, n);
        let bytes: Vec<u8> = toks.iter().flat_map(|t| t.to_le_bytes()).collect();
        std::fs::write(&path, bytes)?;
        println!("dumped {n} tokens to {path}");
    }
    Ok(())
}

fn cmd_throughput(argv: &[String]) -> Result<()> {
    let a = backend_flags(Cli::new("sltrain throughput", "Table-3 training throughput"))
        .opt("steps", "30", "measured steps (after 3 warmup)")
        .opt("seed", "42", "seed")
        .parse(argv);
    let mut be = backend::open(backend_spec(&a)?)?;
    be.init_state(a.u64("seed") as u32)?;
    let batch = be.batch_size();
    let seq = be.seq_len();
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    for w in 0..3 {
        let toks = pipe.train.next_batch(batch, seq);
        be.train_step(w, &toks)?;
    }
    let t0 = std::time::Instant::now();
    let steps = a.usize("steps");
    for s in 0..steps {
        let toks = pipe.train.next_batch(batch, seq);
        be.train_step(3 + s as i32, &toks)?;
    }
    let dt = t0.elapsed().as_secs_f64();
    let tok_s = (steps * batch * seq) as f64 / dt;
    println!(
        "{} / {} [{}]: {:.0} tokens/sec ({} steps, batch {batch}, seq {seq}, {:.2}s)",
        be.preset().name,
        be.method(),
        be.kind(),
        tok_s,
        steps,
        dt
    );
    Ok(())
}

fn cmd_inference(argv: &[String]) -> Result<()> {
    let a = backend_flags(Cli::new(
        "sltrain inference",
        "Table-5 forward-only memory + throughput",
    ))
    .opt("iters", "20", "forward passes to time")
    .opt("seed", "42", "seed")
    .parse(argv);
    let mut be = backend::open(backend_spec(&a)?)?;
    be.init_state(a.u64("seed") as u32)?;
    let batch = be.forward_batch_size();
    let seq = be.seq_len();
    let mut pipe = Pipeline::build(be.preset().vocab, 7);
    // drop optimizer state: inference holds params only (paper Table 5)
    be.drop_optimizer_state()?;
    let rss0 = sltrain::runtime::current_rss_bytes();
    let toks = pipe.valid.next_batch(batch, seq);
    be.forward(&toks)?; // compile+warm
    let t0 = std::time::Instant::now();
    for _ in 0..a.usize("iters") {
        be.forward(&toks)?;
    }
    let dt = t0.elapsed().as_secs_f64();
    let tok_s = (a.usize("iters") * batch * seq) as f64 / dt;
    let rss1 = sltrain::runtime::current_rss_bytes();
    println!(
        "{} / {} [{}]: inference {:.0} tokens/sec | params {:.1} MB | rss {:.0}->{:.0} MB",
        be.preset().name,
        be.method(),
        be.kind(),
        tok_s,
        be.n_params() as f64 * 4.0 / 1e6,
        rss0 as f64 / 1e6,
        rss1 as f64 / 1e6,
    );
    Ok(())
}

fn cmd_serve(argv: &[String]) -> Result<()> {
    let a = backend_flags(Cli::new(
        "sltrain serve",
        "persistent inference daemon: fold the checkpoint dense (Table 5), decode \
         with per-sequence KV caches, batch continuously over a unix socket",
    ))
    .req("socket", "unix socket path to bind")
    .opt("checkpoint", "", "SLTCKPT1 checkpoint to serve (empty = fresh init from --seed)")
    .opt("seed", "42", "init seed when no checkpoint is given")
    .opt("max-batch", "8", "concurrent decode slots (continuous-batching width)")
    .opt(
        "max-queue",
        "64",
        "admission cap: generates queued-or-running before new ones are shed \
         with an overloaded response",
    )
    .opt(
        "read-timeout",
        "30",
        "per-connection read timeout in seconds for mid-request stalls \
         (idle connections are unaffected)",
    )
    .switch(
        "no-fold",
        "serve the live factored/sparse weights instead of folding dense \
         (slower per token; numerics differ only by f32 re-association)",
    )
    .parse(argv);

    // SIGINT/SIGTERM: drain in-flight sequences and exit 0, exactly
    // like a `shutdown` request
    signal::install();
    let BackendSpec::Native {
        preset,
        method,
        batch,
        lr,
        total_steps,
        threads,
        optim_bits,
        galore_every,
        support,
        workers,
    } = backend_spec(&a)?
    else {
        bail!("serve runs on the native engine only (drop --backend xla / --artifact)");
    };
    // explicit flag only: the SLTRAIN_WORKERS env auto-default targets
    // the training suite and is deliberately ignored by the daemon
    if workers > 0 {
        bail!("serve is single-engine: drop --workers (inference has no gradients to all-reduce)");
    }
    let mut be = NativeBackend::build(
        preset, &method, batch, lr, total_steps, threads, optim_bits, galore_every, support,
    )?;
    be.init_state(a.u64("seed") as u32)?;
    if let Some(path) = non_empty(a.str("checkpoint")) {
        let ck = Checkpoint::load(Path::new(&path))?;
        be.load_state_tensors(&ck.to_state_tensors())?;
        sltrain::info!("serve: restored checkpoint {path} (step {})", ck.step);
    }
    // Table 5: inference holds parameters only
    be.drop_optimizer_state()?;
    if !a.flag("no-fold") {
        be.fold_weights()?;
    }
    let cfg = ServeConfig {
        socket: PathBuf::from(a.str("socket")),
        max_batch: a.usize("max-batch"),
        max_queue: a.usize("max-queue"),
        read_timeout_secs: a.u64("read-timeout"),
    };
    sltrain::serve::run(be, &cfg)
}

/// Process-transport replica child (`SLTRAIN_WORKER_TRANSPORT=process`):
/// build one `NativeBackend` over the replica's share of the batch,
/// connect to the parent's unix socket, and serve `Cmd` frames until
/// shutdown. Spawned by `ShardedBackend`; not part of the public CLI.
fn cmd_shard_worker(argv: &[String]) -> Result<()> {
    let a = Cli::new("sltrain shard-worker", "internal data-parallel replica (spawned by train)")
        .req("socket", "parent unix socket path")
        .opt("worker", "0", "replica index")
        .opt("workers", "1", "replica count")
        .opt("config", "tiny", "model preset")
        .opt("method", "sltrain", "weight parameterization")
        .opt("batch", "1", "replica batch rows (one block)")
        .opt("lr", "0.003", "base learning rate")
        .opt("total-steps", "2000", "lr-schedule horizon")
        .opt("threads", "1", "per-replica pool threads")
        .opt("optim-bits", "0", "Adam moment precision")
        .opt("galore-every", "0", "GaLore projector refresh period")
        .opt("support", "random", "sparse-support pattern")
        .parse(argv);
    let name = a.str("config");
    let p = preset(&name).ok_or_else(|| anyhow!("shard-worker: unknown preset {name:?}"))?;
    let support = sltrain::linalg::SupportPattern::parse(&a.str("support"))
        .map_err(|e| anyhow!("shard-worker: {e}"))?;
    sltrain::backend::sharded::run_worker_process(
        Path::new(&a.str("socket")),
        a.usize("worker"),
        a.usize("workers"),
        p,
        &a.str("method"),
        a.usize("batch"),
        a.f64("lr") as f32,
        a.usize("total-steps"),
        a.usize("threads"),
        a.usize("optim-bits"),
        a.usize("galore-every"),
        support,
    )
}

fn cmd_prop1(argv: &[String]) -> Result<()> {
    let a = Cli::new("sltrain prop1", "Monte-Carlo check of Proposition 1")
        .opt("n", "48", "matrix size")
        .opt("rank", "4", "low-rank dimension")
        .opt("trials", "30", "Monte-Carlo trials per delta")
        .opt("seed", "0", "seed")
        .parse(argv);
    let n = a.usize("n");
    let crit = sltrain::analysis::prop1::critical_delta(n);
    let mut t = Table::new(
        &format!("Prop 1: P[BA+S full rank], n={n} (critical delta = {crit:.4})"),
        &["delta", "delta/critical", "P[full rank]"],
    );
    for mult in [0.1, 0.5, 1.0, 2.0, 4.0] {
        let delta = crit * mult;
        let p = full_rank_probability(n, a.usize("rank"), delta, a.usize("trials"), a.u64("seed"));
        t.row(vec![fmt(delta, 4), fmt(mult, 1), fmt(p, 3)]);
    }
    t.print();
    Ok(())
}

fn non_empty(s: String) -> Option<String> {
    if s.is_empty() {
        None
    } else {
        Some(s)
    }
}

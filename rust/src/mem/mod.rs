//! Appendix-F memory estimator: parameter + optimizer-state accounting
//! for every method, reproducing Tables 2/4/8/9/10 and the Fig-3 model.
//!
//! Conventions follow the paper exactly: bfloat16 storage (2 bytes per
//! float), int64 sparse indices (8 bytes), 1G = 1e9 bytes, optimizer
//! state = Adam first+second moments over *trainable* parameters.
//! Verified against the paper's own published breakdowns in unit tests
//! (GaLore 60M optimizer = 78.20M moments + 3.67M projection, SLTrain
//! 60M = 32.78M base + 10M low-rank + 0.76M sparse, ...).

use std::sync::atomic::{AtomicU64, Ordering};

use crate::config::ModelPreset;

pub const BF16: f64 = 2.0;
pub const INT64: f64 = 8.0;
pub const INT8: f64 = 1.0;
pub const QBLOCK: f64 = 256.0; // 8-bit Adam block size (scale overhead)

#[derive(Debug, Clone, Copy, Default)]
pub struct MemEstimate {
    /// counts, in units of parameters (not bytes)
    pub base_params: f64,
    pub adapted_params: f64,
    pub sparse_params: f64,
    pub optim_moment_params: f64,
    pub optim_proj_params: f64, // galore P
    /// bytes
    pub param_bytes: f64,
    pub optim_bytes: f64,
    pub grad_bytes: f64,
}

impl MemEstimate {
    pub fn total_params(&self) -> f64 {
        self.base_params + self.adapted_params + self.sparse_params
    }

    /// Paper Table 2 "Mem": parameter + optimizer state only.
    pub fn table2_bytes(&self) -> f64 {
        self.param_bytes + self.optim_bytes
    }

    /// Fig-3 style training footprint: params + grads + optimizer.
    pub fn train_bytes(&self) -> f64 {
        self.param_bytes + self.optim_bytes + self.grad_bytes
    }

    pub fn gb(bytes: f64) -> f64 {
        bytes / 1e9
    }
}

/// *Measured* (not estimated) footprint of a live training engine, in
/// bytes as actually allocated — f32 params, f32 or i8+scale optimizer
/// moments, gradient buffers. Reported by `Backend::mem_report`; the
/// analytic [`estimate`] below stays the paper-convention (bf16) model.
#[derive(Debug, Clone, Copy, Default)]
pub struct MemReport {
    /// All parameter tensors as held (f32), trainable or frozen.
    pub param_bytes: u64,
    /// Optimizer moments as held: 8·numel for f32 Adam, ~2.03·numel for
    /// the block-wise 8-bit moments. GaLore moments are counted at
    /// their projected size — the method's optimizer-byte win.
    pub optim_bytes: u64,
    /// GaLore projector matrices (f32, one rank-r frame per adapted
    /// linear). Optimizer state, but tracked separately from the
    /// moments so the f32-vs-8-bit moment comparison stays clean. Zero
    /// for every other method.
    pub proj_bytes: u64,
    /// Fixed sparse-support structures (sltrain): flat indices + CSR
    /// arrays, plus the u8 in-group offsets of structured N:M supports
    /// (`--support n:m`). Zero for dense methods.
    pub support_bytes: u64,
    /// High-water mark of live *parameter-gradient* buffers (the
    /// buffers the per-layer-update literature targets; activation
    /// gradients are transient per-op temporaries and are not counted).
    /// The streaming per-layer fused backward releases each buffer
    /// right after its Adam update, so this sits near the largest
    /// single tensor instead of the full trainable size; compare
    /// against `grad_all_bytes`, which uses the same scope.
    pub grad_peak_bytes: u64,
    /// What a two-phase loop holds at its peak: every parameter
    /// gradient at once (same scope as `grad_peak_bytes`).
    pub grad_all_bytes: u64,
    /// Adam moment precision actually in use (32 or 8).
    pub optim_bits: u32,
    /// Data-parallel worker count behind this report. 1 for a plain
    /// engine. For a sharded engine the byte fields are the PER-WORKER
    /// footprint (optimizer moments owner-sharded ~1/N; params
    /// replicated), reduced across replicas by max.
    pub workers: u32,
}

impl MemReport {
    /// Params + optimizer (moments and projectors) + supports +
    /// gradient high-water: the training-state bytes the engine cannot
    /// avoid holding.
    pub fn total_bytes(&self) -> u64 {
        self.param_bytes
            + self.optim_bytes
            + self.proj_bytes
            + self.support_bytes
            + self.grad_peak_bytes
    }
}

/// Monotonic peak-bytes tracker. Atomic so a backend can note the live
/// total from `&self` contexts that must stay `Sync` (the worker pool
/// borrows the backend shared during parallel regions).
#[derive(Debug, Default)]
pub struct PeakTracker {
    peak: AtomicU64,
}

impl PeakTracker {
    /// Record an observed live-byte total; keeps the maximum.
    pub fn note(&self, live_bytes: u64) {
        self.peak.fetch_max(live_bytes, Ordering::Relaxed);
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    pub fn reset(&self) {
        self.peak.store(0, Ordering::Relaxed);
    }
}

#[derive(Debug, Clone, Copy, Default)]
pub struct MemOptions {
    /// quantize Adam moments to int8 (Dettmers et al. [9])
    pub eight_bit: bool,
    /// per-layer weight updates (Lv et al. [36]): gradient storage shrinks
    /// to the largest single layer instead of the full model
    pub per_layer: bool,
}

/// Estimate memory for (preset, method). Mirrors Appendix F line by line.
pub fn estimate(p: &ModelPreset, method: &str, opts: MemOptions) -> MemEstimate {
    let mut e = MemEstimate::default();
    e.base_params = p.base_params() as f64;
    let linears = p.linear_paths();

    // ---- parameter memory -------------------------------------------
    let mut trainable = e.base_params;
    let mut layer_trainables: Vec<f64> = vec![e.base_params]; // for per-layer grads
    for (_, din, dout) in &linears {
        let (din, dout) = (*din as f64, *dout as f64);
        let lr_params = (din + dout) * p.rank as f64;
        let nnz = p.nnz(din as usize, dout as usize) as f64;
        match method {
            "full" | "galore" => {
                e.adapted_params += din * dout;
                trainable += din * dout;
                layer_trainables.push(din * dout);
            }
            "lowrank" => {
                e.adapted_params += lr_params;
                trainable += lr_params;
                layer_trainables.push(lr_params);
            }
            "relora" => {
                // stores W0 (frozen between merges) + adaptors
                e.adapted_params += din * dout + lr_params;
                trainable += lr_params;
                layer_trainables.push(lr_params);
            }
            "sltrain" => {
                e.adapted_params += lr_params;
                e.sparse_params += nnz;
                trainable += lr_params + nnz;
                layer_trainables.push(lr_params + nnz);
            }
            _ => panic!("unknown method {method}"),
        }
    }
    if method == "relora" {
        // Appendix F: ReLoRA stores the original parameters AND adaptor
        // copies "for other parameters" — the base params appear twice
        // (60M: 58.2M originals + 44.5M adaptors ⇒ 102.77M total).
        e.adapted_params += e.base_params;
    }
    e.param_bytes = (e.base_params + e.adapted_params + e.sparse_params) * BF16
        + e.sparse_params * INT64; // sltrain stores indices in int64

    // ---- optimizer state --------------------------------------------
    if method == "galore" {
        // moments live in the projected space for adapted matrices
        let mut moments = 2.0 * e.base_params;
        let mut proj = 0.0;
        for (_, din, dout) in &linears {
            let (d, q) = (*din as f64, *dout as f64);
            let r = p.rank as f64;
            moments += 2.0 * r * d.max(q);
            proj += d.min(q) * r;
        }
        e.optim_moment_params = moments;
        e.optim_proj_params = proj;
    } else {
        e.optim_moment_params = 2.0 * trainable;
    }
    let moment_bytes_per = if opts.eight_bit {
        INT8 + BF16 / QBLOCK // int8 code + amortized per-block scale
    } else {
        BF16
    };
    e.optim_bytes =
        e.optim_moment_params * moment_bytes_per + e.optim_proj_params * BF16;

    // ---- gradient memory (Fig 3 model) --------------------------------
    let grad_params = if opts.per_layer {
        layer_trainables.iter().cloned().fold(0.0, f64::max)
    } else {
        trainable
    };
    e.grad_bytes = grad_params * BF16;
    e
}

/// One row of the Table-8 style breakdown, formatted in paper units.
pub fn breakdown_row(p: &ModelPreset, method: &str, opts: MemOptions) -> String {
    let e = estimate(p, method, opts);
    format!(
        "{:<10} {:>9.2}M params ({:>7.2}M base, {:>7.2}M adapted, {:>6.2}M sparse) | param {:>6.2}G optim {:>6.2}G total {:>6.2}G",
        method,
        e.total_params() / 1e6,
        e.base_params / 1e6,
        e.adapted_params / 1e6,
        e.sparse_params / 1e6,
        MemEstimate::gb(e.param_bytes),
        MemEstimate::gb(e.optim_bytes),
        MemEstimate::gb(e.table2_bytes()),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;

    fn p60() -> ModelPreset {
        preset("paper60m").unwrap()
    }

    #[test]
    fn galore_60m_matches_paper_appendix_f() {
        // paper: moments 78.20M, projection 3.67M, optimizer 0.16G
        let e = estimate(&p60(), "galore", MemOptions::default());
        let moments_m = e.optim_moment_params / 1e6;
        let proj_m = e.optim_proj_params / 1e6;
        assert!((moments_m - 78.20).abs() < 2.0, "moments {moments_m}");
        assert!((proj_m - 3.67).abs() < 0.3, "proj {proj_m}");
        let optim_g = MemEstimate::gb(e.optim_bytes);
        assert!((optim_g - 0.16).abs() < 0.02, "optim {optim_g}");
    }

    #[test]
    fn sltrain_60m_matches_paper_appendix_f() {
        // paper: 32.78M base + 10M low-rank + 0.76M sparse; param 0.09G,
        // optim 0.17G
        let e = estimate(&p60(), "sltrain", MemOptions::default());
        assert!((e.base_params / 1e6 - 32.78).abs() < 1.5, "base {}", e.base_params / 1e6);
        assert!((e.adapted_params / 1e6 - 10.0).abs() < 0.5, "lr {}", e.adapted_params / 1e6);
        assert!((e.sparse_params / 1e6 - 0.76).abs() < 0.05, "sp {}", e.sparse_params / 1e6);
        assert!((MemEstimate::gb(e.param_bytes) - 0.09).abs() < 0.01);
        assert!((MemEstimate::gb(e.optim_bytes) - 0.17).abs() < 0.02);
    }

    #[test]
    fn full_rank_60m_matches_paper() {
        // paper: 0.12G params, 0.23G optimizer
        let e = estimate(&p60(), "full", MemOptions::default());
        assert!((MemEstimate::gb(e.param_bytes) - 0.12).abs() < 0.01);
        assert!((MemEstimate::gb(e.optim_bytes) - 0.23).abs() < 0.02);
    }

    #[test]
    fn method_memory_ordering_table2() {
        // Table 2: lowrank < sltrain < galore < full at every scale;
        // ReLoRA sits above full at 60M (0.36 vs 0.35) but below it at 1B
        // (6.34 vs 8.04) because its optimizer state stays adaptor-sized.
        for name in ["paper60m", "paper130m", "paper1b"] {
            let p = preset(name).unwrap();
            let t = |m: &str| estimate(&p, m, MemOptions::default()).table2_bytes();
            assert!(t("lowrank") < t("sltrain"), "{name}");
            assert!(t("sltrain") < t("galore"), "{name}");
            assert!(t("galore") < t("full"), "{name}");
            assert!(t("relora") > t("sltrain"), "{name}");
        }
        let p60 = preset("paper60m").unwrap();
        let p1b = preset("paper1b").unwrap();
        let t = |p: &ModelPreset, m: &str| estimate(p, m, MemOptions::default()).table2_bytes();
        assert!(t(&p60, "relora") > t(&p60, "full"));
        assert!(t(&p1b, "relora") < t(&p1b, "full"));
    }

    #[test]
    fn table2_absolute_totals_match_paper_1b() {
        // paper 1B row: full 8.04G, lowrank 3.66G, galore 4.76G, sltrain 4.16G
        let p = preset("paper1b").unwrap();
        let t = |m: &str| MemEstimate::gb(estimate(&p, m, MemOptions::default()).table2_bytes());
        assert!((t("full") - 8.04).abs() < 0.15, "full {}", t("full"));
        assert!((t("lowrank") - 3.66).abs() < 0.15, "lowrank {}", t("lowrank"));
        assert!((t("galore") - 4.76).abs() < 0.15, "galore {}", t("galore"));
        assert!((t("sltrain") - 4.16).abs() < 0.15, "sltrain {}", t("sltrain"));
    }

    #[test]
    fn eight_bit_and_per_layer_reduce_memory() {
        let p = preset("spec7b").unwrap();
        let base = estimate(&p, "sltrain", MemOptions::default());
        let q8 = estimate(&p, "sltrain", MemOptions { eight_bit: true, per_layer: false });
        let q8pl = estimate(&p, "sltrain", MemOptions { eight_bit: true, per_layer: true });
        assert!(q8.optim_bytes < base.optim_bytes * 0.6);
        assert!(q8pl.grad_bytes < base.grad_bytes * 0.2);
        assert!(q8pl.train_bytes() < base.train_bytes());
    }

    #[test]
    fn peak_tracker_keeps_maximum_and_resets() {
        let t = PeakTracker::default();
        assert_eq!(t.peak_bytes(), 0);
        t.note(100);
        t.note(50);
        assert_eq!(t.peak_bytes(), 100);
        t.note(300);
        assert_eq!(t.peak_bytes(), 300);
        t.reset();
        assert_eq!(t.peak_bytes(), 0);
    }

    #[test]
    fn mem_report_totals_sum_components() {
        let r = MemReport {
            param_bytes: 10,
            optim_bytes: 20,
            proj_bytes: 4,
            support_bytes: 3,
            grad_peak_bytes: 5,
            grad_all_bytes: 40,
            optim_bits: 8,
            workers: 1,
        };
        assert_eq!(r.total_bytes(), 42);
    }

    #[test]
    fn sltrain_7b_vs_galore_memory_reduction() {
        // Table 4: 8-bit SLTrain 46G vs 8-bit GaLore 62G per GPU (26% cut).
        // Our model excludes activations, so compare the reduction RATIO of
        // the param+optim+grad footprint instead of absolute gigabytes.
        let p = preset("spec7b").unwrap();
        let o = MemOptions { eight_bit: true, per_layer: false };
        let sl = estimate(&p, "sltrain", o).train_bytes();
        let gl = estimate(&p, "galore", o).train_bytes();
        let cut = 1.0 - sl / gl;
        assert!(cut > 0.15 && cut < 0.60, "7b sltrain vs galore cut = {cut:.2}");
    }
}

//! PJRT execution: load HLO-text artifacts, compile once, execute forever.
//!
//! This is the only module in the crate that touches the `xla` crate, and
//! it only exists when the `xla` cargo feature is enabled. It follows the
//! reference wiring of /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`, with
//! tuple-root outputs decomposed per the manifest's output name list.
//!
//! State (params + optimizer buffers + fixed sparse supports) lives here
//! as `xla::Literal`s keyed by tensor name, so the training loop shuttles
//! only token batches and scalars per step.

use super::{Dtype, Entrypoint, Manifest, TensorSpec};

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

/// Thin wrapper over the PJRT CPU client.
pub struct Runtime {
    pub client: xla::PjRtClient,
}

impl Runtime {
    pub fn cpu() -> Result<Runtime> {
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("pjrt cpu: {e}"))?;
        Ok(Runtime { client })
    }

    /// One process-wide PJRT CPU client, shared across artifact opens.
    /// Bench loops that open many artifacts (table2/table3 sweeps)
    /// previously paid client startup per `XlaBackend::open`; this
    /// amortizes it to once per process. Client bring-up failures are
    /// not cached, so a later call can still succeed.
    pub fn cpu_shared() -> Result<std::sync::Arc<Runtime>> {
        static SHARED: std::sync::OnceLock<std::sync::Mutex<Option<std::sync::Arc<Runtime>>>> =
            std::sync::OnceLock::new();
        let cell = SHARED.get_or_init(|| std::sync::Mutex::new(None));
        let mut guard = cell.lock().unwrap();
        if let Some(rt) = guard.as_ref() {
            return Ok(rt.clone());
        }
        let rt = std::sync::Arc::new(Runtime::cpu()?);
        *guard = Some(rt.clone());
        Ok(rt)
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    fn compile_file(&self, path: &Path) -> Result<xla::PjRtLoadedExecutable> {
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )
        .map_err(|e| anyhow!("parse {path:?}: {e}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        self.client.compile(&comp).map_err(|e| anyhow!("compile {path:?}: {e}"))
    }
}

/// Host-resident tensor state: name -> Literal.
pub struct State {
    pub tensors: HashMap<String, xla::Literal>,
}

impl State {
    pub fn new() -> State {
        State { tensors: HashMap::new() }
    }

    pub fn get(&self, name: &str) -> Result<&xla::Literal> {
        self.tensors.get(name).ok_or_else(|| anyhow!("state missing tensor {name:?}"))
    }

    pub fn put(&mut self, name: &str, lit: xla::Literal) {
        self.tensors.insert(name.to_string(), lit);
    }

    /// Copy a tensor out as f32 (for checkpoints / analysis).
    pub fn to_f32(&self, name: &str) -> Result<Vec<f32>> {
        Ok(self.get(name)?.to_vec::<f32>().map_err(|e| anyhow!("{name}: {e}"))?)
    }
}

impl Default for State {
    fn default() -> Self {
        Self::new()
    }
}

// ------------------------------------------------------------- literals

pub fn lit_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_f32 shape {shape:?} != len {}", data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("{e}"))?)
}

pub fn lit_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_i32 shape {shape:?} != len {}", data.len());
    }
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    Ok(xla::Literal::vec1(data).reshape(&dims).map_err(|e| anyhow!("{e}"))?)
}

/// i8 literals: `i8` implements ArrayElement but not NativeType, so go
/// through create_from_shape + copy_raw_from instead of vec1.
pub fn lit_i8(shape: &[usize], data: &[i8]) -> Result<xla::Literal> {
    let n: usize = shape.iter().product();
    if n != data.len() {
        bail!("lit_i8 shape {shape:?} != len {}", data.len());
    }
    let mut lit = xla::Literal::create_from_shape(xla::PrimitiveType::S8, shape);
    lit.copy_raw_from(data).map_err(|e| anyhow!("{e}"))?;
    Ok(lit)
}

pub fn lit_scalar_i32(v: i32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn lit_scalar_u32(v: u32) -> xla::Literal {
    xla::Literal::scalar(v)
}

pub fn zeros_like_spec(spec: &TensorSpec) -> Result<xla::Literal> {
    let n: usize = spec.shape.iter().product();
    match spec.dtype {
        Dtype::F32 => lit_f32(&spec.shape, &vec![0.0; n]),
        Dtype::I32 => lit_i32(&spec.shape, &vec![0; n]),
        Dtype::I8 => lit_i8(&spec.shape, &vec![0i8; n]),
        Dtype::U32 => {
            let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
            Ok(xla::Literal::vec1(&vec![0u32; n]).reshape(&dims).map_err(|e| anyhow!("{e}"))?)
        }
    }
}

// ------------------------------------------------------------- artifact

/// A loaded artifact bundle: manifest + lazily compiled executables.
pub struct Artifact {
    pub dir: PathBuf,
    pub manifest: Manifest,
    execs: HashMap<String, xla::PjRtLoadedExecutable>,
}

impl Artifact {
    pub fn load(dir: &Path) -> Result<Artifact> {
        let man_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&man_path)
            .with_context(|| format!("reading {man_path:?}"))?;
        let manifest = Manifest::parse(&text)?;
        Ok(Artifact { dir: dir.to_path_buf(), manifest, execs: HashMap::new() })
    }

    pub fn entry(&self, name: &str) -> Result<&Entrypoint> {
        self.manifest
            .entrypoints
            .get(name)
            .ok_or_else(|| anyhow!("artifact has no entrypoint {name:?}"))
    }

    /// Compile (and cache) an entrypoint's executable.
    pub fn compile(&mut self, rt: &Runtime, name: &str) -> Result<()> {
        if self.execs.contains_key(name) {
            return Ok(());
        }
        let file = self.entry(name)?.file.clone();
        let exe = rt.compile_file(&self.dir.join(&file))?;
        self.execs.insert(name.to_string(), exe);
        Ok(())
    }

    /// Execute an entrypoint. `extra` supplies the __-prefixed inputs;
    /// everything else is pulled from `state` by name. Outputs named in
    /// the manifest are written back to `state`; __-outputs are returned.
    pub fn run(
        &mut self,
        rt: &Runtime,
        name: &str,
        state: &mut State,
        extra: &HashMap<String, xla::Literal>,
    ) -> Result<HashMap<String, xla::Literal>> {
        self.compile(rt, name)?;
        let entry = self.entry(name)?.clone();
        let exe = self.execs.get(name).expect("compiled above");

        let mut inputs: Vec<&xla::Literal> = Vec::with_capacity(entry.inputs.len());
        for n in &entry.inputs {
            if let Some(l) = extra.get(n) {
                inputs.push(l);
            } else {
                inputs.push(state.get(n)?);
            }
        }
        let result = exe
            .execute::<&xla::Literal>(&inputs)
            .map_err(|e| anyhow!("execute {name}: {e}"))?;
        let tuple = result[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetch {name}: {e}"))?;
        let outs = tuple.to_tuple().map_err(|e| anyhow!("untuple {name}: {e}"))?;
        if outs.len() != entry.outputs.len() {
            bail!(
                "{name}: {} outputs but manifest lists {}",
                outs.len(),
                entry.outputs.len()
            );
        }
        let mut special = HashMap::new();
        for (out_name, lit) in entry.outputs.iter().zip(outs) {
            if out_name.starts_with("__") {
                special.insert(out_name.clone(), lit);
            } else {
                state.put(out_name, lit);
            }
        }
        Ok(special)
    }

    /// Load the fixed sparse supports from sidecar files into state (i32).
    pub fn load_supports(&self, state: &mut State) -> Result<()> {
        for (name, sup) in &self.manifest.supports {
            let raw = std::fs::read(self.dir.join(&sup.file))
                .with_context(|| format!("support {name}"))?;
            if raw.len() != sup.nnz * 4 {
                bail!("support {name}: {} bytes for nnz {}", raw.len(), sup.nnz);
            }
            let idx: Vec<i32> = raw
                .chunks_exact(4)
                .map(|c| u32::from_le_bytes(c.try_into().unwrap()) as i32)
                .collect();
            state.put(name, lit_i32(&[sup.nnz], &idx)?);
        }
        Ok(())
    }

    /// Run init: fills params + optimizer state, then loads supports.
    pub fn init_state(&mut self, rt: &Runtime, seed: u32) -> Result<State> {
        let mut state = State::new();
        let mut extra = HashMap::new();
        extra.insert("__seed".to_string(), lit_scalar_u32(seed));
        self.run(rt, "init", &mut state, &extra)?;
        self.load_supports(&mut state)?;
        Ok(state)
    }

    /// One optimizer step. Returns the scalar loss.
    pub fn train_step(
        &mut self,
        rt: &Runtime,
        state: &mut State,
        step: i32,
        tokens: &[i32],
    ) -> Result<f32> {
        let entry = self.entry("train_step")?;
        let (b, s) = (entry.batch, self.manifest.seq_len());
        if tokens.len() != b * s {
            bail!("train_step expects {}x{} tokens, got {}", b, s, tokens.len());
        }
        let mut extra = HashMap::new();
        extra.insert("__step".to_string(), lit_scalar_i32(step));
        extra.insert("__tokens".to_string(), lit_i32(&[b, s], tokens)?);
        let out = self.run(rt, "train_step", state, &extra)?;
        let loss = out
            .get("__loss")
            .ok_or_else(|| anyhow!("train_step returned no __loss"))?
            .to_vec::<f32>()
            .map_err(|e| anyhow!("{e}"))?[0];
        Ok(loss)
    }

    /// Validation loss on one batch (no state mutation).
    pub fn eval_loss(&mut self, rt: &Runtime, state: &mut State, tokens: &[i32]) -> Result<f32> {
        let entry = self.entry("eval_step")?;
        let (b, s) = (entry.batch, self.manifest.seq_len());
        if tokens.len() != b * s {
            bail!("eval_step expects {}x{} tokens, got {}", b, s, tokens.len());
        }
        let mut extra = HashMap::new();
        extra.insert("__tokens".to_string(), lit_i32(&[b, s], tokens)?);
        let out = self.run(rt, "eval_step", state, &extra)?;
        Ok(out["__loss"].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?[0])
    }

    /// Forward pass returning logits [b, s, vocab] flattened.
    pub fn forward(&mut self, rt: &Runtime, state: &mut State, tokens: &[i32]) -> Result<Vec<f32>> {
        let entry = self.entry("forward")?;
        let (b, s) = (entry.batch, self.manifest.seq_len());
        if tokens.len() != b * s {
            bail!("forward expects {}x{} tokens, got {}", b, s, tokens.len());
        }
        let mut extra = HashMap::new();
        extra.insert("__tokens".to_string(), lit_i32(&[b, s], tokens)?);
        let out = self.run(rt, "forward", state, &extra)?;
        Ok(out["__logits"].to_vec::<f32>().map_err(|e| anyhow!("{e}"))?)
    }

    /// ReLoRA restart: merge BA into W0 (artifact) + reset B/A moments.
    pub fn relora_merge(&mut self, rt: &Runtime, state: &mut State, seed: i32) -> Result<()> {
        let mut extra = HashMap::new();
        extra.insert("__seed".to_string(), lit_scalar_i32(seed));
        self.run(rt, "merge", state, &extra)?;
        // optimizer reset for the re-initialized adaptors
        let opt_specs: Vec<TensorSpec> = self.manifest.opt_state.clone();
        for spec in &opt_specs {
            let base = spec
                .name
                .rsplit_once('.')
                .map(|(b, _)| b)
                .unwrap_or(&spec.name);
            if base.ends_with(".B") || base.ends_with(".A") {
                state.put(&spec.name, zeros_like_spec(spec)?);
            }
        }
        Ok(())
    }
}

// ----------------------------------------------------- device-resident loop

/// Device-resident training state: name -> PjRtBuffer. The §Perf fast
/// path: parameters and optimizer state stay on the PJRT device between
/// steps (the patched `execute_b_untupled` returns one buffer per output
/// leaf), so the per-step host traffic is just tokens in + loss out,
/// instead of a full round-trip of every parameter through Literals.
///
/// NOTE: since perf_steploop moved to the artifact-free Backend trait,
/// this path has no in-repo bench consumer. It is kept as the primitive
/// for the ROADMAP "serving path" item (persistent batched `forward`
/// with device-resident params); wire the next xla-bound bench or the
/// serving process through it rather than duplicating the buffer
/// plumbing.
pub struct DeviceState {
    pub bufs: HashMap<String, xla::PjRtBuffer>,
}

impl Artifact {
    /// Upload all state tensors as device buffers.
    pub fn to_device(&self, rt: &Runtime, state: &State) -> Result<DeviceState> {
        let mut bufs = HashMap::new();
        for (name, lit) in &state.tensors {
            let buf = rt
                .client
                .buffer_from_host_literal(None, lit)
                .map_err(|e| anyhow!("upload {name}: {e}"))?;
            bufs.insert(name.clone(), buf);
        }
        Ok(DeviceState { bufs })
    }

    /// Download device buffers back into a host state (checkpoints/analysis).
    pub fn to_host(&self, dstate: &DeviceState) -> Result<State> {
        let mut state = State::new();
        for (name, buf) in &dstate.bufs {
            state.put(name, buf.to_literal_sync().map_err(|e| anyhow!("{name}: {e}"))?);
        }
        Ok(state)
    }

    /// One optimizer step with device-resident state. Only the token batch
    /// crosses host→device and only the scalar loss crosses device→host.
    pub fn train_step_device(
        &mut self,
        rt: &Runtime,
        dstate: &mut DeviceState,
        step: i32,
        tokens: &[i32],
    ) -> Result<f32> {
        self.compile(rt, "train_step")?;
        let entry = self.entry("train_step")?.clone();
        let (b, s) = (entry.batch, self.manifest.seq_len());
        if tokens.len() != b * s {
            bail!("train_step expects {}x{} tokens, got {}", b, s, tokens.len());
        }
        let step_buf = rt
            .client
            .buffer_from_host_buffer(&[step], &[], None)
            .map_err(|e| anyhow!("{e}"))?;
        let tok_buf = rt
            .client
            .buffer_from_host_buffer(tokens, &[b, s], None)
            .map_err(|e| anyhow!("{e}"))?;
        let mut inputs: Vec<&xla::PjRtBuffer> = Vec::with_capacity(entry.inputs.len());
        for n in &entry.inputs {
            match n.as_str() {
                "__step" => inputs.push(&step_buf),
                "__tokens" => inputs.push(&tok_buf),
                other => inputs.push(
                    dstate
                        .bufs
                        .get(other)
                        .ok_or_else(|| anyhow!("device state missing {other}"))?,
                ),
            }
        }
        let exe = self.execs.get("train_step").expect("compiled above");
        let mut result = exe
            .execute_b_untupled::<&xla::PjRtBuffer>(&inputs)
            .map_err(|e| anyhow!("execute_b_untupled: {e}"))?;
        let outs = std::mem::take(&mut result[0]);
        if outs.len() != entry.outputs.len() {
            bail!(
                "untupled execute: {} outputs vs {} in manifest",
                outs.len(),
                entry.outputs.len()
            );
        }
        let mut loss = 0.0f32;
        for (name, buf) in entry.outputs.iter().zip(outs) {
            if name == "__loss" {
                loss = buf
                    .to_literal_sync()
                    .map_err(|e| anyhow!("{e}"))?
                    .to_vec::<f32>()
                    .map_err(|e| anyhow!("{e}"))?[0];
            } else {
                dstate.bufs.insert(name.clone(), buf);
            }
        }
        Ok(loss)
    }
}

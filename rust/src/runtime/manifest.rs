//! manifest.json schema: the contract between `python/compile/aot.py`
//! and the rust runtime. Parsed with the in-repo JSON parser.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::config::ModelPreset;
use crate::util::json::Json;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dtype {
    F32,
    I32,
    I8,
    U32,
}

impl Dtype {
    pub fn parse(s: &str) -> Result<Dtype> {
        Ok(match s {
            "f32" => Dtype::F32,
            "i32" => Dtype::I32,
            "i8" => Dtype::I8,
            "u32" => Dtype::U32,
            other => return Err(anyhow!("unknown dtype {other:?}")),
        })
    }

    pub fn size_bytes(&self) -> usize {
        match self {
            Dtype::F32 | Dtype::I32 | Dtype::U32 => 4,
            Dtype::I8 => 1,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: Dtype,
    pub trainable: bool,
}

impl TensorSpec {
    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    fn parse(v: &Json) -> Result<TensorSpec> {
        Ok(TensorSpec {
            name: v.req("name")?.as_str().unwrap_or_default().to_string(),
            shape: v
                .req("shape")?
                .as_arr()
                .ok_or_else(|| anyhow!("shape not array"))?
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect(),
            dtype: Dtype::parse(v.req("dtype")?.as_str().unwrap_or("f32"))?,
            trainable: v.get("trainable").and_then(|t| t.as_bool()).unwrap_or(false),
        })
    }
}

#[derive(Debug, Clone)]
pub struct Entrypoint {
    pub file: String,
    pub inputs: Vec<String>,
    pub outputs: Vec<String>,
    pub batch: usize,
}

#[derive(Debug, Clone)]
pub struct SupportSpec {
    pub file: String,
    pub nnz: usize,
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub method: String,
    pub optimizer: String,
    pub batch: usize,
    pub n_params: usize,
    pub preset: ModelPreset,
    pub params: Vec<TensorSpec>,
    pub consts: Vec<TensorSpec>,
    pub opt_state: Vec<TensorSpec>,
    pub supports: BTreeMap<String, SupportSpec>,
    pub entrypoints: BTreeMap<String, Entrypoint>,
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let v = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let specs = |key: &str| -> Result<Vec<TensorSpec>> {
            v.req(key)?
                .as_arr()
                .ok_or_else(|| anyhow!("{key} not array"))?
                .iter()
                .map(TensorSpec::parse)
                .collect()
        };
        let mut entrypoints = BTreeMap::new();
        for (name, e) in v
            .req("entrypoints")?
            .as_obj()
            .ok_or_else(|| anyhow!("entrypoints not object"))?
        {
            let names = |key: &str| -> Result<Vec<String>> {
                Ok(e.req(key)?
                    .as_arr()
                    .ok_or_else(|| anyhow!("{key} not array"))?
                    .iter()
                    .map(|s| s.as_str().unwrap_or_default().to_string())
                    .collect())
            };
            entrypoints.insert(
                name.clone(),
                Entrypoint {
                    file: e.req("file")?.as_str().unwrap_or_default().to_string(),
                    inputs: names("inputs")?,
                    outputs: names("outputs")?,
                    batch: e.get("batch").and_then(|b| b.as_usize()).unwrap_or(0),
                },
            );
        }
        let mut supports = BTreeMap::new();
        if let Some(sup) = v.get("supports").and_then(|s| s.as_obj()) {
            for (name, s) in sup {
                supports.insert(
                    name.clone(),
                    SupportSpec {
                        file: s.req("file")?.as_str().unwrap_or_default().to_string(),
                        nnz: s.req("nnz")?.as_usize().unwrap_or(0),
                    },
                );
            }
        }
        Ok(Manifest {
            method: v.req("method")?.as_str().unwrap_or_default().to_string(),
            optimizer: v
                .req("optimizer")?
                .req("type")?
                .as_str()
                .unwrap_or_default()
                .to_string(),
            batch: v.req("batch")?.as_usize().unwrap_or(0),
            n_params: v.req("n_params")?.as_usize().unwrap_or(0),
            preset: ModelPreset::from_manifest(&v)?,
            params: specs("params")?,
            consts: specs("consts")?,
            opt_state: specs("opt_state")?,
            supports,
            entrypoints,
        })
    }

    pub fn seq_len(&self) -> usize {
        self.preset.seq_len
    }

    /// Total parameter count (sanity check vs n_params).
    pub fn count_params(&self) -> usize {
        self.params.iter().map(|p| p.numel()).sum()
    }

    /// Bytes of live training state as the runtime holds it (f32 host).
    pub fn state_bytes(&self) -> usize {
        let p: usize = self.params.iter().map(|t| t.numel() * t.dtype.size_bytes()).sum();
        let o: usize =
            self.opt_state.iter().map(|t| t.numel() * t.dtype.size_bytes()).sum();
        let c: usize = self.consts.iter().map(|t| t.numel() * t.dtype.size_bytes()).sum();
        p + o + c
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "config": {"name":"tiny","vocab":256,"d_model":64,"n_layers":2,
                 "n_heads":2,"seq_len":64,"rank":16,"delta":0.03,
                 "alpha":32.0,"d_ff":192,"rope_theta":10000.0,
                 "adapt_attn":true,"adapt_mlp":true},
      "method": "sltrain",
      "optimizer": {"type":"adam","lr":0.003},
      "batch": 8, "fwd_batch": 8, "n_params": 80000,
      "params": [{"name":"embed.w","shape":[256,64],"dtype":"f32","trainable":true}],
      "consts": [{"name":"layers.0.attn.q.idx","shape":[123],"dtype":"i32"}],
      "opt_state": [{"name":"embed.w.m","shape":[256,64],"dtype":"f32"}],
      "supports": {"layers.0.attn.q.idx":{"file":"q.support.bin","nnz":123}},
      "entrypoints": {
        "train_step": {"file":"train_step.hlo.txt",
          "inputs":["__step","__tokens","layers.0.attn.q.idx","embed.w","embed.w.m"],
          "outputs":["__loss","embed.w","embed.w.m"],"batch":8}
      }
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE).unwrap();
        assert_eq!(m.method, "sltrain");
        assert_eq!(m.optimizer, "adam");
        assert_eq!(m.preset.d_model, 64);
        assert_eq!(m.params[0].numel(), 256 * 64);
        assert_eq!(m.consts[0].dtype, Dtype::I32);
        assert_eq!(m.supports["layers.0.attn.q.idx"].nnz, 123);
        let e = &m.entrypoints["train_step"];
        assert_eq!(e.inputs.len(), 5);
        assert_eq!(e.outputs[0], "__loss");
        assert_eq!(m.seq_len(), 64);
    }

    #[test]
    fn rejects_bad_dtype() {
        assert!(Dtype::parse("f64").is_err());
        assert_eq!(Dtype::parse("i8").unwrap().size_bytes(), 1);
    }
}

//! Artifact runtime layer.
//!
//! Split in two so the rest of the crate never links XLA by accident:
//!
//! * `manifest` — the manifest.json schema shared by every backend
//!   (tensor specs, dtypes, entrypoints, sparse-support sidecars). Always
//!   compiled; pure rust.
//! * `pjrt` — the PJRT execution engine (compile + execute HLO-text
//!   artifacts). Only compiled with the `xla` cargo feature; it is the
//!   single module in the crate allowed to `use xla::*`.
//!
//! Engine-agnostic process metrics (`current_rss_bytes`) also live here.

pub mod manifest;

pub use manifest::{Dtype, Entrypoint, Manifest, TensorSpec};

#[cfg(feature = "xla")]
pub mod pjrt;

#[cfg(feature = "xla")]
pub use pjrt::{
    lit_f32, lit_i32, lit_i8, lit_scalar_i32, lit_scalar_u32, zeros_like_spec, Artifact,
    DeviceState, Runtime, State,
};

/// Host RSS in bytes (Fig-3 "actual memory" measurements).
pub fn current_rss_bytes() -> u64 {
    if let Ok(statm) = std::fs::read_to_string("/proc/self/statm") {
        if let Some(pages) = statm.split_whitespace().nth(1) {
            if let Ok(p) = pages.parse::<u64>() {
                return p * 4096;
            }
        }
    }
    0
}

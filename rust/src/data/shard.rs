//! Memory-mapped token shards: the production data path.
//!
//! A shard file (`shard-NNNNN.slt`) is an immutable block of BPE token
//! ids with a checksummed header, written atomically and read through a
//! read-only `mmap(2)` (heap fallback on non-unix targets, on mapping
//! failure, or under `SLTRAIN_MMAP=off`). The layout mirrors the
//! SLTCKPT1 checkpoint container byte-for-byte in spirit:
//!
//! ```text
//! [ 8B magic "SLTSHRD1" ][ u64 LE header len ][ JSON header ][ u32 LE tokens... ]
//! ```
//!
//! The JSON header carries `n_tokens`, the tokenizer vocab size, the
//! corpus seed, the shard index, and a CRC-32 of the token payload, so
//! every corruption class (truncated header, bad magic, CRC mismatch,
//! truncated token block) surfaces as a typed [`ShardError`] — never a
//! panic — and the loader names the failing file.
//!
//! [`ShardStream`] extends the repo's bitwise determinism contract to
//! the data path: the shard visit order each epoch is a pure function
//! of `(seed, epoch)` (a seeded Fisher-Yates permutation, no RNG state
//! carried across epochs), so the token at absolute stream position `k`
//! is a pure function of `(seed, k)` — `--resume` replays to the same
//! byte, and thread/worker counts never touch the stream.

use std::collections::BTreeMap;
use std::fmt;
use std::fs;
use std::io::{Read, Write};
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::data::bpe::Bpe;
use crate::data::synth::{CorpusConfig, SynthCorpus};
use crate::linalg::parallel::{resolve_threads, ThreadPool};
use crate::util::crc::crc32;
use crate::util::json::{num, obj, Json};
use crate::util::rng::Rng;

/// File magic, 8 bytes, version-suffixed like `SLTCKPT1`.
pub const MAGIC: &[u8; 8] = b"SLTSHRD1";
/// Current shard format version (stored in the JSON header).
pub const VERSION: u64 = 1;

/// Typed shard-validation failures. Each corruption class maps to one
/// variant so tests (and operators) can tell truncation from bit rot;
/// the reader attaches the shard path as anyhow context on top.
#[derive(Debug, PartialEq, Eq)]
pub enum ShardError {
    /// Zero-length file (e.g. a crash between create and write).
    Empty,
    /// Too short for the fixed preamble, or the magic doesn't match.
    NotAShard,
    /// The header length field points past the end of the file.
    TruncatedHeader {
        /// Bytes actually present after the preamble.
        have: usize,
        /// Bytes the header length field claims.
        need: usize,
    },
    /// The JSON header doesn't parse or is missing required fields.
    BadHeader(String),
    /// The token block is shorter than `n_tokens` promises.
    TruncatedTokens {
        /// Payload bytes actually present.
        have: usize,
        /// Payload bytes required for `n_tokens` u32 ids.
        need: usize,
    },
    /// The token block's CRC-32 doesn't match the header.
    CrcMismatch {
        /// Checksum recorded in the header.
        stored: u32,
        /// Checksum computed over the payload on disk.
        computed: u32,
    },
}

impl fmt::Display for ShardError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ShardError::Empty => write!(f, "empty file"),
            ShardError::NotAShard => write!(f, "not a token shard (bad magic)"),
            ShardError::TruncatedHeader { have, need } => {
                write!(f, "truncated header: have {have} bytes, need {need}")
            }
            ShardError::BadHeader(m) => write!(f, "bad header: {m}"),
            ShardError::TruncatedTokens { have, need } => {
                write!(f, "truncated token block: have {have} bytes, need {need}")
            }
            ShardError::CrcMismatch { stored, computed } => write!(
                f,
                "token block CRC mismatch: stored {stored:#010x}, computed {computed:#010x}"
            ),
        }
    }
}

impl std::error::Error for ShardError {}

/// Header metadata of a validated shard.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ShardMeta {
    /// Shard index within its corpus build.
    pub shard: u64,
    /// Corpus seed the shard was generated from.
    pub seed: u64,
    /// Tokenizer vocab size at build time (ids are `< vocab`).
    pub vocab: u64,
    /// Number of u32 token ids in the payload.
    pub n_tokens: usize,
}

// ---------------------------------------------------------------------
// read-only backing: mmap with a heap fallback
// ---------------------------------------------------------------------

/// Direct syscall binding, no libc crate — same std-only FFI idiom as
/// `util/signal.rs`. 64-bit unix targets only (off_t == i64), which is
/// everything this repo runs on; everything else takes the heap path.
#[cfg(unix)]
mod mm {
    extern "C" {
        fn mmap(
            addr: *mut u8,
            len: usize,
            prot: i32,
            flags: i32,
            fd: i32,
            offset: i64,
        ) -> *mut u8;
        fn munmap(addr: *mut u8, len: usize) -> i32;
    }

    const PROT_READ: i32 = 1;
    const MAP_PRIVATE: i32 = 2;

    /// Map `len` bytes of `f` read-only. `None` on failure (caller
    /// falls back to a heap read; a shard must load either way).
    pub fn map(f: &std::fs::File, len: usize) -> Option<*mut u8> {
        if len == 0 {
            return None;
        }
        use std::os::unix::io::AsRawFd;
        let p = unsafe {
            mmap(std::ptr::null_mut(), len, PROT_READ, MAP_PRIVATE, f.as_raw_fd(), 0)
        };
        if p.is_null() || p as usize == usize::MAX {
            None
        } else {
            Some(p)
        }
    }

    pub fn unmap(ptr: *mut u8, len: usize) {
        unsafe {
            munmap(ptr, len);
        }
    }
}

/// `SLTRAIN_MMAP=off` forces the heap path (both backings are covered
/// by tests); any other non-empty value besides `on` is a loud error,
/// matching the `SLTRAIN_SIMD` typo policy.
fn mmap_enabled() -> bool {
    match std::env::var("SLTRAIN_MMAP") {
        Err(_) => true,
        Ok(v) if v.is_empty() || v == "on" => true,
        Ok(v) if v == "off" => false,
        Ok(v) => panic!("SLTRAIN_MMAP must be `on` or `off`, got {v:?}"),
    }
}

/// The bytes behind a reader: a private read-only mapping, or a plain
/// heap copy where mapping is unavailable or disabled.
enum Backing {
    #[cfg(unix)]
    Mapped { ptr: *mut u8, len: usize },
    Heap(Vec<u8>),
}

// The mapping is PROT_READ/MAP_PRIVATE and never mutated after open.
#[cfg(unix)]
unsafe impl Send for Backing {}
#[cfg(unix)]
unsafe impl Sync for Backing {}

impl Backing {
    fn open(path: &Path) -> Result<Backing> {
        let f = fs::File::open(path)?;
        let len = f.metadata()?.len() as usize;
        #[cfg(unix)]
        if mmap_enabled() {
            if let Some(ptr) = mm::map(&f, len) {
                return Ok(Backing::Mapped { ptr, len });
            }
        }
        let mut buf = Vec::with_capacity(len);
        let mut f = f;
        f.read_to_end(&mut buf)?;
        Ok(Backing::Heap(buf))
    }

    fn bytes(&self) -> &[u8] {
        match self {
            #[cfg(unix)]
            Backing::Mapped { ptr, len } => unsafe {
                std::slice::from_raw_parts(*ptr, *len)
            },
            Backing::Heap(v) => v,
        }
    }
}

impl Drop for Backing {
    fn drop(&mut self) {
        #[cfg(unix)]
        if let Backing::Mapped { ptr, len } = *self {
            mm::unmap(ptr, len);
        }
    }
}

// ---------------------------------------------------------------------
// validation + reader
// ---------------------------------------------------------------------

fn header_u64(h: &BTreeMap<String, Json>, key: &str) -> Result<u64, ShardError> {
    match h.get(key) {
        Some(Json::Num(n)) if *n >= 0.0 && n.fract() == 0.0 => Ok(*n as u64),
        _ => Err(ShardError::BadHeader(format!("missing or non-integer `{key}`"))),
    }
}

/// Validate a shard image. Returns the payload offset and the parsed
/// metadata; every failure is a typed [`ShardError`].
fn validate(data: &[u8]) -> Result<(usize, ShardMeta), ShardError> {
    if data.is_empty() {
        return Err(ShardError::Empty);
    }
    if data.len() < MAGIC.len() + 8 || &data[..MAGIC.len()] != MAGIC {
        return Err(ShardError::NotAShard);
    }
    let hlen = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
    let hend = 16 + hlen;
    if data.len() < hend {
        return Err(ShardError::TruncatedHeader { have: data.len() - 16, need: hlen });
    }
    let htext = std::str::from_utf8(&data[16..hend])
        .map_err(|e| ShardError::BadHeader(format!("header is not utf-8: {e}")))?;
    let hjson = Json::parse(htext).map_err(|e| ShardError::BadHeader(e.to_string()))?;
    let Json::Obj(h) = hjson else {
        return Err(ShardError::BadHeader("header is not a JSON object".into()));
    };
    let meta = ShardMeta {
        shard: header_u64(&h, "shard")?,
        seed: header_u64(&h, "seed")?,
        vocab: header_u64(&h, "vocab")?,
        n_tokens: header_u64(&h, "n_tokens")? as usize,
    };
    let stored_crc = header_u64(&h, "crc32")? as u32;
    let need = meta.n_tokens * 4;
    let have = data.len() - hend;
    if have < need {
        return Err(ShardError::TruncatedTokens { have, need });
    }
    let computed = crc32(&data[hend..hend + need]);
    if computed != stored_crc {
        return Err(ShardError::CrcMismatch { stored: stored_crc, computed });
    }
    Ok((hend, meta))
}

/// A validated, memory-mapped (or heap-backed) token shard.
pub struct ShardReader {
    /// Path the shard was opened from (error reporting / debugging).
    pub path: PathBuf,
    /// Parsed header metadata.
    pub meta: ShardMeta,
    backing: Backing,
    base: usize,
}

impl ShardReader {
    /// Open and fully validate a shard file. Corruption surfaces as a
    /// typed [`ShardError`] wrapped with the shard's path, so the
    /// failing file is always named.
    pub fn open(path: &Path) -> Result<ShardReader> {
        let backing = Backing::open(path)
            .with_context(|| format!("loading token shard {}", path.display()))?;
        let (base, meta) = validate(backing.bytes())
            .map_err(anyhow::Error::from)
            .with_context(|| format!("loading token shard {}", path.display()))?;
        Ok(ShardReader { path: path.to_path_buf(), meta, backing, base })
    }

    /// Number of tokens in this shard.
    pub fn len(&self) -> usize {
        self.meta.n_tokens
    }

    /// True when the shard holds no tokens.
    pub fn is_empty(&self) -> bool {
        self.meta.n_tokens == 0
    }

    /// Token id at position `i` (unaligned LE read off the mapping).
    pub fn token(&self, i: usize) -> u32 {
        let at = self.base + i * 4;
        let b = &self.backing.bytes()[at..at + 4];
        u32::from_le_bytes(b.try_into().unwrap())
    }
}

// ---------------------------------------------------------------------
// atomic writer
// ---------------------------------------------------------------------

fn sync_dir(path: &Path) {
    if let Some(dir) = path.parent() {
        if let Ok(d) = fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
}

/// Write one shard atomically: serialize to `<path>.tmp`, fsync, rename
/// into place, fsync the directory — the same durability ladder as
/// `Checkpoint::save`, so a crash mid-write never leaves a half shard
/// under the final name.
pub fn write_shard(path: &Path, tokens: &[u32], shard: u64, seed: u64, vocab: u64) -> Result<()> {
    let mut payload = Vec::with_capacity(tokens.len() * 4);
    for t in tokens {
        payload.extend_from_slice(&t.to_le_bytes());
    }
    let header = obj(vec![
        ("version", num(VERSION as f64)),
        ("shard", num(shard as f64)),
        ("seed", num(seed as f64)),
        ("vocab", num(vocab as f64)),
        ("n_tokens", num(tokens.len() as f64)),
        ("crc32", num(crc32(&payload) as f64)),
    ])
    .to_string();
    let tmp = path.with_extension("slt.tmp");
    {
        let mut f = fs::File::create(&tmp)
            .with_context(|| format!("creating {}", tmp.display()))?;
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        f.write_all(&payload)?;
        f.sync_all()?;
    }
    fs::rename(&tmp, path)
        .with_context(|| format!("renaming {} into place", tmp.display()))?;
    sync_dir(path);
    Ok(())
}

// ---------------------------------------------------------------------
// corpus builder: synthetic text -> parallel BPE -> shard files
// ---------------------------------------------------------------------

/// Canonical shard file name for index `i`.
pub fn shard_name(i: usize) -> String {
    format!("shard-{i:05}.slt")
}

/// Tokenizer file name inside a shard directory.
pub const TOKENIZER_FILE: &str = "tokenizer.bin";

/// Throughput report from [`build_shards`].
pub struct BuildReport {
    /// Shard files written.
    pub shards: usize,
    /// Total tokens across all shards.
    pub tokens: usize,
    /// Trained tokenizer vocab size.
    pub bpe_vocab: usize,
    /// Wall seconds spent tokenizing + writing (excludes BPE training).
    pub wall_secs: f64,
    /// Tokenization+write throughput in tokens/sec.
    pub tokens_per_sec: f64,
}

/// Build a shard directory from the synthetic corpus: train the BPE
/// tokenizer exactly as `Pipeline::build` does (same 40k-word sample,
/// same vocab clamp, so token ids line up with the live-synthetic
/// path), then tokenize each shard's text in parallel on the worker
/// pool (`Bpe::encode_bytes_par` — bit-identical at every thread
/// count) and write `shard-NNNNN.slt` files plus `tokenizer.bin`.
///
/// Shard `i` draws from chunk streams `i * 2^32 + chunk`, so shards are
/// disjoint and each is a pure function of `(corpus seed, i)`.
pub fn build_shards(
    dir: &Path,
    n_shards: usize,
    tokens_per_shard: usize,
    vocab_cap: usize,
    seed: u64,
    threads: usize,
) -> Result<BuildReport> {
    if n_shards == 0 {
        bail!("--shards must be >= 1");
    }
    fs::create_dir_all(dir).with_context(|| format!("creating {}", dir.display()))?;
    let corpus = SynthCorpus::new(CorpusConfig { seed, ..Default::default() });
    let sample = corpus.generate_text(40_000, u64::MAX);
    let bpe = Bpe::train(&sample, vocab_cap.min(8192).max(256));
    bpe.save(&dir.join(TOKENIZER_FILE))?;
    let pool = ThreadPool::new(resolve_threads(threads));
    let cap = vocab_cap.max(1) as u32;

    let t0 = std::time::Instant::now();
    let mut total = 0usize;
    for i in 0..n_shards {
        let mut toks: Vec<u32> = Vec::with_capacity(tokens_per_shard + 1024);
        let mut chunk = 0u64;
        while toks.len() < tokens_per_shard {
            let stream_seed = (i as u64).wrapping_mul(0x1_0000_0000) + chunk;
            let text = corpus.generate_text(8192, stream_seed);
            toks.extend(
                bpe.encode_bytes_par(text.as_bytes(), &pool).iter().map(|&t| t.min(cap - 1)),
            );
            chunk += 1;
        }
        toks.truncate(tokens_per_shard);
        write_shard(&dir.join(shard_name(i)), &toks, i as u64, seed, bpe.vocab_size() as u64)?;
        total += toks.len();
    }
    let wall = t0.elapsed().as_secs_f64();
    Ok(BuildReport {
        shards: n_shards,
        tokens: total,
        bpe_vocab: bpe.vocab_size(),
        wall_secs: wall,
        tokens_per_sec: total as f64 / wall.max(1e-9),
    })
}

// ---------------------------------------------------------------------
// shard set + deterministic stream
// ---------------------------------------------------------------------

/// All shards of a directory, sorted by file name, plus the tokenizer.
pub struct ShardSet {
    /// Validated readers in name order (`shard-00000.slt`, ...).
    pub readers: Vec<ShardReader>,
    /// The tokenizer the shards were encoded with.
    pub bpe: Bpe,
}

impl ShardSet {
    /// Open every `shard-*.slt` in `dir` (sorted, fully validated) and
    /// the `tokenizer.bin` beside them.
    pub fn open(dir: &Path) -> Result<ShardSet> {
        let mut names: Vec<PathBuf> = fs::read_dir(dir)
            .with_context(|| format!("reading shard dir {}", dir.display()))?
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| {
                p.file_name()
                    .and_then(|n| n.to_str())
                    .map(|n| n.starts_with("shard-") && n.ends_with(".slt"))
                    .unwrap_or(false)
            })
            .collect();
        names.sort();
        if names.is_empty() {
            bail!(
                "no shard-*.slt files in {} (build them with `sltrain data --make-shards`)",
                dir.display()
            );
        }
        let readers =
            names.iter().map(|p| ShardReader::open(p)).collect::<Result<Vec<_>>>()?;
        let bpe = Bpe::load(&dir.join(TOKENIZER_FILE))
            .with_context(|| format!("loading {}/{}", dir.display(), TOKENIZER_FILE))?;
        Ok(ShardSet { readers, bpe })
    }
}

/// Epoch-`e` visit order over `n` shards: a seeded Fisher-Yates
/// permutation that is a **pure function** of `(seed, epoch)` — no RNG
/// state survives an epoch boundary, so resume never has to replay
/// shuffles and every worker computes the identical order.
pub fn epoch_order(seed: u64, epoch: u64, n: usize) -> Vec<usize> {
    let mut idx: Vec<usize> = (0..n).collect();
    Rng::new(seed).fork(0x5EED_0000 ^ epoch).shuffle(&mut idx);
    idx
}

/// Sequential token stream over a set of shards with deterministic
/// per-epoch shard shuffling. The token at absolute position `k` is a
/// pure function of `(shards, seed, k)`.
pub struct ShardStream {
    readers: Vec<ShardReader>,
    seed: u64,
    vocab_cap: u32,
    epoch: u64,
    order: Vec<usize>,
    slot: usize,
    pos: usize,
}

impl ShardStream {
    /// Stream over `readers` with shuffle seed `seed`; ids are clamped
    /// to `vocab_cap` like the synthetic path (model vocab may be
    /// smaller than the tokenizer's).
    pub fn new(readers: Vec<ShardReader>, seed: u64, vocab_cap: usize) -> Result<ShardStream> {
        if readers.iter().all(|r| r.is_empty()) {
            bail!("shard stream has no tokens");
        }
        let n = readers.len();
        Ok(ShardStream {
            readers,
            seed,
            vocab_cap: vocab_cap.max(1) as u32,
            epoch: 0,
            order: epoch_order(seed, 0, n),
            slot: 0,
            pos: 0,
        })
    }

    /// Current epoch (number of completed full passes over the set).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Next token id, advancing across shard and epoch boundaries.
    pub fn next_token(&mut self) -> i32 {
        loop {
            if self.slot >= self.order.len() {
                self.epoch += 1;
                self.order = epoch_order(self.seed, self.epoch, self.readers.len());
                self.slot = 0;
                self.pos = 0;
            }
            let r = &self.readers[self.order[self.slot]];
            if self.pos < r.len() {
                let t = r.token(self.pos).min(self.vocab_cap - 1);
                self.pos += 1;
                return t as i32;
            }
            self.slot += 1;
            self.pos = 0;
        }
    }
}

//! Deterministic token stream + batcher.
//!
//! The paper pretrains "without data repetition": the loader exposes an
//! unbounded stream of fresh synthetic tokens, sharded so concurrent
//! consumers (or multi-process runs) never see overlapping data, and a
//! `Batcher` that packs the stream into `[batch, seq]` i32 matrices for
//! the train-step artifact. Also supports a fixed held-out validation
//! split, regenerated identically across runs for comparable perplexity.

use super::bpe::Bpe;
use super::synth::{CorpusConfig, SynthCorpus};

/// Streams tokens generated on the fly: corpus text -> BPE ids, chunked
/// so memory stays bounded regardless of how many tokens are consumed.
pub struct TokenStream {
    corpus: SynthCorpus,
    bpe: Bpe,
    shard: u64,
    chunk_words: usize,
    buf: Vec<u32>,
    pos: usize,
    chunk_idx: u64,
    vocab_cap: u32,
    pub tokens_served: u64,
}

impl TokenStream {
    pub fn new(corpus: SynthCorpus, bpe: Bpe, shard: u64, vocab_cap: usize) -> Self {
        TokenStream {
            corpus,
            bpe,
            shard,
            chunk_words: 8192,
            buf: vec![],
            pos: 0,
            chunk_idx: 0,
            vocab_cap: vocab_cap as u32,
            tokens_served: 0,
        }
    }

    fn refill(&mut self) {
        // stream id mixes shard and chunk so shards never overlap
        let stream_seed = self.shard.wrapping_mul(0x1_0000_0000) + self.chunk_idx;
        let text = self.corpus.generate_text(self.chunk_words, stream_seed);
        self.buf = self
            .bpe
            .encode(&text)
            .into_iter()
            .map(|t| t.min(self.vocab_cap - 1))
            .collect();
        self.pos = 0;
        self.chunk_idx += 1;
    }

    pub fn next_token(&mut self) -> u32 {
        if self.pos >= self.buf.len() {
            self.refill();
        }
        let t = self.buf[self.pos];
        self.pos += 1;
        self.tokens_served += 1;
        t
    }

    /// Fill a [batch, seq] row-major i32 buffer.
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        (0..batch * seq).map(|_| self.next_token() as i32).collect()
    }
}

/// Builds the standard (train, valid) pair used across all experiments:
/// one corpus, one tokenizer trained on a held-out sample, train shard 0+
/// and a DISJOINT validation shard (shard id u64::MAX/2).
pub struct Pipeline {
    pub train: TokenStream,
    pub valid: TokenStream,
    pub bpe_vocab: usize,
}

impl Pipeline {
    pub fn build(vocab_cap: usize, seed: u64) -> Pipeline {
        let cfg = CorpusConfig { seed, ..Default::default() };
        let corpus = SynthCorpus::new(cfg);
        // train the tokenizer on a fixed sample (build-time analog of the
        // pretrained LLaMA tokenizer); target vocab = model vocab
        let sample = corpus.generate_text(40_000, u64::MAX);
        let bpe = Bpe::train(&sample, vocab_cap.min(8192).max(256));
        let corpus2 = SynthCorpus::new(CorpusConfig { seed, ..Default::default() });
        let train = TokenStream::new(corpus, bpe.clone(), 0, vocab_cap);
        let valid = TokenStream::new(corpus2, bpe.clone(), u64::MAX / 2, vocab_cap);
        Pipeline { train, valid, bpe_vocab: bpe.vocab_size() }
    }

    /// A fixed validation set: `n_batches` of [batch, seq], always equal
    /// across runs (fresh stream from the valid shard).
    pub fn valid_set(&mut self, n_batches: usize, batch: usize, seq: usize) -> Vec<Vec<i32>> {
        (0..n_batches).map(|_| self.valid.next_batch(batch, seq)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pipeline() -> Pipeline {
        Pipeline::build(256, 7)
    }

    #[test]
    fn batches_have_right_shape_and_range() {
        let mut p = pipeline();
        let b = p.train.next_batch(4, 32);
        assert_eq!(b.len(), 4 * 32);
        assert!(b.iter().all(|&t| t >= 0 && (t as usize) < 256));
    }

    #[test]
    fn stream_is_deterministic() {
        let mut p1 = pipeline();
        let mut p2 = pipeline();
        assert_eq!(p1.train.next_batch(2, 16), p2.train.next_batch(2, 16));
    }

    #[test]
    fn no_repetition_across_batches() {
        let mut p = pipeline();
        let a = p.train.next_batch(2, 64);
        let b = p.train.next_batch(2, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn shards_are_disjoint_streams() {
        let mut p = pipeline();
        let train_b = p.train.next_batch(2, 64);
        let valid_b = p.valid.next_batch(2, 64);
        assert_ne!(train_b, valid_b);
    }

    #[test]
    fn valid_set_is_stable() {
        let mut p1 = pipeline();
        let mut p2 = pipeline();
        assert_eq!(p1.valid_set(3, 2, 16), p2.valid_set(3, 2, 16));
    }

    #[test]
    fn tokens_served_counts() {
        let mut p = pipeline();
        p.train.next_batch(2, 10);
        assert_eq!(p.train.tokens_served, 20);
    }
}

//! Deterministic token stream + batcher.
//!
//! The paper pretrains "without data repetition": the loader exposes an
//! unbounded stream of fresh synthetic tokens, sharded so concurrent
//! consumers (or multi-process runs) never see overlapping data, and a
//! `Batcher` that packs the stream into `[batch, seq]` i32 matrices for
//! the train-step artifact. Also supports a fixed held-out validation
//! split, regenerated identically across runs for comparable perplexity.
//!
//! Two sources sit behind the same [`TokenStream`] API:
//! - **Synth**: text generated on the fly and BPE-encoded per chunk
//!   (the original path; zero setup, unbounded fresh tokens).
//! - **Shards**: pre-tokenized memory-mapped shard files from
//!   `sltrain data --make-shards` ([`crate::data::shard`]) — the
//!   production path, with deterministic per-epoch shard shuffling.
//!
//! Both sources are pure functions of their seeds and the absolute
//! stream position, so the trainer's replay-based `--resume` works
//! identically on either.

use std::path::Path;

use anyhow::{bail, Context, Result};

use super::bpe::Bpe;
use super::shard::{ShardSet, ShardStream};
use super::synth::{CorpusConfig, SynthCorpus};

/// On-the-fly synthetic source: corpus text -> BPE ids, chunked so
/// memory stays bounded regardless of how many tokens are consumed.
struct SynthSource {
    corpus: SynthCorpus,
    bpe: Bpe,
    shard: u64,
    chunk_words: usize,
    buf: Vec<u32>,
    pos: usize,
    chunk_idx: u64,
    vocab_cap: u32,
}

impl SynthSource {
    fn refill(&mut self) {
        // stream id mixes shard and chunk so shards never overlap
        let stream_seed = self.shard.wrapping_mul(0x1_0000_0000) + self.chunk_idx;
        let text = self.corpus.generate_text(self.chunk_words, stream_seed);
        self.buf = self
            .bpe
            .encode(&text)
            .into_iter()
            .map(|t| t.min(self.vocab_cap - 1))
            .collect();
        self.pos = 0;
        self.chunk_idx += 1;
    }

    fn next_token(&mut self) -> u32 {
        if self.pos >= self.buf.len() {
            self.refill();
        }
        let t = self.buf[self.pos];
        self.pos += 1;
        t
    }
}

enum Source {
    Synth(SynthSource),
    Shards(ShardStream),
}

/// Streams tokens from either source behind one deterministic API.
pub struct TokenStream {
    src: Source,
    pub tokens_served: u64,
}

impl TokenStream {
    pub fn new(corpus: SynthCorpus, bpe: Bpe, shard: u64, vocab_cap: usize) -> Self {
        TokenStream {
            src: Source::Synth(SynthSource {
                corpus,
                bpe,
                shard,
                chunk_words: 8192,
                buf: vec![],
                pos: 0,
                chunk_idx: 0,
                vocab_cap: vocab_cap as u32,
            }),
            tokens_served: 0,
        }
    }

    /// Stream over pre-tokenized mmap shards (production path).
    pub fn from_shards(stream: ShardStream) -> Self {
        TokenStream { src: Source::Shards(stream), tokens_served: 0 }
    }

    pub fn next_token(&mut self) -> u32 {
        self.tokens_served += 1;
        match &mut self.src {
            Source::Synth(s) => s.next_token(),
            Source::Shards(s) => s.next_token() as u32,
        }
    }

    /// Fill a [batch, seq] row-major i32 buffer.
    pub fn next_batch(&mut self, batch: usize, seq: usize) -> Vec<i32> {
        (0..batch * seq).map(|_| self.next_token() as i32).collect()
    }
}

/// Builds the standard (train, valid) pair used across all experiments:
/// one corpus, one tokenizer trained on a held-out sample, train shard 0+
/// and a DISJOINT validation shard (shard id u64::MAX/2).
pub struct Pipeline {
    pub train: TokenStream,
    pub valid: TokenStream,
    pub bpe_vocab: usize,
}

impl Pipeline {
    pub fn build(vocab_cap: usize, seed: u64) -> Pipeline {
        let cfg = CorpusConfig { seed, ..Default::default() };
        let corpus = SynthCorpus::new(cfg);
        // train the tokenizer on a fixed sample (build-time analog of the
        // pretrained LLaMA tokenizer); target vocab = model vocab
        let sample = corpus.generate_text(40_000, u64::MAX);
        let bpe = Bpe::train(&sample, vocab_cap.min(8192).max(256));
        let corpus2 = SynthCorpus::new(CorpusConfig { seed, ..Default::default() });
        let train = TokenStream::new(corpus, bpe.clone(), 0, vocab_cap);
        let valid = TokenStream::new(corpus2, bpe.clone(), u64::MAX / 2, vocab_cap);
        Pipeline { train, valid, bpe_vocab: bpe.vocab_size() }
    }

    /// Production pair from a shard directory built by
    /// `sltrain data --make-shards`: the LAST shard (by name) is the
    /// fixed held-out validation split, all earlier shards form the
    /// train stream with `(shuffle_seed, epoch)`-pure shard shuffling.
    /// Needs >= 2 shards so train and valid stay disjoint.
    pub fn from_shard_dir(dir: &Path, vocab_cap: usize, shuffle_seed: u64) -> Result<Pipeline> {
        let set = ShardSet::open(dir)
            .with_context(|| format!("opening shard dir {}", dir.display()))?;
        if set.readers.len() < 2 {
            bail!(
                "shard dir {} has {} shard(s); need >= 2 (last is the held-out valid split)",
                dir.display(),
                set.readers.len()
            );
        }
        let bpe_vocab = set.bpe.vocab_size();
        let mut readers = set.readers;
        let valid_reader = readers.pop().expect("len checked above");
        let train = TokenStream::from_shards(ShardStream::new(readers, shuffle_seed, vocab_cap)?);
        // single shard: the epoch permutation is trivially [0], so the
        // valid stream is a fixed byte sequence across runs and seeds
        let valid = TokenStream::from_shards(ShardStream::new(
            vec![valid_reader],
            shuffle_seed,
            vocab_cap,
        )?);
        Ok(Pipeline { train, valid, bpe_vocab })
    }

    /// A fixed validation set: `n_batches` of [batch, seq], always equal
    /// across runs (fresh stream from the valid shard).
    pub fn valid_set(&mut self, n_batches: usize, batch: usize, seq: usize) -> Vec<Vec<i32>> {
        (0..n_batches).map(|_| self.valid.next_batch(batch, seq)).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pipeline() -> Pipeline {
        Pipeline::build(256, 7)
    }

    #[test]
    fn batches_have_right_shape_and_range() {
        let mut p = pipeline();
        let b = p.train.next_batch(4, 32);
        assert_eq!(b.len(), 4 * 32);
        assert!(b.iter().all(|&t| t >= 0 && (t as usize) < 256));
    }

    #[test]
    fn stream_is_deterministic() {
        let mut p1 = pipeline();
        let mut p2 = pipeline();
        assert_eq!(p1.train.next_batch(2, 16), p2.train.next_batch(2, 16));
    }

    #[test]
    fn no_repetition_across_batches() {
        let mut p = pipeline();
        let a = p.train.next_batch(2, 64);
        let b = p.train.next_batch(2, 64);
        assert_ne!(a, b);
    }

    #[test]
    fn shards_are_disjoint_streams() {
        let mut p = pipeline();
        let train_b = p.train.next_batch(2, 64);
        let valid_b = p.valid.next_batch(2, 64);
        assert_ne!(train_b, valid_b);
    }

    #[test]
    fn valid_set_is_stable() {
        let mut p1 = pipeline();
        let mut p2 = pipeline();
        assert_eq!(p1.valid_set(3, 2, 16), p2.valid_set(3, 2, 16));
    }

    #[test]
    fn tokens_served_counts() {
        let mut p = pipeline();
        p.train.next_batch(2, 10);
        assert_eq!(p.train.tokens_served, 20);
    }
}

//! Data pipeline: synthetic C4 stand-in, byte-level BPE tokenizer,
//! deterministic sharded token streams (see DESIGN.md §3 substitutions),
//! and the production path: checksummed memory-mapped token shards
//! ([`shard`]) built with parallel BPE tokenization.

pub mod bpe;
pub mod loader;
pub mod shard;
pub mod synth;

pub use bpe::Bpe;
pub use loader::{Pipeline, TokenStream};
pub use shard::{build_shards, ShardError, ShardReader, ShardSet, ShardStream};
pub use synth::{CorpusConfig, SynthCorpus};

//! Data pipeline: synthetic C4 stand-in, byte-level BPE tokenizer,
//! deterministic sharded token streams (see DESIGN.md §3 substitutions).

pub mod bpe;
pub mod loader;
pub mod synth;

pub use bpe::Bpe;
pub use loader::{Pipeline, TokenStream};
pub use synth::{CorpusConfig, SynthCorpus};

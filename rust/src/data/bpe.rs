//! Byte-level BPE tokenizer: train merges on a corpus sample, then
//! encode/decode streams. This is the substrate the paper takes for
//! granted (C4 ships pre-tokenized with the T5/LLaMA vocab); we build it
//! so the whole pipeline — raw text to token ids — exists in the repo.
//!
//! Training: greedy highest-frequency pair merging over a word-frequency
//! table (the GPT-2 algorithm, word-bounded so merges never cross
//! whitespace). Encoding: longest-match merges per word with a cache.

use std::collections::HashMap;

#[derive(Debug, Clone)]
pub struct Bpe {
    /// merge ranks: (left, right) -> rank (lower = earlier = higher prio)
    ranks: HashMap<(u32, u32), u32>,
    /// token id -> byte sequence
    pub vocab: Vec<Vec<u8>>,
    /// special: document separator token id (newline)
    pub eod: u32,
}

impl Bpe {
    pub const BYTE_VOCAB: usize = 256;

    /// Train to `vocab_size` tokens on `text`.
    pub fn train(text: &str, vocab_size: usize) -> Bpe {
        assert!(vocab_size >= Self::BYTE_VOCAB);
        // word frequency table; words keep a leading space (GPT-2 style)
        let mut word_freq: HashMap<Vec<u32>, usize> = HashMap::new();
        for line in text.split('\n') {
            for (i, w) in line.split_whitespace().enumerate() {
                let mut bytes: Vec<u32> = Vec::with_capacity(w.len() + 1);
                if i > 0 {
                    bytes.push(b' ' as u32);
                }
                bytes.extend(w.as_bytes().iter().map(|&b| b as u32));
                *word_freq.entry(bytes).or_insert(0) += 1;
            }
        }
        let mut vocab: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        let mut ranks = HashMap::new();
        let mut words: Vec<(Vec<u32>, usize)> = word_freq.into_iter().collect();
        words.sort(); // deterministic order

        while vocab.len() < vocab_size {
            // count pairs
            let mut pair_counts: HashMap<(u32, u32), usize> = HashMap::new();
            for (w, f) in &words {
                for pair in w.windows(2) {
                    *pair_counts.entry((pair[0], pair[1])).or_insert(0) += f;
                }
            }
            let Some((&best, &cnt)) = pair_counts
                .iter()
                .max_by_key(|(p, c)| (**c, std::cmp::Reverse(**p)))
            else {
                break;
            };
            if cnt < 2 {
                break; // nothing worth merging
            }
            let new_id = vocab.len() as u32;
            let mut merged_bytes = vocab[best.0 as usize].clone();
            merged_bytes.extend_from_slice(&vocab[best.1 as usize]);
            vocab.push(merged_bytes);
            ranks.insert(best, new_id - Self::BYTE_VOCAB as u32);
            // apply merge to all words
            for (w, _) in &mut words {
                let mut out = Vec::with_capacity(w.len());
                let mut i = 0;
                while i < w.len() {
                    if i + 1 < w.len() && (w[i], w[i + 1]) == best {
                        out.push(new_id);
                        i += 2;
                    } else {
                        out.push(w[i]);
                        i += 1;
                    }
                }
                *w = out;
            }
        }
        Bpe { ranks, vocab, eod: b'\n' as u32 }
    }

    /// Encode text to token ids (applies merges in rank order per word).
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let mut out = Vec::with_capacity(text.len() / 3);
        for (li, line) in text.split('\n').enumerate() {
            if li > 0 {
                out.push(self.eod);
            }
            for (i, w) in line.split_whitespace().enumerate() {
                let mut toks: Vec<u32> = Vec::with_capacity(w.len() + 1);
                if i > 0 {
                    toks.push(b' ' as u32);
                }
                toks.extend(w.as_bytes().iter().map(|&b| b as u32));
                self.merge_word(&mut toks);
                out.extend_from_slice(&toks);
            }
        }
        out
    }

    fn merge_word(&self, toks: &mut Vec<u32>) {
        loop {
            // find the lowest-rank applicable pair
            let mut best: Option<(u32, usize)> = None;
            for i in 0..toks.len().saturating_sub(1) {
                if let Some(&r) = self.ranks.get(&(toks[i], toks[i + 1])) {
                    if best.map(|(br, _)| r < br).unwrap_or(true) {
                        best = Some((r, i));
                    }
                }
            }
            let Some((r, i)) = best else { break };
            let merged = Self::BYTE_VOCAB as u32 + r;
            toks.splice(i..i + 2, [merged]);
        }
    }

    pub fn decode(&self, ids: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &id in ids {
            if id == self.eod {
                bytes.push(b'\n');
            } else {
                bytes.extend_from_slice(&self.vocab[id as usize]);
            }
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    // ---- byte-exact path (production shards) -------------------------
    //
    // `encode` above is whitespace-normalizing (split_whitespace), which
    // is fine for the synthetic corpus but cannot round-trip arbitrary
    // bytes. The shard pipeline uses this byte-exact segmentation
    // instead: every input byte lands in exactly one segment, so
    // `decode_bytes(encode_bytes(x)) == x` for ANY byte string — the
    // property tests in tests/properties.rs hold the identity over
    // random bytes including pathological whitespace runs.

    /// Byte-exact encode. Segmentation: each ASCII-whitespace byte is
    /// its own single-byte segment, except a single space directly
    /// followed by a non-whitespace run, which prefixes that run
    /// (GPT-2's leading-space convention, same as `encode`). Merges are
    /// word-bounded exactly as in training, so learned merges apply to
    /// `" word"`-shaped segments identically on both paths.
    pub fn encode_bytes(&self, data: &[u8]) -> Vec<u32> {
        let mut out = Vec::with_capacity(data.len() / 3);
        let mut i = 0;
        while i < data.len() {
            let b = data[i];
            if b.is_ascii_whitespace() {
                let attach = b == b' '
                    && i + 1 < data.len()
                    && !data[i + 1].is_ascii_whitespace();
                if !attach {
                    out.push(b as u32);
                    i += 1;
                    continue;
                }
            }
            // segment: optional leading space + maximal non-ws run
            let start = i;
            if data[i] == b' ' {
                i += 1;
            }
            while i < data.len() && !data[i].is_ascii_whitespace() {
                i += 1;
            }
            let mut toks: Vec<u32> =
                data[start..i].iter().map(|&b| b as u32).collect();
            self.merge_word(&mut toks);
            out.extend_from_slice(&toks);
        }
        out
    }

    /// Inverse of [`encode_bytes`]: plain vocab concatenation. All 256
    /// single bytes are in the vocab and merged tokens concatenate
    /// their parts, so this is a strict byte-level inverse.
    pub fn decode_bytes(&self, ids: &[u32]) -> Vec<u8> {
        let mut bytes = Vec::with_capacity(ids.len() * 2);
        for &id in ids {
            bytes.extend_from_slice(&self.vocab[id as usize]);
        }
        bytes
    }

    /// Chunk size target for [`encode_bytes_par`]. A constant (never
    /// derived from the thread count) so the chunk boundaries — and
    /// therefore the output — are identical on every pool size.
    const PAR_CHUNK: usize = 16 * 1024;

    /// Parallel [`encode_bytes`] on the worker pool, bit-identical to
    /// the serial path at every thread count: the input splits into
    /// fixed-size-target chunks whose boundaries land only immediately
    /// after a `\n` byte. A newline is always its own single-byte
    /// segment, so no segment straddles a boundary and concatenating
    /// the per-chunk encodings equals the serial encoding exactly.
    /// (`ThreadPool::map` preserves index order.)
    pub fn encode_bytes_par(
        &self,
        data: &[u8],
        pool: &crate::linalg::ThreadPool,
    ) -> Vec<u32> {
        let mut bounds: Vec<(usize, usize)> = Vec::new();
        let mut start = 0;
        while start < data.len() {
            let mut end = (start + Self::PAR_CHUNK).min(data.len());
            if end < data.len() {
                match data[end..].iter().position(|&b| b == b'\n') {
                    Some(off) => end += off + 1,
                    None => end = data.len(),
                }
            }
            bounds.push((start, end));
            start = end;
        }
        let chunks = pool.map(bounds.len(), |c| {
            let (a, b) = bounds[c];
            self.encode_bytes(&data[a..b])
        });
        let mut out = Vec::with_capacity(data.len() / 3);
        for c in chunks {
            out.extend_from_slice(&c);
        }
        out
    }

    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    // ---- persistence (simple binary format) --------------------------

    pub fn save(&self, path: &std::path::Path) -> anyhow::Result<()> {
        let mut out: Vec<u8> = Vec::new();
        out.extend((self.vocab.len() as u32).to_le_bytes());
        for v in &self.vocab {
            out.extend((v.len() as u32).to_le_bytes());
            out.extend_from_slice(v);
        }
        out.extend((self.ranks.len() as u32).to_le_bytes());
        let mut pairs: Vec<_> = self.ranks.iter().collect();
        pairs.sort();
        for (&(a, b), &r) in pairs {
            out.extend(a.to_le_bytes());
            out.extend(b.to_le_bytes());
            out.extend(r.to_le_bytes());
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> anyhow::Result<Bpe> {
        let data = std::fs::read(path)?;
        let mut i = 0usize;
        let rd_u32 = |data: &[u8], i: &mut usize| -> anyhow::Result<u32> {
            let v = u32::from_le_bytes(
                data.get(*i..*i + 4)
                    .ok_or_else(|| anyhow::anyhow!("truncated bpe file"))?
                    .try_into()?,
            );
            *i += 4;
            Ok(v)
        };
        let nv = rd_u32(&data, &mut i)? as usize;
        let mut vocab = Vec::with_capacity(nv);
        for _ in 0..nv {
            let len = rd_u32(&data, &mut i)? as usize;
            let v = data
                .get(i..i + len)
                .ok_or_else(|| anyhow::anyhow!("truncated bpe file"))?
                .to_vec();
            i += len;
            vocab.push(v);
        }
        let nr = rd_u32(&data, &mut i)? as usize;
        let mut ranks = HashMap::with_capacity(nr);
        for _ in 0..nr {
            let a = rd_u32(&data, &mut i)?;
            let b = rd_u32(&data, &mut i)?;
            let r = rd_u32(&data, &mut i)?;
            ranks.insert((a, b), r);
        }
        Ok(Bpe { ranks, vocab, eod: b'\n' as u32 })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "the cat sat on the mat\nthe cat ate the rat\nthe bat and the cat\n";

    #[test]
    fn roundtrip_exact() {
        let bpe = Bpe::train(SAMPLE, 300);
        let ids = bpe.encode(SAMPLE);
        // decode normalizes whitespace runs to single spaces (split_whitespace)
        let decoded = bpe.decode(&ids);
        let norm = |s: &str| {
            s.split('\n')
                .map(|l| l.split_whitespace().collect::<Vec<_>>().join(" "))
                .collect::<Vec<_>>()
                .join("\n")
        };
        assert_eq!(norm(&decoded), norm(SAMPLE));
    }

    #[test]
    fn merges_shrink_token_count() {
        let base = Bpe::train(SAMPLE, 256); // no merges
        let trained = Bpe::train(SAMPLE, 300);
        let n_base = base.encode(SAMPLE).len();
        let n_trained = trained.encode(SAMPLE).len();
        assert!(n_trained < n_base, "{n_trained} !< {n_base}");
    }

    #[test]
    fn vocab_size_respected() {
        let bpe = Bpe::train(SAMPLE, 280);
        assert!(bpe.vocab_size() <= 280);
        assert!(bpe.vocab_size() > 256); // learned something
    }

    #[test]
    fn ids_in_range() {
        let bpe = Bpe::train(SAMPLE, 300);
        let ids = bpe.encode("the cat sat where no rat sat");
        assert!(ids.iter().all(|&id| (id as usize) < bpe.vocab_size()));
    }

    #[test]
    fn save_load_roundtrip() {
        let bpe = Bpe::train(SAMPLE, 290);
        let dir = std::env::temp_dir().join(format!("sltrain-bpe-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("tok.bin");
        bpe.save(&path).unwrap();
        let loaded = Bpe::load(&path).unwrap();
        assert_eq!(bpe.encode(SAMPLE), loaded.encode(SAMPLE));
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn handles_unseen_text() {
        let bpe = Bpe::train(SAMPLE, 300);
        let ids = bpe.encode("zzz qqq unseen words");
        assert!(!ids.is_empty());
        assert!(bpe.decode(&ids).contains("unseen"));
    }
}

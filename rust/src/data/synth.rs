//! Synthetic C4 stand-in: a Zipfian–Markov word-level corpus generator.
//!
//! The paper pretrains on C4 (web text). What the reproduction needs from
//! the data is its *statistics*: a Zipfian unigram distribution, strong
//! local (bigram) structure so models can actually reduce loss, document
//! boundaries, and an unbounded no-repeat stream. We synthesize exactly
//! that: a random vocabulary of letter-words, a sparse first-order Markov
//! chain over them with Zipfian stationary behaviour, and documents of
//! geometric length separated by a delimiter. Deterministic per seed.

use crate::util::rng::{Rng, Zipf};

pub struct CorpusConfig {
    pub n_words: usize,     // distinct word types
    pub zipf_s: f64,        // unigram skew (natural text ~1.0-1.2)
    pub branch: usize,      // successors per word in the Markov chain
    pub mean_doc_len: usize, // words per document (geometric)
    pub seed: u64,
}

impl Default for CorpusConfig {
    fn default() -> Self {
        CorpusConfig { n_words: 2000, zipf_s: 1.07, branch: 24, mean_doc_len: 120, seed: 42 }
    }
}

pub struct SynthCorpus {
    words: Vec<String>,
    /// `chain[w]` = list of (successor, weight)
    chain: Vec<Vec<(usize, f64)>>,
    zipf: Zipf,
    cfg: CorpusConfig,
}

impl SynthCorpus {
    pub fn new(cfg: CorpusConfig) -> Self {
        let mut rng = Rng::new(cfg.seed).fork(0xC0);
        // vocabulary of distinct pronounceable-ish words
        let mut words = Vec::with_capacity(cfg.n_words);
        let mut seen = std::collections::HashSet::new();
        let consonants = b"bcdfghjklmnprstvwz";
        let vowels = b"aeiou";
        while words.len() < cfg.n_words {
            let syll = 1 + rng.below(3) as usize;
            let mut w = String::new();
            for _ in 0..syll {
                w.push(consonants[rng.below(consonants.len() as u64) as usize] as char);
                w.push(vowels[rng.below(vowels.len() as u64) as usize] as char);
                if rng.f64() < 0.35 {
                    w.push(consonants[rng.below(consonants.len() as u64) as usize] as char);
                }
            }
            if seen.insert(w.clone()) {
                words.push(w);
            }
        }
        // sparse Markov chain: each word has `branch` preferred successors,
        // drawn Zipf-biased so frequent words stay frequent (stationary
        // distribution inherits the skew)
        let zipf = Zipf::new(cfg.n_words, cfg.zipf_s);
        let mut chain = Vec::with_capacity(cfg.n_words);
        for _ in 0..cfg.n_words {
            let mut succ = Vec::with_capacity(cfg.branch);
            for _ in 0..cfg.branch {
                let s = zipf.sample(&mut rng);
                // quadratic decay: the first successor dominates, giving
                // the strong bigram structure real text has
                let w = 1.0 / ((1.0 + succ.len() as f64) * (1.0 + succ.len() as f64));
                succ.push((s, w));
            }
            chain.push(succ);
        }
        SynthCorpus { words, chain, zipf, cfg }
    }

    /// Stream `n_words` of text into a String (words + doc delimiters).
    pub fn generate_text(&self, n_words: usize, stream_seed: u64) -> String {
        let mut rng = Rng::new(self.cfg.seed).fork(0xD0 ^ stream_seed);
        let mut out = String::with_capacity(n_words * 6);
        let mut cur = self.zipf.sample(&mut rng);
        let mut doc_left = self.doc_len(&mut rng);
        for i in 0..n_words {
            if i > 0 {
                out.push(' ');
            }
            out.push_str(&self.words[cur]);
            doc_left -= 1;
            if doc_left == 0 {
                out.push('\n');
                cur = self.zipf.sample(&mut rng);
                doc_left = self.doc_len(&mut rng);
            } else {
                // mostly follow the chain; sometimes jump (topic drift)
                cur = if rng.f64() < 0.85 {
                    let succ = &self.chain[cur];
                    let weights: Vec<f64> = succ.iter().map(|(_, w)| *w).collect();
                    succ[rng.categorical(&weights)].0
                } else {
                    self.zipf.sample(&mut rng)
                };
            }
        }
        out
    }

    fn doc_len(&self, rng: &mut Rng) -> usize {
        // geometric with the configured mean, at least 8 words
        let p = 1.0 / self.cfg.mean_doc_len as f64;
        let mut n = 8;
        while rng.f64() > p && n < 20 * self.cfg.mean_doc_len {
            n += 1;
        }
        n
    }

    pub fn vocab_words(&self) -> &[String] {
        &self.words
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small() -> SynthCorpus {
        SynthCorpus::new(CorpusConfig { n_words: 200, ..Default::default() })
    }

    #[test]
    fn deterministic_per_seed() {
        let c1 = small().generate_text(500, 0);
        let c2 = small().generate_text(500, 0);
        assert_eq!(c1, c2);
        let c3 = small().generate_text(500, 1);
        assert_ne!(c1, c3);
    }

    #[test]
    fn unigram_distribution_is_skewed() {
        let text = small().generate_text(20_000, 0);
        let mut counts = std::collections::HashMap::new();
        for w in text.split_whitespace() {
            *counts.entry(w).or_insert(0usize) += 1;
        }
        let mut freqs: Vec<usize> = counts.values().copied().collect();
        freqs.sort_unstable_by(|a, b| b.cmp(a));
        // Zipf-ish: top word much more frequent than the 20th
        assert!(freqs[0] > 4 * freqs[19.min(freqs.len() - 1)]);
    }

    #[test]
    fn bigram_structure_exists() {
        // Markov chain ⇒ conditional entropy < unigram entropy: check that
        // the most common successor of the most common word is far above
        // its unconditional frequency.
        let text = small().generate_text(30_000, 0);
        let toks: Vec<&str> = text.split_whitespace().collect();
        let mut uni = std::collections::HashMap::new();
        for w in &toks {
            *uni.entry(*w).or_insert(0usize) += 1;
        }
        let top = *uni.iter().max_by_key(|(_, c)| **c).unwrap().0;
        let mut succ = std::collections::HashMap::new();
        let mut n_top = 0usize;
        for w in toks.windows(2) {
            if w[0] == top {
                *succ.entry(w[1]).or_insert(0usize) += 1;
                n_top += 1;
            }
        }
        let (_, best) = succ.iter().max_by_key(|(_, c)| **c).unwrap();
        let cond = *best as f64 / n_top as f64;
        let uncond_best = *uni.values().max().unwrap() as f64 / toks.len() as f64;
        assert!(
            cond > 2.0 * uncond_best,
            "cond {cond:.3} vs uncond {uncond_best:.3}"
        );
    }

    #[test]
    fn has_document_boundaries() {
        let text = small().generate_text(5000, 0);
        assert!(text.contains('\n'));
    }
}

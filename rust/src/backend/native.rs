//! NativeBackend: the pure-rust SLTrain trainer.
//!
//! A from-scratch implementation of the paper's pretraining setup on
//! `linalg::Matrix` + `linalg::sparse` — LLaMA-shaped blocks (RMSNorm,
//! rotary attention, SwiGLU), full manual forward/backward, and Adam
//! with the GaLore-repo warmup+cosine schedule, over all five weight
//! parameterizations of `python/compile/layers.py` (the paper's
//! Tables 2–4 comparison set):
//!
//!   full     y = x W
//!   lowrank  y = scale · (x B) A
//!   sltrain  y = scale · (x B) A + x S       (S fixed-support sparse)
//!   relora   y = x W0 + scale · (x B) A      (W0 frozen between merges)
//!   galore   y = x W                         (rank-r *gradient* projection)
//!
//! The two baselines differ from full/lowrank/sltrain only in how state
//! evolves, not in the forward math:
//!
//! * **ReLoRA** (Lialin et al., eq. 1) trains only `{B, A}`; `W0` is
//!   frozen and receives no gradient. Every `relora_every` steps the
//!   coordinator calls [`Backend::merge`], which folds `scale·B·A` into
//!   `W0`, re-initializes the adaptors from the merge seed and zeroes
//!   their Adam moments (codes *and* scales under 8-bit moments).
//! * **GaLore** (Zhao et al., §2) trains the full-rank `W`, but each
//!   adapted linear's Adam moments live in a rank-r projected space:
//!   the projector `P` (top-r singular subspace of the gradient, via
//!   `linalg::svd`) is refreshed every `galore_every` steps, the moment
//!   recurrence runs on `PᵀG` (or `GP`), and the bias-corrected
//!   direction is projected back before the weight update — so
//!   `mem_report()` shows optimizer state at the projected size.
//!
//! Like the paper's kernels (and unlike the densifying oracle), the hot
//! loop never materializes the dense `W = scale·BA ⊕ S` nor its
//! gradient: the sparse contribution flows through `SparseSupport::spmm`
//! / `spmm_t`, and the sparse value gradient is gathered straight off
//! the support (`scatter_grad`, eq. 2). Every `dy @ W^T`-shaped product
//! uses the transpose-hoisted `matmul_transb` path.
//!
//! **Execution model.** The step loop is multi-core: one
//! `linalg::parallel::ThreadPool` (the `--threads` flag; 0 = auto)
//! drives row-panel-parallel blocked matmuls, the per-(batch, head)
//! attention loops, and the row-partitioned sparse kernels. Every
//! parallel region runs independent tasks with fixed f32 reduction
//! order, so losses are bit-identical across runs *and* across thread
//! counts; `--threads 1` spawns nothing and is the serial engine.
//!
//! **Parameter interning.** Parameters live in an id-indexed
//! `Vec<PTensor>`; every per-linear handle (`ParamId`, `LinId`) is
//! interned once at `init_state`, so the step loop does plain vector
//! indexing — no `format!("{path}.B")` string rebuilding, no map
//! lookups. A name table is kept only for the state interchange
//! (checkpoints, parity tooling).
//!
//! **Memory model.** `train_step` is a *streaming per-layer fused
//! backward+update* (Lv et al. [36]): the backward walk applies each
//! parameter's Adam update the moment its gradient is finalized and
//! releases the buffer, so peak gradient memory is O(largest tensor)
//! instead of O(all trainable params) — the walk never reads a
//! parameter again after its gradient is complete, so at
//! `--optim-bits 32` the result is bit-identical to the two-phase
//! "accumulate everything, then `adam_apply`" loop (kept as
//! [`NativeBackend::train_step_two_phase`], the tested reference).
//! Adam moments are held in f32 or, under `--optim-bits 8`, as
//! block-wise absmax-quantized 8-bit codes (`crate::optim`), cutting
//! optimizer state ~4×; both live in checkpoints via `state_tensors`.
//! The gradient high-water is tracked (`mem::PeakTracker`) and exposed
//! through `Backend::mem_report`.
//!
//! No artifacts, no XLA, no Python: this backend is the deterministic
//! reference the AOT/PJRT path is parity-tested against, and the engine
//! behind `sltrain train --backend native`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::{Backend, StateTensor};
use crate::config::ModelPreset;
use crate::linalg::parallel::{self, par_index_ranges, resolve_threads, SendPtr, ThreadPool};
use crate::linalg::{Matrix, SparseSupport, SupportPattern};
use crate::mem::{MemReport, PeakTracker};
use crate::optim::{self, AdamHyper, Moments, OptimBits};
use crate::util::rng::Rng;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
/// Warmup cap, mirroring aot.py's default (100 steps at the default
/// 2000-step horizon); shorter runs warm up over 5% of their horizon.
const WARMUP_CAP: f32 = 100.0;
const RMS_EPS: f32 = 1e-6;
const ROPE_THETA: f32 = 10000.0;
/// GaLore's fixed update scale on projected-back directions (the
/// `gl_scale` of python/compile/optim.py and α of the GaLore repo).
const GALORE_SCALE: f32 = 0.25;
/// Default projector refresh period (aot.py's `galore_refresh`).
const GALORE_DEFAULT_EVERY: usize = 200;

// ------------------------------------------------------------- tensors

/// A named parameter: 2-d weights as `Matrix`, 1-d (norm gains, sparse
/// values) as flat vectors. Uniform flat access for Adam / checkpoints.
#[derive(Debug, Clone)]
enum PTensor {
    Mat(Matrix),
    Vec1(Vec<f32>),
}

impl PTensor {
    fn shape(&self) -> Vec<usize> {
        match self {
            PTensor::Mat(m) => vec![m.rows, m.cols],
            PTensor::Vec1(v) => vec![v.len()],
        }
    }

    fn numel(&self) -> usize {
        match self {
            PTensor::Mat(m) => m.data.len(),
            PTensor::Vec1(v) => v.len(),
        }
    }

    fn data(&self) -> &[f32] {
        match self {
            PTensor::Mat(m) => &m.data,
            PTensor::Vec1(v) => v,
        }
    }

    fn data_mut(&mut self) -> &mut [f32] {
        match self {
            PTensor::Mat(m) => &mut m.data,
            PTensor::Vec1(v) => v,
        }
    }

    fn mat(&self) -> &Matrix {
        match self {
            PTensor::Mat(m) => m,
            PTensor::Vec1(_) => panic!("tensor is 1-d, expected matrix"),
        }
    }

    fn vec(&self) -> &[f32] {
        match self {
            PTensor::Vec1(v) => v,
            PTensor::Mat(_) => panic!("tensor is 2-d, expected vector"),
        }
    }
}

// ------------------------------------------------------------- handles
//
// Interned once at init_state: the step loop addresses every parameter
// by dense index, never by name.

/// Index into the parameter store (`params` / `optim_m` / `optim_v`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ParamId(usize);

/// Index into the per-linear tables (`lins` / `lin_paths` / xb cache),
/// in `preset.linear_paths()` order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct LinId(usize);

#[derive(Debug, Clone, Copy)]
struct SparseHandle {
    vals: ParamId,
    /// Index into `supports` / `support_paths`.
    sup: usize,
}

/// The parameterization of one adapted linear.
#[derive(Debug, Clone, Copy)]
enum LinKind {
    Full { w: ParamId },
    Factored { b: ParamId, a: ParamId, sparse: Option<SparseHandle> },
    /// ReLoRA: frozen base weight + trainable adaptor pair. `w0` never
    /// receives a gradient; it changes only through `merge`.
    Relora { w0: ParamId, b: ParamId, a: ParamId },
}

/// GaLore optimizer state of one adapted full-rank weight: the rank-r
/// projector whose subspace the Adam moments live in.
///
/// `left == true` (d_in ≤ d_out): `P` is [d_in, k], gradients project
/// as `PᵀG` to [k, d_out]. Otherwise `P` is [d_out, k] and gradients
/// project as `GP` to [d_in, k] — always the cheaper side, exactly
/// `galore_targets` in python/compile/optim.py.
#[derive(Debug, Clone)]
struct GaloreProj {
    left: bool,
    k: usize,
    /// Orthonormal-column projector; refreshed from the gradient's
    /// truncated SVD, zero until the step-0 refresh.
    p: Matrix,
    /// `p` transposed, maintained by [`GaloreProj::set_p`]: the
    /// left-projection hot path multiplies by `Pᵀ` every step, so the
    /// transpose is paid once per refresh instead. Empty when `left`
    /// is false (the right side never needs it).
    pt: Matrix,
    /// False until a real frame is installed (SVD refresh or checkpoint
    /// restore). A not-ready frame is the zero matrix, which would turn
    /// every update into a silent no-op — the step loop refreshes
    /// immediately instead of waiting for the next period boundary
    /// (e.g. after a weights-only resume at an arbitrary step).
    ready: bool,
}

impl GaloreProj {
    fn new(left: bool, k: usize, pdim: usize) -> GaloreProj {
        let mut gs =
            GaloreProj { left, k, p: Matrix::zeros(0, 0), pt: Matrix::zeros(0, 0), ready: false };
        gs.clear(pdim);
        gs
    }

    /// Install a projector frame (refresh / checkpoint restore),
    /// keeping the cached transpose in sync. Readiness is derived from
    /// the frame itself: an all-zero P (a snapshot taken before the
    /// first refresh, or the SVD of a zero gradient) is NOT a live
    /// frame — treating it as one would silently zero every update
    /// until the next period boundary, so the step loop keeps
    /// re-refreshing instead (a zero-gradient Jacobi SVD converges
    /// immediately, so the degenerate re-refresh costs nothing).
    fn set_p(&mut self, p: Matrix) {
        self.pt = if self.left { p.transpose() } else { Matrix::zeros(0, 0) };
        self.ready = p.data.iter().any(|&x| x != 0.0);
        self.p = p;
    }

    /// Reset to the not-ready zero frame of `pdim` rows (init / drop).
    fn clear(&mut self, pdim: usize) {
        self.set_p(Matrix::zeros(pdim, self.k));
    }

    /// Projected-moment element count for a [rows, cols] weight.
    fn proj_numel(&self, rows: usize, cols: usize) -> usize {
        if self.left {
            self.k * cols
        } else {
            rows * self.k
        }
    }

    /// Expected projector shape for a [rows, cols] weight.
    fn proj_shape(&self, rows: usize, cols: usize) -> (usize, usize) {
        if self.left {
            (rows, self.k)
        } else {
            (cols, self.k)
        }
    }
}

#[derive(Debug, Clone, Copy)]
struct LayerHandles {
    ln1_g: ParamId,
    ln2_g: ParamId,
    q: LinId,
    k: LinId,
    v: LinId,
    o: LinId,
    gate: LinId,
    up: LinId,
    down: LinId,
}

#[derive(Debug, Clone)]
struct ModelHandles {
    embed: ParamId,
    head: ParamId,
    lnf_g: ParamId,
    layers: Vec<LayerHandles>,
}

/// Linears per layer in `linear_paths()` order (q,k,v,o,gate,up,down).
const LINS_PER_LAYER: usize = 7;

// ----------------------------------------------------- KV cache

/// Per-sequence KV cache for incremental decoding: the post-rope keys
/// and values of every already-processed position, one `[len, head_dim]`
/// matrix per (layer, head). Create with
/// [`NativeBackend::new_kv_cache`], grow it through
/// [`NativeBackend::forward_incremental`]. Rows are appended and never
/// rewritten, which is what makes the cached path bit-identical to a
/// full-sequence recompute (see `forward_incremental`).
#[derive(Debug, Clone)]
pub struct KvCache {
    /// Post-rope keys, `k[layer][head]` of shape `[len, head_dim]`.
    k: Vec<Vec<Matrix>>,
    /// Values, `v[layer][head]` of shape `[len, head_dim]`.
    v: Vec<Vec<Matrix>>,
    /// Positions processed so far (rows held per head matrix).
    len: usize,
}

impl KvCache {
    /// Number of positions already processed through this cache.
    pub fn len(&self) -> usize {
        self.len
    }

    /// True when no tokens have been processed yet (next call prefills).
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Heap bytes held by the cached keys and values.
    pub fn bytes(&self) -> usize {
        let per = |m: &Matrix| m.data.len() * std::mem::size_of::<f32>();
        self.k.iter().flatten().map(per).sum::<usize>()
            + self.v.iter().flatten().map(per).sum::<usize>()
    }
}

// ----------------------------------------------------- forward caches

struct BlockCache {
    /// Normalized pre-gain input of ln1 and its 1/rms per row.
    xhat1: Matrix,
    r1: Vec<f32>,
    /// Gained ln1 output: the input of the q/k/v linears.
    xn1: Matrix,
    /// Post-rope q and k, and v, all [n, d].
    q: Matrix,
    k: Matrix,
    v: Matrix,
    /// Attention probabilities, one [t, t] matrix per (batch, head).
    probs: Vec<Matrix>,
    /// Concatenated attention output: the input of the o linear.
    attn_cat: Matrix,
    xhat2: Matrix,
    r2: Vec<f32>,
    /// Gained ln2 output: the input of the gate/up linears.
    xn2: Matrix,
    /// Gate pre-activation and up output (SwiGLU backward).
    g_pre: Matrix,
    u: Matrix,
    /// silu(g_pre) ⊙ u: the input of the down linear.
    h: Matrix,
}

struct FwdCache {
    tokens: Vec<i32>,
    bsz: usize,
    t: usize,
    blocks: Vec<BlockCache>,
    /// x @ B per factored linear, indexed by LinId (backward reuse).
    xb: Vec<Option<Matrix>>,
    xhatf: Matrix,
    rf: Vec<f32>,
    /// Gained final-norm output: the input of the head matmul.
    xnf: Matrix,
}

/// Per-parameter gradient accumulators, indexed by ParamId (empty =
/// not yet touched).
type Grads = Vec<Vec<f32>>;

/// Where a finalized gradient goes during the backward walk.
///
/// The walk produces each parameter's gradient exactly once, in a fixed
/// order; the sink decides what happens at that moment:
///
/// * `Collect` — keep it in the returned `Grads` (gradcheck and the
///   two-phase reference path).
/// * `Fuse` — run the Adam update immediately and free the buffer (the
///   streaming fused `train_step`).
/// * `Stream` — hand the owned buffer to a callback, again immediately.
///   This is the data-parallel overlap point: `backend::sharded`
///   all-reduces layer k's gradient on the comm path while layer k-1's
///   backward still runs on the compute pool.
pub(crate) enum GradSink<'a> {
    /// Accumulate every gradient into the returned `Grads`.
    Collect,
    /// Apply the Adam update as soon as each gradient finalizes.
    Fuse(&'a AdamHyper),
    /// Hand each finalized gradient `(param id, buffer)` to a callback.
    Stream(&'a mut dyn FnMut(usize, Vec<f32>) -> Result<()>),
}

/// Move an owned gradient into its slot. Every parameter's gradient is
/// produced exactly ONCE per backward walk — the streaming fused path
/// depends on it (a second contribution after `finish_params` already
/// applied the update would be silently dropped), so a refill is a
/// loud invariant violation, not an accumulate.
fn acc_grad_vec(grads: &mut Grads, id: ParamId, g: Vec<f32>) {
    let slot = &mut grads[id.0];
    assert!(
        slot.is_empty(),
        "gradient slot {} filled twice in one backward walk (fused updates \
         require single-contribution parameters)",
        id.0
    );
    *slot = g;
}

// ------------------------------------------------------------ backend

/// The pure-rust training engine behind `--backend native`: full
/// forward/backward, Adam, and all five weight parameterizations (see
/// the module docs for the execution/memory model).
pub struct NativeBackend {
    preset: ModelPreset,
    method: String,
    batch: usize,
    lr: f32,
    total_steps: usize,
    /// The paper's alpha/r balancing factor on B@A.
    scale: f32,
    /// Adam moment precision (`--optim-bits`): f32, or block-wise 8-bit
    /// for tensors clearing `optim::Q8_MIN_NUMEL`.
    optim_bits: OptimBits,
    /// GaLore projector refresh period (steps); method galore only.
    galore_every: usize,
    /// Sparse-support pattern (`--support`): the paper's uniform-random
    /// support at the preset's delta, or SLoPe-style structured N:M.
    /// Used only by methods with a sparse factor (sltrain).
    support: SupportPattern,
    /// Interned parameter store; `ParamId` indexes all three vectors.
    params: Vec<PTensor>,
    param_names: Vec<String>,
    optim_m: Vec<Moments>,
    optim_v: Vec<Moments>,
    /// ParamId-indexed: true for parameters excluded from training
    /// (relora's `W0`). Frozen parameters carry no optimizer moments.
    frozen: Vec<bool>,
    /// ParamId-indexed GaLore projector state; `Some` exactly for the
    /// adapted linear weights when the method is galore.
    galore: Vec<Option<GaloreProj>>,
    /// Name -> id, kept only for the state interchange.
    name_to_id: BTreeMap<String, usize>,
    /// Per-linear parameter handles, `LinId`-indexed.
    lins: Vec<LinKind>,
    lin_paths: Vec<String>,
    /// Fixed sparse supports (sltrain only), `SparseHandle::sup`-indexed.
    supports: Vec<SparseSupport>,
    support_paths: Vec<String>,
    handles: Option<ModelHandles>,
    /// RoPE tables, [seq_len * head_dim/2] row-major.
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    /// Worker pool driving matmuls, attention heads, sparse kernels and
    /// the elementwise passes (Adam, rmsnorm, CE backward, embed scatter).
    pool: ThreadPool,
    /// High-water of live gradient-buffer bytes across the run.
    grad_peak: PeakTracker,
    /// True after `fold_weights`: every linear is dense, optimizer
    /// state is gone, and the engine is inference-only (Table 5).
    folded: bool,
}

impl NativeBackend {
    /// Construct an (uninitialized) engine for `preset` × `method`.
    /// `threads`, `optim_bits` and `galore_every` accept 0 = auto; call
    /// [`Backend::init_state`] before training.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        preset: ModelPreset,
        method: &str,
        batch: usize,
        lr: f32,
        total_steps: usize,
        threads: usize,
        optim_bits: usize,
        galore_every: usize,
        support: SupportPattern,
    ) -> Result<NativeBackend> {
        if let SupportPattern::StructuredNM { n, m } = support {
            if n == 0 || m == 0 || n > m || m > 256 {
                bail!("bad structured support {n}:{m} (need 1 <= n <= m <= 256)");
            }
        }
        if !crate::config::METHODS.contains(&method) {
            bail!(
                "native backend supports full | lowrank | sltrain | relora | galore \
                 (got {method:?})"
            );
        }
        if preset.d_model % preset.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", preset.d_model, preset.n_heads);
        }
        let hd = preset.d_model / preset.n_heads;
        if hd % 2 != 0 {
            bail!("head_dim {hd} must be even for rotary embeddings");
        }
        if preset.seq_len < 2 {
            bail!("seq_len {} too short for next-token training", preset.seq_len);
        }
        let half = hd / 2;
        let mut rope_cos = vec![0.0f32; preset.seq_len * half];
        let mut rope_sin = vec![0.0f32; preset.seq_len * half];
        for pos in 0..preset.seq_len {
            for j in 0..half {
                let freq = ROPE_THETA.powf(-((2 * j) as f32) / hd as f32);
                let ang = pos as f32 * freq;
                rope_cos[pos * half + j] = ang.cos();
                rope_sin[pos * half + j] = ang.sin();
            }
        }
        let scale = (preset.alpha / preset.rank as f64) as f32;
        Ok(NativeBackend {
            preset,
            method: method.to_string(),
            batch: batch.max(1),
            lr,
            total_steps: total_steps.max(1),
            scale,
            optim_bits: optim::resolve_optim_bits(optim_bits)?,
            galore_every: if galore_every == 0 { GALORE_DEFAULT_EVERY } else { galore_every },
            support,
            params: Vec::new(),
            param_names: Vec::new(),
            optim_m: Vec::new(),
            optim_v: Vec::new(),
            frozen: Vec::new(),
            galore: Vec::new(),
            name_to_id: BTreeMap::new(),
            lins: Vec::new(),
            lin_paths: Vec::new(),
            supports: Vec::new(),
            support_paths: Vec::new(),
            handles: None,
            rope_cos,
            rope_sin,
            pool: ThreadPool::new(resolve_threads(threads)),
            grad_peak: PeakTracker::default(),
            folded: false,
        })
    }

    /// Resolved worker count of the step loop's pool.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn head_dim(&self) -> usize {
        self.preset.d_model / self.preset.n_heads
    }

    fn mat(&self, id: ParamId) -> &Matrix {
        self.params[id.0].mat()
    }

    fn vec1(&self, id: ParamId) -> &[f32] {
        self.params[id.0].vec()
    }

    fn handles(&self) -> Result<&ModelHandles> {
        self.handles
            .as_ref()
            .ok_or_else(|| anyhow!("backend state not initialized (call init_state first)"))
    }

    // -------------------------------------------------------- init

    fn intern(&mut self, name: String, t: PTensor) -> ParamId {
        let id = self.params.len();
        self.name_to_id.insert(name.clone(), id);
        self.param_names.push(name);
        self.params.push(t);
        self.frozen.push(false);
        self.galore.push(None);
        ParamId(id)
    }

    /// Paper §3.3 init, mirroring python `model.init_fn` / `init_linear`:
    /// embed N(0, 0.02), head Kaiming, norm gains 1, per-linear Kaiming A
    /// (+ Kaiming B for lowrank, zero B + uniform ±1/√d_in values for
    /// sltrain), and one independent support per linear — uniform random
    /// at delta or structured N:M, per the configured `SupportPattern`.
    /// All parameter handles are interned here, once.
    fn init_params(&mut self, seed: u32) {
        let p = self.preset.clone();
        let root = Rng::new(seed as u64);
        self.params.clear();
        self.param_names.clear();
        self.name_to_id.clear();
        self.frozen.clear();
        self.galore.clear();
        self.lins.clear();
        self.lin_paths.clear();
        self.supports.clear();
        self.support_paths.clear();
        self.folded = false;

        let gauss_mat = |rng: &mut Rng, rows: usize, cols: usize, std: f32| {
            let mut m = Matrix::zeros(rows, cols);
            for x in &mut m.data {
                *x = rng.gaussian() as f32 * std;
            }
            m
        };

        let mut r_embed = root.fork(1);
        let embed = self.intern(
            "embed.w".into(),
            PTensor::Mat(gauss_mat(&mut r_embed, p.vocab, p.d_model, 0.02)),
        );
        let mut r_head = root.fork(2);
        let head_std = (2.0f32 / p.d_model as f32).sqrt();
        let head = self.intern(
            "head.w".into(),
            PTensor::Mat(gauss_mat(&mut r_head, p.d_model, p.vocab, head_std)),
        );
        let lnf_g = self.intern("lnf.g".into(), PTensor::Vec1(vec![1.0; p.d_model]));
        let mut ln1_ids = Vec::with_capacity(p.n_layers);
        let mut ln2_ids = Vec::with_capacity(p.n_layers);
        for i in 0..p.n_layers {
            let g = vec![1.0; p.d_model];
            ln1_ids.push(self.intern(format!("layers.{i}.ln1.g"), PTensor::Vec1(g.clone())));
            ln2_ids.push(self.intern(format!("layers.{i}.ln2.g"), PTensor::Vec1(g)));
        }

        for (j, (path, d_in, d_out)) in p.linear_paths().into_iter().enumerate() {
            let base = root.fork(1000 + j as u64);
            let kaiming_in = (2.0f32 / d_in as f32).sqrt();
            let kaiming_r = (2.0f32 / p.rank as f32).sqrt();
            let kind = match self.method.as_str() {
                "full" => {
                    let mut r1 = base.fork(1);
                    let w = self.intern(
                        format!("{path}.w"),
                        PTensor::Mat(gauss_mat(&mut r1, d_in, d_out, kaiming_in)),
                    );
                    LinKind::Full { w }
                }
                "galore" => {
                    // same full-rank weight; the rank-r treatment lives
                    // entirely in the optimizer (projected moments)
                    let mut r1 = base.fork(1);
                    let w = self.intern(
                        format!("{path}.w"),
                        PTensor::Mat(gauss_mat(&mut r1, d_in, d_out, kaiming_in)),
                    );
                    let k = p.rank.min(d_in).min(d_out);
                    let left = d_in <= d_out;
                    let pdim = if left { d_in } else { d_out };
                    self.galore[w.0] = Some(GaloreProj::new(left, k, pdim));
                    LinKind::Full { w }
                }
                "relora" => {
                    // W0 Kaiming (frozen), B zero, A Kaiming — merge
                    // restarts re-draw A with the same recipe
                    let mut r1 = base.fork(1);
                    let mut r3 = base.fork(3);
                    let w0 = self.intern(
                        format!("{path}.w0"),
                        PTensor::Mat(gauss_mat(&mut r3, d_in, d_out, kaiming_in)),
                    );
                    self.frozen[w0.0] = true;
                    let b = self
                        .intern(format!("{path}.B"), PTensor::Mat(Matrix::zeros(d_in, p.rank)));
                    let a = self.intern(
                        format!("{path}.A"),
                        PTensor::Mat(gauss_mat(&mut r1, p.rank, d_out, kaiming_r)),
                    );
                    LinKind::Relora { w0, b, a }
                }
                "lowrank" => {
                    // lowrank cannot start at BA = 0 (no gradient to
                    // escape); Kaiming B as in [24]
                    let mut r1 = base.fork(1);
                    let mut r2 = base.fork(2);
                    let b = self.intern(
                        format!("{path}.B"),
                        PTensor::Mat(gauss_mat(&mut r2, d_in, p.rank, kaiming_in)),
                    );
                    let a = self.intern(
                        format!("{path}.A"),
                        PTensor::Mat(gauss_mat(&mut r1, p.rank, d_out, kaiming_r)),
                    );
                    LinKind::Factored { b, a, sparse: None }
                }
                "sltrain" => {
                    let mut r1 = base.fork(1);
                    let mut r2 = base.fork(2);
                    let b = self
                        .intern(format!("{path}.B"), PTensor::Mat(Matrix::zeros(d_in, p.rank)));
                    let a = self.intern(
                        format!("{path}.A"),
                        PTensor::Mat(gauss_mat(&mut r1, p.rank, d_out, kaiming_r)),
                    );
                    let mut r_sup = base.fork(3);
                    let sup = match self.support {
                        SupportPattern::UniformRandom => {
                            SparseSupport::random(d_in, d_out, p.delta, &mut r_sup)
                        }
                        SupportPattern::StructuredNM { n, m } => {
                            SparseSupport::structured_nm(d_in, d_out, n, m, &mut r_sup)
                        }
                    };
                    let bound = 1.0f32 / (d_in as f32).sqrt();
                    let vals_data: Vec<f32> =
                        (0..sup.nnz()).map(|_| r2.range_f32(-bound, bound)).collect();
                    let vals = self.intern(format!("{path}.vals"), PTensor::Vec1(vals_data));
                    let sup_idx = self.supports.len();
                    self.supports.push(sup);
                    self.support_paths.push(path.clone());
                    LinKind::Factored { b, a, sparse: Some(SparseHandle { vals, sup: sup_idx }) }
                }
                _ => unreachable!("validated in build"),
            };
            self.lins.push(kind);
            self.lin_paths.push(path);
        }

        self.reset_full_moments();
        self.grad_peak.reset();
        let layers = (0..p.n_layers)
            .map(|l| {
                let b = l * LINS_PER_LAYER;
                LayerHandles {
                    ln1_g: ln1_ids[l],
                    ln2_g: ln2_ids[l],
                    q: LinId(b),
                    k: LinId(b + 1),
                    v: LinId(b + 2),
                    o: LinId(b + 3),
                    gate: LinId(b + 4),
                    up: LinId(b + 5),
                    down: LinId(b + 6),
                }
            })
            .collect();
        self.handles = Some(ModelHandles { embed, head, lnf_g, layers });
    }

    // ----------------------------------------------------- linears

    /// Apply the `lin` linear to x [n, d_in]. Returns (y, x@B cache).
    fn linear_fwd(&self, lin: LinId, x: &Matrix) -> (Matrix, Option<Matrix>) {
        match self.lins[lin.0] {
            LinKind::Full { w } => (x.matmul_par(self.mat(w), &self.pool), None),
            LinKind::Factored { b, a, sparse } => {
                let xb = x.matmul_par(self.mat(b), &self.pool);
                let mut y = xb.matmul_par(self.mat(a), &self.pool);
                y.scale_mut(self.scale);
                if let Some(sh) = sparse {
                    self.supports[sh.sup].spmm_add_par(x, self.vec1(sh.vals), &mut y, &self.pool);
                }
                (y, Some(xb))
            }
            LinKind::Relora { w0, b, a } => {
                let xb = x.matmul_par(self.mat(b), &self.pool);
                let mut y = xb.matmul_par(self.mat(a), &self.pool);
                y.scale_mut(self.scale);
                add_into(&mut y, &x.matmul_par(self.mat(w0), &self.pool));
                (y, Some(xb))
            }
        }
    }

    /// Backward of the `lin` linear: accumulates parameter grads into
    /// `grads` and returns dL/dx. `xt` is the transposed input (hoisted
    /// by the caller — q/k/v and gate/up share one transpose).
    fn linear_bwd(
        &self,
        lin: LinId,
        xt: &Matrix,
        x: &Matrix,
        xb: Option<&Matrix>,
        dy: &Matrix,
        grads: &mut Grads,
    ) -> Matrix {
        match self.lins[lin.0] {
            LinKind::Full { w } => {
                let dw = xt.matmul_par(dy, &self.pool);
                acc_grad_vec(grads, w, dw.data);
                dy.matmul_transb_par(self.mat(w), &self.pool)
            }
            LinKind::Factored { b, a, sparse } => {
                let xb = xb.unwrap_or_else(|| {
                    panic!("{}: missing x@B cache", self.lin_paths[lin.0])
                });
                // eq. (2): the dense d_in × d_out gradient is never formed
                let dy_at = dy.matmul_transb_par(self.mat(a), &self.pool); // [n, r]
                let mut db = xt.matmul_par(&dy_at, &self.pool);
                db.scale_mut(self.scale);
                let mut da = xb.transpose().matmul_par(dy, &self.pool);
                da.scale_mut(self.scale);
                acc_grad_vec(grads, b, db.data);
                acc_grad_vec(grads, a, da.data);
                let mut dx = dy_at.matmul_transb_par(self.mat(b), &self.pool);
                dx.scale_mut(self.scale);
                if let Some(sh) = sparse {
                    let sup = &self.supports[sh.sup];
                    let dvals = sup.scatter_grad_par(x, dy, &self.pool);
                    acc_grad_vec(grads, sh.vals, dvals);
                    sup.spmm_t_add_par(dy, self.vec1(sh.vals), &mut dx, &self.pool);
                }
                dx
            }
            LinKind::Relora { w0, b, a } => {
                // W0 is frozen: no gradient is produced for it (eq. 1
                // trains the adaptors only); it still routes dL/dx.
                let xb = xb.unwrap_or_else(|| {
                    panic!("{}: missing x@B cache", self.lin_paths[lin.0])
                });
                let dy_at = dy.matmul_transb_par(self.mat(a), &self.pool); // [n, r]
                let mut db = xt.matmul_par(&dy_at, &self.pool);
                db.scale_mut(self.scale);
                let mut da = xb.transpose().matmul_par(dy, &self.pool);
                da.scale_mut(self.scale);
                acc_grad_vec(grads, b, db.data);
                acc_grad_vec(grads, a, da.data);
                let mut dx = dy_at.matmul_transb_par(self.mat(b), &self.pool);
                dx.scale_mut(self.scale);
                add_into(&mut dx, &dy.matmul_transb_par(self.mat(w0), &self.pool));
                dx
            }
        }
    }

    // ----------------------------------------------------- forward

    /// Full cached forward over `tokens` ([bsz, t] row-major). Returns
    /// logits [bsz*t, vocab] plus everything the backward pass needs.
    fn forward_cached(&self, tokens: &[i32], bsz: usize, t: usize) -> Result<(Matrix, FwdCache)> {
        let h = self.handles()?.clone();
        let p = &self.preset;
        let (d, nh, hd) = (p.d_model, p.n_heads, self.head_dim());
        let half = hd / 2;
        let n = bsz * t;
        if tokens.len() != n {
            bail!("forward expects {bsz}x{t} tokens, got {}", tokens.len());
        }
        if t > p.seq_len {
            bail!("sequence {t} exceeds preset seq_len {}", p.seq_len);
        }

        let embed = self.mat(h.embed);
        let mut x = Matrix::zeros(n, d);
        for (i, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= p.vocab {
                bail!("token {tok} out of vocab {}", p.vocab);
            }
            x.data[i * d..(i + 1) * d].copy_from_slice(&embed.data[tok * d..(tok + 1) * d]);
        }

        let attn_scale = 1.0f32 / (hd as f32).sqrt();
        let mut blocks = Vec::with_capacity(p.n_layers);
        let mut xb_cache: Vec<Option<Matrix>> = vec![None; self.lins.len()];
        for lh in &h.layers {
            let g1 = self.vec1(lh.ln1_g);
            let (xn1, xhat1, r1) = rmsnorm_fwd(&x, g1, &self.pool);

            let (mut q, xb) = self.linear_fwd(lh.q, &xn1);
            xb_cache[lh.q.0] = xb;
            let (mut k, xb) = self.linear_fwd(lh.k, &xn1);
            xb_cache[lh.k.0] = xb;
            let (v, xb) = self.linear_fwd(lh.v, &xn1);
            xb_cache[lh.v.0] = xb;

            // one independent task per (batch, head): rope, causal
            // softmax, attn-weighted values — written back serially so
            // every output region has exactly one writer
            let heads = self.pool.map(bsz * nh, |ai| {
                let (bi, hi) = (ai / nh, ai % nh);
                let mut q_h = head_slice(&q, bi, hi, t, hd);
                let mut k_h = head_slice(&k, bi, hi, t, hd);
                let v_h = head_slice(&v, bi, hi, t, hd);
                self.rope_head(&mut q_h, half, false);
                self.rope_head(&mut k_h, half, false);
                // causal scores + row softmax
                let mut s = q_h.matmul_transb(&k_h);
                for i in 0..t {
                    let row = &mut s.data[i * t..(i + 1) * t];
                    let mut mx = f32::NEG_INFINITY;
                    for (j, val) in row.iter_mut().enumerate() {
                        if j > i {
                            *val = 0.0;
                        } else {
                            *val *= attn_scale;
                            mx = mx.max(*val);
                        }
                    }
                    let mut sum = 0.0f32;
                    for (j, val) in row.iter_mut().enumerate() {
                        if j > i {
                            *val = 0.0;
                        } else {
                            *val = (*val - mx).exp();
                            sum += *val;
                        }
                    }
                    for val in row.iter_mut() {
                        *val /= sum;
                    }
                }
                let out_h = s.matmul(&v_h);
                (q_h, k_h, s, out_h)
            });
            let mut attn_cat = Matrix::zeros(n, d);
            let mut probs = Vec::with_capacity(bsz * nh);
            for (ai, (q_h, k_h, s, out_h)) in heads.into_iter().enumerate() {
                let (bi, hi) = (ai / nh, ai % nh);
                head_write(&mut attn_cat, &out_h, bi, hi, t, hd);
                // cache post-rope q/k for the backward pass
                head_write(&mut q, &q_h, bi, hi, t, hd);
                head_write(&mut k, &k_h, bi, hi, t, hd);
                probs.push(s);
            }

            let (o_out, xb) = self.linear_fwd(lh.o, &attn_cat);
            xb_cache[lh.o.0] = xb;
            let x_mid = x.add(&o_out);

            let g2 = self.vec1(lh.ln2_g);
            let (xn2, xhat2, r2) = rmsnorm_fwd(&x_mid, g2, &self.pool);
            let (g_pre, xb) = self.linear_fwd(lh.gate, &xn2);
            xb_cache[lh.gate.0] = xb;
            let (u, xb) = self.linear_fwd(lh.up, &xn2);
            xb_cache[lh.up.0] = xb;
            let mut h_act = Matrix::zeros(n, p.d_ff);
            for i in 0..h_act.data.len() {
                let g = g_pre.data[i];
                h_act.data[i] = g * sigmoid(g) * u.data[i];
            }
            let (d_out, xb) = self.linear_fwd(lh.down, &h_act);
            xb_cache[lh.down.0] = xb;
            let x_out = x_mid.add(&d_out);

            blocks.push(BlockCache {
                xhat1,
                r1,
                xn1,
                q,
                k,
                v,
                probs,
                attn_cat,
                xhat2,
                r2,
                xn2,
                g_pre,
                u,
                h: h_act,
            });
            x = x_out;
        }

        let gf = self.vec1(h.lnf_g);
        let (xnf, xhatf, rf) = rmsnorm_fwd(&x, gf, &self.pool);
        let logits = xnf.matmul_par(self.mat(h.head), &self.pool);
        let cache =
            FwdCache { tokens: tokens.to_vec(), bsz, t, blocks, xb: xb_cache, xhatf, rf, xnf };
        Ok((logits, cache))
    }

    fn rope_head(&self, m: &mut Matrix, half: usize, inverse: bool) {
        self.rope_head_at(m, half, inverse, 0);
    }

    /// `rope_head` with the rows at absolute positions `pos0..`. The
    /// tables are indexed by absolute position, so a row decoded
    /// incrementally at position `p` receives the exact rotation the
    /// full-sequence recompute applies to row `p` — one of the
    /// invariants behind the bitwise KV-cache parity contract.
    fn rope_head_at(&self, m: &mut Matrix, half: usize, inverse: bool, pos0: usize) {
        for ti in 0..m.rows {
            let pos = pos0 + ti;
            let row = &mut m.data[ti * 2 * half..(ti + 1) * 2 * half];
            for j in 0..half {
                let c = self.rope_cos[pos * half + j];
                let s = self.rope_sin[pos * half + j];
                let (x1, x2) = (row[2 * j], row[2 * j + 1]);
                if inverse {
                    row[2 * j] = x1 * c + x2 * s;
                    row[2 * j + 1] = -x1 * s + x2 * c;
                } else {
                    row[2 * j] = x1 * c - x2 * s;
                    row[2 * j + 1] = x1 * s + x2 * c;
                }
            }
        }
    }

    // ------------------------------------------ incremental decoding

    /// True once `fold_weights` ran: dense weights only, inference-only.
    pub fn is_folded(&self) -> bool {
        self.folded
    }

    /// Fresh, empty per-sequence KV cache shaped for this model.
    pub fn new_kv_cache(&self) -> KvCache {
        let nh = self.preset.n_heads;
        let hd = self.head_dim();
        let layer = |_: usize| (0..nh).map(|_| Matrix::zeros(0, hd)).collect::<Vec<_>>();
        KvCache {
            k: (0..self.preset.n_layers).map(layer).collect(),
            v: (0..self.preset.n_layers).map(layer).collect(),
            len: 0,
        }
    }

    /// Run the next chunk of ONE sequence through the model, appending
    /// its keys/values to `cache`, and return the logits of the new
    /// rows (`[tokens.len(), vocab]`). An empty cache fed the whole
    /// prompt is the prefill; a one-token chunk is an incremental
    /// decode step. Works on factored and folded weights alike.
    ///
    /// Bitwise contract (the serving extension of the repo's
    /// determinism contract, tested in `tests/serve_parity.rs`): row
    /// `i` of the returned logits is bit-identical to row `pos0 + i`
    /// of a full-sequence recompute over the concatenated tokens, at
    /// every thread count and on either microkernel path. Every op is
    /// row-local except attention, and attention row `p` depends on
    /// rows `<= p` only through the cached post-rope k / v — which are
    /// bit-identical by induction: same per-row dot-product order
    /// (the GEBP kernel sums `l = 0..k` on every path), the same
    /// absolute-position rope, the same masked-softmax numerics, and
    /// the full path's zero-masked `j > p` tail contributes
    /// exactly-`+0.0` products that cannot flip a bit of the row sums
    /// (the softmax row always holds at least one strictly positive
    /// weight, so no partial sum is `-0.0`).
    pub fn forward_incremental(&self, tokens: &[i32], cache: &mut KvCache) -> Result<Matrix> {
        let h = self.handles()?.clone();
        let p = &self.preset;
        let (d, nh, hd) = (p.d_model, p.n_heads, self.head_dim());
        let half = hd / 2;
        let t = tokens.len();
        let pos0 = cache.len;
        if t == 0 {
            bail!("forward_incremental needs at least one token");
        }
        if cache.k.len() != p.n_layers || cache.k.first().is_some_and(|l| l.len() != nh) {
            bail!("KV cache shape does not match this model (use new_kv_cache)");
        }
        if pos0 + t > p.seq_len {
            bail!(
                "sequence length {} exceeds preset seq_len {} (rope tables and the \
                 causal mask are sized to the preset)",
                pos0 + t,
                p.seq_len
            );
        }

        let embed = self.mat(h.embed);
        let mut x = Matrix::zeros(t, d);
        for (i, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= p.vocab {
                bail!("token {tok} out of vocab {}", p.vocab);
            }
            x.data[i * d..(i + 1) * d].copy_from_slice(&embed.data[tok * d..(tok + 1) * d]);
        }

        let attn_scale = 1.0f32 / (hd as f32).sqrt();
        for (li, lh) in h.layers.iter().enumerate() {
            let (xn1, _, _) = rmsnorm_fwd(&x, self.vec1(lh.ln1_g), &self.pool);
            let (q, _) = self.linear_fwd(lh.q, &xn1);
            let (k, _) = self.linear_fwd(lh.k, &xn1);
            let (v, _) = self.linear_fwd(lh.v, &xn1);
            // rope the new rows at their absolute positions (one task
            // per head), then append to the cache serially — exactly
            // one writer per (layer, head) region
            let roped = self.pool.map(nh, |hi| {
                let mut q_h = head_slice(&q, 0, hi, t, hd);
                let mut k_h = head_slice(&k, 0, hi, t, hd);
                let v_h = head_slice(&v, 0, hi, t, hd);
                self.rope_head_at(&mut q_h, half, false, pos0);
                self.rope_head_at(&mut k_h, half, false, pos0);
                (q_h, k_h, v_h)
            });
            for (hi, (_, k_h, v_h)) in roped.iter().enumerate() {
                let kc = &mut cache.k[li][hi];
                kc.data.extend_from_slice(&k_h.data);
                kc.rows += t;
                let vc = &mut cache.v[li][hi];
                vc.data.extend_from_slice(&v_h.data);
                vc.rows += t;
            }
            // attention of the new rows against the whole cache, with
            // the training forward's exact causal-softmax numerics
            let l_total = pos0 + t;
            let heads = self.pool.map(nh, |hi| {
                let mut s = roped[hi].0.matmul_transb(&cache.k[li][hi]);
                for i in 0..t {
                    let limit = pos0 + i;
                    let row = &mut s.data[i * l_total..(i + 1) * l_total];
                    let mut mx = f32::NEG_INFINITY;
                    for (j, val) in row.iter_mut().enumerate() {
                        if j > limit {
                            *val = 0.0;
                        } else {
                            *val *= attn_scale;
                            mx = mx.max(*val);
                        }
                    }
                    let mut sum = 0.0f32;
                    for (j, val) in row.iter_mut().enumerate() {
                        if j > limit {
                            *val = 0.0;
                        } else {
                            *val = (*val - mx).exp();
                            sum += *val;
                        }
                    }
                    for val in row.iter_mut() {
                        *val /= sum;
                    }
                }
                s.matmul(&cache.v[li][hi])
            });
            let mut attn_cat = Matrix::zeros(t, d);
            for (hi, out_h) in heads.iter().enumerate() {
                head_write(&mut attn_cat, out_h, 0, hi, t, hd);
            }

            let (o_out, _) = self.linear_fwd(lh.o, &attn_cat);
            let x_mid = x.add(&o_out);
            let (xn2, _, _) = rmsnorm_fwd(&x_mid, self.vec1(lh.ln2_g), &self.pool);
            let (g_pre, _) = self.linear_fwd(lh.gate, &xn2);
            let (u, _) = self.linear_fwd(lh.up, &xn2);
            let mut h_act = Matrix::zeros(t, p.d_ff);
            for i in 0..h_act.data.len() {
                let g = g_pre.data[i];
                h_act.data[i] = g * sigmoid(g) * u.data[i];
            }
            let (d_out, _) = self.linear_fwd(lh.down, &h_act);
            x = x_mid.add(&d_out);
        }
        cache.len += t;

        let (xnf, _, _) = rmsnorm_fwd(&x, self.vec1(h.lnf_g), &self.pool);
        Ok(xnf.matmul_par(self.mat(h.head), &self.pool))
    }

    // ---------------------------------------------------- backward

    /// The backward walk. With `GradSink::Fuse` this is the *streaming
    /// per-layer fused backward+update*: as soon as a parameter's
    /// gradient is finalized, its Adam update runs (on the worker pool)
    /// and the buffer is released — peak gradient memory is O(largest
    /// tensor), and because no parameter is read again after its
    /// gradient completes, the result is bit-identical to the two-phase
    /// loop at `--optim-bits 32`. With `GradSink::Collect` the walk
    /// keeps every gradient in the returned `Grads` (gradcheck /
    /// two-phase reference); with `GradSink::Stream` each finalized
    /// gradient leaves through the callback instead (the sharded
    /// backend's all-reduce overlap).
    fn backward_impl(
        &mut self,
        cache: &FwdCache,
        dlogits: &Matrix,
        sink: &mut GradSink,
    ) -> Result<Grads> {
        let h = self.handles()?.clone();
        let (d, nh, hd) = (self.preset.d_model, self.preset.n_heads, self.head_dim());
        let (bsz, t) = (cache.bsz, cache.t);
        let attn_scale = 1.0f32 / (hd as f32).sqrt();
        let half = hd / 2;
        let mut grads: Grads = vec![Vec::new(); self.params.len()];

        // head + final norm; dL/dxnf must be formed BEFORE the fused
        // head update mutates the head weights
        let dhead = cache.xnf.transpose().matmul_par(dlogits, &self.pool);
        acc_grad_vec(&mut grads, h.head, dhead.data);
        let dxnf = dlogits.matmul_transb_par(self.mat(h.head), &self.pool);
        self.finish_params(&mut grads, &[h.head], sink)?;
        let mut dx;
        {
            let gf = self.vec1(h.lnf_g);
            let mut dgf = vec![0.0f32; d];
            dx = rmsnorm_bwd(&dxnf, &cache.xhatf, &cache.rf, gf, &mut dgf, &self.pool);
            acc_grad_vec(&mut grads, h.lnf_g, dgf);
        }
        self.finish_params(&mut grads, &[h.lnf_g], sink)?;
        drop(dxnf);

        for (l, blk) in cache.blocks.iter().enumerate().rev() {
            let lh = h.layers[l];
            // ---- mlp branch: x_out = x_mid + down(silu(gate)·up)
            let h_t = blk.h.transpose();
            let dh = self.linear_bwd(
                lh.down,
                &h_t,
                &blk.h,
                cache.xb[lh.down.0].as_ref(),
                &dx,
                &mut grads,
            );
            drop(h_t);
            self.finish_lin(&mut grads, lh.down, sink)?;
            let mut dg_pre = Matrix::zeros(dh.rows, dh.cols);
            let mut du = Matrix::zeros(dh.rows, dh.cols);
            for i in 0..dh.data.len() {
                let g = blk.g_pre.data[i];
                let s = sigmoid(g);
                du.data[i] = dh.data[i] * g * s;
                dg_pre.data[i] = dh.data[i] * blk.u.data[i] * s * (1.0 + g * (1.0 - s));
            }
            drop(dh);
            let xn2_t = blk.xn2.transpose();
            let mut dxn2 = self.linear_bwd(
                lh.gate,
                &xn2_t,
                &blk.xn2,
                cache.xb[lh.gate.0].as_ref(),
                &dg_pre,
                &mut grads,
            );
            self.finish_lin(&mut grads, lh.gate, sink)?;
            drop(dg_pre);
            add_into(
                &mut dxn2,
                &self.linear_bwd(
                    lh.up,
                    &xn2_t,
                    &blk.xn2,
                    cache.xb[lh.up.0].as_ref(),
                    &du,
                    &mut grads,
                ),
            );
            self.finish_lin(&mut grads, lh.up, sink)?;
            drop(du);
            drop(xn2_t);
            let dnorm2;
            {
                let g2 = self.vec1(lh.ln2_g);
                let mut dg2 = vec![0.0f32; d];
                dnorm2 = rmsnorm_bwd(&dxn2, &blk.xhat2, &blk.r2, g2, &mut dg2, &self.pool);
                acc_grad_vec(&mut grads, lh.ln2_g, dg2);
            }
            self.finish_params(&mut grads, &[lh.ln2_g], sink)?;
            let dx_mid = dx.add(&dnorm2);

            // ---- attention branch: x_mid = x_in + o(attn)
            let cat_t = blk.attn_cat.transpose();
            let dcat = self.linear_bwd(
                lh.o,
                &cat_t,
                &blk.attn_cat,
                cache.xb[lh.o.0].as_ref(),
                &dx_mid,
                &mut grads,
            );
            drop(cat_t);
            self.finish_lin(&mut grads, lh.o, sink)?;
            // per-(batch, head) softmax/rope backward, one task each
            let head_grads = self.pool.map(bsz * nh, |ai| {
                let (bi, hi) = (ai / nh, ai % nh);
                let dout_h = head_slice(&dcat, bi, hi, t, hd);
                let q_h = head_slice(&blk.q, bi, hi, t, hd);
                let k_h = head_slice(&blk.k, bi, hi, t, hd);
                let v_h = head_slice(&blk.v, bi, hi, t, hd);
                let probs = &blk.probs[bi * nh + hi];
                let dp = dout_h.matmul_transb(&v_h);
                let dv_h = probs.transpose().matmul(&dout_h);
                // softmax backward; masked entries have prob 0
                let mut ds = Matrix::zeros(t, t);
                for i in 0..t {
                    let prow = &probs.data[i * t..(i + 1) * t];
                    let dprow = &dp.data[i * t..(i + 1) * t];
                    let dot: f32 = prow.iter().zip(dprow).map(|(a, b)| a * b).sum();
                    for j in 0..=i {
                        ds.data[i * t + j] = prow[j] * (dprow[j] - dot);
                    }
                }
                let mut dq_h = ds.matmul(&k_h).scale(attn_scale);
                let mut dk_h = ds.transpose().matmul(&q_h).scale(attn_scale);
                self.rope_head(&mut dq_h, half, true);
                self.rope_head(&mut dk_h, half, true);
                (dq_h, dk_h, dv_h)
            });
            let mut dq = Matrix::zeros(bsz * t, d);
            let mut dk = Matrix::zeros(bsz * t, d);
            let mut dv = Matrix::zeros(bsz * t, d);
            for (ai, (dq_h, dk_h, dv_h)) in head_grads.into_iter().enumerate() {
                let (bi, hi) = (ai / nh, ai % nh);
                head_write_add(&mut dq, &dq_h, bi, hi, t, hd);
                head_write_add(&mut dk, &dk_h, bi, hi, t, hd);
                head_write_add(&mut dv, &dv_h, bi, hi, t, hd);
            }
            let xn1_t = blk.xn1.transpose();
            let mut dxn1 = self.linear_bwd(
                lh.q,
                &xn1_t,
                &blk.xn1,
                cache.xb[lh.q.0].as_ref(),
                &dq,
                &mut grads,
            );
            self.finish_lin(&mut grads, lh.q, sink)?;
            add_into(
                &mut dxn1,
                &self.linear_bwd(
                    lh.k,
                    &xn1_t,
                    &blk.xn1,
                    cache.xb[lh.k.0].as_ref(),
                    &dk,
                    &mut grads,
                ),
            );
            self.finish_lin(&mut grads, lh.k, sink)?;
            add_into(
                &mut dxn1,
                &self.linear_bwd(
                    lh.v,
                    &xn1_t,
                    &blk.xn1,
                    cache.xb[lh.v.0].as_ref(),
                    &dv,
                    &mut grads,
                ),
            );
            self.finish_lin(&mut grads, lh.v, sink)?;
            let dnorm1;
            {
                let g1 = self.vec1(lh.ln1_g);
                let mut dg1 = vec![0.0f32; d];
                dnorm1 = rmsnorm_bwd(&dxn1, &blk.xhat1, &blk.r1, g1, &mut dg1, &self.pool);
                acc_grad_vec(&mut grads, lh.ln1_g, dg1);
            }
            self.finish_params(&mut grads, &[lh.ln1_g], sink)?;
            dx = dx_mid.add(&dnorm1);
        }

        // Embedding scatter: vocab rows sharded over the pool. Every
        // task scans the token stream in ascending order and
        // accumulates only the rows of its own shard, so each embed row
        // sees the exact serial accumulation order (token collisions
        // share rows, but never shards) — bit-identical at every thread
        // count. The shards ARE the per-shard accumulators: they
        // partition the output in fixed shard order, so the "merge" is
        // the identity.
        let embed_numel = self.params[h.embed.0].numel();
        {
            let ge = &mut grads[h.embed.0];
            if ge.is_empty() {
                ge.resize(embed_numel, 0.0);
            }
            let vocab = embed_numel / d;
            let shard_rows = parallel::chunk_len_for(&self.pool, vocab);
            parallel::par_chunks_mut(&self.pool, ge, shard_rows * d, |ci, gchunk| {
                let v0 = ci * shard_rows;
                let v1 = v0 + gchunk.len() / d;
                for (i, &tok) in cache.tokens.iter().enumerate() {
                    let tok = tok as usize;
                    if tok < v0 || tok >= v1 {
                        continue;
                    }
                    let dst = &mut gchunk[(tok - v0) * d..(tok - v0 + 1) * d];
                    let src = &dx.data[i * d..(i + 1) * d];
                    for j in 0..d {
                        dst[j] += src[j];
                    }
                }
            });
        }
        self.finish_params(&mut grads, &[h.embed], sink)?;
        Ok(grads)
    }

    /// Record the live-gradient high-water, then route each finalized
    /// parameter's gradient through the sink (Adam update, stream-out,
    /// or keep for collection).
    fn finish_params(
        &mut self,
        grads: &mut Grads,
        ids: &[ParamId],
        sink: &mut GradSink,
    ) -> Result<()> {
        let live: u64 = grads.iter().map(|g| (g.len() * 4) as u64).sum();
        self.grad_peak.note(live);
        match sink {
            GradSink::Collect => {}
            GradSink::Fuse(hy) => {
                let hy = **hy;
                for &id in ids {
                    let g = std::mem::take(&mut grads[id.0]);
                    if g.is_empty() {
                        bail!("{}: fused update before gradient", self.param_names[id.0]);
                    }
                    self.apply_param_update(id.0, g, &hy)?;
                }
            }
            GradSink::Stream(f) => {
                for &id in ids {
                    let g = std::mem::take(&mut grads[id.0]);
                    if g.is_empty() {
                        bail!("{}: streamed before gradient", self.param_names[id.0]);
                    }
                    f(id.0, g)?;
                }
            }
        }
        Ok(())
    }

    /// `finish_params` over every parameter of one linear.
    fn finish_lin(&mut self, grads: &mut Grads, lin: LinId, sink: &mut GradSink) -> Result<()> {
        match self.lins[lin.0] {
            LinKind::Full { w } => self.finish_params(grads, &[w], sink),
            LinKind::Factored { b, a, sparse: None } => self.finish_params(grads, &[b, a], sink),
            LinKind::Factored { b, a, sparse: Some(sh) } => {
                self.finish_params(grads, &[b, a, sh.vals], sink)
            }
            // w0 is frozen: only the adaptors finalize
            LinKind::Relora { w0: _, b, a } => self.finish_params(grads, &[b, a], sink),
        }
    }

    // ------------------------------------------------- loss + adam

    /// One full forward + backward over a train batch: the shared body
    /// of the fused `train_step` and the collect-mode paths, so the
    /// tokenization/forward contract cannot drift between them.
    fn step_impl(&mut self, tokens: &[i32], sink: &mut GradSink) -> Result<(f64, Grads)> {
        let (inputs, targets, t_in) = split_next_token(tokens, self.batch, self.preset.seq_len)?;
        let (logits, cache) = self.forward_cached(&inputs, self.batch, t_in)?;
        let (loss, dlogits) = ce_loss_grad(&logits, &targets, &self.pool)?;
        let grads = self.backward_impl(&cache, &dlogits, sink)?;
        Ok((loss, grads))
    }

    /// Train-loss forward + backward (no update). The split from
    /// `adam_apply` keeps gradients observable for verification.
    fn loss_and_grads(&mut self, tokens: &[i32]) -> Result<(f64, Grads)> {
        self.step_impl(tokens, &mut GradSink::Collect)
    }

    fn loss_only(&self, tokens: &[i32], bsz: usize) -> Result<f64> {
        let (inputs, targets, t_in) = split_next_token(tokens, bsz, self.preset.seq_len)?;
        let (logits, _) = self.forward_cached(&inputs, bsz, t_in)?;
        ce_loss(&logits, &targets, &self.pool)
    }

    /// Linear warmup then cosine decay to 10% (optim.lr_schedule).
    fn warmup_steps(&self) -> f32 {
        (self.total_steps as f32 * 0.05).clamp(1.0, WARMUP_CAP)
    }

    fn lr_at(&self, step: i32) -> f32 {
        let s = step.max(0) as f32;
        let warmup = self.warmup_steps();
        if s < warmup {
            return self.lr * s / warmup;
        }
        let total = self.total_steps as f32;
        let prog = ((s - warmup) / (total - warmup).max(1.0)).clamp(0.0, 1.0);
        self.lr * (0.1 + 0.45 * (1.0 + (std::f32::consts::PI * prog).cos()))
    }

    /// Per-step Adam constants, computed once so the streaming fused
    /// updates and the two-phase reference use identical values.
    fn adam_hyper(&self, step: i32) -> AdamHyper {
        let t = step.max(0) as f32 + 1.0;
        AdamHyper {
            lr: self.lr_at(step),
            beta1: ADAM_B1,
            beta2: ADAM_B2,
            eps: ADAM_EPS,
            bc1: 1.0 - ADAM_B1.powf(t),
            bc2: 1.0 - ADAM_B2.powf(t),
            step,
        }
    }

    fn optim_ready(&self) -> Result<()> {
        if self.optim_m.len() != self.params.len() || self.optim_v.len() != self.params.len() {
            bail!("optimizer state dropped or uninitialized");
        }
        Ok(())
    }

    fn not_folded(&self) -> Result<()> {
        if self.folded {
            bail!("weights were folded for inference (fold_weights); this engine is forward-only");
        }
        Ok(())
    }

    /// One parameter's optimizer update (f32 or quantized moments, on
    /// the pool): plain Adam, or — for galore-projected weights — the
    /// projector refresh + projected-space Adam + project-back of
    /// `galore_param_update`. Takes the gradient by value (both callers
    /// are done with it; galore reuses the buffer as a matrix without
    /// copying). Shared by the streaming fused path and `adam_apply`,
    /// so the two are bitwise-equal by construction.
    fn apply_param_update(&mut self, idx: usize, g: Vec<f32>, hy: &AdamHyper) -> Result<()> {
        if g.len() != self.params[idx].numel() {
            bail!(
                "{}: grad numel {} != param {}",
                self.param_names[idx],
                g.len(),
                self.params[idx].numel()
            );
        }
        if self.frozen[idx] {
            bail!("{}: gradient produced for a frozen parameter", self.param_names[idx]);
        }
        if self.galore[idx].is_some() {
            return self.galore_param_update(idx, g, hy);
        }
        optim::adam_update(
            &self.pool,
            hy,
            self.params[idx].data_mut(),
            &g,
            &mut self.optim_m[idx],
            &mut self.optim_v[idx],
        );
        Ok(())
    }

    /// The GaLore step for one adapted weight (Zhao et al. §2, the exact
    /// recipe of python/compile/optim.py's `galore_update` with the
    /// subspace iteration replaced by `linalg::svd` — the paper's
    /// original torch.svd projector, available here because the native
    /// engine has a real SVD):
    ///
    /// 1. every `galore_every` steps (and at step 0) refresh `P` to the
    ///    top-k singular subspace of the gradient,
    /// 2. project the gradient (`PᵀG` or `GP`),
    /// 3. run the Adam moment recurrence *in the projected space*
    ///    (`optim::adam_direction` — f32 or block-quantized moments),
    /// 4. project the bias-corrected direction back and apply it scaled
    ///    by `GALORE_SCALE · lr`.
    ///
    /// Every stage is deterministic and thread-count-invariant: the SVD
    /// is serial f64, the matmuls honor the pool's bitwise contract, and
    /// the moment kernels partition element/block-independently.
    fn galore_param_update(&mut self, idx: usize, g: Vec<f32>, hy: &AdamHyper) -> Result<()> {
        let (rows, cols) = {
            let m = self.params[idx].mat();
            (m.rows, m.cols)
        };
        let gm = Matrix::from_vec(rows, cols, g);
        let every = self.galore_every.max(1);
        // refresh on the period, and immediately whenever no real frame
        // is installed (fresh init resuming mid-period, weights-only
        // restore) — a zero P would silently produce zero updates until
        // the next boundary
        let ready = self.galore[idx].as_ref().expect("checked by caller").ready;
        if !ready || (hy.step.max(0) as usize) % every == 0 {
            let f = crate::linalg::svd::svd(&gm);
            let gs = self.galore[idx].as_mut().expect("checked by caller");
            let k = gs.k;
            gs.set_p(if gs.left {
                // top-k left singular vectors: [rows, k]
                Matrix::from_fn(rows, k, |i, j| f.u[(i, j)])
            } else {
                // top-k right singular vectors: [cols, k]
                Matrix::from_fn(cols, k, |i, j| f.vt[(j, i)])
            });
        }
        let gs = self.galore[idx].as_ref().expect("checked by caller");
        let gp = if gs.left {
            gs.pt.matmul_par(&gm, &self.pool) // [k, cols]
        } else {
            gm.matmul_par(&gs.p, &self.pool) // [rows, k]
        };
        if self.optim_m[idx].numel() != gp.data.len() {
            bail!(
                "{}: projected moment numel {} != expected {}",
                self.param_names[idx],
                self.optim_m[idx].numel(),
                gp.data.len()
            );
        }
        let mut upd_p = Matrix::zeros(gp.rows, gp.cols);
        optim::adam_direction(
            &self.pool,
            hy,
            &gp.data,
            &mut self.optim_m[idx],
            &mut self.optim_v[idx],
            &mut upd_p.data,
        );
        let upd = if gs.left {
            gs.p.matmul_par(&upd_p, &self.pool) // [rows, cols]
        } else {
            upd_p.matmul_transb_par(&gs.p, &self.pool) // [rows, cols]
        };
        let step_scale = hy.lr * GALORE_SCALE;
        let pd = self.params[idx].data_mut();
        for (p, u) in pd.iter_mut().zip(&upd.data) {
            *p -= step_scale * u;
        }
        Ok(())
    }

    /// Reference two-phase apply: one pass over fully-accumulated
    /// `Grads` in ParamId order, consuming them. Adam is elementwise,
    /// so this lands on exactly the parameters the streaming fused walk
    /// produces — the bitwise contract `train_step_two_phase` is tested
    /// against.
    fn adam_apply(&mut self, step: i32, grads: Grads) -> Result<()> {
        self.optim_ready()?;
        let hy = self.adam_hyper(step);
        for (idx, g) in grads.into_iter().enumerate() {
            if g.is_empty() {
                continue;
            }
            self.apply_param_update(idx, g, &hy)?;
        }
        Ok(())
    }

    /// The pre-refactor step loop: full backward into a `Grads`
    /// accumulator, then one `adam_apply` pass. Kept public as the
    /// bitwise reference for the fused-vs-two-phase regression tests
    /// (`train_step` streams instead; at `--optim-bits 32` both produce
    /// identical losses and parameters).
    pub fn train_step_two_phase(&mut self, step: i32, tokens: &[i32]) -> Result<f32> {
        self.handles()?;
        self.not_folded()?;
        self.optim_ready()?;
        let (loss, grads) = self.loss_and_grads(tokens)?;
        self.adam_apply(step, grads)?;
        Ok(loss as f32)
    }

    // ---------------------------------------------- data-parallel seams
    //
    // The pub(crate) surface `backend::sharded` drives: each replica
    // runs the streaming backward with gradients exported instead of
    // applied, applies externally-reduced gradients for the parameters
    // it owns, and re-shapes its Adam moments around owner sharding.

    /// Moment sizing per parameter: frozen parameters (relora W0) carry
    /// none, galore targets carry them at the projected size — the
    /// optimizer-byte win `mem_report()` measures.
    fn moment_sizes(&self) -> Vec<usize> {
        (0..self.params.len())
            .map(|idx| {
                if self.frozen[idx] {
                    return 0;
                }
                match (&self.galore[idx], &self.params[idx]) {
                    (Some(gp), PTensor::Mat(m)) => gp.proj_numel(m.rows, m.cols),
                    _ => self.params[idx].numel(),
                }
            })
            .collect()
    }

    /// Forward + streaming backward on one microbatch block, NO
    /// optimizer update: every finalized gradient is handed to
    /// `sink(param id, grad)` in the fixed backward-walk order. Returns
    /// the block's mean next-token loss (serial f64).
    pub(crate) fn shard_loss_grads_stream(
        &mut self,
        tokens: &[i32],
        sink: &mut dyn FnMut(usize, Vec<f32>) -> Result<()>,
    ) -> Result<f64> {
        self.handles()?;
        self.not_folded()?;
        let (loss, _grads) = self.step_impl(tokens, &mut GradSink::Stream(sink))?;
        Ok(loss)
    }

    /// Held-out loss at an explicit row count (the sharded backend's
    /// worker-0 full-batch eval path; `loss_only` is bsz-parametric).
    pub(crate) fn shard_eval_loss(&self, tokens: &[i32], bsz: usize) -> Result<f64> {
        self.handles()?;
        self.loss_only(tokens, bsz)
    }

    /// Apply externally-reduced gradients (the owner's share of the
    /// step): one `apply_param_update` per `(param id, grad)` entry
    /// with the step's shared Adam constants — the exact update the
    /// single-engine fused path would have run for those parameters.
    pub(crate) fn apply_reduced_grads(
        &mut self,
        step: i32,
        grads: Vec<(usize, Vec<f32>)>,
    ) -> Result<()> {
        self.not_folded()?;
        self.optim_ready()?;
        let hy = self.adam_hyper(step);
        for (idx, g) in grads {
            self.apply_param_update(idx, g, &hy)?;
        }
        Ok(())
    }

    /// Drop the Adam moments of every trainable parameter NOT owned by
    /// this worker (`owner(p) = p mod workers`): owner-sharded replicas
    /// hold full moments only for their own parameters, the rest become
    /// zero-length — the same convention frozen parameters already use,
    /// so `optim_ready` still passes and `mem_report` sees the ~1/N
    /// optimizer bytes.
    /// No-op when the optimizer state was dropped (Table-5 inference).
    pub(crate) fn shard_moments(&mut self, worker: usize, workers: usize) {
        if self.optim_m.len() != self.params.len() {
            return;
        }
        let bits = self.optim_bits;
        for idx in 0..self.params.len() {
            if self.frozen[idx] || idx % workers == worker {
                continue;
            }
            self.optim_m[idx] = Moments::zeros(bits, 0);
            self.optim_v[idx] = Moments::zeros(bits, 0);
        }
    }

    /// Re-inflate every moment to its full (zeroed) size. The sharded
    /// checkpoint-load path calls this before `load_state_tensors` so a
    /// full flat-namespace checkpoint validates against full-size
    /// moments; the non-owned ones are re-dropped afterwards.
    pub(crate) fn reset_full_moments(&mut self) {
        let bits = self.optim_bits;
        let sizes = self.moment_sizes();
        self.optim_m = sizes.iter().map(|&n| Moments::zeros(bits, n)).collect();
        self.optim_v = sizes.iter().map(|&n| Moments::zeros(bits, n)).collect();
    }

    /// Parameter count of the interned store (0 before `init_state`).
    pub(crate) fn param_count(&self) -> usize {
        self.params.len()
    }

    /// Interchange name of parameter `idx`.
    pub(crate) fn param_name(&self, idx: usize) -> &str {
        &self.param_names[idx]
    }

    /// Flat f32 data of parameter `idx`.
    pub(crate) fn param_data(&self, idx: usize) -> &[f32] {
        self.params[idx].data()
    }

    /// True when parameter `idx` takes no updates (relora's W0).
    pub(crate) fn param_frozen(&self, idx: usize) -> bool {
        self.frozen[idx]
    }

    /// Overwrite parameter `idx` (the owner's post-update broadcast).
    pub(crate) fn set_param_data(&mut self, idx: usize, data: &[f32]) -> Result<()> {
        if self.params[idx].numel() != data.len() {
            bail!(
                "{}: set numel {} != param {}",
                self.param_names[idx],
                data.len(),
                self.params[idx].numel()
            );
        }
        self.params[idx].data_mut().copy_from_slice(data);
        Ok(())
    }
}

// ----------------------------------------------------- trait impl

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn method(&self) -> &str {
        &self.method
    }

    fn preset(&self) -> &ModelPreset {
        &self.preset
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn optimizer(&self) -> &str {
        // mirror aot.py's opt_kind naming: the galore projector wraps
        // the (possibly quantized) Adam moments
        match (self.method.as_str(), self.optim_bits) {
            ("galore", _) => "galore",
            (_, OptimBits::F32) => "adam",
            (_, OptimBits::Q8) => "adam8bit",
        }
    }

    fn n_params(&self) -> usize {
        if self.params.is_empty() {
            // not yet initialized: the config formula (verified equal to
            // the instantiated sum in tests)
            return self.preset.param_count(&self.method);
        }
        self.params.iter().map(|t| t.numel()).sum()
    }

    fn init_state(&mut self, seed: u32) -> Result<()> {
        self.init_params(seed);
        Ok(())
    }

    /// One optimizer step via the streaming per-layer fused
    /// backward+update (see `backward_impl`); bit-identical to
    /// `train_step_two_phase` at `--optim-bits 32`.
    fn train_step(&mut self, step: i32, tokens: &[i32]) -> Result<f32> {
        self.handles()?;
        self.not_folded()?;
        self.optim_ready()?;
        crate::util::failpoint::hit("native.train_step")?;
        let hy = self.adam_hyper(step);
        let (loss, _grads) = self.step_impl(tokens, &mut GradSink::Fuse(&hy))?;
        Ok(loss as f32)
    }

    fn eval_loss(&mut self, tokens: &[i32]) -> Result<f32> {
        self.handles()?;
        Ok(self.loss_only(tokens, self.batch)? as f32)
    }

    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.handles()?;
        let t = self.preset.seq_len;
        if tokens.len() % t != 0 {
            bail!("forward expects a multiple of seq_len {t} tokens");
        }
        let bsz = tokens.len() / t;
        let (logits, _) = self.forward_cached(tokens, bsz, t)?;
        Ok(logits.data)
    }

    /// The ReLoRA restart (paper eq. 1): fold `scale·B·A` into the
    /// frozen `W0`, zero `B`, re-draw `A` (Kaiming, deterministically
    /// from a root RNG re-seeded with `seed` and forked per linear
    /// exactly like init: `root.fork(1000 + j).fork(1)`), and
    /// reset the adaptors' Adam moments — under 8-bit moments that
    /// zeroes the quantized codes *and* their per-block scales
    /// (`Moments::zeros`), so no stale moment can warp the first
    /// post-merge updates. The function the model computes is unchanged
    /// up to f32 re-association: eval loss is continuous across the
    /// merge. Bit-identical at every thread count (the fold runs on the
    /// pool's bitwise-deterministic matmul).
    fn merge(&mut self, seed: i32) -> Result<()> {
        if self.method != "relora" {
            bail!(
                "merge is the relora restart hook (this backend trains {:?})",
                self.method
            );
        }
        self.handles()?;
        self.not_folded()?;
        let bits = self.optim_bits;
        let kaiming_r = (2.0f32 / self.preset.rank as f32).sqrt();
        let root = Rng::new(seed as u32 as u64);
        let lins = self.lins.clone();
        let have_moments = self.optim_m.len() == self.params.len();
        for (j, lin) in lins.into_iter().enumerate() {
            let LinKind::Relora { w0, b, a } = lin else { continue };
            // W0 <- W0 + scale * B @ A
            let ba = self.mat(b).matmul_par(self.mat(a), &self.pool);
            let scale = self.scale;
            for (w, x) in self.params[w0.0].data_mut().iter_mut().zip(&ba.data) {
                *w += scale * x;
            }
            // B <- 0; A <- fresh Kaiming from the merge seed, drawn
            // with init's exact per-linear scheme (base = root.fork(
            // 1000 + j), A from base.fork(1)) so the documented recipe
            // holds with root re-seeded from the merge seed
            self.params[b.0].data_mut().fill(0.0);
            let base = root.fork(1000 + j as u64);
            let mut r = base.fork(1);
            for x in self.params[a.0].data_mut() {
                *x = r.gaussian() as f32 * kaiming_r;
            }
            // reset the re-initialized adaptors' moments (f32 zeros, or
            // zeroed q8 codes + scales)
            if have_moments {
                for id in [b, a] {
                    let n = self.params[id.0].numel();
                    self.optim_m[id.0] = Moments::zeros(bits, n);
                    self.optim_v[id.0] = Moments::zeros(bits, n);
                }
            }
        }
        Ok(())
    }

    /// Drop ALL optimizer state — f32 moments and, under
    /// `--optim-bits 8`, the quantized code buffers *and* their
    /// per-block scales (a stale quantized moment surviving a
    /// ReLoRA-style merge would silently warp the first post-merge
    /// updates; the unified `Moments` storage makes the drop total) —
    /// plus the GaLore projectors, which are optimizer state too.
    fn drop_optimizer_state(&mut self) -> Result<()> {
        self.optim_m.clear();
        self.optim_v.clear();
        for gs in self.galore.iter_mut().flatten() {
            gs.clear(0);
        }
        Ok(())
    }

    /// Table 5's fold-for-inference, in place: every adapted linear is
    /// materialized dense (`scale·B·A ⊕ S` through the fused kernel for
    /// sltrain, `scale·B·A` for lowrank, `W0 + scale·B·A` in merge's
    /// exact accumulate order for relora, a plain copy for full/galore)
    /// and the parameter store is rebuilt full-style — `{path}.w` names,
    /// no factors, no supports, no optimizer state, no projectors. The
    /// fold runs on the pool's bitwise-deterministic matmuls, so the
    /// same state folds to bit-identical dense weights at every thread
    /// count (tested in `tests/serve_parity.rs`). Afterwards the engine
    /// is inference-only: `train_step` and `merge` refuse.
    fn fold_weights(&mut self) -> Result<()> {
        let h = self.handles()?.clone();
        if self.folded {
            return Ok(());
        }

        // 1) materialize every linear's effective dense weight from the
        //    live factors, before any store is touched
        let mut dense: Vec<Matrix> = Vec::with_capacity(self.lins.len());
        for lin in &self.lins {
            let w = match *lin {
                LinKind::Full { w } => self.mat(w).clone(),
                LinKind::Factored { b, a, sparse: None } => {
                    let mut w = self.mat(b).matmul_par(self.mat(a), &self.pool);
                    w.scale_mut(self.scale);
                    w
                }
                LinKind::Factored { b, a, sparse: Some(sh) } => self.supports[sh.sup]
                    .fused_effective_par(
                        self.mat(b),
                        self.mat(a),
                        self.vec1(sh.vals),
                        self.scale,
                        &self.pool,
                    ),
                LinKind::Relora { w0, b, a } => {
                    // merge's fold without the restart: same elementwise
                    // accumulate order, so the folded weight is
                    // bit-identical to what merge would have produced
                    let ba = self.mat(b).matmul_par(self.mat(a), &self.pool);
                    let mut w = self.mat(w0).clone();
                    for (wi, x) in w.data.iter_mut().zip(&ba.data) {
                        *wi += self.scale * x;
                    }
                    w
                }
            };
            dense.push(w);
        }

        // 2) snapshot the tensors that survive the rebuild as-is
        let embed_t = self.params[h.embed.0].clone();
        let head_t = self.params[h.head.0].clone();
        let lnf_t = self.params[h.lnf_g.0].clone();
        let ln_ts: Vec<(PTensor, PTensor)> = h
            .layers
            .iter()
            .map(|lh| (self.params[lh.ln1_g.0].clone(), self.params[lh.ln2_g.0].clone()))
            .collect();
        let lin_paths = std::mem::take(&mut self.lin_paths);

        // 3) rebuild the store dense-only, in init's intern order
        self.params.clear();
        self.param_names.clear();
        self.name_to_id.clear();
        self.frozen.clear();
        self.galore.clear();
        self.lins.clear();
        self.supports.clear();
        self.support_paths.clear();
        self.optim_m.clear();
        self.optim_v.clear();
        self.grad_peak.reset();

        let embed = self.intern("embed.w".into(), embed_t);
        let head = self.intern("head.w".into(), head_t);
        let lnf_g = self.intern("lnf.g".into(), lnf_t);
        let mut ln1_ids = Vec::with_capacity(h.layers.len());
        let mut ln2_ids = Vec::with_capacity(h.layers.len());
        for (i, (g1, g2)) in ln_ts.into_iter().enumerate() {
            ln1_ids.push(self.intern(format!("layers.{i}.ln1.g"), g1));
            ln2_ids.push(self.intern(format!("layers.{i}.ln2.g"), g2));
        }
        for (path, w) in lin_paths.iter().zip(dense) {
            let id = self.intern(format!("{path}.w"), PTensor::Mat(w));
            self.lins.push(LinKind::Full { w: id });
        }
        self.lin_paths = lin_paths;

        let layers = (0..h.layers.len())
            .map(|l| {
                let b = l * LINS_PER_LAYER;
                LayerHandles {
                    ln1_g: ln1_ids[l],
                    ln2_g: ln2_ids[l],
                    q: LinId(b),
                    k: LinId(b + 1),
                    v: LinId(b + 2),
                    o: LinId(b + 3),
                    gate: LinId(b + 4),
                    up: LinId(b + 5),
                    down: LinId(b + 6),
                }
            })
            .collect();
        self.handles = Some(ModelHandles { embed, head, lnf_g, layers });
        self.folded = true;
        Ok(())
    }

    fn mem_report(&self) -> Option<MemReport> {
        let param_bytes: u64 = self.params.iter().map(|t| (t.numel() * 4) as u64).sum();
        let optim_bytes: u64 =
            self.optim_m.iter().chain(&self.optim_v).map(|m| m.bytes()).sum();
        // actually-held frame bytes: P plus the cached Pᵀ of the
        // left-projection hot path
        let proj_bytes: u64 = self
            .galore
            .iter()
            .flatten()
            .map(|gs| ((gs.p.data.len() + gs.pt.data.len()) * 4) as u64)
            .sum();
        let support_bytes: u64 = self.supports.iter().map(|s| s.bytes()).sum();
        // a two-phase loop holds one f32 gradient per *trainable*
        // parameter at its peak (relora's frozen W0 never has one)
        let grad_all_bytes: u64 = self
            .params
            .iter()
            .zip(&self.frozen)
            .filter(|(_, &fz)| !fz)
            .map(|(t, _)| (t.numel() * 4) as u64)
            .sum();
        Some(MemReport {
            param_bytes,
            optim_bytes,
            proj_bytes,
            support_bytes,
            grad_peak_bytes: self.grad_peak.peak_bytes(),
            grad_all_bytes,
            optim_bits: self.optim_bits.bits() as u32,
            workers: 1,
        })
    }

    fn state_tensors(&self) -> Result<Vec<StateTensor>> {
        self.handles()?;
        let mut out = Vec::with_capacity(self.params.len() + self.supports.len());
        // name order (the interchange contract of the old map layout)
        for (name, &id) in &self.name_to_id {
            let t = &self.params[id];
            out.push(StateTensor::f32(name, t.shape(), t.data()));
        }
        let mut sups: Vec<(&String, &SparseSupport)> =
            self.support_paths.iter().zip(&self.supports).collect();
        sups.sort_by(|a, b| a.0.cmp(b.0));
        for (path, sup) in sups {
            let idx: Vec<i32> = sup.idx.iter().map(|&i| i as i32).collect();
            out.push(StateTensor::i32(&format!("{path}.idx"), vec![sup.nnz()], &idx));
        }
        // Optimizer moments (resume + the quantized-state round-trip):
        // f32 moments as `optim.{m,v}.<param>`; quantized moments as raw
        // I8 codes `optim.{m,v}.q8.<param>` plus f32 per-block scales
        // `optim.{m,v}.scale.<param>` — all bit-exact payloads. Frozen
        // parameters (relora W0) carry no moments; galore-projected
        // parameters carry projected-size moments plus their projector
        // as `optim.proj.<param>` (resumed moments are meaningless in a
        // different subspace, so the frame rides along). Dropped state
        // (Table-5 inference) is simply absent.
        if self.optim_m.len() == self.params.len() && self.optim_v.len() == self.params.len() {
            for (name, &id) in &self.name_to_id {
                if self.frozen[id] {
                    continue;
                }
                if let Some(gs) = &self.galore[id] {
                    out.push(StateTensor::f32(
                        &format!("optim.proj.{name}"),
                        vec![gs.p.rows, gs.p.cols],
                        &gs.p.data,
                    ));
                }
                for (tag, mom) in [("m", &self.optim_m[id]), ("v", &self.optim_v[id])] {
                    match mom {
                        Moments::F32(data) => out.push(StateTensor::f32(
                            &format!("optim.{tag}.{name}"),
                            vec![data.len()],
                            data,
                        )),
                        Moments::Q8 { codes, scales } => {
                            out.push(StateTensor::i8(
                                &format!("optim.{tag}.q8.{name}"),
                                vec![codes.len()],
                                codes,
                            ));
                            out.push(StateTensor::f32(
                                &format!("optim.{tag}.scale.{name}"),
                                vec![scales.len()],
                                scales,
                            ));
                        }
                    }
                }
            }
        }
        Ok(out)
    }

    fn load_state_tensors(&mut self, tensors: &[StateTensor]) -> Result<()> {
        self.handles()?;
        crate::util::failpoint::hit("native.load_state_tensors")?;
        // Stage and validate everything BEFORE mutating, so a mismatched
        // or corrupt checkpoint leaves the backend untouched (and support
        // indices never reach SparseSupport::new's panicking asserts).
        enum MomentPart {
            Full(Vec<f32>),
            Codes(Vec<i8>),
            Scales(Vec<f32>),
        }
        let mut staged_supports: Vec<(usize, SparseSupport)> = Vec::new();
        let mut staged_params: Vec<(usize, Vec<f32>)> = Vec::new();
        // (param id, is_v, payload)
        let mut staged_moments: Vec<(usize, bool, MomentPart)> = Vec::new();
        // (param id, projector) — galore subspace frames
        let mut staged_projs: Vec<(usize, Matrix)> = Vec::new();
        // Pre-scan: a checkpoint written under the other --optim-bits
        // setting is still good for weights/supports, so when ANY of its
        // moment tensors disagrees with this backend's representation,
        // the whole moment family is skipped (weights-only load, logged)
        // instead of bricking every prior checkpoint on a precision
        // switch. The same fallback applies when this backend dropped
        // its optimizer state (`drop_optimizer_state`): a full training
        // checkpoint then restores weights/supports only, identical to
        // a fresh weights-only load — there is no moment storage to
        // validate against, let alone restore into. Within a compatible
        // family, partial/mixed sets still error (the pairing and
        // all-or-nothing checks below).
        let dropped = self.optim_m.len() != self.params.len();
        let mut has_moments = false;
        let mut moments_compatible = true;
        for st in tensors {
            let Some(rest) = st.name.strip_prefix("optim.") else { continue };
            if rest.starts_with("proj.") {
                // projectors are f32 under either --optim-bits
                continue;
            }
            let rest = rest
                .strip_prefix("m.")
                .or_else(|| rest.strip_prefix("v."))
                .unwrap_or(rest);
            has_moments = true;
            if dropped {
                // no representation to compare against; the moment
                // family is skipped wholesale below
                continue;
            }
            let (pname, wants_q8) = if let Some(p) = rest.strip_prefix("q8.") {
                (p, true)
            } else if let Some(p) = rest.strip_prefix("scale.") {
                (p, true)
            } else {
                (rest, false)
            };
            if let Some(&id) = self.name_to_id.get(pname) {
                if self.optim_m[id].is_quantized() != wants_q8 {
                    moments_compatible = false;
                }
            }
        }
        let skip_moments = has_moments && (dropped || !moments_compatible);
        if skip_moments && dropped {
            crate::info!(
                "optimizer state was dropped on this backend; restoring the checkpoint's \
                 weights/supports only"
            );
        } else if skip_moments {
            crate::info!(
                "checkpoint optimizer moments use a different --optim-bits than this \
                 backend ({}); restoring weights/supports (and galore projectors) only",
                self.optim_bits.bits()
            );
        }
        for st in tensors {
            if skip_moments
                && st.name.starts_with("optim.")
                && (dropped || !st.name.starts_with("optim.proj."))
            {
                // the projector frame is f32 under either --optim-bits:
                // keep it through a weights-only fallback, or the
                // restored backend would run zero-update steps until
                // its next refresh boundary. When the optimizer state
                // was dropped outright, the projector goes with it —
                // the drop is total.
                continue;
            }
            if let Some(rest) = st.name.strip_prefix("optim.") {
                if let Some(pname) = rest.strip_prefix("proj.") {
                    let &id = self
                        .name_to_id
                        .get(pname)
                        .ok_or_else(|| anyhow!("{}: unknown parameter for projector", st.name))?;
                    let Some(gs) = &self.galore[id] else {
                        bail!("{}: not a galore-projected parameter", st.name);
                    };
                    let (rows, cols) = {
                        let m = self.params[id].mat();
                        (m.rows, m.cols)
                    };
                    let want = gs.proj_shape(rows, cols);
                    if st.shape != [want.0, want.1] {
                        bail!(
                            "{}: projector shape {:?} != expected [{}, {}]",
                            st.name,
                            st.shape,
                            want.0,
                            want.1
                        );
                    }
                    let data = st.to_f32()?;
                    staged_projs.push((id, Matrix::from_vec(want.0, want.1, data)));
                    continue;
                }
                let (is_v, rest) = if let Some(r) = rest.strip_prefix("m.") {
                    (false, r)
                } else if let Some(r) = rest.strip_prefix("v.") {
                    (true, r)
                } else {
                    bail!("unknown optimizer tensor {:?}", st.name);
                };
                if self.optim_m.len() != self.params.len() {
                    bail!(
                        "{}: cannot restore optimizer moments into dropped state \
                         (call init_state first)",
                        st.name
                    );
                }
                let lookup = |pname: &str| -> Result<usize> {
                    self.name_to_id
                        .get(pname)
                        .copied()
                        .ok_or_else(|| anyhow!("{}: unknown parameter for moment", st.name))
                };
                let current = |id: usize| if is_v { &self.optim_v[id] } else { &self.optim_m[id] };
                let bits_mismatch = || {
                    anyhow!(
                        "{}: checkpoint moment precision does not match this backend's \
                         --optim-bits {} (re-run with matching optimizer bits)",
                        st.name,
                        self.optim_bits.bits()
                    )
                };
                if let Some(pname) = rest.strip_prefix("q8.") {
                    let id = lookup(pname)?;
                    let codes = st.to_i8()?;
                    match current(id) {
                        Moments::Q8 { codes: cur, .. } if cur.len() == codes.len() => {}
                        Moments::Q8 { codes: cur, .. } => bail!(
                            "{}: codes numel {} != expected {}",
                            st.name,
                            codes.len(),
                            cur.len()
                        ),
                        Moments::F32(_) => return Err(bits_mismatch()),
                    }
                    staged_moments.push((id, is_v, MomentPart::Codes(codes)));
                } else if let Some(pname) = rest.strip_prefix("scale.") {
                    let id = lookup(pname)?;
                    let scales = st.to_f32()?;
                    match current(id) {
                        Moments::Q8 { scales: cur, .. } if cur.len() == scales.len() => {}
                        Moments::Q8 { scales: cur, .. } => bail!(
                            "{}: scale count {} != expected {}",
                            st.name,
                            scales.len(),
                            cur.len()
                        ),
                        Moments::F32(_) => return Err(bits_mismatch()),
                    }
                    staged_moments.push((id, is_v, MomentPart::Scales(scales)));
                } else {
                    let id = lookup(rest)?;
                    let data = st.to_f32()?;
                    match current(id) {
                        Moments::F32(cur) if cur.len() == data.len() => {}
                        Moments::F32(cur) => bail!(
                            "{}: moment numel {} != expected {}",
                            st.name,
                            data.len(),
                            cur.len()
                        ),
                        Moments::Q8 { .. } => return Err(bits_mismatch()),
                    }
                    staged_moments.push((id, is_v, MomentPart::Full(data)));
                }
                continue;
            }
            if let Some(path) = st.name.strip_suffix(".idx") {
                let si = self
                    .support_paths
                    .iter()
                    .position(|p| p == path)
                    .ok_or_else(|| anyhow!("unknown support {:?}", st.name))?;
                let sup = &self.supports[si];
                let idx: Vec<u32> = st.to_i32()?.iter().map(|&i| i as u32).collect();
                let bound = (sup.d_in * sup.d_out) as u32;
                if !idx.windows(2).all(|w| w[0] < w[1]) {
                    bail!("{}: support not sorted-distinct", st.name);
                }
                if idx.iter().any(|&i| i >= bound) {
                    bail!("{}: support index out of range {bound}", st.name);
                }
                let mut reloaded = SparseSupport::new(sup.d_in, sup.d_out, idx);
                // checkpoints carry only the flat interchange indices;
                // re-attach the structured fast-path layout when the
                // reloaded support still conforms (falls back to the
                // generic CSR kernels — identical results — otherwise)
                if let SupportPattern::StructuredNM { n, m } = self.support {
                    reloaded.structure_as_nm(n, m);
                }
                staged_supports.push((si, reloaded));
            } else {
                let data = st.to_f32()?;
                let &id = self
                    .name_to_id
                    .get(&st.name)
                    .ok_or_else(|| anyhow!("unknown tensor {:?}", st.name))?;
                if self.params[id].numel() != data.len() {
                    bail!(
                        "{}: numel {} != expected {}",
                        st.name,
                        data.len(),
                        self.params[id].numel()
                    );
                }
                staged_params.push((id, data));
            }
        }
        // cross-check: quantized moment parts must arrive in pairs — new
        // codes with stale scales (or vice versa) would silently corrupt
        // the moment they decode to
        for (id, is_v, part) in &staged_moments {
            let want_other = |other: &MomentPart| match part {
                MomentPart::Codes(_) => matches!(other, MomentPart::Scales(_)),
                MomentPart::Scales(_) => matches!(other, MomentPart::Codes(_)),
                MomentPart::Full(_) => true,
            };
            let paired = matches!(part, MomentPart::Full(_))
                || staged_moments
                    .iter()
                    .any(|(oid, ov, op)| oid == id && ov == is_v && want_other(op));
            if !paired {
                bail!(
                    "optim.{}.{}: quantized moment codes and per-block scales must \
                     round-trip together (one half is missing from the checkpoint)",
                    if *is_v { "v" } else { "m" },
                    self.param_names[*id]
                );
            }
        }
        // cross-check: a moment restore must be all-or-nothing — a
        // checkpoint carrying SOME moments but missing others (a
        // truncated v set, a subset of parameters) would silently mix
        // restored and stale Adam state and diverge from the saved run
        if !staged_moments.is_empty() {
            for id in 0..self.params.len() {
                if self.frozen[id] {
                    // frozen parameters (relora W0) carry no moments
                    continue;
                }
                for is_v in [false, true] {
                    let covered =
                        staged_moments.iter().any(|(oid, ov, _)| *oid == id && *ov == is_v);
                    if !covered {
                        bail!(
                            "optim.{}.{}: checkpoint restores optimizer moments but this \
                             one is missing — moment restores must be complete",
                            if is_v { "v" } else { "m" },
                            self.param_names[id]
                        );
                    }
                }
                // galore moments are coordinates in the projector's
                // subspace: restoring them without their frame would
                // silently continue in the wrong basis
                if self.galore[id].is_some()
                    && !staged_projs.iter().any(|(pid, _)| *pid == id)
                {
                    bail!(
                        "optim.proj.{}: galore moments restored without their \
                         projector — the subspace frame must round-trip with them",
                        self.param_names[id]
                    );
                }
            }
        }
        // cross-check: each reloaded support must agree with the values
        // tensor that will accompany it (staged if present, current else)
        for (si, sup) in &staged_supports {
            let vals_name = format!("{}.vals", self.support_paths[*si]);
            let vals_id = self.name_to_id.get(&vals_name).copied().ok_or_else(|| {
                anyhow!("{}: support without values tensor", self.support_paths[*si])
            })?;
            let vals_len = staged_params
                .iter()
                .find(|(id, _)| *id == vals_id)
                .map(|(_, d)| d.len())
                .unwrap_or_else(|| self.params[vals_id].numel());
            if vals_len != sup.nnz() {
                bail!(
                    "{}: support nnz {} != values len {vals_len}",
                    self.support_paths[*si],
                    sup.nnz()
                );
            }
        }
        for (si, sup) in staged_supports {
            self.supports[si] = sup;
        }
        for (id, data) in staged_params {
            self.params[id].data_mut().copy_from_slice(&data);
        }
        for (id, p) in staged_projs {
            self.galore[id].as_mut().expect("validated during staging").set_p(p);
        }
        for (id, is_v, part) in staged_moments {
            let mom = if is_v { &mut self.optim_v[id] } else { &mut self.optim_m[id] };
            match (mom, part) {
                (Moments::F32(cur), MomentPart::Full(data)) => *cur = data,
                (Moments::Q8 { codes, .. }, MomentPart::Codes(data)) => *codes = data,
                (Moments::Q8 { scales, .. }, MomentPart::Scales(data)) => *scales = data,
                _ => unreachable!("moment representation validated during staging"),
            }
        }
        Ok(())
    }
}

// ------------------------------------------------------- math helpers

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Row-wise RMSNorm with gain: returns (x̂·g, x̂, 1/rms per row). Rows
/// are independent, partitioned over the pool; each row's mean-square
/// reduction stays inside one task in ascending-j order, so results are
/// bit-identical to the serial loop at every thread count.
fn rmsnorm_fwd(x: &Matrix, g: &[f32], pool: &ThreadPool) -> (Matrix, Matrix, Vec<f32>) {
    let d = x.cols;
    assert_eq!(g.len(), d);
    let mut y = Matrix::zeros(x.rows, d);
    let mut xhat = Matrix::zeros(x.rows, d);
    let mut inv_rms = vec![0.0f32; x.rows];
    let yp = SendPtr::new(y.data.as_mut_ptr());
    let xp = SendPtr::new(xhat.data.as_mut_ptr());
    let rp = SendPtr::new(inv_rms.as_mut_ptr());
    par_index_ranges(pool, x.rows, 1, |rows| {
        for i in rows {
            let row = &x.data[i * d..(i + 1) * d];
            let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
            let r = 1.0 / (ms + RMS_EPS).sqrt();
            // SAFETY: row i is written by exactly one task; the borrows
            // outlive the pool run.
            unsafe {
                *rp.get().add(i) = r;
                let yr = std::slice::from_raw_parts_mut(yp.get().add(i * d), d);
                let xr = std::slice::from_raw_parts_mut(xp.get().add(i * d), d);
                for j in 0..d {
                    let xh = row[j] * r;
                    xr[j] = xh;
                    yr[j] = xh * g[j];
                }
            }
        }
    });
    (y, xhat, inv_rms)
}

/// RMSNorm backward: dx = r·(dx̂ − x̂·mean(dx̂⊙x̂)), dg += Σ_rows dy⊙x̂.
/// Two pool passes, both bit-identical to the serial loop at every
/// thread count: dx rows are independent (each row's `dot` reduction
/// stays inside one task, ascending j), and dg is partitioned by
/// *columns* — every `dg[j]` accumulates over rows in ascending order,
/// exactly the per-column order of the serial loop, with no reduction
/// crossing a task boundary.
fn rmsnorm_bwd(
    dy: &Matrix,
    xhat: &Matrix,
    inv_rms: &[f32],
    g: &[f32],
    dg: &mut [f32],
    pool: &ThreadPool,
) -> Matrix {
    let d = dy.cols;
    let mut dx = Matrix::zeros(dy.rows, d);
    let dxp = SendPtr::new(dx.data.as_mut_ptr());
    par_index_ranges(pool, dy.rows, 1, |rows| {
        for i in rows {
            let dyr = &dy.data[i * d..(i + 1) * d];
            let xhr = &xhat.data[i * d..(i + 1) * d];
            let mut dot = 0.0f32;
            for j in 0..d {
                dot += dyr[j] * g[j] * xhr[j];
            }
            dot /= d as f32;
            let r = inv_rms[i];
            // SAFETY: row i is written by exactly one task.
            let dxr = unsafe { std::slice::from_raw_parts_mut(dxp.get().add(i * d), d) };
            for j in 0..d {
                dxr[j] = r * (dyr[j] * g[j] - xhr[j] * dot);
            }
        }
    });
    let chunk = parallel::chunk_len_for(pool, d);
    parallel::par_chunks_mut(pool, dg, chunk, |ci, dgc| {
        let j0 = ci * chunk;
        for i in 0..dy.rows {
            let dyr = &dy.data[i * d..(i + 1) * d];
            let xhr = &xhat.data[i * d..(i + 1) * d];
            for (jj, dgj) in dgc.iter_mut().enumerate() {
                let j = j0 + jj;
                *dgj += dyr[j] * xhr[j];
            }
        }
    });
    dx
}

/// Copy head `h` of batch row-block `bi` out of an [bsz*t, n_heads*hd]
/// matrix into a contiguous [t, hd] one.
fn head_slice(x: &Matrix, bi: usize, h: usize, t: usize, hd: usize) -> Matrix {
    let d = x.cols;
    let mut out = Matrix::zeros(t, hd);
    for ti in 0..t {
        let src = &x.data[(bi * t + ti) * d + h * hd..(bi * t + ti) * d + (h + 1) * hd];
        out.data[ti * hd..(ti + 1) * hd].copy_from_slice(src);
    }
    out
}

fn head_write(dst: &mut Matrix, src: &Matrix, bi: usize, h: usize, t: usize, hd: usize) {
    let d = dst.cols;
    for ti in 0..t {
        let s = &src.data[ti * hd..(ti + 1) * hd];
        dst.data[(bi * t + ti) * d + h * hd..(bi * t + ti) * d + (h + 1) * hd]
            .copy_from_slice(s);
    }
}

fn head_write_add(dst: &mut Matrix, src: &Matrix, bi: usize, h: usize, t: usize, hd: usize) {
    let d = dst.cols;
    for ti in 0..t {
        for j in 0..hd {
            dst.data[(bi * t + ti) * d + h * hd + j] += src.data[ti * hd + j];
        }
    }
}

fn add_into(dst: &mut Matrix, src: &Matrix) {
    assert_eq!(dst.data.len(), src.data.len());
    for (a, b) in dst.data.iter_mut().zip(&src.data) {
        *a += b;
    }
}

/// Next-token split of a [bsz, seq] batch: inputs drop the last column,
/// targets drop the first. Returns (inputs, targets, seq-1).
fn split_next_token(tokens: &[i32], bsz: usize, seq: usize) -> Result<(Vec<i32>, Vec<i32>, usize)> {
    if tokens.len() != bsz * seq {
        bail!("expected {bsz}x{seq} tokens, got {}", tokens.len());
    }
    let t_in = seq - 1;
    let mut inputs = Vec::with_capacity(bsz * t_in);
    let mut targets = Vec::with_capacity(bsz * t_in);
    for b in 0..bsz {
        let row = &tokens[b * seq..(b + 1) * seq];
        inputs.extend_from_slice(&row[..t_in]);
        targets.extend_from_slice(&row[1..]);
    }
    Ok((inputs, targets, t_in))
}

/// Targets must be one per logit row and inside the vocab — validated
/// up front because the parallel CE passes cannot bail mid-task.
fn validate_targets(targets: &[i32], n: usize, v: usize) -> Result<()> {
    if targets.len() != n {
        bail!("{n} logit rows but {} targets", targets.len());
    }
    for &t in targets {
        if t as usize >= v {
            bail!("target {t} out of vocab {v}");
        }
    }
    Ok(())
}

/// One row of the log-sum-exp cross-entropy: returns the row loss and,
/// when `dlr` is given, writes dL/dlogits = (softmax − onehot)·inv_n
/// into it. The single copy of the numerics keeps train loss
/// (`ce_loss_grad`) and eval loss (`ce_loss`) bit-identical by
/// construction.
#[inline]
fn ce_row(row: &[f32], tgt: usize, inv_n: f32, dlr: Option<&mut [f32]>) -> f64 {
    let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
    let sum: f64 = row.iter().map(|&x| ((x - mx) as f64).exp()).sum();
    if let Some(dlr) = dlr {
        for j in 0..row.len() {
            let p = (((row[j] - mx) as f64).exp() / sum) as f32;
            dlr[j] = p * inv_n;
        }
        dlr[tgt] -= inv_n;
    }
    mx as f64 + sum.ln() - row[tgt] as f64
}

/// Mean next-token cross-entropy (f64 accumulation for stability).
/// Row softmaxes run on the pool; the cross-row f64 sum is taken
/// serially in ascending row order afterwards, so the result is
/// bit-identical to the serial loop at every thread count.
fn ce_loss(logits: &Matrix, targets: &[i32], pool: &ThreadPool) -> Result<f64> {
    let (n, v) = (logits.rows, logits.cols);
    validate_targets(targets, n, v)?;
    let mut row_loss = vec![0.0f64; n];
    let rp = SendPtr::new(row_loss.as_mut_ptr());
    par_index_ranges(pool, n, 1, |rows| {
        for i in rows {
            let row = &logits.data[i * v..(i + 1) * v];
            // SAFETY: slot i is written by exactly one task.
            unsafe {
                *rp.get().add(i) = ce_row(row, targets[i] as usize, 0.0, None);
            }
        }
    });
    let mut total = 0.0f64;
    for l in &row_loss {
        total += l;
    }
    Ok(total / n as f64)
}

/// CE loss plus dL/dlogits = (softmax − onehot)/n. Rows on the pool,
/// f64 loss summed serially in row order (bit-identical to the serial
/// loop at every thread count).
fn ce_loss_grad(logits: &Matrix, targets: &[i32], pool: &ThreadPool) -> Result<(f64, Matrix)> {
    let (n, v) = (logits.rows, logits.cols);
    validate_targets(targets, n, v)?;
    let mut dl = Matrix::zeros(n, v);
    let inv_n = 1.0f32 / n as f32;
    let mut row_loss = vec![0.0f64; n];
    let dlp = SendPtr::new(dl.data.as_mut_ptr());
    let rp = SendPtr::new(row_loss.as_mut_ptr());
    par_index_ranges(pool, n, 1, |rows| {
        for i in rows {
            let row = &logits.data[i * v..(i + 1) * v];
            // SAFETY: row i and slot i are written by exactly one task.
            unsafe {
                let dlr = std::slice::from_raw_parts_mut(dlp.get().add(i * v), v);
                *rp.get().add(i) = ce_row(row, targets[i] as usize, inv_n, Some(dlr));
            }
        }
    });
    let mut total = 0.0f64;
    for l in &row_loss {
        total += l;
    }
    Ok((total / n as f64, dl))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro_preset() -> ModelPreset {
        ModelPreset {
            name: "micro".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            seq_len: 12,
            rank: 4,
            delta: 0.05,
            alpha: 8.0,
            d_ff: 32,
        }
    }

    /// Short projector period so micro/tiny runs cross refresh
    /// boundaries within a handful of steps.
    const TEST_GALORE_EVERY: usize = 3;

    fn micro_backend_support(
        method: &str,
        seed: u32,
        threads: usize,
        support: SupportPattern,
    ) -> NativeBackend {
        // optim bits 0 = auto, so the CI SLTRAIN_OPTIM_BITS matrix flows
        // through the whole suite
        let mut be = NativeBackend::build(
            micro_preset(),
            method,
            2,
            3e-3,
            100,
            threads,
            0,
            TEST_GALORE_EVERY,
            support,
        )
        .unwrap();
        be.init_state(seed).unwrap();
        be
    }

    fn micro_backend_threads(method: &str, seed: u32, threads: usize) -> NativeBackend {
        micro_backend_support(method, seed, threads, SupportPattern::UniformRandom)
    }

    fn micro_backend(method: &str, seed: u32) -> NativeBackend {
        micro_backend_threads(method, seed, 2)
    }

    fn tiny_backend(method: &str, seed: u32, threads: usize, bits: usize) -> NativeBackend {
        let p = crate::config::preset("tiny").unwrap();
        let mut be = NativeBackend::build(
            p,
            method,
            2,
            3e-3,
            100,
            threads,
            bits,
            TEST_GALORE_EVERY,
            SupportPattern::UniformRandom,
        )
        .unwrap();
        be.init_state(seed).unwrap();
        be
    }

    fn random_tokens(be: &NativeBackend, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..be.batch * be.preset.seq_len)
            .map(|_| rng.below(be.preset.vocab as u64) as i32)
            .collect()
    }

    /// Central-difference check of the full manual backward pass, for
    /// every supported parameterization. For each parameter tensor the
    /// entry with the largest analytic gradient is perturbed.
    #[test]
    fn gradients_match_finite_differences() {
        // relora checks the frozen-W0 + adaptor backward; galore's
        // backward is the full path (its rank-r treatment lives in the
        // optimizer, not the gradient)
        for method in ["full", "lowrank", "sltrain", "relora", "galore"] {
            let mut be = micro_backend(method, 3);
            let tokens = random_tokens(&be, 11);
            let (_, grads) = be.loss_and_grads(&tokens).unwrap();
            for pid in 0..grads.len() {
                let g = &grads[pid];
                if g.is_empty() {
                    continue;
                }
                let name = be.param_names[pid].clone();
                let (idx, &ga) = g
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                if ga.abs() < 5e-3 {
                    continue; // too small to measure through f32 noise
                }
                let h = 1e-2f32;
                let orig = be.params[pid].data()[idx];
                be.params[pid].data_mut()[idx] = orig + h;
                let lp = be.loss_only(&tokens, be.batch).unwrap();
                be.params[pid].data_mut()[idx] = orig - h;
                let lm = be.loss_only(&tokens, be.batch).unwrap();
                be.params[pid].data_mut()[idx] = orig;
                let gn = ((lp - lm) / (2.0 * h as f64)) as f32;
                let rel = (ga - gn).abs() / gn.abs().max(ga.abs()).max(1e-4);
                assert!(
                    rel < 0.08,
                    "{method}/{name}[{idx}]: analytic {ga:.6} vs numeric {gn:.6} (rel {rel:.3})"
                );
            }
        }
    }

    #[test]
    fn n_params_matches_preset_formula() {
        for method in ["full", "lowrank", "sltrain", "relora", "galore"] {
            let be = micro_backend(method, 0);
            assert_eq!(
                be.n_params(),
                be.preset.param_count(method),
                "{method}: n_params vs config formula"
            );
        }
    }

    #[test]
    fn training_is_deterministic() {
        let mut runs = vec![];
        for _ in 0..2 {
            let mut be = micro_backend("sltrain", 42);
            let tokens = random_tokens(&be, 7);
            let mut losses = vec![];
            for step in 0..3 {
                losses.push(be.train_step(step, &tokens).unwrap());
            }
            runs.push(losses);
        }
        assert_eq!(runs[0], runs[1], "same seed must reproduce bit-identical losses");
    }

    /// The parallelism contract: the pool partitions independent tasks
    /// only, so losses are bit-identical across *different* thread
    /// counts, not just across runs at a fixed one.
    #[test]
    fn losses_bit_identical_across_thread_counts() {
        let mut runs = vec![];
        for threads in [1usize, 2, 3] {
            let mut be = micro_backend_threads("sltrain", 5, threads);
            let tokens = random_tokens(&be, 9);
            let mut losses = vec![];
            for step in 0..3 {
                losses.push(be.train_step(step, &tokens).unwrap());
            }
            runs.push(losses);
        }
        assert_eq!(runs[0], runs[1], "1 vs 2 threads");
        assert_eq!(runs[0], runs[2], "1 vs 3 threads");
    }

    #[test]
    fn loss_starts_near_uniform_and_decreases() {
        let mut be = micro_backend("sltrain", 1);
        let tokens = random_tokens(&be, 5);
        let ln_v = (be.preset.vocab as f64).ln();
        let first = be.train_step(0, &tokens).unwrap() as f64;
        // Kaiming head init gives logit variance 2, lifting the expected
        // initial CE to ≈ ln|V| + 1
        assert!((first - ln_v).abs() < 1.6, "init loss {first} vs ln|V| {ln_v}");
        let mut last = first;
        for step in 1..40 {
            last = be.train_step(step, &tokens).unwrap() as f64;
        }
        // one repeated batch: must overfit decisively
        assert!(last < first - 0.5, "{first} -> {last}");
    }

    #[test]
    fn state_roundtrip_preserves_eval() {
        let mut be = micro_backend("sltrain", 9);
        let tokens = random_tokens(&be, 3);
        for step in 0..3 {
            be.train_step(step, &tokens).unwrap();
        }
        let snap = be.state_tensors().unwrap();
        let before = be.eval_loss(&tokens).unwrap();
        let mut be2 = micro_backend("sltrain", 1234); // different init
        be2.load_state_tensors(&snap).unwrap();
        let after = be2.eval_loss(&tokens).unwrap();
        assert!(
            (before - after).abs() < 1e-6,
            "restored eval {after} != source {before}"
        );
    }

    /// SLoPe-style structured 2:4 support trains end-to-end: the loss
    /// drops, every support row conforms to the N:M layout (fast-path
    /// kernels engaged), and a state roundtrip into a fresh structured
    /// backend re-attaches the N:M layout after reload.
    #[test]
    fn structured_24_support_trains_and_roundtrips() {
        let pat = SupportPattern::StructuredNM { n: 2, m: 4 };
        let mut be = micro_backend_support("sltrain", 9, 2, pat);
        assert!(
            be.supports.iter().all(|s| s.nm_pattern() == Some((2, 4))),
            "structured build must engage the N:M fast path on every linear"
        );
        let tokens = random_tokens(&be, 3);
        let first = be.train_step(0, &tokens).unwrap() as f64;
        let mut last = first;
        for step in 1..25 {
            last = be.train_step(step, &tokens).unwrap() as f64;
        }
        assert!(last < first - 0.3, "2:4 sltrain: {first} -> {last}");

        let snap = be.state_tensors().unwrap();
        let before = be.eval_loss(&tokens).unwrap();
        let mut be2 = micro_backend_support("sltrain", 1234, 2, pat);
        be2.load_state_tensors(&snap).unwrap();
        assert!(
            be2.supports.iter().all(|s| s.nm_pattern() == Some((2, 4))),
            "reloaded supports must regain the N:M layout"
        );
        let after = be2.eval_loss(&tokens).unwrap();
        assert!((before - after).abs() < 1e-6, "restored eval {after} != source {before}");
    }

    /// Structured and random supports are different point sets, so the
    /// two patterns must produce genuinely different models (the
    /// table1_support comparison is not vacuous).
    #[test]
    fn structured_and_random_supports_differ() {
        let a = micro_backend_support("sltrain", 9, 1, SupportPattern::UniformRandom);
        let b = micro_backend_support(
            "sltrain",
            9,
            1,
            SupportPattern::StructuredNM { n: 2, m: 4 },
        );
        assert!(a.supports.iter().all(|s| s.nm_pattern().is_none()));
        let idx_a: Vec<_> = a.supports.iter().map(|s| s.idx.clone()).collect();
        let idx_b: Vec<_> = b.supports.iter().map(|s| s.idx.clone()).collect();
        assert_ne!(idx_a, idx_b, "patterns collapsed to the same support");
    }

    #[test]
    fn forward_shape_and_merge_unsupported() {
        let mut be = micro_backend("full", 2);
        let tokens = random_tokens(&be, 1);
        let logits = be.forward(&tokens).unwrap();
        assert_eq!(logits.len(), be.batch * be.preset.seq_len * be.preset.vocab);
        assert!(be.merge(0).is_err());
    }

    #[test]
    fn lr_schedule_warms_up_then_decays() {
        let be = micro_backend("full", 0);
        // total_steps=100 for the micro backend -> 5 warmup steps
        assert_eq!(be.lr_at(0), 0.0);
        assert!(be.lr_at(2) < be.lr_at(4));
        assert!((be.lr_at(5) - be.lr).abs() / be.lr < 1e-3);
        assert!((be.lr_at(10_000) - 0.1 * be.lr).abs() < 1e-6);
        // at the aot.py-default horizon the warmup is exactly 100 steps
        let long = NativeBackend::build(
            micro_preset(),
            "full",
            2,
            3e-3,
            2000,
            1,
            0,
            0,
            SupportPattern::UniformRandom,
        )
        .unwrap();
        assert_eq!(long.warmup_steps(), 100.0);
    }

    /// The tentpole contract: the streaming per-layer fused
    /// backward+update must match the two-phase "collect all grads,
    /// then adam_apply" loop *bitwise* — losses and every parameter —
    /// at every thread count, for every method, at --optim-bits 32.
    #[test]
    fn fused_updates_match_two_phase_bitwise() {
        for method in ["full", "lowrank", "sltrain", "relora", "galore"] {
            for threads in [1usize, 3] {
                let mut fused = NativeBackend::build(
                    micro_preset(),
                    method,
                    2,
                    3e-3,
                    100,
                    threads,
                    32,
                    TEST_GALORE_EVERY,
                    SupportPattern::UniformRandom,
                )
                .unwrap();
                fused.init_state(11).unwrap();
                let mut twop = NativeBackend::build(
                    micro_preset(),
                    method,
                    2,
                    3e-3,
                    100,
                    threads,
                    32,
                    TEST_GALORE_EVERY,
                    SupportPattern::UniformRandom,
                )
                .unwrap();
                twop.init_state(11).unwrap();
                let tokens = random_tokens(&fused, 13);
                for step in 0..4 {
                    let lf = fused.train_step(step, &tokens).unwrap();
                    let lt = twop.train_step_two_phase(step, &tokens).unwrap();
                    assert_eq!(lf, lt, "{method} x{threads} step {step} loss");
                }
                for idx in 0..fused.params.len() {
                    assert_eq!(
                        fused.params[idx].data(),
                        twop.params[idx].data(),
                        "{method} x{threads}: {}",
                        fused.param_names[idx]
                    );
                }
            }
        }
    }

    /// --optim-bits 8: small tensors are gated to f32 moments, big ones
    /// quantize; training stays deterministic, thread-count-invariant,
    /// and actually learns.
    #[test]
    fn q8_gates_small_tensors_and_trains_thread_invariantly() {
        // micro: every tensor is below Q8_MIN_NUMEL -> all f32
        let mut micro =
            NativeBackend::build(
            micro_preset(),
            "sltrain",
            2,
            3e-3,
            100,
            1,
            8,
            0,
            SupportPattern::UniformRandom,
        )
        .unwrap();
        micro.init_state(0).unwrap();
        assert!(micro.optim_m.iter().all(|m| !m.is_quantized()), "micro must gate to f32");
        // tiny: embed/head/linears quantize, norm gains stay f32
        let be = tiny_backend("sltrain", 3, 1, 8);
        let embed_id = be.name_to_id["embed.w"];
        let lnf_id = be.name_to_id["lnf.g"];
        assert!(be.optim_m[embed_id].is_quantized(), "tiny embed moments must quantize");
        assert!(!be.optim_m[lnf_id].is_quantized(), "norm gains must stay f32");

        let mut runs = vec![];
        for threads in [1usize, 3] {
            let mut be = tiny_backend("sltrain", 3, threads, 8);
            let tokens = random_tokens(&be, 21);
            let mut losses = vec![];
            for step in 0..30 {
                losses.push(be.train_step(step, &tokens).unwrap());
            }
            runs.push(losses);
        }
        assert_eq!(runs[0], runs[1], "q8 losses must be bit-identical across thread counts");
        let (first, last) = (runs[0][0] as f64, *runs[0].last().unwrap() as f64);
        assert!(last < first - 0.3, "q8 must overfit one batch: {first} -> {last}");
    }

    /// mem_report: the streaming walk's gradient high-water sits well
    /// under the two-phase footprint, and 8-bit moments cut optimizer
    /// bytes >= 60% (the Fig-3 acceptance bar) on the tiny preset.
    #[test]
    fn mem_report_tracks_grad_peak_and_q8_shrink() {
        let mut be32 = tiny_backend("sltrain", 1, 2, 32);
        let tokens = random_tokens(&be32, 2);
        be32.train_step(0, &tokens).unwrap();
        let r32 = be32.mem_report().unwrap();
        assert_eq!(r32.optim_bits, 32);
        assert!(r32.grad_peak_bytes > 0);
        assert!(
            r32.grad_peak_bytes < r32.grad_all_bytes / 2,
            "streaming peak {} should sit well under two-phase {}",
            r32.grad_peak_bytes,
            r32.grad_all_bytes
        );
        // the two-phase reference holds every gradient at once
        let mut twop = tiny_backend("sltrain", 1, 2, 32);
        twop.train_step_two_phase(0, &tokens).unwrap();
        let rtp = twop.mem_report().unwrap();
        assert_eq!(rtp.grad_peak_bytes, rtp.grad_all_bytes);
        // 8-bit moments: >= 60% optimizer-state cut
        let be8 = tiny_backend("sltrain", 1, 2, 8);
        let r8 = be8.mem_report().unwrap();
        assert_eq!(r8.optim_bits, 8);
        assert!(
            (r8.optim_bytes as f64) < r32.optim_bytes as f64 * 0.4,
            "q8 optimizer bytes {} vs f32 {} (need >= 60% cut)",
            r8.optim_bytes,
            r32.optim_bytes
        );
    }

    /// Quantized optimizer state round-trips bit-identically through
    /// the interchange tensors, and a restored backend continues
    /// training on the exact same trajectory.
    #[test]
    fn optimizer_state_roundtrips_bit_identical() {
        for bits in [32usize, 8] {
            let mut be = tiny_backend("sltrain", 9, 2, bits);
            let tokens = random_tokens(&be, 3);
            for step in 0..3 {
                be.train_step(step, &tokens).unwrap();
            }
            let snap = be.state_tensors().unwrap();
            if bits == 8 {
                assert!(
                    snap.iter().any(|t| t.name.starts_with("optim.m.q8.")),
                    "q8 snapshot must carry I8 moment codes"
                );
                assert!(
                    snap.iter().any(|t| t.name.starts_with("optim.v.scale.")),
                    "q8 snapshot must carry per-block scales"
                );
            }
            let mut be2 = tiny_backend("sltrain", 1234, 2, bits); // different init
            be2.load_state_tensors(&snap).unwrap();
            let snap2 = be2.state_tensors().unwrap();
            assert_eq!(snap.len(), snap2.len(), "bits {bits}: tensor count");
            for (a, b) in snap.iter().zip(&snap2) {
                assert_eq!(a.name, b.name, "bits {bits}");
                assert_eq!(a.dtype, b.dtype, "bits {bits}: {}", a.name);
                assert_eq!(a.bytes, b.bytes, "bits {bits}: {} bytes drifted", a.name);
            }
            // resumed training must continue the exact trajectory
            for step in 3..6 {
                let l1 = be.train_step(step, &tokens).unwrap();
                let l2 = be2.train_step(step, &tokens).unwrap();
                assert_eq!(l1, l2, "bits {bits}: resumed step {step}");
            }
        }
    }

    /// Loading a checkpoint written under the other --optim-bits
    /// setting degrades to a weights-only restore (moments skipped,
    /// left at init) instead of bricking the checkpoint — switching
    /// precision mid-project must not lose the weights.
    #[test]
    fn cross_precision_checkpoint_restores_weights_only() {
        for (src_bits, dst_bits) in [(32usize, 8usize), (8, 32)] {
            let mut src = tiny_backend("sltrain", 5, 1, src_bits);
            let tokens = random_tokens(&src, 4);
            src.train_step(0, &tokens).unwrap();
            let snap = src.state_tensors().unwrap();
            let want = src.eval_loss(&tokens).unwrap();
            let mut dst = tiny_backend("sltrain", 99, 1, dst_bits); // different init
            dst.load_state_tensors(&snap).unwrap();
            let got = dst.eval_loss(&tokens).unwrap();
            assert!(
                (want - got).abs() < 1e-6,
                "{src_bits}->{dst_bits}: weights not restored ({want} vs {got})"
            );
            // moments were skipped: they must still be at init (all zero)
            for mom in dst.optim_m.iter().chain(&dst.optim_v) {
                match mom {
                    Moments::F32(d) => assert!(d.iter().all(|&x| x == 0.0)),
                    Moments::Q8 { codes, scales } => {
                        assert!(codes.iter().all(|&c| c == 0));
                        assert!(scales.iter().all(|&s| s == 0.0));
                    }
                }
            }
            // and training continues cleanly from the restored weights
            dst.train_step(1, &tokens).unwrap();
        }
    }

    /// Quantized moment codes without their per-block scales (or vice
    /// versa) must be rejected — pairing new codes with stale scales
    /// would silently corrupt the decoded moments.
    #[test]
    fn unpaired_quantized_moments_are_rejected() {
        let mut be = tiny_backend("sltrain", 5, 1, 8);
        let tokens = random_tokens(&be, 4);
        be.train_step(0, &tokens).unwrap();
        let snap = be.state_tensors().unwrap();
        for stripped in [".scale.", ".q8."] {
            let partial: Vec<StateTensor> = snap
                .iter()
                .filter(|t| !(t.name.starts_with("optim.") && t.name.contains(stripped)))
                .cloned()
                .collect();
            assert!(partial.len() < snap.len(), "filter must drop something");
            let mut be2 = tiny_backend("sltrain", 5, 1, 8);
            let err = be2
                .load_state_tensors(&partial)
                .err()
                .unwrap_or_else(|| panic!("load without {stripped} tensors must fail"));
            assert!(
                format!("{err}").contains("round-trip together"),
                "unhelpful error: {err}"
            );
        }
        // and a checkpoint missing one whole moment family (all of v)
        // must be rejected too — restored m + stale v would silently
        // diverge from the saved trajectory
        let no_v: Vec<StateTensor> =
            snap.iter().filter(|t| !t.name.starts_with("optim.v.")).cloned().collect();
        let mut be3 = tiny_backend("sltrain", 5, 1, 8);
        let err = be3
            .load_state_tensors(&no_v)
            .err()
            .expect("load without the v moments must fail");
        assert!(format!("{err}").contains("complete"), "unhelpful error: {err}");
    }

    /// Both native baselines must actually learn: a repeated batch is
    /// decisively overfit, with a ReLoRA merge mid-run (the loss must
    /// keep falling across the restart) and GaLore crossing several
    /// projector refreshes.
    #[test]
    fn relora_and_galore_overfit_one_batch() {
        for method in ["relora", "galore"] {
            let mut be = micro_backend(method, 1);
            let tokens = random_tokens(&be, 5);
            let first = be.train_step(0, &tokens).unwrap() as f64;
            let mut last = first;
            for step in 1..40 {
                last = be.train_step(step, &tokens).unwrap() as f64;
                if method == "relora" && step == 20 {
                    be.merge(step).unwrap();
                }
            }
            assert!(last < first - 0.3, "{method}: {first} -> {last}");
        }
    }

    /// The merge contract, both moment precisions: eval loss is
    /// continuous across the restart (W0 absorbs scale·B·A exactly, up
    /// to f32 re-association), B returns to zero, A is re-drawn, W0
    /// moved, and the adaptors' Adam moments are wiped — under 8-bit
    /// moments the quantized codes *and* the per-block scales.
    #[test]
    fn relora_merge_is_loss_continuous_and_resets_moments() {
        for bits in [32usize, 8] {
            let mut be = tiny_backend("relora", 7, 2, bits);
            let tokens = random_tokens(&be, 15);
            for step in 0..4 {
                be.train_step(step, &tokens).unwrap();
            }
            // pre-merge state of one adapted linear
            let LinKind::Relora { w0, b, a } = be.lins[0] else {
                panic!("relora backend must intern Relora linears");
            };
            if bits == 8 {
                assert!(
                    be.optim_m[b.0].is_quantized(),
                    "tiny relora B moments must quantize at --optim-bits 8"
                );
            }
            let w0_before = be.params[w0.0].data().to_vec();
            let a_before = be.params[a.0].data().to_vec();
            assert!(be.params[b.0].data().iter().any(|&x| x != 0.0), "B trained off zero");
            let before = be.eval_loss(&tokens).unwrap();
            be.merge(4).unwrap();
            let after = be.eval_loss(&tokens).unwrap();
            assert!(
                (before - after).abs() < 1e-3,
                "bits {bits}: merge must be loss-continuous ({before} vs {after})"
            );
            assert!(be.params[b.0].data().iter().all(|&x| x == 0.0), "B must reset to zero");
            assert_ne!(be.params[a.0].data(), &a_before[..], "A must be re-drawn");
            assert_ne!(be.params[w0.0].data(), &w0_before[..], "W0 must absorb the fold");
            for lin in be.lins.clone() {
                let LinKind::Relora { w0, b, a } = lin else { unreachable!() };
                for id in [b, a] {
                    for mom in [&be.optim_m[id.0], &be.optim_v[id.0]] {
                        match mom {
                            Moments::F32(d) => assert!(
                                d.iter().all(|&x| x == 0.0),
                                "bits {bits}: adaptor moments must reset"
                            ),
                            Moments::Q8 { codes, scales } => {
                                assert!(codes.iter().all(|&c| c == 0), "codes must reset");
                                assert!(scales.iter().all(|&s| s == 0.0), "scales must reset");
                            }
                        }
                    }
                }
                // the frozen W0 has no moments to reset
                assert_eq!(be.optim_m[w0.0].numel(), 0, "W0 must carry no moments");
            }
            // training continues cleanly from the merged state
            be.train_step(4, &tokens).unwrap();
        }
    }

    /// ReLoRA trajectories — including the merge fold and the post-merge
    /// re-init — must be bit-identical at 1, 2 and 4 threads.
    #[test]
    fn relora_merge_bit_identical_across_thread_counts() {
        let mut runs = vec![];
        for threads in [1usize, 2, 4] {
            let mut be = micro_backend_threads("relora", 5, threads);
            let tokens = random_tokens(&be, 9);
            let mut losses = vec![];
            for step in 0..3 {
                losses.push(be.train_step(step, &tokens).unwrap());
            }
            be.merge(3).unwrap();
            for step in 3..6 {
                losses.push(be.train_step(step, &tokens).unwrap());
            }
            let snap = be.state_tensors().unwrap();
            runs.push((losses, snap));
        }
        for (i, threads) in [2usize, 4].iter().enumerate() {
            assert_eq!(runs[0].0, runs[i + 1].0, "1 vs {threads} threads: losses");
            for (a, b) in runs[0].1.iter().zip(&runs[i + 1].1) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.bytes, b.bytes, "1 vs {threads} threads: {} drifted", a.name);
            }
        }
    }

    /// Resuming from a checkpoint taken mid-relora-cycle (between two
    /// merges) must reproduce the no-resume trajectory bit-for-bit,
    /// merges included — the merge seed is the step number, so restarts
    /// replay identically. Both moment precisions.
    #[test]
    fn relora_resume_mid_cycle_reproduces_trajectory() {
        for bits in [32usize, 8] {
            let merge_every = 3i32;
            let mut be = tiny_backend("relora", 9, 2, bits);
            let tokens = random_tokens(&be, 3);
            // coordinator schedule: merge after the step when
            // step > 0 && step % merge_every == 0
            for step in 0..5 {
                be.train_step(step, &tokens).unwrap();
                if step > 0 && step % merge_every == 0 {
                    be.merge(step).unwrap();
                }
            }
            // snapshot mid-cycle: after the step-3 merge, before step-6's
            let snap = be.state_tensors().unwrap();
            let mut be2 = tiny_backend("relora", 4242, 2, bits); // different init
            be2.load_state_tensors(&snap).unwrap();
            for step in 5..9 {
                let l1 = be.train_step(step, &tokens).unwrap();
                let l2 = be2.train_step(step, &tokens).unwrap();
                assert_eq!(l1, l2, "bits {bits}: resumed relora step {step}");
                if step > 0 && step % merge_every == 0 {
                    be.merge(step).unwrap();
                    be2.merge(step).unwrap();
                }
            }
        }
    }

    /// GaLore's optimizer-byte win, measured: moments live at the
    /// projected size (k·max(d_in,d_out) per linear instead of
    /// d_in·d_out), so optimizer bytes sit well under the full-rank
    /// baseline while parameter bytes are identical; the projector is
    /// tracked separately and dropped with the optimizer state.
    #[test]
    fn galore_moments_projected_and_optimizer_bytes_shrink() {
        let mut gl = tiny_backend("galore", 1, 2, 32);
        let full = tiny_backend("full", 1, 2, 32);
        let rg = gl.mem_report().unwrap();
        let rf = full.mem_report().unwrap();
        assert_eq!(rg.param_bytes, rf.param_bytes, "same full-rank weights");
        assert!(rg.proj_bytes > 0, "galore must hold projectors");
        assert_eq!(rf.proj_bytes, 0, "full holds no projectors");
        assert!(
            rg.optim_bytes + rg.proj_bytes < rf.optim_bytes,
            "galore optimizer state {} + proj {} must undercut full {}",
            rg.optim_bytes,
            rg.proj_bytes,
            rf.optim_bytes
        );
        // projected moment shape: k*max(d) per attention linear
        let wid = gl.name_to_id["layers.0.attn.q.w"];
        let p = gl.preset.clone();
        assert_eq!(gl.optim_m[wid].numel(), p.rank.min(p.d_model) * p.d_model);
        // drop: moments AND projectors released
        gl.drop_optimizer_state().unwrap();
        let rd = gl.mem_report().unwrap();
        assert_eq!(rd.optim_bytes, 0);
        assert_eq!(rd.proj_bytes, 0, "projectors are optimizer state");
    }

    /// The projector refresh (truncated SVD of the step gradient) and
    /// the projected-space updates must be bit-identical at 1, 2 and 4
    /// threads, across several refresh boundaries.
    #[test]
    fn galore_projector_refresh_deterministic_across_thread_counts() {
        let mut runs = vec![];
        for threads in [1usize, 2, 4] {
            let mut be = micro_backend_threads("galore", 5, threads);
            assert_eq!(be.galore_every, TEST_GALORE_EVERY);
            let tokens = random_tokens(&be, 9);
            let mut losses = vec![];
            for step in 0..7 {
                // refreshes at steps 0, 3, 6
                losses.push(be.train_step(step, &tokens).unwrap());
            }
            let snap = be.state_tensors().unwrap();
            runs.push((losses, snap));
        }
        for (i, threads) in [2usize, 4].iter().enumerate() {
            assert_eq!(runs[0].0, runs[i + 1].0, "1 vs {threads} threads: losses");
            for (a, b) in runs[0].1.iter().zip(&runs[i + 1].1) {
                assert_eq!(a.name, b.name);
                assert_eq!(a.bytes, b.bytes, "1 vs {threads} threads: {} drifted", a.name);
            }
        }
    }

    /// GaLore state — projected moments AND the projector frame — must
    /// round-trip through the interchange tensors, and a restored
    /// backend must continue the exact trajectory across the next
    /// refresh. A checkpoint carrying galore moments without their
    /// projector is rejected (moments are meaningless without their
    /// subspace frame).
    #[test]
    fn galore_state_roundtrips_and_requires_projector() {
        for bits in [32usize, 8] {
            let mut be = tiny_backend("galore", 9, 2, bits);
            let tokens = random_tokens(&be, 3);
            for step in 0..4 {
                be.train_step(step, &tokens).unwrap();
            }
            let snap = be.state_tensors().unwrap();
            assert!(
                snap.iter().any(|t| t.name.starts_with("optim.proj.")),
                "snapshot must carry the projector frames"
            );
            let mut be2 = tiny_backend("galore", 777, 2, bits); // different init
            be2.load_state_tensors(&snap).unwrap();
            for step in 4..8 {
                // crosses the refresh at step 6
                let l1 = be.train_step(step, &tokens).unwrap();
                let l2 = be2.train_step(step, &tokens).unwrap();
                assert_eq!(l1, l2, "bits {bits}: resumed galore step {step}");
            }
            // moments without their frame must be rejected
            let no_proj: Vec<StateTensor> = snap
                .iter()
                .filter(|t| !t.name.starts_with("optim.proj."))
                .cloned()
                .collect();
            assert!(no_proj.len() < snap.len());
            let mut be3 = tiny_backend("galore", 9, 2, bits);
            let err = be3
                .load_state_tensors(&no_proj)
                .err()
                .expect("galore moments without projector must fail");
            assert!(format!("{err}").contains("projector"), "unhelpful error: {err}");
        }
    }

    /// Degraded galore restores must not strand the backend on a zero
    /// projector (which makes every update a silent no-op until the
    /// next refresh boundary): a cross-precision load keeps the
    /// bits-independent `optim.proj.*` frame, and a weights-only load
    /// (no optim.* at all) triggers an immediate refresh on the first
    /// step even off the period.
    #[test]
    fn degraded_galore_restores_still_update_weights() {
        let mut src = tiny_backend("galore", 5, 1, 8);
        let tokens = random_tokens(&src, 4);
        for step in 0..4 {
            src.train_step(step, &tokens).unwrap();
        }
        let snap = src.state_tensors().unwrap();
        let wname = "layers.0.attn.q.w";

        // cross-precision (8 -> 32): moments skipped, projector kept
        let mut dst = tiny_backend("galore", 99, 1, 32);
        dst.load_state_tensors(&snap).unwrap();
        let wid = dst.name_to_id[wname];
        let gs = dst.galore[wid].as_ref().unwrap();
        assert!(gs.ready && gs.p.data.iter().any(|&x| x != 0.0), "projector must survive");
        let before = dst.params[wid].data().to_vec();
        dst.train_step(4, &tokens).unwrap(); // 4 % TEST_GALORE_EVERY != 0
        assert_ne!(dst.params[wid].data(), &before[..], "step must move the weight");

        // weights-only (no optim.* at all, e.g. cross-backend): the
        // not-ready frame forces an immediate refresh off the period
        let weights_only: Vec<StateTensor> =
            snap.iter().filter(|t| !t.name.starts_with("optim.")).cloned().collect();
        let mut dst2 = tiny_backend("galore", 7, 1, 32);
        dst2.load_state_tensors(&weights_only).unwrap();
        let wid2 = dst2.name_to_id[wname];
        assert!(!dst2.galore[wid2].as_ref().unwrap().ready);
        let before = dst2.params[wid2].data().to_vec();
        dst2.train_step(4, &tokens).unwrap();
        assert_ne!(dst2.params[wid2].data(), &before[..], "refresh-on-demand must kick in");
        assert!(dst2.galore[wid2].as_ref().unwrap().ready);

        // a snapshot taken before the first step carries the all-zero
        // frame: restoring it must not mark the projector live
        let cold = tiny_backend("galore", 3, 1, 32);
        let cold_snap = cold.state_tensors().unwrap();
        let mut dst3 = tiny_backend("galore", 8, 1, 32);
        dst3.load_state_tensors(&cold_snap).unwrap();
        let wid3 = dst3.name_to_id[wname];
        assert!(
            !dst3.galore[wid3].as_ref().unwrap().ready,
            "a restored zero frame must stay not-ready"
        );
    }

    /// drop_optimizer_state must drop quantized moments and their
    /// per-block scales too (the ReLoRA-merge staleness fix), after
    /// which training fails cleanly and snapshots carry no moments.
    #[test]
    fn drop_optimizer_state_drops_quantized_buffers() {
        let mut be = tiny_backend("sltrain", 2, 1, 8);
        let tokens = random_tokens(&be, 6);
        be.train_step(0, &tokens).unwrap();
        assert!(be.mem_report().unwrap().optim_bytes > 0);
        be.drop_optimizer_state().unwrap();
        assert_eq!(be.mem_report().unwrap().optim_bytes, 0, "all moment buffers freed");
        let snap = be.state_tensors().unwrap();
        assert!(
            snap.iter().all(|t| !t.name.starts_with("optim.")),
            "dropped state must not leak into snapshots"
        );
        assert!(be.train_step(1, &tokens).is_err(), "stepping without moments must fail");
    }
}

//! NativeBackend: the pure-rust SLTrain trainer.
//!
//! A from-scratch implementation of the paper's pretraining setup on
//! `linalg::Matrix` + `linalg::sparse` — LLaMA-shaped blocks (RMSNorm,
//! rotary attention, SwiGLU), full manual forward/backward, and Adam
//! with the GaLore-repo warmup+cosine schedule, over the `full`,
//! `lowrank` and `sltrain` weight parameterizations of
//! `python/compile/layers.py`:
//!
//!   full     y = x W
//!   lowrank  y = scale · (x B) A
//!   sltrain  y = scale · (x B) A + x S       (S fixed-support sparse)
//!
//! Like the paper's kernels (and unlike the densifying oracle), the hot
//! loop never materializes the dense `W = scale·BA ⊕ S` nor its
//! gradient: the sparse contribution flows through `SparseSupport::spmm`
//! / `spmm_t`, and the sparse value gradient is gathered straight off
//! the support (`scatter_grad`, eq. 2). Every `dy @ W^T`-shaped product
//! uses the transpose-hoisted `matmul_transb` path.
//!
//! **Execution model.** The step loop is multi-core: one
//! `linalg::parallel::ThreadPool` (the `--threads` flag; 0 = auto)
//! drives row-panel-parallel blocked matmuls, the per-(batch, head)
//! attention loops, and the row-partitioned sparse kernels. Every
//! parallel region runs independent tasks with fixed f32 reduction
//! order, so losses are bit-identical across runs *and* across thread
//! counts; `--threads 1` spawns nothing and is the serial engine.
//!
//! **Parameter interning.** Parameters live in an id-indexed
//! `Vec<PTensor>`; every per-linear handle (`ParamId`, `LinId`) is
//! interned once at `init_state`, so the step loop does plain vector
//! indexing — no `format!("{path}.B")` string rebuilding, no map
//! lookups. A name table is kept only for the state interchange
//! (checkpoints, parity tooling).
//!
//! No artifacts, no XLA, no Python: this backend is the deterministic
//! reference the AOT/PJRT path is parity-tested against, and the engine
//! behind `sltrain train --backend native`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::{Backend, StateTensor};
use crate::config::ModelPreset;
use crate::linalg::parallel::{resolve_threads, ThreadPool};
use crate::linalg::{Matrix, SparseSupport};
use crate::util::rng::Rng;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
/// Warmup cap, mirroring aot.py's default (100 steps at the default
/// 2000-step horizon); shorter runs warm up over 5% of their horizon.
const WARMUP_CAP: f32 = 100.0;
const RMS_EPS: f32 = 1e-6;
const ROPE_THETA: f32 = 10000.0;

// ------------------------------------------------------------- tensors

/// A named parameter: 2-d weights as `Matrix`, 1-d (norm gains, sparse
/// values) as flat vectors. Uniform flat access for Adam / checkpoints.
#[derive(Debug, Clone)]
enum PTensor {
    Mat(Matrix),
    Vec1(Vec<f32>),
}

impl PTensor {
    fn shape(&self) -> Vec<usize> {
        match self {
            PTensor::Mat(m) => vec![m.rows, m.cols],
            PTensor::Vec1(v) => vec![v.len()],
        }
    }

    fn numel(&self) -> usize {
        match self {
            PTensor::Mat(m) => m.data.len(),
            PTensor::Vec1(v) => v.len(),
        }
    }

    fn data(&self) -> &[f32] {
        match self {
            PTensor::Mat(m) => &m.data,
            PTensor::Vec1(v) => v,
        }
    }

    fn data_mut(&mut self) -> &mut [f32] {
        match self {
            PTensor::Mat(m) => &mut m.data,
            PTensor::Vec1(v) => v,
        }
    }

    fn mat(&self) -> &Matrix {
        match self {
            PTensor::Mat(m) => m,
            PTensor::Vec1(_) => panic!("tensor is 1-d, expected matrix"),
        }
    }

    fn vec(&self) -> &[f32] {
        match self {
            PTensor::Vec1(v) => v,
            PTensor::Mat(_) => panic!("tensor is 2-d, expected vector"),
        }
    }
}

// ------------------------------------------------------------- handles
//
// Interned once at init_state: the step loop addresses every parameter
// by dense index, never by name.

/// Index into the parameter store (`params` / `adam_m` / `adam_v`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct ParamId(usize);

/// Index into the per-linear tables (`lins` / `lin_paths` / xb cache),
/// in `preset.linear_paths()` order.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
struct LinId(usize);

#[derive(Debug, Clone, Copy)]
struct SparseHandle {
    vals: ParamId,
    /// Index into `supports` / `support_paths`.
    sup: usize,
}

/// The parameterization of one adapted linear.
#[derive(Debug, Clone, Copy)]
enum LinKind {
    Full { w: ParamId },
    Factored { b: ParamId, a: ParamId, sparse: Option<SparseHandle> },
}

#[derive(Debug, Clone, Copy)]
struct LayerHandles {
    ln1_g: ParamId,
    ln2_g: ParamId,
    q: LinId,
    k: LinId,
    v: LinId,
    o: LinId,
    gate: LinId,
    up: LinId,
    down: LinId,
}

#[derive(Debug, Clone)]
struct ModelHandles {
    embed: ParamId,
    head: ParamId,
    lnf_g: ParamId,
    layers: Vec<LayerHandles>,
}

/// Linears per layer in `linear_paths()` order (q,k,v,o,gate,up,down).
const LINS_PER_LAYER: usize = 7;

// ----------------------------------------------------- forward caches

struct BlockCache {
    /// Normalized pre-gain input of ln1 and its 1/rms per row.
    xhat1: Matrix,
    r1: Vec<f32>,
    /// Gained ln1 output: the input of the q/k/v linears.
    xn1: Matrix,
    /// Post-rope q and k, and v, all [n, d].
    q: Matrix,
    k: Matrix,
    v: Matrix,
    /// Attention probabilities, one [t, t] matrix per (batch, head).
    probs: Vec<Matrix>,
    /// Concatenated attention output: the input of the o linear.
    attn_cat: Matrix,
    xhat2: Matrix,
    r2: Vec<f32>,
    /// Gained ln2 output: the input of the gate/up linears.
    xn2: Matrix,
    /// Gate pre-activation and up output (SwiGLU backward).
    g_pre: Matrix,
    u: Matrix,
    /// silu(g_pre) ⊙ u: the input of the down linear.
    h: Matrix,
}

struct FwdCache {
    tokens: Vec<i32>,
    bsz: usize,
    t: usize,
    blocks: Vec<BlockCache>,
    /// x @ B per factored linear, indexed by LinId (backward reuse).
    xb: Vec<Option<Matrix>>,
    xhatf: Matrix,
    rf: Vec<f32>,
    /// Gained final-norm output: the input of the head matmul.
    xnf: Matrix,
}

/// Per-parameter gradient accumulators, indexed by ParamId (empty =
/// not yet touched).
type Grads = Vec<Vec<f32>>;

fn acc_grad(grads: &mut Grads, id: ParamId, g: &[f32]) {
    let slot = &mut grads[id.0];
    if slot.is_empty() {
        slot.extend_from_slice(g);
    } else {
        for (a, b) in slot.iter_mut().zip(g) {
            *a += b;
        }
    }
}

// ------------------------------------------------------------ backend

pub struct NativeBackend {
    preset: ModelPreset,
    method: String,
    batch: usize,
    lr: f32,
    total_steps: usize,
    /// The paper's alpha/r balancing factor on B@A.
    scale: f32,
    /// Interned parameter store; `ParamId` indexes all three vectors.
    params: Vec<PTensor>,
    param_names: Vec<String>,
    adam_m: Vec<Vec<f32>>,
    adam_v: Vec<Vec<f32>>,
    /// Name -> id, kept only for the state interchange.
    name_to_id: BTreeMap<String, usize>,
    /// Per-linear parameter handles, `LinId`-indexed.
    lins: Vec<LinKind>,
    lin_paths: Vec<String>,
    /// Fixed sparse supports (sltrain only), `SparseHandle::sup`-indexed.
    supports: Vec<SparseSupport>,
    support_paths: Vec<String>,
    handles: Option<ModelHandles>,
    /// RoPE tables, [seq_len * head_dim/2] row-major.
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    /// Worker pool driving matmuls, attention heads and sparse kernels.
    pool: ThreadPool,
}

impl NativeBackend {
    pub fn build(
        preset: ModelPreset,
        method: &str,
        batch: usize,
        lr: f32,
        total_steps: usize,
        threads: usize,
    ) -> Result<NativeBackend> {
        if !matches!(method, "full" | "lowrank" | "sltrain") {
            bail!("native backend supports full | lowrank | sltrain (got {method:?})");
        }
        if preset.d_model % preset.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", preset.d_model, preset.n_heads);
        }
        let hd = preset.d_model / preset.n_heads;
        if hd % 2 != 0 {
            bail!("head_dim {hd} must be even for rotary embeddings");
        }
        if preset.seq_len < 2 {
            bail!("seq_len {} too short for next-token training", preset.seq_len);
        }
        let half = hd / 2;
        let mut rope_cos = vec![0.0f32; preset.seq_len * half];
        let mut rope_sin = vec![0.0f32; preset.seq_len * half];
        for pos in 0..preset.seq_len {
            for j in 0..half {
                let freq = ROPE_THETA.powf(-((2 * j) as f32) / hd as f32);
                let ang = pos as f32 * freq;
                rope_cos[pos * half + j] = ang.cos();
                rope_sin[pos * half + j] = ang.sin();
            }
        }
        let scale = (preset.alpha / preset.rank as f64) as f32;
        Ok(NativeBackend {
            preset,
            method: method.to_string(),
            batch: batch.max(1),
            lr,
            total_steps: total_steps.max(1),
            scale,
            params: Vec::new(),
            param_names: Vec::new(),
            adam_m: Vec::new(),
            adam_v: Vec::new(),
            name_to_id: BTreeMap::new(),
            lins: Vec::new(),
            lin_paths: Vec::new(),
            supports: Vec::new(),
            support_paths: Vec::new(),
            handles: None,
            rope_cos,
            rope_sin,
            pool: ThreadPool::new(resolve_threads(threads)),
        })
    }

    /// Resolved worker count of the step loop's pool.
    pub fn threads(&self) -> usize {
        self.pool.threads()
    }

    fn head_dim(&self) -> usize {
        self.preset.d_model / self.preset.n_heads
    }

    fn mat(&self, id: ParamId) -> &Matrix {
        self.params[id.0].mat()
    }

    fn vec1(&self, id: ParamId) -> &[f32] {
        self.params[id.0].vec()
    }

    fn handles(&self) -> Result<&ModelHandles> {
        self.handles
            .as_ref()
            .ok_or_else(|| anyhow!("backend state not initialized (call init_state first)"))
    }

    // -------------------------------------------------------- init

    fn intern(&mut self, name: String, t: PTensor) -> ParamId {
        let id = self.params.len();
        self.name_to_id.insert(name.clone(), id);
        self.param_names.push(name);
        self.params.push(t);
        ParamId(id)
    }

    /// Paper §3.3 init, mirroring python `model.init_fn` / `init_linear`:
    /// embed N(0, 0.02), head Kaiming, norm gains 1, per-linear Kaiming A
    /// (+ Kaiming B for lowrank, zero B + uniform ±1/√d_in values for
    /// sltrain), and one independent uniform support per linear. All
    /// parameter handles are interned here, once.
    fn init_params(&mut self, seed: u32) {
        let p = self.preset.clone();
        let root = Rng::new(seed as u64);
        self.params.clear();
        self.param_names.clear();
        self.name_to_id.clear();
        self.lins.clear();
        self.lin_paths.clear();
        self.supports.clear();
        self.support_paths.clear();

        let gauss_mat = |rng: &mut Rng, rows: usize, cols: usize, std: f32| {
            let mut m = Matrix::zeros(rows, cols);
            for x in &mut m.data {
                *x = rng.gaussian() as f32 * std;
            }
            m
        };

        let mut r_embed = root.fork(1);
        let embed = self.intern(
            "embed.w".into(),
            PTensor::Mat(gauss_mat(&mut r_embed, p.vocab, p.d_model, 0.02)),
        );
        let mut r_head = root.fork(2);
        let head_std = (2.0f32 / p.d_model as f32).sqrt();
        let head = self.intern(
            "head.w".into(),
            PTensor::Mat(gauss_mat(&mut r_head, p.d_model, p.vocab, head_std)),
        );
        let lnf_g = self.intern("lnf.g".into(), PTensor::Vec1(vec![1.0; p.d_model]));
        let mut ln1_ids = Vec::with_capacity(p.n_layers);
        let mut ln2_ids = Vec::with_capacity(p.n_layers);
        for i in 0..p.n_layers {
            let g = vec![1.0; p.d_model];
            ln1_ids.push(self.intern(format!("layers.{i}.ln1.g"), PTensor::Vec1(g.clone())));
            ln2_ids.push(self.intern(format!("layers.{i}.ln2.g"), PTensor::Vec1(g)));
        }

        for (j, (path, d_in, d_out)) in p.linear_paths().into_iter().enumerate() {
            let base = root.fork(1000 + j as u64);
            let kaiming_in = (2.0f32 / d_in as f32).sqrt();
            let kaiming_r = (2.0f32 / p.rank as f32).sqrt();
            let kind = match self.method.as_str() {
                "full" => {
                    let mut r1 = base.fork(1);
                    let w = self.intern(
                        format!("{path}.w"),
                        PTensor::Mat(gauss_mat(&mut r1, d_in, d_out, kaiming_in)),
                    );
                    LinKind::Full { w }
                }
                "lowrank" => {
                    // lowrank cannot start at BA = 0 (no gradient to
                    // escape); Kaiming B as in [24]
                    let mut r1 = base.fork(1);
                    let mut r2 = base.fork(2);
                    let b = self.intern(
                        format!("{path}.B"),
                        PTensor::Mat(gauss_mat(&mut r2, d_in, p.rank, kaiming_in)),
                    );
                    let a = self.intern(
                        format!("{path}.A"),
                        PTensor::Mat(gauss_mat(&mut r1, p.rank, d_out, kaiming_r)),
                    );
                    LinKind::Factored { b, a, sparse: None }
                }
                "sltrain" => {
                    let mut r1 = base.fork(1);
                    let mut r2 = base.fork(2);
                    let b = self
                        .intern(format!("{path}.B"), PTensor::Mat(Matrix::zeros(d_in, p.rank)));
                    let a = self.intern(
                        format!("{path}.A"),
                        PTensor::Mat(gauss_mat(&mut r1, p.rank, d_out, kaiming_r)),
                    );
                    let mut r_sup = base.fork(3);
                    let sup = SparseSupport::random(d_in, d_out, p.delta, &mut r_sup);
                    let bound = 1.0f32 / (d_in as f32).sqrt();
                    let vals_data: Vec<f32> =
                        (0..sup.nnz()).map(|_| r2.range_f32(-bound, bound)).collect();
                    let vals = self.intern(format!("{path}.vals"), PTensor::Vec1(vals_data));
                    let sup_idx = self.supports.len();
                    self.supports.push(sup);
                    self.support_paths.push(path.clone());
                    LinKind::Factored { b, a, sparse: Some(SparseHandle { vals, sup: sup_idx }) }
                }
                _ => unreachable!("validated in build"),
            };
            self.lins.push(kind);
            self.lin_paths.push(path);
        }

        self.adam_m = self.params.iter().map(|t| vec![0.0; t.numel()]).collect();
        self.adam_v = self.params.iter().map(|t| vec![0.0; t.numel()]).collect();
        let layers = (0..p.n_layers)
            .map(|l| {
                let b = l * LINS_PER_LAYER;
                LayerHandles {
                    ln1_g: ln1_ids[l],
                    ln2_g: ln2_ids[l],
                    q: LinId(b),
                    k: LinId(b + 1),
                    v: LinId(b + 2),
                    o: LinId(b + 3),
                    gate: LinId(b + 4),
                    up: LinId(b + 5),
                    down: LinId(b + 6),
                }
            })
            .collect();
        self.handles = Some(ModelHandles { embed, head, lnf_g, layers });
    }

    // ----------------------------------------------------- linears

    /// Apply the `lin` linear to x [n, d_in]. Returns (y, x@B cache).
    fn linear_fwd(&self, lin: LinId, x: &Matrix) -> (Matrix, Option<Matrix>) {
        match self.lins[lin.0] {
            LinKind::Full { w } => (x.matmul_par(self.mat(w), &self.pool), None),
            LinKind::Factored { b, a, sparse } => {
                let xb = x.matmul_par(self.mat(b), &self.pool);
                let mut y = xb.matmul_par(self.mat(a), &self.pool);
                for v in &mut y.data {
                    *v *= self.scale;
                }
                if let Some(sh) = sparse {
                    self.supports[sh.sup].spmm_add_par(x, self.vec1(sh.vals), &mut y, &self.pool);
                }
                (y, Some(xb))
            }
        }
    }

    /// Backward of the `lin` linear: accumulates parameter grads into
    /// `grads` and returns dL/dx. `xt` is the transposed input (hoisted
    /// by the caller — q/k/v and gate/up share one transpose).
    fn linear_bwd(
        &self,
        lin: LinId,
        xt: &Matrix,
        x: &Matrix,
        xb: Option<&Matrix>,
        dy: &Matrix,
        grads: &mut Grads,
    ) -> Matrix {
        match self.lins[lin.0] {
            LinKind::Full { w } => {
                let dw = xt.matmul_par(dy, &self.pool);
                acc_grad(grads, w, &dw.data);
                dy.matmul_transb_par(self.mat(w), &self.pool)
            }
            LinKind::Factored { b, a, sparse } => {
                let xb = xb.unwrap_or_else(|| {
                    panic!("{}: missing x@B cache", self.lin_paths[lin.0])
                });
                // eq. (2): the dense d_in × d_out gradient is never formed
                let dy_at = dy.matmul_transb_par(self.mat(a), &self.pool); // [n, r]
                let db = xt.matmul_par(&dy_at, &self.pool).scale(self.scale);
                let da = xb.transpose().matmul_par(dy, &self.pool).scale(self.scale);
                acc_grad(grads, b, &db.data);
                acc_grad(grads, a, &da.data);
                let mut dx = dy_at.matmul_transb_par(self.mat(b), &self.pool).scale(self.scale);
                if let Some(sh) = sparse {
                    let sup = &self.supports[sh.sup];
                    let dvals = sup.scatter_grad_par(x, dy, &self.pool);
                    acc_grad(grads, sh.vals, &dvals);
                    sup.spmm_t_add_par(dy, self.vec1(sh.vals), &mut dx, &self.pool);
                }
                dx
            }
        }
    }

    // ----------------------------------------------------- forward

    /// Full cached forward over `tokens` ([bsz, t] row-major). Returns
    /// logits [bsz*t, vocab] plus everything the backward pass needs.
    fn forward_cached(&self, tokens: &[i32], bsz: usize, t: usize) -> Result<(Matrix, FwdCache)> {
        let h = self.handles()?.clone();
        let p = &self.preset;
        let (d, nh, hd) = (p.d_model, p.n_heads, self.head_dim());
        let half = hd / 2;
        let n = bsz * t;
        if tokens.len() != n {
            bail!("forward expects {bsz}x{t} tokens, got {}", tokens.len());
        }
        if t > p.seq_len {
            bail!("sequence {t} exceeds preset seq_len {}", p.seq_len);
        }

        let embed = self.mat(h.embed);
        let mut x = Matrix::zeros(n, d);
        for (i, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= p.vocab {
                bail!("token {tok} out of vocab {}", p.vocab);
            }
            x.data[i * d..(i + 1) * d].copy_from_slice(&embed.data[tok * d..(tok + 1) * d]);
        }

        let attn_scale = 1.0f32 / (hd as f32).sqrt();
        let mut blocks = Vec::with_capacity(p.n_layers);
        let mut xb_cache: Vec<Option<Matrix>> = vec![None; self.lins.len()];
        for lh in &h.layers {
            let g1 = self.vec1(lh.ln1_g);
            let (xn1, xhat1, r1) = rmsnorm_fwd(&x, g1);

            let (mut q, xb) = self.linear_fwd(lh.q, &xn1);
            xb_cache[lh.q.0] = xb;
            let (mut k, xb) = self.linear_fwd(lh.k, &xn1);
            xb_cache[lh.k.0] = xb;
            let (v, xb) = self.linear_fwd(lh.v, &xn1);
            xb_cache[lh.v.0] = xb;

            // one independent task per (batch, head): rope, causal
            // softmax, attn-weighted values — written back serially so
            // every output region has exactly one writer
            let heads = self.pool.map(bsz * nh, |ai| {
                let (bi, hi) = (ai / nh, ai % nh);
                let mut q_h = head_slice(&q, bi, hi, t, hd);
                let mut k_h = head_slice(&k, bi, hi, t, hd);
                let v_h = head_slice(&v, bi, hi, t, hd);
                self.rope_head(&mut q_h, half, false);
                self.rope_head(&mut k_h, half, false);
                // causal scores + row softmax
                let mut s = q_h.matmul_transb(&k_h);
                for i in 0..t {
                    let row = &mut s.data[i * t..(i + 1) * t];
                    let mut mx = f32::NEG_INFINITY;
                    for (j, val) in row.iter_mut().enumerate() {
                        if j > i {
                            *val = 0.0;
                        } else {
                            *val *= attn_scale;
                            mx = mx.max(*val);
                        }
                    }
                    let mut sum = 0.0f32;
                    for (j, val) in row.iter_mut().enumerate() {
                        if j > i {
                            *val = 0.0;
                        } else {
                            *val = (*val - mx).exp();
                            sum += *val;
                        }
                    }
                    for val in row.iter_mut() {
                        *val /= sum;
                    }
                }
                let out_h = s.matmul(&v_h);
                (q_h, k_h, s, out_h)
            });
            let mut attn_cat = Matrix::zeros(n, d);
            let mut probs = Vec::with_capacity(bsz * nh);
            for (ai, (q_h, k_h, s, out_h)) in heads.into_iter().enumerate() {
                let (bi, hi) = (ai / nh, ai % nh);
                head_write(&mut attn_cat, &out_h, bi, hi, t, hd);
                // cache post-rope q/k for the backward pass
                head_write(&mut q, &q_h, bi, hi, t, hd);
                head_write(&mut k, &k_h, bi, hi, t, hd);
                probs.push(s);
            }

            let (o_out, xb) = self.linear_fwd(lh.o, &attn_cat);
            xb_cache[lh.o.0] = xb;
            let x_mid = x.add(&o_out);

            let g2 = self.vec1(lh.ln2_g);
            let (xn2, xhat2, r2) = rmsnorm_fwd(&x_mid, g2);
            let (g_pre, xb) = self.linear_fwd(lh.gate, &xn2);
            xb_cache[lh.gate.0] = xb;
            let (u, xb) = self.linear_fwd(lh.up, &xn2);
            xb_cache[lh.up.0] = xb;
            let mut h_act = Matrix::zeros(n, p.d_ff);
            for i in 0..h_act.data.len() {
                let g = g_pre.data[i];
                h_act.data[i] = g * sigmoid(g) * u.data[i];
            }
            let (d_out, xb) = self.linear_fwd(lh.down, &h_act);
            xb_cache[lh.down.0] = xb;
            let x_out = x_mid.add(&d_out);

            blocks.push(BlockCache {
                xhat1,
                r1,
                xn1,
                q,
                k,
                v,
                probs,
                attn_cat,
                xhat2,
                r2,
                xn2,
                g_pre,
                u,
                h: h_act,
            });
            x = x_out;
        }

        let gf = self.vec1(h.lnf_g);
        let (xnf, xhatf, rf) = rmsnorm_fwd(&x, gf);
        let logits = xnf.matmul_par(self.mat(h.head), &self.pool);
        let cache =
            FwdCache { tokens: tokens.to_vec(), bsz, t, blocks, xb: xb_cache, xhatf, rf, xnf };
        Ok((logits, cache))
    }

    fn rope_head(&self, m: &mut Matrix, half: usize, inverse: bool) {
        for ti in 0..m.rows {
            let row = &mut m.data[ti * 2 * half..(ti + 1) * 2 * half];
            for j in 0..half {
                let c = self.rope_cos[ti * half + j];
                let s = self.rope_sin[ti * half + j];
                let (x1, x2) = (row[2 * j], row[2 * j + 1]);
                if inverse {
                    row[2 * j] = x1 * c + x2 * s;
                    row[2 * j + 1] = -x1 * s + x2 * c;
                } else {
                    row[2 * j] = x1 * c - x2 * s;
                    row[2 * j + 1] = x1 * s + x2 * c;
                }
            }
        }
    }

    // ---------------------------------------------------- backward

    fn backward(&self, cache: &FwdCache, dlogits: &Matrix) -> Result<Grads> {
        let h = self.handles()?.clone();
        let p = &self.preset;
        let (d, nh, hd) = (p.d_model, p.n_heads, self.head_dim());
        let (bsz, t) = (cache.bsz, cache.t);
        let attn_scale = 1.0f32 / (hd as f32).sqrt();
        let half = hd / 2;
        let mut grads: Grads = vec![Vec::new(); self.params.len()];

        // head + final norm
        let head = self.mat(h.head);
        let dhead = cache.xnf.transpose().matmul_par(dlogits, &self.pool);
        acc_grad(&mut grads, h.head, &dhead.data);
        let dxnf = dlogits.matmul_transb_par(head, &self.pool);
        let gf = self.vec1(h.lnf_g);
        let mut dgf = vec![0.0f32; d];
        let mut dx = rmsnorm_bwd(&dxnf, &cache.xhatf, &cache.rf, gf, &mut dgf);
        acc_grad(&mut grads, h.lnf_g, &dgf);

        for (l, blk) in cache.blocks.iter().enumerate().rev() {
            let lh = h.layers[l];
            // ---- mlp branch: x_out = x_mid + down(silu(gate)·up)
            let h_t = blk.h.transpose();
            let dh = self.linear_bwd(
                lh.down,
                &h_t,
                &blk.h,
                cache.xb[lh.down.0].as_ref(),
                &dx,
                &mut grads,
            );
            let mut dg_pre = Matrix::zeros(dh.rows, dh.cols);
            let mut du = Matrix::zeros(dh.rows, dh.cols);
            for i in 0..dh.data.len() {
                let g = blk.g_pre.data[i];
                let s = sigmoid(g);
                du.data[i] = dh.data[i] * g * s;
                dg_pre.data[i] = dh.data[i] * blk.u.data[i] * s * (1.0 + g * (1.0 - s));
            }
            let xn2_t = blk.xn2.transpose();
            let mut dxn2 = self.linear_bwd(
                lh.gate,
                &xn2_t,
                &blk.xn2,
                cache.xb[lh.gate.0].as_ref(),
                &dg_pre,
                &mut grads,
            );
            add_into(
                &mut dxn2,
                &self.linear_bwd(
                    lh.up,
                    &xn2_t,
                    &blk.xn2,
                    cache.xb[lh.up.0].as_ref(),
                    &du,
                    &mut grads,
                ),
            );
            let g2 = self.vec1(lh.ln2_g);
            let mut dg2 = vec![0.0f32; d];
            let dnorm2 = rmsnorm_bwd(&dxn2, &blk.xhat2, &blk.r2, g2, &mut dg2);
            acc_grad(&mut grads, lh.ln2_g, &dg2);
            let dx_mid = dx.add(&dnorm2);

            // ---- attention branch: x_mid = x_in + o(attn)
            let cat_t = blk.attn_cat.transpose();
            let dcat = self.linear_bwd(
                lh.o,
                &cat_t,
                &blk.attn_cat,
                cache.xb[lh.o.0].as_ref(),
                &dx_mid,
                &mut grads,
            );
            // per-(batch, head) softmax/rope backward, one task each
            let head_grads = self.pool.map(bsz * nh, |ai| {
                let (bi, hi) = (ai / nh, ai % nh);
                let dout_h = head_slice(&dcat, bi, hi, t, hd);
                let q_h = head_slice(&blk.q, bi, hi, t, hd);
                let k_h = head_slice(&blk.k, bi, hi, t, hd);
                let v_h = head_slice(&blk.v, bi, hi, t, hd);
                let probs = &blk.probs[bi * nh + hi];
                let dp = dout_h.matmul_transb(&v_h);
                let dv_h = probs.transpose().matmul(&dout_h);
                // softmax backward; masked entries have prob 0
                let mut ds = Matrix::zeros(t, t);
                for i in 0..t {
                    let prow = &probs.data[i * t..(i + 1) * t];
                    let dprow = &dp.data[i * t..(i + 1) * t];
                    let dot: f32 = prow.iter().zip(dprow).map(|(a, b)| a * b).sum();
                    for j in 0..=i {
                        ds.data[i * t + j] = prow[j] * (dprow[j] - dot);
                    }
                }
                let mut dq_h = ds.matmul(&k_h).scale(attn_scale);
                let mut dk_h = ds.transpose().matmul(&q_h).scale(attn_scale);
                self.rope_head(&mut dq_h, half, true);
                self.rope_head(&mut dk_h, half, true);
                (dq_h, dk_h, dv_h)
            });
            let mut dq = Matrix::zeros(bsz * t, d);
            let mut dk = Matrix::zeros(bsz * t, d);
            let mut dv = Matrix::zeros(bsz * t, d);
            for (ai, (dq_h, dk_h, dv_h)) in head_grads.into_iter().enumerate() {
                let (bi, hi) = (ai / nh, ai % nh);
                head_write_add(&mut dq, &dq_h, bi, hi, t, hd);
                head_write_add(&mut dk, &dk_h, bi, hi, t, hd);
                head_write_add(&mut dv, &dv_h, bi, hi, t, hd);
            }
            let xn1_t = blk.xn1.transpose();
            let mut dxn1 = self.linear_bwd(
                lh.q,
                &xn1_t,
                &blk.xn1,
                cache.xb[lh.q.0].as_ref(),
                &dq,
                &mut grads,
            );
            add_into(
                &mut dxn1,
                &self.linear_bwd(
                    lh.k,
                    &xn1_t,
                    &blk.xn1,
                    cache.xb[lh.k.0].as_ref(),
                    &dk,
                    &mut grads,
                ),
            );
            add_into(
                &mut dxn1,
                &self.linear_bwd(
                    lh.v,
                    &xn1_t,
                    &blk.xn1,
                    cache.xb[lh.v.0].as_ref(),
                    &dv,
                    &mut grads,
                ),
            );
            let g1 = self.vec1(lh.ln1_g);
            let mut dg1 = vec![0.0f32; d];
            let dnorm1 = rmsnorm_bwd(&dxn1, &blk.xhat1, &blk.r1, g1, &mut dg1);
            acc_grad(&mut grads, lh.ln1_g, &dg1);
            dx = dx_mid.add(&dnorm1);
        }

        // embedding scatter (serial: token collisions share rows)
        let embed_numel = self.params[h.embed.0].numel();
        let ge = &mut grads[h.embed.0];
        if ge.is_empty() {
            ge.resize(embed_numel, 0.0);
        }
        for (i, &tok) in cache.tokens.iter().enumerate() {
            let tok = tok as usize;
            for j in 0..d {
                ge[tok * d + j] += dx.data[i * d + j];
            }
        }
        Ok(grads)
    }

    // ------------------------------------------------- loss + adam

    /// Train-loss forward + backward (no update). The split from
    /// `adam_apply` keeps gradients observable for verification.
    fn loss_and_grads(&self, tokens: &[i32]) -> Result<(f64, Grads)> {
        let (inputs, targets, t_in) = split_next_token(tokens, self.batch, self.preset.seq_len)?;
        let (logits, cache) = self.forward_cached(&inputs, self.batch, t_in)?;
        let (loss, dlogits) = ce_loss_grad(&logits, &targets)?;
        let grads = self.backward(&cache, &dlogits)?;
        Ok((loss, grads))
    }

    fn loss_only(&self, tokens: &[i32], bsz: usize) -> Result<f64> {
        let (inputs, targets, t_in) = split_next_token(tokens, bsz, self.preset.seq_len)?;
        let (logits, _) = self.forward_cached(&inputs, bsz, t_in)?;
        ce_loss(&logits, &targets)
    }

    /// Linear warmup then cosine decay to 10% (optim.lr_schedule).
    fn warmup_steps(&self) -> f32 {
        (self.total_steps as f32 * 0.05).clamp(1.0, WARMUP_CAP)
    }

    fn lr_at(&self, step: i32) -> f32 {
        let s = step.max(0) as f32;
        let warmup = self.warmup_steps();
        if s < warmup {
            return self.lr * s / warmup;
        }
        let total = self.total_steps as f32;
        let prog = ((s - warmup) / (total - warmup).max(1.0)).clamp(0.0, 1.0);
        self.lr * (0.1 + 0.45 * (1.0 + (std::f32::consts::PI * prog).cos()))
    }

    fn adam_apply(&mut self, step: i32, grads: &Grads) -> Result<()> {
        if self.adam_m.len() != self.params.len() || self.adam_v.len() != self.params.len() {
            bail!("optimizer state dropped or uninitialized");
        }
        let lr_t = self.lr_at(step);
        let t = step.max(0) as f32 + 1.0;
        let bc1 = 1.0 - ADAM_B1.powf(t);
        let bc2 = 1.0 - ADAM_B2.powf(t);
        for (idx, g) in grads.iter().enumerate() {
            if g.is_empty() {
                continue;
            }
            let p = self.params[idx].data_mut();
            let m = &mut self.adam_m[idx];
            let v = &mut self.adam_v[idx];
            if g.len() != p.len() {
                bail!("{}: grad numel {} != param {}", self.param_names[idx], g.len(), p.len());
            }
            for i in 0..p.len() {
                m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g[i];
                v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g[i] * g[i];
                let upd = (m[i] / bc1) / ((v[i] / bc2).sqrt() + ADAM_EPS);
                p[i] -= lr_t * upd;
            }
        }
        Ok(())
    }
}

// ----------------------------------------------------- trait impl

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn method(&self) -> &str {
        &self.method
    }

    fn preset(&self) -> &ModelPreset {
        &self.preset
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn n_params(&self) -> usize {
        if self.params.is_empty() {
            // not yet initialized: the config formula (verified equal to
            // the instantiated sum in tests)
            return self.preset.param_count(&self.method);
        }
        self.params.iter().map(|t| t.numel()).sum()
    }

    fn init_state(&mut self, seed: u32) -> Result<()> {
        self.init_params(seed);
        Ok(())
    }

    fn train_step(&mut self, step: i32, tokens: &[i32]) -> Result<f32> {
        self.handles()?;
        let (loss, grads) = self.loss_and_grads(tokens)?;
        self.adam_apply(step, &grads)?;
        Ok(loss as f32)
    }

    fn eval_loss(&mut self, tokens: &[i32]) -> Result<f32> {
        self.handles()?;
        Ok(self.loss_only(tokens, self.batch)? as f32)
    }

    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.handles()?;
        let t = self.preset.seq_len;
        if tokens.len() % t != 0 {
            bail!("forward expects a multiple of seq_len {t} tokens");
        }
        let bsz = tokens.len() / t;
        let (logits, _) = self.forward_cached(tokens, bsz, t)?;
        Ok(logits.data)
    }

    fn drop_optimizer_state(&mut self) -> Result<()> {
        self.adam_m.clear();
        self.adam_v.clear();
        Ok(())
    }

    fn state_tensors(&self) -> Result<Vec<StateTensor>> {
        self.handles()?;
        let mut out = Vec::with_capacity(self.params.len() + self.supports.len());
        // name order (the interchange contract of the old map layout)
        for (name, &id) in &self.name_to_id {
            let t = &self.params[id];
            out.push(StateTensor::f32(name, t.shape(), t.data()));
        }
        let mut sups: Vec<(&String, &SparseSupport)> =
            self.support_paths.iter().zip(&self.supports).collect();
        sups.sort_by(|a, b| a.0.cmp(b.0));
        for (path, sup) in sups {
            let idx: Vec<i32> = sup.idx.iter().map(|&i| i as i32).collect();
            out.push(StateTensor::i32(&format!("{path}.idx"), vec![sup.nnz()], &idx));
        }
        Ok(out)
    }

    fn load_state_tensors(&mut self, tensors: &[StateTensor]) -> Result<()> {
        self.handles()?;
        // Stage and validate everything BEFORE mutating, so a mismatched
        // or corrupt checkpoint leaves the backend untouched (and support
        // indices never reach SparseSupport::new's panicking asserts).
        let mut staged_supports: Vec<(usize, SparseSupport)> = Vec::new();
        let mut staged_params: Vec<(usize, Vec<f32>)> = Vec::new();
        for st in tensors {
            if let Some(path) = st.name.strip_suffix(".idx") {
                let si = self
                    .support_paths
                    .iter()
                    .position(|p| p == path)
                    .ok_or_else(|| anyhow!("unknown support {:?}", st.name))?;
                let sup = &self.supports[si];
                let idx: Vec<u32> = st.to_i32()?.iter().map(|&i| i as u32).collect();
                let bound = (sup.d_in * sup.d_out) as u32;
                if !idx.windows(2).all(|w| w[0] < w[1]) {
                    bail!("{}: support not sorted-distinct", st.name);
                }
                if idx.iter().any(|&i| i >= bound) {
                    bail!("{}: support index out of range {bound}", st.name);
                }
                staged_supports.push((si, SparseSupport::new(sup.d_in, sup.d_out, idx)));
            } else {
                let data = st.to_f32()?;
                let &id = self
                    .name_to_id
                    .get(&st.name)
                    .ok_or_else(|| anyhow!("unknown tensor {:?}", st.name))?;
                if self.params[id].numel() != data.len() {
                    bail!(
                        "{}: numel {} != expected {}",
                        st.name,
                        data.len(),
                        self.params[id].numel()
                    );
                }
                staged_params.push((id, data));
            }
        }
        // cross-check: each reloaded support must agree with the values
        // tensor that will accompany it (staged if present, current else)
        for (si, sup) in &staged_supports {
            let vals_name = format!("{}.vals", self.support_paths[*si]);
            let vals_id = self.name_to_id.get(&vals_name).copied().ok_or_else(|| {
                anyhow!("{}: support without values tensor", self.support_paths[*si])
            })?;
            let vals_len = staged_params
                .iter()
                .find(|(id, _)| *id == vals_id)
                .map(|(_, d)| d.len())
                .unwrap_or_else(|| self.params[vals_id].numel());
            if vals_len != sup.nnz() {
                bail!(
                    "{}: support nnz {} != values len {vals_len}",
                    self.support_paths[*si],
                    sup.nnz()
                );
            }
        }
        for (si, sup) in staged_supports {
            self.supports[si] = sup;
        }
        for (id, data) in staged_params {
            self.params[id].data_mut().copy_from_slice(&data);
        }
        Ok(())
    }
}

// ------------------------------------------------------- math helpers

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Row-wise RMSNorm with gain: returns (x̂·g, x̂, 1/rms per row).
fn rmsnorm_fwd(x: &Matrix, g: &[f32]) -> (Matrix, Matrix, Vec<f32>) {
    let d = x.cols;
    assert_eq!(g.len(), d);
    let mut y = Matrix::zeros(x.rows, d);
    let mut xhat = Matrix::zeros(x.rows, d);
    let mut inv_rms = vec![0.0f32; x.rows];
    for i in 0..x.rows {
        let row = &x.data[i * d..(i + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        inv_rms[i] = r;
        for j in 0..d {
            let xh = row[j] * r;
            xhat.data[i * d + j] = xh;
            y.data[i * d + j] = xh * g[j];
        }
    }
    (y, xhat, inv_rms)
}

/// RMSNorm backward: dx = r·(dx̂ − x̂·mean(dx̂⊙x̂)), dg += Σ_rows dy⊙x̂.
fn rmsnorm_bwd(dy: &Matrix, xhat: &Matrix, inv_rms: &[f32], g: &[f32], dg: &mut [f32]) -> Matrix {
    let d = dy.cols;
    let mut dx = Matrix::zeros(dy.rows, d);
    for i in 0..dy.rows {
        let dyr = &dy.data[i * d..(i + 1) * d];
        let xhr = &xhat.data[i * d..(i + 1) * d];
        let mut dot = 0.0f32;
        for j in 0..d {
            dg[j] += dyr[j] * xhr[j];
            dot += dyr[j] * g[j] * xhr[j];
        }
        dot /= d as f32;
        let r = inv_rms[i];
        for j in 0..d {
            dx.data[i * d + j] = r * (dyr[j] * g[j] - xhr[j] * dot);
        }
    }
    dx
}

/// Copy head `h` of batch row-block `bi` out of an [bsz*t, n_heads*hd]
/// matrix into a contiguous [t, hd] one.
fn head_slice(x: &Matrix, bi: usize, h: usize, t: usize, hd: usize) -> Matrix {
    let d = x.cols;
    let mut out = Matrix::zeros(t, hd);
    for ti in 0..t {
        let src = &x.data[(bi * t + ti) * d + h * hd..(bi * t + ti) * d + (h + 1) * hd];
        out.data[ti * hd..(ti + 1) * hd].copy_from_slice(src);
    }
    out
}

fn head_write(dst: &mut Matrix, src: &Matrix, bi: usize, h: usize, t: usize, hd: usize) {
    let d = dst.cols;
    for ti in 0..t {
        let s = &src.data[ti * hd..(ti + 1) * hd];
        dst.data[(bi * t + ti) * d + h * hd..(bi * t + ti) * d + (h + 1) * hd]
            .copy_from_slice(s);
    }
}

fn head_write_add(dst: &mut Matrix, src: &Matrix, bi: usize, h: usize, t: usize, hd: usize) {
    let d = dst.cols;
    for ti in 0..t {
        for j in 0..hd {
            dst.data[(bi * t + ti) * d + h * hd + j] += src.data[ti * hd + j];
        }
    }
}

fn add_into(dst: &mut Matrix, src: &Matrix) {
    assert_eq!(dst.data.len(), src.data.len());
    for (a, b) in dst.data.iter_mut().zip(&src.data) {
        *a += b;
    }
}

/// Next-token split of a [bsz, seq] batch: inputs drop the last column,
/// targets drop the first. Returns (inputs, targets, seq-1).
fn split_next_token(tokens: &[i32], bsz: usize, seq: usize) -> Result<(Vec<i32>, Vec<i32>, usize)> {
    if tokens.len() != bsz * seq {
        bail!("expected {bsz}x{seq} tokens, got {}", tokens.len());
    }
    let t_in = seq - 1;
    let mut inputs = Vec::with_capacity(bsz * t_in);
    let mut targets = Vec::with_capacity(bsz * t_in);
    for b in 0..bsz {
        let row = &tokens[b * seq..(b + 1) * seq];
        inputs.extend_from_slice(&row[..t_in]);
        targets.extend_from_slice(&row[1..]);
    }
    Ok((inputs, targets, t_in))
}

/// Mean next-token cross-entropy (f64 accumulation for stability).
fn ce_loss(logits: &Matrix, targets: &[i32]) -> Result<f64> {
    let (n, v) = (logits.rows, logits.cols);
    if targets.len() != n {
        bail!("{n} logit rows but {} targets", targets.len());
    }
    let mut total = 0.0f64;
    for i in 0..n {
        let row = &logits.data[i * v..(i + 1) * v];
        let tgt = targets[i] as usize;
        if tgt >= v {
            bail!("target {tgt} out of vocab {v}");
        }
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f64 = row.iter().map(|&x| ((x - mx) as f64).exp()).sum();
        total += mx as f64 + sum.ln() - row[tgt] as f64;
    }
    Ok(total / n as f64)
}

/// CE loss plus dL/dlogits = (softmax − onehot)/n.
fn ce_loss_grad(logits: &Matrix, targets: &[i32]) -> Result<(f64, Matrix)> {
    let (n, v) = (logits.rows, logits.cols);
    if targets.len() != n {
        bail!("{n} logit rows but {} targets", targets.len());
    }
    let mut dl = Matrix::zeros(n, v);
    let inv_n = 1.0f32 / n as f32;
    let mut total = 0.0f64;
    for i in 0..n {
        let row = &logits.data[i * v..(i + 1) * v];
        let tgt = targets[i] as usize;
        if tgt >= v {
            bail!("target {tgt} out of vocab {v}");
        }
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f64 = row.iter().map(|&x| ((x - mx) as f64).exp()).sum();
        total += mx as f64 + sum.ln() - row[tgt] as f64;
        for j in 0..v {
            let p = (((row[j] - mx) as f64).exp() / sum) as f32;
            dl.data[i * v + j] = p * inv_n;
        }
        dl.data[i * v + tgt] -= inv_n;
    }
    Ok((total / n as f64, dl))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro_preset() -> ModelPreset {
        ModelPreset {
            name: "micro".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            seq_len: 12,
            rank: 4,
            delta: 0.05,
            alpha: 8.0,
            d_ff: 32,
        }
    }

    fn micro_backend_threads(method: &str, seed: u32, threads: usize) -> NativeBackend {
        let mut be = NativeBackend::build(micro_preset(), method, 2, 3e-3, 100, threads).unwrap();
        be.init_state(seed).unwrap();
        be
    }

    fn micro_backend(method: &str, seed: u32) -> NativeBackend {
        micro_backend_threads(method, seed, 2)
    }

    fn random_tokens(be: &NativeBackend, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..be.batch * be.preset.seq_len)
            .map(|_| rng.below(be.preset.vocab as u64) as i32)
            .collect()
    }

    /// Central-difference check of the full manual backward pass, for
    /// every supported parameterization. For each parameter tensor the
    /// entry with the largest analytic gradient is perturbed.
    #[test]
    fn gradients_match_finite_differences() {
        for method in ["full", "lowrank", "sltrain"] {
            let mut be = micro_backend(method, 3);
            let tokens = random_tokens(&be, 11);
            let (_, grads) = be.loss_and_grads(&tokens).unwrap();
            for pid in 0..grads.len() {
                let g = &grads[pid];
                if g.is_empty() {
                    continue;
                }
                let name = be.param_names[pid].clone();
                let (idx, &ga) = g
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                if ga.abs() < 5e-3 {
                    continue; // too small to measure through f32 noise
                }
                let h = 1e-2f32;
                let orig = be.params[pid].data()[idx];
                be.params[pid].data_mut()[idx] = orig + h;
                let lp = be.loss_only(&tokens, be.batch).unwrap();
                be.params[pid].data_mut()[idx] = orig - h;
                let lm = be.loss_only(&tokens, be.batch).unwrap();
                be.params[pid].data_mut()[idx] = orig;
                let gn = ((lp - lm) / (2.0 * h as f64)) as f32;
                let rel = (ga - gn).abs() / gn.abs().max(ga.abs()).max(1e-4);
                assert!(
                    rel < 0.08,
                    "{method}/{name}[{idx}]: analytic {ga:.6} vs numeric {gn:.6} (rel {rel:.3})"
                );
            }
        }
    }

    #[test]
    fn n_params_matches_preset_formula() {
        for method in ["full", "lowrank", "sltrain"] {
            let be = micro_backend(method, 0);
            assert_eq!(
                be.n_params(),
                be.preset.param_count(method),
                "{method}: n_params vs config formula"
            );
        }
    }

    #[test]
    fn training_is_deterministic() {
        let mut runs = vec![];
        for _ in 0..2 {
            let mut be = micro_backend("sltrain", 42);
            let tokens = random_tokens(&be, 7);
            let mut losses = vec![];
            for step in 0..3 {
                losses.push(be.train_step(step, &tokens).unwrap());
            }
            runs.push(losses);
        }
        assert_eq!(runs[0], runs[1], "same seed must reproduce bit-identical losses");
    }

    /// The parallelism contract: the pool partitions independent tasks
    /// only, so losses are bit-identical across *different* thread
    /// counts, not just across runs at a fixed one.
    #[test]
    fn losses_bit_identical_across_thread_counts() {
        let mut runs = vec![];
        for threads in [1usize, 2, 3] {
            let mut be = micro_backend_threads("sltrain", 5, threads);
            let tokens = random_tokens(&be, 9);
            let mut losses = vec![];
            for step in 0..3 {
                losses.push(be.train_step(step, &tokens).unwrap());
            }
            runs.push(losses);
        }
        assert_eq!(runs[0], runs[1], "1 vs 2 threads");
        assert_eq!(runs[0], runs[2], "1 vs 3 threads");
    }

    #[test]
    fn loss_starts_near_uniform_and_decreases() {
        let mut be = micro_backend("sltrain", 1);
        let tokens = random_tokens(&be, 5);
        let ln_v = (be.preset.vocab as f64).ln();
        let first = be.train_step(0, &tokens).unwrap() as f64;
        // Kaiming head init gives logit variance 2, lifting the expected
        // initial CE to ≈ ln|V| + 1
        assert!((first - ln_v).abs() < 1.6, "init loss {first} vs ln|V| {ln_v}");
        let mut last = first;
        for step in 1..40 {
            last = be.train_step(step, &tokens).unwrap() as f64;
        }
        // one repeated batch: must overfit decisively
        assert!(last < first - 0.5, "{first} -> {last}");
    }

    #[test]
    fn state_roundtrip_preserves_eval() {
        let mut be = micro_backend("sltrain", 9);
        let tokens = random_tokens(&be, 3);
        for step in 0..3 {
            be.train_step(step, &tokens).unwrap();
        }
        let snap = be.state_tensors().unwrap();
        let before = be.eval_loss(&tokens).unwrap();
        let mut be2 = micro_backend("sltrain", 1234); // different init
        be2.load_state_tensors(&snap).unwrap();
        let after = be2.eval_loss(&tokens).unwrap();
        assert!(
            (before - after).abs() < 1e-6,
            "restored eval {after} != source {before}"
        );
    }

    #[test]
    fn forward_shape_and_merge_unsupported() {
        let mut be = micro_backend("full", 2);
        let tokens = random_tokens(&be, 1);
        let logits = be.forward(&tokens).unwrap();
        assert_eq!(logits.len(), be.batch * be.preset.seq_len * be.preset.vocab);
        assert!(be.merge(0).is_err());
    }

    #[test]
    fn lr_schedule_warms_up_then_decays() {
        let be = micro_backend("full", 0);
        // total_steps=100 for the micro backend -> 5 warmup steps
        assert_eq!(be.lr_at(0), 0.0);
        assert!(be.lr_at(2) < be.lr_at(4));
        assert!((be.lr_at(5) - be.lr).abs() / be.lr < 1e-3);
        assert!((be.lr_at(10_000) - 0.1 * be.lr).abs() < 1e-6);
        // at the aot.py-default horizon the warmup is exactly 100 steps
        let long = NativeBackend::build(micro_preset(), "full", 2, 3e-3, 2000, 1).unwrap();
        assert_eq!(long.warmup_steps(), 100.0);
    }
}

//! NativeBackend: the pure-rust SLTrain trainer.
//!
//! A from-scratch implementation of the paper's pretraining setup on
//! `linalg::Matrix` + `linalg::sparse` — LLaMA-shaped blocks (RMSNorm,
//! rotary attention, SwiGLU), full manual forward/backward, and Adam
//! with the GaLore-repo warmup+cosine schedule, over the `full`,
//! `lowrank` and `sltrain` weight parameterizations of
//! `python/compile/layers.py`:
//!
//!   full     y = x W
//!   lowrank  y = scale · (x B) A
//!   sltrain  y = scale · (x B) A + x S       (S fixed-support sparse)
//!
//! Like the paper's kernels (and unlike the densifying oracle), the hot
//! loop never materializes the dense `W = scale·BA ⊕ S` nor its
//! gradient: the sparse contribution flows through `SparseSupport::spmm`
//! / `spmm_t`, and the sparse value gradient is gathered straight off
//! the support (`scatter_grad`, eq. 2). Every `dy @ W^T`-shaped product
//! uses `Matrix::matmul_transb` with the transpose hoisted.
//!
//! No artifacts, no XLA, no Python: this backend is the deterministic
//! reference the AOT/PJRT path is parity-tested against, and the engine
//! behind `sltrain train --backend native`.

use std::collections::BTreeMap;

use anyhow::{anyhow, bail, Result};

use super::{Backend, StateTensor};
use crate::config::ModelPreset;
use crate::linalg::{Matrix, SparseSupport};
use crate::util::rng::Rng;

const ADAM_B1: f32 = 0.9;
const ADAM_B2: f32 = 0.999;
const ADAM_EPS: f32 = 1e-8;
/// Warmup cap, mirroring aot.py's default (100 steps at the default
/// 2000-step horizon); shorter runs warm up over 5% of their horizon.
const WARMUP_CAP: f32 = 100.0;
const RMS_EPS: f32 = 1e-6;
const ROPE_THETA: f32 = 10000.0;

// ------------------------------------------------------------- tensors

/// A named parameter: 2-d weights as `Matrix`, 1-d (norm gains, sparse
/// values) as flat vectors. Uniform flat access for Adam / checkpoints.
#[derive(Debug, Clone)]
enum PTensor {
    Mat(Matrix),
    Vec1(Vec<f32>),
}

impl PTensor {
    fn shape(&self) -> Vec<usize> {
        match self {
            PTensor::Mat(m) => vec![m.rows, m.cols],
            PTensor::Vec1(v) => vec![v.len()],
        }
    }

    fn numel(&self) -> usize {
        match self {
            PTensor::Mat(m) => m.data.len(),
            PTensor::Vec1(v) => v.len(),
        }
    }

    fn data(&self) -> &[f32] {
        match self {
            PTensor::Mat(m) => &m.data,
            PTensor::Vec1(v) => v,
        }
    }

    fn data_mut(&mut self) -> &mut [f32] {
        match self {
            PTensor::Mat(m) => &mut m.data,
            PTensor::Vec1(v) => v,
        }
    }

    fn mat(&self) -> &Matrix {
        match self {
            PTensor::Mat(m) => m,
            PTensor::Vec1(_) => panic!("tensor is 1-d, expected matrix"),
        }
    }

    fn vec(&self) -> &[f32] {
        match self {
            PTensor::Vec1(v) => v,
            PTensor::Mat(_) => panic!("tensor is 2-d, expected vector"),
        }
    }
}

// ----------------------------------------------------- forward caches

struct BlockCache {
    /// Normalized pre-gain input of ln1 and its 1/rms per row.
    xhat1: Matrix,
    r1: Vec<f32>,
    /// Gained ln1 output: the input of the q/k/v linears.
    xn1: Matrix,
    /// Post-rope q and k, and v, all [n, d].
    q: Matrix,
    k: Matrix,
    v: Matrix,
    /// Attention probabilities, one [t, t] matrix per (batch, head).
    probs: Vec<Matrix>,
    /// Concatenated attention output: the input of the o linear.
    attn_cat: Matrix,
    xhat2: Matrix,
    r2: Vec<f32>,
    /// Gained ln2 output: the input of the gate/up linears.
    xn2: Matrix,
    /// Gate pre-activation and up output (SwiGLU backward).
    g_pre: Matrix,
    u: Matrix,
    /// silu(g_pre) ⊙ u: the input of the down linear.
    h: Matrix,
    /// x @ B per factored linear path (reused by the backward pass).
    xb: BTreeMap<String, Matrix>,
}

struct FwdCache {
    tokens: Vec<i32>,
    bsz: usize,
    t: usize,
    blocks: Vec<BlockCache>,
    xhatf: Matrix,
    rf: Vec<f32>,
    /// Gained final-norm output: the input of the head matmul.
    xnf: Matrix,
}

type Grads = BTreeMap<String, Vec<f32>>;

// ------------------------------------------------------------ backend

pub struct NativeBackend {
    preset: ModelPreset,
    method: String,
    batch: usize,
    lr: f32,
    total_steps: usize,
    /// The paper's alpha/r balancing factor on B@A.
    scale: f32,
    params: BTreeMap<String, PTensor>,
    adam_m: BTreeMap<String, Vec<f32>>,
    adam_v: BTreeMap<String, Vec<f32>>,
    /// Fixed sparse supports keyed by linear path (sltrain only).
    supports: BTreeMap<String, SparseSupport>,
    /// RoPE tables, [seq_len * head_dim/2] row-major.
    rope_cos: Vec<f32>,
    rope_sin: Vec<f32>,
    initialized: bool,
}

impl NativeBackend {
    pub fn build(
        preset: ModelPreset,
        method: &str,
        batch: usize,
        lr: f32,
        total_steps: usize,
    ) -> Result<NativeBackend> {
        if !matches!(method, "full" | "lowrank" | "sltrain") {
            bail!("native backend supports full | lowrank | sltrain (got {method:?})");
        }
        if preset.d_model % preset.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", preset.d_model, preset.n_heads);
        }
        let hd = preset.d_model / preset.n_heads;
        if hd % 2 != 0 {
            bail!("head_dim {hd} must be even for rotary embeddings");
        }
        if preset.seq_len < 2 {
            bail!("seq_len {} too short for next-token training", preset.seq_len);
        }
        let half = hd / 2;
        let mut rope_cos = vec![0.0f32; preset.seq_len * half];
        let mut rope_sin = vec![0.0f32; preset.seq_len * half];
        for pos in 0..preset.seq_len {
            for j in 0..half {
                let freq = ROPE_THETA.powf(-((2 * j) as f32) / hd as f32);
                let ang = pos as f32 * freq;
                rope_cos[pos * half + j] = ang.cos();
                rope_sin[pos * half + j] = ang.sin();
            }
        }
        let scale = (preset.alpha / preset.rank as f64) as f32;
        Ok(NativeBackend {
            preset,
            method: method.to_string(),
            batch: batch.max(1),
            lr,
            total_steps: total_steps.max(1),
            scale,
            params: BTreeMap::new(),
            adam_m: BTreeMap::new(),
            adam_v: BTreeMap::new(),
            supports: BTreeMap::new(),
            rope_cos,
            rope_sin,
            initialized: false,
        })
    }

    fn head_dim(&self) -> usize {
        self.preset.d_model / self.preset.n_heads
    }

    fn param(&self, name: &str) -> Result<&PTensor> {
        self.params.get(name).ok_or_else(|| anyhow!("native state missing tensor {name:?}"))
    }

    fn param_mat(&self, name: &str) -> Result<&Matrix> {
        Ok(self.param(name)?.mat())
    }

    fn param_vec(&self, name: &str) -> Result<&[f32]> {
        Ok(self.param(name)?.vec())
    }

    fn ensure_init(&self) -> Result<()> {
        if !self.initialized {
            bail!("backend state not initialized (call init_state first)");
        }
        Ok(())
    }

    // -------------------------------------------------------- init

    /// Paper §3.3 init, mirroring python `model.init_fn` / `init_linear`:
    /// embed N(0, 0.02), head Kaiming, norm gains 1, per-linear Kaiming A
    /// (+ Kaiming B for lowrank, zero B + uniform ±1/√d_in values for
    /// sltrain), and one independent uniform support per linear.
    fn init_params(&mut self, seed: u32) {
        let p = self.preset.clone();
        let root = Rng::new(seed as u64);
        self.params.clear();
        self.supports.clear();

        let gauss_mat = |rng: &mut Rng, rows: usize, cols: usize, std: f32| {
            let mut m = Matrix::zeros(rows, cols);
            for x in &mut m.data {
                *x = rng.gaussian() as f32 * std;
            }
            m
        };

        let mut r_embed = root.fork(1);
        self.params.insert(
            "embed.w".into(),
            PTensor::Mat(gauss_mat(&mut r_embed, p.vocab, p.d_model, 0.02)),
        );
        let mut r_head = root.fork(2);
        let head_std = (2.0f32 / p.d_model as f32).sqrt();
        self.params.insert(
            "head.w".into(),
            PTensor::Mat(gauss_mat(&mut r_head, p.d_model, p.vocab, head_std)),
        );
        self.params.insert("lnf.g".into(), PTensor::Vec1(vec![1.0; p.d_model]));
        for i in 0..p.n_layers {
            self.params
                .insert(format!("layers.{i}.ln1.g"), PTensor::Vec1(vec![1.0; p.d_model]));
            self.params
                .insert(format!("layers.{i}.ln2.g"), PTensor::Vec1(vec![1.0; p.d_model]));
        }

        for (j, (path, d_in, d_out)) in p.linear_paths().into_iter().enumerate() {
            let base = root.fork(1000 + j as u64);
            let kaiming_in = (2.0f32 / d_in as f32).sqrt();
            let kaiming_r = (2.0f32 / p.rank as f32).sqrt();
            match self.method.as_str() {
                "full" => {
                    let mut r1 = base.fork(1);
                    self.params.insert(
                        format!("{path}.w"),
                        PTensor::Mat(gauss_mat(&mut r1, d_in, d_out, kaiming_in)),
                    );
                }
                "lowrank" => {
                    // lowrank cannot start at BA = 0 (no gradient to
                    // escape); Kaiming B as in [24]
                    let mut r1 = base.fork(1);
                    let mut r2 = base.fork(2);
                    self.params.insert(
                        format!("{path}.B"),
                        PTensor::Mat(gauss_mat(&mut r2, d_in, p.rank, kaiming_in)),
                    );
                    self.params.insert(
                        format!("{path}.A"),
                        PTensor::Mat(gauss_mat(&mut r1, p.rank, d_out, kaiming_r)),
                    );
                }
                "sltrain" => {
                    let mut r1 = base.fork(1);
                    let mut r2 = base.fork(2);
                    self.params.insert(
                        format!("{path}.B"),
                        PTensor::Mat(Matrix::zeros(d_in, p.rank)),
                    );
                    self.params.insert(
                        format!("{path}.A"),
                        PTensor::Mat(gauss_mat(&mut r1, p.rank, d_out, kaiming_r)),
                    );
                    let mut r_sup = base.fork(3);
                    let sup = SparseSupport::random(d_in, d_out, p.delta, &mut r_sup);
                    let bound = 1.0f32 / (d_in as f32).sqrt();
                    let vals: Vec<f32> =
                        (0..sup.nnz()).map(|_| r2.range_f32(-bound, bound)).collect();
                    self.params.insert(format!("{path}.vals"), PTensor::Vec1(vals));
                    self.supports.insert(path.clone(), sup);
                }
                _ => unreachable!("validated in build"),
            }
        }

        self.adam_m.clear();
        self.adam_v.clear();
        for (name, t) in &self.params {
            self.adam_m.insert(name.clone(), vec![0.0; t.numel()]);
            self.adam_v.insert(name.clone(), vec![0.0; t.numel()]);
        }
        self.initialized = true;
    }

    // ----------------------------------------------------- linears

    /// Apply the `path` linear to x [n, d_in]. Returns (y, x@B cache).
    fn linear_fwd(&self, path: &str, x: &Matrix) -> Result<(Matrix, Option<Matrix>)> {
        match self.method.as_str() {
            "full" => {
                let w = self.param_mat(&format!("{path}.w"))?;
                Ok((x.matmul(w), None))
            }
            "lowrank" | "sltrain" => {
                let b = self.param_mat(&format!("{path}.B"))?;
                let a = self.param_mat(&format!("{path}.A"))?;
                let xb = x.matmul(b);
                let mut y = xb.matmul(a);
                for v in &mut y.data {
                    *v *= self.scale;
                }
                if self.method == "sltrain" {
                    let sup = self
                        .supports
                        .get(path)
                        .ok_or_else(|| anyhow!("missing support for {path}"))?;
                    let vals = self.param_vec(&format!("{path}.vals"))?;
                    sup.spmm_add(x, vals, &mut y);
                }
                Ok((y, Some(xb)))
            }
            m => bail!("unsupported method {m:?}"),
        }
    }

    /// Backward of the `path` linear: accumulates parameter grads into
    /// `grads` and returns dL/dx. `xt` is the transposed input (hoisted
    /// by the caller — q/k/v and gate/up share one transpose).
    fn linear_bwd(
        &self,
        path: &str,
        xt: &Matrix,
        x: &Matrix,
        xb: Option<&Matrix>,
        dy: &Matrix,
        grads: &mut Grads,
    ) -> Result<Matrix> {
        match self.method.as_str() {
            "full" => {
                let w = self.param_mat(&format!("{path}.w"))?;
                let dw = xt.matmul(dy);
                acc_grad(grads, &format!("{path}.w"), &dw.data);
                Ok(dy.matmul_transb(w))
            }
            "lowrank" | "sltrain" => {
                let b = self.param_mat(&format!("{path}.B"))?;
                let a = self.param_mat(&format!("{path}.A"))?;
                let xb = xb.ok_or_else(|| anyhow!("{path}: missing x@B cache"))?;
                // eq. (2): the dense d_in × d_out gradient is never formed
                let dy_at = dy.matmul_transb(a); // [n, r]
                let db = xt.matmul(&dy_at).scale(self.scale);
                let da = xb.transpose().matmul(dy).scale(self.scale);
                acc_grad(grads, &format!("{path}.B"), &db.data);
                acc_grad(grads, &format!("{path}.A"), &da.data);
                let mut dx = dy_at.matmul_transb(b).scale(self.scale);
                if self.method == "sltrain" {
                    let sup = self
                        .supports
                        .get(path)
                        .ok_or_else(|| anyhow!("missing support for {path}"))?;
                    let vals = self.param_vec(&format!("{path}.vals"))?;
                    let dvals = sup.scatter_grad(x, dy);
                    acc_grad(grads, &format!("{path}.vals"), &dvals);
                    sup.spmm_t_add(dy, vals, &mut dx);
                }
                Ok(dx)
            }
            m => bail!("unsupported method {m:?}"),
        }
    }

    // ----------------------------------------------------- forward

    /// Full cached forward over `tokens` ([bsz, t] row-major). Returns
    /// logits [bsz*t, vocab] plus everything the backward pass needs.
    fn forward_cached(&self, tokens: &[i32], bsz: usize, t: usize) -> Result<(Matrix, FwdCache)> {
        self.ensure_init()?;
        let p = &self.preset;
        let (d, nh, hd) = (p.d_model, p.n_heads, self.head_dim());
        let half = hd / 2;
        let n = bsz * t;
        if tokens.len() != n {
            bail!("forward expects {bsz}x{t} tokens, got {}", tokens.len());
        }
        if t > p.seq_len {
            bail!("sequence {t} exceeds preset seq_len {}", p.seq_len);
        }

        let embed = self.param_mat("embed.w")?;
        let mut x = Matrix::zeros(n, d);
        for (i, &tok) in tokens.iter().enumerate() {
            let tok = tok as usize;
            if tok >= p.vocab {
                bail!("token {tok} out of vocab {}", p.vocab);
            }
            x.data[i * d..(i + 1) * d].copy_from_slice(&embed.data[tok * d..(tok + 1) * d]);
        }

        let attn_scale = 1.0f32 / (hd as f32).sqrt();
        let mut blocks = Vec::with_capacity(p.n_layers);
        for l in 0..p.n_layers {
            let pfx = format!("layers.{l}");
            let mut xb_cache = BTreeMap::new();
            let mut stash = |path: String, xb: Option<Matrix>| {
                if let Some(m) = xb {
                    xb_cache.insert(path, m);
                }
            };

            let g1 = self.param_vec(&format!("{pfx}.ln1.g"))?;
            let (xn1, xhat1, r1) = rmsnorm_fwd(&x, g1);

            let (mut q, xb) = self.linear_fwd(&format!("{pfx}.attn.q"), &xn1)?;
            stash(format!("{pfx}.attn.q"), xb);
            let (mut k, xb) = self.linear_fwd(&format!("{pfx}.attn.k"), &xn1)?;
            stash(format!("{pfx}.attn.k"), xb);
            let (v, xb) = self.linear_fwd(&format!("{pfx}.attn.v"), &xn1)?;
            stash(format!("{pfx}.attn.v"), xb);

            let mut attn_cat = Matrix::zeros(n, d);
            let mut probs = Vec::with_capacity(bsz * nh);
            for bi in 0..bsz {
                for h in 0..nh {
                    let mut q_h = head_slice(&q, bi, h, t, hd);
                    let mut k_h = head_slice(&k, bi, h, t, hd);
                    let v_h = head_slice(&v, bi, h, t, hd);
                    self.rope_head(&mut q_h, half, false);
                    self.rope_head(&mut k_h, half, false);
                    // causal scores + row softmax
                    let mut s = q_h.matmul_transb(&k_h);
                    for i in 0..t {
                        let row = &mut s.data[i * t..(i + 1) * t];
                        let mut mx = f32::NEG_INFINITY;
                        for (j, val) in row.iter_mut().enumerate() {
                            if j > i {
                                *val = 0.0;
                            } else {
                                *val *= attn_scale;
                                mx = mx.max(*val);
                            }
                        }
                        let mut sum = 0.0f32;
                        for (j, val) in row.iter_mut().enumerate() {
                            if j > i {
                                *val = 0.0;
                            } else {
                                *val = (*val - mx).exp();
                                sum += *val;
                            }
                        }
                        for val in row.iter_mut() {
                            *val /= sum;
                        }
                    }
                    let out_h = s.matmul(&v_h);
                    head_write(&mut attn_cat, &out_h, bi, h, t, hd);
                    // cache post-rope q/k for the backward pass
                    head_write(&mut q, &q_h, bi, h, t, hd);
                    head_write(&mut k, &k_h, bi, h, t, hd);
                    probs.push(s);
                }
            }

            let (o_out, xb) = self.linear_fwd(&format!("{pfx}.attn.o"), &attn_cat)?;
            stash(format!("{pfx}.attn.o"), xb);
            let x_mid = x.add(&o_out);

            let g2 = self.param_vec(&format!("{pfx}.ln2.g"))?;
            let (xn2, xhat2, r2) = rmsnorm_fwd(&x_mid, g2);
            let (g_pre, xb) = self.linear_fwd(&format!("{pfx}.mlp.gate"), &xn2)?;
            stash(format!("{pfx}.mlp.gate"), xb);
            let (u, xb) = self.linear_fwd(&format!("{pfx}.mlp.up"), &xn2)?;
            stash(format!("{pfx}.mlp.up"), xb);
            let mut h_act = Matrix::zeros(n, p.d_ff);
            for i in 0..h_act.data.len() {
                let g = g_pre.data[i];
                h_act.data[i] = g * sigmoid(g) * u.data[i];
            }
            let (d_out, xb) = self.linear_fwd(&format!("{pfx}.mlp.down"), &h_act)?;
            stash(format!("{pfx}.mlp.down"), xb);
            let x_out = x_mid.add(&d_out);

            blocks.push(BlockCache {
                xhat1,
                r1,
                xn1,
                q,
                k,
                v,
                probs,
                attn_cat,
                xhat2,
                r2,
                xn2,
                g_pre,
                u,
                h: h_act,
                xb: xb_cache,
            });
            x = x_out;
        }

        let gf = self.param_vec("lnf.g")?;
        let (xnf, xhatf, rf) = rmsnorm_fwd(&x, gf);
        let logits = xnf.matmul(self.param_mat("head.w")?);
        let cache =
            FwdCache { tokens: tokens.to_vec(), bsz, t, blocks, xhatf, rf, xnf };
        Ok((logits, cache))
    }

    fn rope_head(&self, m: &mut Matrix, half: usize, inverse: bool) {
        for ti in 0..m.rows {
            let row = &mut m.data[ti * 2 * half..(ti + 1) * 2 * half];
            for j in 0..half {
                let c = self.rope_cos[ti * half + j];
                let s = self.rope_sin[ti * half + j];
                let (x1, x2) = (row[2 * j], row[2 * j + 1]);
                if inverse {
                    row[2 * j] = x1 * c + x2 * s;
                    row[2 * j + 1] = -x1 * s + x2 * c;
                } else {
                    row[2 * j] = x1 * c - x2 * s;
                    row[2 * j + 1] = x1 * s + x2 * c;
                }
            }
        }
    }

    // ---------------------------------------------------- backward

    fn backward(&self, cache: &FwdCache, dlogits: &Matrix) -> Result<Grads> {
        let p = &self.preset;
        let (d, nh, hd) = (p.d_model, p.n_heads, self.head_dim());
        let (bsz, t) = (cache.bsz, cache.t);
        let attn_scale = 1.0f32 / (hd as f32).sqrt();
        let half = hd / 2;
        let mut grads: Grads = BTreeMap::new();

        // head + final norm
        let head = self.param_mat("head.w")?;
        let dhead = cache.xnf.transpose().matmul(dlogits);
        acc_grad(&mut grads, "head.w", &dhead.data);
        let dxnf = dlogits.matmul_transb(head);
        let gf = self.param_vec("lnf.g")?;
        let mut dgf = vec![0.0f32; d];
        let mut dx = rmsnorm_bwd(&dxnf, &cache.xhatf, &cache.rf, gf, &mut dgf);
        acc_grad(&mut grads, "lnf.g", &dgf);

        for (l, blk) in cache.blocks.iter().enumerate().rev() {
            let pfx = format!("layers.{l}");
            // ---- mlp branch: x_out = x_mid + down(silu(gate)·up)
            let h_t = blk.h.transpose();
            let dh = self.linear_bwd(
                &format!("{pfx}.mlp.down"),
                &h_t,
                &blk.h,
                blk.xb.get(&format!("{pfx}.mlp.down")),
                &dx,
                &mut grads,
            )?;
            let mut dg_pre = Matrix::zeros(dh.rows, dh.cols);
            let mut du = Matrix::zeros(dh.rows, dh.cols);
            for i in 0..dh.data.len() {
                let g = blk.g_pre.data[i];
                let s = sigmoid(g);
                du.data[i] = dh.data[i] * g * s;
                dg_pre.data[i] = dh.data[i] * blk.u.data[i] * s * (1.0 + g * (1.0 - s));
            }
            let xn2_t = blk.xn2.transpose();
            let mut dxn2 = self.linear_bwd(
                &format!("{pfx}.mlp.gate"),
                &xn2_t,
                &blk.xn2,
                blk.xb.get(&format!("{pfx}.mlp.gate")),
                &dg_pre,
                &mut grads,
            )?;
            add_into(
                &mut dxn2,
                &self.linear_bwd(
                    &format!("{pfx}.mlp.up"),
                    &xn2_t,
                    &blk.xn2,
                    blk.xb.get(&format!("{pfx}.mlp.up")),
                    &du,
                    &mut grads,
                )?,
            );
            let g2 = self.param_vec(&format!("{pfx}.ln2.g"))?;
            let mut dg2 = vec![0.0f32; d];
            let dnorm2 = rmsnorm_bwd(&dxn2, &blk.xhat2, &blk.r2, g2, &mut dg2);
            acc_grad(&mut grads, &format!("{pfx}.ln2.g"), &dg2);
            let dx_mid = dx.add(&dnorm2);

            // ---- attention branch: x_mid = x_in + o(attn)
            let cat_t = blk.attn_cat.transpose();
            let dcat = self.linear_bwd(
                &format!("{pfx}.attn.o"),
                &cat_t,
                &blk.attn_cat,
                blk.xb.get(&format!("{pfx}.attn.o")),
                &dx_mid,
                &mut grads,
            )?;
            let mut dq = Matrix::zeros(bsz * t, d);
            let mut dk = Matrix::zeros(bsz * t, d);
            let mut dv = Matrix::zeros(bsz * t, d);
            for bi in 0..bsz {
                for h in 0..nh {
                    let dout_h = head_slice(&dcat, bi, h, t, hd);
                    let q_h = head_slice(&blk.q, bi, h, t, hd);
                    let k_h = head_slice(&blk.k, bi, h, t, hd);
                    let v_h = head_slice(&blk.v, bi, h, t, hd);
                    let probs = &blk.probs[bi * nh + h];
                    let dp = dout_h.matmul_transb(&v_h);
                    let dv_h = probs.transpose().matmul(&dout_h);
                    // softmax backward; masked entries have prob 0
                    let mut ds = Matrix::zeros(t, t);
                    for i in 0..t {
                        let prow = &probs.data[i * t..(i + 1) * t];
                        let dprow = &dp.data[i * t..(i + 1) * t];
                        let dot: f32 =
                            prow.iter().zip(dprow).map(|(a, b)| a * b).sum();
                        for j in 0..=i {
                            ds.data[i * t + j] = prow[j] * (dprow[j] - dot);
                        }
                    }
                    let mut dq_h = ds.matmul(&k_h).scale(attn_scale);
                    let mut dk_h = ds.transpose().matmul(&q_h).scale(attn_scale);
                    self.rope_head(&mut dq_h, half, true);
                    self.rope_head(&mut dk_h, half, true);
                    head_write_add(&mut dq, &dq_h, bi, h, t, hd);
                    head_write_add(&mut dk, &dk_h, bi, h, t, hd);
                    head_write_add(&mut dv, &dv_h, bi, h, t, hd);
                }
            }
            let xn1_t = blk.xn1.transpose();
            let mut dxn1 = self.linear_bwd(
                &format!("{pfx}.attn.q"),
                &xn1_t,
                &blk.xn1,
                blk.xb.get(&format!("{pfx}.attn.q")),
                &dq,
                &mut grads,
            )?;
            add_into(
                &mut dxn1,
                &self.linear_bwd(
                    &format!("{pfx}.attn.k"),
                    &xn1_t,
                    &blk.xn1,
                    blk.xb.get(&format!("{pfx}.attn.k")),
                    &dk,
                    &mut grads,
                )?,
            );
            add_into(
                &mut dxn1,
                &self.linear_bwd(
                    &format!("{pfx}.attn.v"),
                    &xn1_t,
                    &blk.xn1,
                    blk.xb.get(&format!("{pfx}.attn.v")),
                    &dv,
                    &mut grads,
                )?,
            );
            let g1 = self.param_vec(&format!("{pfx}.ln1.g"))?;
            let mut dg1 = vec![0.0f32; d];
            let dnorm1 = rmsnorm_bwd(&dxn1, &blk.xhat1, &blk.r1, g1, &mut dg1);
            acc_grad(&mut grads, &format!("{pfx}.ln1.g"), &dg1);
            dx = dx_mid.add(&dnorm1);
        }

        // embedding scatter
        let embed_numel = self.param("embed.w")?.numel();
        let ge = grads.entry("embed.w".into()).or_insert_with(|| vec![0.0; embed_numel]);
        for (i, &tok) in cache.tokens.iter().enumerate() {
            let tok = tok as usize;
            for j in 0..d {
                ge[tok * d + j] += dx.data[i * d + j];
            }
        }
        Ok(grads)
    }

    // ------------------------------------------------- loss + adam

    /// Train-loss forward + backward (no update). The split from
    /// `adam_apply` keeps gradients observable for verification.
    fn loss_and_grads(&self, tokens: &[i32]) -> Result<(f64, Grads)> {
        let (inputs, targets, t_in) = split_next_token(tokens, self.batch, self.preset.seq_len)?;
        let (logits, cache) = self.forward_cached(&inputs, self.batch, t_in)?;
        let (loss, dlogits) = ce_loss_grad(&logits, &targets)?;
        let grads = self.backward(&cache, &dlogits)?;
        Ok((loss, grads))
    }

    fn loss_only(&self, tokens: &[i32], bsz: usize) -> Result<f64> {
        let (inputs, targets, t_in) = split_next_token(tokens, bsz, self.preset.seq_len)?;
        let (logits, _) = self.forward_cached(&inputs, bsz, t_in)?;
        ce_loss(&logits, &targets)
    }

    /// Linear warmup then cosine decay to 10% (optim.lr_schedule).
    fn warmup_steps(&self) -> f32 {
        (self.total_steps as f32 * 0.05).clamp(1.0, WARMUP_CAP)
    }

    fn lr_at(&self, step: i32) -> f32 {
        let s = step.max(0) as f32;
        let warmup = self.warmup_steps();
        if s < warmup {
            return self.lr * s / warmup;
        }
        let total = self.total_steps as f32;
        let prog = ((s - warmup) / (total - warmup).max(1.0)).clamp(0.0, 1.0);
        self.lr * (0.1 + 0.45 * (1.0 + (std::f32::consts::PI * prog).cos()))
    }

    fn adam_apply(&mut self, step: i32, grads: &Grads) -> Result<()> {
        let lr_t = self.lr_at(step);
        let t = step.max(0) as f32 + 1.0;
        let bc1 = 1.0 - ADAM_B1.powf(t);
        let bc2 = 1.0 - ADAM_B2.powf(t);
        for (name, g) in grads {
            let p = self
                .params
                .get_mut(name)
                .ok_or_else(|| anyhow!("gradient for unknown tensor {name:?}"))?
                .data_mut();
            let m = self.adam_m.get_mut(name).ok_or_else(|| anyhow!("no moment m {name:?}"))?;
            let v = self.adam_v.get_mut(name).ok_or_else(|| anyhow!("no moment v {name:?}"))?;
            if g.len() != p.len() {
                bail!("{name}: grad numel {} != param {}", g.len(), p.len());
            }
            for i in 0..p.len() {
                m[i] = ADAM_B1 * m[i] + (1.0 - ADAM_B1) * g[i];
                v[i] = ADAM_B2 * v[i] + (1.0 - ADAM_B2) * g[i] * g[i];
                let upd = (m[i] / bc1) / ((v[i] / bc2).sqrt() + ADAM_EPS);
                p[i] -= lr_t * upd;
            }
        }
        Ok(())
    }
}

// ----------------------------------------------------- trait impl

impl Backend for NativeBackend {
    fn kind(&self) -> &'static str {
        "native"
    }

    fn method(&self) -> &str {
        &self.method
    }

    fn preset(&self) -> &ModelPreset {
        &self.preset
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn n_params(&self) -> usize {
        if self.params.is_empty() {
            // not yet initialized: the config formula (verified equal to
            // the instantiated sum in tests)
            return self.preset.param_count(&self.method);
        }
        self.params.values().map(|t| t.numel()).sum()
    }

    fn init_state(&mut self, seed: u32) -> Result<()> {
        self.init_params(seed);
        Ok(())
    }

    fn train_step(&mut self, step: i32, tokens: &[i32]) -> Result<f32> {
        self.ensure_init()?;
        let (loss, grads) = self.loss_and_grads(tokens)?;
        self.adam_apply(step, &grads)?;
        Ok(loss as f32)
    }

    fn eval_loss(&mut self, tokens: &[i32]) -> Result<f32> {
        self.ensure_init()?;
        Ok(self.loss_only(tokens, self.batch)? as f32)
    }

    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.ensure_init()?;
        let t = self.preset.seq_len;
        if tokens.len() % t != 0 {
            bail!("forward expects a multiple of seq_len {t} tokens");
        }
        let bsz = tokens.len() / t;
        let (logits, _) = self.forward_cached(tokens, bsz, t)?;
        Ok(logits.data)
    }

    fn drop_optimizer_state(&mut self) -> Result<()> {
        self.adam_m.clear();
        self.adam_v.clear();
        Ok(())
    }

    fn state_tensors(&self) -> Result<Vec<StateTensor>> {
        self.ensure_init()?;
        let mut out = Vec::with_capacity(self.params.len() + self.supports.len());
        for (name, t) in &self.params {
            out.push(StateTensor::f32(name, t.shape(), t.data()));
        }
        for (path, sup) in &self.supports {
            let idx: Vec<i32> = sup.idx.iter().map(|&i| i as i32).collect();
            out.push(StateTensor::i32(&format!("{path}.idx"), vec![sup.nnz()], &idx));
        }
        Ok(out)
    }

    fn load_state_tensors(&mut self, tensors: &[StateTensor]) -> Result<()> {
        self.ensure_init()?;
        // Stage and validate everything BEFORE mutating, so a mismatched
        // or corrupt checkpoint leaves the backend untouched (and support
        // indices never reach SparseSupport::new's panicking asserts).
        let mut staged_supports: Vec<(String, SparseSupport)> = Vec::new();
        let mut staged_params: Vec<(&str, Vec<f32>)> = Vec::new();
        for st in tensors {
            if let Some(path) = st.name.strip_suffix(".idx") {
                let sup = self
                    .supports
                    .get(path)
                    .ok_or_else(|| anyhow!("unknown support {:?}", st.name))?;
                let idx: Vec<u32> = st.to_i32()?.iter().map(|&i| i as u32).collect();
                let bound = (sup.d_in * sup.d_out) as u32;
                if !idx.windows(2).all(|w| w[0] < w[1]) {
                    bail!("{}: support not sorted-distinct", st.name);
                }
                if idx.iter().any(|&i| i >= bound) {
                    bail!("{}: support index out of range {bound}", st.name);
                }
                staged_supports
                    .push((path.to_string(), SparseSupport::new(sup.d_in, sup.d_out, idx)));
            } else {
                let data = st.to_f32()?;
                let p = self
                    .params
                    .get(&st.name)
                    .ok_or_else(|| anyhow!("unknown tensor {:?}", st.name))?;
                if p.numel() != data.len() {
                    bail!("{}: numel {} != expected {}", st.name, data.len(), p.numel());
                }
                staged_params.push((st.name.as_str(), data));
            }
        }
        // cross-check: each reloaded support must agree with the values
        // tensor that will accompany it (staged if present, current else)
        for (path, sup) in &staged_supports {
            let vals_name = format!("{path}.vals");
            let vals_len = staged_params
                .iter()
                .find(|(n, _)| *n == vals_name)
                .map(|(_, d)| d.len())
                .or_else(|| self.params.get(&vals_name).map(|p| p.numel()))
                .ok_or_else(|| anyhow!("{path}: support without values tensor"))?;
            if vals_len != sup.nnz() {
                bail!("{path}: support nnz {} != values len {vals_len}", sup.nnz());
            }
        }
        for (path, sup) in staged_supports {
            self.supports.insert(path, sup);
        }
        for (name, data) in staged_params {
            self.params.get_mut(name).expect("validated above").data_mut().copy_from_slice(&data);
        }
        Ok(())
    }
}

// ------------------------------------------------------- math helpers

fn sigmoid(x: f32) -> f32 {
    1.0 / (1.0 + (-x).exp())
}

/// Row-wise RMSNorm with gain: returns (x̂·g, x̂, 1/rms per row).
fn rmsnorm_fwd(x: &Matrix, g: &[f32]) -> (Matrix, Matrix, Vec<f32>) {
    let d = x.cols;
    assert_eq!(g.len(), d);
    let mut y = Matrix::zeros(x.rows, d);
    let mut xhat = Matrix::zeros(x.rows, d);
    let mut inv_rms = vec![0.0f32; x.rows];
    for i in 0..x.rows {
        let row = &x.data[i * d..(i + 1) * d];
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / d as f32;
        let r = 1.0 / (ms + RMS_EPS).sqrt();
        inv_rms[i] = r;
        for j in 0..d {
            let xh = row[j] * r;
            xhat.data[i * d + j] = xh;
            y.data[i * d + j] = xh * g[j];
        }
    }
    (y, xhat, inv_rms)
}

/// RMSNorm backward: dx = r·(dx̂ − x̂·mean(dx̂⊙x̂)), dg += Σ_rows dy⊙x̂.
fn rmsnorm_bwd(dy: &Matrix, xhat: &Matrix, inv_rms: &[f32], g: &[f32], dg: &mut [f32]) -> Matrix {
    let d = dy.cols;
    let mut dx = Matrix::zeros(dy.rows, d);
    for i in 0..dy.rows {
        let dyr = &dy.data[i * d..(i + 1) * d];
        let xhr = &xhat.data[i * d..(i + 1) * d];
        let mut dot = 0.0f32;
        for j in 0..d {
            dg[j] += dyr[j] * xhr[j];
            dot += dyr[j] * g[j] * xhr[j];
        }
        dot /= d as f32;
        let r = inv_rms[i];
        for j in 0..d {
            dx.data[i * d + j] = r * (dyr[j] * g[j] - xhr[j] * dot);
        }
    }
    dx
}

/// Copy head `h` of batch row-block `bi` out of an [bsz*t, n_heads*hd]
/// matrix into a contiguous [t, hd] one.
fn head_slice(x: &Matrix, bi: usize, h: usize, t: usize, hd: usize) -> Matrix {
    let d = x.cols;
    let mut out = Matrix::zeros(t, hd);
    for ti in 0..t {
        let src = &x.data[(bi * t + ti) * d + h * hd..(bi * t + ti) * d + (h + 1) * hd];
        out.data[ti * hd..(ti + 1) * hd].copy_from_slice(src);
    }
    out
}

fn head_write(dst: &mut Matrix, src: &Matrix, bi: usize, h: usize, t: usize, hd: usize) {
    let d = dst.cols;
    for ti in 0..t {
        let s = &src.data[ti * hd..(ti + 1) * hd];
        dst.data[(bi * t + ti) * d + h * hd..(bi * t + ti) * d + (h + 1) * hd]
            .copy_from_slice(s);
    }
}

fn head_write_add(dst: &mut Matrix, src: &Matrix, bi: usize, h: usize, t: usize, hd: usize) {
    let d = dst.cols;
    for ti in 0..t {
        for j in 0..hd {
            dst.data[(bi * t + ti) * d + h * hd + j] += src.data[ti * hd + j];
        }
    }
}

fn add_into(dst: &mut Matrix, src: &Matrix) {
    assert_eq!(dst.data.len(), src.data.len());
    for (a, b) in dst.data.iter_mut().zip(&src.data) {
        *a += b;
    }
}

fn acc_grad(grads: &mut Grads, name: &str, g: &[f32]) {
    match grads.get_mut(name) {
        Some(acc) => {
            for (a, b) in acc.iter_mut().zip(g) {
                *a += b;
            }
        }
        None => {
            grads.insert(name.to_string(), g.to_vec());
        }
    }
}

/// Next-token split of a [bsz, seq] batch: inputs drop the last column,
/// targets drop the first. Returns (inputs, targets, seq-1).
fn split_next_token(tokens: &[i32], bsz: usize, seq: usize) -> Result<(Vec<i32>, Vec<i32>, usize)> {
    if tokens.len() != bsz * seq {
        bail!("expected {bsz}x{seq} tokens, got {}", tokens.len());
    }
    let t_in = seq - 1;
    let mut inputs = Vec::with_capacity(bsz * t_in);
    let mut targets = Vec::with_capacity(bsz * t_in);
    for b in 0..bsz {
        let row = &tokens[b * seq..(b + 1) * seq];
        inputs.extend_from_slice(&row[..t_in]);
        targets.extend_from_slice(&row[1..]);
    }
    Ok((inputs, targets, t_in))
}

/// Mean next-token cross-entropy (f64 accumulation for stability).
fn ce_loss(logits: &Matrix, targets: &[i32]) -> Result<f64> {
    let (n, v) = (logits.rows, logits.cols);
    if targets.len() != n {
        bail!("{n} logit rows but {} targets", targets.len());
    }
    let mut total = 0.0f64;
    for i in 0..n {
        let row = &logits.data[i * v..(i + 1) * v];
        let tgt = targets[i] as usize;
        if tgt >= v {
            bail!("target {tgt} out of vocab {v}");
        }
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f64 = row.iter().map(|&x| ((x - mx) as f64).exp()).sum();
        total += mx as f64 + sum.ln() - row[tgt] as f64;
    }
    Ok(total / n as f64)
}

/// CE loss plus dL/dlogits = (softmax − onehot)/n.
fn ce_loss_grad(logits: &Matrix, targets: &[i32]) -> Result<(f64, Matrix)> {
    let (n, v) = (logits.rows, logits.cols);
    if targets.len() != n {
        bail!("{n} logit rows but {} targets", targets.len());
    }
    let mut dl = Matrix::zeros(n, v);
    let inv_n = 1.0f32 / n as f32;
    let mut total = 0.0f64;
    for i in 0..n {
        let row = &logits.data[i * v..(i + 1) * v];
        let tgt = targets[i] as usize;
        if tgt >= v {
            bail!("target {tgt} out of vocab {v}");
        }
        let mx = row.iter().cloned().fold(f32::NEG_INFINITY, f32::max);
        let sum: f64 = row.iter().map(|&x| ((x - mx) as f64).exp()).sum();
        total += mx as f64 + sum.ln() - row[tgt] as f64;
        for j in 0..v {
            let p = (((row[j] - mx) as f64).exp() / sum) as f32;
            dl.data[i * v + j] = p * inv_n;
        }
        dl.data[i * v + tgt] -= inv_n;
    }
    Ok((total / n as f64, dl))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn micro_preset() -> ModelPreset {
        ModelPreset {
            name: "micro".into(),
            vocab: 32,
            d_model: 16,
            n_layers: 1,
            n_heads: 2,
            seq_len: 12,
            rank: 4,
            delta: 0.05,
            alpha: 8.0,
            d_ff: 32,
        }
    }

    fn micro_backend(method: &str, seed: u32) -> NativeBackend {
        let mut be = NativeBackend::build(micro_preset(), method, 2, 3e-3, 100).unwrap();
        be.init_state(seed).unwrap();
        be
    }

    fn random_tokens(be: &NativeBackend, seed: u64) -> Vec<i32> {
        let mut rng = Rng::new(seed);
        (0..be.batch * be.preset.seq_len)
            .map(|_| rng.below(be.preset.vocab as u64) as i32)
            .collect()
    }

    /// Central-difference check of the full manual backward pass, for
    /// every supported parameterization. For each parameter tensor the
    /// entry with the largest analytic gradient is perturbed.
    #[test]
    fn gradients_match_finite_differences() {
        for method in ["full", "lowrank", "sltrain"] {
            let mut be = micro_backend(method, 3);
            let tokens = random_tokens(&be, 11);
            let (_, grads) = be.loss_and_grads(&tokens).unwrap();
            let names: Vec<String> = grads.keys().cloned().collect();
            for name in names {
                let g = &grads[&name];
                let (idx, &ga) = g
                    .iter()
                    .enumerate()
                    .max_by(|a, b| a.1.abs().partial_cmp(&b.1.abs()).unwrap())
                    .unwrap();
                if ga.abs() < 5e-3 {
                    continue; // too small to measure through f32 noise
                }
                let h = 1e-2f32;
                let orig = be.params.get(&name).unwrap().data()[idx];
                be.params.get_mut(&name).unwrap().data_mut()[idx] = orig + h;
                let lp = be.loss_only(&tokens, be.batch).unwrap();
                be.params.get_mut(&name).unwrap().data_mut()[idx] = orig - h;
                let lm = be.loss_only(&tokens, be.batch).unwrap();
                be.params.get_mut(&name).unwrap().data_mut()[idx] = orig;
                let gn = ((lp - lm) / (2.0 * h as f64)) as f32;
                let rel = (ga - gn).abs() / gn.abs().max(ga.abs()).max(1e-4);
                assert!(
                    rel < 0.08,
                    "{method}/{name}[{idx}]: analytic {ga:.6} vs numeric {gn:.6} (rel {rel:.3})"
                );
            }
        }
    }

    #[test]
    fn n_params_matches_preset_formula() {
        for method in ["full", "lowrank", "sltrain"] {
            let be = micro_backend(method, 0);
            assert_eq!(
                be.n_params(),
                be.preset.param_count(method),
                "{method}: n_params vs config formula"
            );
        }
    }

    #[test]
    fn training_is_deterministic() {
        let mut runs = vec![];
        for _ in 0..2 {
            let mut be = micro_backend("sltrain", 42);
            let tokens = random_tokens(&be, 7);
            let mut losses = vec![];
            for step in 0..3 {
                losses.push(be.train_step(step, &tokens).unwrap());
            }
            runs.push(losses);
        }
        assert_eq!(runs[0], runs[1], "same seed must reproduce bit-identical losses");
    }

    #[test]
    fn loss_starts_near_uniform_and_decreases() {
        let mut be = micro_backend("sltrain", 1);
        let tokens = random_tokens(&be, 5);
        let ln_v = (be.preset.vocab as f64).ln();
        let first = be.train_step(0, &tokens).unwrap() as f64;
        // Kaiming head init gives logit variance 2, lifting the expected
        // initial CE to ≈ ln|V| + 1
        assert!((first - ln_v).abs() < 1.6, "init loss {first} vs ln|V| {ln_v}");
        let mut last = first;
        for step in 1..40 {
            last = be.train_step(step, &tokens).unwrap() as f64;
        }
        // one repeated batch: must overfit decisively
        assert!(last < first - 0.5, "{first} -> {last}");
    }

    #[test]
    fn state_roundtrip_preserves_eval() {
        let mut be = micro_backend("sltrain", 9);
        let tokens = random_tokens(&be, 3);
        for step in 0..3 {
            be.train_step(step, &tokens).unwrap();
        }
        let snap = be.state_tensors().unwrap();
        let before = be.eval_loss(&tokens).unwrap();
        let mut be2 = micro_backend("sltrain", 1234); // different init
        be2.load_state_tensors(&snap).unwrap();
        let after = be2.eval_loss(&tokens).unwrap();
        assert!(
            (before - after).abs() < 1e-6,
            "restored eval {after} != source {before}"
        );
    }

    #[test]
    fn forward_shape_and_merge_unsupported() {
        let mut be = micro_backend("full", 2);
        let tokens = random_tokens(&be, 1);
        let logits = be.forward(&tokens).unwrap();
        assert_eq!(logits.len(), be.batch * be.preset.seq_len * be.preset.vocab);
        assert!(be.merge(0).is_err());
    }

    #[test]
    fn lr_schedule_warms_up_then_decays() {
        let be = micro_backend("full", 0);
        // total_steps=100 for the micro backend -> 5 warmup steps
        assert_eq!(be.lr_at(0), 0.0);
        assert!(be.lr_at(2) < be.lr_at(4));
        assert!((be.lr_at(5) - be.lr).abs() / be.lr < 1e-3);
        assert!((be.lr_at(10_000) - 0.1 * be.lr).abs() < 1e-6);
        // at the aot.py-default horizon the warmup is exactly 100 steps
        let long = NativeBackend::build(micro_preset(), "full", 2, 3e-3, 2000).unwrap();
        assert_eq!(long.warmup_steps(), 100.0);
    }
}

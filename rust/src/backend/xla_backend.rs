//! XlaBackend: the AOT/PJRT execution engine behind the `Backend` trait.
//!
//! A thin adapter over `runtime::pjrt` — the artifact bundle owns the
//! compute (init / train_step / eval_step / forward / merge entrypoints
//! lowered from JAX), this type owns the host-resident literal state and
//! translates between the trait's interchange types and `xla::Literal`s.
//! Only compiled with the `xla` cargo feature.

use std::path::Path;

use anyhow::{anyhow, bail, Result};

use super::{Backend, StateTensor};
use crate::config::ModelPreset;
use crate::runtime::{lit_f32, lit_i32, lit_i8, Artifact, Dtype, Runtime, State, TensorSpec};

/// The AOT/PJRT execution engine: one loaded artifact bundle plus the
/// host-resident literal state it trains.
pub struct XlaBackend {
    /// Process-shared PJRT CPU client (one bring-up per process, not
    /// per artifact open — bench loops sweep many artifacts).
    rt: std::sync::Arc<Runtime>,
    art: Artifact,
    state: Option<State>,
}

impl XlaBackend {
    /// Load an artifact bundle onto the shared PJRT CPU client.
    pub fn open(dir: &Path) -> Result<XlaBackend> {
        let rt = Runtime::cpu_shared()?;
        let art = Artifact::load(dir)?;
        Ok(XlaBackend { rt, art, state: None })
    }

    /// The artifact bundle's manifest (shapes, entrypoints, method).
    pub fn manifest(&self) -> &crate::runtime::Manifest {
        &self.art.manifest
    }

    /// PJRT platform name of the shared client ("cpu", …).
    pub fn platform(&self) -> String {
        self.rt.platform()
    }

    /// Persistent tensor specs: params + fixed supports (consts).
    fn persistent_specs(&self) -> Vec<TensorSpec> {
        let mut specs = self.art.manifest.params.clone();
        specs.extend(self.art.manifest.consts.iter().cloned());
        specs
    }

    fn spec_to_tensor(&self, state: &State, spec: &TensorSpec) -> Result<StateTensor> {
        let lit = state.get(&spec.name)?;
        let bytes: Vec<u8> = match spec.dtype {
            Dtype::F32 => {
                let v = lit.to_vec::<f32>().map_err(|e| anyhow!("{}: {e}", spec.name))?;
                v.iter().flat_map(|x| x.to_le_bytes()).collect()
            }
            Dtype::I32 => {
                let v = lit.to_vec::<i32>().map_err(|e| anyhow!("{}: {e}", spec.name))?;
                v.iter().flat_map(|x| x.to_le_bytes()).collect()
            }
            Dtype::U32 => {
                let v = lit.to_vec::<u32>().map_err(|e| anyhow!("{}: {e}", spec.name))?;
                v.iter().flat_map(|x| x.to_le_bytes()).collect()
            }
            Dtype::I8 => {
                let v = lit.to_vec::<i8>().map_err(|e| anyhow!("{}: {e}", spec.name))?;
                v.iter().map(|&x| x as u8).collect()
            }
        };
        Ok(StateTensor {
            name: spec.name.clone(),
            shape: spec.shape.clone(),
            dtype: spec.dtype,
            bytes,
        })
    }
}

impl Backend for XlaBackend {
    fn kind(&self) -> &'static str {
        "xla"
    }

    fn method(&self) -> &str {
        &self.art.manifest.method
    }

    fn preset(&self) -> &ModelPreset {
        &self.art.manifest.preset
    }

    fn batch_size(&self) -> usize {
        self.art.manifest.batch
    }

    fn forward_batch_size(&self) -> usize {
        self.art.entry("forward").map(|e| e.batch).unwrap_or_else(|_| self.batch_size())
    }

    fn optimizer(&self) -> &str {
        &self.art.manifest.optimizer
    }

    fn n_params(&self) -> usize {
        self.art.manifest.n_params
    }

    fn init_state(&mut self, seed: u32) -> Result<()> {
        let state = self.art.init_state(&self.rt, seed)?;
        self.state = Some(state);
        Ok(())
    }

    fn train_step(&mut self, step: i32, tokens: &[i32]) -> Result<f32> {
        let state = self.state.as_mut().ok_or_else(|| anyhow!("init_state not called"))?;
        self.art.train_step(&self.rt, state, step, tokens)
    }

    fn eval_loss(&mut self, tokens: &[i32]) -> Result<f32> {
        let state = self.state.as_mut().ok_or_else(|| anyhow!("init_state not called"))?;
        self.art.eval_loss(&self.rt, state, tokens)
    }

    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        let state = self.state.as_mut().ok_or_else(|| anyhow!("init_state not called"))?;
        self.art.forward(&self.rt, state, tokens)
    }

    fn merge(&mut self, seed: i32) -> Result<()> {
        let state = self.state.as_mut().ok_or_else(|| anyhow!("init_state not called"))?;
        self.art.relora_merge(&self.rt, state, seed)
    }

    fn drop_optimizer_state(&mut self) -> Result<()> {
        let state = self.state.as_mut().ok_or_else(|| anyhow!("init_state not called"))?;
        for spec in &self.art.manifest.opt_state {
            state.tensors.remove(&spec.name);
        }
        Ok(())
    }

    fn state_tensors(&self) -> Result<Vec<StateTensor>> {
        let state = self.state.as_ref().ok_or_else(|| anyhow!("init_state not called"))?;
        self.persistent_specs().iter().map(|s| self.spec_to_tensor(state, s)).collect()
    }

    fn load_state_tensors(&mut self, tensors: &[StateTensor]) -> Result<()> {
        let known: std::collections::HashSet<&str> = self
            .art
            .manifest
            .params
            .iter()
            .chain(&self.art.manifest.consts)
            .chain(&self.art.manifest.opt_state)
            .map(|s| s.name.as_str())
            .collect();
        let state = self.state.as_mut().ok_or_else(|| anyhow!("init_state not called"))?;
        for t in tensors {
            if t.name.starts_with("optim.") {
                // native-backend optimizer moments (f32 or quantized
                // codes+scales): the artifact path owns its own opt_state
                // layout, so cross-backend loads carry weights/supports
                // only and the moments are skipped, not an error
                continue;
            }
            if !known.contains(t.name.as_str()) {
                bail!("{}: not a tensor of this artifact", t.name);
            }
            let lit = match t.dtype {
                Dtype::F32 => {
                    let v: Vec<f32> = t
                        .bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    lit_f32(&t.shape, &v)?
                }
                Dtype::I32 | Dtype::U32 => {
                    let v: Vec<i32> = t
                        .bytes
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    lit_i32(&t.shape, &v)?
                }
                Dtype::I8 => {
                    let v: Vec<i8> = t.bytes.iter().map(|&b| b as i8).collect();
                    lit_i8(&t.shape, &v)?
                }
            };
            let n: usize = t.shape.iter().product();
            if n * t.dtype.size_bytes() != t.bytes.len() {
                bail!("{}: byte length mismatch", t.name);
            }
            state.put(&t.name, lit);
        }
        Ok(())
    }
}

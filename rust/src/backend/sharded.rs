//! Deterministic data-parallel training: N native replicas behind one
//! `Backend`.
//!
//! [`ShardedBackend`] wraps N [`super::native::NativeBackend`] replicas
//! (worker threads by default; child processes over Unix sockets with
//! `SLTRAIN_WORKER_TRANSPORT=process`, behind the same
//! [`super::comm`] traits) and extends the repo's determinism contract
//! to a fourth axis: **bit-identical losses and state at 1, 2 and 4
//! workers**, on top of run-to-run, thread-count and SIMD-vs-scalar
//! invariance. The mechanisms:
//!
//! * **Fixed microbatch blocks.** Every train batch splits into `B`
//!   contiguous row blocks where `B` is the largest power of two ≤ 4
//!   dividing the batch — a function of the batch alone, never of the
//!   worker count. Each block is one independent microbatch on some
//!   replica; worker `w` of `N` owns the contiguous range
//!   `w·B/N .. (w+1)·B/N` (N is clamped to a power of two ≤ B).
//! * **Fixed-tree all-reduce.** Per parameter, the B block gradients
//!   land in block-indexed slots; once full they are combined by a
//!   stride-doubling pairwise tree (`slot[i] += slot[i+s]`, serial f32
//!   in ascending element order, on the parent thread) and scaled by
//!   `1/B`. The tree's shape depends only on B, so the reduced gradient
//!   — and everything downstream — is independent of N and of event
//!   arrival order. The batch loss is the serial f64 sum of per-block
//!   losses in block order, divided by B.
//! * **Overlapped comm.** Replicas run the streaming fused backward
//!   (`GradSink::Stream`): each finalized gradient is shipped the
//!   moment the backward walk produces it, so the parent reduces layer
//!   k's gradient while layer k-1's backward still runs on the
//!   replicas' compute pools.
//! * **Owner-sharded optimizer.** Parameter `p` is owned by worker
//!   `p mod N`; only the owner holds its Adam moments (the rest hold
//!   the zero-length moments frozen parameters already use) and applies
//!   the update, then the updated weights are broadcast. Per-worker
//!   optimizer bytes drop ~1/N — `mem_report()` shows the sharded view.
//!
//! A 1-worker sharded run is the bitwise reference point for the axis.
//! It is *not* bit-identical to the plain single-engine path (B
//! microbatch means + a `1/B` combine re-associate the loss/gradient
//! sums differently than one full-batch mean) — the plain path keeps
//! its own unchanged contract, and `--workers 0` (the default) keeps
//! using it.

use std::cell::RefCell;
use std::collections::HashMap;
use std::io::{BufReader, ErrorKind};
use std::os::unix::net::UnixListener;
use std::path::PathBuf;
use std::process::{Child, Command};
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::{channel, Receiver, Sender};
use std::thread::JoinHandle;
use std::time::{Duration, Instant};

use anyhow::{anyhow, bail, Result};

use super::comm::{
    read_hello, spawn_socket_reader, Cmd, Event, ReplicaLink, SocketLink, SocketWorkerChannel,
    ThreadLink, ThreadWorkerChannel, WorkerChannel,
};
use super::native::NativeBackend;
use super::{Backend, StateTensor};
use crate::config::ModelPreset;
use crate::linalg::parallel::resolve_worker_threads;
use crate::linalg::SupportPattern;
use crate::mem::MemReport;

/// How long the parent waits for any single worker event before
/// declaring the fleet wedged. Generous: events flow *during* each
/// replica's backward, so real gaps are sub-second even on big presets.
const EVENT_TIMEOUT: Duration = Duration::from_secs(600);

/// How long `process` transport waits for all children to dial back.
const ACCEPT_TIMEOUT: Duration = Duration::from_secs(30);

/// Distinguishes concurrent sharded backends in one process when
/// naming the process-transport socket directory.
static SOCKET_SEQ: AtomicUsize = AtomicUsize::new(0);

/// Largest power of two ≤ 4 dividing `batch` (the block count B), and
/// the effective worker count: largest power of two ≤ min(requested,
/// B). Both are pure functions of their inputs — B never depends on
/// the worker count, which is what makes the reduction N-invariant.
fn plan(batch: usize, requested: usize) -> (usize, usize) {
    let mut blocks = 1usize;
    while blocks < 4 && batch % (blocks * 2) == 0 {
        blocks *= 2;
    }
    let mut workers = 1usize;
    while workers * 2 <= requested.min(blocks) {
        workers *= 2;
    }
    (blocks, workers)
}

/// Stride-doubling pairwise tree over the B block gradients of one
/// parameter, then the `1/B` mean scale. Serial f32 on the calling
/// thread, ascending element order inside every combine — the fixed
/// reduction order of the determinism contract. The tree shape is a
/// function of B alone.
fn tree_reduce(mut bufs: Vec<Option<Vec<f32>>>) -> Result<Vec<f32>> {
    let b = bufs.len();
    let mut s = 1usize;
    while s < b {
        let mut i = 0usize;
        while i + s < b {
            let rhs = bufs[i + s].take().ok_or_else(|| anyhow!("reduce slot {} empty", i + s))?;
            let lhs = bufs[i].as_mut().ok_or_else(|| anyhow!("reduce slot {i} empty"))?;
            if lhs.len() != rhs.len() {
                bail!("reduce slot length mismatch");
            }
            for (x, y) in lhs.iter_mut().zip(&rhs) {
                *x += y;
            }
            i += 2 * s;
        }
        s *= 2;
    }
    let mut out = bufs[0].take().ok_or_else(|| anyhow!("reduce slot 0 empty"))?;
    let inv = 1.0f32 / b as f32;
    for x in &mut out {
        *x *= inv;
    }
    Ok(out)
}

/// The parameter a flat-namespace `optim.*` tensor name belongs to
/// (`optim.m.q8.embed.w` → `embed.w`), or `None` for non-optim names.
fn optim_param_name(name: &str) -> Option<&str> {
    let rest = name.strip_prefix("optim.")?;
    if let Some(p) = rest.strip_prefix("proj.") {
        return Some(p);
    }
    let rest = rest.strip_prefix("m.").or_else(|| rest.strip_prefix("v."))?;
    Some(
        rest.strip_prefix("q8.")
            .or_else(|| rest.strip_prefix("scale."))
            .unwrap_or(rest),
    )
}

// --------------------------------------------------- worker side

/// Serve one replica: receive commands, run them, emit events. Shared
/// verbatim by both transports (a worker thread and a `shard-worker`
/// child process run exactly this loop). Handler errors are reported as
/// `Event::Err` and the loop continues; `Shutdown` or a dead parent
/// link ends it.
pub(crate) fn worker_loop(
    mut be: NativeBackend,
    mut ch: impl WorkerChannel,
    worker: usize,
    workers: usize,
) {
    loop {
        let cmd = match ch.recv() {
            Ok(Cmd::Shutdown) | Err(_) => return,
            Ok(c) => c,
        };
        if let Err(e) = handle_cmd(&mut be, &mut ch, worker, workers, cmd) {
            if ch.send(Event::Err { msg: format!("{e:#}") }).is_err() {
                return;
            }
        }
    }
}

fn handle_cmd(
    be: &mut NativeBackend,
    ch: &mut impl WorkerChannel,
    worker: usize,
    workers: usize,
    cmd: Cmd,
) -> Result<()> {
    match cmd {
        Cmd::Init { seed } => {
            be.init_state(seed)?;
            be.shard_moments(worker, workers);
            let n = be.param_count();
            ch.send(Event::Inited {
                names: (0..n).map(|i| be.param_name(i).to_string()).collect(),
                numels: (0..n).map(|i| be.param_data(i).len()).collect(),
                frozen: (0..n).map(|i| be.param_frozen(i)).collect(),
            })?;
        }
        Cmd::Step { step: _, blocks } => {
            // each block is one microbatch: stream its gradients out in
            // the fixed backward-walk order, the overlap traffic the
            // parent reduces while later blocks/layers still compute
            let mut losses = Vec::with_capacity(blocks.len());
            for (block, tokens) in blocks {
                let loss = be.shard_loss_grads_stream(&tokens, &mut |param, grad| {
                    ch.send(Event::Grad { block, param, grad })
                })?;
                losses.push((block, loss));
            }
            ch.send(Event::StepDone { losses })?;
        }
        Cmd::Apply { step, grads } => {
            let ids: Vec<usize> = grads.iter().map(|(i, _)| *i).collect();
            be.apply_reduced_grads(step, grads)?;
            let updated = ids.into_iter().map(|i| (i, be.param_data(i).to_vec())).collect();
            ch.send(Event::Applied { updated })?;
        }
        Cmd::SetParams { params } => {
            for (i, d) in &params {
                be.set_param_data(*i, d)?;
            }
            ch.send(Event::SetDone)?;
        }
        Cmd::Eval { bsz, tokens } => {
            ch.send(Event::EvalDone { loss: be.shard_eval_loss(&tokens, bsz)? })?;
        }
        Cmd::Forward { tokens } => {
            ch.send(Event::ForwardDone { logits: be.forward(&tokens)? })?;
        }
        Cmd::Merge { seed } => {
            // the merge re-inflates the restarted adaptors' moments on
            // every replica; re-drop the non-owned ones
            be.merge(seed)?;
            be.shard_moments(worker, workers);
            ch.send(Event::Merged)?;
        }
        Cmd::DropOptim => {
            be.drop_optimizer_state()?;
            ch.send(Event::Dropped)?;
        }
        Cmd::Fold => {
            be.fold_weights()?;
            ch.send(Event::Folded)?;
        }
        Cmd::GetState => {
            ch.send(Event::State { tensors: be.state_tensors()? })?;
        }
        Cmd::LoadState { tensors } => {
            // a full flat-namespace checkpoint carries full-size moments;
            // validate against full-size staging, then re-drop the
            // non-owned ones — this is what lets a 4-worker checkpoint
            // resume bit-identically on 1 worker and vice versa
            let has_moments = tensors
                .iter()
                .any(|t| t.name.starts_with("optim.m.") || t.name.starts_with("optim.v."));
            if has_moments {
                be.reset_full_moments();
            }
            be.load_state_tensors(&tensors)?;
            be.shard_moments(worker, workers);
            ch.send(Event::Loaded)?;
        }
        Cmd::MemReport => {
            let report =
                be.mem_report().ok_or_else(|| anyhow!("native replica has no mem report"))?;
            ch.send(Event::Mem { report })?;
        }
        Cmd::Shutdown => unreachable!("handled by worker_loop"),
    }
    Ok(())
}

/// Entry point of the hidden `shard-worker` CLI subcommand (the
/// `process` transport's child side): rebuild the replica exactly as
/// the parent would have in-process, dial the parent's socket, and
/// serve [`worker_loop`] until `Shutdown`.
#[allow(clippy::too_many_arguments)]
pub fn run_worker_process(
    socket: &std::path::Path,
    worker: usize,
    workers: usize,
    preset: ModelPreset,
    method: &str,
    rows_per_block: usize,
    lr: f32,
    total_steps: usize,
    threads: usize,
    optim_bits: usize,
    galore_every: usize,
    support: SupportPattern,
) -> Result<()> {
    let be = NativeBackend::build(
        preset,
        method,
        rows_per_block,
        lr,
        total_steps,
        threads,
        optim_bits,
        galore_every,
        support,
    )?;
    let ch = SocketWorkerChannel::connect(socket, worker)?;
    worker_loop(be, ch, worker, workers);
    Ok(())
}

// --------------------------------------------------- parent side

/// Data-parallel `Backend`: N native replicas, deterministic fixed-tree
/// all-reduce, owner-sharded Adam. See the module docs for the design.
pub struct ShardedBackend {
    preset: ModelPreset,
    method: String,
    optimizer: &'static str,
    /// Full train-batch rows (what the coordinator sees).
    batch: usize,
    n_workers: usize,
    n_blocks: usize,
    rows_per_block: usize,
    /// Pool threads per replica (the global budget split N ways).
    threads_per_worker: usize,
    /// Command links, worker-indexed. RefCell: the `Backend` trait
    /// exposes read-only entrypoints (`state_tensors`, `mem_report`)
    /// that still need to talk to the replicas.
    links: RefCell<Vec<Box<dyn ReplicaLink>>>,
    /// All workers' events, tagged with the worker index.
    events: Receiver<(usize, Event)>,
    /// Parameter metadata from `init_state` (worker 0's, verified equal
    /// across replicas). Empty before init.
    names: Vec<String>,
    numels: Vec<usize>,
    frozen: Vec<bool>,
    worker_threads: Vec<JoinHandle<()>>,
    reader_threads: Vec<JoinHandle<()>>,
    children: Vec<Child>,
    sock_dir: Option<PathBuf>,
}

impl ShardedBackend {
    /// Construct an (uninitialized) N-worker engine. `workers` is the
    /// requested count; the effective count is clamped to a power of
    /// two ≤ the batch's block count (see module docs) with an info log
    /// when that changes it. `SLTRAIN_WORKER_TRANSPORT` picks `thread`
    /// (default) or `process` replicas.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        preset: ModelPreset,
        method: &str,
        batch: usize,
        lr: f32,
        total_steps: usize,
        threads: usize,
        optim_bits: usize,
        galore_every: usize,
        support: SupportPattern,
        workers: usize,
    ) -> Result<ShardedBackend> {
        let batch = batch.max(1);
        let (n_blocks, n_workers) = plan(batch, workers.max(1));
        if n_workers != workers.max(1) {
            crate::info!(
                "workers clamped {} -> {n_workers} (batch {batch} splits into \
                 {n_blocks} blocks; workers must be a power of two dividing that)",
                workers.max(1)
            );
        }
        let rows_per_block = batch / n_blocks;
        let threads_per_worker = resolve_worker_threads(threads, n_workers);
        let optimizer = match (method, crate::optim::resolve_optim_bits(optim_bits)?) {
            ("galore", _) => "galore",
            (_, crate::optim::OptimBits::F32) => "adam",
            (_, crate::optim::OptimBits::Q8) => "adam8bit",
        };

        let transport = std::env::var("SLTRAIN_WORKER_TRANSPORT")
            .unwrap_or_else(|_| "thread".to_string());
        let (tx, events) = channel::<(usize, Event)>();
        let mut be = ShardedBackend {
            preset: preset.clone(),
            method: method.to_string(),
            optimizer,
            batch,
            n_workers,
            n_blocks,
            rows_per_block,
            threads_per_worker,
            links: RefCell::new(Vec::new()),
            events,
            names: Vec::new(),
            numels: Vec::new(),
            frozen: Vec::new(),
            worker_threads: Vec::new(),
            reader_threads: Vec::new(),
            children: Vec::new(),
            sock_dir: None,
        };
        match transport.trim() {
            "" | "thread" => be.spawn_thread_workers(
                tx, lr, total_steps, optim_bits, galore_every, support,
            )?,
            "process" => be.spawn_process_workers(
                tx, lr, total_steps, optim_bits, galore_every, support,
            )?,
            other => bail!("SLTRAIN_WORKER_TRANSPORT must be thread | process (got {other:?})"),
        }
        Ok(be)
    }

    fn build_replica(
        &self,
        lr: f32,
        total_steps: usize,
        optim_bits: usize,
        galore_every: usize,
        support: SupportPattern,
    ) -> Result<NativeBackend> {
        NativeBackend::build(
            self.preset.clone(),
            &self.method,
            self.rows_per_block,
            lr,
            total_steps,
            self.threads_per_worker,
            optim_bits,
            galore_every,
            support,
        )
    }

    fn spawn_thread_workers(
        &mut self,
        tx: Sender<(usize, Event)>,
        lr: f32,
        total_steps: usize,
        optim_bits: usize,
        galore_every: usize,
        support: SupportPattern,
    ) -> Result<()> {
        let mut links = self.links.borrow_mut();
        for w in 0..self.n_workers {
            let replica =
                self.build_replica(lr, total_steps, optim_bits, galore_every, support.clone())?;
            let (ctx, crx) = channel::<Cmd>();
            let ch = ThreadWorkerChannel { worker: w, rx: crx, tx: tx.clone() };
            let workers = self.n_workers;
            self.worker_threads.push(
                std::thread::Builder::new()
                    .name(format!("shard-worker-{w}"))
                    .spawn(move || worker_loop(replica, ch, w, workers))
                    .map_err(|e| anyhow!("spawn worker thread: {e}"))?,
            );
            links.push(Box::new(ThreadLink { tx: ctx }));
        }
        Ok(())
    }

    fn spawn_process_workers(
        &mut self,
        tx: Sender<(usize, Event)>,
        lr: f32,
        total_steps: usize,
        optim_bits: usize,
        galore_every: usize,
        support: SupportPattern,
    ) -> Result<()> {
        let dir = std::env::temp_dir().join(format!(
            "sltrain-shard-{}-{}",
            std::process::id(),
            SOCKET_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::create_dir_all(&dir)?;
        self.sock_dir = Some(dir.clone());
        let sock = dir.join("workers.sock");
        let listener = UnixListener::bind(&sock)?;
        listener.set_nonblocking(true)?;

        let exe = std::env::current_exe()?;
        for w in 0..self.n_workers {
            // lr crosses as Rust's shortest round-trip f32 text, so the
            // child reparses the identical bits
            let child = Command::new(&exe)
                .arg("shard-worker")
                .args(["--socket", &sock.to_string_lossy()])
                .args(["--worker", &w.to_string()])
                .args(["--workers", &self.n_workers.to_string()])
                .args(["--config", &self.preset.name])
                .args(["--method", &self.method])
                .args(["--batch", &self.rows_per_block.to_string()])
                .args(["--lr", &lr.to_string()])
                .args(["--total-steps", &total_steps.to_string()])
                .args(["--threads", &self.threads_per_worker.to_string()])
                .args(["--optim-bits", &optim_bits.to_string()])
                .args(["--galore-every", &galore_every.to_string()])
                .args(["--support", &support.label()])
                .spawn()
                .map_err(|e| anyhow!("spawn shard-worker {w}: {e}"))?;
            self.children.push(child);
        }
        if let Err(e) = self.accept_workers(&listener, tx) {
            for c in &mut self.children {
                let _ = c.kill();
                let _ = c.wait();
            }
            return Err(e);
        }
        Ok(())
    }

    /// Accept one connection per child, match them to worker indices by
    /// the hello frame, and start an event-reader thread per socket.
    /// Polls with a deadline and watches for children that died before
    /// dialing in (bad flags, missing preset, …).
    fn accept_workers(&mut self, listener: &UnixListener, tx: Sender<(usize, Event)>) -> Result<()> {
        let mut links: Vec<Option<Box<dyn ReplicaLink>>> =
            (0..self.n_workers).map(|_| None).collect();
        let deadline = Instant::now() + ACCEPT_TIMEOUT;
        let mut accepted = 0usize;
        while accepted < self.n_workers {
            match listener.accept() {
                Ok((stream, _)) => {
                    stream.set_nonblocking(false)?;
                    let mut reader = BufReader::new(stream.try_clone()?);
                    let w = read_hello(&mut reader)?;
                    if w >= self.n_workers || links[w].is_some() {
                        bail!("bad hello from shard worker: index {w}");
                    }
                    links[w] = Some(Box::new(SocketLink::new(stream)));
                    self.reader_threads.push(spawn_socket_reader(reader, w, tx.clone()));
                    accepted += 1;
                }
                Err(e) if e.kind() == ErrorKind::WouldBlock => {
                    if Instant::now() > deadline {
                        bail!(
                            "shard workers: {accepted}/{} connected within {:?}",
                            self.n_workers,
                            ACCEPT_TIMEOUT
                        );
                    }
                    for (w, c) in self.children.iter_mut().enumerate() {
                        if let Some(status) = c.try_wait()? {
                            bail!("shard worker {w} exited before connecting: {status}");
                        }
                    }
                    std::thread::sleep(Duration::from_millis(20));
                }
                Err(e) if e.kind() == ErrorKind::Interrupted => {}
                Err(e) => return Err(e.into()),
            }
        }
        *self.links.borrow_mut() =
            links.into_iter().map(|l| l.expect("all workers accepted")).collect();
        Ok(())
    }

    fn send_to(&self, w: usize, cmd: Cmd) -> Result<()> {
        self.links.borrow_mut()[w].send(cmd)
    }

    fn recv_event(&self) -> Result<(usize, Event)> {
        self.events
            .recv_timeout(EVENT_TIMEOUT)
            .map_err(|e| anyhow!("waiting for shard worker events: {e}"))
    }

    /// Drain exactly one expected acknowledgment per worker;
    /// `take(worker, event)` returns true when the event was the one
    /// awaited. `Err` events abort.
    fn collect_acks(
        &self,
        n: usize,
        mut take: impl FnMut(usize, Event) -> Result<bool>,
    ) -> Result<()> {
        let mut got = 0usize;
        while got < n {
            let (w, ev) = self.recv_event()?;
            if let Event::Err { msg } = ev {
                bail!("shard worker {w}: {msg}");
            }
            if take(w, ev)? {
                got += 1;
            }
        }
        Ok(())
    }

    fn require_init(&self) -> Result<()> {
        if self.names.is_empty() {
            bail!("sharded backend: state not initialized (call init_state)");
        }
        Ok(())
    }

    fn merged_state(&self) -> Result<Vec<StateTensor>> {
        self.require_init()?;
        for w in 0..self.n_workers {
            self.send_to(w, Cmd::GetState)?;
        }
        let mut states: Vec<Option<Vec<StateTensor>>> = vec![None; self.n_workers];
        self.collect_acks(self.n_workers, |w, ev| match ev {
            Event::State { tensors } => {
                states[w] = Some(tensors);
                Ok(true)
            }
            other => bail!("unexpected event {other:?} while snapshotting"),
        })?;
        let states: Vec<Vec<StateTensor>> =
            states.into_iter().map(|s| s.expect("collected")).collect();

        // Merge into the plain engine's exact flat namespace and tensor
        // order: worker 0's non-optim tensors (params in name order,
        // then supports — identical on every replica), then per
        // parameter IN WORKER 0'S EMISSION ORDER the owner's `optim.*`
        // tensors (projector, m, v — the owner holds the live moments;
        // everyone else serializes zero-length placeholders, dropped
        // here). The result is byte-comparable with any other worker
        // count's snapshot — the sharded-checkpoint portability
        // contract.
        let id_of: HashMap<&str, usize> =
            self.names.iter().enumerate().map(|(i, n)| (n.as_str(), i)).collect();
        let mut merged: Vec<StateTensor> = Vec::new();
        let mut order: Vec<String> = Vec::new();
        let mut groups: Vec<HashMap<String, Vec<StateTensor>>> = Vec::new();
        for (w, tensors) in states.iter().enumerate() {
            let mut g: HashMap<String, Vec<StateTensor>> = HashMap::new();
            for t in tensors {
                if !t.name.starts_with("optim.") {
                    if w == 0 {
                        merged.push(t.clone());
                    }
                    continue;
                }
                let pname = optim_param_name(&t.name)
                    .ok_or_else(|| anyhow!("{}: unrecognized optim tensor", t.name))?;
                if w == 0 && !g.contains_key(pname) {
                    if !id_of.contains_key(pname) {
                        bail!("{}: unknown parameter", t.name);
                    }
                    order.push(pname.to_string());
                }
                g.entry(pname.to_string()).or_default().push(t.clone());
            }
            groups.push(g);
        }
        for pname in order {
            let pname = pname.as_str();
            let &id = id_of.get(pname).ok_or_else(|| anyhow!("{pname}: unknown parameter"))?;
            let owner = id % self.n_workers;
            let g = groups[owner]
                .remove(pname)
                .ok_or_else(|| anyhow!("{pname}: owner {owner} has no optim tensors"))?;
            merged.extend(g);
        }
        Ok(merged)
    }
}

impl Backend for ShardedBackend {
    fn kind(&self) -> &'static str {
        "sharded"
    }

    fn method(&self) -> &str {
        &self.method
    }

    fn preset(&self) -> &ModelPreset {
        &self.preset
    }

    fn batch_size(&self) -> usize {
        self.batch
    }

    fn optimizer(&self) -> &str {
        self.optimizer
    }

    fn workers(&self) -> usize {
        self.n_workers
    }

    fn n_params(&self) -> usize {
        if self.numels.is_empty() {
            return self.preset.param_count(&self.method);
        }
        self.numels.iter().sum()
    }

    fn init_state(&mut self, seed: u32) -> Result<()> {
        for w in 0..self.n_workers {
            self.send_to(w, Cmd::Init { seed })?;
        }
        let mut metas: Vec<Option<(Vec<String>, Vec<usize>, Vec<bool>)>> =
            vec![None; self.n_workers];
        self.collect_acks(self.n_workers, |w, ev| match ev {
            Event::Inited { names, numels, frozen } => {
                metas[w] = Some((names, numels, frozen));
                Ok(true)
            }
            other => bail!("unexpected event {other:?} during init"),
        })?;
        let metas: Vec<_> = metas.into_iter().map(|m| m.expect("collected")).collect();
        for m in &metas[1..] {
            if m.0 != metas[0].0 {
                bail!("replicas disagree on the parameter set — nondeterministic init?");
            }
        }
        let (names, numels, frozen) = metas.into_iter().next().expect("n_workers >= 1");
        self.names = names;
        self.numels = numels;
        self.frozen = frozen;
        Ok(())
    }

    fn train_step(&mut self, step: i32, tokens: &[i32]) -> Result<f32> {
        self.require_init()?;
        let seq = self.preset.seq_len;
        if tokens.len() != self.batch * seq {
            bail!(
                "train_step expects batch*seq = {} tokens (got {})",
                self.batch * seq,
                tokens.len()
            );
        }
        let np = self.names.len();
        let block_tokens = self.rows_per_block * seq;
        let blocks_per_worker = self.n_blocks / self.n_workers;

        // fan the contiguous blocks out to their owners
        for w in 0..self.n_workers {
            let blocks = (w * blocks_per_worker..(w + 1) * blocks_per_worker)
                .map(|b| (b, tokens[b * block_tokens..(b + 1) * block_tokens].to_vec()))
                .collect();
            self.send_to(w, Cmd::Step { step, blocks })?;
        }

        // overlapped reduce: gradients stream in while replicas are
        // still walking their backwards; each parameter reduces the
        // moment its B'th block arrives
        let mut slots: Vec<Vec<Option<Vec<f32>>>> =
            (0..np).map(|_| vec![None; self.n_blocks]).collect();
        let mut filled = vec![0usize; np];
        let mut reduced: Vec<Option<Vec<f32>>> = (0..np).map(|_| None).collect();
        let mut awaiting = self.frozen.iter().filter(|&&f| !f).count();
        let mut losses: Vec<Option<f64>> = vec![None; self.n_blocks];
        let mut stepdones = 0usize;
        while stepdones < self.n_workers || awaiting > 0 {
            let (w, ev) = self.recv_event()?;
            match ev {
                Event::Grad { block, param, grad } => {
                    if param >= np || block >= self.n_blocks {
                        bail!("worker {w}: gradient for unknown param {param} block {block}");
                    }
                    if self.frozen[param] || grad.len() != self.numels[param] {
                        bail!("worker {w}: malformed gradient for param {param}");
                    }
                    if slots[param][block].replace(grad).is_some() {
                        bail!("worker {w}: duplicate gradient param {param} block {block}");
                    }
                    filled[param] += 1;
                    if filled[param] == self.n_blocks {
                        reduced[param] = Some(tree_reduce(std::mem::take(&mut slots[param]))?);
                        awaiting -= 1;
                    }
                }
                Event::StepDone { losses: ls } => {
                    for (b, l) in ls {
                        if b >= self.n_blocks || losses[b].replace(l).is_some() {
                            bail!("worker {w}: bad or duplicate loss for block {b}");
                        }
                    }
                    stepdones += 1;
                }
                Event::Err { msg } => bail!("shard worker {w}: {msg}"),
                other => bail!("unexpected event {other:?} during step"),
            }
        }
        // serial f64 sum in block order: the N-invariant batch loss
        let mut sum = 0f64;
        for b in 0..self.n_blocks {
            sum += losses[b].ok_or_else(|| anyhow!("block {b} reported no loss"))?;
        }
        let loss = sum / self.n_blocks as f64;

        // owner-sharded Adam: each worker applies its own parameters...
        let mut owned: Vec<Vec<(usize, Vec<f32>)>> =
            (0..self.n_workers).map(|_| Vec::new()).collect();
        for (idx, g) in reduced.iter_mut().enumerate() {
            if let Some(g) = g.take() {
                owned[idx % self.n_workers].push((idx, g));
            }
        }
        for (w, grads) in owned.into_iter().enumerate() {
            self.send_to(w, Cmd::Apply { step, grads })?;
        }
        let mut updated: Vec<(usize, Vec<f32>)> = Vec::new();
        self.collect_acks(self.n_workers, |w, ev| match ev {
            Event::Applied { updated: u } => {
                updated.extend(u);
                Ok(true)
            }
            other => bail!("unexpected event {other:?} during apply (worker {w})"),
        })?;
        // ...then every replica absorbs the other owners' updates
        for w in 0..self.n_workers {
            let params: Vec<(usize, Vec<f32>)> = updated
                .iter()
                .filter(|(i, _)| i % self.n_workers != w)
                .cloned()
                .collect();
            self.send_to(w, Cmd::SetParams { params })?;
        }
        self.collect_acks(self.n_workers, |w, ev| match ev {
            Event::SetDone => Ok(true),
            other => bail!("unexpected event {other:?} during broadcast (worker {w})"),
        })?;
        Ok(loss as f32)
    }

    fn eval_loss(&mut self, tokens: &[i32]) -> Result<f32> {
        self.require_init()?;
        // replicas hold identical parameters between steps; worker 0
        // evaluates the full batch at the full-batch row count
        self.send_to(0, Cmd::Eval { bsz: self.batch, tokens: tokens.to_vec() })?;
        let mut loss = 0f64;
        self.collect_acks(1, |w, ev| match ev {
            Event::EvalDone { loss: l } => {
                loss = l;
                Ok(true)
            }
            other => bail!("unexpected event {other:?} during eval (worker {w})"),
        })?;
        Ok(loss as f32)
    }

    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>> {
        self.require_init()?;
        self.send_to(0, Cmd::Forward { tokens: tokens.to_vec() })?;
        let mut out = Vec::new();
        self.collect_acks(1, |w, ev| match ev {
            Event::ForwardDone { logits } => {
                out = logits;
                Ok(true)
            }
            other => bail!("unexpected event {other:?} during forward (worker {w})"),
        })?;
        Ok(out)
    }

    fn merge(&mut self, seed: i32) -> Result<()> {
        self.require_init()?;
        // deterministic from the seed, so every replica restarts its
        // adaptors identically — no broadcast needed
        for w in 0..self.n_workers {
            self.send_to(w, Cmd::Merge { seed })?;
        }
        self.collect_acks(self.n_workers, |w, ev| match ev {
            Event::Merged => Ok(true),
            other => bail!("unexpected event {other:?} during merge (worker {w})"),
        })
    }

    fn drop_optimizer_state(&mut self) -> Result<()> {
        for w in 0..self.n_workers {
            self.send_to(w, Cmd::DropOptim)?;
        }
        self.collect_acks(self.n_workers, |w, ev| match ev {
            Event::Dropped => Ok(true),
            other => bail!("unexpected event {other:?} during drop (worker {w})"),
        })
    }

    fn fold_weights(&mut self) -> Result<()> {
        self.require_init()?;
        for w in 0..self.n_workers {
            self.send_to(w, Cmd::Fold)?;
        }
        self.collect_acks(self.n_workers, |w, ev| match ev {
            Event::Folded => Ok(true),
            other => bail!("unexpected event {other:?} during fold (worker {w})"),
        })
    }

    fn mem_report(&self) -> Option<MemReport> {
        let fetch = || -> Result<MemReport> {
            for w in 0..self.n_workers {
                self.send_to(w, Cmd::MemReport)?;
            }
            let mut reports: Vec<Option<MemReport>> = vec![None; self.n_workers];
            self.collect_acks(self.n_workers, |w, ev| match ev {
                Event::Mem { report } => {
                    reports[w] = Some(report);
                    Ok(true)
                }
                other => bail!("unexpected event {other:?} during mem report (worker {w})"),
            })?;
            // params/supports/projectors are replicated (same bytes
            // everywhere); moments are owner-sharded, so the honest
            // per-worker figure is the max across replicas — ~1/N of
            // the single-engine optimizer bytes
            let mut out = reports[0].take().expect("collected");
            for r in reports.into_iter().flatten() {
                out.optim_bytes = out.optim_bytes.max(r.optim_bytes);
                out.grad_peak_bytes = out.grad_peak_bytes.max(r.grad_peak_bytes);
            }
            out.workers = self.n_workers as u32;
            Ok(out)
        };
        fetch().ok()
    }

    fn state_tensors(&self) -> Result<Vec<StateTensor>> {
        self.merged_state()
    }

    fn load_state_tensors(&mut self, tensors: &[StateTensor]) -> Result<()> {
        self.require_init()?;
        for w in 0..self.n_workers {
            self.send_to(w, Cmd::LoadState { tensors: tensors.to_vec() })?;
        }
        self.collect_acks(self.n_workers, |w, ev| match ev {
            Event::Loaded => Ok(true),
            other => bail!("unexpected event {other:?} during load (worker {w})"),
        })
    }
}

impl Drop for ShardedBackend {
    fn drop(&mut self) {
        {
            let mut links = self.links.borrow_mut();
            for l in links.iter_mut() {
                let _ = l.send(Cmd::Shutdown);
            }
        }
        for h in self.worker_threads.drain(..) {
            let _ = h.join();
        }
        for mut c in self.children.drain(..) {
            let _ = c.wait();
        }
        for h in self.reader_threads.drain(..) {
            let _ = h.join();
        }
        if let Some(d) = self.sock_dir.take() {
            let _ = std::fs::remove_dir_all(d);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn block_plan_is_a_pure_function_of_the_batch() {
        // B = largest power of two <= 4 dividing batch
        assert_eq!(plan(1, 4), (1, 1));
        assert_eq!(plan(2, 4), (2, 2));
        assert_eq!(plan(3, 4), (1, 1));
        assert_eq!(plan(4, 4), (4, 4));
        assert_eq!(plan(6, 4), (2, 2));
        assert_eq!(plan(8, 4), (4, 4));
        assert_eq!(plan(12, 2), (4, 2));
        // workers clamp to a power of two <= min(requested, B)
        assert_eq!(plan(4, 3), (4, 2));
        assert_eq!(plan(4, 1), (4, 1));
        assert_eq!(plan(8, 16), (4, 4));
    }

    #[test]
    fn tree_reduce_is_block_order_invariant_of_worker_assignment() {
        // the tree reads slots by block index, so HOW blocks were
        // distributed across workers cannot matter; check the 4-block
        // tree does ((b0+b1)+(b2+b3))/4 exactly
        let mk = |v: [f32; 2]| Some(v.to_vec());
        let got = tree_reduce(vec![mk([1.0, -2.0]), mk([0.5, 4.0]), mk([2.0, 8.0]), mk([4.0, 16.0])])
            .unwrap();
        let want0 = (((1.0f32 + 0.5) + (2.0 + 4.0)) * 0.25).to_bits();
        let want1 = ((((-2.0f32) + 4.0) + (8.0 + 16.0)) * 0.25).to_bits();
        assert_eq!(got[0].to_bits(), want0);
        assert_eq!(got[1].to_bits(), want1);
    }

    #[test]
    fn optim_names_parse_back_to_their_parameter() {
        for (name, want) in [
            ("optim.m.embed.w", Some("embed.w")),
            ("optim.v.layers.0.attn.q.B", Some("layers.0.attn.q.B")),
            ("optim.m.q8.head.w", Some("head.w")),
            ("optim.v.scale.head.w", Some("head.w")),
            ("optim.proj.layers.1.mlp.up.w", Some("layers.1.mlp.up.w")),
            ("layers.0.attn.q.B", None),
            ("optim.bogus.x", None),
        ] {
            assert_eq!(optim_param_name(name), want, "{name}");
        }
    }
}

//! Execution backends: the seam between SLTrain's method logic and the
//! engine that runs the compute.
//!
//! The training coordinator (`coordinator::trainer`), the CLI and the
//! bench harness all program against `dyn Backend` — the execution
//! contract a pretraining run actually needs: state init, one optimizer
//! step, held-out loss, a raw forward, the ReLoRA restart hook, and
//! enough state introspection to checkpoint and analyze. Two
//! implementations exist:
//!
//! * [`native::NativeBackend`] — a pure-rust transformer trainer built on
//!   `linalg::Matrix` + `linalg::sparse`, covering all five methods of
//!   `config::METHODS` (full, lowrank, sltrain, relora, galore) with
//!   full forward/backward, Adam (f32 or 8-bit moments), the ReLoRA
//!   merge-and-restart hook and the GaLore projected-space optimizer.
//!   Needs no artifacts, no XLA, no Python: the deterministic reference
//!   the AOT path is parity-tested against.
//! * `xla_backend::XlaBackend` (cargo feature `xla`) — a thin adapter
//!   over the AOT/PJRT machinery in `runtime::pjrt`, executing the
//!   HLO-text artifact bundles emitted by `python/compile/aot.py`.
//!
//! Selection is data-driven via [`BackendSpec`] (the `--backend
//! {xla,native}` CLI flag), so every consumer from `main.rs` down to the
//! bench binaries is engine-agnostic.
#![deny(missing_docs)]

pub(crate) mod comm;
pub mod native;
pub mod sharded;

#[cfg(feature = "xla")]
pub mod xla_backend;

use std::path::PathBuf;

use anyhow::{bail, Result};

use crate::config::{preset, ModelPreset};
use crate::linalg::SupportPattern;
use crate::runtime::Dtype;

/// One named tensor of backend state, in the interchange layout shared
/// with checkpoints and artifact sidecars (little-endian raw bytes).
#[derive(Debug, Clone)]
pub struct StateTensor {
    /// Dot-path tensor name (`layers.0.attn.q.B`, `optim.m.embed.w`, …).
    pub name: String,
    /// Logical shape; the byte payload is row-major.
    pub shape: Vec<usize>,
    /// Element type of the payload.
    pub dtype: Dtype,
    /// Little-endian raw bytes, `shape.product()` elements.
    pub bytes: Vec<u8>,
}

impl StateTensor {
    /// Pack an f32 tensor into the interchange layout.
    pub fn f32(name: &str, shape: Vec<usize>, data: &[f32]) -> StateTensor {
        StateTensor {
            name: name.to_string(),
            shape,
            dtype: Dtype::F32,
            bytes: data.iter().flat_map(|x| x.to_le_bytes()).collect(),
        }
    }

    /// Pack an i32 tensor (sparse-support indices) into the layout.
    pub fn i32(name: &str, shape: Vec<usize>, data: &[i32]) -> StateTensor {
        StateTensor {
            name: name.to_string(),
            shape,
            dtype: Dtype::I32,
            bytes: data.iter().flat_map(|x| x.to_le_bytes()).collect(),
        }
    }

    /// Raw signed-8 payload (quantized optimizer moment codes).
    pub fn i8(name: &str, shape: Vec<usize>, data: &[i8]) -> StateTensor {
        StateTensor {
            name: name.to_string(),
            shape,
            dtype: Dtype::I8,
            bytes: data.iter().map(|&x| x as u8).collect(),
        }
    }

    /// Decode the payload as f32 (errors on any other dtype).
    pub fn to_f32(&self) -> Result<Vec<f32>> {
        if self.dtype != Dtype::F32 {
            bail!("{}: not f32", self.name);
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Decode the payload as i32 (u32 accepted bit-for-bit).
    pub fn to_i32(&self) -> Result<Vec<i32>> {
        if self.dtype != Dtype::I32 && self.dtype != Dtype::U32 {
            bail!("{}: not i32/u32", self.name);
        }
        Ok(self
            .bytes
            .chunks_exact(4)
            .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
            .collect())
    }

    /// Decode the payload as raw i8 (quantized moment codes).
    pub fn to_i8(&self) -> Result<Vec<i8>> {
        if self.dtype != Dtype::I8 {
            bail!("{}: not i8", self.name);
        }
        Ok(self.bytes.iter().map(|&b| b as i8).collect())
    }
}

/// The execution contract of one pretraining run.
///
/// A backend owns its model/optimizer state after `init_state`; the
/// coordinator shuttles only token batches in and scalar losses out —
/// exactly the host traffic pattern of the AOT artifact path, so a
/// pure-rust engine and a PJRT engine are interchangeable behind it.
pub trait Backend {
    /// Short engine tag ("native", "xla") for logs and summaries.
    fn kind(&self) -> &'static str;

    /// Weight parameterization under training (config::METHODS).
    fn method(&self) -> &str;

    /// Architectural shape of the model being trained.
    fn preset(&self) -> &ModelPreset;

    /// Rows per train-step token batch.
    fn batch_size(&self) -> usize;

    /// Rows per forward-entrypoint batch (may differ from train batch).
    fn forward_batch_size(&self) -> usize {
        self.batch_size()
    }

    /// Optimizer family driving `train_step`.
    fn optimizer(&self) -> &str {
        "adam"
    }

    /// Sequence length of every token batch (the preset's `seq_len`).
    fn seq_len(&self) -> usize {
        self.preset().seq_len
    }

    /// Data-parallel worker count behind this engine: 1 for a
    /// single-replica engine, N for `sharded::ShardedBackend` — a
    /// logging/reporting hook, not a behavioral knob.
    fn workers(&self) -> usize {
        1
    }

    /// Trainable parameter count (paper Table 2 "Param").
    fn n_params(&self) -> usize;

    /// Initialize parameters, optimizer state and sparse supports.
    fn init_state(&mut self, seed: u32) -> Result<()>;

    /// One optimizer step on a [batch, seq] row-major token batch.
    /// Returns the scalar training loss.
    fn train_step(&mut self, step: i32, tokens: &[i32]) -> Result<f32>;

    /// Held-out loss on one batch (no state mutation).
    fn eval_loss(&mut self, tokens: &[i32]) -> Result<f32>;

    /// Forward pass returning logits [batch, seq, vocab] flattened.
    fn forward(&mut self, tokens: &[i32]) -> Result<Vec<f32>>;

    /// ReLoRA restart hook (paper eq. 1), implemented by both engines
    /// for `method == "relora"`. The contract:
    ///
    /// * `W0 ← W0 + scale·B·A` for every adapted linear, then `B ← 0`
    ///   and `A ←` a fresh Kaiming draw derived deterministically from
    ///   `seed` — so the function the model computes is unchanged up to
    ///   f32 re-association (eval loss is continuous across a merge).
    /// * The Adam moments of the re-initialized adaptors are reset to
    ///   zero — under 8-bit moments that means the quantized codes
    ///   *and* their per-block scales.
    /// * Same `seed` ⇒ bit-identical post-merge state, at every thread
    ///   count (the coordinator passes the step number as the seed, so
    ///   resumed runs replay merges exactly).
    ///
    /// Errors for every other method; the default implementation errors
    /// unconditionally (an engine that cannot restart must refuse, not
    /// no-op, or the relora baseline silently degrades to lowrank).
    fn merge(&mut self, seed: i32) -> Result<()> {
        let _ = seed;
        bail!("{} backend has no merge/restart entrypoint", self.kind())
    }

    /// Drop optimizer moments (Table-5 inference footprint).
    fn drop_optimizer_state(&mut self) -> Result<()> {
        Ok(())
    }

    /// Fold every adapted linear into a plain dense weight — the
    /// paper's Table-5 inference recipe, applied in place:
    ///
    /// * sltrain: `W ← scale·B·A ⊕_idx vals` (the fused kernel of
    ///   `linalg::sparse::SparseSupport::fused_effective`),
    /// * lowrank: `W ← scale·B·A`,
    /// * relora: `W ← W0 + scale·B·A` (the merge fold, without the
    ///   restart),
    /// * full / galore: the weight is already dense — unchanged.
    ///
    /// After folding the engine is inference-only: `forward` and
    /// `eval_loss` run on the dense weights (one matmul per linear, no
    /// factored or sparse kernels on the hot path), optimizer state is
    /// dropped, and `train_step`/`merge` refuse. Folding is
    /// deterministic: the same state folds to bit-identical dense
    /// weights at every thread count. The default implementation
    /// errors — an engine that cannot materialize its effective
    /// weights must refuse rather than silently serve factored ones.
    fn fold_weights(&mut self) -> Result<()> {
        bail!("{} backend has no fold-for-inference entrypoint", self.kind())
    }

    /// Measured memory footprint of the live training state — params,
    /// optimizer moments as actually held (f32 or 8-bit), and the
    /// gradient-buffer high-water of the step loop. `None` when the
    /// engine does not track it (the PJRT path holds device buffers).
    fn mem_report(&self) -> Option<crate::mem::MemReport> {
        None
    }

    /// Snapshot persistent state (params + fixed supports) for
    /// checkpointing and analysis.
    fn state_tensors(&self) -> Result<Vec<StateTensor>>;

    /// Restore state previously captured by `state_tensors` (resume /
    /// parity tooling). Unknown names error; missing names are left at
    /// their initialized values.
    fn load_state_tensors(&mut self, tensors: &[StateTensor]) -> Result<()>;
}

/// Data-driven backend selection: everything the CLI / bench flags say.
#[derive(Debug, Clone)]
pub enum BackendSpec {
    /// AOT artifact bundle executed through PJRT (feature `xla`).
    Xla {
        /// Directory holding the HLO-text artifact bundle.
        artifact_dir: PathBuf,
    },
    /// Pure-rust engine: preset + method + run hyperparameters.
    Native {
        /// Architectural shape to instantiate.
        preset: ModelPreset,
        /// Weight parameterization (`config::METHODS`).
        method: String,
        /// Rows per train-step token batch.
        batch: usize,
        /// Base learning rate of the warmup+cosine schedule.
        lr: f32,
        /// lr-schedule horizon (mirrors aot.py's total_steps default).
        total_steps: usize,
        /// Worker threads for the step loop (0 = auto: SLTRAIN_THREADS
        /// env, else available parallelism). Losses are bit-identical
        /// for every thread count.
        threads: usize,
        /// Adam moment precision: 32 (f32) or 8 (block-wise absmax
        /// quantized, Dettmers et al. [9]); 0 = auto (the
        /// SLTRAIN_OPTIM_BITS env var, else 32). At 32 the step loop is
        /// bit-identical to the two-phase reference; at 8 it stays
        /// deterministic and thread-count-invariant but diverges
        /// numerically (bounded per-block quantization error).
        optim_bits: usize,
        /// GaLore projector refresh period in steps (`--galore-every`):
        /// the rank-r gradient subspace is recomputed by truncated SVD
        /// at step 0 and every multiple of this period. 0 = default
        /// (200, the aot.py `galore_refresh` default). Ignored unless
        /// the method is galore.
        galore_every: usize,
        /// Sparse-support pattern for the sltrain method (`--support`):
        /// the paper's uniform-random support at the preset's `delta`,
        /// or SLoPe-style structured N:M (density n/m, vectorizable
        /// kernels). Ignored by methods without a sparse factor.
        support: SupportPattern,
        /// Data-parallel worker count (`--workers`): 0 = auto (the
        /// `SLTRAIN_WORKERS` env var, else single-engine). Any value
        /// ≥ 1 opens the deterministic `sharded::ShardedBackend` —
        /// including 1, the bitwise reference point of the worker-count
        /// determinism axis. The effective count is clamped to a power
        /// of two no larger than the batch's microbatch block count.
        workers: usize,
    },
}

impl BackendSpec {
    /// Build a spec from the shared CLI flag set. `backend` is "xla" or
    /// "native"; `artifact` is required for xla, `config`/`method` for
    /// native.
    #[allow(clippy::too_many_arguments)]
    pub fn from_flags(
        backend: &str,
        artifact: &str,
        config: &str,
        method: &str,
        batch: usize,
        lr: f64,
        total_steps: usize,
        threads: usize,
        optim_bits: usize,
        galore_every: usize,
        support: &str,
        workers: usize,
    ) -> Result<BackendSpec> {
        match backend {
            "xla" => {
                if artifact.is_empty() {
                    bail!("--backend xla needs --artifact <dir>");
                }
                Ok(BackendSpec::Xla { artifact_dir: PathBuf::from(artifact) })
            }
            "native" => {
                if !artifact.is_empty() {
                    bail!(
                        "--artifact is an xla-backend flag; pass --backend xla \
                         (or drop --artifact)"
                    );
                }
                let p = preset(config)
                    .ok_or_else(|| anyhow::anyhow!("unknown preset {config:?}"))?;
                let support =
                    SupportPattern::parse(support).map_err(|e| anyhow::anyhow!("--support: {e}"))?;
                Ok(BackendSpec::Native {
                    preset: p,
                    method: method.to_string(),
                    batch: batch.max(1),
                    lr: lr as f32,
                    total_steps: total_steps.max(1),
                    threads,
                    optim_bits,
                    galore_every,
                    support,
                    workers,
                })
            }
            other => bail!("unknown backend {other:?} (expected xla | native)"),
        }
    }
}

/// Resolve the `--workers` flag: `0` means "auto" — the
/// `SLTRAIN_WORKERS` env var if set (so the whole test suite can run
/// data-parallel without touching every call site), else 0 = the plain
/// single-engine path.
pub fn resolve_workers(requested: usize) -> Result<usize> {
    if requested > 0 {
        return Ok(requested);
    }
    match std::env::var("SLTRAIN_WORKERS") {
        Ok(raw) => match raw.trim().parse::<usize>() {
            Ok(n) => Ok(n),
            Err(_) => bail!("SLTRAIN_WORKERS must be a worker count (got {raw:?})"),
        },
        Err(_) => Ok(0),
    }
}

/// Open the backend a spec describes. The xla arm fails at runtime (not
/// compile time) when the crate was built without the `xla` feature, so
/// every binary stays artifact-free by default. A native spec with
/// `workers >= 1` (flag or `SLTRAIN_WORKERS`) opens the data-parallel
/// [`sharded::ShardedBackend`]; `workers == 0` keeps the plain
/// single-engine path, bit-for-bit unchanged.
pub fn open(spec: BackendSpec) -> Result<Box<dyn Backend>> {
    match spec {
        BackendSpec::Xla { artifact_dir } => open_xla(artifact_dir),
        BackendSpec::Native {
            preset,
            method,
            batch,
            lr,
            total_steps,
            threads,
            optim_bits,
            galore_every,
            support,
            workers,
        } => match resolve_workers(workers)? {
            0 => Ok(Box::new(native::NativeBackend::build(
                preset,
                &method,
                batch,
                lr,
                total_steps,
                threads,
                optim_bits,
                galore_every,
                support,
            )?)),
            n => Ok(Box::new(sharded::ShardedBackend::build(
                preset,
                &method,
                batch,
                lr,
                total_steps,
                threads,
                optim_bits,
                galore_every,
                support,
                n,
            )?)),
        },
    }
}

#[cfg(feature = "xla")]
fn open_xla(artifact_dir: PathBuf) -> Result<Box<dyn Backend>> {
    Ok(Box::new(xla_backend::XlaBackend::open(&artifact_dir)?))
}

#[cfg(not(feature = "xla"))]
fn open_xla(artifact_dir: PathBuf) -> Result<Box<dyn Backend>> {
    bail!(
        "backend xla requested for {artifact_dir:?}, but this build has no XLA \
         support — rebuild with `--features xla`, or use --backend native"
    )
}

//! Comm layer of the data-parallel sharded backend.
//!
//! Defines the command/event protocol between the parent
//! [`super::sharded::ShardedBackend`] and its N replica workers, plus
//! the two transports that carry it:
//!
//! * **threads** (default) — each replica lives on a worker thread in
//!   this process; commands and events move over `std::sync::mpsc`
//!   channels with no serialization.
//! * **processes** (`SLTRAIN_WORKER_TRANSPORT=process`) — each replica
//!   is a child OS process (the hidden `shard-worker` subcommand of the
//!   own binary) connected over a Unix domain socket. Frames reuse the
//!   serve daemon's idioms: one JSON header line, then a raw
//!   little-endian byte payload, so every f32/f64 crosses the wire
//!   bit-exactly and the determinism contract holds across transports.
//!
//! Both sides of a transport implement the same two small traits —
//! [`ReplicaLink`] (parent → worker commands) and [`WorkerChannel`]
//! (worker side: receive commands, emit events) — and all events from
//! every worker funnel into ONE parent-side `mpsc` receiver tagged with
//! the worker index, which is what lets the parent reduce gradients in
//! arrival order while replicas are still walking their backward.

use std::io::{BufRead, BufReader, BufWriter, Read, Write};
use std::os::unix::net::UnixStream;
use std::sync::mpsc::{Receiver, Sender};

use anyhow::{anyhow, bail, Result};

use super::StateTensor;
use crate::mem::MemReport;
use crate::runtime::Dtype;
use crate::util::json::{num, obj, s, Json};

/// Parent → worker commands. Token/gradient/parameter payloads ride as
/// `(id, data)` pairs so a worker only ever sees the blocks and
/// parameters the parent routed to it.
#[derive(Debug)]
pub(crate) enum Cmd {
    /// Initialize replica state from the seed, then owner-shard moments.
    Init { seed: u32 },
    /// Run forward+backward on the listed `(block id, tokens)` blocks,
    /// streaming one `Event::Grad` per finalized gradient.
    Step { step: i32, blocks: Vec<(usize, Vec<i32>)> },
    /// Apply the reduced gradients for the worker's owned parameters.
    Apply { step: i32, grads: Vec<(usize, Vec<f32>)> },
    /// Overwrite parameters updated by OTHER owners this step.
    SetParams { params: Vec<(usize, Vec<f32>)> },
    /// Held-out loss on a full batch (worker 0 only).
    Eval { bsz: usize, tokens: Vec<i32> },
    /// Raw forward logits (worker 0 only).
    Forward { tokens: Vec<i32> },
    /// ReLoRA merge-and-restart from the seed (all replicas).
    Merge { seed: i32 },
    /// Drop optimizer state (Table-5 inference footprint).
    DropOptim,
    /// Fold every adapted linear dense, in place.
    Fold,
    /// Snapshot the replica's state tensors.
    GetState,
    /// Restore a full flat-namespace state set, then re-shard moments.
    LoadState { tensors: Vec<StateTensor> },
    /// Report the replica's measured memory footprint.
    MemReport,
    /// Exit the worker loop.
    Shutdown,
}

/// Worker → parent events, tagged with the worker index by the
/// transport. `Err` carries any handler failure to the parent, which
/// bails the in-flight operation.
#[derive(Debug)]
pub(crate) enum Event {
    /// Replica initialized; parameter metadata for the parent's reducer
    /// and state-merge bookkeeping.
    Inited { names: Vec<String>, numels: Vec<usize>, frozen: Vec<bool> },
    /// One finalized block gradient (the all-reduce overlap traffic).
    Grad { block: usize, param: usize, grad: Vec<f32> },
    /// All of this worker's blocks finished; per-block mean losses.
    StepDone { losses: Vec<(usize, f64)> },
    /// Owned parameters updated; their post-update data for broadcast.
    Applied { updated: Vec<(usize, Vec<f32>)> },
    /// `SetParams` absorbed.
    SetDone,
    /// `Eval` result.
    EvalDone { loss: f64 },
    /// `Forward` result.
    ForwardDone { logits: Vec<f32> },
    /// `Merge` finished (moments re-sharded).
    Merged,
    /// `DropOptim` finished.
    Dropped,
    /// `Fold` finished.
    Folded,
    /// `GetState` snapshot.
    State { tensors: Vec<StateTensor> },
    /// `LoadState` finished (moments re-sharded).
    Loaded,
    /// `MemReport` result.
    Mem { report: MemReport },
    /// A handler failed; the message carries the error chain.
    Err { msg: String },
}

/// Parent-side handle to one replica: sends commands. Events arrive on
/// the shared `(worker, Event)` receiver owned by the parent.
pub(crate) trait ReplicaLink: Send {
    /// Enqueue one command toward the replica.
    fn send(&mut self, cmd: Cmd) -> Result<()>;
}

/// Worker-side endpoint: blocking command receive + event emit.
pub(crate) trait WorkerChannel {
    /// Block until the next command arrives.
    fn recv(&mut self) -> Result<Cmd>;
    /// Emit one event toward the parent.
    fn send(&mut self, ev: Event) -> Result<()>;
}

// ------------------------------------------------ thread transport

/// In-process link: commands over a private mpsc channel.
pub(crate) struct ThreadLink {
    pub tx: Sender<Cmd>,
}

impl ReplicaLink for ThreadLink {
    fn send(&mut self, cmd: Cmd) -> Result<()> {
        self.tx.send(cmd).map_err(|_| anyhow!("worker thread hung up"))
    }
}

/// In-process worker endpoint: private command receiver, shared tagged
/// event sender.
pub(crate) struct ThreadWorkerChannel {
    pub worker: usize,
    pub rx: Receiver<Cmd>,
    pub tx: Sender<(usize, Event)>,
}

impl WorkerChannel for ThreadWorkerChannel {
    fn recv(&mut self) -> Result<Cmd> {
        self.rx.recv().map_err(|_| anyhow!("parent hung up"))
    }

    fn send(&mut self, ev: Event) -> Result<()> {
        self.tx.send((self.worker, ev)).map_err(|_| anyhow!("parent hung up"))
    }
}

// ------------------------------------------------ socket framing
//
// One frame = one compact JSON header line (`{"op": ..., ...}\n`) +
// `nbytes` raw payload bytes, little-endian — the serve daemon's
// newline-delimited-JSON control plane with a binary data plane bolted
// on. Integer metadata (ids, lengths) is exact in JSON below 2^53;
// every float payload crosses as raw LE bytes, never as decimal text.

fn dtype_name(d: &Dtype) -> &'static str {
    match d {
        Dtype::F32 => "f32",
        Dtype::I32 => "i32",
        Dtype::I8 => "i8",
        Dtype::U32 => "u32",
    }
}

fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn i32s_to_bytes(v: &[i32]) -> Vec<u8> {
    v.iter().flat_map(|x| x.to_le_bytes()).collect()
}

fn bytes_to_f32s(b: &[u8]) -> Vec<f32> {
    b.chunks_exact(4).map(|c| f32::from_le_bytes(c.try_into().unwrap())).collect()
}

fn bytes_to_i32s(b: &[u8]) -> Vec<i32> {
    b.chunks_exact(4).map(|c| i32::from_le_bytes(c.try_into().unwrap())).collect()
}

fn arr_usize(v: &[usize]) -> Json {
    Json::Arr(v.iter().map(|&x| num(x as f64)).collect())
}

fn get_usizes(h: &Json, key: &str) -> Result<Vec<usize>> {
    h.req(key)?
        .as_arr()
        .ok_or_else(|| anyhow!("{key}: not an array"))?
        .iter()
        .map(|v| v.as_usize().ok_or_else(|| anyhow!("{key}: not a number")))
        .collect()
}

fn get_usize(h: &Json, key: &str) -> Result<usize> {
    h.req(key)?.as_usize().ok_or_else(|| anyhow!("{key}: not a number"))
}

fn get_i64(h: &Json, key: &str) -> Result<i64> {
    h.req(key)?.as_i64().ok_or_else(|| anyhow!("{key}: not a number"))
}

fn write_frame(w: &mut impl Write, header: &Json, payload: &[u8]) -> Result<()> {
    let mut line = header.to_string();
    line.push('\n');
    w.write_all(line.as_bytes())?;
    w.write_all(payload)?;
    w.flush()?;
    Ok(())
}

fn read_header(r: &mut impl BufRead) -> Result<Json> {
    let mut line = String::new();
    if r.read_line(&mut line)? == 0 {
        bail!("worker link closed");
    }
    Json::parse(line.trim_end()).map_err(|e| anyhow!("bad frame header: {e}"))
}

fn read_payload(r: &mut impl Read, nbytes: usize) -> Result<Vec<u8>> {
    let mut b = vec![0u8; nbytes];
    r.read_exact(&mut b)?;
    Ok(b)
}

/// Encode `(id, f32 data)` pairs: ids+lens in the header object (under
/// `ids`/`lens`), concatenated data in the returned payload.
fn encode_pairs_f32(pairs: &[(usize, Vec<f32>)]) -> (Json, Json, Vec<u8>) {
    let ids: Vec<usize> = pairs.iter().map(|(i, _)| *i).collect();
    let lens: Vec<usize> = pairs.iter().map(|(_, d)| d.len()).collect();
    let mut payload = Vec::with_capacity(lens.iter().sum::<usize>() * 4);
    for (_, d) in pairs {
        payload.extend(f32s_to_bytes(d));
    }
    (arr_usize(&ids), arr_usize(&lens), payload)
}

fn decode_pairs_f32(h: &Json, payload: &[u8]) -> Result<Vec<(usize, Vec<f32>)>> {
    let ids = get_usizes(h, "ids")?;
    let lens = get_usizes(h, "lens")?;
    if ids.len() != lens.len() {
        bail!("ids/lens length mismatch");
    }
    let mut out = Vec::with_capacity(ids.len());
    let mut off = 0usize;
    for (id, len) in ids.into_iter().zip(lens) {
        let end = off + len * 4;
        if end > payload.len() {
            bail!("frame payload truncated");
        }
        out.push((id, bytes_to_f32s(&payload[off..end])));
        off = end;
    }
    if off != payload.len() {
        bail!("frame payload has trailing bytes");
    }
    Ok(out)
}

fn encode_tensors(tensors: &[StateTensor]) -> (Json, Vec<u8>) {
    let mut metas = Vec::with_capacity(tensors.len());
    let mut payload = Vec::new();
    for t in tensors {
        metas.push(obj(vec![
            ("name", s(&t.name)),
            ("shape", arr_usize(&t.shape)),
            ("dtype", s(dtype_name(&t.dtype))),
            ("nbytes", num(t.bytes.len() as f64)),
        ]));
        payload.extend_from_slice(&t.bytes);
    }
    (Json::Arr(metas), payload)
}

fn decode_tensors(h: &Json, payload: &[u8]) -> Result<Vec<StateTensor>> {
    let metas = h
        .req("tensors")?
        .as_arr()
        .ok_or_else(|| anyhow!("tensors: not an array"))?;
    let mut out = Vec::with_capacity(metas.len());
    let mut off = 0usize;
    for m in metas {
        let name = m.req("name")?.as_str().ok_or_else(|| anyhow!("tensor name"))?;
        let shape = get_usizes(m, "shape")?;
        let dtype = Dtype::parse(m.req("dtype")?.as_str().ok_or_else(|| anyhow!("dtype"))?)?;
        let nbytes = get_usize(m, "nbytes")?;
        let end = off + nbytes;
        if end > payload.len() {
            bail!("tensor payload truncated at {name}");
        }
        out.push(StateTensor {
            name: name.to_string(),
            shape,
            dtype,
            bytes: payload[off..end].to_vec(),
        });
        off = end;
    }
    if off != payload.len() {
        bail!("tensor payload has trailing bytes");
    }
    Ok(out)
}

fn write_cmd(w: &mut impl Write, cmd: &Cmd) -> Result<()> {
    match cmd {
        Cmd::Init { seed } => {
            write_frame(w, &obj(vec![("op", s("init")), ("seed", num(*seed as f64))]), &[])
        }
        Cmd::Step { step, blocks } => {
            let ids: Vec<usize> = blocks.iter().map(|(i, _)| *i).collect();
            let lens: Vec<usize> = blocks.iter().map(|(_, t)| t.len()).collect();
            let mut payload = Vec::with_capacity(lens.iter().sum::<usize>() * 4);
            for (_, t) in blocks {
                payload.extend(i32s_to_bytes(t));
            }
            let h = obj(vec![
                ("op", s("step")),
                ("step", num(*step as f64)),
                ("ids", arr_usize(&ids)),
                ("lens", arr_usize(&lens)),
            ]);
            write_frame(w, &h, &payload)
        }
        Cmd::Apply { step, grads } => {
            let (ids, lens, payload) = encode_pairs_f32(grads);
            let h = obj(vec![
                ("op", s("apply")),
                ("step", num(*step as f64)),
                ("ids", ids),
                ("lens", lens),
            ]);
            write_frame(w, &h, &payload)
        }
        Cmd::SetParams { params } => {
            let (ids, lens, payload) = encode_pairs_f32(params);
            let h = obj(vec![("op", s("set")), ("ids", ids), ("lens", lens)]);
            write_frame(w, &h, &payload)
        }
        Cmd::Eval { bsz, tokens } => {
            let h = obj(vec![
                ("op", s("eval")),
                ("bsz", num(*bsz as f64)),
                ("n", num(tokens.len() as f64)),
            ]);
            write_frame(w, &h, &i32s_to_bytes(tokens))
        }
        Cmd::Forward { tokens } => {
            let h = obj(vec![("op", s("forward")), ("n", num(tokens.len() as f64))]);
            write_frame(w, &h, &i32s_to_bytes(tokens))
        }
        Cmd::Merge { seed } => {
            write_frame(w, &obj(vec![("op", s("merge")), ("seed", num(*seed as f64))]), &[])
        }
        Cmd::DropOptim => write_frame(w, &obj(vec![("op", s("drop_optim"))]), &[]),
        Cmd::Fold => write_frame(w, &obj(vec![("op", s("fold"))]), &[]),
        Cmd::GetState => write_frame(w, &obj(vec![("op", s("get_state"))]), &[]),
        Cmd::LoadState { tensors } => {
            let (metas, payload) = encode_tensors(tensors);
            write_frame(w, &obj(vec![("op", s("load_state")), ("tensors", metas)]), &payload)
        }
        Cmd::MemReport => write_frame(w, &obj(vec![("op", s("mem_report"))]), &[]),
        Cmd::Shutdown => write_frame(w, &obj(vec![("op", s("shutdown"))]), &[]),
    }
}

fn read_cmd(r: &mut (impl BufRead + Read)) -> Result<Cmd> {
    let h = read_header(r)?;
    let op = h.req("op")?.as_str().ok_or_else(|| anyhow!("op: not a string"))?.to_string();
    Ok(match op.as_str() {
        "init" => Cmd::Init { seed: get_i64(&h, "seed")? as u32 },
        "step" => {
            let ids = get_usizes(&h, "ids")?;
            let lens = get_usizes(&h, "lens")?;
            let payload = read_payload(r, lens.iter().sum::<usize>() * 4)?;
            let mut blocks = Vec::with_capacity(ids.len());
            let mut off = 0usize;
            for (id, len) in ids.into_iter().zip(lens) {
                blocks.push((id, bytes_to_i32s(&payload[off..off + len * 4])));
                off += len * 4;
            }
            Cmd::Step { step: get_i64(&h, "step")? as i32, blocks }
        }
        "apply" => {
            let lens = get_usizes(&h, "lens")?;
            let payload = read_payload(r, lens.iter().sum::<usize>() * 4)?;
            Cmd::Apply {
                step: get_i64(&h, "step")? as i32,
                grads: decode_pairs_f32(&h, &payload)?,
            }
        }
        "set" => {
            let lens = get_usizes(&h, "lens")?;
            let payload = read_payload(r, lens.iter().sum::<usize>() * 4)?;
            Cmd::SetParams { params: decode_pairs_f32(&h, &payload)? }
        }
        "eval" => {
            let n = get_usize(&h, "n")?;
            let payload = read_payload(r, n * 4)?;
            Cmd::Eval { bsz: get_usize(&h, "bsz")?, tokens: bytes_to_i32s(&payload) }
        }
        "forward" => {
            let n = get_usize(&h, "n")?;
            let payload = read_payload(r, n * 4)?;
            Cmd::Forward { tokens: bytes_to_i32s(&payload) }
        }
        "merge" => Cmd::Merge { seed: get_i64(&h, "seed")? as i32 },
        "drop_optim" => Cmd::DropOptim,
        "fold" => Cmd::Fold,
        "get_state" => Cmd::GetState,
        "load_state" => {
            let nbytes: usize = h
                .req("tensors")?
                .as_arr()
                .ok_or_else(|| anyhow!("tensors: not an array"))?
                .iter()
                .map(|m| get_usize(m, "nbytes"))
                .sum::<Result<Vec<usize>>>()?
                .iter()
                .sum();
            let payload = read_payload(r, nbytes)?;
            Cmd::LoadState { tensors: decode_tensors(&h, &payload)? }
        }
        "mem_report" => Cmd::MemReport,
        "shutdown" => Cmd::Shutdown,
        other => bail!("unknown command op {other:?}"),
    })
}

fn write_event(w: &mut impl Write, ev: &Event) -> Result<()> {
    match ev {
        Event::Inited { names, numels, frozen } => {
            let h = obj(vec![
                ("op", s("inited")),
                ("names", Json::Arr(names.iter().map(|n| s(n)).collect())),
                ("numels", arr_usize(numels)),
                (
                    "frozen",
                    Json::Arr(frozen.iter().map(|&f| Json::Bool(f)).collect()),
                ),
            ]);
            write_frame(w, &h, &[])
        }
        Event::Grad { block, param, grad } => {
            let h = obj(vec![
                ("op", s("grad")),
                ("block", num(*block as f64)),
                ("param", num(*param as f64)),
                ("n", num(grad.len() as f64)),
            ]);
            write_frame(w, &h, &f32s_to_bytes(grad))
        }
        Event::StepDone { losses } => {
            let ids: Vec<usize> = losses.iter().map(|(b, _)| *b).collect();
            let mut payload = Vec::with_capacity(losses.len() * 8);
            for (_, l) in losses {
                payload.extend(l.to_le_bytes());
            }
            write_frame(w, &obj(vec![("op", s("step_done")), ("ids", arr_usize(&ids))]), &payload)
        }
        Event::Applied { updated } => {
            let (ids, lens, payload) = encode_pairs_f32(updated);
            write_frame(w, &obj(vec![("op", s("applied")), ("ids", ids), ("lens", lens)]), &payload)
        }
        Event::SetDone => write_frame(w, &obj(vec![("op", s("set_done"))]), &[]),
        Event::EvalDone { loss } => {
            write_frame(w, &obj(vec![("op", s("eval_done"))]), &loss.to_le_bytes())
        }
        Event::ForwardDone { logits } => {
            let h = obj(vec![("op", s("forward_done")), ("n", num(logits.len() as f64))]);
            write_frame(w, &h, &f32s_to_bytes(logits))
        }
        Event::Merged => write_frame(w, &obj(vec![("op", s("merged"))]), &[]),
        Event::Dropped => write_frame(w, &obj(vec![("op", s("dropped"))]), &[]),
        Event::Folded => write_frame(w, &obj(vec![("op", s("folded"))]), &[]),
        Event::State { tensors } => {
            let (metas, payload) = encode_tensors(tensors);
            write_frame(w, &obj(vec![("op", s("state")), ("tensors", metas)]), &payload)
        }
        Event::Loaded => write_frame(w, &obj(vec![("op", s("loaded"))]), &[]),
        Event::Mem { report } => {
            let h = obj(vec![
                ("op", s("mem")),
                ("param_bytes", num(report.param_bytes as f64)),
                ("optim_bytes", num(report.optim_bytes as f64)),
                ("proj_bytes", num(report.proj_bytes as f64)),
                ("support_bytes", num(report.support_bytes as f64)),
                ("grad_peak_bytes", num(report.grad_peak_bytes as f64)),
                ("grad_all_bytes", num(report.grad_all_bytes as f64)),
                ("optim_bits", num(report.optim_bits as f64)),
                ("workers", num(report.workers as f64)),
            ]);
            write_frame(w, &h, &[])
        }
        Event::Err { msg } => write_frame(w, &obj(vec![("op", s("err")), ("msg", s(msg))]), &[]),
    }
}

fn read_event(r: &mut (impl BufRead + Read)) -> Result<Event> {
    let h = read_header(r)?;
    let op = h.req("op")?.as_str().ok_or_else(|| anyhow!("op: not a string"))?.to_string();
    Ok(match op.as_str() {
        "inited" => {
            let names = h
                .req("names")?
                .as_arr()
                .ok_or_else(|| anyhow!("names: not an array"))?
                .iter()
                .map(|v| {
                    v.as_str().map(str::to_string).ok_or_else(|| anyhow!("name: not a string"))
                })
                .collect::<Result<Vec<_>>>()?;
            let frozen = h
                .req("frozen")?
                .as_arr()
                .ok_or_else(|| anyhow!("frozen: not an array"))?
                .iter()
                .map(|v| v.as_bool().ok_or_else(|| anyhow!("frozen: not a bool")))
                .collect::<Result<Vec<_>>>()?;
            Event::Inited { names, numels: get_usizes(&h, "numels")?, frozen }
        }
        "grad" => {
            let n = get_usize(&h, "n")?;
            let payload = read_payload(r, n * 4)?;
            Event::Grad {
                block: get_usize(&h, "block")?,
                param: get_usize(&h, "param")?,
                grad: bytes_to_f32s(&payload),
            }
        }
        "step_done" => {
            let ids = get_usizes(&h, "ids")?;
            let payload = read_payload(r, ids.len() * 8)?;
            let losses = ids
                .into_iter()
                .zip(payload.chunks_exact(8))
                .map(|(b, c)| (b, f64::from_le_bytes(c.try_into().unwrap())))
                .collect();
            Event::StepDone { losses }
        }
        "applied" => {
            let lens = get_usizes(&h, "lens")?;
            let payload = read_payload(r, lens.iter().sum::<usize>() * 4)?;
            Event::Applied { updated: decode_pairs_f32(&h, &payload)? }
        }
        "set_done" => Event::SetDone,
        "eval_done" => {
            let payload = read_payload(r, 8)?;
            Event::EvalDone { loss: f64::from_le_bytes(payload.as_slice().try_into().unwrap()) }
        }
        "forward_done" => {
            let n = get_usize(&h, "n")?;
            let payload = read_payload(r, n * 4)?;
            Event::ForwardDone { logits: bytes_to_f32s(&payload) }
        }
        "merged" => Event::Merged,
        "dropped" => Event::Dropped,
        "folded" => Event::Folded,
        "state" => {
            let nbytes: usize = h
                .req("tensors")?
                .as_arr()
                .ok_or_else(|| anyhow!("tensors: not an array"))?
                .iter()
                .map(|m| get_usize(m, "nbytes"))
                .sum::<Result<Vec<usize>>>()?
                .iter()
                .sum();
            let payload = read_payload(r, nbytes)?;
            Event::State { tensors: decode_tensors(&h, &payload)? }
        }
        "loaded" => Event::Loaded,
        "mem" => Event::Mem {
            report: MemReport {
                param_bytes: get_i64(&h, "param_bytes")? as u64,
                optim_bytes: get_i64(&h, "optim_bytes")? as u64,
                proj_bytes: get_i64(&h, "proj_bytes")? as u64,
                support_bytes: get_i64(&h, "support_bytes")? as u64,
                grad_peak_bytes: get_i64(&h, "grad_peak_bytes")? as u64,
                grad_all_bytes: get_i64(&h, "grad_all_bytes")? as u64,
                optim_bits: get_i64(&h, "optim_bits")? as u32,
                workers: get_i64(&h, "workers")? as u32,
            },
        },
        "err" => Event::Err {
            msg: h.req("msg")?.as_str().ok_or_else(|| anyhow!("msg: not a string"))?.to_string(),
        },
        other => bail!("unknown event op {other:?}"),
    })
}

// ------------------------------------------------ socket transport

/// Parent-side socket link: writes command frames to the child.
pub(crate) struct SocketLink {
    w: BufWriter<UnixStream>,
}

impl SocketLink {
    /// Wrap the parent's half of an accepted worker connection.
    pub fn new(stream: UnixStream) -> SocketLink {
        SocketLink { w: BufWriter::new(stream) }
    }
}

impl ReplicaLink for SocketLink {
    fn send(&mut self, cmd: Cmd) -> Result<()> {
        write_cmd(&mut self.w, &cmd)
    }
}

/// Worker-side socket endpoint: reads command frames, writes events.
pub(crate) struct SocketWorkerChannel {
    r: BufReader<UnixStream>,
    w: BufWriter<UnixStream>,
}

impl SocketWorkerChannel {
    /// Connect to the parent's listener and identify this worker with a
    /// hello frame.
    pub fn connect(path: &std::path::Path, worker: usize) -> Result<SocketWorkerChannel> {
        let stream = UnixStream::connect(path)?;
        let r = BufReader::new(stream.try_clone()?);
        let mut w = BufWriter::new(stream);
        write_frame(&mut w, &obj(vec![("op", s("hello")), ("worker", num(worker as f64))]), &[])?;
        Ok(SocketWorkerChannel { r, w })
    }
}

impl WorkerChannel for SocketWorkerChannel {
    fn recv(&mut self) -> Result<Cmd> {
        read_cmd(&mut self.r)
    }

    fn send(&mut self, ev: Event) -> Result<()> {
        write_event(&mut self.w, &ev)
    }
}

/// Read the hello frame off a freshly-accepted worker connection and
/// return the worker index it claims.
pub(crate) fn read_hello(r: &mut BufReader<UnixStream>) -> Result<usize> {
    let h = read_header(r)?;
    if h.req("op")?.as_str() != Some("hello") {
        bail!("expected hello frame from worker");
    }
    get_usize(&h, "worker")
}

/// Pump events from one worker's socket into the parent's shared
/// receiver until the socket closes (normal at shutdown).
pub(crate) fn spawn_socket_reader(
    mut r: BufReader<UnixStream>,
    worker: usize,
    tx: Sender<(usize, Event)>,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("shard-rx-{worker}"))
        .spawn(move || {
            loop {
                match read_event(&mut r) {
                    Ok(ev) => {
                        if tx.send((worker, ev)).is_err() {
                            return;
                        }
                    }
                    // closed socket: the worker exited (shutdown or
                    // crash); the parent notices on its next wait
                    Err(_) => return,
                }
            }
        })
        .expect("spawn socket reader")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip_cmd(cmd: &Cmd) -> Cmd {
        let mut buf = Vec::new();
        write_cmd(&mut buf, cmd).unwrap();
        read_cmd(&mut std::io::BufReader::new(buf.as_slice())).unwrap()
    }

    fn roundtrip_event(ev: &Event) -> Event {
        let mut buf = Vec::new();
        write_event(&mut buf, ev).unwrap();
        read_event(&mut std::io::BufReader::new(buf.as_slice())).unwrap()
    }

    #[test]
    fn cmd_frames_roundtrip_bit_exactly() {
        let got = roundtrip_cmd(&Cmd::Step {
            step: -3,
            blocks: vec![(0, vec![1, 2, 3]), (2, vec![4, 5, 6])],
        });
        match got {
            Cmd::Step { step, blocks } => {
                assert_eq!(step, -3);
                assert_eq!(blocks, vec![(0, vec![1, 2, 3]), (2, vec![4, 5, 6])]);
            }
            other => panic!("wrong cmd {other:?}"),
        }
        // f32 payloads must survive bit-exactly, including non-finite
        // and denormal values no decimal text round-trips reliably
        let tricky = vec![f32::MIN_POSITIVE / 2.0, -0.0, 1.0e-42, 3.5];
        let got = roundtrip_cmd(&Cmd::Apply { step: 7, grads: vec![(5, tricky.clone())] });
        match got {
            Cmd::Apply { step, grads } => {
                assert_eq!(step, 7);
                assert_eq!(grads.len(), 1);
                assert_eq!(grads[0].0, 5);
                let bits: Vec<u32> = grads[0].1.iter().map(|x| x.to_bits()).collect();
                let want: Vec<u32> = tricky.iter().map(|x| x.to_bits()).collect();
                assert_eq!(bits, want);
            }
            other => panic!("wrong cmd {other:?}"),
        }
    }

    #[test]
    fn event_frames_roundtrip_bit_exactly() {
        let loss = 2.302585092994046_f64;
        match roundtrip_event(&Event::StepDone { losses: vec![(1, loss)] }) {
            Event::StepDone { losses } => {
                assert_eq!(losses[0].0, 1);
                assert_eq!(losses[0].1.to_bits(), loss.to_bits());
            }
            other => panic!("wrong event {other:?}"),
        }
        match roundtrip_event(&Event::Err { msg: "boom\nwith newline".into() }) {
            Event::Err { msg } => assert_eq!(msg, "boom\nwith newline"),
            other => panic!("wrong event {other:?}"),
        }
    }

    #[test]
    fn state_tensor_frames_roundtrip() {
        let tensors = vec![
            StateTensor::f32("a.w", vec![2, 2], &[1.0, 2.0, 3.0, 4.0]),
            StateTensor::i32("s.idx", vec![3], &[0, 5, 9]),
            StateTensor::i8("optim.m.q8.a.w", vec![4], &[-1, 0, 1, 127]),
        ];
        match roundtrip_cmd(&Cmd::LoadState { tensors: tensors.clone() }) {
            Cmd::LoadState { tensors: got } => {
                assert_eq!(got.len(), tensors.len());
                for (g, w) in got.iter().zip(&tensors) {
                    assert_eq!(g.name, w.name);
                    assert_eq!(g.shape, w.shape);
                    assert_eq!(g.bytes, w.bytes);
                }
            }
            other => panic!("wrong cmd {other:?}"),
        }
    }
}

//! Model / training configuration presets, mirroring `python/compile/configs.py`.
//!
//! The rust side needs the architectural shapes independently of the
//! artifacts for two reasons: the Appendix-F memory estimator (which also
//! covers the analytic-only `spec7b` and the paper's true 60M..1B dims),
//! and sanity-checking manifests against expectations.

use crate::util::json::Json;

/// The paper's Tables 2–4 comparison set, every one trainable on the
/// native backend (see docs/METHODS.md for the equation ↔ code map):
/// `full` (vanilla Adam), `lowrank` (W = scale·BA), `sltrain`
/// (W = scale·BA ⊕ S, eq. 2), `relora` (W0 + scale·BA with periodic
/// merges, eq. 1) and `galore` (full-rank W, rank-r gradient
/// projection in the optimizer).
pub const METHODS: [&str; 5] = ["full", "lowrank", "sltrain", "relora", "galore"];

#[derive(Debug, Clone, PartialEq)]
pub struct ModelPreset {
    pub name: String,
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    pub seq_len: usize,
    pub rank: usize,
    pub delta: f64,
    pub alpha: f64,
    pub d_ff: usize,
}

/// LLaMA SwiGLU hidden size: 2/3 * 4d rounded up to a multiple of 64.
fn ff(d: usize) -> usize {
    ((8 * d / 3) + 63) / 64 * 64
}

impl ModelPreset {
    fn new(
        name: &str, vocab: usize, d: usize, layers: usize, heads: usize,
        seq: usize, rank: usize, delta: f64, alpha: f64, d_ff: usize,
    ) -> Self {
        ModelPreset {
            name: name.into(),
            vocab,
            d_model: d,
            n_layers: layers,
            n_heads: heads,
            seq_len: seq,
            rank,
            delta,
            alpha,
            d_ff: if d_ff == 0 { ff(d) } else { d_ff },
        }
    }

    /// All adapted linears as (path, d_in, d_out) — must match
    /// `model._linear_paths` in python exactly.
    pub fn linear_paths(&self) -> Vec<(String, usize, usize)> {
        let mut out = vec![];
        for i in 0..self.n_layers {
            for nm in ["q", "k", "v", "o"] {
                out.push((format!("layers.{i}.attn.{nm}"), self.d_model, self.d_model));
            }
            out.push((format!("layers.{i}.mlp.gate"), self.d_model, self.d_ff));
            out.push((format!("layers.{i}.mlp.up"), self.d_model, self.d_ff));
            out.push((format!("layers.{i}.mlp.down"), self.d_ff, self.d_model));
        }
        out
    }

    /// Parameters outside the adapted linears (embed, head, norms) —
    /// always trained full-rank (paper §5.1).
    pub fn base_params(&self) -> usize {
        let embed = self.vocab * self.d_model;
        let head = self.d_model * self.vocab;
        let norms = (2 * self.n_layers + 1) * self.d_model;
        embed + head + norms
    }

    pub fn nnz(&self, d_in: usize, d_out: usize) -> usize {
        ((self.delta * d_in as f64 * d_out as f64).round() as usize).max(1)
    }

    /// Parameter count per method (paper Table 2 "Param"). Counts every
    /// stored parameter, matching the table's convention: for `relora`
    /// that includes the frozen `W0` (only the adaptors receive
    /// gradients), and `galore` equals `full` (its rank-r saving is in
    /// optimizer state, not parameters — see `mem::estimate`).
    pub fn param_count(&self, method: &str) -> usize {
        let base = self.base_params();
        let linears = self.linear_paths();
        let adapted: usize = linears
            .iter()
            .map(|(_, din, dout)| match method {
                "full" | "galore" => din * dout,
                "lowrank" => (din + dout) * self.rank,
                "relora" => din * dout + (din + dout) * self.rank,
                "sltrain" => (din + dout) * self.rank + self.nnz(*din, *dout),
                _ => panic!("unknown method {method}"),
            })
            .sum();
        base + adapted
    }

    pub fn from_manifest(man: &Json) -> anyhow::Result<Self> {
        let c = man.req("config")?;
        let get = |k: &str| -> anyhow::Result<f64> {
            c.req(k)?.as_f64().ok_or_else(|| anyhow::anyhow!("bad {k}"))
        };
        Ok(ModelPreset {
            name: c.req("name")?.as_str().unwrap_or("?").to_string(),
            vocab: get("vocab")? as usize,
            d_model: get("d_model")? as usize,
            n_layers: get("n_layers")? as usize,
            n_heads: get("n_heads")? as usize,
            seq_len: get("seq_len")? as usize,
            rank: get("rank")? as usize,
            delta: get("delta")?,
            alpha: get("alpha")?,
            d_ff: get("d_ff")? as usize,
        })
    }
}

/// The scaled presets (trained on this testbed) plus the paper's exact
/// dimensions (analytic memory rows). Keep in sync with configs.py.
pub fn preset(name: &str) -> Option<ModelPreset> {
    let p = match name {
        "tiny" => ModelPreset::new("tiny", 256, 64, 2, 2, 64, 16, 0.03, 32.0, 0),
        "tiny2" => ModelPreset::new("tiny2", 512, 96, 3, 4, 64, 24, 0.03, 32.0, 0),
        "s60m" => ModelPreset::new("s60m", 4096, 192, 4, 4, 128, 48, 0.03, 32.0, 0),
        "s130m" => ModelPreset::new("s130m", 4096, 256, 6, 8, 128, 64, 0.03, 16.0, 0),
        "s350m" => ModelPreset::new("s350m", 8192, 384, 8, 8, 192, 96, 0.03, 16.0, 0),
        "s1b" => ModelPreset::new("s1b", 8192, 512, 10, 8, 256, 128, 0.03, 8.0, 0),
        "e2e100m" => ModelPreset::new("e2e100m", 24576, 640, 14, 10, 256, 160, 0.03, 16.0, 0),
        "spec7b" => {
            ModelPreset::new("spec7b", 32000, 4096, 32, 32, 2048, 1024, 0.05, 8.0, 11008)
        }
        // the paper's ACTUAL training dims (for Appendix-F estimator rows)
        "paper60m" => ModelPreset::new("paper60m", 32000, 512, 8, 8, 1024, 128, 0.03, 32.0, 1376),
        "paper130m" => ModelPreset::new("paper130m", 32000, 768, 12, 12, 1024, 256, 0.03, 16.0, 2048),
        "paper350m" => ModelPreset::new("paper350m", 32000, 1024, 24, 16, 1024, 256, 0.03, 16.0, 2736),
        "paper1b" => ModelPreset::new("paper1b", 32000, 2048, 24, 32, 1024, 512, 0.03, 8.0, 5461),
        _ => return None,
    };
    Some(p)
}

pub fn all_scaled() -> Vec<&'static str> {
    vec!["tiny", "tiny2", "s60m", "s130m", "s350m", "s1b"]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_resolve() {
        for n in ["tiny", "s60m", "s130m", "s350m", "s1b", "e2e100m", "spec7b"] {
            assert!(preset(n).is_some(), "{n}");
        }
        assert!(preset("nope").is_none());
    }

    #[test]
    fn param_ordering_matches_paper() {
        // Table 2 ordering: lowrank < sltrain < full < relora; galore == full
        for n in all_scaled() {
            let p = preset(n).unwrap();
            let c = |m: &str| p.param_count(m);
            assert!(c("lowrank") < c("sltrain"), "{n}");
            assert!(c("sltrain") < c("full"), "{n}");
            assert!(c("full") < c("relora"), "{n}");
            assert_eq!(c("full"), c("galore"), "{n}");
        }
    }

    #[test]
    fn sltrain_overhead_is_exactly_nnz() {
        let p = preset("s60m").unwrap();
        let extra = p.param_count("sltrain") - p.param_count("lowrank");
        let expect: usize =
            p.linear_paths().iter().map(|(_, i, o)| p.nnz(*i, *o)).sum();
        assert_eq!(extra, expect);
    }

    #[test]
    fn paper_dims_param_counts_are_plausible() {
        // the paper reports 58.2M (60M), 134.11M, 367.97M, 1339.08M full-rank
        let cases = [
            ("paper60m", 58.2e6, 0.10),
            ("paper130m", 134.11e6, 0.10),
            ("paper350m", 367.97e6, 0.10),
            ("paper1b", 1339.08e6, 0.10),
        ];
        for (name, expect, tol) in cases {
            let p = preset(name).unwrap();
            let got = p.param_count("full") as f64;
            let rel = (got - expect).abs() / expect;
            assert!(rel < tol, "{name}: got {got:.3e}, paper {expect:.3e}, rel {rel:.3}");
        }
    }

    #[test]
    fn e2e_preset_is_about_100m() {
        let p = preset("e2e100m").unwrap();
        let n = p.param_count("full") as f64;
        assert!((80e6..130e6).contains(&n), "{n}");
    }

    #[test]
    fn ff_multiple_of_64() {
        for d in [64, 192, 640, 1000] {
            assert_eq!(ff(d) % 64, 0);
            assert!(ff(d) >= 8 * d / 3);
        }
    }
}

//! Dense linear algebra substrate (no external BLAS/LAPACK).
//!
//! Powers the paper's analysis experiments: SVD spectra of trained
//! weights (Fig 2, 10, 11), residual-after-rank-r statistics, Prop-1
//! rank verification, and GaLore cross-checks. One-sided Jacobi SVD is
//! exact enough (1e-5) for every matrix size we analyze and has no
//! dependencies.

pub mod sparse;
pub mod svd;

pub use sparse::SparseSupport;
pub use svd::{svd, Svd};

/// Row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn random(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.gaussian() as f32).collect();
        Matrix { rows, cols, data }
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Blocked matmul with a transposed-B inner loop (cache-friendly).
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        self.matmul_transb(&other.transpose())
    }

    /// `self @ bt^T` with `bt` already transposed ([n, k] for a [m, k]
    /// self). Callers that multiply by the same matrix repeatedly (or
    /// that naturally hold B^T, like every `dy @ W^T` in backprop) hoist
    /// the transpose out of the hot loop instead of paying a fresh
    /// re-layout on every `matmul` call.
    pub fn matmul_transb(&self, bt: &Matrix) -> Matrix {
        assert_eq!(self.cols, bt.cols, "matmul_transb inner-dim mismatch");
        let (m, k, n) = (self.rows, self.cols, bt.rows);
        let mut out = Matrix::zeros(m, n);
        for i in 0..m {
            let a_row = &self.data[i * k..(i + 1) * k];
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for (j, o) in out_row.iter_mut().enumerate() {
                let b_row = &bt.data[j * k..(j + 1) * k];
                let mut acc = 0.0f32;
                for l in 0..k {
                    acc += a_row[l] * b_row[l];
                }
                *o = acc;
            }
        }
        out
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Scatter-add values at flat row-major indices (the ⊕ of Algorithm 1).
    pub fn scatter_add(&mut self, idx: &[u32], vals: &[f32]) {
        assert_eq!(idx.len(), vals.len());
        for (&i, &v) in idx.iter().zip(vals) {
            self.data[i as usize] += v;
        }
    }

    /// Numerical rank: #singular values > tol * s_max.
    pub fn rank(&self, tol: f32) -> usize {
        let sv = svd(self).s;
        let smax = sv.first().copied().unwrap_or(0.0);
        if smax == 0.0 {
            return 0;
        }
        sv.iter().filter(|&&s| s > tol * smax).count()
    }

    /// Best rank-r approximation via SVD (Table 1 / Fig 2 tooling).
    pub fn truncate_rank(&self, r: usize) -> Matrix {
        let Svd { u, s, vt } = svd(self);
        let r = r.min(s.len());
        // U_r diag(s_r) Vt_r
        let mut us = Matrix::zeros(self.rows, r);
        for i in 0..self.rows {
            for j in 0..r {
                us[(i, j)] = u[(i, j)] * s[j];
            }
        }
        // copy V_r out transposed once and skip matmul's internal re-layout
        let vr = Matrix::from_fn(self.cols, r, |i, j| vt[(j, i)]);
        us.matmul_transb(&vr)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::random(5, 7, &mut rng);
        let i7 = Matrix::eye(7);
        let out = a.matmul(&i7);
        assert!(a.sub(&out).max_abs() < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_transb_matches_matmul() {
        let mut rng = Rng::new(9);
        let a = Matrix::random(6, 5, &mut rng);
        let b = Matrix::random(5, 8, &mut rng);
        let via_plain = a.matmul(&b);
        let via_transb = a.matmul_transb(&b.transpose());
        assert!(via_plain.sub(&via_transb).max_abs() < 1e-6);
        assert_eq!(via_transb.rows, 6);
        assert_eq!(via_transb.cols, 8);
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(4, 9, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn scatter_add_matches_dense() {
        let mut m = Matrix::zeros(3, 4);
        m.scatter_add(&[0, 5, 11], &[1.0, 2.0, 3.0]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 1)], 2.0);
        assert_eq!(m[(2, 3)], 3.0);
        assert_eq!(m.data.iter().filter(|&&x| x != 0.0).count(), 3);
    }

    #[test]
    fn rank_of_outer_product() {
        let mut rng = Rng::new(2);
        let b = Matrix::random(12, 3, &mut rng);
        let a = Matrix::random(3, 10, &mut rng);
        let low = b.matmul(&a);
        assert_eq!(low.rank(1e-4), 3);
    }

    #[test]
    fn truncate_rank_is_best_approx() {
        let mut rng = Rng::new(3);
        let b = Matrix::random(10, 2, &mut rng);
        let a = Matrix::random(2, 8, &mut rng);
        let low = b.matmul(&a);
        // rank-2 truncation of a rank-2 matrix reproduces it
        let t = low.truncate_rank(2);
        assert!(low.sub(&t).max_abs() < 1e-3, "err {}", low.sub(&t).max_abs());
    }
}

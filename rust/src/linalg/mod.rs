//! Dense linear algebra substrate (no external BLAS/LAPACK).
//!
//! Powers the paper's analysis experiments: SVD spectra of trained
//! weights (Fig 2, 10, 11), residual-after-rank-r statistics, Prop-1
//! rank verification, and GaLore cross-checks. One-sided Jacobi SVD is
//! exact enough (1e-5) for every matrix size we analyze and has no
//! dependencies.

pub mod parallel;
pub mod simd;
pub mod sparse;
pub(crate) mod sparse_simd;
pub mod svd;

pub use parallel::ThreadPool;
pub use sparse::{SparseSupport, SupportPattern};
pub use svd::{svd, Svd};

use simd::{MR, NR};

/// Row-major f32 matrix.
#[derive(Debug, Clone, PartialEq)]
pub struct Matrix {
    pub rows: usize,
    pub cols: usize,
    pub data: Vec<f32>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn eye(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Self {
        assert_eq!(data.len(), rows * cols, "shape mismatch");
        Matrix { rows, cols, data }
    }

    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut m = Matrix::zeros(rows, cols);
        for i in 0..rows {
            for j in 0..cols {
                m[(i, j)] = f(i, j);
            }
        }
        m
    }

    pub fn random(rows: usize, cols: usize, rng: &mut crate::util::rng::Rng) -> Self {
        let data = (0..rows * cols).map(|_| rng.gaussian() as f32).collect();
        Matrix { rows, cols, data }
    }

    pub fn transpose(&self) -> Matrix {
        let mut t = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                t[(j, i)] = self[(i, j)];
            }
        }
        t
    }

    /// Register-blocked matmul over packed column panels of B
    /// (cache-friendly, autovectorizable microkernel). Per output
    /// element the f32 accumulation order is the plain `l = 0..k` dot
    /// product, so results are bit-identical to a naive triple loop.
    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        gemm(self, &pack_b(other), None)
    }

    /// `self @ bt^T` with `bt` already transposed ([n, k] for a [m, k]
    /// self). Callers that multiply by the same matrix repeatedly (or
    /// that naturally hold B^T, like every `dy @ W^T` in backprop) hoist
    /// the transpose out of the hot loop instead of paying a fresh
    /// re-layout on every `matmul` call.
    pub fn matmul_transb(&self, bt: &Matrix) -> Matrix {
        assert_eq!(self.cols, bt.cols, "matmul_transb inner-dim mismatch");
        gemm(self, &pack_bt(bt), None)
    }

    /// `matmul`, row-panel parallel over the pool. Bit-identical to the
    /// serial version for every thread count: output rows are written by
    /// exactly one task and no reduction crosses a task boundary.
    pub fn matmul_par(&self, other: &Matrix, pool: &ThreadPool) -> Matrix {
        assert_eq!(self.cols, other.rows, "matmul shape mismatch");
        gemm(self, &pack_b(other), Some(pool))
    }

    /// `matmul_transb`, row-panel parallel over the pool (bit-identical
    /// to the serial version for every thread count).
    pub fn matmul_transb_par(&self, bt: &Matrix, pool: &ThreadPool) -> Matrix {
        assert_eq!(self.cols, bt.cols, "matmul_transb inner-dim mismatch");
        gemm(self, &pack_bt(bt), Some(pool))
    }

    pub fn add(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a + b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn sub(&self, other: &Matrix) -> Matrix {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        let data = self.data.iter().zip(&other.data).map(|(a, b)| a - b).collect();
        Matrix { rows: self.rows, cols: self.cols, data }
    }

    pub fn scale(&self, s: f32) -> Matrix {
        Matrix {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|x| x * s).collect(),
        }
    }

    /// `scale` without the copy, for hot-loop callers that own the
    /// matrix (same elementwise multiply, so bit-identical results).
    pub fn scale_mut(&mut self, s: f32) {
        for x in &mut self.data {
            *x *= s;
        }
    }

    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|x| x * x).sum::<f32>().sqrt()
    }

    pub fn max_abs(&self) -> f32 {
        self.data.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// Scatter-add values at flat row-major indices (the ⊕ of Algorithm 1).
    pub fn scatter_add(&mut self, idx: &[u32], vals: &[f32]) {
        assert_eq!(idx.len(), vals.len());
        for (&i, &v) in idx.iter().zip(vals) {
            self.data[i as usize] += v;
        }
    }

    /// Numerical rank: #singular values > tol * s_max.
    pub fn rank(&self, tol: f32) -> usize {
        let sv = svd(self).s;
        let smax = sv.first().copied().unwrap_or(0.0);
        if smax == 0.0 {
            return 0;
        }
        sv.iter().filter(|&&s| s > tol * smax).count()
    }

    /// Best rank-r approximation via SVD (Table 1 / Fig 2 tooling).
    pub fn truncate_rank(&self, r: usize) -> Matrix {
        let Svd { u, s, vt } = svd(self);
        let r = r.min(s.len());
        // U_r diag(s_r) Vt_r
        let mut us = Matrix::zeros(self.rows, r);
        for i in 0..self.rows {
            for j in 0..r {
                us[(i, j)] = u[(i, j)] * s[j];
            }
        }
        // copy V_r out transposed once and skip matmul's internal re-layout
        let vr = Matrix::from_fn(self.cols, r, |i, j| vt[(j, i)]);
        us.matmul_transb(&vr)
    }
}

// ----------------------------------------------------- blocked GEMM core
//
// GEBP-style kernel: B is packed once into zero-padded column panels of
// width NR; the microkernel keeps an MR x NR accumulator tile in
// registers and streams the panel. Full tiles dispatch to the runtime-
// selected SIMD microkernel in `simd` (AVX2 / NEON / scalar); ragged
// bottom rows take a scalar edge loop. Crucially every path sums
// `a[i, l] * b[l, j]` for `l = 0..k` sequentially with unfused mul+add
// — the exact IEEE rounding sequence of the naive dot product — so
// blocking, padding, vectorization and row-panel threading change
// performance, not a single output bit (see `simd` module docs).

/// B packed into `ceil(n / NR)` zero-padded column panels; panel `p`
/// stores `B[l, p*NR + jj]` at `data[p*k*NR + l*NR + jj]`.
struct PackedB {
    data: Vec<f32>,
    k: usize,
    n: usize,
}

/// Pack a row-major [k, n] matrix (panel rows are contiguous reads).
#[allow(clippy::needless_range_loop)]
fn pack_b(b: &Matrix) -> PackedB {
    let (k, n) = (b.rows, b.cols);
    let panels = n.div_ceil(NR).max(1);
    let mut data = vec![0.0f32; panels * k * NR];
    for p in 0..panels {
        let j0 = p * NR;
        let w = NR.min(n - j0.min(n));
        if w == 0 {
            continue;
        }
        let dst = &mut data[p * k * NR..(p + 1) * k * NR];
        for l in 0..k {
            dst[l * NR..l * NR + w].copy_from_slice(&b.data[l * n + j0..l * n + j0 + w]);
        }
    }
    PackedB { data, k, n }
}

/// Pack an already-transposed [n, k] matrix (per-panel transpose).
#[allow(clippy::needless_range_loop)]
fn pack_bt(bt: &Matrix) -> PackedB {
    let (n, k) = (bt.rows, bt.cols);
    let panels = n.div_ceil(NR).max(1);
    let mut data = vec![0.0f32; panels * k * NR];
    for p in 0..panels {
        let j0 = p * NR;
        let w = NR.min(n - j0.min(n));
        let dst = &mut data[p * k * NR..(p + 1) * k * NR];
        for jj in 0..w {
            let src = &bt.data[(j0 + jj) * k..(j0 + jj + 1) * k];
            for l in 0..k {
                dst[l * NR + jj] = src[l];
            }
        }
    }
    PackedB { data, k, n }
}

/// Compute output rows [r0, r1) of `a @ B` into `out` (row r0 at offset
/// 0, row-major, width `pb.n`) on the process-wide microkernel path.
fn gemm_rows(a: &[f32], k: usize, pb: &PackedB, r0: usize, r1: usize, out: &mut [f32]) {
    gemm_rows_on(simd::active_path(), a, k, pb, r0, r1, out)
}

/// `gemm_rows` pinned to an explicit microkernel path (the SIMD-vs-scalar
/// bitwise tests drive both paths through here).
#[allow(clippy::needless_range_loop)]
fn gemm_rows_on(
    path: simd::Path,
    a: &[f32],
    k: usize,
    pb: &PackedB,
    r0: usize,
    r1: usize,
    out: &mut [f32],
) {
    let n = pb.n;
    debug_assert_eq!(out.len(), (r1 - r0) * n);
    debug_assert_eq!(pb.k, k);
    let panels = n.div_ceil(NR).max(1);
    let mut i0 = r0;
    while i0 < r1 {
        let mr = MR.min(r1 - i0);
        for p in 0..panels {
            let j0 = p * NR;
            let w = NR.min(n - j0);
            let panel = &pb.data[p * k * NR..(p + 1) * k * NR];
            let mut acc = [[0.0f32; NR]; MR];
            if mr == MR {
                simd::tile(path, a, i0, k, panel, &mut acc);
            } else {
                // ragged bottom rows: scalar edge loop, same `l` order
                // on every path (so chunk boundaries never change bits)
                for l in 0..k {
                    let bl: &[f32; NR] = panel[l * NR..l * NR + NR].try_into().unwrap();
                    for ii in 0..mr {
                        let av = a[(i0 + ii) * k + l];
                        for jj in 0..NR {
                            acc[ii][jj] += av * bl[jj];
                        }
                    }
                }
            }
            for ii in 0..mr {
                let row_off = (i0 - r0 + ii) * n + j0;
                out[row_off..row_off + w].copy_from_slice(&acc[ii][..w]);
            }
        }
        i0 += mr;
    }
}

/// `a @ B` over a packed B; row panels go across the pool when given.
fn gemm(a: &Matrix, pb: &PackedB, pool: Option<&ThreadPool>) -> Matrix {
    let (m, n) = (a.rows, pb.n);
    let mut out = Matrix::zeros(m, n);
    match pool {
        Some(pool) if pool.threads() > 1 && m > MR => {
            // at most `threads` chunks, aligned to microkernel tiles
            let chunk_rows = m.div_ceil(pool.threads()).div_ceil(MR) * MR;
            parallel::par_chunks_mut(pool, &mut out.data, chunk_rows * n, |ci, chunk| {
                let r0 = ci * chunk_rows;
                let r1 = (r0 + chunk_rows).min(m);
                gemm_rows(&a.data, a.cols, pb, r0, r1, chunk);
            });
        }
        _ => gemm_rows(&a.data, a.cols, pb, 0, m, &mut out.data),
    }
    out
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f32;
    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f32 {
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f32 {
        &mut self.data[i * self.cols + j]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn matmul_identity() {
        let mut rng = Rng::new(0);
        let a = Matrix::random(5, 7, &mut rng);
        let i7 = Matrix::eye(7);
        let out = a.matmul(&i7);
        assert!(a.sub(&out).max_abs() < 1e-6);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_vec(2, 2, vec![1.0, 2.0, 3.0, 4.0]);
        let b = Matrix::from_vec(2, 2, vec![1.0, 1.0, 1.0, 1.0]);
        let c = a.matmul(&b);
        assert_eq!(c.data, vec![3.0, 3.0, 7.0, 7.0]);
    }

    #[test]
    fn matmul_transb_matches_matmul() {
        let mut rng = Rng::new(9);
        let a = Matrix::random(6, 5, &mut rng);
        let b = Matrix::random(5, 8, &mut rng);
        let via_plain = a.matmul(&b);
        let via_transb = a.matmul_transb(&b.transpose());
        assert!(via_plain.sub(&via_transb).max_abs() < 1e-6);
        assert_eq!(via_transb.rows, 6);
        assert_eq!(via_transb.cols, 8);
    }

    /// Naive triple-loop reference (the pre-blocking kernel).
    fn matmul_naive(a: &Matrix, b: &Matrix) -> Matrix {
        assert_eq!(a.cols, b.rows);
        let mut out = Matrix::zeros(a.rows, b.cols);
        for i in 0..a.rows {
            for j in 0..b.cols {
                let mut acc = 0.0f32;
                for l in 0..a.cols {
                    acc += a[(i, l)] * b[(l, j)];
                }
                out[(i, j)] = acc;
            }
        }
        out
    }

    #[test]
    fn blocked_matmul_bitwise_matches_naive_on_ragged_shapes() {
        let mut rng = Rng::new(17);
        // shapes straddling the MR=8 / NR=8 tile edges, incl. k % NR != 0
        // and m % MR != 0
        for (m, k, n) in [
            (1, 1, 1),
            (3, 5, 7),
            (4, 8, 8),
            (5, 9, 17),
            (7, 3, 9),
            (8, 8, 8),
            (9, 17, 5),
            (13, 31, 6),
            (8, 2, 24),
            (16, 9, 24),
            (23, 31, 15),
        ] {
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let want = matmul_naive(&a, &b);
            let got = a.matmul(&b);
            assert_eq!(want.data, got.data, "matmul {m}x{k}x{n} not bit-identical");
            let got_t = a.matmul_transb(&b.transpose());
            assert_eq!(want.data, got_t.data, "matmul_transb {m}x{k}x{n} not bit-identical");
        }
    }

    #[test]
    fn parallel_matmul_bitwise_matches_serial() {
        let mut rng = Rng::new(23);
        let pool = ThreadPool::new(3);
        for (m, k, n) in [(11, 7, 5), (32, 16, 24), (2, 3, 2)] {
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let serial = a.matmul(&b);
            assert_eq!(serial.data, a.matmul_par(&b, &pool).data, "{m}x{k}x{n}");
            assert_eq!(
                serial.data,
                a.matmul_transb_par(&b.transpose(), &pool).data,
                "transb {m}x{k}x{n}"
            );
        }
    }

    #[test]
    fn simd_and_scalar_gemm_paths_bitwise_identical() {
        // the active microkernel path (AVX2/NEON where detected) must
        // reproduce the scalar path bit for bit, including ragged
        // shapes, tiny matrices, and empty dimensions
        let mut rng = Rng::new(41);
        let active = simd::active_path();
        let mut shapes = vec![
            (0usize, 3usize, 4usize),
            (3, 0, 4),
            (4, 3, 0),
            (1, 1, 1),
            (8, 8, 8),
            (9, 13, 17),
            (16, 5, 9),
            (23, 31, 15),
            (64, 33, 40),
        ];
        // plus random ragged shapes around the tile edges
        for _ in 0..20 {
            shapes.push((
                1 + rng.below(40) as usize,
                1 + rng.below(37) as usize,
                1 + rng.below(29) as usize,
            ));
        }
        for (m, k, n) in shapes {
            let a = Matrix::random(m, k, &mut rng);
            let b = Matrix::random(k, n, &mut rng);
            let pb = pack_b(&b);
            let mut got = vec![0.0f32; m * n];
            gemm_rows_on(active, &a.data, k, &pb, 0, m, &mut got);
            let mut want = vec![0.0f32; m * n];
            gemm_rows_on(simd::Path::Scalar, &a.data, k, &pb, 0, m, &mut want);
            assert_eq!(got, want, "path {active:?} diverges from scalar at {m}x{k}x{n}");
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(4, 9, &mut rng);
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn scatter_add_matches_dense() {
        let mut m = Matrix::zeros(3, 4);
        m.scatter_add(&[0, 5, 11], &[1.0, 2.0, 3.0]);
        assert_eq!(m[(0, 0)], 1.0);
        assert_eq!(m[(1, 1)], 2.0);
        assert_eq!(m[(2, 3)], 3.0);
        assert_eq!(m.data.iter().filter(|&&x| x != 0.0).count(), 3);
    }

    #[test]
    fn rank_of_outer_product() {
        let mut rng = Rng::new(2);
        let b = Matrix::random(12, 3, &mut rng);
        let a = Matrix::random(3, 10, &mut rng);
        let low = b.matmul(&a);
        assert_eq!(low.rank(1e-4), 3);
    }

    #[test]
    fn truncate_rank_is_best_approx() {
        let mut rng = Rng::new(3);
        let b = Matrix::random(10, 2, &mut rng);
        let a = Matrix::random(2, 8, &mut rng);
        let low = b.matmul(&a);
        // rank-2 truncation of a rank-2 matrix reproduces it
        let t = low.truncate_rank(2);
        assert!(low.sub(&t).max_abs() < 1e-3, "err {}", low.sub(&t).max_abs());
    }
}

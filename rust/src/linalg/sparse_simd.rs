//! Runtime-dispatched SIMD inner loops for the structured-N:M sparse
//! kernels, sharing the GEBP microkernel's dispatch (`simd::active_path`)
//! and its determinism discipline.
//!
//! The structured layout guarantees a uniform entry count per support
//! row, so each row's entries form one contiguous `cols`/`vals` slice —
//! these kernels walk that slice in 8-wide (AVX2) or 4-wide (NEON)
//! windows with a scalar remainder. Windows never cross a support-row
//! boundary (the callers slice per row), and each window uses the
//! *entry-aligned column array* as gather indices, so any `n:m` pattern
//! vectorizes — not just 2:4.
//!
//! **Determinism contract.** Bitwise equality with the scalar group
//! loops in `sparse.rs` holds by construction:
//!
//!   * products use unfused multiply (never FMA), one IEEE-754 rounding
//!     per element, exactly like the scalar `xv * vals[k]`;
//!   * every accumulation *chain* stays serial and in ascending entry /
//!     batch-row order: `spmm_t_row` stores the vector products to a
//!     stack temp and adds them scalar in order, `scatter_grad` keeps
//!     one lane per support entry so each lane's chain is the scalar
//!     chain, and `spmm_row`'s scatter-adds are scalar in entry order.
//!
//! `SLTRAIN_SIMD=off` never reaches this module: `sparse.rs` keeps its
//! scalar group loops on `Path::Scalar`.

use super::simd::Path;
use super::Matrix;

/// One support row of `y_row[cols[k]] += xv * vals[k]`: products are
/// vectorized, scatter-adds stay scalar in ascending entry order.
/// `cols`/`vals` are the row's entry slices; every column is < y_row.len()
/// (the `SparseSupport::new` range invariant).
pub(crate) fn spmm_row(path: Path, xv: f32, cols: &[u32], vals: &[f32], y_row: &mut [f32]) {
    debug_assert_eq!(cols.len(), vals.len());
    debug_assert!(cols.iter().all(|&c| (c as usize) < y_row.len()));
    #[cfg(target_arch = "x86_64")]
    if path == Path::Avx2 {
        // SAFETY: Avx2 is only produced by runtime cpuid detection.
        unsafe { avx2_spmm_row(xv, cols, vals, y_row) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if path == Path::Neon {
        // SAFETY: NEON is a baseline feature of every aarch64 target.
        unsafe { neon_spmm_row(xv, cols, vals, y_row) };
        return;
    }
    let _ = path;
    for (c, v) in cols.iter().zip(vals) {
        y_row[*c as usize] += xv * v;
    }
}

/// One support row of `Σ_k dy_row[cols[k]] · vals[k]`: gathers and
/// products are vectorized, the accumulation chain stays scalar in
/// ascending entry order (the caller adds the result onto `dx_row[i]`).
pub(crate) fn spmm_t_row(path: Path, dy_row: &[f32], cols: &[u32], vals: &[f32]) -> f32 {
    debug_assert_eq!(cols.len(), vals.len());
    debug_assert!(cols.iter().all(|&c| (c as usize) < dy_row.len()));
    #[cfg(target_arch = "x86_64")]
    if path == Path::Avx2 {
        // SAFETY: Avx2 is only produced by runtime cpuid detection, and
        // every gather index is < dy_row.len() (support range invariant).
        return unsafe { avx2_spmm_t_row(dy_row, cols, vals) };
    }
    #[cfg(target_arch = "aarch64")]
    if path == Path::Neon {
        // SAFETY: NEON is baseline on aarch64; gather indices in range.
        return unsafe { neon_spmm_t_row(dy_row, cols, vals) };
    }
    let _ = path;
    let mut acc = 0.0f32;
    for (c, v) in cols.iter().zip(vals) {
        acc += dy_row[*c as usize] * v;
    }
    acc
}

/// Entries `k0 .. k0 + out.len()` of eq.-(2)'s sparse gradient on a
/// structured support: `out[kk] = Σ_n x[n, row] · dy[n, col]` with the
/// scalar per-entry chain (ascending batch row `n`). Row boundaries are
/// arithmetic (`k / per_row`), so the range — which may start and end
/// mid-row when the pool partitions entries — is split per row and each
/// row's entries run through the vector window kernel, one lane per
/// entry.
pub(crate) fn scatter_grad_range(
    path: Path,
    x: &Matrix,
    dy: &Matrix,
    per_row: usize,
    cols: &[u32],
    k0: usize,
    out: &mut [f32],
) {
    let end = k0 + out.len();
    let mut k = k0;
    let mut o = 0usize;
    while k < end {
        let i = k / per_row;
        let row_end = ((i + 1) * per_row).min(end);
        let len = row_end - k;
        scatter_grad_row(path, x, dy, i, &cols[k..row_end], &mut out[o..o + len]);
        k = row_end;
        o += len;
    }
}

/// A same-row span of support entries: every lane shares the x column
/// `i`, so the batch loop broadcasts `x[n, i]`, gathers `dy[n, cols]`,
/// and keeps one accumulator lane per entry.
fn scatter_grad_row(path: Path, x: &Matrix, dy: &Matrix, i: usize, cols: &[u32], out: &mut [f32]) {
    debug_assert_eq!(cols.len(), out.len());
    debug_assert!(i < x.cols);
    debug_assert!(cols.iter().all(|&c| (c as usize) < dy.cols));
    let mut k = 0usize;
    #[cfg(target_arch = "x86_64")]
    if path == Path::Avx2 {
        while k + 8 <= cols.len() {
            // SAFETY: Avx2 runtime-detected; gather indices < dy.cols.
            unsafe { avx2_scatter_win(x, dy, i, &cols[k..k + 8], &mut out[k..k + 8]) };
            k += 8;
        }
    }
    #[cfg(target_arch = "aarch64")]
    if path == Path::Neon {
        while k + 4 <= cols.len() {
            // SAFETY: NEON is baseline on aarch64; indices in range.
            unsafe { neon_scatter_win(x, dy, i, &cols[k..k + 4], &mut out[k..k + 4]) };
            k += 4;
        }
    }
    let _ = path;
    for (kk, d) in out.iter_mut().enumerate().skip(k) {
        let c = cols[kk] as usize;
        let mut acc = 0.0f32;
        for n in 0..x.rows {
            acc += x.data[n * x.cols + i] * dy.data[n * dy.cols + c];
        }
        *d = acc;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_spmm_row(xv: f32, cols: &[u32], vals: &[f32], y_row: &mut [f32]) {
    use std::arch::x86_64::*;
    let n = vals.len();
    let xvv = _mm256_set1_ps(xv);
    let mut t = [0.0f32; 8];
    let mut k = 0usize;
    while k + 8 <= n {
        // unfused mul — one rounding per product, same as the scalar
        // `xv * vals[k]`; the += below is the scalar second rounding
        let prod = _mm256_mul_ps(xvv, _mm256_loadu_ps(vals.as_ptr().add(k)));
        _mm256_storeu_ps(t.as_mut_ptr(), prod);
        for (e, &tv) in t.iter().enumerate() {
            *y_row.get_unchecked_mut(*cols.get_unchecked(k + e) as usize) += tv;
        }
        k += 8;
    }
    while k < n {
        *y_row.get_unchecked_mut(*cols.get_unchecked(k) as usize) += xv * vals.get_unchecked(k);
        k += 1;
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_spmm_t_row(dy_row: &[f32], cols: &[u32], vals: &[f32]) -> f32 {
    use std::arch::x86_64::*;
    let n = vals.len();
    let mut acc = 0.0f32;
    let mut t = [0.0f32; 8];
    let mut k = 0usize;
    while k + 8 <= n {
        let idx = _mm256_loadu_si256(cols.as_ptr().add(k) as *const __m256i);
        let g = _mm256_i32gather_ps::<4>(dy_row.as_ptr(), idx);
        // unfused mul, then a scalar in-order accumulation chain — the
        // exact rounding sequence of the scalar group loop
        _mm256_storeu_ps(t.as_mut_ptr(), _mm256_mul_ps(g, _mm256_loadu_ps(vals.as_ptr().add(k))));
        for &tv in &t {
            acc += tv;
        }
        k += 8;
    }
    while k < n {
        acc += dy_row.get_unchecked(*cols.get_unchecked(k) as usize) * vals.get_unchecked(k);
        k += 1;
    }
    acc
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_scatter_win(x: &Matrix, dy: &Matrix, i: usize, cols: &[u32], out: &mut [f32]) {
    use std::arch::x86_64::*;
    let idx = _mm256_loadu_si256(cols.as_ptr() as *const __m256i);
    let mut acc = _mm256_setzero_ps();
    let xp = x.data.as_ptr();
    let dyp = dy.data.as_ptr();
    for n in 0..x.rows {
        let xv = _mm256_set1_ps(*xp.add(n * x.cols + i));
        let dyv = _mm256_i32gather_ps::<4>(dyp.add(n * dy.cols), idx);
        // unfused mul + add — two roundings per batch row per lane,
        // ascending n: each lane replays the scalar per-entry chain
        acc = _mm256_add_ps(acc, _mm256_mul_ps(xv, dyv));
    }
    _mm256_storeu_ps(out.as_mut_ptr(), acc);
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn neon_spmm_row(xv: f32, cols: &[u32], vals: &[f32], y_row: &mut [f32]) {
    use std::arch::aarch64::*;
    let n = vals.len();
    let xvv = vdupq_n_f32(xv);
    let mut t = [0.0f32; 4];
    let mut k = 0usize;
    while k + 4 <= n {
        // unfused mul (never vfmaq) — one rounding per product
        let prod = vmulq_f32(xvv, vld1q_f32(vals.as_ptr().add(k)));
        vst1q_f32(t.as_mut_ptr(), prod);
        for (e, &tv) in t.iter().enumerate() {
            *y_row.get_unchecked_mut(*cols.get_unchecked(k + e) as usize) += tv;
        }
        k += 4;
    }
    while k < n {
        *y_row.get_unchecked_mut(*cols.get_unchecked(k) as usize) += xv * vals.get_unchecked(k);
        k += 1;
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn neon_spmm_t_row(dy_row: &[f32], cols: &[u32], vals: &[f32]) -> f32 {
    use std::arch::aarch64::*;
    let n = vals.len();
    let mut acc = 0.0f32;
    let mut t = [0.0f32; 4];
    let mut k = 0usize;
    while k + 4 <= n {
        // manual 4-wide gather (no NEON gather instruction)
        let g = [
            *dy_row.get_unchecked(*cols.get_unchecked(k) as usize),
            *dy_row.get_unchecked(*cols.get_unchecked(k + 1) as usize),
            *dy_row.get_unchecked(*cols.get_unchecked(k + 2) as usize),
            *dy_row.get_unchecked(*cols.get_unchecked(k + 3) as usize),
        ];
        // unfused mul, scalar in-order accumulation chain
        let prod = vmulq_f32(vld1q_f32(g.as_ptr()), vld1q_f32(vals.as_ptr().add(k)));
        vst1q_f32(t.as_mut_ptr(), prod);
        for &tv in &t {
            acc += tv;
        }
        k += 4;
    }
    while k < n {
        acc += dy_row.get_unchecked(*cols.get_unchecked(k) as usize) * vals.get_unchecked(k);
        k += 1;
    }
    acc
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn neon_scatter_win(x: &Matrix, dy: &Matrix, i: usize, cols: &[u32], out: &mut [f32]) {
    use std::arch::aarch64::*;
    let mut acc = vdupq_n_f32(0.0);
    let xp = x.data.as_ptr();
    let dyp = dy.data.as_ptr();
    let c = [
        *cols.get_unchecked(0) as usize,
        *cols.get_unchecked(1) as usize,
        *cols.get_unchecked(2) as usize,
        *cols.get_unchecked(3) as usize,
    ];
    for n in 0..x.rows {
        let xv = vdupq_n_f32(*xp.add(n * x.cols + i));
        let row = dyp.add(n * dy.cols);
        let g = [*row.add(c[0]), *row.add(c[1]), *row.add(c[2]), *row.add(c[3])];
        // unfused mul + add (never vfmaq): each lane replays the scalar
        // per-entry chain in ascending n
        acc = vaddq_f32(acc, vmulq_f32(xv, vld1q_f32(g.as_ptr())));
    }
    vst1q_f32(out.as_mut_ptr(), acc);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::linalg::simd::active_path;
    use crate::util::rng::Rng;

    // Each kernel run on the detected path must match its own scalar
    // fallback bit for bit — ragged lengths exercise window + remainder.
    // (End-to-end SIMD-vs-scalar coverage of the full N:M kernels lives
    // in sparse.rs's `nm_kernels_bitwise_match_generic_csr`.)

    #[test]
    fn vector_spmm_row_bitwise_matches_scalar() {
        let mut rng = Rng::new(21);
        for len in [0usize, 1, 3, 4, 7, 8, 9, 16, 19] {
            let cols: Vec<u32> = (0..len).map(|_| rng.below(24) as u32).collect();
            let vals: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let start: Vec<f32> = (0..24).map(|_| rng.gaussian() as f32).collect();
            let xv = rng.gaussian() as f32;
            let mut got = start.clone();
            spmm_row(active_path(), xv, &cols, &vals, &mut got);
            let mut want = start;
            spmm_row(Path::Scalar, xv, &cols, &vals, &mut want);
            assert_eq!(got, want, "len {len}");
        }
    }

    #[test]
    fn vector_spmm_t_row_bitwise_matches_scalar() {
        let mut rng = Rng::new(22);
        let dy: Vec<f32> = (0..32).map(|_| rng.gaussian() as f32).collect();
        for len in [0usize, 1, 4, 7, 8, 11, 16, 23] {
            let cols: Vec<u32> = (0..len).map(|_| rng.below(32) as u32).collect();
            let vals: Vec<f32> = (0..len).map(|_| rng.gaussian() as f32).collect();
            let got = spmm_t_row(active_path(), &dy, &cols, &vals);
            let want = spmm_t_row(Path::Scalar, &dy, &cols, &vals);
            assert_eq!(got.to_bits(), want.to_bits(), "len {len}");
        }
    }

    #[test]
    fn vector_scatter_grad_bitwise_matches_scalar_at_any_split() {
        let mut rng = Rng::new(23);
        let (d_in, d_out, per_row) = (5usize, 20usize, 9usize);
        let x = Matrix::random(6, d_in, &mut rng);
        let dy = Matrix::random(6, d_out, &mut rng);
        let cols: Vec<u32> =
            (0..d_in * per_row).map(|_| rng.below(d_out as u64) as u32).collect();
        let nnz = cols.len();
        let mut want = vec![0.0f32; nnz];
        scatter_grad_range(Path::Scalar, &x, &dy, per_row, &cols, 0, &mut want);
        // whole range, and mid-row chunked ranges (pool partitions)
        let mut got = vec![0.0f32; nnz];
        scatter_grad_range(active_path(), &x, &dy, per_row, &cols, 0, &mut got);
        assert_eq!(got, want, "whole range");
        for chunk in [1usize, 4, 7, 13] {
            let mut got = vec![0.0f32; nnz];
            let mut k0 = 0;
            while k0 < nnz {
                let end = (k0 + chunk).min(nnz);
                scatter_grad_range(active_path(), &x, &dy, per_row, &cols, k0, &mut got[k0..end]);
                k0 = end;
            }
            assert_eq!(got, want, "chunk {chunk}");
        }
    }
}

//! A small reusable worker pool for the native backend's hot loops.
//!
//! std-only (no rayon/crossbeam in the vendor set): N-1 persistent
//! worker threads plus the submitting thread cooperatively drain an
//! atomic task counter. Three properties the training engine relies on:
//!
//! * **Determinism.** The pool only ever runs *independent* tasks —
//!   every task writes its own disjoint output region and any f32
//!   reduction happens entirely inside one task in a fixed order. Which
//!   thread runs which task therefore cannot change a single bit of the
//!   result: the native engine produces bit-identical losses for every
//!   thread count, not just for a fixed one (tested in
//!   `tests/properties.rs`).
//! * **Zero overhead at one thread.** A pool built with `threads == 1`
//!   spawns nothing and `run` degenerates to an inline `for` loop, so
//!   `--threads 1` is the pre-pool engine, instruction for instruction.
//! * **No nesting surprises.** A `run` issued from inside a pool task
//!   (e.g. a parallel matmul called from a parallel attention head)
//!   executes inline on that worker instead of deadlocking on the pool.
//!
//! Safety note: `run` erases the task closure's lifetime to hand it to
//! the persistent workers. This is sound because `run` does not return
//! — and does not *unwind* — until every worker has checked in as
//! finished with the job: the submitter's own task drain runs under
//! `catch_unwind`, worker tasks run under `catch_unwind` (a panicking
//! task poisons the job, which the submitter re-raises after the
//! barrier), and concurrent submissions from different threads are
//! serialized on an internal mutex. So the borrow outlives every
//! dereference on every path.

use std::panic::{catch_unwind, resume_unwind, AssertUnwindSafe};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::thread::JoinHandle;

thread_local! {
    /// True on pool worker threads: nested `run` calls go inline.
    static IN_POOL: std::cell::Cell<bool> = std::cell::Cell::new(false);
}

/// Resolve a requested thread count: `0` means "auto" — the
/// `SLTRAIN_THREADS` env var if set, else the machine's available
/// parallelism. Always at least 1.
pub fn resolve_threads(requested: usize) -> usize {
    if requested > 0 {
        return requested;
    }
    if let Ok(v) = std::env::var("SLTRAIN_THREADS") {
        if let Ok(n) = v.trim().parse::<usize>() {
            if n > 0 {
                return n;
            }
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Per-replica pool size for an N-worker data-parallel run: the
/// resolved global budget split evenly, floor 1 — so `--threads 8
/// --workers 4` runs four replicas of two pool threads each instead of
/// oversubscribing the machine 4×. Determinism is unaffected: the
/// native engine is bit-identical at every pool size.
pub fn resolve_worker_threads(requested: usize, workers: usize) -> usize {
    (resolve_threads(requested) / workers.max(1)).max(1)
}

/// Lifetime-erased pointer to the current job's task closure.
struct RawTask(*const (dyn Fn(usize) + Sync));
// SAFETY: the pointee is `Sync` (shared calls are fine) and the pool
// guarantees it outlives all worker accesses (see `run`).
unsafe impl Send for RawTask {}
unsafe impl Sync for RawTask {}

struct Job {
    task: RawTask,
    /// Next task index to claim.
    next: AtomicUsize,
    total: usize,
    /// Workers that have not yet finished with this job.
    running: AtomicUsize,
    /// Set when any task panicked; the submitter re-raises it.
    panicked: AtomicBool,
}

struct PoolState {
    job: Option<Arc<Job>>,
    epoch: u64,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    work_cv: Condvar,
    done_cv: Condvar,
}

/// A fixed-size pool of persistent worker threads. The submitting
/// thread participates in every job, so a pool of `threads == T` uses
/// exactly T threads of compute and spawns T-1 workers.
pub struct ThreadPool {
    shared: Arc<Shared>,
    workers: Vec<JoinHandle<()>>,
    threads: usize,
    /// Serializes concurrent `run` calls from different threads (one
    /// job slot exists; a second submitter must wait its turn).
    submit: Mutex<()>,
}

impl ThreadPool {
    /// Build a pool. `threads` is clamped to at least 1; a 1-thread
    /// pool spawns no workers and runs everything inline.
    pub fn new(threads: usize) -> ThreadPool {
        let threads = threads.max(1);
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState { job: None, epoch: 0, shutdown: false }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let mut workers = Vec::new();
        for w in 1..threads {
            let sh = shared.clone();
            let handle = std::thread::Builder::new()
                .name(format!("sltrain-pool-{w}"))
                .spawn(move || worker_loop(&sh))
                .expect("spawn pool worker");
            workers.push(handle);
        }
        ThreadPool { shared, workers, threads, submit: Mutex::new(()) }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `f(0), f(1), .., f(n-1)` across the pool and return once all
    /// have completed. Tasks must be independent: `f` is called
    /// concurrently for distinct indices. Runs inline when the pool has
    /// one thread, when `n <= 1`, or when called from a pool worker.
    pub fn run<F: Fn(usize) + Sync>(&self, n: usize, f: F) {
        if n == 0 {
            return;
        }
        if self.workers.is_empty() || n == 1 || IN_POOL.with(|c| c.get()) {
            for i in 0..n {
                f(i);
            }
            return;
        }
        let obj: &(dyn Fn(usize) + Sync) = &f;
        // SAFETY: only the lifetime is erased; `run` blocks below until
        // every worker has finished dereferencing the pointer.
        let raw = RawTask(unsafe {
            std::mem::transmute::<&(dyn Fn(usize) + Sync), &'static (dyn Fn(usize) + Sync)>(obj)
                as *const (dyn Fn(usize) + Sync)
        });
        let job = Arc::new(Job {
            task: raw,
            next: AtomicUsize::new(0),
            total: n,
            running: AtomicUsize::new(self.workers.len()),
            panicked: AtomicBool::new(false),
        });
        // one job slot: serialize submitters from different threads
        let submit_guard = self.submit.lock().unwrap();
        {
            let mut st = self.shared.state.lock().unwrap();
            st.epoch += 1;
            st.job = Some(job.clone());
            self.shared.work_cv.notify_all();
        }
        // The submitting thread drains tasks too. While it does, mark it
        // as in-pool so a nested `run` from inside one of its tasks goes
        // inline instead of clobbering the active job. The drain runs
        // under catch_unwind so a panicking task cannot unwind past the
        // wait-for-workers barrier below (the closure must stay alive
        // until no worker can still dereference it).
        IN_POOL.with(|c| c.set(true));
        let my_result = catch_unwind(AssertUnwindSafe(|| loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= n {
                break;
            }
            f(i);
        }));
        IN_POOL.with(|c| c.set(false));
        if my_result.is_err() {
            // stop handing out task indices so workers finish promptly
            job.next.fetch_max(n, Ordering::Relaxed);
        }
        {
            let mut st = self.shared.state.lock().unwrap();
            while job.running.load(Ordering::Acquire) != 0 {
                st = self.shared.done_cv.wait(st).unwrap();
            }
            st.job = None;
        }
        drop(submit_guard);
        if let Err(payload) = my_result {
            resume_unwind(payload);
        }
        if job.panicked.load(Ordering::Acquire) {
            panic!("a pool task panicked (see worker output above)");
        }
    }

    /// Run `f` over `0..n` and collect the results in index order.
    pub fn map<R: Send, F: Fn(usize) -> R + Sync>(&self, n: usize, f: F) -> Vec<R> {
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        for _ in 0..n {
            out.push(None);
        }
        {
            let slots = SendPtr(out.as_mut_ptr());
            self.run(n, |i| {
                // SAFETY: each task writes only slot i; slots outlive run()
                unsafe {
                    *slots.get().add(i) = Some(f(i));
                }
            });
        }
        out.into_iter().map(|r| r.expect("pool task did not run")).collect()
    }
}

impl Drop for ThreadPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.state.lock().unwrap();
            st.shutdown = true;
            self.shared.work_cv.notify_all();
        }
        for h in self.workers.drain(..) {
            let _ = h.join();
        }
    }
}

fn worker_loop(shared: &Shared) {
    IN_POOL.with(|c| c.set(true));
    let mut last_epoch = 0u64;
    loop {
        let job = {
            let mut st = shared.state.lock().unwrap();
            loop {
                if st.shutdown {
                    return;
                }
                if let Some(j) = &st.job {
                    if st.epoch != last_epoch {
                        last_epoch = st.epoch;
                        break j.clone();
                    }
                }
                st = shared.work_cv.wait(st).unwrap();
            }
        };
        loop {
            let i = job.next.fetch_add(1, Ordering::Relaxed);
            if i >= job.total {
                break;
            }
            // SAFETY: the submitter keeps the closure alive until
            // `running` hits zero (below).
            let task = unsafe { &*job.task.0 };
            // a panicking task must not kill the worker (the submitter
            // would deadlock waiting for its check-in): poison the job
            // and let the submitter re-raise after the barrier
            if catch_unwind(AssertUnwindSafe(|| task(i))).is_err() {
                job.panicked.store(true, Ordering::Release);
                job.next.fetch_max(job.total, Ordering::Relaxed);
            }
        }
        if job.running.fetch_sub(1, Ordering::AcqRel) == 1 {
            let _guard = shared.state.lock().unwrap();
            shared.done_cv.notify_all();
        }
    }
}

/// A raw pointer wrapper that lets pool tasks write disjoint regions of
/// one buffer. The *user* guarantees disjointness; the helpers below
/// encapsulate the common safe patterns. Public so the optimizer and
/// backend elementwise passes can partition several parallel buffers by
/// one shared index range (`par_index_ranges`).
pub struct SendPtr<T>(*mut T);
unsafe impl<T: Send> Send for SendPtr<T> {}
unsafe impl<T: Send> Sync for SendPtr<T> {}
impl<T> SendPtr<T> {
    /// Wrap a base pointer. Callers must guarantee that concurrent
    /// tasks dereference disjoint offsets and that the pointee outlives
    /// the pool run.
    pub fn new(ptr: *mut T) -> SendPtr<T> {
        SendPtr(ptr)
    }

    pub fn get(&self) -> *mut T {
        self.0
    }
}

/// Split `data` into contiguous chunks of `chunk_len` (last one may be
/// shorter) and run `f(chunk_index, chunk)` over the pool. Each task
/// owns exactly one chunk, so this is a safe wrapper.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(
    pool: &ThreadPool,
    data: &mut [T],
    chunk_len: usize,
    f: F,
) {
    let len = data.len();
    if len == 0 {
        return;
    }
    let chunk_len = chunk_len.max(1);
    let n_chunks = len.div_ceil(chunk_len);
    let base = SendPtr(data.as_mut_ptr());
    pool.run(n_chunks, |ci| {
        let start = ci * chunk_len;
        let end = (start + chunk_len).min(len);
        // SAFETY: chunks [start, end) are disjoint across ci and within
        // bounds; the borrow of `data` outlives pool.run.
        let chunk = unsafe { std::slice::from_raw_parts_mut(base.get().add(start), end - start) };
        f(ci, chunk);
    });
}

/// Evenly partition `n` items over the pool: returns the per-task chunk
/// length so that at most `threads` tasks are created.
pub fn chunk_len_for(pool: &ThreadPool, n: usize) -> usize {
    n.div_ceil(pool.threads().max(1)).max(1)
}

/// Partition `0..n` into contiguous index ranges (one per pool thread,
/// the last possibly shorter) and run `f(range)` over the pool. Every
/// range boundary is a multiple of `granule`, so units of work spanning
/// `granule` consecutive indices (rows of a matrix, 8-bit quantization
/// blocks) are never split across tasks. All callers partition
/// element-wise or block-wise *independent* work, so which thread runs
/// which range cannot change a bit of the result — the determinism
/// contract holds at every thread count.
pub fn par_index_ranges<F: Fn(std::ops::Range<usize>) + Sync>(
    pool: &ThreadPool,
    n: usize,
    granule: usize,
    f: F,
) {
    if n == 0 {
        return;
    }
    let granule = granule.max(1);
    let per = n.div_ceil(pool.threads().max(1));
    let chunk = per.div_ceil(granule) * granule;
    let tasks = n.div_ceil(chunk);
    pool.run(tasks, |t| {
        let start = t * chunk;
        f(start..(start + chunk).min(n));
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn run_covers_every_index_once() {
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            let hits: Vec<AtomicU64> = (0..97).map(|_| AtomicU64::new(0)).collect();
            pool.run(97, |i| {
                hits[i].fetch_add(1, Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(h.load(Ordering::Relaxed), 1, "index {i} at {threads} threads");
            }
        }
    }

    #[test]
    fn map_preserves_index_order() {
        let pool = ThreadPool::new(3);
        let out = pool.map(50, |i| i * i);
        assert_eq!(out, (0..50).map(|i| i * i).collect::<Vec<_>>());
    }

    #[test]
    fn pool_is_reusable_across_jobs() {
        let pool = ThreadPool::new(4);
        for round in 0..20 {
            let sum = AtomicU64::new(0);
            pool.run(round + 1, |i| {
                sum.fetch_add(i as u64, Ordering::Relaxed);
            });
            let want: u64 = (0..(round as u64 + 1)).sum();
            assert_eq!(sum.load(Ordering::Relaxed), want, "round {round}");
        }
    }

    #[test]
    fn nested_run_executes_inline() {
        let pool = ThreadPool::new(2);
        let count = AtomicU64::new(0);
        pool.run(4, |_| {
            // nested: must not deadlock
            pool.run(3, |_| {
                count.fetch_add(1, Ordering::Relaxed);
            });
        });
        assert_eq!(count.load(Ordering::Relaxed), 12);
    }

    #[test]
    fn panicking_task_propagates_and_pool_survives() {
        let pool = ThreadPool::new(3);
        let result = catch_unwind(AssertUnwindSafe(|| {
            pool.run(8, |i| {
                if i == 5 {
                    panic!("boom");
                }
            });
        }));
        assert!(result.is_err(), "task panic must propagate to the submitter");
        // the pool must still be fully usable afterwards
        let sum = AtomicU64::new(0);
        pool.run(10, |i| {
            sum.fetch_add(i as u64, Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 45);
    }

    #[test]
    fn par_chunks_mut_writes_disjoint_regions() {
        let pool = ThreadPool::new(3);
        let mut data = vec![0u32; 103];
        par_chunks_mut(&pool, &mut data, 10, |ci, chunk| {
            for x in chunk.iter_mut() {
                *x = ci as u32 + 1;
            }
        });
        for (i, &x) in data.iter().enumerate() {
            assert_eq!(x, (i / 10) as u32 + 1, "index {i}");
        }
    }

    #[test]
    fn resolve_threads_clamps_and_reads_env() {
        assert_eq!(resolve_threads(3), 3);
        assert!(resolve_threads(0) >= 1);
    }

    #[test]
    fn par_index_ranges_covers_all_indices_with_aligned_boundaries() {
        for threads in [1, 2, 4] {
            let pool = ThreadPool::new(threads);
            for (n, granule) in [(1usize, 4usize), (255, 4), (256, 4), (1000, 7), (13, 256)] {
                let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
                par_index_ranges(&pool, n, granule, |r| {
                    assert!(r.start % granule == 0, "start {} not {granule}-aligned", r.start);
                    assert!(r.end == n || r.end % granule == 0, "end {} unaligned", r.end);
                    for i in r {
                        hits[i].fetch_add(1, Ordering::Relaxed);
                    }
                });
                for (i, h) in hits.iter().enumerate() {
                    assert_eq!(h.load(Ordering::Relaxed), 1, "n={n} g={granule} index {i}");
                }
            }
        }
    }
}

//! Runtime-dispatched SIMD microkernels for the GEBP matmul core.
//!
//! The blocked GEMM in `linalg::mod` spends essentially all of its time
//! in one microkernel: accumulate an `MR × NR` register tile of
//! `A @ panel(B)`. This module provides that kernel on three paths —
//! AVX2 (x86_64), NEON (aarch64) and plain scalar rust — selected once
//! per process by runtime feature detection.
//!
//! **Determinism contract.** The repo guarantees bit-identical results
//! across thread counts *and* across microkernel paths. The vector
//! kernels uphold this by construction: they use unfused multiply +
//! add intrinsics (never FMA), so every output element experiences the
//! exact same sequence of IEEE-754 f32 roundings, in the same naive
//! `l = 0..k` order, as the scalar kernel. Widening the tile changes
//! which elements are computed together, never the per-element order.
//!
//! `SLTRAIN_SIMD=off` forces the scalar path (the escape hatch and the
//! CI cross-check); `SLTRAIN_SIMD=auto` (or unset) picks the widest
//! available ISA. Anything else aborts with a clear message rather than
//! silently running a path the operator did not ask for.

use std::sync::OnceLock;

/// Microkernel tile height (output rows held in registers).
pub const MR: usize = 8;
/// Packed panel width (output cols per panel; one AVX2 vector, two
/// NEON vectors).
pub const NR: usize = 8;

/// The `MR × NR` register accumulator tile.
pub type Acc = [[f32; NR]; MR];

/// Which instruction set the microkernel dispatches to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Path {
    /// Plain rust loops — always compiled, forced by `SLTRAIN_SIMD=off`,
    /// and the bitwise reference every vector path must match.
    Scalar,
    /// 8-lane f32 vectors on x86_64 (runtime-detected via cpuid).
    Avx2,
    /// Paired 4-lane f32 vectors on aarch64 (baseline feature).
    Neon,
}

impl Path {
    /// Stable lower-case name for logs and bench metadata.
    pub fn name(self) -> &'static str {
        match self {
            Path::Scalar => "scalar",
            Path::Avx2 => "avx2",
            Path::Neon => "neon",
        }
    }
}

static ACTIVE: OnceLock<Path> = OnceLock::new();

/// The microkernel path selected for this process. Resolved once from
/// `SLTRAIN_SIMD` + CPU feature detection and cached (the env var is
/// read at first use, so set it before any matmul runs).
pub fn active_path() -> Path {
    *ACTIVE.get_or_init(|| match std::env::var("SLTRAIN_SIMD") {
        Err(_) => detect(),
        Ok(v) => match v.trim().to_ascii_lowercase().as_str() {
            "" | "auto" => detect(),
            "off" => Path::Scalar,
            other => panic!("SLTRAIN_SIMD={other:?}: expected \"auto\" or \"off\""),
        },
    })
}

// the scalar tail is unreachable only on aarch64, where NEON is baseline
#[allow(unreachable_code)]
fn detect() -> Path {
    #[cfg(target_arch = "x86_64")]
    if is_x86_feature_detected!("avx2") {
        return Path::Avx2;
    }
    #[cfg(target_arch = "aarch64")]
    return Path::Neon;
    Path::Scalar
}

/// Accumulate `a[i0..i0+MR, 0..k] @ panel` into `acc` on the given path.
///
/// `panel` is a zero-padded packed B panel (`panel[l*NR + jj]` holds
/// `B[l, j0+jj]`). Only `active_path()` (or `Path::Scalar`) may be
/// passed: the vector variants assume their ISA was runtime-detected.
#[inline]
pub fn tile(path: Path, a: &[f32], i0: usize, k: usize, panel: &[f32], acc: &mut Acc) {
    debug_assert!(panel.len() >= k * NR);
    debug_assert!(a.len() >= (i0 + MR) * k);
    #[cfg(target_arch = "x86_64")]
    if path == Path::Avx2 {
        // SAFETY: Avx2 is only produced by `detect` after cpuid says so.
        unsafe { avx2_tile(a, i0, k, panel, acc) };
        return;
    }
    #[cfg(target_arch = "aarch64")]
    if path == Path::Neon {
        // SAFETY: NEON is a baseline feature of every aarch64 target.
        unsafe { neon_tile(a, i0, k, panel, acc) };
        return;
    }
    let _ = path;
    scalar_tile(a, i0, k, panel, acc);
}

/// The reference microkernel: per output element the plain `l = 0..k`
/// mul-then-add chain, i.e. exactly the naive dot product.
pub fn scalar_tile(a: &[f32], i0: usize, k: usize, panel: &[f32], acc: &mut Acc) {
    for l in 0..k {
        let bl: &[f32; NR] = panel[l * NR..l * NR + NR].try_into().unwrap();
        for (ii, row) in acc.iter_mut().enumerate() {
            let av = a[(i0 + ii) * k + l];
            for (c, &b) in row.iter_mut().zip(bl) {
                *c += av * b;
            }
        }
    }
}

#[cfg(target_arch = "x86_64")]
#[target_feature(enable = "avx2")]
unsafe fn avx2_tile(a: &[f32], i0: usize, k: usize, panel: &[f32], acc: &mut Acc) {
    use std::arch::x86_64::*;
    let mut v: [__m256; MR] = [_mm256_setzero_ps(); MR];
    for (vr, row) in v.iter_mut().zip(acc.iter()) {
        *vr = _mm256_loadu_ps(row.as_ptr());
    }
    let ap = a.as_ptr();
    let pp = panel.as_ptr();
    for l in 0..k {
        let bl = _mm256_loadu_ps(pp.add(l * NR));
        for (ii, vr) in v.iter_mut().enumerate() {
            let av = _mm256_set1_ps(*ap.add((i0 + ii) * k + l));
            // unfused mul + add — NOT _mm256_fmadd_ps: two IEEE
            // roundings per lane, matching the scalar kernel bit for bit
            *vr = _mm256_add_ps(*vr, _mm256_mul_ps(av, bl));
        }
    }
    for (row, vr) in acc.iter_mut().zip(v.iter()) {
        _mm256_storeu_ps(row.as_mut_ptr(), *vr);
    }
}

#[cfg(target_arch = "aarch64")]
#[target_feature(enable = "neon")]
unsafe fn neon_tile(a: &[f32], i0: usize, k: usize, panel: &[f32], acc: &mut Acc) {
    use std::arch::aarch64::*;
    let mut lo = [vdupq_n_f32(0.0); MR];
    let mut hi = [vdupq_n_f32(0.0); MR];
    for (ii, row) in acc.iter().enumerate() {
        lo[ii] = vld1q_f32(row.as_ptr());
        hi[ii] = vld1q_f32(row.as_ptr().add(4));
    }
    let ap = a.as_ptr();
    let pp = panel.as_ptr();
    for l in 0..k {
        let b0 = vld1q_f32(pp.add(l * NR));
        let b1 = vld1q_f32(pp.add(l * NR + 4));
        for (ii, (lv, hv)) in lo.iter_mut().zip(hi.iter_mut()).enumerate() {
            let av = vdupq_n_f32(*ap.add((i0 + ii) * k + l));
            // unfused mul + add — NOT vfmaq_f32: two IEEE roundings per
            // lane, matching the scalar kernel bit for bit
            *lv = vaddq_f32(*lv, vmulq_f32(av, b0));
            *hv = vaddq_f32(*hv, vmulq_f32(av, b1));
        }
    }
    for (ii, row) in acc.iter_mut().enumerate() {
        vst1q_f32(row.as_mut_ptr(), lo[ii]);
        vst1q_f32(row.as_mut_ptr().add(4), hi[ii]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn path_names_are_stable() {
        assert_eq!(Path::Scalar.name(), "scalar");
        assert_eq!(Path::Avx2.name(), "avx2");
        assert_eq!(Path::Neon.name(), "neon");
    }

    #[test]
    fn active_path_is_cached_and_valid() {
        let p = active_path();
        assert_eq!(p, active_path(), "path must be stable within a process");
        if std::env::var("SLTRAIN_SIMD").as_deref() == Ok("off") {
            assert_eq!(p, Path::Scalar);
        }
    }

    #[test]
    fn vector_tile_bitwise_matches_scalar_tile() {
        // ragged k (k % NR != 0), k == 0, and accumulation on top of a
        // non-zero starting tile — every case must agree bit for bit
        let mut rng = Rng::new(7);
        for k in [0usize, 1, 3, 8, 13, 64, 129] {
            let a: Vec<f32> = (0..(MR + 2) * k.max(1)).map(|_| rng.gaussian() as f32).collect();
            let panel: Vec<f32> = (0..k * NR).map(|_| rng.gaussian() as f32).collect();
            let mut start = [[0.0f32; NR]; MR];
            for row in start.iter_mut() {
                for c in row.iter_mut() {
                    *c = rng.gaussian() as f32;
                }
            }
            let mut got = start;
            tile(active_path(), &a, 0, k, &panel, &mut got);
            let mut want = start;
            scalar_tile(&a, 0, k, &panel, &mut want);
            assert_eq!(got, want, "path {:?} diverges at k={k}", active_path());
        }
    }
}

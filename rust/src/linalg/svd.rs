//! One-sided Jacobi SVD (Hestenes), f64 accumulation.
//!
//! Rotates column pairs of A until all pairs are orthogonal; then
//! singular values are column norms, U the normalized columns, and V the
//! accumulated rotations. Cost O(m n^2) per sweep, a handful of sweeps —
//! fine for the d ≤ 2k weight matrices the analysis benches decompose.
//! For rows < cols we factor the transpose and swap U/V.
//!
//! The GaLore projector refresh (`backend::native`) also runs this on
//! each adapted linear's gradient — the paper's original torch.svd
//! recipe. That is a full decomposition to keep only the top-r columns,
//! so refresh steps are much more expensive than regular ones; the
//! `--galore-every` period (default 200) amortizes it, and off-refresh
//! steps pay only rank-r matmuls. If refresh stalls ever matter at
//! larger scales, the warm-started subspace iteration of
//! `python/compile/optim.py` (pure matmuls) is the drop-in alternative.

use super::Matrix;

pub struct Svd {
    pub u: Matrix,  // [m, k]
    pub s: Vec<f32>, // k = min(m, n), descending
    pub vt: Matrix, // [k, n]
}

pub fn svd(a: &Matrix) -> Svd {
    if a.rows < a.cols {
        let t = svd(&a.transpose());
        return Svd { u: t.vt.transpose(), s: t.s, vt: t.u.transpose() };
    }
    let m = a.rows;
    let n = a.cols;
    // work in f64 for accumulation
    let mut u: Vec<f64> = a.data.iter().map(|&x| x as f64).collect(); // [m, n] col-updated
    let mut v = vec![0.0f64; n * n];
    for i in 0..n {
        v[i * n + i] = 1.0;
    }

    let max_sweeps = 60;
    let eps = 1e-12;
    for _sweep in 0..max_sweeps {
        let mut off = 0.0f64;
        for p in 0..n.saturating_sub(1) {
            for q in (p + 1)..n {
                // alpha = ||a_p||^2, beta = ||a_q||^2, gamma = a_p . a_q
                let (mut alpha, mut beta, mut gamma) = (0.0f64, 0.0, 0.0);
                for i in 0..m {
                    let ap = u[i * n + p];
                    let aq = u[i * n + q];
                    alpha += ap * ap;
                    beta += aq * aq;
                    gamma += ap * aq;
                }
                if gamma.abs() <= eps * (alpha * beta).sqrt() || gamma == 0.0 {
                    continue;
                }
                off += gamma.abs() / (alpha * beta).sqrt().max(1e-300);
                // Jacobi rotation zeroing gamma
                let zeta = (beta - alpha) / (2.0 * gamma);
                let t = zeta.signum() / (zeta.abs() + (1.0 + zeta * zeta).sqrt());
                let c = 1.0 / (1.0 + t * t).sqrt();
                let s = c * t;
                for i in 0..m {
                    let ap = u[i * n + p];
                    let aq = u[i * n + q];
                    u[i * n + p] = c * ap - s * aq;
                    u[i * n + q] = s * ap + c * aq;
                }
                for i in 0..n {
                    let vp = v[i * n + p];
                    let vq = v[i * n + q];
                    v[i * n + p] = c * vp - s * vq;
                    v[i * n + q] = s * vp + c * vq;
                }
            }
        }
        if off < 1e-10 {
            break;
        }
    }

    // singular values = column norms; sort descending with permutation
    let mut sv: Vec<(f64, usize)> = (0..n)
        .map(|j| {
            let norm: f64 = (0..m).map(|i| u[i * n + j] * u[i * n + j]).sum::<f64>().sqrt();
            (norm, j)
        })
        .collect();
    sv.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());

    let mut um = Matrix::zeros(m, n);
    let mut vtm = Matrix::zeros(n, n);
    let mut s_out = Vec::with_capacity(n);
    for (k, &(norm, j)) in sv.iter().enumerate() {
        s_out.push(norm as f32);
        let inv = if norm > 1e-300 { 1.0 / norm } else { 0.0 };
        for i in 0..m {
            um[(i, k)] = (u[i * n + j] * inv) as f32;
        }
        for i in 0..n {
            vtm[(k, i)] = v[i * n + j] as f32;
        }
    }
    Svd { u: um, s: s_out, vt: vtm }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn reconstruct(f: &Svd) -> Matrix {
        let k = f.s.len();
        let mut us = Matrix::zeros(f.u.rows, k);
        for i in 0..f.u.rows {
            for j in 0..k {
                us[(i, j)] = f.u[(i, j)] * f.s[j];
            }
        }
        us.matmul(&f.vt)
    }

    #[test]
    fn reconstructs_random_tall() {
        let mut rng = Rng::new(0);
        let a = Matrix::random(20, 8, &mut rng);
        let f = svd(&a);
        let err = a.sub(&reconstruct(&f)).max_abs();
        assert!(err < 1e-4, "err {err}");
    }

    #[test]
    fn reconstructs_random_wide() {
        let mut rng = Rng::new(1);
        let a = Matrix::random(6, 15, &mut rng);
        let f = svd(&a);
        let err = a.sub(&reconstruct(&f)).max_abs();
        assert!(err < 1e-4, "err {err}");
    }

    #[test]
    fn singular_values_descending_nonneg() {
        let mut rng = Rng::new(2);
        let a = Matrix::random(12, 12, &mut rng);
        let f = svd(&a);
        assert!(f.s.windows(2).all(|w| w[0] >= w[1] - 1e-6));
        assert!(f.s.iter().all(|&s| s >= 0.0));
    }

    #[test]
    fn u_and_v_orthonormal() {
        let mut rng = Rng::new(3);
        let a = Matrix::random(10, 7, &mut rng);
        let f = svd(&a);
        let utu = f.u.transpose().matmul(&f.u);
        let vvt = f.vt.matmul(&f.vt.transpose());
        for i in 0..7 {
            for j in 0..7 {
                let expect = if i == j { 1.0 } else { 0.0 };
                assert!((utu[(i, j)] - expect).abs() < 1e-4, "UtU[{i},{j}]");
                assert!((vvt[(i, j)] - expect).abs() < 1e-4, "VVt[{i},{j}]");
            }
        }
    }

    #[test]
    fn known_diagonal() {
        let a = Matrix::from_vec(3, 3, vec![3.0, 0.0, 0.0, 0.0, -5.0, 0.0, 0.0, 0.0, 1.0]);
        let f = svd(&a);
        assert!((f.s[0] - 5.0).abs() < 1e-5);
        assert!((f.s[1] - 3.0).abs() < 1e-5);
        assert!((f.s[2] - 1.0).abs() < 1e-5);
    }

    #[test]
    fn rank_deficient_tail_is_zero() {
        let mut rng = Rng::new(4);
        let b = Matrix::random(10, 2, &mut rng);
        let c = Matrix::random(2, 9, &mut rng);
        let a = b.matmul(&c);
        let f = svd(&a);
        assert!(f.s[2] < 1e-4 * f.s[0], "s2 {} s0 {}", f.s[2], f.s[0]);
    }
}

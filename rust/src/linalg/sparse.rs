//! Fixed-support sparse kernels for the native SLTrain backend.
//!
//! SLTrain's sparse factor S never changes support: `idx` is chosen once
//! at init (paper §3.2) and only the values are learned. That makes the
//! support a build-once structure — we keep the paper's flat row-major
//! COO indices (the interchange format of the artifact sidecars and
//! checkpoints) and derive a CSR row partition from them once, so the
//! per-step kernels are straight loops with no searching:
//!
//!   * `spmm`          y  += x @ S        (forward sparse contribution)
//!   * `spmm_t`        dx += dy @ S^T     (backward input gradient)
//!   * `scatter_grad`  dvals = (x^T dy) gathered at the support — the
//!                     paper's eq. (2) sparse gradient, never
//!                     materializing the dense d_in × d_out matrix
//!   * `fused_effective`  W = scale·(B@A) ⊕_idx vals  (Algorithm 1 line 4)
//!
//! Two support *patterns* share this machinery (`SupportPattern`): the
//! paper's uniform-random support, and SLoPe-style structured N:M
//! (`n` nonzeros in every aligned group of `m` consecutive columns).
//! A structured support carries an extra `NmLayout` that lets the
//! kernels walk fixed-trip-count groups with contiguous value blocks
//! and byte-sized in-group offsets instead of per-entry u32 column
//! gathers — same entry order, so results are bit-identical to the
//! generic CSR path; only speed differs. On AVX2/NEON hosts the
//! structured inner loops additionally run through the vectorized
//! window kernels in `sparse_simd` (same `simd::active_path`
//! dispatch as the GEBP tile), still bit-for-bit equal.

use super::parallel::{self, ThreadPool};
use super::simd::{self, Path};
use super::{sparse_simd, Matrix};
use crate::util::rng::Rng;

/// How the fixed support of the sparse factor is chosen and laid out.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SupportPattern {
    /// `nnz = round(delta · d_in · d_out)` entries drawn uniformly at
    /// random — the paper's §3.2 strategy.
    UniformRandom,
    /// `n` nonzeros in every aligned group of `m` consecutive columns,
    /// per row (SLoPe's 2:4 scheme generalized). Density is `n/m`;
    /// the preset's `delta` is ignored.
    StructuredNM {
        /// Nonzeros kept per group.
        n: usize,
        /// Group width in columns (≤ 256 so in-group offsets fit a byte).
        m: usize,
    },
}

impl SupportPattern {
    /// Parse a CLI support spec: `random`, or `n:m` (e.g. `2:4`).
    pub fn parse(s: &str) -> Result<SupportPattern, String> {
        let t = s.trim();
        if t.is_empty() || t == "random" {
            return Ok(SupportPattern::UniformRandom);
        }
        if let Some((ns, ms)) = t.split_once(':') {
            let parse = |x: &str| {
                x.trim()
                    .parse::<usize>()
                    .map_err(|_| format!("bad support pattern {s:?}: {x:?} is not a number"))
            };
            let (n, m) = (parse(ns)?, parse(ms)?);
            if n == 0 || m == 0 || n > m || m > 256 {
                return Err(format!(
                    "bad support pattern {s:?}: need 1 <= n <= m <= 256"
                ));
            }
            return Ok(SupportPattern::StructuredNM { n, m });
        }
        Err(format!("unknown support pattern {s:?} (expected \"random\" or \"n:m\", e.g. \"2:4\")"))
    }

    /// Stable label for logs, benches and CSV rows.
    pub fn label(&self) -> String {
        match self {
            SupportPattern::UniformRandom => "random".to_string(),
            SupportPattern::StructuredNM { n, m } => format!("{n}:{m}"),
        }
    }

    /// Fraction of entries kept: `Some(n/m)` for structured patterns,
    /// `None` for random (density comes from the preset's `delta`).
    pub fn density(&self) -> Option<f64> {
        match self {
            SupportPattern::UniformRandom => None,
            SupportPattern::StructuredNM { n, m } => Some(*n as f64 / *m as f64),
        }
    }
}

impl std::fmt::Display for SupportPattern {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(&self.label())
    }
}

/// The structured-N:M fast-path layout: with every row holding exactly
/// `n` entries per complete `m`-wide group (plus `min(n, d_out % m)` in
/// the ragged tail group), group boundaries are pure arithmetic and
/// each entry's column is `group·m + off` with a byte-sized `off`.
#[derive(Debug, Clone)]
struct NmLayout {
    n: usize,
    m: usize,
    /// In-group column offset (`col % m`) of each entry, aligned with `idx`.
    offs: Vec<u8>,
    /// Complete m-wide groups per row (`d_out / m`).
    full_groups: usize,
    /// Entries in the ragged tail group (`min(n, d_out % m)`).
    tail: usize,
}

impl NmLayout {
    /// Entries per row (uniform across rows by construction).
    fn per_row(&self) -> usize {
        self.full_groups * self.n + self.tail
    }
}

/// A fixed sparse support over a `d_in × d_out` matrix: sorted flat
/// row-major COO indices plus the derived CSR row partition, and — for
/// conforming N:M supports — the structured fast-path layout.
#[derive(Debug, Clone)]
pub struct SparseSupport {
    pub d_in: usize,
    pub d_out: usize,
    /// Flat row-major indices, sorted ascending, distinct.
    pub idx: Vec<u32>,
    /// Column of each nonzero (idx % d_out), aligned with `idx`.
    cols: Vec<u32>,
    /// CSR row pointer: nonzeros of row i live in `row_ptr[i]..row_ptr[i+1]`.
    row_ptr: Vec<usize>,
    /// Structured-N:M layout when the support conforms (`None` = generic).
    nm: Option<NmLayout>,
}

impl SparseSupport {
    /// Build from sorted-distinct flat indices (the sidecar/checkpoint
    /// format). Panics on out-of-range or unsorted input.
    pub fn new(d_in: usize, d_out: usize, idx: Vec<u32>) -> SparseSupport {
        assert!(d_out > 0 && d_in > 0, "empty support shape");
        let bound = (d_in * d_out) as u32;
        assert!(idx.iter().all(|&i| i < bound), "support index out of range");
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "support not sorted-distinct");
        let cols: Vec<u32> = idx.iter().map(|&i| i % d_out as u32).collect();
        let mut row_ptr = vec![0usize; d_in + 1];
        for &i in &idx {
            row_ptr[i as usize / d_out + 1] += 1;
        }
        for r in 0..d_in {
            row_ptr[r + 1] += row_ptr[r];
        }
        SparseSupport { d_in, d_out, idx, cols, row_ptr, nm: None }
    }

    /// Structured N:M support: in every row, `n` distinct columns drawn
    /// per aligned `m`-wide group (and `min(n, tail)` in the ragged tail
    /// of `d_out % m` columns). Density is `n/m` by construction; the
    /// returned support carries the vectorizable fast-path layout.
    pub fn structured_nm(d_in: usize, d_out: usize, n: usize, m: usize, rng: &mut Rng) -> Self {
        assert!(n >= 1 && n <= m && m <= 256, "bad N:M pattern {n}:{m}");
        assert!(d_out > 0 && d_in > 0, "empty support shape");
        let full_groups = d_out / m;
        let tail_cols = d_out % m;
        let tail = n.min(tail_cols);
        let mut idx = Vec::with_capacity(d_in * (full_groups * n + tail));
        for i in 0..d_in {
            let row0 = (i * d_out) as u32;
            for g in 0..full_groups {
                let base = row0 + (g * m) as u32;
                for off in rng.sample_without_replacement(m as u64, n) {
                    idx.push(base + off as u32);
                }
            }
            if tail > 0 {
                let base = row0 + (full_groups * m) as u32;
                for off in rng.sample_without_replacement(tail_cols as u64, tail) {
                    idx.push(base + off as u32);
                }
            }
        }
        let mut sup = SparseSupport::new(d_in, d_out, idx);
        let ok = sup.structure_as_nm(n, m);
        debug_assert!(ok, "freshly generated N:M support must conform");
        sup
    }

    /// Attach the structured N:M fast-path layout if the support
    /// conforms (exactly `n` entries in every complete `m`-wide group
    /// and `min(n, d_out % m)` in the tail group, for every row).
    /// Returns whether it attached. A non-conforming support keeps the
    /// generic CSR kernels — results are identical either way, only
    /// speed differs; this is how checkpoint-reloaded supports regain
    /// the fast path.
    pub fn structure_as_nm(&mut self, n: usize, m: usize) -> bool {
        if n == 0 || m == 0 || n > m || m > 256 {
            return false;
        }
        let full_groups = self.d_out / m;
        let tail_cols = self.d_out % m;
        let tail = n.min(tail_cols);
        let per_row = full_groups * n + tail;
        if self.idx.len() != self.d_in * per_row {
            return false;
        }
        let mut offs = Vec::with_capacity(self.idx.len());
        for i in 0..self.d_in {
            if self.row_ptr[i] != i * per_row {
                return false;
            }
            for (e, k) in (self.row_ptr[i]..self.row_ptr[i] + per_row).enumerate() {
                let col = self.cols[k] as usize;
                // entry e of the row must live in group e/n (tail last)
                let want_g = if e < full_groups * n { e / n } else { full_groups };
                if col / m != want_g {
                    return false;
                }
                offs.push((col - want_g * m) as u8);
            }
        }
        self.nm = Some(NmLayout { n, m, offs, full_groups, tail });
        true
    }

    /// The structured pattern this support is laid out as, if any.
    pub fn nm_pattern(&self) -> Option<(usize, usize)> {
        self.nm.as_ref().map(|l| (l.n, l.m))
    }

    /// Uniform random support with `nnz = max(1, round(delta·d_in·d_out))`
    /// distinct entries — the paper's fixed-support strategy, mirroring
    /// `ref.random_support` on the python side.
    pub fn random(d_in: usize, d_out: usize, delta: f64, rng: &mut Rng) -> SparseSupport {
        let total = d_in * d_out;
        let nnz = ((delta * total as f64).round() as usize).clamp(1, total);
        let idx: Vec<u32> = rng
            .sample_without_replacement(total as u64, nnz)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        SparseSupport::new(d_in, d_out, idx)
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Bytes actually held by the fixed support: the flat u32 indices
    /// plus the derived CSR arrays (cols + row pointer) plus, for
    /// structured supports, the byte-sized in-group offsets. Counted by
    /// the backend's `mem_report` — supports are training state too.
    pub fn bytes(&self) -> u64 {
        (self.idx.len() * 4
            + self.cols.len() * 4
            + self.row_ptr.len() * std::mem::size_of::<usize>()
            + self.nm.as_ref().map_or(0, |l| l.offs.len())) as u64
    }

    /// Scatter-add the values into a dense [d_in, d_out] matrix (the ⊕).
    pub fn densify_into(&self, w: &mut Matrix, vals: &[f32]) {
        assert_eq!((w.rows, w.cols), (self.d_in, self.d_out));
        assert_eq!(vals.len(), self.nnz());
        w.scatter_add(&self.idx, vals);
    }

    /// Shared tail of the Algorithm-1 apply: scale the B@A product and
    /// scatter the sparse values onto it.
    fn scale_and_scatter(&self, mut w: Matrix, vals: &[f32], scale: f32) -> Matrix {
        if scale != 1.0 {
            for x in &mut w.data {
                *x *= scale;
            }
        }
        self.densify_into(&mut w, vals);
        w
    }

    /// Fused `scale·(B @ A) ⊕_idx vals` — the transient dense weight of
    /// Algorithm 1, built in one pass for consumers that want it
    /// materialized (inference, analysis, parity checks).
    pub fn fused_effective(&self, b: &Matrix, a: &Matrix, vals: &[f32], scale: f32) -> Matrix {
        assert_eq!(b.rows, self.d_in);
        assert_eq!(a.cols, self.d_out);
        self.scale_and_scatter(b.matmul(a), vals, scale)
    }

    /// One batch row of `y += x @ S` (shared by the serial and the
    /// row-partitioned parallel drivers; fixed accumulation order).
    fn spmm_row(&self, x_row: &[f32], vals: &[f32], y_row: &mut [f32]) {
        if let Some(nm) = &self.nm {
            return self.spmm_row_nm(nm, x_row, vals, y_row);
        }
        for i in 0..self.d_in {
            let xv = x_row[i];
            if xv == 0.0 {
                continue;
            }
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                y_row[self.cols[k] as usize] += xv * vals[k];
            }
        }
    }

    /// `spmm_row` on the structured-N:M layout: fixed-trip-count group
    /// loops, contiguous value blocks, byte offsets into an m-wide
    /// window — no per-entry u32 column gather. Entry order (ascending
    /// k) is identical to the generic path, so results are bitwise equal.
    /// On a detected SIMD path the uniform per-row entry count lets the
    /// inner loop run vectorized (`sparse_simd`), still bit-for-bit.
    fn spmm_row_nm(&self, nm: &NmLayout, x_row: &[f32], vals: &[f32], y_row: &mut [f32]) {
        let per_row = nm.per_row();
        let path = simd::active_path();
        if path != Path::Scalar {
            for i in 0..self.d_in {
                let xv = x_row[i];
                if xv == 0.0 {
                    continue;
                }
                let k = i * per_row;
                let kn = k + per_row;
                sparse_simd::spmm_row(path, xv, &self.cols[k..kn], &vals[k..kn], y_row);
            }
            return;
        }
        for i in 0..self.d_in {
            let xv = x_row[i];
            if xv == 0.0 {
                continue;
            }
            let mut k = i * per_row;
            for g in 0..nm.full_groups {
                let y_g = &mut y_row[g * nm.m..(g + 1) * nm.m];
                for e in 0..nm.n {
                    y_g[nm.offs[k + e] as usize] += xv * vals[k + e];
                }
                k += nm.n;
            }
            let base = nm.full_groups * nm.m;
            for e in 0..nm.tail {
                y_row[base + nm.offs[k + e] as usize] += xv * vals[k + e];
            }
        }
    }

    /// One batch row of `dx += dy @ S^T`.
    fn spmm_t_row(&self, dy_row: &[f32], vals: &[f32], dx_row: &mut [f32]) {
        if let Some(nm) = &self.nm {
            return self.spmm_t_row_nm(nm, dy_row, vals, dx_row);
        }
        for i in 0..self.d_in {
            let mut acc = 0.0f32;
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += dy_row[self.cols[k] as usize] * vals[k];
            }
            dx_row[i] += acc;
        }
    }

    /// `spmm_t_row` on the structured-N:M layout (same entry order as
    /// the generic path — bitwise-equal results, vectorizable loops).
    /// On a detected SIMD path the gathers + products vectorize while
    /// the accumulation chain stays scalar in entry order (`sparse_simd`).
    fn spmm_t_row_nm(&self, nm: &NmLayout, dy_row: &[f32], vals: &[f32], dx_row: &mut [f32]) {
        let per_row = nm.per_row();
        let path = simd::active_path();
        if path != Path::Scalar {
            for (i, dx) in dx_row.iter_mut().enumerate().take(self.d_in) {
                let k = i * per_row;
                let kn = k + per_row;
                *dx += sparse_simd::spmm_t_row(path, dy_row, &self.cols[k..kn], &vals[k..kn]);
            }
            return;
        }
        for (i, dx) in dx_row.iter_mut().enumerate().take(self.d_in) {
            let mut acc = 0.0f32;
            let mut k = i * per_row;
            for g in 0..nm.full_groups {
                let dy_g = &dy_row[g * nm.m..(g + 1) * nm.m];
                for e in 0..nm.n {
                    acc += dy_g[nm.offs[k + e] as usize] * vals[k + e];
                }
                k += nm.n;
            }
            let base = nm.full_groups * nm.m;
            for e in 0..nm.tail {
                acc += dy_row[base + nm.offs[k + e] as usize] * vals[k + e];
            }
            *dx += acc;
        }
    }

    /// `y += x @ S` for x [n, d_in]: the forward sparse contribution.
    /// CSR traversal — each nonzero touches one x column and one y column.
    pub fn spmm_add(&self, x: &Matrix, vals: &[f32], y: &mut Matrix) {
        assert_eq!(x.cols, self.d_in, "spmm x width");
        assert_eq!((y.rows, y.cols), (x.rows, self.d_out), "spmm y shape");
        assert_eq!(vals.len(), self.nnz());
        for n in 0..x.rows {
            let x_row = &x.data[n * self.d_in..(n + 1) * self.d_in];
            let y_row = &mut y.data[n * self.d_out..(n + 1) * self.d_out];
            self.spmm_row(x_row, vals, y_row);
        }
    }

    /// `spmm_add`, batch rows partitioned over the pool. Each y row is
    /// written by exactly one task, so results are bit-identical to the
    /// serial kernel at every thread count.
    pub fn spmm_add_par(&self, x: &Matrix, vals: &[f32], y: &mut Matrix, pool: &ThreadPool) {
        assert_eq!(x.cols, self.d_in, "spmm x width");
        assert_eq!((y.rows, y.cols), (x.rows, self.d_out), "spmm y shape");
        assert_eq!(vals.len(), self.nnz());
        let chunk_rows = parallel::chunk_len_for(pool, x.rows);
        parallel::par_chunks_mut(pool, &mut y.data, chunk_rows * self.d_out, |ci, ychunk| {
            let r0 = ci * chunk_rows;
            for rr in 0..ychunk.len() / self.d_out {
                let n = r0 + rr;
                let x_row = &x.data[n * self.d_in..(n + 1) * self.d_in];
                let y_row = &mut ychunk[rr * self.d_out..(rr + 1) * self.d_out];
                self.spmm_row(x_row, vals, y_row);
            }
        });
    }

    /// `y = x @ S` (fresh output).
    pub fn spmm(&self, x: &Matrix, vals: &[f32]) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.d_out);
        self.spmm_add(x, vals, &mut y);
        y
    }

    /// `dx += dy @ S^T` for dy [n, d_out]: the backward input gradient.
    pub fn spmm_t_add(&self, dy: &Matrix, vals: &[f32], dx: &mut Matrix) {
        assert_eq!(dy.cols, self.d_out, "spmm_t dy width");
        assert_eq!((dx.rows, dx.cols), (dy.rows, self.d_in), "spmm_t dx shape");
        assert_eq!(vals.len(), self.nnz());
        for n in 0..dy.rows {
            let dy_row = &dy.data[n * self.d_out..(n + 1) * self.d_out];
            let dx_row = &mut dx.data[n * self.d_in..(n + 1) * self.d_in];
            self.spmm_t_row(dy_row, vals, dx_row);
        }
    }

    /// `spmm_t_add`, batch rows partitioned over the pool
    /// (bit-identical to the serial kernel at every thread count).
    pub fn spmm_t_add_par(&self, dy: &Matrix, vals: &[f32], dx: &mut Matrix, pool: &ThreadPool) {
        assert_eq!(dy.cols, self.d_out, "spmm_t dy width");
        assert_eq!((dx.rows, dx.cols), (dy.rows, self.d_in), "spmm_t dx shape");
        assert_eq!(vals.len(), self.nnz());
        let chunk_rows = parallel::chunk_len_for(pool, dy.rows);
        parallel::par_chunks_mut(pool, &mut dx.data, chunk_rows * self.d_in, |ci, dxchunk| {
            let r0 = ci * chunk_rows;
            for rr in 0..dxchunk.len() / self.d_in {
                let n = r0 + rr;
                let dy_row = &dy.data[n * self.d_out..(n + 1) * self.d_out];
                let dx_row = &mut dxchunk[rr * self.d_in..(rr + 1) * self.d_in];
                self.spmm_t_row(dy_row, vals, dx_row);
            }
        });
    }

    /// `dy @ S^T` (fresh output).
    pub fn spmm_t(&self, dy: &Matrix, vals: &[f32]) -> Matrix {
        let mut dx = Matrix::zeros(dy.rows, self.d_in);
        self.spmm_t_add(dy, vals, &mut dx);
        dx
    }

    /// One support entry of eq. (2): `Σ_n x[n, row_k] · dy[n, col_k]`,
    /// accumulated in ascending n (fixed order). On the structured-N:M
    /// layout, (row, col) come from group arithmetic + the byte offset
    /// instead of the idx/cols gathers — same sum, same order.
    fn scatter_grad_at(&self, x: &Matrix, dy: &Matrix, k: usize) -> f32 {
        let (i, c) = match &self.nm {
            Some(nm) => {
                let per_row = nm.per_row();
                let e = k % per_row;
                let g = if e < nm.full_groups * nm.n { e / nm.n } else { nm.full_groups };
                (k / per_row, g * nm.m + nm.offs[k] as usize)
            }
            None => (self.idx[k] as usize / self.d_out, self.cols[k] as usize),
        };
        let mut acc = 0.0f32;
        for n in 0..x.rows {
            acc += x.data[n * self.d_in + i] * dy.data[n * self.d_out + c];
        }
        acc
    }

    /// Entries `k0 .. k0 + out.len()` of the eq.-(2) gradient. On a
    /// structured support with a detected SIMD path the range runs
    /// through the vectorized window kernel (one accumulator lane per
    /// entry, scalar per-entry chains — bitwise equal); otherwise it is
    /// the plain per-entry loop.
    fn scatter_grad_range(&self, x: &Matrix, dy: &Matrix, k0: usize, out: &mut [f32]) {
        if let Some(nm) = &self.nm {
            let path = simd::active_path();
            if path != Path::Scalar {
                sparse_simd::scatter_grad_range(path, x, dy, nm.per_row(), &self.cols, k0, out);
                return;
            }
        }
        for (kk, d) in out.iter_mut().enumerate() {
            *d = self.scatter_grad_at(x, dy, k0 + kk);
        }
    }

    /// Sparse value gradient of eq. (2): `dvals[k] = (x^T dy)[idx[k]]`
    /// computed as `Σ_n x[n, row_k] · dy[n, col_k]` — the dense d_in×d_out
    /// gradient is never formed.
    pub fn scatter_grad(&self, x: &Matrix, dy: &Matrix) -> Vec<f32> {
        assert_eq!(x.cols, self.d_in);
        assert_eq!(dy.cols, self.d_out);
        assert_eq!(x.rows, dy.rows);
        let mut dvals = vec![0.0f32; self.nnz()];
        self.scatter_grad_range(x, dy, 0, &mut dvals);
        dvals
    }

    /// `scatter_grad`, support entries partitioned over the pool. Every
    /// `dvals[k]` is computed wholly inside one task with the serial
    /// accumulation order, so results are bit-identical at every thread
    /// count.
    pub fn scatter_grad_par(&self, x: &Matrix, dy: &Matrix, pool: &ThreadPool) -> Vec<f32> {
        assert_eq!(x.cols, self.d_in);
        assert_eq!(dy.cols, self.d_out);
        assert_eq!(x.rows, dy.rows);
        let mut dvals = vec![0.0f32; self.nnz()];
        let chunk = parallel::chunk_len_for(pool, dvals.len());
        parallel::par_chunks_mut(pool, &mut dvals, chunk, |ci, dchunk| {
            self.scatter_grad_range(x, dy, ci * chunk, dchunk);
        });
        dvals
    }

    /// `fused_effective` with the B@A product spread over the pool (the
    /// Algorithm-1 apply for inference/analysis consumers).
    pub fn fused_effective_par(
        &self,
        b: &Matrix,
        a: &Matrix,
        vals: &[f32],
        scale: f32,
        pool: &ThreadPool,
    ) -> Matrix {
        assert_eq!(b.rows, self.d_in);
        assert_eq!(a.cols, self.d_out);
        self.scale_and_scatter(b.matmul_par(a, pool), vals, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(seed: u64, d_in: usize, d_out: usize, delta: f64) -> (SparseSupport, Vec<f32>, Rng) {
        let mut rng = Rng::new(seed);
        let sup = SparseSupport::random(d_in, d_out, delta, &mut rng);
        let vals: Vec<f32> = (0..sup.nnz()).map(|_| rng.gaussian() as f32).collect();
        (sup, vals, rng)
    }

    #[test]
    fn random_support_is_sorted_distinct_in_range() {
        let (sup, _, _) = fixture(0, 13, 9, 0.1);
        assert_eq!(sup.nnz(), (0.1f64 * 13.0 * 9.0).round() as usize);
        assert!(sup.idx.windows(2).all(|w| w[0] < w[1]));
        assert!(sup.idx.iter().all(|&i| (i as usize) < 13 * 9));
    }

    #[test]
    fn csr_rows_partition_the_support() {
        let (sup, _, _) = fixture(1, 7, 11, 0.2);
        let mut count = 0;
        for i in 0..sup.d_in {
            for k in sup.row_ptr[i]..sup.row_ptr[i + 1] {
                assert_eq!(sup.idx[k] as usize / sup.d_out, i);
                count += 1;
            }
        }
        assert_eq!(count, sup.nnz());
    }

    #[test]
    fn spmm_matches_densify_then_matmul() {
        let (sup, vals, mut rng) = fixture(2, 10, 6, 0.15);
        let x = Matrix::random(4, 10, &mut rng);
        let mut dense = Matrix::zeros(10, 6);
        sup.densify_into(&mut dense, &vals);
        let want = x.matmul(&dense);
        let got = sup.spmm(&x, &vals);
        assert!(want.sub(&got).max_abs() < 1e-5);
    }

    #[test]
    fn spmm_t_matches_dense_transpose() {
        let (sup, vals, mut rng) = fixture(3, 8, 12, 0.1);
        let dy = Matrix::random(5, 12, &mut rng);
        let mut dense = Matrix::zeros(8, 12);
        sup.densify_into(&mut dense, &vals);
        let want = dy.matmul_transb(&dense);
        let got = sup.spmm_t(&dy, &vals);
        assert!(want.sub(&got).max_abs() < 1e-5);
    }

    #[test]
    fn scatter_grad_matches_dense_gather() {
        let (sup, _, mut rng) = fixture(4, 9, 7, 0.2);
        let x = Matrix::random(6, 9, &mut rng);
        let dy = Matrix::random(6, 7, &mut rng);
        let dense = x.transpose().matmul(&dy);
        let got = sup.scatter_grad(&x, &dy);
        for (k, &i) in sup.idx.iter().enumerate() {
            let want = dense.data[i as usize];
            assert!((got[k] - want).abs() < 1e-4, "nnz {k}: {} vs {want}", got[k]);
        }
    }

    #[test]
    fn parallel_sparse_kernels_bitwise_match_serial() {
        let (sup, vals, mut rng) = fixture(6, 12, 9, 0.15);
        let pool = ThreadPool::new(3);
        let x = Matrix::random(7, 12, &mut rng);
        let dy = Matrix::random(7, 9, &mut rng);

        let mut y_s = Matrix::zeros(7, 9);
        sup.spmm_add(&x, &vals, &mut y_s);
        let mut y_p = Matrix::zeros(7, 9);
        sup.spmm_add_par(&x, &vals, &mut y_p, &pool);
        assert_eq!(y_s.data, y_p.data, "spmm");

        let mut dx_s = Matrix::zeros(7, 12);
        sup.spmm_t_add(&dy, &vals, &mut dx_s);
        let mut dx_p = Matrix::zeros(7, 12);
        sup.spmm_t_add_par(&dy, &vals, &mut dx_p, &pool);
        assert_eq!(dx_s.data, dx_p.data, "spmm_t");

        assert_eq!(sup.scatter_grad(&x, &dy), sup.scatter_grad_par(&x, &dy, &pool), "scatter");

        let b = Matrix::random(12, 3, &mut rng);
        let a = Matrix::random(3, 9, &mut rng);
        assert_eq!(
            sup.fused_effective(&b, &a, &vals, 2.0).data,
            sup.fused_effective_par(&b, &a, &vals, 2.0, &pool).data,
            "fused"
        );
    }

    #[test]
    fn support_pattern_parses_and_labels() {
        assert_eq!(SupportPattern::parse("random").unwrap(), SupportPattern::UniformRandom);
        assert_eq!(SupportPattern::parse("").unwrap(), SupportPattern::UniformRandom);
        assert_eq!(
            SupportPattern::parse("2:4").unwrap(),
            SupportPattern::StructuredNM { n: 2, m: 4 }
        );
        assert_eq!(
            SupportPattern::parse(" 1:32 ").unwrap(),
            SupportPattern::StructuredNM { n: 1, m: 32 }
        );
        assert_eq!(SupportPattern::parse("2:4").unwrap().label(), "2:4");
        assert_eq!(SupportPattern::parse("random").unwrap().label(), "random");
        assert_eq!(SupportPattern::parse("2:4").unwrap().density(), Some(0.5));
        assert!(SupportPattern::parse("4:2").is_err());
        assert!(SupportPattern::parse("0:4").is_err());
        assert!(SupportPattern::parse("2:999").is_err());
        assert!(SupportPattern::parse("dense").is_err());
    }

    #[test]
    fn structured_nm_support_conforms() {
        let mut rng = Rng::new(11);
        // d_out = 10 exercises the ragged tail group (10 % 4 = 2)
        for (d_in, d_out, n, m) in [(7, 12, 2, 4), (5, 10, 2, 4), (6, 9, 1, 3), (4, 16, 3, 8)] {
            let sup = SparseSupport::structured_nm(d_in, d_out, n, m, &mut rng);
            assert_eq!(sup.nm_pattern(), Some((n, m)));
            assert!(sup.idx.windows(2).all(|w| w[0] < w[1]), "sorted-distinct");
            let full_groups = d_out / m;
            let tail = n.min(d_out % m);
            assert_eq!(sup.nnz(), d_in * (full_groups * n + tail), "{n}:{m} on {d_in}x{d_out}");
            // count entries per (row, group)
            for i in 0..d_in {
                let mut per_group = vec![0usize; full_groups + 1];
                for k in sup.row_ptr[i]..sup.row_ptr[i + 1] {
                    per_group[sup.cols[k] as usize / m] += 1;
                }
                for (g, &c) in per_group.iter().enumerate() {
                    let want = if g < full_groups { n } else { tail };
                    assert_eq!(c, want, "row {i} group {g}");
                }
            }
        }
    }

    #[test]
    fn structure_as_nm_rejects_nonconforming_supports() {
        let mut rng = Rng::new(12);
        let mut sup = SparseSupport::random(9, 16, 0.5, &mut rng);
        assert!(!sup.structure_as_nm(2, 4), "random support should not conform");
        assert_eq!(sup.nm_pattern(), None);
        // a conforming support reloaded through the flat-idx interchange
        // format regains the fast path
        let orig = SparseSupport::structured_nm(9, 16, 2, 4, &mut rng);
        let mut reloaded = SparseSupport::new(9, 16, orig.idx.clone());
        assert_eq!(reloaded.nm_pattern(), None);
        assert!(reloaded.structure_as_nm(2, 4));
        assert_eq!(reloaded.nm_pattern(), Some((2, 4)));
    }

    #[test]
    fn nm_kernels_bitwise_match_generic_csr() {
        // the structured fast path must agree bit for bit with the
        // generic CSR kernels on the same support, serially and at
        // 1/2/4 threads — for spmm, spmm_t and scatter_grad
        let mut rng = Rng::new(13);
        for (d_in, d_out, n, m) in [(12, 16, 2, 4), (9, 10, 2, 4), (8, 9, 1, 3), (6, 24, 3, 8)] {
            let fast = SparseSupport::structured_nm(d_in, d_out, n, m, &mut rng);
            // same support, forced onto the generic path
            let generic = SparseSupport::new(d_in, d_out, fast.idx.clone());
            let vals: Vec<f32> = (0..fast.nnz()).map(|_| rng.gaussian() as f32).collect();
            let x = Matrix::random(7, d_in, &mut rng);
            let dy = Matrix::random(7, d_out, &mut rng);

            assert_eq!(fast.spmm(&x, &vals).data, generic.spmm(&x, &vals).data, "spmm {n}:{m}");
            assert_eq!(
                fast.spmm_t(&dy, &vals).data,
                generic.spmm_t(&dy, &vals).data,
                "spmm_t {n}:{m}"
            );
            assert_eq!(
                fast.scatter_grad(&x, &dy),
                generic.scatter_grad(&x, &dy),
                "scatter_grad {n}:{m}"
            );
            for threads in [1usize, 2, 4] {
                let pool = ThreadPool::new(threads);
                let mut y_f = Matrix::zeros(7, d_out);
                fast.spmm_add_par(&x, &vals, &mut y_f, &pool);
                let mut y_g = Matrix::zeros(7, d_out);
                generic.spmm_add_par(&x, &vals, &mut y_g, &pool);
                assert_eq!(y_f.data, y_g.data, "spmm {n}:{m} @{threads}t");

                let mut dx_f = Matrix::zeros(7, d_in);
                fast.spmm_t_add_par(&dy, &vals, &mut dx_f, &pool);
                let mut dx_g = Matrix::zeros(7, d_in);
                generic.spmm_t_add_par(&dy, &vals, &mut dx_g, &pool);
                assert_eq!(dx_f.data, dx_g.data, "spmm_t {n}:{m} @{threads}t");

                assert_eq!(
                    fast.scatter_grad_par(&x, &dy, &pool),
                    generic.scatter_grad_par(&x, &dy, &pool),
                    "scatter_grad {n}:{m} @{threads}t"
                );
            }
        }
    }

    #[test]
    fn nm_support_counts_offs_in_bytes() {
        let mut rng = Rng::new(14);
        let fast = SparseSupport::structured_nm(8, 16, 2, 4, &mut rng);
        let generic = SparseSupport::new(8, 16, fast.idx.clone());
        assert_eq!(fast.bytes(), generic.bytes() + fast.nnz() as u64);
    }

    #[test]
    fn fused_effective_matches_parts() {
        let (sup, vals, mut rng) = fixture(5, 10, 8, 0.1);
        let b = Matrix::random(10, 3, &mut rng);
        let a = Matrix::random(3, 8, &mut rng);
        let scale = 1.75f32;
        let w = sup.fused_effective(&b, &a, &vals, scale);
        let mut want = b.matmul(&a).scale(scale);
        sup.densify_into(&mut want, &vals);
        assert!(w.sub(&want).max_abs() < 1e-5);
    }
}

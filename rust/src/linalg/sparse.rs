//! Fixed-support sparse kernels for the native SLTrain backend.
//!
//! SLTrain's sparse factor S never changes support: `idx` is chosen once
//! at init (paper §3.2) and only the values are learned. That makes the
//! support a build-once structure — we keep the paper's flat row-major
//! COO indices (the interchange format of the artifact sidecars and
//! checkpoints) and derive a CSR row partition from them once, so the
//! per-step kernels are straight loops with no searching:
//!
//!   * `spmm`          y  += x @ S        (forward sparse contribution)
//!   * `spmm_t`        dx += dy @ S^T     (backward input gradient)
//!   * `scatter_grad`  dvals = (x^T dy) gathered at the support — the
//!                     paper's eq. (2) sparse gradient, never
//!                     materializing the dense d_in × d_out matrix
//!   * `fused_effective`  W = scale·(B@A) ⊕_idx vals  (Algorithm 1 line 4)

use super::parallel::{self, ThreadPool};
use super::Matrix;
use crate::util::rng::Rng;

/// A fixed sparse support over a `d_in × d_out` matrix: sorted flat
/// row-major COO indices plus the derived CSR row partition.
#[derive(Debug, Clone)]
pub struct SparseSupport {
    pub d_in: usize,
    pub d_out: usize,
    /// Flat row-major indices, sorted ascending, distinct.
    pub idx: Vec<u32>,
    /// Column of each nonzero (idx % d_out), aligned with `idx`.
    cols: Vec<u32>,
    /// CSR row pointer: nonzeros of row i live in `row_ptr[i]..row_ptr[i+1]`.
    row_ptr: Vec<usize>,
}

impl SparseSupport {
    /// Build from sorted-distinct flat indices (the sidecar/checkpoint
    /// format). Panics on out-of-range or unsorted input.
    pub fn new(d_in: usize, d_out: usize, idx: Vec<u32>) -> SparseSupport {
        assert!(d_out > 0 && d_in > 0, "empty support shape");
        let bound = (d_in * d_out) as u32;
        assert!(idx.iter().all(|&i| i < bound), "support index out of range");
        assert!(idx.windows(2).all(|w| w[0] < w[1]), "support not sorted-distinct");
        let cols: Vec<u32> = idx.iter().map(|&i| i % d_out as u32).collect();
        let mut row_ptr = vec![0usize; d_in + 1];
        for &i in &idx {
            row_ptr[i as usize / d_out + 1] += 1;
        }
        for r in 0..d_in {
            row_ptr[r + 1] += row_ptr[r];
        }
        SparseSupport { d_in, d_out, idx, cols, row_ptr }
    }

    /// Uniform random support with `nnz = max(1, round(delta·d_in·d_out))`
    /// distinct entries — the paper's fixed-support strategy, mirroring
    /// `ref.random_support` on the python side.
    pub fn random(d_in: usize, d_out: usize, delta: f64, rng: &mut Rng) -> SparseSupport {
        let total = d_in * d_out;
        let nnz = ((delta * total as f64).round() as usize).clamp(1, total);
        let idx: Vec<u32> = rng
            .sample_without_replacement(total as u64, nnz)
            .into_iter()
            .map(|i| i as u32)
            .collect();
        SparseSupport::new(d_in, d_out, idx)
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Bytes actually held by the fixed support: the flat u32 indices
    /// plus the derived CSR arrays (cols + row pointer). Counted by the
    /// backend's `mem_report` — supports are training state too.
    pub fn bytes(&self) -> u64 {
        (self.idx.len() * 4
            + self.cols.len() * 4
            + self.row_ptr.len() * std::mem::size_of::<usize>()) as u64
    }

    /// Scatter-add the values into a dense [d_in, d_out] matrix (the ⊕).
    pub fn densify_into(&self, w: &mut Matrix, vals: &[f32]) {
        assert_eq!((w.rows, w.cols), (self.d_in, self.d_out));
        assert_eq!(vals.len(), self.nnz());
        w.scatter_add(&self.idx, vals);
    }

    /// Shared tail of the Algorithm-1 apply: scale the B@A product and
    /// scatter the sparse values onto it.
    fn scale_and_scatter(&self, mut w: Matrix, vals: &[f32], scale: f32) -> Matrix {
        if scale != 1.0 {
            for x in &mut w.data {
                *x *= scale;
            }
        }
        self.densify_into(&mut w, vals);
        w
    }

    /// Fused `scale·(B @ A) ⊕_idx vals` — the transient dense weight of
    /// Algorithm 1, built in one pass for consumers that want it
    /// materialized (inference, analysis, parity checks).
    pub fn fused_effective(&self, b: &Matrix, a: &Matrix, vals: &[f32], scale: f32) -> Matrix {
        assert_eq!(b.rows, self.d_in);
        assert_eq!(a.cols, self.d_out);
        self.scale_and_scatter(b.matmul(a), vals, scale)
    }

    /// One batch row of `y += x @ S` (shared by the serial and the
    /// row-partitioned parallel drivers; fixed accumulation order).
    fn spmm_row(&self, x_row: &[f32], vals: &[f32], y_row: &mut [f32]) {
        for i in 0..self.d_in {
            let xv = x_row[i];
            if xv == 0.0 {
                continue;
            }
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                y_row[self.cols[k] as usize] += xv * vals[k];
            }
        }
    }

    /// One batch row of `dx += dy @ S^T`.
    fn spmm_t_row(&self, dy_row: &[f32], vals: &[f32], dx_row: &mut [f32]) {
        for i in 0..self.d_in {
            let mut acc = 0.0f32;
            for k in self.row_ptr[i]..self.row_ptr[i + 1] {
                acc += dy_row[self.cols[k] as usize] * vals[k];
            }
            dx_row[i] += acc;
        }
    }

    /// `y += x @ S` for x [n, d_in]: the forward sparse contribution.
    /// CSR traversal — each nonzero touches one x column and one y column.
    pub fn spmm_add(&self, x: &Matrix, vals: &[f32], y: &mut Matrix) {
        assert_eq!(x.cols, self.d_in, "spmm x width");
        assert_eq!((y.rows, y.cols), (x.rows, self.d_out), "spmm y shape");
        assert_eq!(vals.len(), self.nnz());
        for n in 0..x.rows {
            let x_row = &x.data[n * self.d_in..(n + 1) * self.d_in];
            let y_row = &mut y.data[n * self.d_out..(n + 1) * self.d_out];
            self.spmm_row(x_row, vals, y_row);
        }
    }

    /// `spmm_add`, batch rows partitioned over the pool. Each y row is
    /// written by exactly one task, so results are bit-identical to the
    /// serial kernel at every thread count.
    pub fn spmm_add_par(&self, x: &Matrix, vals: &[f32], y: &mut Matrix, pool: &ThreadPool) {
        assert_eq!(x.cols, self.d_in, "spmm x width");
        assert_eq!((y.rows, y.cols), (x.rows, self.d_out), "spmm y shape");
        assert_eq!(vals.len(), self.nnz());
        let chunk_rows = parallel::chunk_len_for(pool, x.rows);
        parallel::par_chunks_mut(pool, &mut y.data, chunk_rows * self.d_out, |ci, ychunk| {
            let r0 = ci * chunk_rows;
            for rr in 0..ychunk.len() / self.d_out {
                let n = r0 + rr;
                let x_row = &x.data[n * self.d_in..(n + 1) * self.d_in];
                let y_row = &mut ychunk[rr * self.d_out..(rr + 1) * self.d_out];
                self.spmm_row(x_row, vals, y_row);
            }
        });
    }

    /// `y = x @ S` (fresh output).
    pub fn spmm(&self, x: &Matrix, vals: &[f32]) -> Matrix {
        let mut y = Matrix::zeros(x.rows, self.d_out);
        self.spmm_add(x, vals, &mut y);
        y
    }

    /// `dx += dy @ S^T` for dy [n, d_out]: the backward input gradient.
    pub fn spmm_t_add(&self, dy: &Matrix, vals: &[f32], dx: &mut Matrix) {
        assert_eq!(dy.cols, self.d_out, "spmm_t dy width");
        assert_eq!((dx.rows, dx.cols), (dy.rows, self.d_in), "spmm_t dx shape");
        assert_eq!(vals.len(), self.nnz());
        for n in 0..dy.rows {
            let dy_row = &dy.data[n * self.d_out..(n + 1) * self.d_out];
            let dx_row = &mut dx.data[n * self.d_in..(n + 1) * self.d_in];
            self.spmm_t_row(dy_row, vals, dx_row);
        }
    }

    /// `spmm_t_add`, batch rows partitioned over the pool
    /// (bit-identical to the serial kernel at every thread count).
    pub fn spmm_t_add_par(&self, dy: &Matrix, vals: &[f32], dx: &mut Matrix, pool: &ThreadPool) {
        assert_eq!(dy.cols, self.d_out, "spmm_t dy width");
        assert_eq!((dx.rows, dx.cols), (dy.rows, self.d_in), "spmm_t dx shape");
        assert_eq!(vals.len(), self.nnz());
        let chunk_rows = parallel::chunk_len_for(pool, dy.rows);
        parallel::par_chunks_mut(pool, &mut dx.data, chunk_rows * self.d_in, |ci, dxchunk| {
            let r0 = ci * chunk_rows;
            for rr in 0..dxchunk.len() / self.d_in {
                let n = r0 + rr;
                let dy_row = &dy.data[n * self.d_out..(n + 1) * self.d_out];
                let dx_row = &mut dxchunk[rr * self.d_in..(rr + 1) * self.d_in];
                self.spmm_t_row(dy_row, vals, dx_row);
            }
        });
    }

    /// `dy @ S^T` (fresh output).
    pub fn spmm_t(&self, dy: &Matrix, vals: &[f32]) -> Matrix {
        let mut dx = Matrix::zeros(dy.rows, self.d_in);
        self.spmm_t_add(dy, vals, &mut dx);
        dx
    }

    /// One support entry of eq. (2): `Σ_n x[n, row_k] · dy[n, col_k]`,
    /// accumulated in ascending n (fixed order).
    fn scatter_grad_at(&self, x: &Matrix, dy: &Matrix, k: usize) -> f32 {
        let i = self.idx[k] as usize / self.d_out;
        let c = self.cols[k] as usize;
        let mut acc = 0.0f32;
        for n in 0..x.rows {
            acc += x.data[n * self.d_in + i] * dy.data[n * self.d_out + c];
        }
        acc
    }

    /// Sparse value gradient of eq. (2): `dvals[k] = (x^T dy)[idx[k]]`
    /// computed as `Σ_n x[n, row_k] · dy[n, col_k]` — the dense d_in×d_out
    /// gradient is never formed.
    pub fn scatter_grad(&self, x: &Matrix, dy: &Matrix) -> Vec<f32> {
        assert_eq!(x.cols, self.d_in);
        assert_eq!(dy.cols, self.d_out);
        assert_eq!(x.rows, dy.rows);
        (0..self.nnz()).map(|k| self.scatter_grad_at(x, dy, k)).collect()
    }

    /// `scatter_grad`, support entries partitioned over the pool. Every
    /// `dvals[k]` is computed wholly inside one task with the serial
    /// accumulation order, so results are bit-identical at every thread
    /// count.
    pub fn scatter_grad_par(&self, x: &Matrix, dy: &Matrix, pool: &ThreadPool) -> Vec<f32> {
        assert_eq!(x.cols, self.d_in);
        assert_eq!(dy.cols, self.d_out);
        assert_eq!(x.rows, dy.rows);
        let mut dvals = vec![0.0f32; self.nnz()];
        let chunk = parallel::chunk_len_for(pool, dvals.len());
        parallel::par_chunks_mut(pool, &mut dvals, chunk, |ci, dchunk| {
            let k0 = ci * chunk;
            for (kk, d) in dchunk.iter_mut().enumerate() {
                *d = self.scatter_grad_at(x, dy, k0 + kk);
            }
        });
        dvals
    }

    /// `fused_effective` with the B@A product spread over the pool (the
    /// Algorithm-1 apply for inference/analysis consumers).
    pub fn fused_effective_par(
        &self,
        b: &Matrix,
        a: &Matrix,
        vals: &[f32],
        scale: f32,
        pool: &ThreadPool,
    ) -> Matrix {
        assert_eq!(b.rows, self.d_in);
        assert_eq!(a.cols, self.d_out);
        self.scale_and_scatter(b.matmul_par(a, pool), vals, scale)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fixture(seed: u64, d_in: usize, d_out: usize, delta: f64) -> (SparseSupport, Vec<f32>, Rng) {
        let mut rng = Rng::new(seed);
        let sup = SparseSupport::random(d_in, d_out, delta, &mut rng);
        let vals: Vec<f32> = (0..sup.nnz()).map(|_| rng.gaussian() as f32).collect();
        (sup, vals, rng)
    }

    #[test]
    fn random_support_is_sorted_distinct_in_range() {
        let (sup, _, _) = fixture(0, 13, 9, 0.1);
        assert_eq!(sup.nnz(), (0.1f64 * 13.0 * 9.0).round() as usize);
        assert!(sup.idx.windows(2).all(|w| w[0] < w[1]));
        assert!(sup.idx.iter().all(|&i| (i as usize) < 13 * 9));
    }

    #[test]
    fn csr_rows_partition_the_support() {
        let (sup, _, _) = fixture(1, 7, 11, 0.2);
        let mut count = 0;
        for i in 0..sup.d_in {
            for k in sup.row_ptr[i]..sup.row_ptr[i + 1] {
                assert_eq!(sup.idx[k] as usize / sup.d_out, i);
                count += 1;
            }
        }
        assert_eq!(count, sup.nnz());
    }

    #[test]
    fn spmm_matches_densify_then_matmul() {
        let (sup, vals, mut rng) = fixture(2, 10, 6, 0.15);
        let x = Matrix::random(4, 10, &mut rng);
        let mut dense = Matrix::zeros(10, 6);
        sup.densify_into(&mut dense, &vals);
        let want = x.matmul(&dense);
        let got = sup.spmm(&x, &vals);
        assert!(want.sub(&got).max_abs() < 1e-5);
    }

    #[test]
    fn spmm_t_matches_dense_transpose() {
        let (sup, vals, mut rng) = fixture(3, 8, 12, 0.1);
        let dy = Matrix::random(5, 12, &mut rng);
        let mut dense = Matrix::zeros(8, 12);
        sup.densify_into(&mut dense, &vals);
        let want = dy.matmul_transb(&dense);
        let got = sup.spmm_t(&dy, &vals);
        assert!(want.sub(&got).max_abs() < 1e-5);
    }

    #[test]
    fn scatter_grad_matches_dense_gather() {
        let (sup, _, mut rng) = fixture(4, 9, 7, 0.2);
        let x = Matrix::random(6, 9, &mut rng);
        let dy = Matrix::random(6, 7, &mut rng);
        let dense = x.transpose().matmul(&dy);
        let got = sup.scatter_grad(&x, &dy);
        for (k, &i) in sup.idx.iter().enumerate() {
            let want = dense.data[i as usize];
            assert!((got[k] - want).abs() < 1e-4, "nnz {k}: {} vs {want}", got[k]);
        }
    }

    #[test]
    fn parallel_sparse_kernels_bitwise_match_serial() {
        let (sup, vals, mut rng) = fixture(6, 12, 9, 0.15);
        let pool = ThreadPool::new(3);
        let x = Matrix::random(7, 12, &mut rng);
        let dy = Matrix::random(7, 9, &mut rng);

        let mut y_s = Matrix::zeros(7, 9);
        sup.spmm_add(&x, &vals, &mut y_s);
        let mut y_p = Matrix::zeros(7, 9);
        sup.spmm_add_par(&x, &vals, &mut y_p, &pool);
        assert_eq!(y_s.data, y_p.data, "spmm");

        let mut dx_s = Matrix::zeros(7, 12);
        sup.spmm_t_add(&dy, &vals, &mut dx_s);
        let mut dx_p = Matrix::zeros(7, 12);
        sup.spmm_t_add_par(&dy, &vals, &mut dx_p, &pool);
        assert_eq!(dx_s.data, dx_p.data, "spmm_t");

        assert_eq!(sup.scatter_grad(&x, &dy), sup.scatter_grad_par(&x, &dy, &pool), "scatter");

        let b = Matrix::random(12, 3, &mut rng);
        let a = Matrix::random(3, 9, &mut rng);
        assert_eq!(
            sup.fused_effective(&b, &a, &vals, 2.0).data,
            sup.fused_effective_par(&b, &a, &vals, 2.0, &pool).data,
            "fused"
        );
    }

    #[test]
    fn fused_effective_matches_parts() {
        let (sup, vals, mut rng) = fixture(5, 10, 8, 0.1);
        let b = Matrix::random(10, 3, &mut rng);
        let a = Matrix::random(3, 8, &mut rng);
        let scale = 1.75f32;
        let w = sup.fused_effective(&b, &a, &vals, scale);
        let mut want = b.matmul(&a).scale(scale);
        sup.densify_into(&mut want, &vals);
        assert!(w.sub(&want).max_abs() < 1e-5);
    }
}

//! Analysis tooling for the paper's Figures 2/5–11 and Proposition 1.
//!
//! Works off checkpoints (trained weights) or synthetic matrices, using
//! the in-repo Jacobi SVD — no Python anywhere.

pub mod prop1;
pub mod residual;
pub mod spectrum;

pub use prop1::full_rank_probability;
pub use residual::ResidualReport;
pub use spectrum::SpectrumDecomp;

//! Fig-2 analysis: singular-value decay of a trained weight matrix, the
//! residual after removing the best rank-r approximation, and the
//! cumulative magnitude distribution of that residual.
//!
//! The paper's Figure 2(c) finding — 97% of residual entries below 0.04
//! after removing rank-128 from LLaMA-60M attention weights — is the
//! empirical case for a *random-support* sparse factor; this module
//! regenerates that evidence from our own pretrained checkpoints.

use crate::linalg::{svd, Matrix};

#[derive(Debug, Clone)]
pub struct ResidualReport {
    pub rows: usize,
    pub cols: usize,
    pub rank_cut: usize,
    /// all singular values, descending (Fig 2a)
    pub singular_values: Vec<f32>,
    /// residual magnitude stats after removing rank-r (Fig 2b)
    pub resid_max: f32,
    pub resid_mean_abs: f32,
    pub resid_frob: f32,
    pub orig_frob: f32,
    /// (threshold, fraction of |entries| <= threshold) — Fig 2c CDF
    pub cdf: Vec<(f32, f32)>,
    /// fraction of residual entries with magnitude <= cdf97_threshold
    pub p97_threshold: f32,
}

impl ResidualReport {
    pub fn compute(w: &Matrix, rank_cut: usize) -> ResidualReport {
        let f = svd(w);
        let low = w.truncate_rank(rank_cut);
        let resid = w.sub(&low);

        let mut mags: Vec<f32> = resid.data.iter().map(|x| x.abs()).collect();
        mags.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = mags.len().max(1);
        let q = |p: f64| mags[(p * (n - 1) as f64).round() as usize];
        let cdf: Vec<(f32, f32)> = (0..=20)
            .map(|i| {
                let p = i as f64 / 20.0;
                (q(p), p as f32)
            })
            .collect();

        ResidualReport {
            rows: w.rows,
            cols: w.cols,
            rank_cut,
            singular_values: f.s,
            resid_max: mags.last().copied().unwrap_or(0.0),
            resid_mean_abs: mags.iter().sum::<f32>() / n as f32,
            resid_frob: resid.frob_norm(),
            orig_frob: w.frob_norm(),
            cdf,
            p97_threshold: q(0.97),
        }
    }

    /// Fraction of spectral energy captured by the top-r subspace.
    pub fn energy_in_top(&self) -> f32 {
        let total: f32 = self.singular_values.iter().map(|s| s * s).sum();
        let top: f32 = self.singular_values[..self.rank_cut.min(self.singular_values.len())]
            .iter()
            .map(|s| s * s)
            .sum();
        if total > 0.0 {
            top / total
        } else {
            0.0
        }
    }

    pub fn print(&self, name: &str) {
        println!(
            "{name}: [{}x{}] rank-cut {} | top-r energy {:.1}% | resid max {:.4} mean|.| {:.5} | p97 |resid| <= {:.4}",
            self.rows,
            self.cols,
            self.rank_cut,
            100.0 * self.energy_in_top(),
            self.resid_max,
            self.resid_mean_abs,
            self.p97_threshold,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// A matrix with the paper's structure: strong low-rank head + small
    /// dense residual.
    fn structured(rng: &mut Rng, d: usize, r: usize) -> Matrix {
        let b = Matrix::random(d, r, rng).scale(1.0);
        let a = Matrix::random(r, d, rng);
        let noise = Matrix::random(d, d, rng).scale(0.02);
        b.matmul(&a).add(&noise)
    }

    #[test]
    fn detects_lowrank_plus_small_residual() {
        let mut rng = Rng::new(0);
        let w = structured(&mut rng, 40, 4);
        let rep = ResidualReport::compute(&w, 4);
        assert!(rep.energy_in_top() > 0.95, "energy {}", rep.energy_in_top());
        // residual entries should be tiny relative to the original
        assert!(rep.resid_frob < 0.2 * rep.orig_frob);
        assert!(rep.p97_threshold < rep.resid_max + 1e-6);
    }

    #[test]
    fn cdf_is_monotone_and_normalized() {
        let mut rng = Rng::new(1);
        let w = Matrix::random(20, 30, &mut rng);
        let rep = ResidualReport::compute(&w, 5);
        assert_eq!(rep.cdf.first().unwrap().1, 0.0);
        assert_eq!(rep.cdf.last().unwrap().1, 1.0);
        assert!(rep.cdf.windows(2).all(|w| w[0].0 <= w[1].0 && w[0].1 <= w[1].1));
    }

    #[test]
    fn full_rank_cut_leaves_zero_residual() {
        let mut rng = Rng::new(2);
        let w = Matrix::random(12, 12, &mut rng);
        let rep = ResidualReport::compute(&w, 12);
        assert!(rep.resid_frob < 1e-3, "resid {}", rep.resid_frob);
    }
}

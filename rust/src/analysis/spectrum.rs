//! Fig-10/11 analysis: how the low-rank (BA) and sparse (S) components
//! of a trained SLTrain weight share the singular spectrum.
//!
//! Following Appendix D: with UΣVᵀ = BA + S, plot diag(Σ),
//! diag(Uᵀ(BA)V) and diag(UᵀSV). The paper's finding — L owns the head,
//! S owns the tail — is the justification for the hybrid parameterization.

use crate::linalg::{svd, Matrix};

#[derive(Debug, Clone)]
pub struct SpectrumDecomp {
    /// singular values of W = scale*BA + S (descending)
    pub sigma: Vec<f32>,
    /// diag(Uᵀ (scale*BA) V) — low-rank contribution per singular direction
    pub lowrank_contrib: Vec<f32>,
    /// diag(Uᵀ S V) — sparse contribution per singular direction
    pub sparse_contrib: Vec<f32>,
    pub rank: usize,
}

impl SpectrumDecomp {
    pub fn compute(
        b: &Matrix,
        a: &Matrix,
        idx: &[u32],
        vals: &[f32],
        scale: f32,
    ) -> SpectrumDecomp {
        let d = b.rows;
        let p = a.cols;
        let ba = b.matmul(a).scale(scale);
        let mut s_mat = Matrix::zeros(d, p);
        s_mat.scatter_add(idx, vals);
        let w = ba.add(&s_mat);
        let f = svd(&w);
        let k = f.s.len();

        // diag(Uᵀ M V) = column-wise u_iᵀ M v_i
        let diag_of = |m: &Matrix| -> Vec<f32> {
            let mv = m.matmul(&f.vt.transpose()); // [d, k]
            (0..k)
                .map(|i| (0..d).map(|r| f.u[(r, i)] * mv[(r, i)]).sum())
                .collect()
        };

        SpectrumDecomp {
            sigma: f.s,
            lowrank_contrib: diag_of(&ba),
            sparse_contrib: diag_of(&s_mat),
            rank: b.cols,
        }
    }

    /// Head/tail attribution: mean |contribution| of each component over
    /// the top-r directions vs the remaining tail.
    pub fn head_tail_split(&self) -> (f32, f32, f32, f32) {
        let r = self.rank.min(self.sigma.len());
        let mean_abs = |xs: &[f32]| {
            if xs.is_empty() {
                0.0
            } else {
                xs.iter().map(|x| x.abs()).sum::<f32>() / xs.len() as f32
            }
        };
        (
            mean_abs(&self.lowrank_contrib[..r]),
            mean_abs(&self.lowrank_contrib[r..]),
            mean_abs(&self.sparse_contrib[..r]),
            mean_abs(&self.sparse_contrib[r..]),
        )
    }

    pub fn print(&self, name: &str) {
        let (lh, lt, sh, st) = self.head_tail_split();
        println!(
            "{name}: sigma[0]={:.4} sigma[r]={:.4} | L head/tail {:.4}/{:.4} | S head/tail {:.4}/{:.4}",
            self.sigma.first().copied().unwrap_or(0.0),
            self.sigma.get(self.rank).copied().unwrap_or(0.0),
            lh, lt, sh, st
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn setup(d: usize, r: usize, delta: f64) -> (Matrix, Matrix, Vec<u32>, Vec<f32>) {
        let mut rng = Rng::new(3);
        let b = Matrix::random(d, r, &mut rng);
        let a = Matrix::random(r, d, &mut rng).scale(0.5);
        let nnz = (delta * (d * d) as f64) as usize;
        let idx: Vec<u32> = rng
            .sample_without_replacement((d * d) as u64, nnz)
            .into_iter()
            .map(|x| x as u32)
            .collect();
        let vals: Vec<f32> = (0..nnz).map(|_| rng.gaussian() as f32 * 0.05).collect();
        (b, a, idx, vals)
    }

    #[test]
    fn decomposition_sums_to_sigma() {
        // diag(UᵀWV) == Σ, and W = BA + S ⇒ contributions sum to Σ
        let (b, a, idx, vals) = setup(24, 4, 0.05);
        let dec = SpectrumDecomp::compute(&b, &a, &idx, &vals, 1.0);
        for i in 0..dec.sigma.len() {
            let sum = dec.lowrank_contrib[i] + dec.sparse_contrib[i];
            assert!(
                (sum - dec.sigma[i]).abs() < 1e-3 * dec.sigma[0].max(1.0),
                "dir {i}: {} + {} != {}",
                dec.lowrank_contrib[i],
                dec.sparse_contrib[i],
                dec.sigma[i]
            );
        }
    }

    #[test]
    fn lowrank_owns_head_sparse_owns_tail() {
        // the Appendix-D claim, on a synthetic SLTrain-like weight
        let (b, a, idx, vals) = setup(32, 4, 0.1);
        let dec = SpectrumDecomp::compute(&b, &a, &idx, &vals, 1.0);
        let (l_head, l_tail, s_head, s_tail) = dec.head_tail_split();
        assert!(l_head > 10.0 * l_tail.max(1e-6), "L head {l_head} tail {l_tail}");
        assert!(s_tail > 0.0);
        // in the tail, sparse dominates low-rank
        assert!(s_tail > l_tail, "tail: S {s_tail} vs L {l_tail}");
        let _ = s_head;
    }

    #[test]
    fn sigma_beyond_rank_nonzero_due_to_sparse() {
        // Table/Fig-10 claim: the sparse factor extends the spectrum past r
        let (b, a, idx, vals) = setup(32, 4, 0.1);
        let dec = SpectrumDecomp::compute(&b, &a, &idx, &vals, 1.0);
        assert!(dec.sigma[8] > 1e-4, "tail sigma {}", dec.sigma[8]);
        // and without the sparse factor it would be (numerically) zero
        let dec0 = SpectrumDecomp::compute(&b, &a, &idx, &vec![0.0; vals.len()], 1.0);
        assert!(dec0.sigma[8] < 1e-4 * dec0.sigma[0]);
    }
}

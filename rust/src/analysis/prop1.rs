//! Empirical verification of Proposition 1: for a uniform random support
//! with δ = Ω(log n / n), BA + S is full rank with probability 1 - O(1/n).

use crate::linalg::Matrix;
use crate::util::rng::Rng;

/// Monte-Carlo estimate of P[rank(BA + S) == n] over `trials` draws.
pub fn full_rank_probability(n: usize, r: usize, delta: f64, trials: usize, seed: u64) -> f64 {
    let rng = Rng::new(seed);
    let mut full = 0usize;
    for t in 0..trials {
        let mut tr = rng.fork(t as u64 + 1);
        let b = Matrix::random(n, r, &mut tr);
        let a = Matrix::random(r, n, &mut tr);
        let mut w = b.matmul(&a);
        // support: each entry kept independently w.p. delta (the paper's
        // Bernoulli model)
        let mut idx = vec![];
        let mut vals = vec![];
        for i in 0..n * n {
            if tr.f64() < delta {
                idx.push(i as u32);
                vals.push(tr.gaussian() as f32);
            }
        }
        w.scatter_add(&idx, &vals);
        if w.rank(1e-5) == n {
            full += 1;
        }
    }
    full as f64 / trials as f64
}

/// The paper's threshold: δ* = 2 log n / n.
pub fn critical_delta(n: usize) -> f64 {
    2.0 * (n as f64).ln() / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn above_threshold_is_full_rank_whp() {
        let n = 24;
        let delta = 2.0 * critical_delta(n); // comfortably above
        let p = full_rank_probability(n, 2, delta, 20, 0);
        assert!(p >= 0.9, "p = {p}");
    }

    #[test]
    fn far_below_threshold_is_rank_deficient() {
        let n = 24;
        // essentially no sparse entries: rank ≈ r << n
        let p = full_rank_probability(n, 2, 0.001, 10, 1);
        assert!(p <= 0.2, "p = {p}");
    }

    #[test]
    fn probability_increases_with_delta() {
        let n = 20;
        let lo = full_rank_probability(n, 2, 0.02, 15, 2);
        let hi = full_rank_probability(n, 2, 0.5, 15, 2);
        assert!(hi >= lo, "hi {hi} lo {lo}");
        assert!(hi >= 0.95);
    }
}

//! Mini-criterion: the bench harness used by every `benches/` binary
//! (the vendored crate set has no criterion).
//!
//! Provides warmup + timed iterations with mean/σ/min, a Markdown-ish
//! table printer so each bench binary prints the same rows/series as the
//! paper's table or figure, and CSV export for the figure-shaped outputs.

use std::time::Instant;

use crate::coordinator::metrics::{stats, Stats};

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub secs: Stats,
}

impl BenchResult {
    pub fn per_iter_ms(&self) -> f64 {
        self.secs.mean * 1e3
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
pub fn bench<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        f();
        samples.push(t0.elapsed().as_secs_f64());
    }
    BenchResult { name: name.to_string(), iters, secs: stats(&samples) }
}

/// Fixed-width table printer: paper-style rows.
pub struct Table {
    pub title: String,
    pub headers: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: vec![],
        }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        println!("\n== {} ==", self.title);
        let line = |cells: &[String]| {
            let parts: Vec<String> = cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!("{:<w$}", c, w = widths[i]))
                .collect();
            println!("| {} |", parts.join(" | "));
        };
        line(&self.headers);
        let sep: Vec<String> = widths.iter().map(|w| "-".repeat(*w)).collect();
        line(&sep);
        for row in &self.rows {
            line(row);
        }
    }

    pub fn to_csv(&self) -> String {
        let mut out = self.headers.join(",") + "\n";
        for row in &self.rows {
            out.push_str(&row.join(","));
            out.push('\n');
        }
        out
    }

    pub fn save_csv(&self, path: &str) -> anyhow::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_csv())?;
        println!("[table saved to {path}]");
        Ok(())
    }
}

/// Format a float with sensible precision for table cells.
pub fn fmt(v: f64, digits: usize) -> String {
    format!("{:.*}", digits, v)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_work() {
        let mut acc = 0u64;
        let r = bench("spin", 1, 5, || {
            for i in 0..10_000u64 {
                acc = acc.wrapping_add(i);
            }
        });
        assert_eq!(r.iters, 5);
        assert!(r.secs.mean > 0.0);
        assert!(r.secs.min <= r.secs.mean);
        std::hint::black_box(acc);
    }

    #[test]
    fn table_roundtrip() {
        let mut t = Table::new("Demo", &["method", "ppl"]);
        t.row(vec!["sltrain".into(), "34.15".into()]);
        t.row(vec!["full".into(), "34.06".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("method,ppl"));
        assert!(csv.lines().count() == 3);
        t.print(); // should not panic
    }

    #[test]
    #[should_panic]
    fn table_rejects_ragged_rows() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }
}

//! Optimizer-state machinery for the native engine: Adam moment
//! storage (f32 or block-wise 8-bit quantized) and the parallel
//! elementwise update kernel.
//!
//! The paper's headline memory result combines SLTrain with the 8-bit
//! Adam of Dettmers et al. [9]: both moments are held as 8-bit codes
//! with one f32 absmax scale per [`quant::Q8_BLOCK`] elements
//! (~1.016 bytes/element instead of 4) — a signed grid for `m`, the
//! full unsigned 0..=255 grid for the nonnegative second moment. The
//! second moment is stored in the **sqrt domain** — codes represent
//! `sqrt(v)`, dequantized as `(code·scale)²` — because a linear absmax
//! grid collapses small `v` entries to zero while their `m` blockmates
//! stay nonzero, which turns `m/(√v+ε)` into a divergent update
//! (reproduced in the PR's simulation; the sqrt grid matches `m`'s
//! dynamic range and trains indistinguishably from f32).
//!
//! Determinism: the f32 path is element-independent and the q8 path is
//! block-independent (dequant → update → requant never leaves a block),
//! so the pool partition cannot change a bit of the result — updates
//! are bit-identical across runs *and* thread counts.
//!
//! Two entry points share the same kernels: [`adam_update`] applies the
//! step to a parameter in place (the full/lowrank/sltrain/relora path),
//! and [`adam_direction`] only advances the moments and returns the
//! bias-corrected direction — the GaLore path, whose moments live in a
//! projected space of a different shape than the parameter, so the
//! caller projects the direction back before touching the weights.
//!
//! Owner sharding (`train --workers N`): the data-parallel backend
//! assigns each parameter one owner replica; only the owner keeps that
//! parameter's moments and applies its Adam step. Non-owners hold
//! zero-length [`Moments::zeros`]`(bits, 0)` placeholders — the same
//! convention frozen parameters use — so every kernel and serializer
//! here works unchanged, and per-replica optimizer bytes drop to ~1/N.
#![deny(missing_docs)]

pub mod quant;

use anyhow::{bail, Result};

use crate::linalg::parallel::{par_index_ranges, SendPtr, ThreadPool};
pub use quant::{dequant_unsigned, quantize_block, quantize_block_unsigned, Q8_BLOCK};

/// Tensors smaller than this keep f32 moments even under
/// `--optim-bits 8` (mirrors bitsandbytes' `min_8bit_size`): norm gains
/// and other small tensors contribute nothing to the footprint but are
/// the most quantization-sensitive.
pub const Q8_MIN_NUMEL: usize = 1024;

/// Below this many elements the update runs inline: pool dispatch costs
/// more than the loop, and element/block independence makes serial and
/// parallel results bit-identical anyway.
const PAR_CUTOFF: usize = 8192;

/// Adam moment precision of one backend (`--optim-bits {32,8}`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OptimBits {
    /// Full-precision f32 moments (`--optim-bits 32`, the default).
    F32,
    /// Block-wise absmax-quantized 8-bit moments (`--optim-bits 8`),
    /// for tensors clearing [`Q8_MIN_NUMEL`].
    Q8,
}

impl OptimBits {
    /// The flag value this precision corresponds to (32 or 8).
    pub fn bits(self) -> usize {
        match self {
            OptimBits::F32 => 32,
            OptimBits::Q8 => 8,
        }
    }
}

/// Resolve the `--optim-bits` flag: `0` means "auto" — the
/// `SLTRAIN_OPTIM_BITS` env var if set, else 32. Only 32 and 8 are
/// valid precisions; a set-but-garbled env var is an error, not a
/// silent fall-back to f32 (a typo in a CI matrix leg must not turn
/// the quantized run green without coverage).
pub fn resolve_optim_bits(requested: usize) -> Result<OptimBits> {
    let v = if requested == 0 {
        match std::env::var("SLTRAIN_OPTIM_BITS") {
            Ok(raw) => match raw.trim().parse::<usize>() {
                Ok(n) => n,
                Err(_) => bail!("SLTRAIN_OPTIM_BITS must be 32 or 8 (got {raw:?})"),
            },
            Err(_) => 32,
        }
    } else {
        requested
    };
    match v {
        32 => Ok(OptimBits::F32),
        8 => Ok(OptimBits::Q8),
        other => bail!("--optim-bits must be 32 or 8 (got {other})"),
    }
}

/// One Adam moment tensor. The representation is chosen per parameter
/// at init: f32 always, or block-wise 8-bit when the backend runs
/// `--optim-bits 8` *and* the tensor clears [`Q8_MIN_NUMEL`].
#[derive(Debug, Clone)]
pub enum Moments {
    /// Full-precision moments, one f32 per element.
    F32(Vec<f32>),
    /// 8-bit codes + one f32 absmax scale per [`Q8_BLOCK`] codes. For
    /// the first moment the codes hold `m` on the signed grid; for the
    /// second moment they hold `sqrt(v)` on the unsigned 0..=255 grid
    /// (bit-pattern stored as i8; see module docs).
    Q8 {
        /// One signed-8 code per element (see the variant doc for what
        /// the codes represent per moment).
        codes: Vec<i8>,
        /// One f32 absmax scale per [`Q8_BLOCK`] codes.
        scales: Vec<f32>,
    },
}

impl Moments {
    /// Fresh all-zero moments for an `n`-element tensor: quantized when
    /// the backend runs 8-bit moments *and* `n` clears [`Q8_MIN_NUMEL`],
    /// f32 otherwise. Zeroing covers the codes *and* the per-block
    /// scales, so a reset moment decodes to exact zero.
    pub fn zeros(bits: OptimBits, n: usize) -> Moments {
        match bits {
            OptimBits::Q8 if n >= Q8_MIN_NUMEL => Moments::Q8 {
                codes: vec![0; n],
                scales: vec![0.0; n.div_ceil(Q8_BLOCK)],
            },
            _ => Moments::F32(vec![0.0; n]),
        }
    }

    /// Elements tracked (code count for quantized moments).
    pub fn numel(&self) -> usize {
        match self {
            Moments::F32(v) => v.len(),
            Moments::Q8 { codes, .. } => codes.len(),
        }
    }

    /// Bytes actually held (i8 codes + f32 scales, or 4 bytes/element).
    pub fn bytes(&self) -> u64 {
        match self {
            Moments::F32(v) => (v.len() * 4) as u64,
            Moments::Q8 { codes, scales } => (codes.len() + scales.len() * 4) as u64,
        }
    }

    /// True when this moment is held as 8-bit codes + scales.
    pub fn is_quantized(&self) -> bool {
        matches!(self, Moments::Q8 { .. })
    }
}

/// Per-step Adam hyperparameters, precomputed once so every per-layer
/// fused update of the step uses identical constants.
#[derive(Debug, Clone, Copy)]
pub struct AdamHyper {
    /// Scheduled learning rate of this step.
    pub lr: f32,
    /// First-moment decay β₁.
    pub beta1: f32,
    /// Second-moment decay β₂.
    pub beta2: f32,
    /// Denominator stabilizer ε.
    pub eps: f32,
    /// First-moment bias correction `1 − β₁ᵗ`.
    pub bc1: f32,
    /// Second-moment bias correction `1 − β₂ᵗ`.
    pub bc2: f32,
    /// The optimizer step these constants were computed for. Carried so
    /// schedule-dependent optimizer state (the GaLore projector refresh)
    /// sees the same step in the fused and two-phase paths.
    pub step: i32,
}

/// One Adam update `p -= lr · m̂/(√v̂ + ε)` over a full parameter
/// tensor, moments updated in place. Elementwise passes run on the
/// pool; results are bit-identical to the serial loop at every thread
/// count (see module docs).
pub fn adam_update(
    pool: &ThreadPool,
    h: &AdamHyper,
    p: &mut [f32],
    g: &[f32],
    m: &mut Moments,
    v: &mut Moments,
) {
    let n = p.len();
    assert_eq!(g.len(), n, "adam grad/param numel mismatch");
    match (m, v) {
        (Moments::F32(m), Moments::F32(v)) => {
            assert_eq!(m.len(), n, "adam m numel");
            assert_eq!(v.len(), n, "adam v numel");
            if n <= PAR_CUTOFF || pool.threads() == 1 {
                adam_f32_chunk(h, p, g, m, v);
                return;
            }
            let pp = SendPtr::new(p.as_mut_ptr());
            let mp = SendPtr::new(m.as_mut_ptr());
            let vp = SendPtr::new(v.as_mut_ptr());
            par_index_ranges(pool, n, 1, |r| {
                // SAFETY: ranges are disjoint across tasks; the borrows
                // outlive the pool run (par_index_ranges blocks).
                let (ps, ms, vs) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(pp.get().add(r.start), r.len()),
                        std::slice::from_raw_parts_mut(mp.get().add(r.start), r.len()),
                        std::slice::from_raw_parts_mut(vp.get().add(r.start), r.len()),
                    )
                };
                adam_f32_chunk(h, ps, &g[r], ms, vs);
            });
        }
        (
            Moments::Q8 { codes: mc, scales: ms },
            Moments::Q8 { codes: vc, scales: vs },
        ) => {
            assert_eq!(mc.len(), n, "adam m codes numel");
            assert_eq!(vc.len(), n, "adam v codes numel");
            assert_eq!(ms.len(), n.div_ceil(Q8_BLOCK), "adam m scales");
            assert_eq!(vs.len(), n.div_ceil(Q8_BLOCK), "adam v scales");
            if n <= PAR_CUTOFF || pool.threads() == 1 {
                adam_q8_chunk(h, p, g, mc, ms, vc, vs);
                return;
            }
            let pp = SendPtr::new(p.as_mut_ptr());
            let mcp = SendPtr::new(mc.as_mut_ptr());
            let msp = SendPtr::new(ms.as_mut_ptr());
            let vcp = SendPtr::new(vc.as_mut_ptr());
            let vsp = SendPtr::new(vs.as_mut_ptr());
            // granule Q8_BLOCK: a quantization block is never split, so
            // each task's requant sees its blocks whole (bit-identical
            // at every thread count) and the per-task scale subranges
            // below are disjoint.
            par_index_ranges(pool, n, Q8_BLOCK, |r| {
                let b0 = r.start / Q8_BLOCK;
                let b1 = r.end.div_ceil(Q8_BLOCK);
                // SAFETY: element ranges and block ranges are disjoint
                // across tasks; borrows outlive the pool run.
                let (ps, mcs, mss, vcs, vss) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(pp.get().add(r.start), r.len()),
                        std::slice::from_raw_parts_mut(mcp.get().add(r.start), r.len()),
                        std::slice::from_raw_parts_mut(msp.get().add(b0), b1 - b0),
                        std::slice::from_raw_parts_mut(vcp.get().add(r.start), r.len()),
                        std::slice::from_raw_parts_mut(vsp.get().add(b0), b1 - b0),
                    )
                };
                adam_q8_chunk(h, ps, &g[r], mcs, mss, vcs, vss);
            });
        }
        _ => panic!("adam moments m/v disagree on representation"),
    }
}

/// Advance the Adam moments on `g` and write the bias-corrected update
/// direction `m̂/(√v̂ + ε)` into `upd` **without touching a parameter**.
/// This is [`adam_update`] minus the final `p -= lr·upd` application:
/// the GaLore optimizer keeps its moments in a rank-r projected space,
/// so the direction must be projected back to the weight's shape before
/// it can be applied. Same kernels, same partitioning, same determinism
/// contract (bit-identical across runs and thread counts).
pub fn adam_direction(
    pool: &ThreadPool,
    h: &AdamHyper,
    g: &[f32],
    m: &mut Moments,
    v: &mut Moments,
    upd: &mut [f32],
) {
    let n = g.len();
    assert_eq!(upd.len(), n, "adam direction/grad numel mismatch");
    match (m, v) {
        (Moments::F32(m), Moments::F32(v)) => {
            assert_eq!(m.len(), n, "adam m numel");
            assert_eq!(v.len(), n, "adam v numel");
            if n <= PAR_CUTOFF || pool.threads() == 1 {
                adam_dir_f32_chunk(h, g, m, v, upd);
                return;
            }
            let up = SendPtr::new(upd.as_mut_ptr());
            let mp = SendPtr::new(m.as_mut_ptr());
            let vp = SendPtr::new(v.as_mut_ptr());
            par_index_ranges(pool, n, 1, |r| {
                // SAFETY: ranges are disjoint across tasks; the borrows
                // outlive the pool run (par_index_ranges blocks).
                let (us, ms, vs) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(up.get().add(r.start), r.len()),
                        std::slice::from_raw_parts_mut(mp.get().add(r.start), r.len()),
                        std::slice::from_raw_parts_mut(vp.get().add(r.start), r.len()),
                    )
                };
                adam_dir_f32_chunk(h, &g[r], ms, vs, us);
            });
        }
        (
            Moments::Q8 { codes: mc, scales: ms },
            Moments::Q8 { codes: vc, scales: vs },
        ) => {
            assert_eq!(mc.len(), n, "adam m codes numel");
            assert_eq!(vc.len(), n, "adam v codes numel");
            assert_eq!(ms.len(), n.div_ceil(Q8_BLOCK), "adam m scales");
            assert_eq!(vs.len(), n.div_ceil(Q8_BLOCK), "adam v scales");
            if n <= PAR_CUTOFF || pool.threads() == 1 {
                adam_dir_q8_chunk(h, g, mc, ms, vc, vs, upd);
                return;
            }
            let up = SendPtr::new(upd.as_mut_ptr());
            let mcp = SendPtr::new(mc.as_mut_ptr());
            let msp = SendPtr::new(ms.as_mut_ptr());
            let vcp = SendPtr::new(vc.as_mut_ptr());
            let vsp = SendPtr::new(vs.as_mut_ptr());
            // granule Q8_BLOCK: quantization blocks are never split (see
            // adam_update's q8 arm for the partition contract)
            par_index_ranges(pool, n, Q8_BLOCK, |r| {
                let b0 = r.start / Q8_BLOCK;
                let b1 = r.end.div_ceil(Q8_BLOCK);
                // SAFETY: element ranges and block ranges are disjoint
                // across tasks; borrows outlive the pool run.
                let (us, mcs, mss, vcs, vss) = unsafe {
                    (
                        std::slice::from_raw_parts_mut(up.get().add(r.start), r.len()),
                        std::slice::from_raw_parts_mut(mcp.get().add(r.start), r.len()),
                        std::slice::from_raw_parts_mut(msp.get().add(b0), b1 - b0),
                        std::slice::from_raw_parts_mut(vcp.get().add(r.start), r.len()),
                        std::slice::from_raw_parts_mut(vsp.get().add(b0), b1 - b0),
                    )
                };
                adam_dir_q8_chunk(h, &g[r], mcs, mss, vcs, vss, us);
            });
        }
        _ => panic!("adam moments m/v disagree on representation"),
    }
}

/// The f32 kernel over one contiguous chunk — the exact expression
/// order of the pre-refactor serial loop, so the fused/parallel paths
/// stay bit-identical to it.
fn adam_f32_chunk(h: &AdamHyper, p: &mut [f32], g: &[f32], m: &mut [f32], v: &mut [f32]) {
    for i in 0..p.len() {
        m[i] = h.beta1 * m[i] + (1.0 - h.beta1) * g[i];
        v[i] = h.beta2 * v[i] + (1.0 - h.beta2) * g[i] * g[i];
        let upd = (m[i] / h.bc1) / ((v[i] / h.bc2).sqrt() + h.eps);
        p[i] -= h.lr * upd;
    }
}

/// The q8 kernel over one block-aligned chunk: per block, dequantize
/// both moments, run the f32 Adam recurrence, requantize (`m` linear,
/// `v` in the sqrt domain).
fn adam_q8_chunk(
    h: &AdamHyper,
    p: &mut [f32],
    g: &[f32],
    m_codes: &mut [i8],
    m_scales: &mut [f32],
    v_codes: &mut [i8],
    v_scales: &mut [f32],
) {
    let n = p.len();
    let mut mbuf = [0.0f32; Q8_BLOCK];
    let mut vbuf = [0.0f32; Q8_BLOCK];
    for (b, start) in (0..n).step_by(Q8_BLOCK).enumerate() {
        let end = (start + Q8_BLOCK).min(n);
        let msc = m_scales[b];
        let vsc = v_scales[b];
        for i in start..end {
            let k = i - start;
            let mi = m_codes[i] as f32 * msc;
            let vroot = dequant_unsigned(v_codes[i], vsc);
            let vi = vroot * vroot;
            let mn = h.beta1 * mi + (1.0 - h.beta1) * g[i];
            let vn = h.beta2 * vi + (1.0 - h.beta2) * g[i] * g[i];
            let upd = (mn / h.bc1) / ((vn / h.bc2).sqrt() + h.eps);
            p[i] -= h.lr * upd;
            mbuf[k] = mn;
            vbuf[k] = vn.sqrt();
        }
        m_scales[b] = quantize_block(&mbuf[..end - start], &mut m_codes[start..end]);
        // sqrt(v) is nonnegative: the unsigned grid doubles its
        // resolution at the same byte cost
        v_scales[b] = quantize_block_unsigned(&vbuf[..end - start], &mut v_codes[start..end]);
    }
}

/// [`adam_f32_chunk`] with the parameter application stripped: same
/// moment recurrence, but the bias-corrected direction lands in `upd`.
fn adam_dir_f32_chunk(h: &AdamHyper, g: &[f32], m: &mut [f32], v: &mut [f32], upd: &mut [f32]) {
    for i in 0..g.len() {
        m[i] = h.beta1 * m[i] + (1.0 - h.beta1) * g[i];
        v[i] = h.beta2 * v[i] + (1.0 - h.beta2) * g[i] * g[i];
        upd[i] = (m[i] / h.bc1) / ((v[i] / h.bc2).sqrt() + h.eps);
    }
}

/// [`adam_q8_chunk`] with the parameter application stripped: per
/// block, dequantize both moments, run the f32 Adam recurrence into
/// `upd`, requantize (`m` linear, `v` in the sqrt domain).
fn adam_dir_q8_chunk(
    h: &AdamHyper,
    g: &[f32],
    m_codes: &mut [i8],
    m_scales: &mut [f32],
    v_codes: &mut [i8],
    v_scales: &mut [f32],
    upd: &mut [f32],
) {
    let n = g.len();
    let mut mbuf = [0.0f32; Q8_BLOCK];
    let mut vbuf = [0.0f32; Q8_BLOCK];
    for (b, start) in (0..n).step_by(Q8_BLOCK).enumerate() {
        let end = (start + Q8_BLOCK).min(n);
        let msc = m_scales[b];
        let vsc = v_scales[b];
        for i in start..end {
            let k = i - start;
            let mi = m_codes[i] as f32 * msc;
            let vroot = dequant_unsigned(v_codes[i], vsc);
            let vi = vroot * vroot;
            let mn = h.beta1 * mi + (1.0 - h.beta1) * g[i];
            let vn = h.beta2 * vi + (1.0 - h.beta2) * g[i] * g[i];
            upd[i] = (mn / h.bc1) / ((vn / h.bc2).sqrt() + h.eps);
            mbuf[k] = mn;
            vbuf[k] = vn.sqrt();
        }
        m_scales[b] = quantize_block(&mbuf[..end - start], &mut m_codes[start..end]);
        v_scales[b] = quantize_block_unsigned(&vbuf[..end - start], &mut v_codes[start..end]);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn randvec(rng: &mut Rng, n: usize, mag: f32) -> Vec<f32> {
        (0..n).map(|_| rng.gaussian() as f32 * mag).collect()
    }

    fn hyper(step: usize) -> AdamHyper {
        let t = step as f32 + 1.0;
        AdamHyper {
            lr: 3e-3,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            bc1: 1.0 - 0.9f32.powf(t),
            bc2: 1.0 - 0.999f32.powf(t),
            step: step as i32,
        }
    }

    /// The f32 parallel path must be bit-identical to the serial kernel
    /// at every thread count (element independence).
    #[test]
    fn f32_update_is_bit_identical_across_thread_counts() {
        let n = 3 * PAR_CUTOFF + 17; // force the parallel path
        let mut rng = Rng::new(1);
        let g: Vec<f32> = randvec(&mut rng, n, 0.1);
        let p0: Vec<f32> = randvec(&mut rng, n, 1.0);
        let mut want: Option<(Vec<f32>, Moments, Moments)> = None;
        for threads in [1usize, 2, 5] {
            let pool = ThreadPool::new(threads);
            let mut p = p0.clone();
            let mut m = Moments::zeros(OptimBits::F32, n);
            let mut v = Moments::zeros(OptimBits::F32, n);
            for step in 0..3 {
                adam_update(&pool, &hyper(step), &mut p, &g, &mut m, &mut v);
            }
            match &want {
                None => want = Some((p, m, v)),
                Some((wp, wm, wv)) => {
                    assert_eq!(&p, wp, "params at {threads} threads");
                    match (&m, wm, &v, wv) {
                        (Moments::F32(a), Moments::F32(b), Moments::F32(c), Moments::F32(d)) => {
                            assert_eq!(a, b, "m at {threads} threads");
                            assert_eq!(c, d, "v at {threads} threads");
                        }
                        _ => unreachable!(),
                    }
                }
            }
        }
    }

    /// The q8 parallel path must be bit-identical across thread counts
    /// (block independence + block-aligned partition).
    #[test]
    fn q8_update_is_bit_identical_across_thread_counts() {
        let n = 3 * PAR_CUTOFF + Q8_BLOCK / 2; // parallel path, ragged tail block
        let mut rng = Rng::new(2);
        let g: Vec<f32> = randvec(&mut rng, n, 0.1);
        let p0: Vec<f32> = randvec(&mut rng, n, 1.0);
        let mut want: Option<Vec<f32>> = None;
        for threads in [1usize, 3, 4] {
            let pool = ThreadPool::new(threads);
            let mut p = p0.clone();
            let mut m = Moments::zeros(OptimBits::Q8, n);
            let mut v = Moments::zeros(OptimBits::Q8, n);
            assert!(m.is_quantized() && v.is_quantized());
            for step in 0..3 {
                adam_update(&pool, &hyper(step), &mut p, &g, &mut m, &mut v);
            }
            match &want {
                None => want = Some(p),
                Some(wp) => assert_eq!(&p, wp, "q8 params at {threads} threads"),
            }
        }
    }

    /// q8 must track the f32 trajectory closely on a well-scaled
    /// problem (the convergence claim behind `--optim-bits 8`).
    #[test]
    fn q8_update_tracks_f32_trajectory() {
        let n = 2 * Q8_BLOCK;
        let mut rng = Rng::new(3);
        let pool = ThreadPool::new(1);
        let mut pf: Vec<f32> = randvec(&mut rng, n, 1.0);
        let mut pq = pf.clone();
        let mut mf = Moments::zeros(OptimBits::F32, n);
        let mut vf = Moments::zeros(OptimBits::F32, n);
        // force quantized moments despite n < Q8_MIN_NUMEL
        let mut mq = Moments::Q8 { codes: vec![0; n], scales: vec![0.0; n.div_ceil(Q8_BLOCK)] };
        let mut vq = Moments::Q8 { codes: vec![0; n], scales: vec![0.0; n.div_ceil(Q8_BLOCK)] };
        for step in 0..100 {
            // gradient of f(p) = ||p||²/2 — drives p toward 0
            let gf: Vec<f32> = pf.clone();
            let gq: Vec<f32> = pq.clone();
            adam_update(&pool, &hyper(step), &mut pf, &gf, &mut mf, &mut vf);
            adam_update(&pool, &hyper(step), &mut pq, &gq, &mut mq, &mut vq);
        }
        let nf: f32 = pf.iter().map(|x| x * x).sum::<f32>().sqrt();
        let nq: f32 = pq.iter().map(|x| x * x).sum::<f32>().sqrt();
        let n0: f32 = (n as f32).sqrt(); // ~initial norm (unit gaussians)
        assert!(nf < n0 * 0.9, "f32 Adam failed to descend: {nf} vs {n0}");
        assert!(nq < n0 * 0.9, "q8 Adam failed to descend: {nq} vs {n0}");
        assert!((nf - nq).abs() < n0 * 0.1, "q8 drifted: f32 {nf} vs q8 {nq}");
    }

    /// adam_direction must advance the moments exactly like adam_update
    /// and return the direction adam_update would have applied — the
    /// contract that lets GaLore reuse the Adam kernels with a
    /// project-back in between. Both precisions, both partition paths.
    #[test]
    fn direction_matches_applied_update_bitwise() {
        for bits in [OptimBits::F32, OptimBits::Q8] {
            let n = 2 * PAR_CUTOFF + Q8_BLOCK / 2; // parallel path, ragged tail
            let mut rng = Rng::new(5);
            let p0: Vec<f32> = randvec(&mut rng, n, 1.0);
            let g: Vec<f32> = randvec(&mut rng, n, 0.1);
            for threads in [1usize, 3] {
                let pool = ThreadPool::new(threads);
                let mut pa = p0.clone();
                let mut ma = Moments::Q8 {
                    codes: vec![0; n],
                    scales: vec![0.0; n.div_ceil(Q8_BLOCK)],
                };
                let mut va = ma.clone();
                if bits == OptimBits::F32 {
                    ma = Moments::F32(vec![0.0; n]);
                    va = Moments::F32(vec![0.0; n]);
                }
                let mut mb = ma.clone();
                let mut vb = va.clone();
                let mut pb = p0.clone();
                let mut upd = vec![0.0f32; n];
                for step in 0..3 {
                    let h = hyper(step);
                    adam_update(&pool, &h, &mut pa, &g, &mut ma, &mut va);
                    adam_direction(&pool, &h, &g, &mut mb, &mut vb, &mut upd);
                    for i in 0..n {
                        pb[i] -= h.lr * upd[i];
                    }
                }
                assert_eq!(pa, pb, "{bits:?} x{threads}: applied vs direction params");
                match (&ma, &mb) {
                    (Moments::F32(a), Moments::F32(b)) => assert_eq!(a, b),
                    (
                        Moments::Q8 { codes: a, scales: sa },
                        Moments::Q8 { codes: b, scales: sb },
                    ) => {
                        assert_eq!(a, b);
                        assert_eq!(sa, sb);
                    }
                    _ => unreachable!(),
                }
            }
        }
    }

    #[test]
    fn moments_gate_small_tensors_and_report_bytes() {
        let small = Moments::zeros(OptimBits::Q8, Q8_MIN_NUMEL - 1);
        assert!(!small.is_quantized(), "below the gate stays f32");
        let big = Moments::zeros(OptimBits::Q8, 4 * Q8_MIN_NUMEL);
        assert!(big.is_quantized());
        let n = 4 * Q8_MIN_NUMEL;
        assert_eq!(Moments::zeros(OptimBits::F32, n).bytes(), (n * 4) as u64);
        assert_eq!(big.bytes(), (n + n.div_ceil(Q8_BLOCK) * 4) as u64);
        assert_eq!(big.numel(), n);
    }

    #[test]
    fn resolve_optim_bits_validates() {
        assert_eq!(resolve_optim_bits(32).unwrap(), OptimBits::F32);
        assert_eq!(resolve_optim_bits(8).unwrap(), OptimBits::Q8);
        assert!(resolve_optim_bits(16).is_err());
        assert!(resolve_optim_bits(0).is_ok(), "0 = auto must resolve");
    }
}

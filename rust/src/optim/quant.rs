//! Block-wise absmax 8-bit quantization (Dettmers et al. [9]) for the
//! native engine's Adam moments.
//!
//! A tensor is split into fixed blocks of [`Q8_BLOCK`] consecutive
//! elements; each block stores one f32 scale (`absmax / 127`) plus one
//! signed-8 code per element (`round(x / scale)`, clamped to ±127).
//! Properties the optimizer relies on:
//!
//! * **Bounded error.** `|dequant(quant(x)) − x| ≤ absmax/127` per
//!   block (the round-off is at most half a code, `absmax/254`; the
//!   bound leaves fp slack). Sole exception: blocks whose absmax sits
//!   under [`Q8_FLUSH_BELOW`] flush to exact zero (see its doc).
//!   Tested below.
//! * **Block independence.** A block's codes depend only on that
//!   block's values, so any block-aligned partition of the
//!   dequant→update→requant pass over the worker pool is bit-identical
//!   to the serial pass — the thread-count-invariance contract.
//! * **All-zero blocks** stay exactly zero (scale 0, codes 0), so fresh
//!   moments survive a quantized round-trip untouched.

/// Elements per quantization block (one f32 scale amortized over 256
/// i8 codes: 1.015625 bytes/element vs 4 for f32 moments).
pub const Q8_BLOCK: usize = 256;

/// Blocks whose peak magnitude is below this are flushed to exact zero
/// instead of quantized: beneath ~3.7e-37 the `127/absmax` reciprocal
/// overflows to +inf and would snap every nonzero element to ±absmax
/// (breaking the error bound by up to 127×). A moment this small is
/// indistinguishable from zero for the Adam update, so the flush costs
/// nothing — but it is the one documented exception to the
/// `err ≤ absmax/127` bound (flushed blocks have `err ≤ absmax`,
/// absolutely below this constant).
pub const Q8_FLUSH_BELOW: f32 = 1e-35;

/// Quantize one block: writes `codes[i] = round(src[i] / scale)` and
/// returns the block scale `absmax / 127` (0.0 for an all-zero block).
pub fn quantize_block(src: &[f32], codes: &mut [i8]) -> f32 {
    assert_eq!(src.len(), codes.len(), "quantize_block length mismatch");
    let mut absmax = 0.0f32;
    for &x in src {
        absmax = absmax.max(x.abs());
    }
    if absmax < Q8_FLUSH_BELOW {
        for c in codes.iter_mut() {
            *c = 0;
        }
        return 0.0;
    }
    let inv = 127.0f32 / absmax;
    for (c, &x) in codes.iter_mut().zip(src) {
        *c = (x * inv).round().clamp(-127.0, 127.0) as i8;
    }
    absmax / 127.0
}

/// Quantize a *nonnegative* block onto the full unsigned 8-bit grid —
/// codes 0..=255, stored as the i8 with the same bit pattern (decode
/// with [`dequant_unsigned`]). Twice the resolution of the signed grid
/// for values that cannot be negative, which is exactly the sqrt-domain
/// second Adam moment. Returns the block scale `max / 255`.
pub fn quantize_block_unsigned(src: &[f32], codes: &mut [i8]) -> f32 {
    assert_eq!(src.len(), codes.len(), "quantize_block_unsigned length mismatch");
    let mut mx = 0.0f32;
    for &x in src {
        debug_assert!(x >= 0.0, "unsigned grid fed a negative value");
        mx = mx.max(x);
    }
    if mx < Q8_FLUSH_BELOW {
        for c in codes.iter_mut() {
            *c = 0;
        }
        return 0.0;
    }
    let inv = 255.0f32 / mx;
    for (c, &x) in codes.iter_mut().zip(src) {
        *c = ((x * inv).round().clamp(0.0, 255.0) as u8) as i8;
    }
    mx / 255.0
}

/// Decode one unsigned-grid code (see [`quantize_block_unsigned`]).
#[inline]
pub fn dequant_unsigned(code: i8, scale: f32) -> f32 {
    (code as u8) as f32 * scale
}

/// Dequantize `codes` (with one scale per [`Q8_BLOCK`] codes) into
/// `out`: the raw-code inverse of repeated [`quantize_block`] calls.
/// NOTE: this decodes what the codes *store* — for the second Adam
/// moment that is `sqrt(v)` (the optimizer squares it on dequant); the
/// real runtime decode lives inline in `adam_q8_chunk`. Test-only: the
/// oracle for the roundtrip error bound.
#[cfg(test)]
fn dequantize_into(codes: &[i8], scales: &[f32], out: &mut [f32]) {
    assert_eq!(codes.len(), out.len(), "dequantize length mismatch");
    assert_eq!(scales.len(), codes.len().div_ceil(Q8_BLOCK), "dequantize scale count");
    for (b, chunk) in codes.chunks(Q8_BLOCK).enumerate() {
        let s = scales[b];
        for (k, &c) in chunk.iter().enumerate() {
            out[b * Q8_BLOCK + k] = c as f32 * s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn roundtrip_error_is_within_absmax_over_127() {
        let mut rng = Rng::new(7);
        for trial in 0..50 {
            // mixed magnitudes, including exact zeros and sign flips
            let n = 1 + (trial * 37) % (2 * Q8_BLOCK);
            let mag = 10.0f32.powf((trial % 13) as f32 - 6.0);
            let src: Vec<f32> = (0..n)
                .map(|i| if i % 11 == 0 { 0.0 } else { rng.gaussian() as f32 * mag })
                .collect();
            let mut codes = vec![0i8; n];
            let mut scales = vec![0.0f32; n.div_ceil(Q8_BLOCK)];
            for (b, chunk) in src.chunks(Q8_BLOCK).enumerate() {
                let start = b * Q8_BLOCK;
                scales[b] = quantize_block(chunk, &mut codes[start..start + chunk.len()]);
            }
            let mut back = vec![0.0f32; n];
            dequantize_into(&codes, &scales, &mut back);
            for (b, chunk) in src.chunks(Q8_BLOCK).enumerate() {
                let absmax = chunk.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                let bound = absmax / 127.0;
                for (k, &x) in chunk.iter().enumerate() {
                    let err = (back[b * Q8_BLOCK + k] - x).abs();
                    assert!(
                        err <= bound,
                        "trial {trial} block {b} elem {k}: err {err} > bound {bound}"
                    );
                }
            }
        }
    }

    #[test]
    fn zero_block_stays_exactly_zero() {
        let src = vec![0.0f32; Q8_BLOCK];
        let mut codes = vec![5i8; Q8_BLOCK];
        let scale = quantize_block(&src, &mut codes);
        assert_eq!(scale, 0.0);
        assert!(codes.iter().all(|&c| c == 0));
    }

    /// Below Q8_FLUSH_BELOW the 127/absmax reciprocal would overflow to
    /// +inf and snap every element to ±absmax; such blocks must flush
    /// to exact zero instead.
    #[test]
    fn subnormal_blocks_flush_to_zero() {
        let src = [1e-38f32, -2e-38, 0.0, 5e-39];
        let mut codes = [9i8; 4];
        assert_eq!(quantize_block(&src, &mut codes), 0.0);
        assert!(codes.iter().all(|&c| c == 0));
        let srcu = [1e-38f32, 2e-38, 0.0, 5e-39];
        let mut codes = [9i8; 4];
        assert_eq!(quantize_block_unsigned(&srcu, &mut codes), 0.0);
        assert!(codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn extremes_map_to_full_code_range() {
        let src = [1.0f32, -1.0, 0.5, -0.25, 0.0];
        let mut codes = [0i8; 5];
        let scale = quantize_block(&src, &mut codes);
        assert_eq!(codes[0], 127);
        assert_eq!(codes[1], -127);
        assert_eq!(codes[4], 0);
        assert!((scale - 1.0 / 127.0).abs() < 1e-9);
    }

    #[test]
    fn unsigned_grid_bounds_error_and_uses_full_range() {
        let mut rng = Rng::new(11);
        for trial in 0..30 {
            let n = 1 + (trial * 29) % Q8_BLOCK;
            let mag = 10.0f32.powf((trial % 9) as f32 - 4.0);
            let src: Vec<f32> = (0..n)
                .map(|i| if i % 7 == 0 { 0.0 } else { (rng.gaussian() as f32 * mag).abs() })
                .collect();
            let mut codes = vec![0i8; n];
            let scale = quantize_block_unsigned(&src, &mut codes);
            let mx = src.iter().fold(0.0f32, |a, &x| a.max(x));
            for (k, &x) in src.iter().enumerate() {
                let err = (dequant_unsigned(codes[k], scale) - x).abs();
                assert!(err <= mx / 255.0, "trial {trial} elem {k}: err {err} > {}", mx / 255.0);
            }
            if mx > 0.0 {
                let top = src.iter().position(|&x| x == mx).unwrap();
                assert_eq!(codes[top] as u8, 255, "max must hit the top code");
            }
        }
        // zero block stays zero
        let mut codes = [7i8; 4];
        assert_eq!(quantize_block_unsigned(&[0.0; 4], &mut codes), 0.0);
        assert!(codes.iter().all(|&c| c == 0));
    }

    #[test]
    fn quantization_is_deterministic() {
        let mut rng = Rng::new(3);
        let src: Vec<f32> = (0..Q8_BLOCK).map(|_| rng.gaussian() as f32).collect();
        let mut c1 = vec![0i8; Q8_BLOCK];
        let mut c2 = vec![0i8; Q8_BLOCK];
        let s1 = quantize_block(&src, &mut c1);
        let s2 = quantize_block(&src, &mut c2);
        assert_eq!(s1.to_bits(), s2.to_bits());
        assert_eq!(c1, c2);
    }
}

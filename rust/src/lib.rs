//! # sltrain — sparse plus low-rank pretraining, reproduced
//!
//! Rust + JAX + Pallas reproduction of *"SLTrain: a sparse plus low-rank
//! approach for parameter and memory efficient pretraining"* (NeurIPS
//! 2024). Three layers:
//!
//! * **L1** — Pallas kernels for the SLTrain linear layer
//!   (`python/compile/kernels/`), verified against a pure-jnp oracle.
//! * **L2** — the LLaMA-family model + optimizers in JAX
//!   (`python/compile/`), AOT-lowered to HLO-text artifacts.
//! * **L3** — this crate: the training coordinator, data pipeline,
//!   memory estimator, analysis tooling, and two execution backends
//!   behind one `backend::Backend` trait — the pure-rust `native`
//!   engine (no artifacts, no XLA; the default), and the PJRT runtime
//!   that executes the AOT artifacts (cargo feature `xla`) with Python
//!   nowhere on the hot path.
//!
//! See DESIGN.md for the per-experiment index and EXPERIMENTS.md for the
//! measured reproduction of every table and figure.

pub mod analysis;
pub mod backend;
pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod linalg;
pub mod mem;
pub mod optim;
pub mod runtime;
pub mod serve;
pub mod util;

pub use util::json::Json;

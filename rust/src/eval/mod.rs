//! Quality-eval harness: held-out perplexity plus a small deterministic
//! synthetic task suite, reported per method so quality claims (paper
//! Tables 2/12) regress in CI alongside speed (BENCH_steploop) and
//! memory (BENCH_memory).
//!
//! Everything here is a pure function of the backend state and fixed
//! seeds — no wall clock, no thread-count dependence — so the numbers
//! are bit-comparable across runs and machines with the same weights.
//!
//! The suite:
//! - **eval_loss / ppl**: mean cross-entropy over the held-out valid
//!   set, and `exp` of it (the standard pretraining quality number).
//! - **next_token_acc**: top-1 next-token accuracy from `forward`
//!   logits over the same valid set (an accuracy-shaped stand-in for
//!   the paper's downstream Table 12 scores).
//! - **induction_gap**: a copy-task probe. Rows are `[prefix ‖ prefix]`
//!   with a fixed-seed random prefix; the gap is the mean CE on the
//!   first (unpredictable) half minus the mean CE on the second
//!   (copyable) half. A model with working attention scores a positive
//!   gap that grows with training; a bigram-only model scores ~0.

use anyhow::Result;

use crate::backend::Backend;
use crate::coordinator::metrics::perplexity;
use crate::util::rng::Rng;

/// One backend's quality numbers (see module docs for the tasks).
#[derive(Debug, Clone, Copy)]
pub struct QualityReport {
    /// Mean cross-entropy over the held-out valid set (nats/token).
    pub eval_loss: f64,
    /// `exp(eval_loss)` — held-out perplexity.
    pub ppl: f64,
    /// Top-1 next-token accuracy over the valid set, in [0, 1].
    pub next_token_acc: f64,
    /// Copy-task CE gap (first half minus second half), nats/token.
    pub induction_gap: f64,
}

/// Numerically-stable host-side log-sum-exp over one vocab row, f64.
fn logsumexp(row: &[f32]) -> f64 {
    let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max) as f64;
    let sum: f64 = row.iter().map(|&x| ((x as f64) - mx).exp()).sum();
    mx + sum.ln()
}

/// Argmax index of one vocab row (first max wins — deterministic).
fn argmax(row: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in row.iter().enumerate() {
        if x > row[best] {
            best = i;
        }
    }
    best
}

/// Mean CE and top-1 accuracy for a [rows, seq] token block from the
/// flattened [rows, seq, vocab] logits.
fn block_acc(tokens: &[i32], logits: &[f32], rows: usize, seq: usize, vocab: usize) -> (u64, u64) {
    let mut hits = 0u64;
    let mut total = 0u64;
    for r in 0..rows {
        for t in 0..seq - 1 {
            let at = (r * seq + t) * vocab;
            let pred = argmax(&logits[at..at + vocab]);
            if pred as i32 == tokens[r * seq + t + 1] {
                hits += 1;
            }
            total += 1;
        }
    }
    (hits, total)
}

/// Deterministic induction-probe row `r`: a random prefix of length
/// `seq/2` (pure function of `r`), repeated to fill `seq`.
fn induction_row(r: u64, seq: usize, vocab: usize) -> Vec<i32> {
    let mut rng = Rng::new(0x1DC0DE).fork(r);
    let half = (seq / 2).max(1);
    let prefix: Vec<i32> = (0..half).map(|_| rng.below(vocab as u64) as i32).collect();
    (0..seq).map(|t| prefix[t % half]).collect()
}

/// Run the full quality suite: `valid` is a fixed held-out set of
/// `[batch, seq]` blocks (as produced by `Pipeline::valid_set` with the
/// backend's batch size); `induction_batches` forward batches of copy
/// rows are probed on top.
pub fn evaluate(
    be: &mut dyn Backend,
    valid: &[Vec<i32>],
    induction_batches: usize,
) -> Result<QualityReport> {
    let batch = be.batch_size();
    let fwd_b = be.forward_batch_size();
    let seq = be.seq_len();
    let vocab = be.preset().vocab;

    // held-out CE -> perplexity
    let mut loss_sum = 0.0f64;
    for b in valid {
        loss_sum += be.eval_loss(b)? as f64;
    }
    let eval_loss = loss_sum / valid.len().max(1) as f64;

    // top-1 next-token accuracy over the same rows, re-grouped to the
    // forward entrypoint's batch size (the last group repeat-pads with
    // its final row; padded rows are not counted)
    let rows: Vec<&[i32]> =
        valid.iter().flat_map(|b| b.chunks(seq).take(batch)).collect();
    let mut hits = 0u64;
    let mut total = 0u64;
    for group in rows.chunks(fwd_b) {
        let mut block: Vec<i32> = Vec::with_capacity(fwd_b * seq);
        let last = *group.last().expect("non-empty group");
        for r in 0..fwd_b {
            block.extend_from_slice(group.get(r).copied().unwrap_or(last));
        }
        let logits = be.forward(&block)?;
        let (h, t) = block_acc(&block, &logits, group.len(), seq, vocab);
        hits += h;
        total += t;
    }
    let next_token_acc = hits as f64 / total.max(1) as f64;

    // induction probe: CE on the unpredictable first half vs the
    // copyable second half
    let half = (seq / 2).max(1);
    let (mut ce_first, mut n_first) = (0.0f64, 0u64);
    let (mut ce_second, mut n_second) = (0.0f64, 0u64);
    for k in 0..induction_batches {
        let mut block: Vec<i32> = Vec::with_capacity(fwd_b * seq);
        for r in 0..fwd_b {
            block.extend(induction_row((k * fwd_b + r) as u64, seq, vocab));
        }
        let logits = be.forward(&block)?;
        for r in 0..fwd_b {
            for t in 0..seq - 1 {
                let at = (r * seq + t) * vocab;
                let target = block[r * seq + t + 1] as usize;
                let row = &logits[at..at + vocab];
                let ce = logsumexp(row) - row[target] as f64;
                if t + 1 < half {
                    ce_first += ce;
                    n_first += 1;
                } else {
                    ce_second += ce;
                    n_second += 1;
                }
            }
        }
    }
    let induction_gap =
        ce_first / n_first.max(1) as f64 - ce_second / n_second.max(1) as f64;

    Ok(QualityReport {
        eval_loss,
        ppl: perplexity(eval_loss),
        next_token_acc,
        induction_gap,
    })
}

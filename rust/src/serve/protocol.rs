//! Wire format of the serving daemon: newline-delimited JSON.
//!
//! One request object per line in, one response object per line out.
//! Parsing is total: any malformed line maps to an error *response*
//! (`{"ok":false,"error":...}`), never a dropped connection — the
//! daemon must survive hostile input (tested in `tests/serve_e2e.rs`).

use anyhow::{anyhow, bail, Result};

use crate::util::json::{num, obj, s, Json};

/// A parsed client request. `Generate::id` is the client's `id` value
/// echoed verbatim in the response (clients use it to match pipelined
/// responses); it defaults to `Json::Null`.
#[derive(Debug, Clone, PartialEq)]
pub enum Request {
    /// Liveness probe; answered immediately with `{"ok":true,"op":"pong"}`.
    Ping,
    /// Model card: preset, method, vocab, seq_len, folded, n_params.
    Info,
    /// Greedy generation from a token prompt.
    Generate {
        /// Client correlation id, echoed verbatim.
        id: Json,
        /// Prompt token ids (must be non-empty, all `< vocab`).
        prompt: Vec<i32>,
        /// Tokens to generate (clamped to the seq_len budget).
        max_tokens: usize,
    },
    /// Live counters: in-flight generates + whether a drain has begun.
    Stats,
    /// Stop admitting, drain in-flight sequences, exit cleanly.
    Shutdown,
}

/// Parse one request line. Errors name what was wrong — they become
/// the `error` field of an `{"ok":false}` response.
pub fn parse_request(line: &str) -> Result<Request> {
    let v = Json::parse(line.trim()).map_err(|e| anyhow!("bad json: {e}"))?;
    let op = v
        .get("op")
        .and_then(|o| o.as_str())
        .ok_or_else(|| anyhow!("missing string field \"op\""))?;
    match op {
        "ping" => Ok(Request::Ping),
        "info" => Ok(Request::Info),
        "stats" => Ok(Request::Stats),
        "shutdown" => Ok(Request::Shutdown),
        "generate" => {
            let prompt_v = v.req("prompt")?;
            let arr = prompt_v
                .as_arr()
                .ok_or_else(|| anyhow!("\"prompt\" must be an array of token ids"))?;
            let mut prompt = Vec::with_capacity(arr.len());
            for t in arr {
                let n = t.as_f64().ok_or_else(|| anyhow!("non-numeric token in prompt"))?;
                if n.fract() != 0.0 || n < 0.0 {
                    bail!("token {n} is not a non-negative integer");
                }
                prompt.push(n as i32);
            }
            let max_tokens = v
                .get("max_tokens")
                .map(|m| m.as_usize().ok_or_else(|| anyhow!("\"max_tokens\" must be a number")))
                .transpose()?
                .unwrap_or(16);
            let id = v.get("id").cloned().unwrap_or(Json::Null);
            Ok(Request::Generate { id, prompt, max_tokens })
        }
        other => bail!("unknown op {other:?} (ping | info | stats | generate | shutdown)"),
    }
}

/// `{"ok":false,"error":<msg>}` with the client id echoed when known.
pub fn error_line(id: &Json, msg: &str) -> String {
    let mut pairs = vec![("ok", Json::Bool(false)), ("error", s(msg))];
    if *id != Json::Null {
        pairs.push(("id", id.clone()));
    }
    obj(pairs).to_string()
}

/// The typed load-shed response: `{"ok":false,"overloaded":true,...}`.
/// Clients distinguish it from hard failures by the `overloaded` flag
/// (retry with backoff instead of giving up).
pub fn overloaded_line(id: &Json, max_queue: u64) -> String {
    let mut pairs = vec![
        ("ok", Json::Bool(false)),
        ("overloaded", Json::Bool(true)),
        ("error", s(&format!("overloaded: admission queue is full (cap {max_queue})"))),
    ];
    if *id != Json::Null {
        pairs.push(("id", id.clone()));
    }
    obj(pairs).to_string()
}

/// The `stats` response: in-flight generate count and drain state.
pub fn stats_line(inflight: u64, shutting_down: bool) -> String {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("op", s("stats")),
        ("inflight", num(inflight as f64)),
        ("shutting_down", Json::Bool(shutting_down)),
    ])
    .to_string()
}

/// `{"ok":true,"op":"pong"}`.
pub fn pong_line() -> String {
    obj(vec![("ok", Json::Bool(true)), ("op", s("pong"))]).to_string()
}

/// `{"ok":true,"op":"shutdown"}` — the ack written *before* the daemon
/// starts draining (after that, the process may exit at any moment).
pub fn shutdown_line() -> String {
    obj(vec![("ok", Json::Bool(true)), ("op", s("shutdown"))]).to_string()
}

/// The `generate` success response.
pub fn generate_line(id: &Json, prompt_len: usize, tokens: &[i32]) -> String {
    obj(vec![
        ("ok", Json::Bool(true)),
        ("op", s("generate")),
        ("id", id.clone()),
        ("prompt_len", num(prompt_len as f64)),
        ("tokens", Json::Arr(tokens.iter().map(|&t| num(t as f64)).collect())),
    ])
    .to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_op() {
        assert_eq!(parse_request(r#"{"op":"ping"}"#).unwrap(), Request::Ping);
        assert_eq!(parse_request(r#"{"op":"info"}"#).unwrap(), Request::Info);
        assert_eq!(parse_request(r#"{"op":"stats"}"#).unwrap(), Request::Stats);
        assert_eq!(parse_request(r#"{"op":"shutdown"}"#).unwrap(), Request::Shutdown);
        let g = parse_request(r#"{"op":"generate","prompt":[1,2],"max_tokens":3,"id":9}"#).unwrap();
        assert_eq!(
            g,
            Request::Generate { id: Json::Num(9.0), prompt: vec![1, 2], max_tokens: 3 }
        );
    }

    #[test]
    fn generate_defaults() {
        let g = parse_request(r#"{"op":"generate","prompt":[0]}"#).unwrap();
        let Request::Generate { id, prompt, max_tokens } = g else { panic!("not generate") };
        assert_eq!(id, Json::Null);
        assert_eq!(prompt, vec![0]);
        assert_eq!(max_tokens, 16);
    }

    #[test]
    fn rejects_malformed() {
        assert!(parse_request("not json").is_err());
        assert!(parse_request(r#"{"op":"warp"}"#).is_err());
        assert!(parse_request(r#"{"op":"generate"}"#).is_err());
        assert!(parse_request(r#"{"op":"generate","prompt":"abc"}"#).is_err());
        assert!(parse_request(r#"{"op":"generate","prompt":[1.5]}"#).is_err());
        assert!(parse_request(r#"{"op":"generate","prompt":[-1]}"#).is_err());
        assert!(parse_request(r#"{"op":"generate","prompt":[1],"max_tokens":"x"}"#).is_err());
    }

    #[test]
    fn responses_round_trip() {
        let line = generate_line(&Json::Num(3.0), 2, &[4, 5]);
        let v = Json::parse(&line).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_i64(), Some(3));
        assert_eq!(v.get("tokens").unwrap().as_arr().unwrap().len(), 2);
        let e = error_line(&Json::Null, "nope");
        let v = Json::parse(&e).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("error").unwrap().as_str(), Some("nope"));
        assert!(v.get("id").is_none());
    }

    #[test]
    fn overloaded_and_stats_lines_round_trip() {
        let o = overloaded_line(&Json::Num(4.0), 64);
        let v = Json::parse(&o).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(false));
        assert_eq!(v.get("overloaded").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("id").unwrap().as_i64(), Some(4));
        assert!(v.get("error").unwrap().as_str().unwrap().contains("cap 64"));

        let st = stats_line(3, true);
        let v = Json::parse(&st).unwrap();
        assert_eq!(v.get("ok").unwrap().as_bool(), Some(true));
        assert_eq!(v.get("inflight").unwrap().as_i64(), Some(3));
        assert_eq!(v.get("shutting_down").unwrap().as_bool(), Some(true));
    }
}

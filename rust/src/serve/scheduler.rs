//! Continuous batching over the incremental-decode path.
//!
//! The scheduler owns the backend and a set of in-flight sequences,
//! each with its own [`KvCache`]. One [`Scheduler::step`] call (a)
//! admits queued requests into free batch slots — the prefill runs
//! their whole prompt through `forward_incremental` in one shot — and
//! (b) advances every active sequence by one greedily-decoded token,
//! evicting the ones that hit their budget. Admission between decode
//! steps is what makes the batching *continuous*: a 512-token
//! generation never blocks a 4-token one arriving behind it.
//!
//! Decoding is greedy argmax with lowest-index tie-break, so the
//! output tokens are a pure function of (weights, prompt) — batching
//! order, admission timing, and thread count cannot change them
//! (per-row matmul results are independent of batch composition, and
//! each sequence carries its own cache).

use std::collections::VecDeque;

use anyhow::{bail, Result};

use crate::backend::native::{KvCache, NativeBackend};
use crate::backend::Backend;

/// A queued generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    /// Scheduler-scoped id; results carry it back.
    pub id: u64,
    /// Prompt token ids (non-empty, all `< vocab`).
    pub prompt: Vec<i32>,
    /// Tokens to generate (clamped to the seq_len budget at submit).
    pub max_tokens: usize,
}

/// A finished generation.
#[derive(Debug, Clone)]
pub struct GenResult {
    /// The request's id.
    pub id: u64,
    /// The generated continuation (prompt not included).
    pub tokens: Vec<i32>,
    /// Length of the prompt that was prefilled.
    pub prompt_len: usize,
}

/// One in-flight sequence: its cache, its last token (the next decode
/// input), and what it has produced so far.
struct Seq {
    id: u64,
    cache: KvCache,
    prompt_len: usize,
    last: i32,
    generated: Vec<i32>,
    max_tokens: usize,
}

/// Continuous-batching scheduler; see the module docs.
pub struct Scheduler {
    backend: NativeBackend,
    queue: VecDeque<GenRequest>,
    active: Vec<Seq>,
    max_batch: usize,
}

impl Scheduler {
    /// Wrap a ready-to-serve backend (init'd, checkpoint loaded,
    /// usually folded). `max_batch` is the number of concurrent decode
    /// slots; queued requests wait for a free one.
    pub fn new(backend: NativeBackend, max_batch: usize) -> Scheduler {
        Scheduler {
            backend,
            queue: VecDeque::new(),
            active: Vec::new(),
            max_batch: max_batch.max(1),
        }
    }

    /// The wrapped backend (model card queries).
    pub fn backend(&self) -> &NativeBackend {
        &self.backend
    }

    /// Validate and enqueue. `max_tokens` is clamped so that
    /// `prompt + generated` fits the preset's seq_len (rope tables and
    /// the causal mask are sized to it); a prompt that leaves no room
    /// to generate even one token is rejected.
    pub fn submit(&mut self, mut req: GenRequest) -> Result<()> {
        let p = self.backend.preset();
        if req.prompt.is_empty() {
            bail!("empty prompt");
        }
        if let Some(&t) = req.prompt.iter().find(|&&t| t < 0 || t as usize >= p.vocab) {
            bail!("prompt token {t} out of vocab {}", p.vocab);
        }
        if req.prompt.len() >= p.seq_len {
            bail!(
                "prompt length {} leaves no room to generate (seq_len {})",
                req.prompt.len(),
                p.seq_len
            );
        }
        if req.max_tokens == 0 {
            bail!("max_tokens must be at least 1");
        }
        req.max_tokens = req.max_tokens.min(p.seq_len - req.prompt.len());
        self.queue.push_back(req);
        Ok(())
    }

    /// Queued requests not yet admitted.
    pub fn pending(&self) -> usize {
        self.queue.len()
    }

    /// Sequences currently holding a decode slot.
    pub fn active(&self) -> usize {
        self.active.len()
    }

    /// True when there is nothing queued and nothing in flight.
    pub fn is_idle(&self) -> bool {
        self.queue.is_empty() && self.active.is_empty()
    }

    /// One scheduling round: admit into free slots (prefill), advance
    /// every active sequence one token, evict and return the finished
    /// ones. Returns an empty vec when idle.
    pub fn step(&mut self) -> Result<Vec<GenResult>> {
        // admit: prefill the whole prompt, producing the first token
        while self.active.len() < self.max_batch {
            let Some(req) = self.queue.pop_front() else { break };
            let mut cache = self.backend.new_kv_cache();
            let logits = self.backend.forward_incremental(&req.prompt, &mut cache)?;
            let last_row = &logits.data[(logits.rows - 1) * logits.cols..];
            let first = argmax(last_row);
            self.active.push(Seq {
                id: req.id,
                cache,
                prompt_len: req.prompt.len(),
                last: first,
                generated: vec![first],
                max_tokens: req.max_tokens,
            });
        }

        // decode: one token per active sequence (skip the ones the
        // prefill already completed)
        for seq in &mut self.active {
            if seq.generated.len() >= seq.max_tokens {
                continue;
            }
            let logits = self.backend.forward_incremental(&[seq.last], &mut seq.cache)?;
            let row = &logits.data[(logits.rows - 1) * logits.cols..];
            let tok = argmax(row);
            seq.last = tok;
            seq.generated.push(tok);
        }

        // evict finished sequences, preserving admission order among
        // the survivors
        let mut done = Vec::new();
        self.active.retain_mut(|seq| {
            let full = seq.cache.len() >= self.backend.preset().seq_len;
            if seq.generated.len() >= seq.max_tokens || full {
                done.push(GenResult {
                    id: seq.id,
                    tokens: std::mem::take(&mut seq.generated),
                    prompt_len: seq.prompt_len,
                });
                false
            } else {
                true
            }
        });
        Ok(done)
    }

    /// Run a single request to completion (test / bench convenience):
    /// submit, then step until its result comes back.
    pub fn generate(&mut self, prompt: &[i32], max_tokens: usize) -> Result<GenResult> {
        let id = u64::MAX; // reserved: never collides with daemon ids
        self.submit(GenRequest { id, prompt: prompt.to_vec(), max_tokens })?;
        loop {
            for r in self.step()? {
                if r.id == id {
                    return Ok(r);
                }
            }
            if self.is_idle() {
                bail!("request completed without a result (scheduler bug)");
            }
        }
    }
}

/// Greedy argmax with lowest-index tie-break: deterministic for any
/// logits row, independent of batching and thread count.
fn argmax(row: &[f32]) -> i32 {
    let mut best = 0usize;
    let mut best_v = f32::NEG_INFINITY;
    for (i, &v) in row.iter().enumerate() {
        if v > best_v {
            best_v = v;
            best = i;
        }
    }
    best as i32
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::preset;
    use crate::linalg::SupportPattern;

    fn tiny_scheduler(max_batch: usize) -> Scheduler {
        let mut be = NativeBackend::build(
            preset("tiny").unwrap(),
            "sltrain",
            2,
            3e-3,
            100,
            1,
            32,
            0,
            SupportPattern::UniformRandom,
        )
        .unwrap();
        be.init_state(11).unwrap();
        be.drop_optimizer_state().unwrap();
        be.fold_weights().unwrap();
        Scheduler::new(be, max_batch)
    }

    #[test]
    fn argmax_low_index_tie_break() {
        assert_eq!(argmax(&[0.0, 1.0, 1.0]), 1);
        assert_eq!(argmax(&[2.0, 2.0]), 0);
    }

    #[test]
    fn submit_validates() {
        let mut s = tiny_scheduler(2);
        assert!(s.submit(GenRequest { id: 0, prompt: vec![], max_tokens: 4 }).is_err());
        assert!(s.submit(GenRequest { id: 0, prompt: vec![-3], max_tokens: 4 }).is_err());
        assert!(s.submit(GenRequest { id: 0, prompt: vec![99999], max_tokens: 4 }).is_err());
        assert!(s.submit(GenRequest { id: 0, prompt: vec![1], max_tokens: 0 }).is_err());
        let long = vec![1i32; s.backend().preset().seq_len];
        assert!(s.submit(GenRequest { id: 0, prompt: long, max_tokens: 4 }).is_err());
        assert!(s.submit(GenRequest { id: 0, prompt: vec![1, 2, 3], max_tokens: 4 }).is_ok());
    }

    #[test]
    fn batching_does_not_change_outputs() {
        // the same prompts served solo and interleaved produce
        // identical continuations: each sequence carries its own
        // cache, and per-row matmuls are independent of batch-mates
        let prompts: Vec<Vec<i32>> = vec![vec![1, 2, 3], vec![7, 8], vec![4, 5, 6, 9]];
        let mut solo = Vec::new();
        for p in &prompts {
            let mut s = tiny_scheduler(1);
            solo.push(s.generate(p, 6).unwrap().tokens);
        }
        let mut s = tiny_scheduler(2); // fewer slots than requests: queueing
        for (i, p) in prompts.iter().enumerate() {
            s.submit(GenRequest { id: i as u64, prompt: p.clone(), max_tokens: 6 }).unwrap();
        }
        let mut batched: Vec<Option<Vec<i32>>> = vec![None; prompts.len()];
        while !s.is_idle() {
            for r in s.step().unwrap() {
                batched[r.id as usize] = Some(r.tokens);
            }
        }
        for (a, b) in solo.iter().zip(&batched) {
            assert_eq!(b.as_ref(), Some(a));
        }
    }

    #[test]
    fn max_tokens_clamps_to_seq_len() {
        let mut s = tiny_scheduler(1);
        let seq_len = s.backend().preset().seq_len;
        let prompt = vec![1i32; seq_len - 2];
        let r = s.generate(&prompt, 100).unwrap();
        assert_eq!(r.tokens.len(), 2); // only 2 positions left
        assert!(s.is_idle());
    }
}

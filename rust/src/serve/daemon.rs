//! The persistent serving process.
//!
//! One scheduler thread (the caller's) owns the backend and runs the
//! admit/decode/evict loop; one accept thread blocks on the Unix
//! listener; one lightweight thread per connection reads request lines,
//! hands `generate`s to the scheduler through a shared queue, and
//! writes the response when the scheduler completes them. Everything is
//! std-only (`std::os::unix::net`, `std::sync::mpsc`).
//!
//! No busy-waiting: the scheduler loop parks on a condvar while the
//! queue is empty and no sequence is decoding (connection threads
//! `notify_one` on every push), and the accept thread blocks in
//! `accept(2)` (woken at shutdown by a dummy self-connect). The condvar
//! wait is bounded at 100 ms only because a signal handler cannot
//! notify a condvar — that bound is the SIGTERM reaction latency, not a
//! polling interval doing work.
//!
//! Robustness:
//!
//! * **Admission control** — at most `max_queue` generates may be
//!   queued-or-running; excess requests get an immediate
//!   `{"ok":false,"overloaded":true}` shed response instead of
//!   unbounded queue growth.
//! * **Read timeouts** — each connection carries a read timeout; a
//!   peer that stalls mid-request-line is dropped (its partial bytes
//!   discarded), while an *idle* connection with no partial line stays
//!   open indefinitely.
//! * **Graceful shutdown** — SIGINT/SIGTERM (see `util::signal`) is
//!   honored exactly like a `shutdown` request: stop admitting, finish
//!   every in-flight sequence, answer stragglers with a clean error,
//!   unlink the socket, exit 0.
//!
//! Lifecycle: `run` binds the socket (removing a stale file from a
//! crashed predecessor), serves until a `shutdown` request or signal
//! arrives, drains, unlinks the socket, and returns `Ok`. Malformed
//! requests are answered with `{"ok":false,...}` on the same
//! connection; they never terminate the daemon or the connection
//! (tested black-box in `tests/serve_e2e.rs`).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::backend::native::NativeBackend;
use crate::backend::Backend;
use crate::serve::protocol::{self, Request};
use crate::serve::scheduler::{GenRequest, GenResult, Scheduler};
use crate::util::json::{num, obj, s, Json};
use crate::util::signal;

/// Daemon configuration (the `sltrain serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Unix socket path to bind.
    pub socket: PathBuf,
    /// Concurrent decode slots (continuous-batching width).
    pub max_batch: usize,
    /// Admission cap: generates queued-or-running before new ones are
    /// shed with an `overloaded` response.
    pub max_queue: usize,
    /// Per-connection read timeout in seconds: a peer stalled in the
    /// middle of a request line is dropped after this long (idle
    /// connections with no partial line are unaffected).
    pub read_timeout_secs: u64,
}

/// A generate handed from a connection thread to the scheduler loop,
/// with the channel its result travels back on.
type Submission = (GenRequest, Sender<std::result::Result<GenResult, String>>);

struct Shared {
    queue: Mutex<Vec<Submission>>,
    /// Wakes the scheduler loop when a submission or shutdown arrives.
    wake: Condvar,
    shutdown: AtomicBool,
    next_id: AtomicU64,
    /// Generates admitted but not yet answered. Incremented under the
    /// queue lock (so admission-cap checks cannot over-admit),
    /// decremented lock-free only after the response bytes are written
    /// — `run` waits for zero before exiting, so a drained request's
    /// response cannot be lost to the process teardown.
    inflight: AtomicU64,
    max_inflight: u64,
    read_timeout: Duration,
    info_line: String,
}

impl Shared {
    /// True once shutdown began — via `shutdown` request or OS signal.
    fn stopping(&self) -> bool {
        self.shutdown.load(Ordering::SeqCst) || signal::requested()
    }
}

/// Serve `backend` on `cfg.socket` until a `shutdown` request or a
/// SIGINT/SIGTERM drains the daemon. The backend should arrive ready:
/// initialized, checkpoint loaded, optimizer state dropped, and
/// (normally) folded.
pub fn run(backend: NativeBackend, cfg: &ServeConfig) -> Result<()> {
    let mut sched = Scheduler::new(backend, cfg.max_batch);
    if cfg.socket.exists() {
        // a previous daemon that crashed leaves the socket file behind;
        // binding over it needs the unlink first
        std::fs::remove_file(&cfg.socket)
            .with_context(|| format!("removing stale socket {:?}", cfg.socket))?;
    }
    let listener = UnixListener::bind(&cfg.socket)
        .with_context(|| format!("binding {:?}", cfg.socket))?;
    let shared = Arc::new(Shared {
        queue: Mutex::new(Vec::new()),
        wake: Condvar::new(),
        shutdown: AtomicBool::new(false),
        next_id: AtomicU64::new(0),
        inflight: AtomicU64::new(0),
        max_inflight: cfg.max_queue.max(1) as u64,
        read_timeout: Duration::from_secs(cfg.read_timeout_secs.max(1)),
        info_line: info_line(sched.backend()),
    });
    crate::info!(
        "serve: {} / {} on {:?} ({} decode slots, queue cap {}, folded: {})",
        sched.backend().preset().name,
        sched.backend().method(),
        cfg.socket,
        cfg.max_batch,
        cfg.max_queue.max(1),
        sched.backend().is_folded()
    );

    let accept_shared = shared.clone();
    let accept_handle = std::thread::spawn(move || accept_loop(listener, accept_shared));

    // the scheduler loop: drain submissions, step, dispatch results
    let mut waiters: HashMap<u64, Sender<std::result::Result<GenResult, String>>> = HashMap::new();
    loop {
        let subs: Vec<Submission> = {
            let mut q = shared.queue.lock().unwrap();
            // park until there is work (or shutdown): the bounded wait
            // exists solely so an OS signal — which can only flip an
            // atomic, never notify the condvar — is noticed promptly
            while q.is_empty() && sched.is_idle() && !shared.stopping() {
                let (guard, _) = shared
                    .wake
                    .wait_timeout(q, Duration::from_millis(100))
                    .unwrap();
                q = guard;
            }
            std::mem::take(&mut *q)
        };
        if subs.is_empty() && sched.is_idle() && shared.stopping() {
            // nothing queued, nothing decoding: every in-flight
            // sequence has been drained — leave
            break;
        }
        for (req, tx) in subs {
            let rid = req.id;
            match sched.submit(req) {
                Ok(()) => {
                    waiters.insert(rid, tx);
                }
                Err(e) => {
                    let _ = tx.send(Err(format!("{e:#}")));
                }
            }
        }
        for r in sched.step()? {
            if let Some(tx) = waiters.remove(&r.id) {
                let _ = tx.send(Ok(r));
            }
        }
    }
    // stragglers that slipped into the queue after the final drain get
    // a clean error instead of a hung connection
    for (_, tx) in shared.queue.lock().unwrap().drain(..) {
        let _ = tx.send(Err("daemon is shutting down".into()));
    }
    // connection threads are still flushing the responses for requests
    // the drain just completed; exiting now would race those socket
    // writes, so wait (bounded) for the in-flight counter to reach zero
    let t0 = std::time::Instant::now();
    while shared.inflight.load(Ordering::SeqCst) > 0 && t0.elapsed() < Duration::from_secs(10) {
        std::thread::sleep(Duration::from_millis(5));
    }
    // the accept thread blocks in accept(2); raise the flag it checks
    // post-accept, then wake it with a throwaway self-connection
    shared.shutdown.store(true, Ordering::SeqCst);
    let _ = UnixStream::connect(&cfg.socket);
    let _ = accept_handle.join();
    let _ = std::fs::remove_file(&cfg.socket);
    crate::info!("serve: clean shutdown");
    Ok(())
}

fn accept_loop(listener: UnixListener, shared: Arc<Shared>) {
    loop {
        match listener.accept() {
            Ok((stream, _)) => {
                if shared.stopping() {
                    // either the wake-up self-connect or a late client;
                    // dropping the stream gives the client a clean EOF
                    return;
                }
                let conn_shared = shared.clone();
                std::thread::spawn(move || handle_conn(stream, conn_shared));
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => continue,
            Err(_) => return,
        }
    }
}

/// Read one request line into `buf` (which may already hold partial
/// bytes from a timed-out previous call — `read_until` keeps them).
/// Returns `Some(eof)` when a line is ready (`eof`: the peer closed
/// after it), `None` when the connection should be dropped.
fn read_request_line(
    reader: &mut BufReader<UnixStream>,
    buf: &mut Vec<u8>,
    shared: &Shared,
) -> Option<bool> {
    loop {
        match reader.read_until(b'\n', buf) {
            // no new bytes + clean EOF: final (possibly empty) line
            Ok(0) => return Some(true),
            Ok(_) => {
                // EOF can also land mid-line; the bytes so far are the
                // final request
                return Some(buf.last() != Some(&b'\n'));
            }
            Err(e)
                if e.kind() == std::io::ErrorKind::WouldBlock
                    || e.kind() == std::io::ErrorKind::TimedOut =>
            {
                // read timeout. A peer stalled MID-LINE is dead or
                // hostile — drop it (partial bytes and all). An idle
                // connection with no partial line keeps waiting, unless
                // the daemon is draining.
                if !buf.is_empty() || shared.stopping() {
                    return None;
                }
            }
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(_) => return None,
        }
    }
}

fn handle_conn(stream: UnixStream, shared: Arc<Shared>) {
    // bounded reads: without this a wedged peer pins the thread forever
    let _ = stream.set_read_timeout(Some(shared.read_timeout));
    let Ok(read_half) = stream.try_clone() else { return };
    let mut reader = BufReader::new(read_half);
    let mut writer = stream;
    let mut buf: Vec<u8> = Vec::new();
    loop {
        buf.clear();
        let Some(eof) = read_request_line(&mut reader, &mut buf, &shared) else { return };
        let line = String::from_utf8_lossy(&buf).into_owned();
        if !line.trim().is_empty() {
            let resp = match protocol::parse_request(&line) {
                Err(e) => protocol::error_line(&Json::Null, &format!("{e:#}")),
                Ok(Request::Ping) => protocol::pong_line(),
                Ok(Request::Info) => shared.info_line.clone(),
                Ok(Request::Stats) => protocol::stats_line(
                    shared.inflight.load(Ordering::SeqCst),
                    shared.stopping(),
                ),
                Ok(Request::Shutdown) => {
                    // respond BEFORE raising the flag: once the
                    // scheduler loop sees it, the process may exit at
                    // any moment
                    if write_line(&mut writer, &protocol::shutdown_line()).is_err() {
                        return;
                    }
                    shared.shutdown.store(true, Ordering::SeqCst);
                    shared.wake.notify_one();
                    if eof {
                        return;
                    }
                    continue;
                }
                Ok(Request::Generate { id, prompt, max_tokens }) => {
                    // writes its own response (the inflight counter
                    // must not drop until the bytes are out)
                    if !handle_generate(&shared, id, prompt, max_tokens, &mut writer) {
                        return;
                    }
                    if eof {
                        return;
                    }
                    continue;
                }
            };
            if write_line(&mut writer, &resp).is_err() {
                return;
            }
        }
        if eof {
            return;
        }
    }
}

/// Admit + await + answer one generate. Returns false when the
/// connection should be dropped (write failure).
fn handle_generate(
    shared: &Shared,
    id: Json,
    prompt: Vec<i32>,
    max_tokens: usize,
    writer: &mut UnixStream,
) -> bool {
    if shared.stopping() {
        let line = protocol::error_line(&id, "daemon is shutting down");
        return write_line(writer, &line).is_ok();
    }
    // admission under the queue lock: the inflight increment and the
    // push are atomic together, so the cap can never over-admit and a
    // `stats` reading inflight >= 1 proves the submission is queued
    let admitted = {
        let mut q = shared.queue.lock().unwrap();
        if shared.inflight.load(Ordering::SeqCst) >= shared.max_inflight {
            None
        } else {
            shared.inflight.fetch_add(1, Ordering::SeqCst);
            let rid = shared.next_id.fetch_add(1, Ordering::SeqCst);
            let (tx, rx) = channel();
            q.push((GenRequest { id: rid, prompt, max_tokens }, tx));
            Some(rx)
        }
    };
    let Some(rx) = admitted else {
        let line = protocol::overloaded_line(&id, shared.max_inflight);
        return write_line(writer, &line).is_ok();
    };
    shared.wake.notify_one();
    let resp = match rx.recv() {
        Ok(Ok(r)) => protocol::generate_line(&id, r.prompt_len, &r.tokens),
        Ok(Err(msg)) => protocol::error_line(&id, &msg),
        Err(_) => protocol::error_line(&id, "daemon exited before the request completed"),
    };
    let wrote = write_line(writer, &resp).is_ok();
    // only after the response bytes are out: run()'s shutdown path
    // waits on this counter before letting the process exit
    shared.inflight.fetch_sub(1, Ordering::SeqCst);
    wrote
}

fn write_line(w: &mut UnixStream, line: &str) -> std::io::Result<()> {
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

fn info_line(be: &NativeBackend) -> String {
    let p = be.preset();
    obj(vec![
        ("ok", Json::Bool(true)),
        ("op", s("info")),
        ("preset", s(&p.name)),
        ("method", s(be.method())),
        ("vocab", num(p.vocab as f64)),
        ("seq_len", num(p.seq_len as f64)),
        ("n_params", num(be.n_params() as f64)),
        ("folded", Json::Bool(be.is_folded())),
    ])
    .to_string()
}

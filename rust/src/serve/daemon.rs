//! The persistent serving process.
//!
//! One scheduler thread (the caller's) owns the backend and runs the
//! admit/decode/evict loop; one accept thread polls the Unix listener;
//! one lightweight thread per connection reads request lines, hands
//! `generate`s to the scheduler through a shared queue, and writes the
//! response when the scheduler completes them. Everything is std-only
//! (`std::os::unix::net`, `std::sync::mpsc`).
//!
//! Lifecycle: `run` binds the socket (removing a stale file from a
//! crashed predecessor), serves until a `shutdown` request arrives,
//! finishes every in-flight sequence, stops admitting (late `generate`s
//! get an error response), unlinks the socket, and returns `Ok` — the
//! process exits 0. Malformed requests are answered with
//! `{"ok":false,...}` on the same connection; they never terminate the
//! daemon or the connection (tested black-box in `tests/serve_e2e.rs`).

use std::collections::HashMap;
use std::io::{BufRead, BufReader, Write};
use std::os::unix::net::{UnixListener, UnixStream};
use std::path::PathBuf;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{channel, Sender};
use std::sync::{Arc, Mutex};
use std::time::Duration;

use anyhow::{Context, Result};

use crate::backend::native::NativeBackend;
use crate::backend::Backend;
use crate::serve::protocol::{self, Request};
use crate::serve::scheduler::{GenRequest, GenResult, Scheduler};
use crate::util::json::{num, obj, s, Json};

/// Daemon configuration (the `sltrain serve` flags).
#[derive(Debug, Clone)]
pub struct ServeConfig {
    /// Unix socket path to bind.
    pub socket: PathBuf,
    /// Concurrent decode slots (continuous-batching width).
    pub max_batch: usize,
}

/// A generate handed from a connection thread to the scheduler loop,
/// with the channel its result travels back on.
type Submission = (GenRequest, Sender<std::result::Result<GenResult, String>>);

struct Shared {
    queue: Mutex<Vec<Submission>>,
    shutdown: AtomicBool,
    next_id: AtomicU64,
    info_line: String,
}

/// Serve `backend` on `cfg.socket` until a `shutdown` request drains
/// the daemon. The backend should arrive ready: initialized,
/// checkpoint loaded, optimizer state dropped, and (normally) folded.
pub fn run(backend: NativeBackend, cfg: &ServeConfig) -> Result<()> {
    let mut sched = Scheduler::new(backend, cfg.max_batch);
    if cfg.socket.exists() {
        // a previous daemon that crashed leaves the socket file behind;
        // binding over it needs the unlink first
        std::fs::remove_file(&cfg.socket)
            .with_context(|| format!("removing stale socket {:?}", cfg.socket))?;
    }
    let listener = UnixListener::bind(&cfg.socket)
        .with_context(|| format!("binding {:?}", cfg.socket))?;
    listener.set_nonblocking(true)?;
    let shared = Arc::new(Shared {
        queue: Mutex::new(Vec::new()),
        shutdown: AtomicBool::new(false),
        next_id: AtomicU64::new(0),
        info_line: info_line(sched.backend()),
    });
    crate::info!(
        "serve: {} / {} on {:?} ({} decode slots, folded: {})",
        sched.backend().preset().name,
        sched.backend().method(),
        cfg.socket,
        cfg.max_batch,
        sched.backend().is_folded()
    );

    let accept_shared = shared.clone();
    let accept_handle = std::thread::spawn(move || accept_loop(listener, accept_shared));

    // the scheduler loop: drain submissions, step, dispatch results
    let mut waiters: HashMap<u64, Sender<std::result::Result<GenResult, String>>> = HashMap::new();
    loop {
        let subs: Vec<Submission> = std::mem::take(&mut *shared.queue.lock().unwrap());
        for (req, tx) in subs {
            let rid = req.id;
            match sched.submit(req) {
                Ok(()) => {
                    waiters.insert(rid, tx);
                }
                Err(e) => {
                    let _ = tx.send(Err(format!("{e:#}")));
                }
            }
        }
        if sched.is_idle() {
            if shared.shutdown.load(Ordering::SeqCst) {
                break;
            }
            std::thread::sleep(Duration::from_millis(1));
            continue;
        }
        for r in sched.step()? {
            if let Some(tx) = waiters.remove(&r.id) {
                let _ = tx.send(Ok(r));
            }
        }
    }
    // stragglers that slipped into the queue after the final drain get
    // a clean error instead of a hung connection
    for (_, tx) in shared.queue.lock().unwrap().drain(..) {
        let _ = tx.send(Err("daemon is shutting down".into()));
    }
    let _ = accept_handle.join();
    let _ = std::fs::remove_file(&cfg.socket);
    crate::info!("serve: clean shutdown");
    Ok(())
}

fn accept_loop(listener: UnixListener, shared: Arc<Shared>) {
    loop {
        if shared.shutdown.load(Ordering::SeqCst) {
            return;
        }
        match listener.accept() {
            Ok((stream, _)) => {
                // accepted sockets inherit the listener's non-blocking
                // mode on some platforms; connection reads are blocking
                if stream.set_nonblocking(false).is_err() {
                    continue;
                }
                let conn_shared = shared.clone();
                std::thread::spawn(move || handle_conn(stream, conn_shared));
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(Duration::from_millis(5));
            }
            Err(_) => return,
        }
    }
}

fn handle_conn(stream: UnixStream, shared: Arc<Shared>) {
    let Ok(read_half) = stream.try_clone() else { return };
    let reader = BufReader::new(read_half);
    let mut writer = stream;
    for line in reader.lines() {
        let Ok(line) = line else { return };
        if line.trim().is_empty() {
            continue;
        }
        let resp = match protocol::parse_request(&line) {
            Err(e) => protocol::error_line(&Json::Null, &format!("{e:#}")),
            Ok(Request::Ping) => protocol::pong_line(),
            Ok(Request::Info) => shared.info_line.clone(),
            Ok(Request::Shutdown) => {
                // respond BEFORE raising the flag: once the scheduler
                // loop sees it, the process may exit at any moment
                if write_line(&mut writer, &protocol::shutdown_line()).is_err() {
                    return;
                }
                shared.shutdown.store(true, Ordering::SeqCst);
                continue;
            }
            Ok(Request::Generate { id, prompt, max_tokens }) => {
                if shared.shutdown.load(Ordering::SeqCst) {
                    protocol::error_line(&id, "daemon is shutting down")
                } else {
                    let rid = shared.next_id.fetch_add(1, Ordering::SeqCst);
                    let (tx, rx) = channel();
                    shared
                        .queue
                        .lock()
                        .unwrap()
                        .push((GenRequest { id: rid, prompt, max_tokens }, tx));
                    match rx.recv() {
                        Ok(Ok(r)) => protocol::generate_line(&id, r.prompt_len, &r.tokens),
                        Ok(Err(msg)) => protocol::error_line(&id, &msg),
                        Err(_) => {
                            protocol::error_line(&id, "daemon exited before the request completed")
                        }
                    }
                }
            }
        };
        if write_line(&mut writer, &resp).is_err() {
            return;
        }
    }
}

fn write_line(w: &mut UnixStream, line: &str) -> std::io::Result<()> {
    w.write_all(line.as_bytes())?;
    w.write_all(b"\n")?;
    w.flush()
}

fn info_line(be: &NativeBackend) -> String {
    let p = be.preset();
    obj(vec![
        ("ok", Json::Bool(true)),
        ("op", s("info")),
        ("preset", s(&p.name)),
        ("method", s(be.method())),
        ("vocab", num(p.vocab as f64)),
        ("seq_len", num(p.seq_len as f64)),
        ("n_params", num(be.n_params() as f64)),
        ("folded", Json::Bool(be.is_folded())),
    ])
    .to_string()
}

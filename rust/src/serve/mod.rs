//! The serving stack: fold-for-inference daemon with KV-cache decoding
//! and continuous batching.
//!
//! The paper's Table-5 inference recipe is *fold once, serve dense*:
//! `Backend::fold_weights` materializes every adapted linear into a
//! plain dense weight (`scale·B·A ⊕ S` for sltrain, `W0 + scale·B·A`
//! for relora, `scale·B·A` for lowrank), after which generation runs
//! one matmul per linear with no factored or sparse kernels on the hot
//! path. This module is the consumer of that fold:
//!
//! * [`protocol`] — the wire format: newline-delimited JSON over a Unix
//!   socket. One request object per line, one response object per line.
//! * [`scheduler`] — continuous batching over
//!   `NativeBackend::forward_incremental`: sequences are admitted into
//!   the running batch between decode steps and evicted the moment
//!   they finish, so a long generation never blocks a short one.
//! * [`daemon`] — the persistent process: bind the socket, accept
//!   connections, run the scheduler loop until a `shutdown` request
//!   drains it.
//! * [`loadgen`] — a synthetic open-loop load generator (fixed arrival
//!   rate, latency measured from arrival, queueing included) emitting
//!   the tokens/sec + p50/p99 numbers behind `BENCH_serving.json`.
//!
//! ## Protocol
//!
//! Requests (one JSON object per line, `op` selects):
//!
//! ```json
//! {"op":"ping"}
//! {"op":"info"}
//! {"op":"generate","prompt":[1,2,3],"max_tokens":8,"id":7}
//! {"op":"shutdown"}
//! ```
//!
//! Responses always carry `"ok"` (`true`/`false`); errors carry
//! `"error"` with a message and never kill the daemon or the
//! connection. A `generate` response echoes the request's `id`
//! verbatim and returns the greedily-decoded continuation:
//!
//! ```json
//! {"ok":true,"op":"generate","id":7,"prompt_len":3,"tokens":[5,9,2,...]}
//! ```
//!
//! Decoding is greedy argmax (lowest index wins ties), so a served
//! continuation is a pure function of the checkpoint and the prompt —
//! the serving extension of the repo's determinism contract.

pub mod daemon;
pub mod loadgen;
pub mod protocol;
pub mod scheduler;

pub use daemon::{run, ServeConfig};
pub use loadgen::{percentile, run_open_loop, LoadReport, LoadSpec};
pub use protocol::{error_line, parse_request, Request};
pub use scheduler::{GenRequest, GenResult, Scheduler};

//! The training coordinator: the L3 event loop.
//!
//! Owns the whole run: data pipeline feeding, train-step execution,
//! ReLoRA restart scheduling (the paper's eq. 1 baseline), periodic
//! held-out evaluation (perplexity), metric/JSONL emission, throughput
//! accounting, and checkpointing. The compute engine is fully abstract:
//! everything here goes through `dyn Backend`, so the same loop drives
//! the AOT/PJRT path and the pure-rust native path unchanged.
//!
//! Robustness layer (uniform across all five methods):
//!
//! * **Durable checkpoints** — every save is atomic + checksummed and
//!   keeps the last `keep_checkpoints` files (`coordinator::checkpoint`).
//! * **Divergence guard** — a non-finite loss (always) or a loss above
//!   `loss_guard ×` the running EMA (opt-in) rolls the model back to
//!   the newest valid checkpoint and continues past the offending data
//!   window; `max_guard_trips` consecutive trips abort with a
//!   diagnostic instead of looping forever.
//! * **Graceful shutdown** — a SIGINT/SIGTERM (see `util::signal`)
//!   finishes the current step, saves a resumable checkpoint, and
//!   returns cleanly with `interrupted_at` set.

use std::path::{Path, PathBuf};

use anyhow::{bail, Result};

use super::checkpoint::Checkpoint;
use super::metrics::{perplexity, Curve, Ema, Throughput};
use crate::backend::Backend;
use crate::data::Pipeline;
use crate::util::json::{num, obj, s, Json};
use crate::util::logging::MetricsWriter;
use crate::util::{failpoint, signal};

#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub eval_every: usize,
    pub eval_batches: usize,
    pub log_every: usize,
    /// ReLoRA restart period (ignored unless the method is relora)
    pub relora_every: usize,
    pub seed: u32,
    pub metrics_path: Option<PathBuf>,
    pub checkpoint_path: Option<PathBuf>,
    pub checkpoint_every: usize,
    /// How many checkpoints the rotation keeps on disk (min 1): the
    /// newest at `checkpoint_path`, older ones as `.1`, `.2`, …
    pub keep_checkpoints: usize,
    /// Loss-spike guard factor: a step whose loss exceeds `ema × this`
    /// counts as divergence and triggers rollback. `0.0` disables the
    /// spike check; non-finite losses (NaN/Inf) always trip the guard.
    pub loss_guard: f64,
    /// Abort the run (nonzero exit) after this many *consecutive*
    /// guard trips — a persistent divergence no rollback can outrun.
    pub max_guard_trips: usize,
    /// Resume from the newest valid checkpoint in the rotation chain
    /// at `checkpoint_path`: restore state and the step counter,
    /// fast-forward the data stream, and continue to `steps`. The
    /// resumed trajectory is bit-identical to an uninterrupted run
    /// (the lr schedule is a pure function of the absolute step, and
    /// relora merge seeds are step numbers).
    pub resume: bool,
    /// Fine-tune warm start: tensors loaded into the backend right
    /// after `init_state`, BEFORE the `--resume` restore (so resuming a
    /// fine-tune run correctly overrides the warm start with the run's
    /// own newest checkpoint). `optim.*` entries should be filtered out
    /// by the caller when a fresh optimizer is wanted.
    pub init_tensors: Option<Vec<crate::backend::StateTensor>>,
}

impl Default for TrainConfig {
    fn default() -> Self {
        TrainConfig {
            steps: 200,
            eval_every: 50,
            eval_batches: 4,
            log_every: 10,
            relora_every: 100,
            seed: 42,
            metrics_path: None,
            checkpoint_path: None,
            checkpoint_every: 0,
            keep_checkpoints: 2,
            loss_guard: 0.0,
            max_guard_trips: 3,
            resume: false,
            init_tensors: None,
        }
    }
}

#[derive(Debug, Clone)]
pub struct TrainResult {
    pub train_curve: Curve,
    pub eval_curve: Curve,
    pub final_eval_loss: f64,
    pub final_ppl: f64,
    pub tokens_per_sec: f64,
    pub wall_secs: f64,
    pub peak_rss_bytes: u64,
    pub n_params: usize,
    pub relora_merges: usize,
    /// Total divergence-guard trips (each one rolled the model back).
    pub guard_trips: usize,
    /// `Some(step)` when a shutdown signal stopped the run early; the
    /// saved checkpoint makes it resumable at exactly that step.
    pub interrupted_at: Option<usize>,
}

/// Run a full pretraining job on one backend.
pub fn train(
    backend: &mut dyn Backend,
    pipe: &mut Pipeline,
    cfg: &TrainConfig,
) -> Result<TrainResult> {
    let batch = backend.batch_size();
    let seq = backend.seq_len();
    let method = backend.method().to_string();

    backend.init_state(cfg.seed)?;
    if let Some(ts) = &cfg.init_tensors {
        backend.load_state_tensors(ts)?;
        crate::info!("warm start: {} tensors loaded over the fresh init", ts.len());
    }
    if backend.workers() > 1 {
        crate::info!(
            "data-parallel: {} workers x {} rows/step (losses bit-identical to 1 worker)",
            backend.workers(),
            batch
        );
    }

    // --resume: restore state + step counter from the newest VALID
    // checkpoint in the rotation chain (a torn newest file falls back
    // to the previous one), then consume the batches the original run
    // already saw so the data stream lines up with an uninterrupted
    // trajectory. No checkpoint at all degrades to a fresh start
    // (first run of a restartable job).
    let mut start_step = 0usize;
    if cfg.resume {
        let Some(path) = &cfg.checkpoint_path else {
            bail!("--resume needs a checkpoint path");
        };
        match Checkpoint::load_newest_valid(path)? {
            Some((ck, from)) => {
                backend.load_state_tensors(&ck.to_state_tensors())?;
                start_step = ck.step;
                crate::info!("resumed {from:?} at step {start_step}");
            }
            None => crate::info!("resume: no checkpoint at {path:?}, starting fresh"),
        }
    }

    let valid_set = pipe.valid_set(cfg.eval_batches, batch, seq);

    let mut metrics = match &cfg.metrics_path {
        Some(p) => Some(MetricsWriter::create(p)?),
        None => None,
    };

    let mut train_curve = Curve::default();
    let mut eval_curve = Curve::default();
    let mut ema = Ema::new(0.1);
    let mut thr = Throughput::start();
    let mut peak_rss = crate::runtime::current_rss_bytes();
    let mut relora_merges = 0usize;
    let mut guard_trips = 0usize;
    let mut consecutive_trips = 0usize;
    let mut interrupted_at: Option<usize> = None;
    // set when the in-loop periodic save already covered the final step,
    // so the post-loop save doesn't write the same checkpoint twice
    let mut saved_at_final_step = false;

    // replay the already-trained prefix of the data stream (cheap: the
    // synthetic pipeline generates batches, it doesn't store them)
    for _ in 0..start_step.min(cfg.steps) {
        pipe.train.next_batch(batch, seq);
    }

    // while-loop (not a range for): the divergence guard rewinds `step`
    // to a checkpoint, which a range iterator cannot express
    let mut step = start_step;
    while step < cfg.steps {
        // graceful shutdown: the signal flag is polled at step
        // boundaries, so the current optimizer step always completes
        // before we save and leave
        if signal::requested() {
            if let Some(p) = &cfg.checkpoint_path {
                save_checkpoint_rotated(backend, step, p, cfg.keep_checkpoints)?;
            }
            crate::info!("shutdown signal honored — resumable at step {step}");
            interrupted_at = Some(step);
            break;
        }

        let tokens = pipe.train.next_batch(batch, seq);
        let loss = backend.train_step(step as i32, &tokens)? as f64;
        failpoint::hit("train.after_step")?;

        // divergence guard: NaN/Inf always trips; a finite spike trips
        // only when loss_guard is armed and the EMA has a baseline
        let spiked = cfg.loss_guard > 0.0
            && matches!(ema.get(), Some(m) if loss > m * cfg.loss_guard);
        if !loss.is_finite() || spiked {
            guard_trips += 1;
            consecutive_trips += 1;
            crate::warn_!(
                "divergence guard tripped at step {step}: loss {loss} \
                 (trip {consecutive_trips}/{})",
                cfg.max_guard_trips
            );
            if let Some(w) = metrics.as_mut() {
                // loss serialized as a string: NaN has no JSON literal
                w.emit(obj(vec![
                    ("kind", s("guard")),
                    ("step", num(step as f64)),
                    ("loss", s(&loss.to_string())),
                    ("trips", num(guard_trips as f64)),
                ]))?;
            }
            if consecutive_trips >= cfg.max_guard_trips.max(1) {
                bail!(
                    "divergence guard: {consecutive_trips} consecutive trips \
                     (last loss {loss} at step {step}) — rollback cannot outrun \
                     this; check lr/seed/data or raise --loss-guard"
                );
            }
            let Some(path) = &cfg.checkpoint_path else {
                bail!(
                    "divergence at step {step} (loss {loss}) and no checkpoint \
                     path configured to roll back to"
                );
            };
            let Some((ck, from)) = Checkpoint::load_newest_valid(path)? else {
                bail!(
                    "divergence at step {step} (loss {loss}) before the first \
                     checkpoint was saved — nothing to roll back to"
                );
            };
            backend.load_state_tensors(&ck.to_state_tensors())?;
            crate::warn_!(
                "rolled back to step {} from {from:?}; data stream stays \
                 forward-only, so the offending window is skipped",
                ck.step
            );
            step = ck.step;
            // the spike poisoned the EMA baseline; restart smoothing
            ema = Ema::new(0.1);
            continue;
        }
        consecutive_trips = 0;

        thr.add_tokens((batch * seq) as u64);
        let smooth = ema.update(loss);
        train_curve.push(step, loss);
        peak_rss = peak_rss.max(crate::runtime::current_rss_bytes());

        if cfg.log_every > 0 && step % cfg.log_every == 0 {
            crate::info!(
                "step {step:>5} loss {loss:.4} (ema {smooth:.4}) {:.0} tok/s",
                thr.tokens_per_sec()
            );
            if let Some(w) = metrics.as_mut() {
                w.emit(obj(vec![
                    ("kind", s("train")),
                    ("step", num(step as f64)),
                    ("loss", num(loss)),
                    ("ema", num(smooth)),
                    ("tok_s", num(thr.tokens_per_sec())),
                ]))?;
            }
        }

        // ReLoRA restarts: merge low-rank adaptors into W0 + reset moments
        if method == "relora"
            && cfg.relora_every > 0
            && step > 0
            && step % cfg.relora_every == 0
        {
            backend.merge(step as i32)?;
            relora_merges += 1;
            crate::info!("relora merge at step {step} (#{relora_merges})");
        }

        if cfg.eval_every > 0 && (step + 1) % cfg.eval_every == 0 {
            let ev = eval(backend, &valid_set)?;
            eval_curve.push(step + 1, ev);
            crate::info!("eval @ {:>5}: loss {ev:.4} ppl {:.2}", step + 1, perplexity(ev));
            if let Some(w) = metrics.as_mut() {
                w.emit(obj(vec![
                    ("kind", s("eval")),
                    ("step", num((step + 1) as f64)),
                    ("loss", num(ev)),
                    ("ppl", num(perplexity(ev))),
                ]))?;
            }
        }

        if cfg.checkpoint_every > 0 && (step + 1) % cfg.checkpoint_every == 0 {
            if let Some(p) = &cfg.checkpoint_path {
                save_checkpoint_rotated(backend, step + 1, p, cfg.keep_checkpoints)?;
                saved_at_final_step = step + 1 == cfg.steps;
            }
        }

        step += 1;
    }

    let final_eval_loss = match eval_curve.last() {
        Some(v) => v,
        None => eval(backend, &valid_set)?,
    };
    if let Some(p) = &cfg.checkpoint_path {
        // the shutdown branch saved already; don't overwrite its step
        if interrupted_at.is_none() && !saved_at_final_step {
            save_checkpoint_rotated(backend, cfg.steps.max(start_step), p, cfg.keep_checkpoints)?;
        }
    }

    Ok(TrainResult {
        train_curve,
        eval_curve,
        final_eval_loss,
        final_ppl: perplexity(final_eval_loss),
        tokens_per_sec: thr.tokens_per_sec(),
        wall_secs: thr.elapsed_secs(),
        peak_rss_bytes: peak_rss,
        n_params: backend.n_params(),
        relora_merges,
        guard_trips,
        interrupted_at,
    })
}

/// Mean eval loss over a fixed validation set.
pub fn eval(backend: &mut dyn Backend, valid_set: &[Vec<i32>]) -> Result<f64> {
    let mut total = 0.0;
    for batch in valid_set {
        total += backend.eval_loss(batch)? as f64;
    }
    Ok(total / valid_set.len().max(1) as f64)
}

/// Persist the backend's durable state (params + supports) to a
/// self-contained checkpoint (atomic, checksummed, no rotation).
pub fn save_checkpoint(backend: &dyn Backend, step: usize, path: &PathBuf) -> Result<()> {
    Checkpoint::from_tensors(backend.state_tensors()?, step).save(path)?;
    crate::info!("checkpoint @ {step} -> {path:?}");
    Ok(())
}

/// Rotated variant used by the training loop: the previous checkpoint
/// survives as `<path>.1` (and so on up to `keep`), giving the
/// divergence guard and crash recovery a fallback generation.
pub fn save_checkpoint_rotated(
    backend: &dyn Backend,
    step: usize,
    path: &Path,
    keep: usize,
) -> Result<()> {
    Checkpoint::from_tensors(backend.state_tensors()?, step).save_rotated(path, keep)?;
    crate::info!("checkpoint @ {step} -> {path:?} (keep {})", keep.max(1));
    Ok(())
}

/// One-call wrapper used by the bench binaries: build the standard
/// pipeline for the backend's vocab, train `steps`, return the result.
pub fn quick_train(
    backend: &mut dyn Backend,
    steps: usize,
    data_seed: u64,
) -> Result<TrainResult> {
    let mut pipe = Pipeline::build(backend.preset().vocab, data_seed);
    let cfg = TrainConfig {
        steps,
        eval_every: 0,
        eval_batches: 4,
        log_every: 0,
        ..Default::default()
    };
    train(backend, &mut pipe, &cfg)
}

/// Emit a one-line experiment summary (used by the bench binaries).
pub fn summary_json(tag: &str, r: &TrainResult) -> Json {
    obj(vec![
        ("tag", s(tag)),
        ("final_eval_loss", num(r.final_eval_loss)),
        ("ppl", num(r.final_ppl)),
        ("tokens_per_sec", num(r.tokens_per_sec)),
        ("wall_secs", num(r.wall_secs)),
        ("peak_rss_mb", num(r.peak_rss_bytes as f64 / 1e6)),
        ("n_params", num(r.n_params as f64)),
        ("relora_merges", num(r.relora_merges as f64)),
        ("guard_trips", num(r.guard_trips as f64)),
    ])
}

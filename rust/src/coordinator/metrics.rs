//! Training metrics: loss curves, EMA smoothing, perplexity, throughput
//! accounting, and summary statistics shared with the bench harness.

/// Exponential moving average (the loss smoother used in log lines).
#[derive(Debug, Clone)]
pub struct Ema {
    alpha: f64,
    value: Option<f64>,
}

impl Ema {
    pub fn new(alpha: f64) -> Ema {
        assert!((0.0..=1.0).contains(&alpha));
        Ema { alpha, value: None }
    }

    pub fn update(&mut self, x: f64) -> f64 {
        let v = match self.value {
            None => x,
            Some(prev) => self.alpha * x + (1.0 - self.alpha) * prev,
        };
        self.value = Some(v);
        v
    }

    pub fn get(&self) -> Option<f64> {
        self.value
    }
}

pub fn perplexity(mean_ce: f64) -> f64 {
    mean_ce.exp()
}

/// Mean / stddev / min / max over a sample.
#[derive(Debug, Clone, Copy)]
pub struct Stats {
    pub n: usize,
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

pub fn stats(xs: &[f64]) -> Stats {
    if xs.is_empty() {
        return Stats { n: 0, mean: 0.0, std: 0.0, min: 0.0, max: 0.0 };
    }
    let n = xs.len() as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    Stats {
        n: xs.len(),
        mean,
        std: var.sqrt(),
        min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
        max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    }
}

/// Tokens/second meter with monotonic accounting.
#[derive(Debug)]
pub struct Throughput {
    start: std::time::Instant,
    tokens: u64,
}

impl Throughput {
    pub fn start() -> Throughput {
        Throughput { start: std::time::Instant::now(), tokens: 0 }
    }

    pub fn add_tokens(&mut self, n: u64) {
        self.tokens += n;
    }

    pub fn tokens_per_sec(&self) -> f64 {
        let dt = self.start.elapsed().as_secs_f64();
        if dt <= 0.0 {
            0.0
        } else {
            self.tokens as f64 / dt
        }
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

/// Records (step, value) curves and serializes them to CSV.
#[derive(Debug, Default, Clone)]
pub struct Curve {
    pub points: Vec<(usize, f64)>,
}

impl Curve {
    pub fn push(&mut self, step: usize, v: f64) {
        self.points.push((step, v));
    }

    pub fn last(&self) -> Option<f64> {
        self.points.last().map(|&(_, v)| v)
    }

    pub fn to_csv(&self, header: &str) -> String {
        let mut out = format!("step,{header}\n");
        for (s, v) in &self.points {
            out.push_str(&format!("{s},{v}\n"));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ema_converges_to_constant() {
        let mut e = Ema::new(0.2);
        for _ in 0..200 {
            e.update(5.0);
        }
        assert!((e.get().unwrap() - 5.0).abs() < 1e-9);
    }

    #[test]
    fn ema_first_value_passthrough() {
        let mut e = Ema::new(0.1);
        assert_eq!(e.update(3.0), 3.0);
    }

    #[test]
    fn stats_known() {
        let s = stats(&[1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.mean, 2.5);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.std - (1.25f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn perplexity_of_uniform() {
        let v: f64 = 256.0;
        assert!((perplexity(v.ln()) - v).abs() < 1e-6);
    }

    #[test]
    fn curve_csv() {
        let mut c = Curve::default();
        c.push(0, 3.5);
        c.push(10, 2.75);
        let csv = c.to_csv("loss");
        assert!(csv.starts_with("step,loss\n0,3.5\n"));
        assert_eq!(c.last(), Some(2.75));
    }
}

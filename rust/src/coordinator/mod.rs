//! L3 coordination: training loop, checkpoints, metrics, ReLoRA restarts.

pub mod checkpoint;
pub mod metrics;
pub mod trainer;

pub use checkpoint::{Checkpoint, CheckpointError};
pub use trainer::{train, TrainConfig, TrainResult};

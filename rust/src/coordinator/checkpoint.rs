//! Binary checkpoints: params + optimizer state + step counter —
//! crash-safe, checksummed, and rotated.
//!
//! Format: `SLTCKPT1` magic, u64 header length, JSON header describing
//! each tensor (name, shape, dtype, byte offset/length, crc32), then
//! raw little-endian tensor data, then a `SLTCKSUM` footer carrying a
//! whole-file CRC-32. Self-describing, so `analyze` subcommands can
//! load checkpoints without the original manifest.
//!
//! ## Durability contract
//!
//! * **Atomic**: [`Checkpoint::save`] writes `<path>.tmp`, fsyncs it,
//!   renames over `<path>`, and fsyncs the parent directory. A SIGKILL
//!   (or power cut) at any instant leaves either the old checkpoint or
//!   the new one at `<path>` — never a torn file.
//! * **Checksummed**: every tensor carries its own CRC-32 in the
//!   header, and the footer covers all preceding bytes. Loads verify
//!   both and fail with a typed [`CheckpointError`] — never a panic.
//!   The checksum fields are version-gated: pre-footer checkpoints
//!   (older writers) still load, their integrity simply unverified.
//! * **Rotated**: [`Checkpoint::save_rotated`] keeps the last K
//!   checkpoints as `<path>` (newest), `<path>.1`, … `<path>.{K-1}`,
//!   shifting by atomic renames. [`Checkpoint::load_newest_valid`]
//!   walks that chain newest-first and returns the first candidate
//!   that passes validation, warning about the ones that do not — a
//!   corrupted newest checkpoint costs one save interval, not the run.
//!
//! The fail points threaded through the save windows
//! (`checkpoint.save.{before_write,after_header,before_rotate,
//! before_rename,after_rename}`) let the crash harness
//! (`tests/crash_resume.rs`) kill a real training process inside each
//! window and prove `--resume` recovers from all of them.

use std::collections::BTreeMap;
use std::fmt;
use std::io::Write;
use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::StateTensor;
use crate::runtime::Dtype;
use crate::util::crc::{crc32, Crc32};
use crate::util::failpoint;
use crate::util::json::{num, obj, s, Json};

const MAGIC: &[u8; 8] = b"SLTCKPT1";
/// Footer magic: 8 bytes + 4-byte LE CRC-32 of everything before it.
const FOOTER_MAGIC: &[u8; 8] = b"SLTCKSUM";
const FOOTER_LEN: usize = 12;
/// How far past the primary `load_newest_valid` scans for history
/// siblings — a ceiling on `--keep-checkpoints`, not a tuning knob.
const MAX_HISTORY_SCAN: usize = 64;

/// Typed checkpoint validation failures. `Checkpoint::load` returns
/// these (wrapped in `anyhow`, downcastable) instead of panicking on
/// any malformed input — a truncated, corrupted, or zero-byte file is
/// an expected artifact of a crash, not a programming error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum CheckpointError {
    /// Zero-byte file: the crash landed before any write reached disk.
    Empty,
    /// Magic bytes missing or wrong — not a SLTCKPT1 file at all.
    NotACheckpoint,
    /// The declared header extends past the end of the file.
    TruncatedHeader {
        /// Bytes actually present in the file.
        have: usize,
        /// Bytes the header length field claims to need.
        need: usize,
    },
    /// The header is present but not parseable (bad utf-8/JSON/field).
    BadHeader(String),
    /// A tensor's declared byte range extends past the end of the file.
    TruncatedTensor {
        /// The tensor whose payload is cut short.
        name: String,
        /// Bytes actually present in the file.
        have: usize,
        /// File offset the tensor's payload runs to.
        need: usize,
    },
    /// A CRC-32 check failed (`scope` is a tensor name, or "file" for
    /// the whole-file footer).
    CrcMismatch {
        /// What the checksum covered: a tensor name or "file".
        scope: String,
        /// The checksum recorded at save time.
        stored: u32,
        /// The checksum recomputed from the bytes on disk.
        computed: u32,
    },
}

impl fmt::Display for CheckpointError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            CheckpointError::Empty => {
                write!(f, "zero-byte checkpoint (crash before any bytes reached disk)")
            }
            CheckpointError::NotACheckpoint => write!(f, "not a SLTCKPT1 checkpoint (bad magic)"),
            CheckpointError::TruncatedHeader { have, need } => {
                write!(f, "truncated header: file has {have} bytes, header needs {need}")
            }
            CheckpointError::BadHeader(msg) => write!(f, "bad checkpoint header: {msg}"),
            CheckpointError::TruncatedTensor { name, have, need } => write!(
                f,
                "truncated tensor payload: {name:?} runs to byte {need}, file has {have}"
            ),
            CheckpointError::CrcMismatch { scope, stored, computed } => write!(
                f,
                "crc32 mismatch on {scope}: stored {stored:#010x}, computed {computed:#010x}"
            ),
        }
    }
}

impl std::error::Error for CheckpointError {}

pub struct Checkpoint {
    pub step: usize,
    /// name -> (shape, dtype, raw bytes)
    pub tensors: BTreeMap<String, (Vec<usize>, Dtype, Vec<u8>)>,
}

/// `<path>` with `suffix` appended to the full file name (keeps the
/// original extension: `ckpt.bin` -> `ckpt.bin.1` / `ckpt.bin.tmp`).
fn sibling(path: &Path, suffix: &str) -> PathBuf {
    let mut os = path.as_os_str().to_os_string();
    os.push(suffix);
    PathBuf::from(os)
}

/// The i-th rotated history sibling (1 = previous, 2 = older, ...).
pub fn history_path(path: &Path, i: usize) -> PathBuf {
    sibling(path, &format!(".{i}"))
}

fn tmp_path(path: &Path) -> PathBuf {
    sibling(path, ".tmp")
}

/// fsync the directory containing `path`, making a just-completed
/// rename durable. Best-effort: opening a directory read-only works on
/// the unix targets we ship on; elsewhere the rename is still atomic.
fn sync_dir(path: &Path) {
    if let Some(dir) = path.parent() {
        let dir = if dir.as_os_str().is_empty() { Path::new(".") } else { dir };
        if let Ok(d) = std::fs::File::open(dir) {
            let _ = d.sync_all();
        }
    }
}

impl Checkpoint {
    /// Snapshot a backend's interchange tensors (the engine-agnostic
    /// path: any `Backend::state_tensors` output checkpoints this way).
    pub fn from_tensors(tensors: Vec<StateTensor>, step: usize) -> Checkpoint {
        let tensors = tensors
            .into_iter()
            .map(|t| (t.name, (t.shape, t.dtype, t.bytes)))
            .collect();
        Checkpoint { step, tensors }
    }

    /// Back to interchange tensors (`Backend::load_state_tensors` input).
    pub fn to_state_tensors(&self) -> Vec<StateTensor> {
        self.tensors
            .iter()
            .map(|(name, (shape, dtype, bytes))| StateTensor {
                name: name.clone(),
                shape: shape.clone(),
                dtype: *dtype,
                bytes: bytes.clone(),
            })
            .collect()
    }

    /// Atomic, checksummed save (no rotation): write `<path>.tmp`,
    /// fsync, rename over `<path>`, fsync the directory.
    pub fn save(&self, path: &Path) -> Result<()> {
        self.save_rotated(path, 1)
    }

    /// Atomic save keeping the last `keep` checkpoints: the previous
    /// `<path>` survives as `<path>.1`, and so on up to
    /// `<path>.{keep-1}`. Every transition is a single rename, so a
    /// kill at any instant leaves a chain `load_newest_valid` can
    /// recover from (worst case: the newest entry is mid-shift and the
    /// previous one is selected instead).
    pub fn save_rotated(&self, path: &Path, keep: usize) -> Result<()> {
        let keep = keep.max(1);
        failpoint::hit("checkpoint.save.before_write")?;
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        // the new checkpoint becomes fully durable at <path>.tmp BEFORE
        // anything existing is touched
        let tmp = tmp_path(path);
        self.write_file(&tmp).with_context(|| format!("writing {tmp:?}"))?;
        failpoint::hit("checkpoint.save.before_rotate")?;
        if keep > 1 && path.exists() {
            let _ = std::fs::remove_file(history_path(path, keep - 1));
            for i in (1..keep - 1).rev() {
                let from = history_path(path, i);
                if from.exists() {
                    let _ = std::fs::rename(&from, history_path(path, i + 1));
                }
            }
            let _ = std::fs::rename(path, history_path(path, 1));
        }
        failpoint::hit("checkpoint.save.before_rename")?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
        failpoint::hit("checkpoint.save.after_rename")?;
        sync_dir(path);
        Ok(())
    }

    /// Serialize to `tmp` and fsync it: magic, header (with per-tensor
    /// CRCs), payload, whole-file CRC footer.
    fn write_file(&self, tmp: &Path) -> Result<()> {
        let mut offset = 0u64;
        let mut entries: Vec<Json> = vec![];
        for (name, (shape, dtype, bytes)) in &self.tensors {
            entries.push(obj(vec![
                ("name", s(name)),
                (
                    "shape",
                    Json::Arr(shape.iter().map(|&d| num(d as f64)).collect()),
                ),
                ("dtype", s(dtype_name(*dtype))),
                ("offset", num(offset as f64)),
                ("len", num(bytes.len() as f64)),
                // per-tensor integrity: pinpoints WHICH tensor a
                // flipped bit landed in (the footer only says "some")
                ("crc32", num(crc32(bytes) as f64)),
            ]));
            offset += bytes.len() as u64;
        }
        let header = obj(vec![
            ("step", num(self.step as f64)),
            ("tensors", Json::Arr(entries)),
        ])
        .to_string();

        let file = std::fs::File::create(tmp)?;
        let mut f = std::io::BufWriter::new(file);
        let mut crc = Crc32::new();
        fn put(
            f: &mut std::io::BufWriter<std::fs::File>,
            crc: &mut Crc32,
            bytes: &[u8],
        ) -> std::io::Result<()> {
            f.write_all(bytes)?;
            crc.update(bytes);
            Ok(())
        }
        put(&mut f, &mut crc, MAGIC)?;
        put(&mut f, &mut crc, &(header.len() as u64).to_le_bytes())?;
        put(&mut f, &mut crc, header.as_bytes())?;
        failpoint::hit("checkpoint.save.after_header")?;
        for (_, (_, _, bytes)) in &self.tensors {
            put(&mut f, &mut crc, bytes)?;
        }
        // footer: covers magic + header + payload (not itself)
        f.write_all(FOOTER_MAGIC)?;
        f.write_all(&crc.finalize().to_le_bytes())?;
        f.flush()?;
        // fsync BEFORE the rename: the atomic swap must only ever
        // install bytes that are already durable
        f.get_ref().sync_all()?;
        Ok(())
    }

    /// Load and validate `<path>`. Any malformed input — truncated,
    /// corrupted, empty, or foreign — yields a typed
    /// [`CheckpointError`] (downcastable through the `anyhow` chain);
    /// this function never panics on file content.
    pub fn load(path: &Path) -> Result<Checkpoint> {
        failpoint::hit("checkpoint.load.before_read")?;
        let data = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        Self::from_bytes(&data).with_context(|| format!("loading {path:?}"))
    }

    /// Parse + validate the serialized form (the body of [`load`]).
    pub fn from_bytes(data: &[u8]) -> Result<Checkpoint> {
        if data.is_empty() {
            return Err(CheckpointError::Empty.into());
        }
        if data.len() < 16 || &data[..8] != MAGIC {
            return Err(CheckpointError::NotACheckpoint.into());
        }
        let hlen = u64::from_le_bytes(data[8..16].try_into().unwrap()) as usize;
        let hend = 16usize
            .checked_add(hlen)
            .ok_or(CheckpointError::TruncatedHeader { have: data.len(), need: usize::MAX })?;
        let hbytes = data
            .get(16..hend)
            .ok_or(CheckpointError::TruncatedHeader { have: data.len(), need: hend })?;
        let header = std::str::from_utf8(hbytes)
            .map_err(|e| CheckpointError::BadHeader(format!("non-utf8 header: {e}")))?;
        let v = Json::parse(header).map_err(|e| CheckpointError::BadHeader(e.to_string()))?;
        let bad = |e: anyhow::Error| CheckpointError::BadHeader(format!("{e:#}"));
        let step = v.req("step").map_err(bad)?.as_usize().unwrap_or(0);
        let base = hend;
        let mut tensors = BTreeMap::new();
        let mut payload_end = base;
        for e in v.req("tensors").map_err(bad)?.as_arr().unwrap_or(&[]) {
            let name = e.req("name").map_err(bad)?.as_str().unwrap_or_default().to_string();
            let shape: Vec<usize> = e
                .req("shape")
                .map_err(bad)?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let dtype = Dtype::parse(e.req("dtype").map_err(bad)?.as_str().unwrap_or("f32"))
                .map_err(bad)?;
            let off = base
                .checked_add(e.req("offset").map_err(bad)?.as_usize().unwrap_or(0))
                .ok_or_else(|| CheckpointError::BadHeader(format!("{name}: offset overflow")))?;
            let len = e.req("len").map_err(bad)?.as_usize().unwrap_or(0);
            let end = off
                .checked_add(len)
                .ok_or_else(|| CheckpointError::BadHeader(format!("{name}: length overflow")))?;
            let bytes = data
                .get(off..end)
                .ok_or_else(|| CheckpointError::TruncatedTensor {
                    name: name.clone(),
                    have: data.len(),
                    need: end,
                })?
                .to_vec();
            // version gate: pre-checksum checkpoints have no crc32
            // field — they load, their integrity just unverified
            if let Some(stored) = e.get("crc32").and_then(|c| c.as_f64()) {
                let stored = stored as u32;
                let computed = crc32(&bytes);
                if stored != computed {
                    return Err(CheckpointError::CrcMismatch {
                        scope: name,
                        stored,
                        computed,
                    }
                    .into());
                }
            }
            payload_end = payload_end.max(end);
            tensors.insert(name, (shape, dtype, bytes));
        }
        // whole-file footer (also version-gated): catches corruption in
        // the header itself, which per-tensor checks can miss
        if let Some(footer) = data.get(payload_end..payload_end + FOOTER_LEN) {
            if &footer[..8] == FOOTER_MAGIC {
                let stored = u32::from_le_bytes(footer[8..12].try_into().unwrap());
                let computed = crc32(&data[..payload_end]);
                if stored != computed {
                    return Err(CheckpointError::CrcMismatch {
                        scope: "file".into(),
                        stored,
                        computed,
                    }
                    .into());
                }
            }
        }
        Ok(Checkpoint { step, tensors })
    }

    /// Walk the rotation chain newest-first (`<path>`, `<path>.1`, …)
    /// and return the first checkpoint that passes validation plus the
    /// path it came from. Candidates that fail are warned about and
    /// skipped — a torn newest checkpoint falls back to the previous
    /// one instead of killing the run. `Ok(None)` when no candidate
    /// file exists at all (a restartable job's first run); an error
    /// only when candidates exist and none validates.
    pub fn load_newest_valid(path: &Path) -> Result<Option<(Checkpoint, PathBuf)>> {
        let mut candidates = vec![path.to_path_buf()];
        for i in 1..=MAX_HISTORY_SCAN {
            let h = history_path(path, i);
            if !h.exists() {
                break;
            }
            candidates.push(h);
        }
        let mut failures: Vec<String> = vec![];
        for cand in &candidates {
            if !cand.exists() {
                continue;
            }
            match Checkpoint::load(cand) {
                Ok(ck) => {
                    if !failures.is_empty() {
                        crate::warn_!(
                            "resume: falling back to {cand:?} (step {})",
                            ck.step
                        );
                    }
                    return Ok(Some((ck, cand.clone())));
                }
                Err(e) => {
                    crate::warn_!("checkpoint {cand:?} failed validation: {e:#}");
                    failures.push(format!("{cand:?}: {e:#}"));
                }
            }
        }
        if failures.is_empty() {
            Ok(None)
        } else {
            bail!(
                "no valid checkpoint for {path:?} — every candidate failed validation: {}",
                failures.join("; ")
            )
        }
    }

    /// Fetch one f32 tensor (analysis path).
    pub fn tensor_f32(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let (shape, dtype, bytes) = self
            .tensors
            .get(name)
            .ok_or_else(|| anyhow!("checkpoint has no tensor {name:?}"))?;
        if *dtype != Dtype::F32 {
            bail!("{name} is not f32");
        }
        let v = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok((shape.clone(), v))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }
}

fn dtype_name(d: Dtype) -> &'static str {
    match d {
        Dtype::F32 => "f32",
        Dtype::I32 => "i32",
        Dtype::I8 => "i8",
        Dtype::U32 => "u32",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sltrain-ckpt-{tag}-{}", std::process::id()))
    }

    fn small_ckpt(step: usize, seed: f32) -> Checkpoint {
        let tensors = vec![
            StateTensor::f32("w", vec![2, 3], &[seed, 2.0, 3.0, 4.0, 5.0, 6.0]),
            StateTensor::i32("idx", vec![3], &[7, 8, 9]),
        ];
        Checkpoint::from_tensors(tensors, step)
    }

    fn kind(e: &anyhow::Error) -> Option<&CheckpointError> {
        e.downcast_ref::<CheckpointError>()
    }

    #[test]
    fn save_load_roundtrip() {
        let ck = small_ckpt(42, 1.0);
        let dir = tmp_dir("rt");
        let path = dir.join("test.ckpt");
        ck.save(&path).unwrap();

        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 42);
        let (shape, w) = loaded.tensor_f32("w").unwrap();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(w, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let back = loaded.to_state_tensors();
        assert_eq!(back.len(), 2);
        let by_name = |n: &str| back.iter().find(|t| t.name == n).unwrap();
        assert_eq!(by_name("w").to_f32().unwrap(), w);
        assert_eq!(by_name("idx").to_i32().unwrap(), vec![7, 8, 9]);
        // atomic save leaves no tmp residue
        assert!(!tmp_path(&path).exists(), "tmp file left behind");
        std::fs::remove_dir_all(dir).ok();
    }

    /// Bit-identical round-trip for every dtype the interchange format
    /// carries, including non-finite f32 payloads and raw i8 moments.
    #[test]
    fn roundtrip_is_bit_identical_per_dtype() {
        let f32_bits: Vec<f32> = vec![0.0, -0.0, 1.5e-39, f32::INFINITY, f32::NAN, -7.25];
        let i32_vals: Vec<i32> = vec![i32::MIN, -1, 0, 1, i32::MAX];
        let i8_bytes: Vec<u8> = vec![0, 1, 127, 128, 255];
        let tensors = vec![
            StateTensor::f32("a.f32", vec![2, 3], &f32_bits),
            StateTensor::i32("b.i32", vec![5], &i32_vals),
            StateTensor {
                name: "c.i8".into(),
                shape: vec![5],
                dtype: Dtype::I8,
                bytes: i8_bytes.clone(),
            },
        ];
        let want: Vec<Vec<u8>> = tensors.iter().map(|t| t.bytes.clone()).collect();
        let dir = tmp_dir("dtype");
        let path = dir.join("dtypes.ckpt");
        Checkpoint::from_tensors(tensors, 7).save(&path).unwrap();

        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 7);
        for (i, name) in ["a.f32", "b.i32", "c.i8"].iter().enumerate() {
            let (_, dtype, bytes) = &loaded.tensors[*name];
            assert_eq!(bytes, &want[i], "{name} bytes drifted");
            match i {
                0 => assert_eq!(*dtype, Dtype::F32),
                1 => assert_eq!(*dtype, Dtype::I32),
                _ => assert_eq!(*dtype, Dtype::I8),
            }
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = tmp_dir("junk");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        let err = Checkpoint::load(&path).unwrap_err();
        assert_eq!(kind(&err), Some(&CheckpointError::NotACheckpoint));
        std::fs::remove_dir_all(dir).ok();
    }

    /// Each crash artifact class yields its typed error — never a panic.
    #[test]
    fn malformed_files_give_typed_errors() {
        let dir = tmp_dir("malformed");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("good.ckpt");
        small_ckpt(3, 1.0).save(&path).unwrap();
        let good = std::fs::read(&path).unwrap();
        let put = |name: &str, bytes: &[u8]| {
            let p = dir.join(name);
            std::fs::write(&p, bytes).unwrap();
            p
        };

        // zero-byte file
        let e = Checkpoint::load(&put("empty.ckpt", b"")).unwrap_err();
        assert_eq!(kind(&e), Some(&CheckpointError::Empty));

        // truncated inside the magic/length prelude
        let e = Checkpoint::load(&put("prelude.ckpt", &good[..10])).unwrap_err();
        assert_eq!(kind(&e), Some(&CheckpointError::NotACheckpoint));

        // truncated inside the header
        let e = Checkpoint::load(&put("header.ckpt", &good[..20])).unwrap_err();
        assert!(
            matches!(kind(&e), Some(CheckpointError::TruncatedHeader { .. })),
            "got {e:#}"
        );

        // truncated inside the tensor payload (cut the last 20 bytes:
        // footer + part of the final tensor)
        let e = Checkpoint::load(&put("payload.ckpt", &good[..good.len() - 20])).unwrap_err();
        assert!(
            matches!(kind(&e), Some(CheckpointError::TruncatedTensor { .. })),
            "got {e:#}"
        );

        // flipped bit in a tensor payload -> per-tensor crc mismatch
        // naming the tensor
        let mut corrupt = good.clone();
        let n = corrupt.len();
        corrupt[n - FOOTER_LEN - 2] ^= 0x40;
        let e = Checkpoint::load(&put("bitflip.ckpt", &corrupt)).unwrap_err();
        match kind(&e) {
            Some(CheckpointError::CrcMismatch { scope, .. }) => {
                assert_ne!(scope, "file", "per-tensor check should fire first");
            }
            other => panic!("expected CrcMismatch, got {other:?} ({e:#})"),
        }

        // corrupted footer checksum -> whole-file mismatch
        let mut corrupt = good.clone();
        let n = corrupt.len();
        corrupt[n - 1] ^= 0xFF;
        let e = Checkpoint::load(&put("footer.ckpt", &corrupt)).unwrap_err();
        assert!(
            matches!(kind(&e), Some(CheckpointError::CrcMismatch { scope, .. }) if scope == "file"),
            "got {e:#}"
        );

        std::fs::remove_dir_all(dir).ok();
    }

    /// Pre-checksum checkpoints (no crc32 fields, no footer) still load
    /// — the integrity layer is version-gated, not a format break.
    #[test]
    fn legacy_format_without_checksums_loads() {
        let data: Vec<f32> = vec![1.5, -2.0, 0.25];
        let payload: Vec<u8> = data.iter().flat_map(|x| x.to_le_bytes()).collect();
        let header = format!(
            r#"{{"step":5,"tensors":[{{"name":"w","shape":[3],"dtype":"f32","offset":0,"len":{}}}]}}"#,
            payload.len()
        );
        let mut bytes = Vec::new();
        bytes.extend_from_slice(MAGIC);
        bytes.extend_from_slice(&(header.len() as u64).to_le_bytes());
        bytes.extend_from_slice(header.as_bytes());
        bytes.extend_from_slice(&payload);
        let ck = Checkpoint::from_bytes(&bytes).unwrap();
        assert_eq!(ck.step, 5);
        assert_eq!(ck.tensor_f32("w").unwrap().1, data);
    }

    #[test]
    fn save_rotated_keeps_history_and_caps_it() {
        let dir = tmp_dir("rotate");
        let path = dir.join("ckpt.bin");
        for step in [1usize, 2, 3] {
            small_ckpt(step, step as f32).save_rotated(&path, 2).unwrap();
        }
        // keep=2: primary (step 3) + one history slot (step 2); step 1 gone
        assert_eq!(Checkpoint::load(&path).unwrap().step, 3);
        assert_eq!(Checkpoint::load(&history_path(&path, 1)).unwrap().step, 2);
        assert!(!history_path(&path, 2).exists(), "keep=2 must cap history at .1");
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn load_newest_valid_prefers_primary_and_falls_back() {
        let dir = tmp_dir("newest");
        let path = dir.join("ckpt.bin");
        small_ckpt(1, 1.0).save_rotated(&path, 3).unwrap();
        small_ckpt(2, 2.0).save_rotated(&path, 3).unwrap();

        // intact chain: primary wins
        let (ck, from) = Checkpoint::load_newest_valid(&path).unwrap().unwrap();
        assert_eq!((ck.step, from), (2, path.clone()));

        // torn primary (simulated mid-write kill): previous one wins
        std::fs::write(&path, &std::fs::read(&path).unwrap()[..30]).unwrap();
        let (ck, from) = Checkpoint::load_newest_valid(&path).unwrap().unwrap();
        assert_eq!((ck.step, from), (1, history_path(&path, 1)));

        // every candidate corrupt: a hard, diagnostic error
        std::fs::write(history_path(&path, 1), b"garbage").unwrap();
        let err = Checkpoint::load_newest_valid(&path).unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("ckpt.bin"), "diagnostic must name the files: {msg}");

        // no candidates at all: fresh start, not an error
        let none = Checkpoint::load_newest_valid(&dir.join("absent.bin")).unwrap();
        assert!(none.is_none());
        std::fs::remove_dir_all(dir).ok();
    }

    /// A save over an existing (even corrupt) primary replaces it
    /// atomically — the tmp+rename path never appends or half-writes.
    #[test]
    fn save_replaces_corrupt_primary_cleanly() {
        let dir = tmp_dir("replace");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("ckpt.bin");
        std::fs::write(&path, b"torn garbage from a crashed writer").unwrap();
        small_ckpt(9, 1.0).save(&path).unwrap();
        assert_eq!(Checkpoint::load(&path).unwrap().step, 9);
        std::fs::remove_dir_all(dir).ok();
    }
}

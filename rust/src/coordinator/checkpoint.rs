//! Binary checkpoints: params + optimizer state + step counter.
//!
//! Format: `SLTCKPT1` magic, u64 header length, JSON header describing
//! each tensor (name, shape, dtype, byte offset/length), then raw
//! little-endian tensor data. Self-describing, so `analyze` subcommands
//! can load checkpoints without the original manifest.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::runtime::{lit_f32, lit_i32, Dtype, State};
use crate::util::json::{num, obj, s, Json};

const MAGIC: &[u8; 8] = b"SLTCKPT1";

pub struct Checkpoint {
    pub step: usize,
    /// name -> (shape, dtype, raw bytes)
    pub tensors: BTreeMap<String, (Vec<usize>, Dtype, Vec<u8>)>,
}

impl Checkpoint {
    /// Snapshot the named tensors out of a runtime state.
    pub fn from_state(state: &State, names: &[(String, Vec<usize>, Dtype)], step: usize) -> Result<Checkpoint> {
        let mut tensors = BTreeMap::new();
        for (name, shape, dtype) in names {
            let lit = state.get(name)?;
            let bytes = match dtype {
                Dtype::F32 => {
                    let v = lit.to_vec::<f32>().map_err(|e| anyhow!("{name}: {e}"))?;
                    v.iter().flat_map(|x| x.to_le_bytes()).collect::<Vec<u8>>()
                }
                Dtype::I32 => {
                    let v = lit.to_vec::<i32>().map_err(|e| anyhow!("{name}: {e}"))?;
                    v.iter().flat_map(|x| x.to_le_bytes()).collect()
                }
                Dtype::U32 => {
                    let v = lit.to_vec::<u32>().map_err(|e| anyhow!("{name}: {e}"))?;
                    v.iter().flat_map(|x| x.to_le_bytes()).collect()
                }
                Dtype::I8 => {
                    let v = lit.to_vec::<i8>().map_err(|e| anyhow!("{name}: {e}"))?;
                    v.iter().map(|&x| x as u8).collect()
                }
            };
            tensors.insert(name.clone(), (shape.clone(), *dtype, bytes));
        }
        Ok(Checkpoint { step, tensors })
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut offset = 0u64;
        let mut entries: Vec<Json> = vec![];
        for (name, (shape, dtype, bytes)) in &self.tensors {
            entries.push(obj(vec![
                ("name", s(name)),
                (
                    "shape",
                    Json::Arr(shape.iter().map(|&d| num(d as f64)).collect()),
                ),
                ("dtype", s(dtype_name(*dtype))),
                ("offset", num(offset as f64)),
                ("len", num(bytes.len() as f64)),
            ]));
            offset += bytes.len() as u64;
        }
        let header = obj(vec![
            ("step", num(self.step as f64)),
            ("tensors", Json::Arr(entries)),
        ])
        .to_string();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, (_, _, bytes)) in &self.tensors {
            f.write_all(bytes)?;
        }
        f.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let data = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if data.len() < 16 || &data[..8] != MAGIC {
            bail!("{path:?}: not a SLTCKPT1 checkpoint");
        }
        let hlen = u64::from_le_bytes(data[8..16].try_into()?) as usize;
        let header = std::str::from_utf8(&data[16..16 + hlen])?;
        let v = Json::parse(header).map_err(|e| anyhow!("checkpoint header: {e}"))?;
        let step = v.req("step")?.as_usize().unwrap_or(0);
        let base = 16 + hlen;
        let mut tensors = BTreeMap::new();
        for e in v.req("tensors")?.as_arr().unwrap_or(&[]) {
            let name = e.req("name")?.as_str().unwrap_or_default().to_string();
            let shape: Vec<usize> = e
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let dtype = Dtype::parse(e.req("dtype")?.as_str().unwrap_or("f32"))?;
            let off = base + e.req("offset")?.as_usize().unwrap_or(0);
            let len = e.req("len")?.as_usize().unwrap_or(0);
            let bytes = data
                .get(off..off + len)
                .ok_or_else(|| anyhow!("checkpoint truncated at {name}"))?
                .to_vec();
            tensors.insert(name, (shape, dtype, bytes));
        }
        Ok(Checkpoint { step, tensors })
    }

    /// Materialize all tensors back into a runtime state.
    pub fn restore_into(&self, state: &mut State) -> Result<()> {
        for (name, (shape, dtype, bytes)) in &self.tensors {
            match dtype {
                Dtype::F32 => {
                    let v: Vec<f32> = bytes
                        .chunks_exact(4)
                        .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    state.put(name, lit_f32(shape, &v)?);
                }
                Dtype::I32 | Dtype::U32 => {
                    let v: Vec<i32> = bytes
                        .chunks_exact(4)
                        .map(|c| i32::from_le_bytes(c.try_into().unwrap()))
                        .collect();
                    state.put(name, lit_i32(shape, &v)?);
                }
                Dtype::I8 => {
                    let v: Vec<i8> = bytes.iter().map(|&b| b as i8).collect();
                    state.put(name, crate::runtime::lit_i8(shape, &v)?);
                }
            }
        }
        Ok(())
    }

    /// Fetch one f32 tensor (analysis path).
    pub fn tensor_f32(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let (shape, dtype, bytes) = self
            .tensors
            .get(name)
            .ok_or_else(|| anyhow!("checkpoint has no tensor {name:?}"))?;
        if *dtype != Dtype::F32 {
            bail!("{name} is not f32");
        }
        let v = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok((shape.clone(), v))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }
}

fn dtype_name(d: Dtype) -> &'static str {
    match d {
        Dtype::F32 => "f32",
        Dtype::I32 => "i32",
        Dtype::I8 => "i8",
        Dtype::U32 => "u32",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn save_load_roundtrip() {
        let mut state = State::new();
        state.put("w", lit_f32(&[2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]).unwrap());
        state.put("idx", lit_i32(&[3], &[7, 8, 9]).unwrap());
        let names = vec![
            ("w".to_string(), vec![2, 3], Dtype::F32),
            ("idx".to_string(), vec![3], Dtype::I32),
        ];
        let ck = Checkpoint::from_state(&state, &names, 42).unwrap();
        let dir = std::env::temp_dir().join(format!("sltrain-ckpt-{}", std::process::id()));
        let path = dir.join("test.ckpt");
        ck.save(&path).unwrap();

        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 42);
        let (shape, w) = loaded.tensor_f32("w").unwrap();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(w, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);

        let mut restored = State::new();
        loaded.restore_into(&mut restored).unwrap();
        assert_eq!(restored.to_f32("w").unwrap(), w);
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = std::env::temp_dir().join(format!("sltrain-ckpt2-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn missing_tensor_errors() {
        let state = State::new();
        let names = vec![("nope".to_string(), vec![1], Dtype::F32)];
        assert!(Checkpoint::from_state(&state, &names, 0).is_err());
    }
}

//! Binary checkpoints: params + optimizer state + step counter.
//!
//! Format: `SLTCKPT1` magic, u64 header length, JSON header describing
//! each tensor (name, shape, dtype, byte offset/length), then raw
//! little-endian tensor data. Self-describing, so `analyze` subcommands
//! can load checkpoints without the original manifest.

use std::collections::BTreeMap;
use std::io::Write;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::backend::StateTensor;
use crate::runtime::Dtype;
use crate::util::json::{num, obj, s, Json};

const MAGIC: &[u8; 8] = b"SLTCKPT1";

pub struct Checkpoint {
    pub step: usize,
    /// name -> (shape, dtype, raw bytes)
    pub tensors: BTreeMap<String, (Vec<usize>, Dtype, Vec<u8>)>,
}

impl Checkpoint {
    /// Snapshot a backend's interchange tensors (the engine-agnostic
    /// path: any `Backend::state_tensors` output checkpoints this way).
    pub fn from_tensors(tensors: Vec<StateTensor>, step: usize) -> Checkpoint {
        let tensors = tensors
            .into_iter()
            .map(|t| (t.name, (t.shape, t.dtype, t.bytes)))
            .collect();
        Checkpoint { step, tensors }
    }

    /// Back to interchange tensors (`Backend::load_state_tensors` input).
    pub fn to_state_tensors(&self) -> Vec<StateTensor> {
        self.tensors
            .iter()
            .map(|(name, (shape, dtype, bytes))| StateTensor {
                name: name.clone(),
                shape: shape.clone(),
                dtype: *dtype,
                bytes: bytes.clone(),
            })
            .collect()
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(dir) = path.parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut offset = 0u64;
        let mut entries: Vec<Json> = vec![];
        for (name, (shape, dtype, bytes)) in &self.tensors {
            entries.push(obj(vec![
                ("name", s(name)),
                (
                    "shape",
                    Json::Arr(shape.iter().map(|&d| num(d as f64)).collect()),
                ),
                ("dtype", s(dtype_name(*dtype))),
                ("offset", num(offset as f64)),
                ("len", num(bytes.len() as f64)),
            ]));
            offset += bytes.len() as u64;
        }
        let header = obj(vec![
            ("step", num(self.step as f64)),
            ("tensors", Json::Arr(entries)),
        ])
        .to_string();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(header.len() as u64).to_le_bytes())?;
        f.write_all(header.as_bytes())?;
        for (_, (_, _, bytes)) in &self.tensors {
            f.write_all(bytes)?;
        }
        f.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Checkpoint> {
        let data = std::fs::read(path).with_context(|| format!("reading {path:?}"))?;
        if data.len() < 16 || &data[..8] != MAGIC {
            bail!("{path:?}: not a SLTCKPT1 checkpoint");
        }
        let hlen = u64::from_le_bytes(data[8..16].try_into()?) as usize;
        let header = std::str::from_utf8(&data[16..16 + hlen])?;
        let v = Json::parse(header).map_err(|e| anyhow!("checkpoint header: {e}"))?;
        let step = v.req("step")?.as_usize().unwrap_or(0);
        let base = 16 + hlen;
        let mut tensors = BTreeMap::new();
        for e in v.req("tensors")?.as_arr().unwrap_or(&[]) {
            let name = e.req("name")?.as_str().unwrap_or_default().to_string();
            let shape: Vec<usize> = e
                .req("shape")?
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .map(|d| d.as_usize().unwrap_or(0))
                .collect();
            let dtype = Dtype::parse(e.req("dtype")?.as_str().unwrap_or("f32"))?;
            let off = base + e.req("offset")?.as_usize().unwrap_or(0);
            let len = e.req("len")?.as_usize().unwrap_or(0);
            let bytes = data
                .get(off..off + len)
                .ok_or_else(|| anyhow!("checkpoint truncated at {name}"))?
                .to_vec();
            tensors.insert(name, (shape, dtype, bytes));
        }
        Ok(Checkpoint { step, tensors })
    }

    /// Fetch one f32 tensor (analysis path).
    pub fn tensor_f32(&self, name: &str) -> Result<(Vec<usize>, Vec<f32>)> {
        let (shape, dtype, bytes) = self
            .tensors
            .get(name)
            .ok_or_else(|| anyhow!("checkpoint has no tensor {name:?}"))?;
        if *dtype != Dtype::F32 {
            bail!("{name} is not f32");
        }
        let v = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        Ok((shape.clone(), v))
    }

    pub fn names(&self) -> Vec<&str> {
        self.tensors.keys().map(|s| s.as_str()).collect()
    }
}

fn dtype_name(d: Dtype) -> &'static str {
    match d {
        Dtype::F32 => "f32",
        Dtype::I32 => "i32",
        Dtype::I8 => "i8",
        Dtype::U32 => "u32",
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tmp_dir(tag: &str) -> std::path::PathBuf {
        std::env::temp_dir().join(format!("sltrain-ckpt-{tag}-{}", std::process::id()))
    }

    #[test]
    fn save_load_roundtrip() {
        let tensors = vec![
            StateTensor::f32("w", vec![2, 3], &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]),
            StateTensor::i32("idx", vec![3], &[7, 8, 9]),
        ];
        let ck = Checkpoint::from_tensors(tensors, 42);
        let dir = tmp_dir("rt");
        let path = dir.join("test.ckpt");
        ck.save(&path).unwrap();

        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 42);
        let (shape, w) = loaded.tensor_f32("w").unwrap();
        assert_eq!(shape, vec![2, 3]);
        assert_eq!(w, vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let back = loaded.to_state_tensors();
        assert_eq!(back.len(), 2);
        let by_name = |n: &str| back.iter().find(|t| t.name == n).unwrap();
        assert_eq!(by_name("w").to_f32().unwrap(), w);
        assert_eq!(by_name("idx").to_i32().unwrap(), vec![7, 8, 9]);
        std::fs::remove_dir_all(dir).ok();
    }

    /// Bit-identical round-trip for every dtype the interchange format
    /// carries, including non-finite f32 payloads and raw i8 moments.
    #[test]
    fn roundtrip_is_bit_identical_per_dtype() {
        let f32_bits: Vec<f32> = vec![0.0, -0.0, 1.5e-39, f32::INFINITY, f32::NAN, -7.25];
        let i32_vals: Vec<i32> = vec![i32::MIN, -1, 0, 1, i32::MAX];
        let i8_bytes: Vec<u8> = vec![0, 1, 127, 128, 255];
        let tensors = vec![
            StateTensor::f32("a.f32", vec![2, 3], &f32_bits),
            StateTensor::i32("b.i32", vec![5], &i32_vals),
            StateTensor {
                name: "c.i8".into(),
                shape: vec![5],
                dtype: Dtype::I8,
                bytes: i8_bytes.clone(),
            },
        ];
        let want: Vec<Vec<u8>> = tensors.iter().map(|t| t.bytes.clone()).collect();
        let dir = tmp_dir("dtype");
        let path = dir.join("dtypes.ckpt");
        Checkpoint::from_tensors(tensors, 7).save(&path).unwrap();

        let loaded = Checkpoint::load(&path).unwrap();
        assert_eq!(loaded.step, 7);
        for (i, name) in ["a.f32", "b.i32", "c.i8"].iter().enumerate() {
            let (_, dtype, bytes) = &loaded.tensors[*name];
            assert_eq!(bytes, &want[i], "{name} bytes drifted");
            match i {
                0 => assert_eq!(*dtype, Dtype::F32),
                1 => assert_eq!(*dtype, Dtype::I32),
                _ => assert_eq!(*dtype, Dtype::I8),
            }
        }
        std::fs::remove_dir_all(dir).ok();
    }

    #[test]
    fn rejects_garbage_file() {
        let dir = tmp_dir("junk");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("junk.ckpt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
        std::fs::remove_dir_all(dir).ok();
    }
}

#!/usr/bin/env python3
"""Compare a fresh BENCH_quality.json against the committed baseline.

Gate: any matched (config, method) row whose held-out perplexity RISES
more than --max-rise-pct (default 2%) vs the baseline fails the run
(exit 1) — quality regressions gate just like throughput regressions
(compare_bench.py), but in the opposite direction: lower ppl is better,
so only increases fail. next_token_acc and induction_gap are reported
informationally; they are noisier at smoke-test step counts and are
reviewed by hand.

Rows are matched on the identity keys present in both records:
(config, method). Rows only present on one side are reported, not
failed, so adding a method or preset never breaks CI.

A baseline with a top-level "bootstrap": true marker (or non-positive
ppl values) is a schema placeholder committed before any runner
measured real numbers: the comparison is printed but the gate is
skipped. Refresh the snapshot per BENCH_baseline/README.md to arm it.

Usage:
  python3 scripts/compare_quality.py BENCH_baseline/BENCH_quality.json BENCH_quality.json
  python3 scripts/compare_quality.py --max-rise-pct 5 <baseline.json> <new.json>

stdlib only; exit 0 = pass (or unarmed baseline), exit 1 = regression.
"""

import argparse
import json
import sys

IDENTITY_KEYS = ("config", "method")


def row_key(row):
    return tuple((k, row[k]) for k in IDENTITY_KEYS if k in row)


def fmt_key(key):
    return "/".join(f"{k}={v}" for k, v in key)


def load_rows(path):
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("results", [])
    if not isinstance(rows, list):
        sys.exit(f"error: {path}: 'results' is not a list")
    return doc, {row_key(r): r for r in rows if isinstance(r, dict)}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed snapshot JSON (BENCH_baseline/...)")
    ap.add_argument("new", help="freshly emitted quality JSON")
    ap.add_argument(
        "--max-rise-pct",
        type=float,
        default=2.0,
        help="max tolerated held-out perplexity rise vs baseline (default 2)",
    )
    args = ap.parse_args()

    base_doc, base_rows = load_rows(args.baseline)
    _, new_rows = load_rows(args.new)
    bootstrap = bool(base_doc.get("bootstrap"))

    failures = []
    for key, new in sorted(new_rows.items()):
        base = base_rows.get(key)
        label = fmt_key(key) or "<unkeyed>"
        if base is None:
            print(f"  [new]  {label}: no baseline row")
            continue
        if "ppl" in new and "ppl" in base:
            b, n = float(base["ppl"]), float(new["ppl"])
            if b <= 0.0:
                print(f"  [skip] {label}: baseline ppl not armed ({b})")
            else:
                delta = 100.0 * (n - b) / b
                status = "ok"
                if delta > args.max_rise_pct:
                    status = "FAIL"
                    failures.append((label, b, n, delta))
                print(f"  [{status:>4}] {label}: ppl {b:.2f} -> {n:.2f} ({delta:+.2f}%)")
        for extra in ("next_token_acc", "induction_gap"):
            if extra in new and extra in base and float(base[extra]) != 0.0:
                b, n = float(base[extra]), float(new[extra])
                print(f"  [info] {label}: {extra} {b:.4f} -> {n:.4f}")
    for key in sorted(set(base_rows) - set(new_rows)):
        print(f"  [gone] {fmt_key(key)}: baseline row not re-measured")

    if failures and bootstrap:
        print("\nbootstrap baseline: regressions reported but not gating")
        return 0
    if failures:
        print(f"\n{len(failures)} row(s) regressed beyond +{args.max_rise_pct:.0f}% ppl:")
        for label, b, n, delta in failures:
            print(f"  {label}: ppl {b:.2f} -> {n:.2f} ({delta:+.2f}%)")
        return 1
    print(
        "\nquality comparison passed"
        + (" (bootstrap baseline, gate unarmed)" if bootstrap else "")
    )
    return 0


if __name__ == "__main__":
    sys.exit(main())

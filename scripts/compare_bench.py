#!/usr/bin/env python3
"""Compare a fresh bench JSON against the committed BENCH_baseline snapshot.

Gate: any matched row whose tokens_per_sec drops more than --max-drop-pct
(default 15%) vs the baseline fails the run (exit 1). Memory rows
(total_bytes) are reported informationally but never gate — byte
footprints move with config changes by design and are reviewed by hand.

Rows are matched on the identity keys present in both records:
(config, method, threads, workers, optim_bits, support). Rows only
present on one side are reported, not failed, so adding a bench cell
(e.g. a new worker count) never breaks CI; old baselines without a
"workers" field still match because absent keys are skipped per row.

A baseline with a top-level "bootstrap": true marker (or zeroed
tokens_per_sec values) is a schema placeholder committed before any
runner measured real numbers: the comparison is printed but the gate is
skipped. Refresh the snapshot per BENCH_baseline/README.md to arm it.

Usage:
  python3 scripts/compare_bench.py BENCH_baseline/BENCH_steploop.json BENCH_steploop.json
  python3 scripts/compare_bench.py --max-drop-pct 10 <baseline.json> <new.json>

stdlib only; exit 0 = pass (or unarmed baseline), exit 1 = regression.
"""

import argparse
import json
import sys

IDENTITY_KEYS = ("config", "method", "threads", "workers", "optim_bits", "support")


def row_key(row):
    return tuple((k, row[k]) for k in IDENTITY_KEYS if k in row)


def fmt_key(key):
    return "/".join(f"{k}={v}" for k, v in key)


def load_rows(path):
    with open(path) as f:
        doc = json.load(f)
    rows = doc.get("results", [])
    if not isinstance(rows, list):
        sys.exit(f"error: {path}: 'results' is not a list")
    return doc, {row_key(r): r for r in rows if isinstance(r, dict)}


def main():
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed snapshot JSON (BENCH_baseline/...)")
    ap.add_argument("new", help="freshly emitted bench JSON")
    ap.add_argument(
        "--max-drop-pct",
        type=float,
        default=15.0,
        help="max tolerated tokens/sec drop vs baseline (default 15)",
    )
    args = ap.parse_args()

    base_doc, base_rows = load_rows(args.baseline)
    _, new_rows = load_rows(args.new)
    bootstrap = bool(base_doc.get("bootstrap"))

    failures = []
    for key, new in sorted(new_rows.items()):
        base = base_rows.get(key)
        label = fmt_key(key) or "<unkeyed>"
        if base is None:
            print(f"  [new]  {label}: no baseline row")
            continue
        if "tokens_per_sec" in new and "tokens_per_sec" in base:
            b, n = float(base["tokens_per_sec"]), float(new["tokens_per_sec"])
            if b <= 0.0:
                print(f"  [skip] {label}: baseline tokens/sec not armed ({b})")
            else:
                delta = 100.0 * (n - b) / b
                status = "ok"
                if delta < -args.max_drop_pct:
                    status = "FAIL"
                    failures.append((label, b, n, delta))
                print(
                    f"  [{status:>4}] {label}: {b:.0f} -> {n:.0f} tok/s ({delta:+.1f}%)"
                )
        if "total_bytes" in new and "total_bytes" in base:
            b, n = float(base["total_bytes"]), float(new["total_bytes"])
            if b > 0.0:
                print(
                    f"  [info] {label}: total {b/1e6:.3f} -> {n/1e6:.3f} MB "
                    f"({100.0 * (n - b) / b:+.1f}%)"
                )
    for key in sorted(set(base_rows) - set(new_rows)):
        print(f"  [gone] {fmt_key(key)}: baseline row not re-measured")

    if failures and bootstrap:
        print("\nbootstrap baseline: regressions reported but not gating")
        return 0
    if failures:
        print(f"\n{len(failures)} row(s) regressed beyond {args.max_drop_pct:.0f}%:")
        for label, b, n, delta in failures:
            print(f"  {label}: {b:.0f} -> {n:.0f} tok/s ({delta:+.1f}%)")
        return 1
    print("\nbench comparison passed" + (" (bootstrap baseline, gate unarmed)" if bootstrap else ""))
    return 0


if __name__ == "__main__":
    sys.exit(main())

#!/usr/bin/env sh
# Enable the AOT/PJRT execution path: uncomment the vendored `xla` path
# dependency in rust/Cargo.toml so `cargo build --features xla` links
# the third_party_xla bindings. The dependency line is commented out in
# the committed tree so the default offline build never resolves the
# bindings' crates.io dependencies (bindgen, cc, zip, ...).
#
#   scripts/enable_xla.sh            # uncomment the dep line
#   scripts/enable_xla.sh --revert   # re-comment it (back to offline default)
#
# Building with the feature additionally needs the XLA C++ extension:
# set XLA_EXTENSION_DIR to an unpacked xla_extension release (defaults
# to third_party_xla/xla_extension).

set -eu
cd "$(dirname "$0")/.."
manifest=rust/Cargo.toml

if [ "${1:-}" = "--revert" ]; then
    sed -i.bak 's|^xla = { path = "../third_party_xla" }|# xla = { path = "../third_party_xla" }   # required by `--features xla`|' "$manifest"
    rm -f "$manifest.bak"
    echo "xla dependency commented out in $manifest (offline default)"
    exit 0
fi

if grep -q '^xla = { path = "../third_party_xla" }' "$manifest"; then
    echo "xla dependency already enabled in $manifest"
    exit 0
fi

sed -i.bak 's|^# xla = { path = "../third_party_xla" }.*|xla = { path = "../third_party_xla" }|' "$manifest"
rm -f "$manifest.bak"

if grep -q '^xla = { path = "../third_party_xla" }' "$manifest"; then
    echo "xla dependency enabled in $manifest"
    echo "next: cargo build --release --features xla"
else
    echo "error: could not find the commented xla dependency line in $manifest" >&2
    exit 1
fi

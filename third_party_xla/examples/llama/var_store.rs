use xla::{ArrayElement, ElementType, FromRawBytes, PjRtBuffer, PjRtClient, Result, XlaOp};

#[allow(dead_code)]
#[derive(Clone)]
struct NamedVar {
    path: String,
    ty: ElementType,
    dims: Vec<usize>,
    is_arg: bool,
}

#[derive(Clone)]
pub struct VarBuilder {
    path: Vec<String>,
    vars: std::rc::Rc<std::cell::RefCell<Vec<NamedVar>>>,
    builder: xla::XlaBuilder,
    default_buffer_type_for_var: ElementType,
    default_op_type_for_var: ElementType,
}

#[allow(dead_code)]
pub struct VarStore {
    vars: Vec<NamedVar>,
}

impl VarBuilder {
    pub fn new<B: ArrayElement, O: ArrayElement>(builder: &xla::XlaBuilder) -> Self {
        let vars = std::rc::Rc::new(std::cell::RefCell::new(vec![]));
        Self {
            builder: builder.clone(),
            path: vec![],
            vars,
            default_buffer_type_for_var: B::TY,
            default_op_type_for_var: O::TY,
        }
    }

    pub fn len(&self) -> usize {
        self.vars.borrow().len()
    }

    pub fn var_(
        &mut self,
        s: &str,
        ty: ElementType,
        dims: &[usize],
        is_arg: bool,
    ) -> Result<XlaOp> {
        let path = format!("{}.{s}", self.path.join("."));
        let mut vars = self.vars.borrow_mut();
        let dims64 = dims.iter().map(|c| *c as i64).collect::<Vec<_>>();
        let id = vars.len();
        let parameter = self.builder.parameter(id as i64, ty, &dims64, &path);
        vars.push(NamedVar { path, ty, dims: dims.to_vec(), is_arg });
        parameter
    }

    pub fn var(&mut self, s: &str, dims: &[usize]) -> Result<XlaOp> {
        let v = self.var_(s, self.default_buffer_type_for_var, dims, false)?;
        v.convert(self.default_op_type_for_var.primitive_type())
    }

    pub fn arg(&mut self, s: &str, ty: ElementType, dims: &[usize]) -> Result<XlaOp> {
        self.var_(s, ty, dims, true)
    }

    pub fn into_store(self) -> VarStore {
        let vars = self.vars.borrow();
        VarStore { vars: vars.to_vec() }
    }
}

impl<S: ToString> std::ops::Div<S> for &VarBuilder {
    type Output = VarBuilder;

    fn div(self, rhs: S) -> VarBuilder {
        let mut path = self.path.clone();
        path.push(rhs.to_string());
        VarBuilder {
            path,
            vars: self.vars.clone(),
            builder: self.builder.clone(),
            default_op_type_for_var: self.default_op_type_for_var,
            default_buffer_type_for_var: self.default_buffer_type_for_var,
        }
    }
}

impl<S: ToString> std::ops::Div<S> for VarBuilder {
    type Output = VarBuilder;

    fn div(self, rhs: S) -> VarBuilder {
        &self / rhs
    }
}

impl VarStore {
    pub fn arg_indexes(&self) -> Vec<usize> {
        self.vars
            .iter()
            .enumerate()
            .filter_map(|(i, n)| if n.is_arg { Some(i) } else { None })
            .collect()
    }

    pub fn load_from_npz<P: AsRef<std::path::Path>>(
        &mut self,
        path: P,
        c: &PjRtClient,
    ) -> Result<Vec<PjRtBuffer>> {
        let names: Vec<_> = self
            .vars
            .iter()
            .filter_map(|n| if n.is_arg { None } else { Some(n.path.as_str()) })
            .collect();
        let mut weight_buffers = PjRtBuffer::read_npz_by_name(path, c, &names)?;
        let mut buffers = vec![];
        for var in self.vars.iter() {
            let buffer = if var.is_arg {
                let ty = var.ty;
                let element_count: usize = var.dims.iter().product();
                let element_size_in_bytes = ty.element_size_in_bytes();
                let data = vec![0u8; element_count * element_size_in_bytes];
                c.buffer_from_host_raw_bytes(ty, &data, &var.dims, None)?
            } else {
                // meh
                weight_buffers.remove(0)
            };
            buffers.push(buffer)
        }
        Ok(buffers)
    }
}

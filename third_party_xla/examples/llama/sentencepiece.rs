// A very naive sentencepiece encoder/decoder, this only supports the BPE model and not the unigram
// one.
use anyhow::{bail, Context, Result};
use std::collections::{HashMap, HashSet};
pub struct Tokenizer {
    encoder: HashMap<Vec<u8>, usize>,
    // TODO: Maybe use a vec instead of a hashmap?
    decoder: HashMap<usize, String>,
    bpe_ranks: HashMap<(Vec<u8>, Vec<u8>), usize>,
}

const DELIM: char = '▁';

impl Tokenizer {
    pub fn from_file<P: AsRef<std::path::Path>>(path: P) -> Result<Self> {
        let reader = std::io::BufReader::new(std::fs::File::open(path)?);
        let config: serde_json::Value = serde_json::from_reader(reader)?;
        let model = config.get("model").context("no model key")?;
        let type_ =
            model.get("type").context("no model.type key")?.as_str().context("not a string")?;
        if type_ != "BPE" {
            bail!(format!("model type is not BPE: {type_}"))
        }
        let vocab = model
            .get("vocab")
            .context("no model.vocab key")?
            .as_object()
            .context("model.vocab not an object")?;
        let single_chars: HashSet<u8> = vocab
            .iter()
            .filter_map(|(key, _)| {
                let b = key.as_bytes();
                if b.len() == 1 {
                    Some(b[0])
                } else {
                    None
                }
            })
            .collect();
        let encoder = vocab
            .iter()
            .rev()
            .map(|(key, value)| {
                let key = key
                    .strip_prefix("<0x")
                    .and_then(|s| s.strip_suffix('>'))
                    .and_then(|s| u8::from_str_radix(s, 16).ok())
                    .and_then(|s| if single_chars.contains(&s) { None } else { Some(s) })
                    .map_or_else(|| key.as_bytes().to_vec(), |s| vec![s]);
                value.as_i64().context("not an int").map(|v| (key, v as usize))
            })
            .collect::<Result<HashMap<_, _>>>()?;
        let bpe_ranks = model
            .get("merges")
            .context("no model.merges key")?
            .as_array()
            .context("model.merges not an array")?
            .iter()
            .enumerate()
            .map(|(i, value)| {
                let value = value.as_str().context("not a string")?;
                match value.split_once(' ') {
                    Some((v1, v2)) => {
                        let key = (v1.as_bytes().to_vec(), v2.as_bytes().to_vec());
                        Ok((key, i))
                    }
                    None => bail!(format!("no space in merge '{value}'")),
                }
            })
            .collect::<Result<HashMap<_, _>>>()?;
        let decoder = encoder
            .iter()
            .map(|(k, v)| (*v, String::from_utf8_lossy(k).replace(DELIM, " ")))
            .collect();
        Ok(Self { encoder, decoder, bpe_ranks })
    }

    fn get_pairs(word: &[Vec<u8>]) -> HashSet<(Vec<u8>, Vec<u8>)> {
        let mut pairs = HashSet::new();
        for (i, v) in word.iter().enumerate() {
            if i > 0 {
                pairs.insert((word[i - 1].clone(), v.clone()));
            }
        }
        pairs
    }

    fn bpe(&self, s: &str) -> Vec<usize> {
        let mut buffer = [0u8; 4];
        let mut word: Vec<Vec<u8>> = vec![];
        for c in s.chars() {
            let buffer = c.encode_utf8(&mut buffer);
            word.push(buffer.as_bytes().to_vec())
        }
        if word.is_empty() {
            return Vec::new();
        }
        while word.len() > 1 {
            let mut current_min = None;
            let pairs = Self::get_pairs(&word);
            for p in pairs.iter() {
                match self.bpe_ranks.get(p) {
                    None => {}
                    Some(v) => {
                        let should_replace = match current_min {
                            None => true,
                            Some((current_min, _)) => v < current_min,
                        };
                        if should_replace {
                            current_min = Some((v, p))
                        }
                    }
                }
            }
            let (first, second) = match current_min {
                None => break,
                Some((_v, (first, second))) => (first, second),
            };
            let mut new_word = vec![];
            let mut index = 0;
            while index < word.len() {
                let w = &word[index];
                if index + 1 < word.len() && w == first && &word[index + 1] == second {
                    let mut first_and_second = first.clone();
                    first_and_second.extend_from_slice(second);
                    new_word.push(first_and_second);
                    index += 2
                } else {
                    new_word.push(w.clone());
                    index += 1
                }
            }
            word = new_word
        }
        word.iter().filter_map(|x| self.encoder.get(x)).copied().collect()
    }

    // Run bpe on the whole string, very very inefficient but should be good enough for
    // prompts. The original string should first be split on whitespace/...
    pub fn encode(&self, s: &str) -> Result<Vec<usize>> {
        let mut buffer = [0u8; 4];
        let s = format!("{DELIM}{}", s.replace(' ', DELIM.encode_utf8(&mut buffer)));
        Ok(self.bpe(&s))
    }

    pub fn decode(&self, tokens: &[usize]) -> String {
        tokens.iter().map(|token| self.decoder[token].as_str()).collect()
    }
}

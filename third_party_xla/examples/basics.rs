use anyhow::Result;
extern crate xla;

fn main() -> Result<()> {
    xla::set_tf_min_log_level(xla::TfLogLevel::Warning);

    let client = xla::PjRtClient::cpu()?;
    println!("{} {} {}", client.platform_name(), client.platform_version(), client.device_count());
    for device in client.devices().iter() {
        println!(
            "{} {} {} {}",
            device.id(),
            device.to_string(),
            device.debug_string(),
            device.kind()
        )
    }
    let xla_builder = xla::XlaBuilder::new("test");
    let cst42 = xla_builder.constant_r0(42f32)?;
    let cst43 = xla_builder.constant_r1(&[43f32, 44f32])?;
    let sum = (cst42 + cst43)?;
    println!("Shape: {:?}", xla_builder.get_shape(&sum));
    let sum = sum.build()?;
    let result = client.compile(&sum)?;
    let result = &result.execute::<xla::Literal>(&[])?[0][0].to_literal_sync()?;
    let shape = result.shape()?;
    println!(
        "Result: {:?} {:?} {}",
        shape,
        result.to_vec::<f32>(),
        result.get_first_element::<f32>()?,
    );
    let param = xla_builder.parameter_s(0, &xla::Shape::array::<f32>(vec![]), "p")?;
    let sum = param.add_(&param)?;
    let sum = sum.sqrt()?.build()?;
    let result = client.compile(&sum)?;
    let result = result.execute(&[xla::Literal::from(12f32)])?[0][0].to_literal_sync()?;
    println!("Result: {:?} {:?}", result.shape(), result.get_first_element::<f32>());
    let result = client.compile(&sum)?;
    let result = result.execute(&[xla::Literal::from(13f32)])?[0][0].to_literal_sync()?;
    println!("Result: {:?} {:?}", result.shape(), result.get_first_element::<f32>());
    Ok(())
}

//! Figures 10/11 (Appendix D): singular-value composition of TRAINED
//! SLTrain weights — the low-rank factor owns the spectrum head, the
//! sparse factor owns the tail, and the combined spectrum extends past
//! rank r (which pure low-rank cannot do).
//!
//!   cargo bench --bench fig10_spectrum -- --steps 300

use std::path::Path;

use sltrain::analysis::SpectrumDecomp;
use sltrain::bench::{fmt, Table};
use sltrain::data::Pipeline;
use sltrain::linalg::Matrix;
use sltrain::runtime::{Artifact, Runtime};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("fig10_spectrum", "Fig 10/11 spectrum decomposition")
        .opt("steps", "200", "sltrain pretraining steps")
        .opt("csv", "results/fig10.csv", "output CSV")
        .parse_env();
    let rt = Runtime::cpu()?;

    println!("pretraining tiny_sltrain for {} steps...", a.usize("steps"));
    let mut art = Artifact::load(Path::new("artifacts/tiny_sltrain"))?;
    let mut pipe = Pipeline::build(art.manifest.preset.vocab, 7);
    let mut state = art.init_state(&rt, 42)?;
    let batch = art.entry("train_step")?.batch;
    let seq = art.manifest.seq_len();
    for step in 0..a.usize("steps") {
        let toks = pipe.train.next_batch(batch, seq);
        art.train_step(&rt, &mut state, step as i32, &toks)?;
    }

    let scale = (art.manifest.preset.alpha / art.manifest.preset.rank as f64) as f32;
    let rank = art.manifest.preset.rank;
    let mut t = Table::new(
        "Fig 10/11 — spectrum attribution of trained SLTrain weights",
        &["weight", "sigma[0]", "sigma[r]", "L head", "L tail", "S head", "S tail"],
    );
    let mut csv = String::from("weight,index,sigma,lowrank,sparse\n");
    for (name, sup) in art.manifest.supports.clone() {
        let base = name.trim_end_matches(".idx").to_string();
        let (bs, bv) = shape_vec(&art, &state, &format!("{base}.B"))?;
        let (as_, av) = shape_vec(&art, &state, &format!("{base}.A"))?;
        let (_, vals) = shape_vec(&art, &state, &format!("{base}.vals"))?;
        let idx_raw = std::fs::read(art.dir.join(&sup.file))?;
        let idx: Vec<u32> = idx_raw
            .chunks_exact(4)
            .map(|c| u32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        let b = Matrix::from_vec(bs[0], bs[1], bv);
        let am = Matrix::from_vec(as_[0], as_[1], av);
        let dec = SpectrumDecomp::compute(&b, &am, &idx, &vals, scale);
        let (lh, lt, sh, st) = dec.head_tail_split();
        t.row(vec![
            base.clone(),
            fmt(dec.sigma[0] as f64, 4),
            fmt(dec.sigma.get(rank).copied().unwrap_or(0.0) as f64, 4),
            fmt(lh as f64, 4),
            fmt(lt as f64, 4),
            fmt(sh as f64, 4),
            fmt(st as f64, 4),
        ]);
        for i in 0..dec.sigma.len() {
            csv.push_str(&format!(
                "{base},{i},{},{},{}\n",
                dec.sigma[i], dec.lowrank_contrib[i], dec.sparse_contrib[i]
            ));
        }
    }
    t.print();
    std::fs::create_dir_all("results")?;
    std::fs::write(a.str("csv"), csv)?;
    println!("\npaper shape: sigma has a cliff at index r (low-rank head), a nonzero\ntail past r contributed by S; L-tail ≈ 0 while S-tail > 0 (Fig 11).");
    Ok(())
}

fn shape_vec(
    art: &Artifact,
    state: &sltrain::runtime::State,
    name: &str,
) -> anyhow::Result<(Vec<usize>, Vec<f32>)> {
    let spec = art
        .manifest
        .params
        .iter()
        .find(|t| t.name == name)
        .ok_or_else(|| anyhow::anyhow!("no spec for {name}"))?;
    Ok((spec.shape.clone(), state.to_f32(name)?))
}

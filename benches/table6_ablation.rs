//! Table 6: how rank r and sparsity δ trade off against perplexity and
//! memory. Paper shape: more parameters (higher r or δ) → better ppl,
//! with δ the cheaper axis (sparse params are a small fraction).
//!
//!   cargo bench --bench table6_ablation -- --steps 250

use std::path::Path;

use sltrain::backend::xla_backend::XlaBackend;
use sltrain::bench::{fmt, Table};
use sltrain::coordinator::trainer::quick_train;
use sltrain::mem::{estimate, MemEstimate, MemOptions};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("table6_ablation", "Table 6 (r, delta) ablation")
        .opt("steps", "100", "train steps per cell")
        .opt("csv", "results/table6.csv", "output CSV")
        .parse_env();
    let steps = a.usize("steps");

    // artifact suffix -> (r, delta) description
    let cells: Vec<(&str, &str)> = vec![
        ("artifacts/tiny_sltrain_r8", "r=8,  d=0.03"),
        ("artifacts/tiny_sltrain", "r=16, d=0.03"),
        ("artifacts/tiny_sltrain_r24", "r=24, d=0.03"),
        ("artifacts/tiny_sltrain_d001", "r=16, d=0.01"),
        ("artifacts/tiny_sltrain_d005", "r=16, d=0.05"),
        ("artifacts/tiny_full", "full-rank"),
    ];

    let mut t = Table::new(
        &format!("Table 6 — (r, delta) ablation, tiny, {steps} steps"),
        &["setting", "ppl", "param(M)", "est mem(G)"],
    );
    for (dir, label) in cells {
        if !Path::new(dir).exists() {
            println!("[skip] {dir}");
            continue;
        }
        let mut be = XlaBackend::open(Path::new(dir))?;
        let r = quick_train(&mut be, steps, 7)?;
        let man = be.manifest();
        let method = man.method.as_str();
        let e = estimate(&man.preset, method, MemOptions::default());
        t.row(vec![
            label.to_string(),
            fmt(r.final_ppl, 2),
            fmt(r.n_params as f64 / 1e6, 3),
            fmt(MemEstimate::gb(e.table2_bytes()), 4),
        ]);
        println!("  [{label}] ppl {:.2}", r.final_ppl);
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper shape: ppl improves monotonically with r and with delta;\nr=0.75r0 vs 1.25r0 spans ~1.5 ppl at 60M; delta 0.01->0.05 ~1.4 ppl.");
    Ok(())
}

//! Proposition 1: BA + S is full rank w.h.p. once the uniform support
//! density passes δ = Ω(log n / n). Monte-Carlo over a δ grid at several
//! n, using the in-repo Jacobi SVD for the rank test.
//!
//!   cargo bench --bench prop1_rank -- --trials 30

use sltrain::analysis::prop1::{critical_delta, full_rank_probability};
use sltrain::bench::{fmt, Table};
use sltrain::util::cli::Cli;

fn main() -> anyhow::Result<()> {
    let a = Cli::new("prop1_rank", "Proposition 1 Monte-Carlo verification")
        .opt("trials", "15", "trials per (n, delta) cell")
        .opt("rank", "4", "low-rank dimension r")
        .opt("csv", "results/prop1.csv", "output CSV")
        .parse_env();
    let trials = a.usize("trials");
    let r = a.usize("rank");

    let mut t = Table::new(
        &format!("Prop 1 — P[rank(BA+S) = n], r={r}, {trials} trials/cell"),
        &["n", "delta*=2ln(n)/n", "0.25x", "0.5x", "1x", "2x", "4x"],
    );
    for n in [16usize, 32, 48] {
        let crit = critical_delta(n);
        let mut row = vec![n.to_string(), fmt(crit, 4)];
        for mult in [0.25, 0.5, 1.0, 2.0, 4.0] {
            let p = full_rank_probability(n, r, crit * mult, trials, 7 + n as u64);
            row.push(fmt(p, 2));
        }
        t.row(row);
    }
    t.print();
    t.save_csv(&a.str("csv"))?;
    println!("\npaper shape: a sharp transition to P≈1 around the log(n)/n threshold —\nthe theoretical basis for tiny delta giving full-rank weights.");
    Ok(())
}
